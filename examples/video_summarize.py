"""Video summarization with SS (paper §4.3 / §5.13) on a synthetic SumMe-like
video: select 15% of frames, compare SS against full greedy and the first-15%
baseline, report timing and F1 against the novelty reference.

    PYTHONPATH=src python examples/video_summarize.py [--frames 2000]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import frame_f1
from benchmarks.table2_video import _reference
from repro.core import FeatureCoverage, greedy
from repro.core.sparsify import ss_sparsify
from repro.data import video


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=2000)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    X = video(args.seed, args.frames, n_features=256)
    k = int(0.15 * args.frames)
    fn = FeatureCoverage(W=jnp.asarray(X), phi="sqrt")
    print(f"video: {args.frames} frames, budget k = {k} (15%)")

    t0 = time.perf_counter()
    full = jax.block_until_ready(greedy(fn, k))
    t_full = time.perf_counter() - t0

    key = jax.random.PRNGKey(args.seed)
    t0 = time.perf_counter()
    ss = ss_sparsify(fn, key, r=8, c=8.0)
    red = jax.block_until_ready(greedy(fn, k, alive=ss.vprime))
    t_ss = time.perf_counter() - t0

    ref = _reference(X)
    nv = int(jnp.sum(ss.vprime))
    print(f"greedy: f={float(full.value):.3f}  {t_full:.2f}s")
    print(f"SS:     f={float(red.value):.3f}  {t_ss:.2f}s  "
          f"|V'|={nv} ({100 * nv / args.frames:.0f}% kept)")
    print(f"relative utility: {float(red.value / full.value):.4f}")
    for name, sel in [("greedy", np.asarray(full.selected)),
                      ("ss", np.asarray(red.selected)),
                      ("first15%", np.arange(k))]:
        print(f"  F1 vs reference [{name:9s}]: "
              f"{frame_f1(sel, ref, args.frames):.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
