"""Serving with SS KV-cache pruning (beyond-paper): prefill a prompt, prune
the KV cache to a budget with submodular selection of representative
positions, keep decoding, and compare fidelity against random pruning.

    PYTHONPATH=src python examples/serve_kv_pruning.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import decode_step, init_params, prefill
from repro.serve import KVSelectConfig, prune_cache


def main() -> int:
    cfg = configs.smoke("qwen2-7b")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S, budget = 2, 48, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    logits, cache = prefill(cfg, params, toks, max_len=S + 16)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    ref, _ = decode_step(cfg, params, nxt, cache, jnp.int32(S))

    # SS pruning
    pruned, clen, kept = prune_cache(
        cfg, cache, S, KVSelectConfig(budget=budget), key
    )
    out_ss, _ = decode_step(cfg, params, nxt, pruned, clen, pos=jnp.int32(S))

    # random pruning baseline
    rng = np.random.default_rng(0)
    kept_r = jnp.asarray(
        np.sort(rng.choice(S, budget, replace=False))
    )[None].repeat(B, 0)

    def compact(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        if names[-1] not in ("k", "v"):
            return leaf
        def per_row(row, idx):
            return jnp.zeros_like(row).at[:budget].set(row[idx])
        if leaf.ndim == 5:
            return jax.vmap(lambda g: jax.vmap(per_row)(g, kept_r))(leaf)
        return jax.vmap(per_row)(leaf, kept_r)

    rand = jax.tree_util.tree_map_with_path(compact, cache)
    out_r, _ = decode_step(cfg, params, nxt, rand, jnp.int32(budget),
                           pos=jnp.int32(S))

    mse_ss = float(jnp.mean((out_ss - ref) ** 2))
    mse_r = float(jnp.mean((out_r - ref) ** 2))
    agree_ss = float(jnp.mean(jnp.argmax(out_ss, -1) == jnp.argmax(ref, -1)))
    agree_r = float(jnp.mean(jnp.argmax(out_r, -1) == jnp.argmax(ref, -1)))
    print(f"KV cache {S} -> {budget} positions "
          f"({100 * budget / S:.0f}% kept)")
    print(f"  SS pruning:     logit MSE {mse_ss:.4f}, "
          f"next-token agreement {agree_ss:.2f}")
    print(f"  random pruning: logit MSE {mse_r:.4f}, "
          f"next-token agreement {agree_r:.2f}")
    print("kept positions (row 0):", kept[0].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
