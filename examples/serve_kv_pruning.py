"""Serving with SS KV-cache pruning (beyond-paper): prefill a prompt, prune
the KV cache to a budget with submodular selection of representative
positions, keep decoding, and compare fidelity against random pruning.

The selection runs through the summarization *service*
(repro.serve.summarize_service): the decode batch's pooled key-features are
one micro-batched lane of SS + compact greedy, executed as a single compiled
loop — ``Engine.prune_kv`` rides the same execution core, so the explicit
service round-trip below (through the stable ``repro.api`` facade) selects
the identical positions.

    PYTHONPATH=src python examples/serve_kv_pruning.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import api, configs
from repro.models import init_params
from repro.serve import Engine, KVSelectConfig, ServeConfig, SummarizeRequest
from repro.serve.kv_select import pooled_keys


def main() -> int:
    cfg = configs.smoke("qwen2-7b")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S, budget = 2, 48, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    engine = Engine(cfg, params, ServeConfig(max_len=S + 16))
    logits, cache = engine.prefill(toks)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    ref, _ = engine.decode_with_cache(nxt, cache, jnp.int32(S))

    # SS pruning — Engine.prune_kv drives the service's batched execution
    # core; KV selection knobs ride KVSelectConfig, execution knobs its
    # nested RunConfig.
    pruned, clen, kept = engine.prune_kv(
        cache, S, key, KVSelectConfig(budget=budget)
    )
    out_ss, _ = engine.decode_with_cache(nxt, pruned, clen, pos=jnp.int32(S))

    # The same selection as an explicit service round-trip: one request per
    # decode row, same per-row keys — the queue micro-batches them into one
    # lane and must pick the identical positions.
    svc = api.serve(api.RunConfig(backend="oracle", max_batch=8))
    feats = pooled_keys(cache, S)
    row_keys = jax.random.split(key, B)
    responses = svc.run([
        SummarizeRequest(k=budget, key=row_keys[i], features=feats[i])
        for i in range(B)
    ])
    kept_svc = jnp.stack([jnp.sort(r.selected) for r in responses])
    assert bool(jnp.all(kept_svc == kept)), "service/prune_cache must agree"
    st = svc.stats()
    print(f"service round-trip: {st['queries']} queries in {st['batches']} "
          f"micro-batch(es), padding waste {st['padding_waste_frac']:.0%}, "
          f"|V'|={responses[0].vprime_size}, "
          f"eps^={responses[0].eps_hat:.4f}")

    # random pruning baseline
    rng = np.random.default_rng(0)
    kept_r = jnp.asarray(
        np.sort(rng.choice(S, budget, replace=False))
    )[None].repeat(B, 0)

    def compact(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        if names[-1] not in ("k", "v"):
            return leaf
        def per_row(row, idx):
            return jnp.zeros_like(row).at[:budget].set(row[idx])
        if leaf.ndim == 5:
            return jax.vmap(lambda g: jax.vmap(per_row)(g, kept_r))(leaf)
        return jax.vmap(per_row)(leaf, kept_r)

    rand = jax.tree_util.tree_map_with_path(compact, cache)
    out_r, _ = engine.decode_with_cache(nxt, rand, jnp.int32(budget),
                                        pos=jnp.int32(S))

    mse_ss = float(jnp.mean((out_ss - ref) ** 2))
    mse_r = float(jnp.mean((out_r - ref) ** 2))
    agree_ss = float(jnp.mean(jnp.argmax(out_ss, -1) == jnp.argmax(ref, -1)))
    agree_r = float(jnp.mean(jnp.argmax(out_r, -1) == jnp.argmax(ref, -1)))
    print(f"KV cache {S} -> {budget} positions "
          f"({100 * budget / S:.0f}% kept)")
    print(f"  SS pruning:     logit MSE {mse_ss:.4f}, "
          f"next-token agreement {agree_ss:.2f}")
    print(f"  random pruning: logit MSE {mse_r:.4f}, "
          f"next-token agreement {agree_r:.2f}")
    print("kept positions (row 0):", kept[0].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
