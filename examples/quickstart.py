"""Quickstart: the paper in 40 lines.

Builds a synthetic news day, runs the full greedy baseline, then Submodular
Sparsification (Algorithm 1) + greedy on the reduced set, and prints the
utility ratio, reduction, and the Theorem-2-style certificate.  The same
pipeline is then re-run on each available execution backend (oracle jnp,
Pallas kernels in interpret mode on CPU, shard_map) through the unified
``backend=`` dispatch — identical algorithm, different execution.

    PYTHONPATH=src python examples/quickstart.py [backend]
"""

import sys

import jax
import jax.numpy as jnp

from repro.core import (
    FeatureCoverage,
    StreamingFacilityLocation,
    greedy,
    selection_bucket,
    sieve_streaming,
)
from repro import api, obs
from repro.core.sparsify import ss_sparsify, summarize
from repro.data import clustered_embeddings, news_day

N, K = 4096, 10
BACKEND = sys.argv[1] if len(sys.argv) > 1 else "oracle"

print(f"ground set: {N} sentences (synthetic NYT-like day)")
W = jnp.asarray(news_day(seed=0, n_sentences=N, n_features=512))
fn = FeatureCoverage(W=W, phi="sqrt")   # the paper's f(S) = Σ_f sqrt(c_f(S))

# --- offline baseline: greedy on the full ground set -----------------------
full = greedy(fn, K, backend=BACKEND)
print(f"greedy on V:        f(S) = {float(full.value):.4f}")

# --- the paper: SS (c=8, r=8) then greedy on V' -----------------------------
# greedy auto-compacts: V' is sparse, so the per-step gains/argmax run over a
# static |V'|-sized bucket instead of all n (repro.core.greedy).
key = jax.random.PRNGKey(0)
ss = ss_sparsify(fn, key, r=8, c=8.0, backend=BACKEND)
reduced = greedy(fn, K, alive=ss.vprime, backend=BACKEND)
nv = int(jnp.sum(ss.vprime))
bucket = selection_bucket(N, nv)
sel_path = "full-width" if bucket is None else f"compact bucket={bucket}"
print(f"SS -> |V'| = {nv} ({100 * nv / N:.1f}% of V, "
      f"{int(ss.rounds)} rounds, backend={BACKEND}, selection={sel_path})")
print(f"greedy on V':       f(S) = {float(reduced.value):.4f}  "
      f"(relative = {float(reduced.value / full.value):.4f})")
print(f"certificate eps^ = {float(ss.eps_hat):.4f}  "
      f"(Thm 2: f(S') >= (1-1/e)(f(S*) - 2k*eps))")

# --- backend parity: one SS round on every registered backend ---------------
for be in ("oracle", "pallas", "sharded"):
    ss_be = ss_sparsify(fn, key, r=8, c=8.0, backend=be)
    val = float(greedy(fn, K, alive=ss_be.vprime).value)
    print(f"backend {be:8s}: |V'| = {int(jnp.sum(ss_be.vprime)):5d}  "
          f"f(S) = {val:.4f}")

# --- streaming baseline ------------------------------------------------------
sv = sieve_streaming(fn, K)
print(f"sieve-streaming:    f(S) = {float(sv.value):.4f}  "
      f"(relative = {float(sv.value / full.value):.4f})")

# --- one-call pipeline -------------------------------------------------------
res, ss2 = summarize(fn, K, key, preprune=True, importance=True)
print(f"summarize(+§3.4):   f(S) = {float(res.value):.4f}")

# --- one-call facade (the stable public surface, repro.api) ------------------
# docs/serving.md covers the full surface: RunConfig, the async SLO-aware
# scheduler (scheduler="async" + per-request deadline_s), Ticket futures,
# and the "Failure semantics" contract — admission validation, bounded
# retry + backend failover (RunConfig.max_retries / failover_backend), the
# chunk watchdog, the deadline-pressure degradation ladder
# (RunConfig.ladder), and the FaultPlan chaos-testing hook.
# Tracing on for this one request (docs/observability.md): the service
# emits request.admit / queue.wait / chunk.exec spans and the core emits
# ss.sparsify / greedy spans under them — results stay bit-identical
# (telemetry only observes outputs; tests/test_obs.py pins this).
obs.configure(trace=True)
resp = api.summarize(
    W, k=K, key=0,
    config=api.RunConfig(backend=BACKEND if BACKEND != "sharded"
                         else "oracle"),
)
obs.configure(trace=False)
if BACKEND == "oracle":                  # same key + arithmetic -> same picks
    assert (resp.selected == reduced.selected).all()
else:
    # pallas/sharded sequential runs use different execution strategies
    # (fused kernels / distributed probes); values agree, picks may not.
    assert abs(resp.value - float(reduced.value)) < 1e-3 * abs(resp.value)
print(f"api.summarize:      f(S) = {resp.value:.4f}  "
      f"(|V'| = {resp.vprime_size}, batch {resp.batch_size}/"
      f"{resp.batch_bucket}, queue {resp.queue_delay_s * 1e3:.1f} ms)")
print(obs.trace_summary())               # the request's span tree

# --- durable streaming sessions ----------------------------------------------
# A live summary per session over an unbounded element stream: each session
# runs a multi-threshold sieve online, SS periodically prunes its retained
# buffer, and (with root=<dir>) a WAL + snapshots make recovery after a
# crash bit-identical — docs/streaming.md has the full contract.  Volatile
# engine here (root=None); F matches the session config, elements stream
# one (F,) row at a time.
F_s = 64
eng = api.sessions(api.SessionConfig(k=K, n_features=F_s, buffer_cap=64,
                                     resparsify_every=16))
sid = api.open_session(key=0, engine=eng)
for row in jnp.asarray(news_day(seed=1, n_sentences=256, n_features=F_s)):
    api.append(sid, row, engine=eng)
live = api.summary(sid, engine=eng)
print(f"api.summary (live): f(S) = {live.value:.4f}  "
      f"(seen {live.seen}, retained {live.retained}, "
      f"{live.resparsifies} SS compactions)")

# --- matrix-free facility location round-trip --------------------------------
# StreamingFacilityLocation stores only (n, d) embeddings and computes
# similarity tiles on the fly — the objective for ground sets where the dense
# (n, n) sim matrix would not fit (kernels/fl_stream.py, docs/backends.md).
X = jnp.asarray(clustered_embeddings(seed=0, n=N, d=16))
sfl = StreamingFacilityLocation.from_features(X, kernel="dot")
ss_fl = ss_sparsify(sfl, key, r=8, c=8.0, backend=BACKEND)
red_fl = greedy(sfl, K, alive=ss_fl.vprime, backend=BACKEND)
full_fl = greedy(sfl, K, backend=BACKEND)
print(f"streaming FL:       f(S) = {float(red_fl.value):.4f}  "
      f"(relative = {float(red_fl.value / full_fl.value):.4f}, "
      f"|V'| = {int(jnp.sum(ss_fl.vprime))}, memory O(n*d) not O(n^2))")
assert float(red_fl.value / full_fl.value) > 0.9

assert float(reduced.value / full.value) > 0.95
print("OK: SS matches greedy at a fraction of the ground set.")
