"""End-to-end driver: train a ~small LM for a few hundred steps with the SS
coreset-selection data pipeline, checkpointing, and restart-on-preemption —
the (b) "end-to-end driver" deliverable, runnable on CPU.

    PYTHONPATH=src python examples/train_lm_ss.py [--steps 200] [--selection ss]

Compares the final loss of SS-selected batches against uniform selection on
the same redundant synthetic stream (the coreset pays off because duplicate
documents waste gradient steps).
"""

import argparse
import os
import shutil

import jax

from repro import configs
from repro.data import DataConfig, Pipeline
from repro.train import (
    Checkpointer,
    TrainConfig,
    make_train_state,
    make_train_step,
    resume_or_init,
    run,
)


def train(selection: str, steps: int, seed: int = 0, arch: str = "llama3.2-3b",
          ckpt_dir: str | None = None):
    cfg = configs.smoke(arch)
    tc = TrainConfig(optimizer="adamw", lr=1e-3, warmup_steps=10,
                     total_steps=steps)
    dc = DataConfig(batch_size=8, seq_len=96, vocab_size=cfg.vocab_size,
                    selection=selection, pool_factor=4, feature_dim=256,
                    dup_frac=0.5)
    pipe = Pipeline(dc, seed=seed)
    step = jax.jit(make_train_step(cfg, tc), donate_argnums=(0,))
    ckpt = Checkpointer(ckpt_dir or f"/tmp/repro_example_{selection}", keep=2)
    state_shape = jax.eval_shape(
        lambda: make_train_state(jax.random.PRNGKey(seed), cfg, tc))
    state, start, resumed = resume_or_init(
        ckpt, state_shape,
        lambda: make_train_state(jax.random.PRNGKey(seed), cfg, tc))
    if resumed:
        print(f"  resumed from step {start}")
    state, rep = run(state, step, pipe, ckpt, num_steps=steps,
                     start_step=start, ckpt_every=max(50, steps // 4),
                     log_every=max(1, steps // 8),
                     log_fn=lambda s: print("  " + s))

    # held-out eval: FRESH, duplicate-free documents.  (Train loss is the
    # wrong yardstick on a redundant stream — uniform batches contain
    # near-duplicates that are easy to memorize.)
    from repro.data.synthetic import lm_documents
    from repro.models import forward, lm_loss
    import jax.numpy as jnp

    docs = lm_documents(999_999, 32, dc.seq_len + 1, cfg.vocab_size,
                        dup_frac=0.0)
    toks, labels = jnp.asarray(docs[:, :-1]), jnp.asarray(docs[:, 1:])
    logits, _ = forward(cfg, state["params"], toks)
    eval_loss = float(lm_loss(cfg, logits, labels))
    return {"train": rep.metrics_history[-1]["loss"], "eval": eval_loss}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--selection", default="both",
                    choices=["ss", "uniform", "both"])
    args = ap.parse_args()

    results = {}
    sels = ["uniform", "ss"] if args.selection == "both" else [args.selection]
    for sel in sels:
        d = f"/tmp/repro_example_{sel}"
        shutil.rmtree(d, ignore_errors=True)
        print(f"[{sel}] training {args.steps} steps...")
        results[sel] = train(sel, args.steps, ckpt_dir=d)
    print("\nloss by selection policy (eval = held-out, duplicate-free):")
    for k, v in results.items():
        print(f"  {k:8s} train {v['train']:.4f}   eval {v['eval']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
