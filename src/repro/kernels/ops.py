"""Public wrappers around the Pallas kernels, routed through the execution
backend layer (``repro.core.backend``).

There is no objective-specific dispatch here anymore: objectives advertise
kernel support through their ``pallas_divergence`` / ``pallas_gains`` hooks
(see :class:`repro.core.functions.SubmodularFunction`), and the pallas backend
falls back to the jnp oracle whenever a hook returns ``None`` (no shipped
configuration does: FeatureCoverage covers ``feat_w`` and FacilityLocation has
its fused (r, n, n) kernel in ``fl_divergence.py``).  These functions are kept as the
kernels' stable public entry points for tests and benchmarks;
``repro.core.sparsify.ss_sparsify(backend="pallas")`` and the greedy driver
reach the same code through the backend registry.
"""

from __future__ import annotations

import jax

from repro.core.backend import default_pallas_interpret, get_backend

Array = jax.Array


def ss_divergence(
    fn,
    probes: Array,
    residual: Array,
    state: Array | None = None,
    **block_kw,
) -> Array:
    """Kernel-backed divergence w_{U,v} (paper Def. 2).  Shape (n,).

    Matches ``repro.core.graph.divergence`` on all *live* candidates
    (candidates v equal to a probe are owned by V' and their entry is
    unspecified — the SS loop never reads them).
    """
    return get_backend("pallas").divergence(
        fn, probes, residual=residual, state=state, **block_kw
    )


def ss_divergence_compact(
    fn,
    probes: Array,
    cand_idx: Array,
    residual: Array,
    state: Array | None = None,
    **block_kw,
) -> Array:
    """Kernel-backed compacted divergence over candidates ``cand_idx``.  (k,).

    Elementwise equal to ``ss_divergence(...)[cand_idx]`` — the shrink-aware
    SS loop's hot path (grid cost tracks the live count, not n).
    """
    return get_backend("pallas").divergence_compact(
        fn, probes, cand_idx, residual=residual, state=state, **block_kw
    )


def feature_gains(fn, state: Array, **block_kw) -> Array:
    """Kernel-backed greedy gains f(v|S) for all v.  Shape (n,)."""
    return get_backend("pallas").gains(fn, state, **block_kw)


def _interpret() -> bool:
    """Deprecated alias — use repro.core.backend.default_pallas_interpret."""
    return default_pallas_interpret()
