"""Jitted public wrappers around the Pallas kernels.

These adapt the kernels to the ``repro.core`` objects (FeatureCoverage /
FacilityLocation) and dispatch between the real TPU kernel and interpret mode
(CPU correctness path).  ``repro.core.sparsify.ss_sparsify(use_kernel=True)``
and the greedy driver route their hot loops through here.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.functions import FacilityLocation, FeatureCoverage
from repro.kernels.feature_gains import feature_gains_kernel
from repro.kernels.ss_weights import ss_divergence_kernel

Array = jax.Array


def _interpret() -> bool:
    """Pallas interpret mode unless we are actually on TPU."""
    if os.environ.get("REPRO_PALLAS_INTERPRET"):
        return os.environ["REPRO_PALLAS_INTERPRET"] == "1"
    return jax.default_backend() != "tpu"


def _fc_cap(fn: FeatureCoverage) -> Array | None:
    if fn.phi != "satcov":
        return None
    return fn.alpha * jnp.sum(fn.W, axis=0)


def ss_divergence(
    fn,
    probes: Array,
    residual: Array,
    state: Array | None = None,
    **block_kw,
) -> Array:
    """Kernel-backed divergence w_{U,v} (paper Def. 2).  Shape (n,).

    Matches ``repro.core.graph.divergence`` on all *live* candidates
    (candidates v equal to a probe are owned by V' and their entry is
    unspecified — the SS loop never reads them).
    """
    if isinstance(fn, FeatureCoverage):
        base = fn.empty_state() if state is None else state
        CU = base[None, :] + fn.W[probes]                   # (r, F)
        cap = _fc_cap(fn)
        from repro.kernels.ref import _phi as _phi_ref

        phi_cu = jnp.sum(
            _phi_ref(fn.phi, CU.astype(jnp.float32), cap), axis=-1
        )
        if fn.feat_w is not None:
            # Fold feature weights into W/CU (phi is applied per feature and
            # then weighted: sum_f w_f * phi(x_f) — kernel has no feat_w path,
            # so fall back to the jnp oracle in that case).
            from repro.core import graph

            return graph.divergence(fn, probes, residual=residual, state=state)
        return ss_divergence_kernel(
            fn.W,
            CU,
            phi_cu,
            residual[probes],
            cap,
            phi=fn.phi,
            interpret=_interpret(),
            **block_kw,
        )
    if isinstance(fn, FacilityLocation):
        # Similarity-based objective: same fused pattern, (r, n, n) reduction.
        from repro.core import graph

        return graph.divergence(fn, probes, residual=residual, state=state)
    raise TypeError(type(fn))


def feature_gains(fn: FeatureCoverage, state: Array, **block_kw) -> Array:
    """Kernel-backed greedy gains f(v|S) for all v.  Shape (n,)."""
    assert isinstance(fn, FeatureCoverage)
    if fn.feat_w is not None:
        return fn.gains(state)
    cap = _fc_cap(fn)
    from repro.kernels.ref import _phi as _phi_ref

    phi_c = jnp.sum(_phi_ref(fn.phi, state.astype(jnp.float32), cap))
    return feature_gains_kernel(
        fn.W, state, phi_c, cap, phi=fn.phi, interpret=_interpret(), **block_kw
    )
