"""Pallas TPU flash attention — the fused form of
``models.attention.blockwise_attention``.

Motivation (from the dry-run profile, EXPERIMENTS.md §Perf): in the XLA path
every (bq x bk) score tile and its softmax intermediates round-trip through
HBM (~2.6e13 B/chip of the llama4 prefill_32k memory term is attention-loop
temporaries).  This kernel keeps the whole online-softmax state — scores,
running max m, running sum l, and the output accumulator — in VMEM across
the k-block reduction, so per layer the HBM traffic is exactly
q+k+v reads + out write: the roofline minimum.

Grid/tiling (v5e):
  grid = (B*H, nq, nk) — the k axis is a sequential ("arbitrary") reduction,
  (batch*head, q-block) are parallel.
  q tile   (1, bq, hd)    k/v tiles (1, bk, hd)
  VMEM scratch: acc (bq, hd) f32, m/l (bq, 128) f32 broadcast lanes.
  bq = bk = 512, hd up to 256 -> ~1.3 MB resident per program instance,
  well inside the 128 MB/core VMEM budget, MXU-aligned (multiples of 128).

Causality: k-blocks strictly above the diagonal are masked to -inf; the
caller can skip them entirely by passing ``causal_skip=True`` (grid still
visits them — Pallas grids are dense — but the body exits early, so only
the ~half below the diagonal does matmul work).

GQA is handled by the caller expanding k/v head indices (see ops.py), so the
kernel sees matched (B*H) leading axes.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

Array = jax.Array

NEG_INF = -1e30


def _flash_kernel(
    q_ref,       # (1, bq, hd)
    k_ref,       # (1, bk, hd)
    v_ref,       # (1, bk, hd)
    o_ref,       # (1, bq, hd)
    acc_ref,     # (bq, hd) f32 scratch
    m_ref,       # (bq, 128) f32 scratch (lane-broadcast running max)
    l_ref,       # (bq, 128) f32 scratch
    *,
    scale: float,
    n_k_blocks: int,
    bq: int,
    bk: int,
    causal: bool,
    window: int,
    seq_len: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level causal skip: q block i only attends k blocks with
    # start <= q_end; for windows also k_end >= q_start - window
    q_start, k_start = iq * bq, ik * bk
    run = True
    if causal:
        run = k_start <= q_start + bq - 1
        if window > 0:
            run = jnp.logical_and(run, k_start + bk - 1 >= q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)                   # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                   # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                           # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < seq_len                               # padding rows
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
            if window > 0:
                mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0:1]                              # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                              # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                     # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(
            p, axis=1, keepdims=True
        ) * jnp.ones_like(l_ref)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new * jnp.ones_like(m_ref)

    @pl.when(ik == n_k_blocks - 1)
    def _finish():
        lse = jnp.maximum(l_ref[:, 0:1], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / lse).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "interpret"),
)
def flash_attention(
    q: Array,            # (BH, S, hd)  — batch*heads flattened
    k: Array,            # (BH, S, hd)
    v: Array,            # (BH, S, hd)
    *,
    causal: bool = True,
    window: int = 0,
    bq: int = 512,
    bk: int = 512,
    interpret: bool = False,
) -> Array:
    """Fused online-softmax attention.  Returns (BH, S, hd)."""
    BH, S, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    bq = min(bq, S)
    bk = min(bk, S)
    Sp = -(-S // max(bq, bk)) * max(bq, bk)
    if Sp != S:
        pad = ((0, 0), (0, Sp - S), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    nq, nk = Sp // bq, Sp // bk

    grid = (BH, nq, nk)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, n_k_blocks=nk, bq=bq, bk=bk,
            causal=causal, window=window, seq_len=S,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]
