"""Pallas TPU kernels for the perf-critical hot spots, each with a pure-jnp
oracle in ref.py.  Dispatch is owned by the backend layer
(``repro.core.backend``; objectives opt in via their ``pallas_*`` hooks) —
ops.py keeps the kernels' stable public entry points on top of it:

- ss_weights.ss_divergence_kernel  — the paper's hot spot: fused
  submodularity-graph edge weights + min-over-probes (one HBM pass over W).
- feature_gains.feature_gains_kernel — greedy's per-step marginal gains.
- flash_attention.flash_attention  — fused online-softmax attention for the
  LM stack (the §Perf-dominant memory term of the 32k cells).
"""

from repro.kernels import ops
