"""Pallas TPU kernels for the perf-critical hot spots, each with a pure-jnp
oracle in ref.py.  Dispatch is owned by the backend layer
(``repro.core.backend``; objectives opt in via their ``pallas_*`` hooks) —
ops.py keeps the kernels' stable public entry points on top of it:

- ss_weights.ss_divergence_kernel  — the paper's hot spot: fused
  submodularity-graph edge weights + min-over-probes (one HBM pass over W),
  with an optional feat_w feature-weight tile through the phi-reduction.
- feature_gains.feature_gains_kernel — greedy's per-step marginal gains
  (same feat_w support).
- fl_divergence.fl_divergence_kernel — facility location's fused (r, n, n)
  max/accumulate divergence; fl_gains_kernel is its single-probe instance
  for greedy.
- flash_attention.flash_attention  — fused online-softmax attention for the
  LM stack (the §Perf-dominant memory term of the 32k cells).
"""

from repro.kernels import ops
