"""Pallas TPU kernel for the greedy inner loop: batched marginal gains of the
feature-based coverage objective.

g[v] = sum_f w_f phi(c_f + W[v, f]) - sum_f w_f phi(c_f)    for all v

(``feat_w`` feature weights w_f default to ones; like the divergence kernel
they ride as a resident (1, BF) tile with 0 on padded feature columns.)

This is evaluated once per greedy step (the TPU replacement for the lazy-
greedy priority queue — see DESIGN.md §3).  The kernel tiles (candidates x
features), keeps the coverage row resident, accumulates the feature reduction
into the output block and subtracts the scalar baseline at the last feature
block.  HBM traffic = one read of W + one (n,) write per step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params
from repro.kernels.ss_weights import _phi, _round_up

Array = jax.Array


def _feature_gains_kernel(
    w_ref,      # (BN, BF) candidate features tile
    c_ref,      # (1, BF)  coverage state tile
    phic_ref,   # (1, 1)   scalar sum_f w_f phi(c)
    cap_ref,    # (1, BF)
    fw_ref,     # (1, BF)  feature weights (ones when unweighted; 0 on pads)
    out_ref,    # (1, BN)
    *,
    phi: str,
    n_f_blocks: int,
):
    i_f = pl.program_id(1)

    @pl.when(i_f == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)          # (1, BF)
    cap = cap_ref[...].astype(jnp.float32)
    fw = fw_ref[...].astype(jnp.float32)
    val = _phi(phi, c + w, cap) * fw             # (BN, BF)
    out_ref[...] += jnp.sum(val, axis=1)[None, :]

    @pl.when(i_f == n_f_blocks - 1)
    def _finish():
        out_ref[...] -= phic_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("phi", "bn", "bf", "interpret"))
def feature_gains_kernel(
    W: Array,           # (n, F)
    c: Array,           # (F,)
    phi_c_total: Array,  # scalar: sum_f w_f phi(c) (weighted when feat_w given)
    cap: Array | None = None,
    feat_w: Array | None = None,  # (F,) feature weights, None = unweighted
    cand_idx: Array | None = None,  # (k,) compacted candidate buffer
    *,
    phi: str = "sqrt",
    bn: int = 512,
    bf: int = 512,
    interpret: bool = False,
) -> Array:
    # Compact-candidate path: only the gathered candidate rows enter the
    # grid; the output is the (k,) compacted gains buffer.
    if cand_idx is not None:
        W = jnp.take(W, cand_idx, axis=0)
    n, F = W.shape
    f32 = jnp.float32
    bn = min(bn, _round_up(n, 128))
    bf = min(bf, _round_up(F, 128))
    npad = _round_up(n, bn)
    fpad = _round_up(F, bf)

    Wp = jnp.zeros((npad, fpad), W.dtype).at[:n, :F].set(W)
    cp = jnp.zeros((1, fpad), f32).at[0, :F].set(c.astype(f32))
    capp = jnp.zeros((1, fpad), f32)
    if cap is not None:
        capp = capp.at[0, :F].set(cap.astype(f32))
    fwp = jnp.zeros((1, fpad), f32).at[0, :F].set(
        jnp.ones((F,), f32) if feat_w is None else feat_w.astype(f32)
    )
    phic = jnp.asarray(phi_c_total, f32).reshape(1, 1)

    # Padded feature columns have c = 0, W = 0 and weight 0 -> they contribute
    # nothing, so padding is exact.
    grid = (npad // bn, fpad // bf)
    out = pl.pallas_call(
        functools.partial(_feature_gains_kernel, phi=phi, n_f_blocks=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bf), lambda i, j: (i, j)),
            pl.BlockSpec((1, bf), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, bf), lambda i, j: (0, j)),
            pl.BlockSpec((1, bf), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, npad), f32),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(Wp, cp, phic, capp, fwp)
    return out[0, :n]
