"""Pure-jnp oracles for the Pallas kernels.  These define the exact semantics
the kernels must match (tests sweep shapes/dtypes and assert_allclose)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

INF = 1e30


def _phi(kind: str, c: Array, cap: Array | None) -> Array:
    if kind == "sqrt":
        return jnp.sqrt(jnp.maximum(c, 0.0))
    if kind == "log1p":
        return jnp.log1p(jnp.maximum(c, 0.0))
    if kind == "setcover":
        return jnp.minimum(c, 1.0)
    if kind == "satcov":
        assert cap is not None
        return jnp.minimum(c, cap)
    if kind == "linear":
        return c
    raise ValueError(kind)


def ss_divergence_ref(
    W: Array,        # (n, F) candidate feature rows
    CU: Array,       # (r, F) probe coverage rows (state + W[probe])
    phi_cu: Array,   # (r,)  precomputed sum_f phi(CU) ( = +INF for pad rows )
    resid: Array,    # (r,)  residual gains f(u | V \\ u) ( = 0 for pad rows )
    cap: Array | None,  # (F,) saturation caps for phi='satcov', else None
    phi: str = "sqrt",
    feat_w: Array | None = None,  # (F,) feature weights, None = unweighted
) -> Array:
    """w_{U,v} = min_u [ sum_f w_f phi(CU_u + W_v) - phi_cu_u - resid_u ].  (n,).

    Pad-row convention: padded probe rows carry phi_cu = -INF, so their weight
    is +INF and they never win the min.
    """
    f32 = jnp.float32
    Wf, CUf = W.astype(f32), CU.astype(f32)
    both = CUf[:, None, :] + Wf[None, :, :]          # (r, n, F)
    val = _phi(phi, both, cap)
    if feat_w is not None:
        val = val * feat_w.astype(f32)
    acc = jnp.sum(val, axis=-1)                       # (r, n)
    wmat = acc - phi_cu.astype(f32)[:, None] - resid.astype(f32)[:, None]
    return jnp.min(wmat, axis=0)


def feature_gains_ref(
    W: Array,          # (n, F)
    c: Array,          # (F,) current coverage state
    phi_c_total: Array,  # scalar: sum_f w_f phi(c)
    cap: Array | None,
    phi: str = "sqrt",
    feat_w: Array | None = None,  # (F,) feature weights, None = unweighted
) -> Array:
    """g[v] = sum_f w_f phi(c + W_v) - phi_c_total.  (n,)."""
    f32 = jnp.float32
    val = _phi(phi, c.astype(f32)[None, :] + W.astype(f32), cap)
    if feat_w is not None:
        val = val * feat_w.astype(f32)
    return jnp.sum(val, axis=-1) - jnp.asarray(phi_c_total, f32)


def fl_divergence_ref(
    sim: Array,      # (n, n) similarity; sim[i, v] = service of row i by v
    MU: Array,       # (r, n) probe coverage rows: mu[u, i] = max(state_i, sim[i, u])
    resid: Array,    # (r,)  residual gains of probes; -INF masks a probe
) -> Array:
    """Facility-location divergence:
    min_u [ sum_i max(sim[i,v] - mu[u,i], 0) - resid_u ].  (n,).

    Pad/mask-row convention: masked probe rows carry resid = -INF, so their
    weight is +INF and they never win the min.
    """
    f32 = jnp.float32
    acc = jnp.sum(
        jnp.maximum(
            sim.T.astype(f32)[None, :, :] - MU.astype(f32)[:, None, :], 0.0
        ),
        axis=-1,
    )  # (r, n)
    wmat = acc - resid.astype(f32)[:, None]
    return jnp.min(wmat, axis=0)


def flash_attention_ref(
    q, k, v, causal: bool = True, window: int = 0
):
    """Oracle for the flash-attention kernel: plain softmax attention over
    (BH, S, hd) with optional causal/sliding-window masking.  f32 math."""
    import math as _math

    BH, S, hd = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / _math.sqrt(hd)
    if causal:
        qpos = jnp.arange(S)[:, None]
        kpos = jnp.arange(S)[None, :]
        mask = qpos >= kpos
        if window > 0:
            mask = mask & (qpos - kpos < window)
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
