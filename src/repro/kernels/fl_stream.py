"""Matrix-free facility location: flash-style similarity-on-the-fly kernels.

Every dense FacilityLocation path consumes a materialized (n, n) similarity
matrix, so memory — not compute — is the scaling wall (n = 1M is 4 TB of
f32).  This module applies the memory-efficient-attention trick the repo
already ships in :mod:`repro.kernels.flash_attention` to the SS hot spots:
similarity tiles ``sim = relu(Xs_blk @ Xc_blkᵀ)`` are computed *inside* the
kernel from the (n, d) embedding rows, fused with the hinge/accumulate
reduction of :mod:`repro.kernels.fl_divergence`, and never leave VMEM — the
(n, n) matrix is never materialized anywhere.

The objective semantics are exactly dense ``FacilityLocation.from_features``
with the "dot" / "cosine" kernels (cosine = dot after row normalization, done
once at construction):

    sim[i, v] = max(x_i . x_v, 0)
    f(v | S + u) = sum_i max(sim[i, v] - mu[u, i], 0)
    w_{U,v} = min_u [ f(v | S + u) - resid_u ]

Pallas kernel (``fl_stream_divergence_kernel``), mirroring fl_divergence:
  - grid = (candidate blocks, served-row blocks); candidates parallel,
    served rows a sequential reduction.
  - Xc tile (BN, dp) and Xs tile (BI, dp): the embedding rows for this tile;
    ``sim_tile = relu(dot_general(Xs, Xc^T))`` is computed in f32 on the MXU
    (``preferred_element_type``), consumed immediately by the hinge, and
    discarded — VMEM holds (BI + BN) * dp floats instead of an (n, n) slab.
  - MU tile (RP, BI), resid (RP, 1), acc (RP, BN) persistent VMEM scratch,
    out (1, BN) written at the last served-row block: identical layout and
    accumulation order to fl_divergence's kernel.
  - pad conventions carried over: padded served rows are all-zero embedding
    rows => sim = relu(0) = 0 and mu = 0, so the hinge contributes nothing;
    padded probe rows carry resid = -INF so their weight is +INF and never
    wins the min; padded embedding columns (d -> dp) are zeros and do not
    change any dot product.
  - compact path: ``cand_idx`` gathers candidate *feature rows* (k, d) —
    a tiny gather — so only the surviving candidates enter the grid, while
    the served-row reduction still spans all rows (that is f's definition).
    This is how the streaming objective composes with the PR-3/4 live-set
    compaction for free.

Oracle block reference (``fl_stream_pair_ref``): a ``lax.scan`` over
(candidate block, served-row block) pairs with the kernel's probe-chunk inner
loop and the same served-row block size, so the accumulation order of every
output element matches the kernel's.  Peak intermediate is the
(probe_chunk, BI, BN) hinge slab — the streaming memory contract that
tests/test_fl_stream.py pins on the jaxpr.

Residual gains f(v | V \\ v) need per-served-row top-2 statistics over all
candidate columns; ``fl_stream_top2`` / ``fl_stream_count_best`` /
``fl_stream_best_loss_sum`` compute them in three matrix-free passes (the
sharded backend reuses the same passes per shard and reduces with the
existing all_gather/psum pattern of the dense objective).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params
from repro.kernels.ss_weights import _round_up

Array = jax.Array

NEG = -1e30
INF = 1e30


# --------------------------------------------------------------------------
# Pallas kernel: fused sim-tile matmul + hinge/accumulate + min-over-probes
# --------------------------------------------------------------------------
def _fl_stream_kernel(
    xs_ref,      # (BI, dp) served-row embedding tile
    xc_ref,      # (BN, dp) candidate embedding tile
    mu_ref,      # (RP, BI) probe coverage tile
    resid_ref,   # (RP, 1)  probe residual gains (-INF for pad rows)
    out_ref,     # (1, BN)  divergence tile
    acc_ref,     # (RP, BN) f32 VMEM scratch accumulator
    *,
    n_i_blocks: int,
    probe_chunk: int,
):
    i_i = pl.program_id(1)

    @pl.when(i_i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xs = xs_ref[...].astype(jnp.float32)      # (BI, dp)
    xc = xc_ref[...].astype(jnp.float32)      # (BN, dp)
    # The similarity tile, on the fly: relu(Xs_blk @ Xc_blk^T) in f32 on the
    # MXU.  It lives only in registers/VMEM for the duration of this tile.
    sim = jnp.maximum(
        jax.lax.dot_general(
            xs, xc, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ),
        0.0,
    )                                          # (BI, BN)
    mu = mu_ref[...].astype(jnp.float32)      # (RP, BI)

    rp = mu.shape[0]
    n_chunks = rp // probe_chunk

    def body(j, acc):
        # Probe chunk (PC, BI) against the whole candidate tile (BI, BN):
        # contrib[p, v] = sum_i max(sim[i, v] - mu[p, i], 0)
        mu_j = jax.lax.dynamic_slice_in_dim(mu, j * probe_chunk, probe_chunk, 0)
        val = jnp.maximum(sim[None, :, :] - mu_j[:, :, None], 0.0)
        contrib = jnp.sum(val, axis=1)        # (PC, BN)
        return jax.lax.dynamic_update_slice_in_dim(
            acc,
            jax.lax.dynamic_slice_in_dim(acc, j * probe_chunk, probe_chunk, 0)
            + contrib,
            j * probe_chunk,
            0,
        )

    acc_ref[...] = jax.lax.fori_loop(0, n_chunks, body, acc_ref[...])

    @pl.when(i_i == n_i_blocks - 1)
    def _finish():
        wmat = acc_ref[...] - resid_ref[...]                   # (RP, BN)
        out_ref[...] = jnp.min(wmat, axis=0, keepdims=True)    # (1, BN)


@functools.partial(
    jax.jit,
    static_argnames=("bn", "bi", "probe_chunk", "interpret"),
)
def fl_stream_divergence_kernel(
    X: Array,         # (ni, d) served-row embeddings
    MU: Array,        # (r, ni) probe coverage rows max(state, relu(X @ x_u))
    resid: Array,     # (r,)  residual gains f(u | V \\ u); -INF masks a probe
    cand_idx: Array | None = None,  # (k,) compacted candidate buffer
    Xc: Array | None = None,        # candidate embeddings; None = X
    *,
    bn: int = 256,
    bi: int = 256,
    probe_chunk: int = 8,
    interpret: bool = False,
) -> Array:
    """Padded + tiled pallas_call wrapper.  Returns (n,) divergences
    (or the (k,) compacted buffer when ``cand_idx`` is given).

    ``Xc`` lets a sharded local view pass candidate rows distinct from the
    served rows; ``cand_idx`` gathers rows *of Xc* — the gathered candidates
    pick which embedding rows enter the grid.
    """
    Xc = X if Xc is None else Xc
    if cand_idx is not None:
        Xc = jnp.take(Xc, cand_idx, axis=0)
    ni, d = X.shape
    n = Xc.shape[0]
    r = MU.shape[0]
    f32 = jnp.float32

    dp = _round_up(d, 128)
    bn = min(bn, _round_up(n, 128))
    bi = min(bi, _round_up(ni, 128))
    npad = _round_up(n, bn)
    ipad = _round_up(ni, bi)
    rp = _round_up(r, probe_chunk)

    Xsp = jnp.zeros((ipad, dp), f32).at[:ni, :d].set(X.astype(f32))
    Xcp = jnp.zeros((npad, dp), f32).at[:n, :d].set(Xc.astype(f32))
    MUp = jnp.zeros((rp, ipad), f32).at[:r, :ni].set(MU.astype(f32))
    residp = jnp.full((rp, 1), jnp.float32(-INF)).at[:r, 0].set(
        resid.astype(f32)
    )

    grid = (npad // bn, ipad // bi)
    out = pl.pallas_call(
        functools.partial(
            _fl_stream_kernel,
            n_i_blocks=grid[1],
            probe_chunk=probe_chunk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, dp), lambda i, j: (j, 0)),       # Xs
            pl.BlockSpec((bn, dp), lambda i, j: (i, 0)),       # Xc
            pl.BlockSpec((rp, bi), lambda i, j: (0, j)),       # MU
            pl.BlockSpec((rp, 1), lambda i, j: (0, 0)),        # resid
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, npad), f32),
        scratch_shapes=[pltpu.VMEM((rp, bn), f32)],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(Xsp, Xcp, MUp, residp)
    return out[0, :n]


def fl_stream_gains_kernel(
    X: Array,        # (ni, d) served-row embeddings
    state: Array,    # (ni,) current coverage m_i
    cand_idx: Array | None = None,
    Xc: Array | None = None,
    *,
    interpret: bool = False,
    **block_kw,
) -> Array:
    """Greedy gains f(v|S) = sum_i max(sim[i, v] - m_i, 0) for all v —
    the single-probe instance of the streaming divergence kernel (MU = the
    state row, resid = 0), exactly like fl_gains_kernel over fl_divergence."""
    return fl_stream_divergence_kernel(
        X,
        state.astype(jnp.float32)[None, :],
        jnp.zeros((1,), jnp.float32),
        cand_idx,
        Xc,
        interpret=interpret,
        **block_kw,
    )


# --------------------------------------------------------------------------
# Oracle block reference: lax.scan with the kernel's accumulation order
# --------------------------------------------------------------------------
def fl_stream_pair_ref(
    X: Array,         # (ni, d) served-row embeddings
    MU: Array,        # (r, ni) probe coverage rows
    cand_idx: Array | None = None,
    Xc: Array | None = None,
    *,
    bn: int = 2048,
    bi: int = 256,
    probe_chunk: int = 8,
) -> Array:
    """acc[u, v] = sum_i max(relu(x_i . xc_v) - mu[u, i], 0).  Shape (r, k).

    Matrix-free ``lax.scan`` block reference with the pallas kernel's
    arithmetic: an outer scan over candidate blocks, an inner scan over
    served-row blocks (same ``bi`` and zero-padding as the kernel, so the
    per-element accumulation order matches), and the kernel's probe-chunk
    fori loop inside.  Peak intermediate is the (probe_chunk, bi, bn) hinge
    slab — never anything O(n^2).
    """
    f32 = jnp.float32
    Xc = X if Xc is None else Xc
    if cand_idx is not None:
        Xc = jnp.take(Xc, cand_idx, axis=0)
    ni, d = X.shape
    n = Xc.shape[0]
    r = MU.shape[0]

    bn = min(bn, max(_round_up(n, 128), 1))
    bi = min(bi, max(_round_up(ni, 128), 1))
    npad = _round_up(n, bn)
    ipad = _round_up(ni, bi)
    rp = _round_up(r, probe_chunk)

    Xsp = jnp.zeros((ipad, d), f32).at[:ni].set(X.astype(f32))
    Xcp = jnp.zeros((npad, d), f32).at[:n].set(Xc.astype(f32))
    MUp = jnp.zeros((rp, ipad), f32).at[:r, :ni].set(MU.astype(f32))

    xs_blocks = Xsp.reshape(ipad // bi, bi, d)
    mu_blocks = jnp.moveaxis(MUp.reshape(rp, ipad // bi, bi), 1, 0)

    def cand_block(_, xc_b):                  # xc_b: (bn, d)
        def row_block(acc, inp):
            xs_b, mu_b = inp                  # (bi, d), (rp, bi)
            sim = jnp.maximum(xs_b @ xc_b.T, 0.0)          # (bi, bn)

            def chunk(j, a):
                mu_j = jax.lax.dynamic_slice_in_dim(
                    mu_b, j * probe_chunk, probe_chunk, 0
                )
                val = jnp.maximum(sim[None, :, :] - mu_j[:, :, None], 0.0)
                contrib = jnp.sum(val, axis=1)             # (PC, bn)
                return jax.lax.dynamic_update_slice_in_dim(
                    a,
                    jax.lax.dynamic_slice_in_dim(
                        a, j * probe_chunk, probe_chunk, 0
                    )
                    + contrib,
                    j * probe_chunk,
                    0,
                )

            acc = jax.lax.fori_loop(0, rp // probe_chunk, chunk, acc)
            return acc, None

        acc0 = jnp.zeros((rp, bn), f32)
        acc, _ = jax.lax.scan(row_block, acc0, (xs_blocks, mu_blocks))
        return None, acc

    _, accs = jax.lax.scan(
        cand_block, None, Xcp.reshape(npad // bn, bn, d)
    )                                          # (ncb, rp, bn)
    acc = jnp.moveaxis(accs, 0, 1).reshape(rp, npad)
    return acc[:r, :n]


def fl_stream_divergence_ref(
    X: Array,
    MU: Array,
    resid: Array,     # (r,); -INF masks a probe
    cand_idx: Array | None = None,
    Xc: Array | None = None,
    **block_kw,
) -> Array:
    """w_{U,v} = min_u [ acc[u, v] - resid_u ].  (n,) (or (k,) compacted).
    The jnp oracle the streaming kernel's parity is pinned against."""
    acc = fl_stream_pair_ref(X, MU, cand_idx, Xc, **block_kw)
    return jnp.min(acc - resid.astype(jnp.float32)[:, None], axis=0)


# --------------------------------------------------------------------------
# Matrix-free column reductions: running max / top-2 / best-count passes
# --------------------------------------------------------------------------
def _cand_blocks(Xc: Array, bv: int):
    """Pad candidate rows to a multiple of ``bv`` and return (blocks, valid):
    (ncb, bv, d) embedding blocks and the (ncb, bv) validity mask."""
    n, d = Xc.shape
    bv = min(bv, max(n, 1))
    npad = _round_up(n, bv)
    Xcp = jnp.zeros((npad, d), jnp.float32).at[:n].set(Xc.astype(jnp.float32))
    valid = (jnp.arange(npad) < n).reshape(-1, bv)
    return Xcp.reshape(-1, bv, d), valid


def fl_stream_col_max(
    X: Array,         # (ni, d) served rows
    Xc: Array,        # (n, d) candidate rows
    mask: Array | None = None,  # (n,) candidate mask; None = all
    *,
    bv: int = 2048,
) -> Array:
    """max over (masked) candidates v of sim[i, v] per served row i.  (ni,).
    All-masked rows return NEG (the dense add_many convention)."""
    Xs = X.astype(jnp.float32)
    blocks, valid = _cand_blocks(Xc, bv)
    if mask is not None:
        npad = valid.size
        mpad = jnp.zeros((npad,), bool).at[: mask.shape[0]].set(mask)
        valid = valid & mpad.reshape(valid.shape)

    def blk(run, inp):
        xc_b, ok_b = inp
        cols = jnp.maximum(Xs @ xc_b.T, 0.0)               # (ni, bv)
        cols = jnp.where(ok_b[None, :], cols, NEG)
        return jnp.maximum(run, jnp.max(cols, axis=1)), None

    run0 = jnp.full((X.shape[0],), jnp.float32(NEG))
    run, _ = jax.lax.scan(blk, run0, (blocks, valid))
    return run


def fl_stream_top2(
    X: Array,         # (ni, d) served rows
    Xc: Array,        # (n, d) candidate rows
    *,
    bv: int = 2048,
) -> Array:
    """Per-served-row top-2 of sim[i, :] over the candidate columns.  (ni, 2).
    Streaming merge of per-block top-2s — equal values merge exactly like the
    dense ``lax.top_k(sim, 2)`` (ties yield best == second)."""
    Xs = X.astype(jnp.float32)
    blocks, valid = _cand_blocks(Xc, bv)
    k2 = min(2, blocks.shape[1])

    def blk(run, inp):
        xc_b, ok_b = inp
        cols = jnp.maximum(Xs @ xc_b.T, 0.0)               # (ni, bv)
        cols = jnp.where(ok_b[None, :], cols, NEG)
        t = jax.lax.top_k(cols, k2)[0]                     # (ni, k2)
        merged = jax.lax.top_k(jnp.concatenate([run, t], axis=1), 2)[0]
        return merged, None

    run0 = jnp.full((X.shape[0], 2), jnp.float32(NEG))
    run, _ = jax.lax.scan(blk, run0, (blocks, valid))
    return run


def fl_stream_count_best(
    X: Array,
    Xc: Array,
    best: Array,      # (ni,) per-row max similarity
    *,
    bv: int = 2048,
) -> Array:
    """Number of candidate columns achieving sim[i, v] >= best_i per row.
    (ni,) int32 — the tie count of the dense residual computation."""
    Xs = X.astype(jnp.float32)
    blocks, valid = _cand_blocks(Xc, bv)

    def blk(run, inp):
        xc_b, ok_b = inp
        cols = jnp.maximum(Xs @ xc_b.T, 0.0)
        hit = (cols >= best[:, None]) & ok_b[None, :]
        return run + jnp.sum(hit, axis=1).astype(jnp.int32), None

    run0 = jnp.zeros((X.shape[0],), jnp.int32)
    run, _ = jax.lax.scan(blk, run0, (blocks, valid))
    return run


def fl_stream_best_loss_sum(
    X: Array,
    Xc: Array,
    best: Array,      # (ni,)
    loss: Array,      # (ni,) per-row loss if v is the unique argmax
    *,
    bv: int = 2048,
) -> Array:
    """resid[v] = sum_i 1[sim[i, v] >= best_i] * loss_i per candidate.  (n,).
    The scatter pass of the matrix-free residual computation."""
    Xs = X.astype(jnp.float32)
    n = Xc.shape[0]
    blocks, valid = _cand_blocks(Xc, bv)

    def blk(_, inp):
        xc_b, ok_b = inp
        cols = jnp.maximum(Xs @ xc_b.T, 0.0)               # (ni, bv)
        is_best = cols >= best[:, None]
        out = jnp.sum(jnp.where(is_best, loss[:, None], 0.0), axis=0)
        return None, jnp.where(ok_b, out, 0.0)

    _, outs = jax.lax.scan(blk, None, (blocks, valid))
    return outs.reshape(-1)[:n]


def fl_stream_residuals(
    X: Array,         # (ni, d) served rows
    Xc: Array | None = None,  # candidate rows; None = X
    *,
    bv: int = 2048,
) -> Array:
    """f(v | V \\ v) for every candidate — three matrix-free passes with the
    dense FacilityLocation.residual_gains tie semantics (rows whose best is
    achieved by >1 column lose nothing when one of them leaves)."""
    Xc = X if Xc is None else Xc
    top2 = fl_stream_top2(X, Xc, bv=bv)
    best, second = top2[:, 0], top2[:, 1]
    cnt = fl_stream_count_best(X, Xc, best, bv=bv)
    loss = jnp.where(
        cnt > 1, 0.0, jnp.maximum(best, 0.0) - jnp.maximum(second, 0.0)
    )
    return fl_stream_best_loss_sum(X, Xc, best, loss, bv=bv)
