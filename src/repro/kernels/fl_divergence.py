"""Pallas TPU kernel for the facility-location SS hot spot: fused
submodularity-graph divergence for  f(S) = sum_i max_{s in S} sim(i, s).

Computes   w_{U,v} = min_{u in U} [ f(v | S + u) - f(u | V \\ u) ]   for every
candidate v in one pass.  With the probe coverage rows
``mu[u, i] = max(state_i, sim[i, u])``, the probe-conditioned gain is a
hinge/accumulate reduction:

    f(v | S + u) = sum_i max(sim[i, v] - mu[u, i], 0)

so  w_{U,v} = min_u [ acc[u, v] - resid[u] ]  with
``acc[u, v] = sum_i max(sim[i, v] - mu[u, i], 0)``.  The kernel accumulates
the *hinge terms* directly (not ``sum_i max(sim, mu)`` minus the baseline
``sum_i mu`` afterwards): subtracting two O(n)-magnitude sums would lose the
small inter-candidate divergence gaps to f32 cancellation at exactly the
scales the kernel exists for.

Why a kernel: the naive computation materializes the (r, n, n) hinge tensor
in HBM (r probes — r'·log2 n with the paper's r' = 8 — n candidates, n served
rows).  At n = 1e6, r = 160 that is ~0.6 PB of f32 written and read back:
over a petabyte of HBM traffic per SS round.  The kernel tiles
(candidates x served rows) into VMEM, keeps the probe coverage block resident,
accumulates the served-row reduction in a VMEM scratch accumulator, and fuses
the final min-over-probes — so HBM traffic is exactly one read of ``sim``
(n x n) plus one write of the (n,) result: the roofline minimum.

Layout / tiling (TPU v5e target), mirroring :mod:`repro.kernels.ss_weights`:
  - grid = (n_blocks, i_blocks); candidate blocks are parallel, served-row
    blocks are a sequential reduction (dimension_semantics below).
  - sim tile (BI, BN) : BI=512 served rows x BN=256 candidates = 512 KB f32.
    The tile is indexed (j, i) — rows are the *reduction* dimension — so the
    kernel consumes ``sim`` in its natural (served row, candidate) layout and
    no transpose is ever materialized.
  - MU tile  (RP, BI) : all probe coverage rows resident per served-row block
    (RP = r padded to a multiple of the probe chunk).
  - acc      (RP, BN) f32 VMEM scratch, persistent across the i reduction.
  - out tile (1, BN)  written once, at the last served-row block.
Like the feature-coverage kernel, the reduction is a nonlinear (max) transform
— VPU work by nature; the win is HBM -> VMEM blocking, which dominates at
scale.

The pure-jnp reference lives in :func:`repro.kernels.ref.fl_divergence_ref`;
parity is enforced in interpret mode by tests/test_kernels.py and the CI
kernel-bench gate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params
from repro.kernels.ss_weights import _round_up

Array = jax.Array


def _fl_divergence_kernel(
    sim_ref,     # (BI, BN) similarity tile: rows = served, cols = candidates
    mu_ref,      # (RP, BI) probe coverage tile
    resid_ref,   # (RP, 1)  probe residual gains (-INF for pad rows)
    out_ref,     # (1, BN)  divergence tile
    acc_ref,     # (RP, BN) f32 VMEM scratch accumulator
    *,
    n_i_blocks: int,
    probe_chunk: int,
):
    i_i = pl.program_id(1)

    @pl.when(i_i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sim = sim_ref[...].astype(jnp.float32)    # (BI, BN)
    mu = mu_ref[...].astype(jnp.float32)      # (RP, BI)

    rp = mu.shape[0]
    n_chunks = rp // probe_chunk

    def body(j, acc):
        # Probe chunk (PC, BI) against the whole candidate tile (BI, BN):
        # contrib[p, v] = sum_i max(sim[i, v] - mu[p, i], 0)
        mu_j = jax.lax.dynamic_slice_in_dim(mu, j * probe_chunk, probe_chunk, 0)
        val = jnp.maximum(sim[None, :, :] - mu_j[:, :, None], 0.0)
        contrib = jnp.sum(val, axis=1)        # (PC, BN)
        return jax.lax.dynamic_update_slice_in_dim(
            acc,
            jax.lax.dynamic_slice_in_dim(acc, j * probe_chunk, probe_chunk, 0)
            + contrib,
            j * probe_chunk,
            0,
        )

    acc_ref[...] = jax.lax.fori_loop(0, n_chunks, body, acc_ref[...])

    @pl.when(i_i == n_i_blocks - 1)
    def _finish():
        wmat = acc_ref[...] - resid_ref[...]                   # (RP, BN)
        out_ref[...] = jnp.min(wmat, axis=0, keepdims=True)    # (1, BN)


@functools.partial(
    jax.jit,
    static_argnames=("bn", "bi", "probe_chunk", "interpret"),
)
def fl_divergence_kernel(
    sim: Array,       # (ni, n) similarity; sim[i, v] = service of row i by v
    MU: Array,        # (r, ni) probe coverage rows max(state, sim[:, u])
    resid: Array,     # (r,)  residual gains f(u | V \\ u); -INF masks a probe
    cand_idx: Array | None = None,  # (k,) compacted candidate buffer
    *,
    bn: int = 256,
    bi: int = 512,
    probe_chunk: int = 8,
    interpret: bool = False,
) -> Array:
    """Padded + tiled pallas_call wrapper.  Returns (n,) divergences.

    Pad-row convention: padded (and caller-masked) probe rows carry
    ``resid = -INF`` so their edge weight ``acc - resid`` is +INF and they
    never win the min.  Padded served rows are all-zero in both ``sim`` and
    ``MU``, so the hinge ``max(0 - 0, 0) = 0`` contributes nothing.

    Compact-candidate path: with ``cand_idx`` (k,) only the gathered candidate
    *columns* enter the grid (the served-row reduction still spans all ni rows
    — that is f's definition) and the output is the (k,) compacted buffer.
    The served-row blocking is unchanged, so per-candidate accumulation order
    — and hence the output — matches the full grid bitwise.
    """
    if cand_idx is not None:
        sim = jnp.take(sim, cand_idx, axis=1)
    ni, n = sim.shape
    r = MU.shape[0]
    f32 = jnp.float32

    bn = min(bn, _round_up(n, 128))
    bi = min(bi, _round_up(ni, 128))
    npad = _round_up(n, bn)
    ipad = _round_up(ni, bi)
    rp = _round_up(r, probe_chunk)

    INF = jnp.float32(1e30)
    simp = jnp.zeros((ipad, npad), sim.dtype).at[:ni, :n].set(sim)
    MUp = jnp.zeros((rp, ipad), f32).at[:r, :ni].set(MU.astype(f32))
    residp = jnp.full((rp, 1), -INF).at[:r, 0].set(resid.astype(f32))

    grid = (npad // bn, ipad // bi)
    out = pl.pallas_call(
        functools.partial(
            _fl_divergence_kernel,
            n_i_blocks=grid[1],
            probe_chunk=probe_chunk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, bn), lambda i, j: (j, i)),       # sim
            pl.BlockSpec((rp, bi), lambda i, j: (0, j)),       # MU
            pl.BlockSpec((rp, 1), lambda i, j: (0, 0)),        # resid
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, npad), f32),
        scratch_shapes=[pltpu.VMEM((rp, bn), f32)],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(simp, MUp, residp)
    return out[0, :n]


def fl_gains_kernel(
    sim: Array,      # (n, n)
    state: Array,    # (n,) current coverage m_i = max(0, max_{s in S} sim[i, s])
    cand_idx: Array | None = None,  # (k,) compacted candidate buffer
    *,
    interpret: bool = False,
    **block_kw,
) -> Array:
    """Greedy gains f(v|S) = sum_i max(sim[i, v] - m_i, 0) for all v.  (n,)
    — or the (k,) compacted buffer when ``cand_idx`` is given.

    A single-probe instance of the divergence kernel: with MU = state (one
    row) and resid = 0 the fused output is exactly f(v|S) — same tiling, no
    separate kernel to maintain.
    """
    return fl_divergence_kernel(
        sim,
        state.astype(jnp.float32)[None, :],
        jnp.zeros((1,), jnp.float32),
        cand_idx,
        interpret=interpret,
        **block_kw,
    )
