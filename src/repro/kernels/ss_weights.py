"""Pallas TPU kernel for the SS hot spot: fused submodularity-graph divergence.

Computes   w_{U,v} = min_{u in U} [ f(v | S + u) - f(u | V \\ u) ]   for every
candidate v in one pass, for the feature-based objective
f(S) = sum_f w_f * phi(c_f(S)) — the optional ``feat_w`` feature-weight vector
rides through the phi-reduction as a resident (1, BF) tile (weights default to
ones; padded feature columns carry weight 0, which also makes the padding
exact for any phi).

Why a kernel: the naive computation materializes the (r, n, F) tensor
phi(CU[u] + W[v]) in HBM (r = |U| = r·log n probes, n candidates, F features).
At n = 1e6, r = 160, F = 4096 that is 2.6 PB of f32 traffic.  The kernel tiles
(candidates x features) into VMEM, keeps the probe block resident, accumulates
the feature reduction in a VMEM scratch accumulator, and fuses the final
min-over-probes — so HBM traffic is exactly one read of W (n x F) plus one
write of the (n,) result: the roofline minimum.

Layout / tiling (TPU v5e target):
  - grid = (n_blocks, f_blocks); candidate blocks are parallel, feature blocks
    are a sequential reduction (dimension_semantics below).
  - W tile   (BN, BF)  : BN=256 candidates x BF=512 features = 512 KB f32.
  - CU tile  (RP, BF)  : all probes resident per feature block (RP = r padded
    to a multiple of 8 sublanes).
  - acc      (RP, BN)  f32 VMEM scratch, persistent across the f reduction.
  - out tile (1, BN)   written once, at the last feature block.
MXU note: phi is a nonlinear (concave) transform, so the reduction cannot be
expressed as a matmul — this kernel is VPU work by nature; the MXU-bound parts
of the system live in the LM stack.  The win here is pure memory-hierarchy
management (HBM -> VMEM blocking), which is what dominates at scale.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

Array = jax.Array


def _phi(kind: str, c, cap):
    if kind == "sqrt":
        return jnp.sqrt(jnp.maximum(c, 0.0))
    if kind == "log1p":
        return jnp.log1p(jnp.maximum(c, 0.0))
    if kind == "setcover":
        return jnp.minimum(c, 1.0)
    if kind == "satcov":
        return jnp.minimum(c, cap)
    if kind == "linear":
        return c
    raise ValueError(kind)


def _ss_divergence_kernel(
    w_ref,       # (BN, BF) candidate features tile
    cu_ref,      # (RP, BF) probe coverage tile
    phicu_ref,   # (RP, 1)  sum_f w_f phi(CU) per probe (-INF for pad rows)
    resid_ref,   # (RP, 1)  probe residual gains
    cap_ref,     # (1, BF)  satcov caps (zeros otherwise)
    fw_ref,      # (1, BF)  feature weights (ones when unweighted; 0 on pads)
    out_ref,     # (1, BN)  divergence tile
    acc_ref,     # (RP, BN) f32 VMEM scratch accumulator
    *,
    phi: str,
    n_f_blocks: int,
    probe_chunk: int,
):
    i_f = pl.program_id(1)

    @pl.when(i_f == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...].astype(jnp.float32)        # (BN, BF)
    cu = cu_ref[...].astype(jnp.float32)      # (RP, BF)
    cap = cap_ref[...].astype(jnp.float32)    # (1, BF)
    fw = fw_ref[...].astype(jnp.float32)      # (1, BF)

    rp = cu.shape[0]
    n_chunks = rp // probe_chunk

    def body(j, acc):
        # Probe chunk (PC, BF) against the whole candidate tile (BN, BF):
        # contrib[p, v] = sum_f w_f * phi(cu[p, f] + w[v, f])
        cu_j = jax.lax.dynamic_slice_in_dim(cu, j * probe_chunk, probe_chunk, 0)
        val = _phi(phi, cu_j[:, None, :] + w[None, :, :], cap[None, :, :])
        contrib = jnp.sum(val * fw[None, :, :], axis=-1)  # (PC, BN)
        return jax.lax.dynamic_update_slice_in_dim(
            acc,
            jax.lax.dynamic_slice_in_dim(acc, j * probe_chunk, probe_chunk, 0)
            + contrib,
            j * probe_chunk,
            0,
        )

    acc_ref[...] = jax.lax.fori_loop(0, n_chunks, body, acc_ref[...])

    @pl.when(i_f == n_f_blocks - 1)
    def _finish():
        wmat = acc_ref[...] - phicu_ref[...] - resid_ref[...]   # (RP, BN)
        out_ref[...] = jnp.min(wmat, axis=0, keepdims=True)     # (1, BN)


@functools.partial(
    jax.jit,
    static_argnames=("phi", "bn", "bf", "probe_chunk", "interpret"),
)
def ss_divergence_kernel(
    W: Array,         # (n, F)
    CU: Array,        # (r, F)
    phi_cu: Array,    # (r,)  sum_f w_f phi(CU)  (weighted when feat_w given)
    resid: Array,     # (r,)
    cap: Array | None = None,
    feat_w: Array | None = None,  # (F,) feature weights, None = unweighted
    cand_idx: Array | None = None,  # (k,) compacted candidate buffer
    *,
    phi: str = "sqrt",
    bn: int = 256,
    bf: int = 512,
    probe_chunk: int = 8,
    interpret: bool = False,
) -> Array:
    """Padded + tiled pallas_call wrapper.  Returns (n,) divergences.

    Compact-candidate path: with ``cand_idx`` (k,) the kernel grid covers only
    the gathered k candidate rows — dead candidates cost neither HBM reads nor
    grid cells — and the output is the (k,) compacted divergence buffer.
    Per-candidate arithmetic (feature blocking, accumulation order) is
    identical to the full grid, so compacted and full outputs match bitwise.
    """
    if cand_idx is not None:
        W = jnp.take(W, cand_idx, axis=0)
    n, F = W.shape
    r = CU.shape[0]
    f32 = jnp.float32

    bn = min(bn, _round_up(n, 128))
    bf = min(bf, _round_up(F, 128))
    npad = _round_up(n, bn)
    fpad = _round_up(F, bf)
    rp = _round_up(r, probe_chunk)

    INF = jnp.float32(1e30)
    Wp = jnp.zeros((npad, fpad), W.dtype).at[:n, :F].set(W)
    CUp = jnp.zeros((rp, fpad), f32).at[:r, :F].set(CU.astype(f32))
    # Pad rows: phi_cu = -INF => weight +INF, never the min.
    phicup = jnp.full((rp, 1), -INF).at[:r, 0].set(phi_cu.astype(f32))
    residp = jnp.zeros((rp, 1), f32).at[:r, 0].set(resid.astype(f32))
    capp = jnp.zeros((1, fpad), f32)
    if cap is not None:
        capp = capp.at[0, :F].set(cap.astype(f32))
    # Weight 1 on real features, 0 on padded columns (padding stays exact for
    # any phi, including hypothetical phi(0) != 0 transforms).
    fwp = jnp.zeros((1, fpad), f32).at[0, :F].set(
        jnp.ones((F,), f32) if feat_w is None else feat_w.astype(f32)
    )

    grid = (npad // bn, fpad // bf)
    out = pl.pallas_call(
        functools.partial(
            _ss_divergence_kernel,
            phi=phi,
            n_f_blocks=grid[1],
            probe_chunk=probe_chunk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bf), lambda i, j: (i, j)),       # W
            pl.BlockSpec((rp, bf), lambda i, j: (0, j)),       # CU
            pl.BlockSpec((rp, 1), lambda i, j: (0, 0)),        # phi_cu
            pl.BlockSpec((rp, 1), lambda i, j: (0, 0)),        # resid
            pl.BlockSpec((1, bf), lambda i, j: (0, j)),        # cap
            pl.BlockSpec((1, bf), lambda i, j: (0, j)),        # feat_w
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, npad), f32),
        scratch_shapes=[pltpu.VMEM((rp, bn), f32)],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(Wp, CUp, phicup, residp, capp, fwp)
    return out[0, :n]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
