"""Data substrate: synthetic corpora (paper-matched), hashed featurizers, and
the sharded pipeline with SS coreset selection."""

from repro.data.pipeline import DataConfig, Pipeline, selection_quality
from repro.data.synthetic import (
    clustered_embeddings,
    hashed_features,
    lm_documents,
    news_day,
    video,
    zipf_tokens,
)
