"""Sharded LM data pipeline with the paper's technique as a first-class
coreset-selection stage.

Flow per batch (selection="ss"):

    pool of pool_factor*B candidate sequences   (this shard's slice)
      -> hashed n-gram features (n, F)
      -> FeatureCoverage objective  f(S) = Σ_f sqrt(c_f(S))
      -> Submodular Sparsification prunes the pool to V'   (Algorithm 1)
      -> greedy on V' picks the B most feature-covering sequences
      -> batch = {tokens, labels}

i.e. exactly the paper's pipeline (SS -> greedy on the reduced set), applied
to training-data selection: each batch is a non-redundant summary of its
candidate pool.  selection="uniform" and "greedy" (no SS) are the ablation
baselines, selection="none" is a plain loader.  selection="ss_fl" swaps the
objective for the matrix-free StreamingFacilityLocation over the same hashed
rows — O(n*F) memory at any pool size, no (n, n) similarity matrix.

Sharding: each host/data shard owns a disjoint seed range (``shard_id`` /
``num_shards``); the same pipeline object drives the per-host loader at
cluster scale.  ``slow_every`` injects an artificial stall for the straggler
tests.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FeatureCoverage, StreamingFacilityLocation, greedy
from repro.core.sparsify import ss_sparsify
from repro.data import synthetic

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int = 8
    seq_len: int = 128
    vocab_size: int = 50304
    selection: str = "ss"          # none | uniform | greedy | ss | ss_fl
    pool_factor: int = 4           # candidate pool = pool_factor * batch
    feature_dim: int = 512
    ngram: int = 2
    ss_r: int = 8
    ss_c: float = 8.0
    dup_frac: float = 0.3          # redundancy planted in the synthetic stream
    num_codebooks: int = 1
    patch_count: int = 0           # >0: emit stub patch embeddings (vlm)
    d_model: int = 0               # for patch stub width


class Pipeline:
    def __init__(
        self,
        cfg: DataConfig,
        shard_id: int = 0,
        num_shards: int = 1,
        seed: int = 0,
        slow_every: int = 0,
        slow_s: float = 0.0,
    ):
        self.cfg = cfg
        self.shard_id = shard_id
        self.num_shards = num_shards
        self.seed = seed
        self.slow_every = slow_every
        self.slow_s = slow_s
        self._step = 0
        self._key = jax.random.PRNGKey(seed * 1009 + shard_id)

    # -- candidate pool -------------------------------------------------------
    def _pool(self) -> np.ndarray:
        c = self.cfg
        n = c.batch_size * (c.pool_factor if c.selection != "none" else 1)
        # +1 token so labels are a clean shift
        pool_seed = (
            self.seed * 7_919
            + self._step * self.num_shards
            + self.shard_id
        )
        return synthetic.lm_documents(
            pool_seed, n, c.seq_len + 1, c.vocab_size, dup_frac=c.dup_frac
        )

    # -- selection stage ------------------------------------------------------
    def _select(self, docs: np.ndarray) -> np.ndarray:
        c = self.cfg
        B = c.batch_size
        if c.selection in ("none",):
            return docs[:B]
        if c.selection == "uniform":
            rng = np.random.default_rng(self._step)
            return docs[rng.choice(len(docs), B, replace=False)]
        W = synthetic.hashed_features(docs[:, :-1], c.feature_dim, c.ngram)
        if c.selection == "ss_fl":
            # Matrix-free facility location over the (already l2-normalized)
            # hashed rows: SS + greedy at O(n*F) memory regardless of pool
            # size — the selection mode for pools where an (n, n) similarity
            # matrix would dwarf the batch itself.
            fn = StreamingFacilityLocation.from_features(
                jnp.asarray(W), kernel="dot"
            )
            self._key, sub = jax.random.split(self._key)
            ss = ss_sparsify(fn, sub, r=c.ss_r, c=c.ss_c)
            res = greedy(fn, B, alive=ss.vprime)
            return docs[np.asarray(res.selected)]
        fn = FeatureCoverage(W=jnp.asarray(W), phi="sqrt")
        if c.selection == "greedy":
            res = greedy(fn, B)
            return docs[np.asarray(res.selected)]
        if c.selection == "ss":
            self._key, sub = jax.random.split(self._key)
            ss = ss_sparsify(fn, sub, r=c.ss_r, c=c.ss_c)
            res = greedy(fn, B, alive=ss.vprime)
            return docs[np.asarray(res.selected)]
        raise ValueError(c.selection)

    # -- batch emission ---------------------------------------------------------
    def __call__(self) -> dict:
        if self.slow_every and self._step > 0 and self._step % self.slow_every == 0:
            time.sleep(self.slow_s)   # injected straggler
        docs = self._select(self._pool())
        self._step += 1
        c = self.cfg
        tokens = docs[:, :-1]
        labels = docs[:, 1:]
        if c.num_codebooks > 1:
            # replicate the stream into K codebooks with per-book offsets
            reps = np.stack(
                [(tokens + k) % c.vocab_size for k in range(c.num_codebooks)],
                axis=-1,
            )
            lreps = np.stack(
                [(labels + k) % c.vocab_size for k in range(c.num_codebooks)],
                axis=-1,
            )
            batch = {"tokens": jnp.asarray(reps), "labels": jnp.asarray(lreps)}
        else:
            batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        if c.patch_count > 0:
            rng = np.random.default_rng(self._step)
            batch["patches"] = jnp.asarray(
                rng.normal(0, 1, (c.batch_size, c.patch_count, c.d_model))
                .astype(np.float32)
            )
        return batch

    def __iter__(self):
        while True:
            yield self()


def selection_quality(cfg: DataConfig, steps: int = 4, seed: int = 0) -> dict:
    """Utility of each selection policy's batches under the coverage
    objective (diagnostic used by tests + the data-selection benchmark)."""
    out = {}
    for sel in ("uniform", "ss", "greedy"):
        pipe = Pipeline(dataclasses.replace(cfg, selection=sel), seed=seed)
        vals = []
        for _ in range(steps):
            docs = pipe._pool()
            chosen = pipe._select(docs)
            W = synthetic.hashed_features(
                chosen[:, :-1], cfg.feature_dim, cfg.ngram
            )
            fn = FeatureCoverage(W=jnp.asarray(W), phi="sqrt")
            vals.append(float(fn.value(fn.add_many(fn.empty_state(),
                                                   jnp.ones(len(W), bool)))))
            pipe._step += 1
        out[sel] = float(np.mean(vals))
    return out
