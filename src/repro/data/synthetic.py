"""Synthetic corpora matched to the paper's datasets (offline container —
DESIGN.md §7).  Three generators:

* ``news_day``   — NYT-like: ``n`` sentences as hashed-TFIDF rows over ``F``
  features with Zipfian token draws + per-day topical clusters (sentences
  within a cluster share a topic distribution => real redundancy for SS to
  find, like same-story sentences in a day of news).
* ``video``      — SumMe-like: ``n`` frames whose descriptors follow a
  smooth piecewise random walk through "scenes" => strong temporal
  redundancy, occasional shot cuts.
* ``lm_documents`` — token documents for the LM-training coreset stage:
  a Zipfian unigram stream with planted near-duplicate documents, so
  coreset selection has measurable headroom over uniform sampling.

Everything is numpy (host-side data path); returns float32 / int32.
"""

from __future__ import annotations

import numpy as np


def _rng(seed) -> np.random.Generator:
    return np.random.default_rng(seed)


def zipf_tokens(rng: np.random.Generator, size, vocab: int, a: float = 1.07):
    """Zipf-distributed token ids in [0, vocab) (rejection-free truncation)."""
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks ** (-a)
    probs /= probs.sum()
    return rng.choice(vocab, size=size, p=probs).astype(np.int32)


def news_day(
    seed: int,
    n_sentences: int,
    n_features: int = 1024,
    n_topics: int = 12,
    mean_len: int = 20,
    zipf_a: float = 1.07,
) -> np.ndarray:
    """One day's sentences as a nonnegative (n, F) TFIDF-like matrix."""
    rng = _rng(seed)
    topics = rng.dirichlet(np.full(n_features, 0.05), size=n_topics)
    # cluster sizes ~ broken-stick: few big stories, many small ones
    weights = rng.dirichlet(np.ones(n_topics) * 0.6)
    assign = rng.choice(n_topics, size=n_sentences, p=weights)
    lengths = np.maximum(3, rng.poisson(mean_len, size=n_sentences))
    W = np.zeros((n_sentences, n_features), np.float32)
    zipf_boost = (np.arange(1, n_features + 1) ** (-zipf_a))
    for t in range(n_topics):
        idx = np.where(assign == t)[0]
        if idx.size == 0:
            continue
        p = topics[t] * zipf_boost
        p /= p.sum()
        for i in idx:
            toks = rng.choice(n_features, size=lengths[i], p=p)
            np.add.at(W[i], toks, 1.0)
    # tf * idf, l2-normalized rows (standard setup for coverage objectives)
    df = np.maximum((W > 0).sum(axis=0), 1)
    idf = np.log(1.0 + n_sentences / df).astype(np.float32)
    W = W * idf[None, :]
    W /= np.maximum(np.linalg.norm(W, axis=1, keepdims=True), 1e-9)
    return W


def video(
    seed: int,
    n_frames: int,
    n_features: int = 512,
    n_scenes: int | None = None,
    walk_sigma: float = 0.02,
) -> np.ndarray:
    """SumMe-like frame descriptors (n, F), nonnegative, unit-norm rows."""
    rng = _rng(seed)
    if n_scenes is None:
        n_scenes = max(3, n_frames // 400)
    cuts = np.sort(rng.choice(np.arange(1, n_frames), n_scenes - 1, replace=False))
    bounds = np.concatenate([[0], cuts, [n_frames]])
    X = np.zeros((n_frames, n_features), np.float32)
    for s in range(n_scenes):
        lo, hi = bounds[s], bounds[s + 1]
        center = np.abs(rng.normal(0, 1, n_features))
        steps = rng.normal(0, walk_sigma, (hi - lo, n_features)).cumsum(axis=0)
        X[lo:hi] = np.abs(center[None, :] + steps)
    X /= np.maximum(np.linalg.norm(X, axis=1, keepdims=True), 1e-9)
    return X.astype(np.float32)


def clustered_embeddings(
    seed: int,
    n: int,
    d: int = 16,
    n_clusters: int = 32,
    noise: float = 0.25,
) -> np.ndarray:
    """Unit-norm gaussian-cluster embedding rows (n, d) float32 — the
    large-n input for the matrix-free StreamingFacilityLocation objective.

    Rows are ``normalize(center[c] + noise * N(0, I))`` with broken-stick
    cluster sizes, so same-cluster rows have high dot similarity (the
    redundancy SS prunes) while the (n, n) similarity matrix is never
    needed, or even representable, at the n this generator targets.
    Memory is O(n * d): n = 1M at d = 16 is 64 MB.
    """
    rng = _rng(seed)
    centers = rng.normal(0, 1, (n_clusters, d))
    centers /= np.maximum(np.linalg.norm(centers, axis=1, keepdims=True), 1e-9)
    weights = rng.dirichlet(np.ones(n_clusters) * 0.6)
    assign = rng.choice(n_clusters, size=n, p=weights)
    X = centers[assign] + noise * rng.normal(0, 1, (n, d))
    X /= np.maximum(np.linalg.norm(X, axis=1, keepdims=True), 1e-9)
    return X.astype(np.float32)


def lm_documents(
    seed: int,
    n_docs: int,
    doc_len: int,
    vocab: int,
    dup_frac: float = 0.3,
    zipf_a: float = 1.1,
) -> np.ndarray:
    """(n_docs, doc_len) int32 token matrix with planted near-duplicates.

    ``dup_frac`` of documents are noisy copies of earlier ones (10% token
    perturbation) — the redundancy the SS coreset stage should remove.
    """
    rng = _rng(seed)
    n_unique = max(1, int(n_docs * (1.0 - dup_frac)))
    docs = zipf_tokens(rng, (n_unique, doc_len), vocab, zipf_a)
    out = np.zeros((n_docs, doc_len), np.int32)
    out[:n_unique] = docs
    for i in range(n_unique, n_docs):
        src = rng.integers(0, n_unique)
        copy = docs[src].copy()
        flip = rng.random(doc_len) < 0.1
        copy[flip] = zipf_tokens(rng, int(flip.sum()), vocab, zipf_a)
        out[i] = copy
    perm = rng.permutation(n_docs)
    return out[perm]


def hashed_features(
    tokens: np.ndarray, n_features: int = 1024, ngram: int = 2
) -> np.ndarray:
    """Hashed n-gram count features for token documents.

    tokens: (n, L) int32 -> (n, F) float32, l2-normalized.  This is the
    arch-agnostic featurizer the SS data-selection stage runs on (the paper's
    TFIDF analogue for token streams).
    """
    n, L = tokens.shape
    W = np.zeros((n, n_features), np.float32)
    t = tokens.astype(np.int64)
    for g in range(1, ngram + 1):
        h = np.zeros((n, L - g + 1), np.int64)
        for j in range(g):
            h = h * 1_000_003 + t[:, j : L - g + 1 + j]
        h = (h ^ (h >> 13)) * 0x9E3779B1
        h = np.abs(h) % n_features
        for i in range(n):
            np.add.at(W[i], h[i], 1.0)
    W /= np.maximum(np.linalg.norm(W, axis=1, keepdims=True), 1e-9)
    return W
