"""Structured request tracing: span trees over the serving stack.

A **span** is one timed region — monotonic ``t0``/``t1`` from
``time.perf_counter()``, a ``span_id``, its ``parent_id`` (implicit: the
span that was active on this thread when it started), a ``trace_id``
grouping one request's tree, and free-form ``attrs``.  Finished spans land
in a bounded in-process ring buffer (:class:`repro.obs.events.RingLog`)
with a drop counter; nothing is ever written synchronously to disk.

Tracing is **off by default** (enable with :func:`configure` or
``REPRO_TRACE=1``).  When off, :func:`span` returns a shared no-op context
manager after one attribute check — the hooks stay in compiled-adjacent
hot paths at effectively zero cost, and results are bit-identical either
way because instrumentation only *observes* outputs (tests/test_obs.py).

Compiled-code safety contract: spans time **host-side around jitted
calls** only.  A traced region may host-read the *results* of a compiled
call after it returns (that sync was about to happen anyway), but never
injects a host sync inside a traced ``lax.scan``/``while_loop`` — per-round
SS records are derived post-hoc from ``SSResult.alive_trace``, and
per-round wall times are model-apportioned estimates of the measured total
(``wall_est``), not in-loop measurements.

Span trees are assembled per request: a span belongs to request ``i`` when
its ``trace_id == f"req-{i}"`` or its ``request_ids`` attr contains ``i``
(a chunk span is shared by its batch mates).  :func:`format_trace` renders
one request's tree; :func:`trace_summary` renders the most recent one
(the quickstart prints this).
"""

from __future__ import annotations

import contextlib
import contextvars
import itertools
import os
import threading
import time
from typing import Any, Iterator

from repro.obs.events import RingLog

DEFAULT_CAPACITY = 8192


class Span:
    """One timed region.  Mutable while open (``attrs`` may be filled as
    results become known); immutable by convention once finished."""

    __slots__ = (
        "span_id", "parent_id", "trace_id", "name", "t0", "t1", "status",
        "attrs",
    )

    def __init__(self, span_id: int, parent_id: int | None, trace_id: str,
                 name: str, t0: float, attrs: dict):
        self.span_id = span_id
        self.parent_id = parent_id
        self.trace_id = trace_id
        self.name = name
        self.t0 = t0
        self.t1: float | None = None
        self.status = "ok"
        self.attrs = attrs

    @property
    def wall_s(self) -> float:
        return (self.t1 if self.t1 is not None else time.perf_counter()) \
            - self.t0

    def set(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    def to_dict(self) -> dict:
        return {
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "trace_id": self.trace_id,
            "name": self.name,
            "t0": self.t0,
            "t1": self.t1,
            "wall_s": None if self.t1 is None else self.t1 - self.t0,
            "status": self.status,
            "attrs": self.attrs,
        }

    def __repr__(self) -> str:
        return (
            f"Span({self.name!r}, id={self.span_id}, "
            f"trace={self.trace_id!r}, wall={self.wall_s * 1e3:.2f}ms)"
        )


class _NoopSpan:
    """Shared do-nothing span handed out while tracing is disabled — every
    mutator is a cheap no-op so call sites need no ``if`` guards."""

    __slots__ = ()
    span_id = -1
    parent_id = None
    trace_id = ""
    name = ""
    status = "ok"
    attrs: dict = {}
    wall_s = 0.0

    def set(self, **attrs: Any) -> "_NoopSpan":
        return self


_NOOP_SPAN = _NoopSpan()


@contextlib.contextmanager
def _noop_cm() -> Iterator[_NoopSpan]:
    yield _NOOP_SPAN


class Tracer:
    """Span recorder: bounded ring buffer + contextvar-based implicit
    parenting (thread- and task-local, so the async flusher's spans never
    adopt a submitter's parent)."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 enabled: bool | None = None):
        if enabled is None:
            enabled = os.environ.get("REPRO_TRACE", "") == "1"
        self.enabled = bool(enabled)
        self._ring = RingLog(capacity)
        self._ids = itertools.count()
        self._current: contextvars.ContextVar[Span | None] = \
            contextvars.ContextVar("repro_obs_span", default=None)
        self._lock = threading.Lock()

    # -- recording ---------------------------------------------------------
    def start_span(self, name: str, *, trace_id: str | None = None,
                   parent: Span | None = None, **attrs: Any) -> Span:
        """Open a span explicitly (for lifetimes that don't nest lexically,
        e.g. a request span living from submit to settle).  The caller owns
        calling :meth:`finish`."""
        if not self.enabled:
            return _NOOP_SPAN  # type: ignore[return-value]
        if parent is None:
            parent = self._current.get()
        if trace_id is None:
            trace_id = parent.trace_id if parent is not None else "untraced"
        with self._lock:
            sid = next(self._ids)
        return Span(
            span_id=sid,
            parent_id=None if parent is None else parent.span_id,
            trace_id=trace_id, name=name, t0=time.perf_counter(),
            attrs=dict(attrs),
        )

    def finish(self, sp: Span, status: str = "ok") -> None:
        """Close and record an explicitly-started span."""
        if sp is _NOOP_SPAN or not self.enabled:
            return
        if sp.t1 is None:
            sp.t1 = time.perf_counter()
        sp.status = status
        self._ring.append(sp)

    def span(self, name: str, *, trace_id: str | None = None,
             **attrs: Any):
        """Context manager: open a child of the currently-active span, make
        it current for the body, record it on exit (``status="error"`` when
        the body raises)."""
        if not self.enabled:
            return _noop_cm()
        return self._span_cm(name, trace_id, attrs)

    @contextlib.contextmanager
    def _span_cm(self, name: str, trace_id: str | None,
                 attrs: dict) -> Iterator[Span]:
        sp = self.start_span(name, trace_id=trace_id, **attrs)
        token = self._current.set(sp)
        try:
            yield sp
        except BaseException:
            self.finish(sp, status="error")
            raise
        finally:
            self._current.reset(token)
        self.finish(sp)

    def record(self, name: str, t0: float, t1: float, *,
               trace_id: str | None = None, parent: Span | None = None,
               status: str = "ok", **attrs: Any) -> None:
        """Record a span retroactively from already-measured perf_counter
        endpoints (e.g. a queue-residency span derived at execution start
        from the admission timestamp)."""
        if not self.enabled:
            return
        sp = self.start_span(name, trace_id=trace_id, parent=parent, **attrs)
        sp.t0, sp.t1 = t0, t1
        sp.status = status
        self._ring.append(sp)

    def current_span(self) -> Span | None:
        """The span active on this thread/task (None outside any span)."""
        return self._current.get()

    # -- reading -----------------------------------------------------------
    @property
    def dropped(self) -> int:
        return self._ring.dropped

    def spans(self, trace_id: str | None = None,
              name: str | None = None) -> list[Span]:
        """Finished spans (oldest first), optionally filtered."""
        return [
            s for s in self._ring
            if (trace_id is None or s.trace_id == trace_id)
            and (name is None or s.name == name)
        ]

    def spans_for_request(self, index: int) -> list[Span]:
        """Every span belonging to request ``index``'s tree: its own
        ``req-<i>`` trace plus shared spans (chunk executions and their
        children) whose ``request_ids`` attr contains ``i``."""
        tid = f"req-{index}"
        out, shared_roots = [], set()
        for s in self._ring:
            if s.trace_id == tid:
                out.append(s)
            elif index in s.attrs.get("request_ids", ()):
                out.append(s)
                shared_roots.add(s.span_id)
        if shared_roots:
            # pull in descendants of the shared (chunk) spans: SS / greedy /
            # objective-build children recorded under the chunk's trace.
            known = {s.span_id for s in out}
            grew = True
            while grew:
                grew = False
                for s in self._ring:
                    if s.span_id not in known and s.parent_id in known:
                        out.append(s)
                        known.add(s.span_id)
                        grew = True
        return sorted(out, key=lambda s: (s.t0, s.span_id))

    def export(self) -> list[dict]:
        """JSON-serializable dump of every retained span (the trace
        artifact serve_bench/stream_bench emit)."""
        return [s.to_dict() for s in self._ring]

    def clear(self) -> None:
        self._ring.clear()


_tracer = Tracer()


def get_tracer() -> Tracer:
    """The process-wide tracer every ``span()`` hook records into."""
    return _tracer


def configure(*, trace: bool | None = None,
              capacity: int | None = None) -> Tracer:
    """Enable/disable tracing and/or resize the span ring.  Resizing drops
    recorded spans (the ring is rebuilt); the enable flag is cheap to flip
    at any time."""
    global _tracer
    if capacity is not None and capacity != _tracer._ring.capacity:
        _tracer = Tracer(capacity=capacity, enabled=_tracer.enabled)
    if trace is not None:
        _tracer.enabled = bool(trace)
    return _tracer


def trace_enabled() -> bool:
    return _tracer.enabled


def span(name: str, *, trace_id: str | None = None, **attrs: Any):
    """Module-level convenience: a span on the global tracer (no-op context
    manager when tracing is disabled)."""
    return _tracer.span(name, trace_id=trace_id, **attrs)


# ------------------------------------------------------------- rendering ----

def _render(spans: list[Span]) -> str:
    if not spans:
        return "(no spans recorded — is tracing enabled?)"
    by_parent: dict[int | None, list[Span]] = {}
    ids = {s.span_id for s in spans}
    for s in spans:
        parent = s.parent_id if s.parent_id in ids else None
        by_parent.setdefault(parent, []).append(s)
    t_base = min(s.t0 for s in spans)
    lines: list[str] = []

    def walk(parent: int | None, depth: int) -> None:
        for s in sorted(by_parent.get(parent, ()),
                        key=lambda s: (s.t0, s.span_id)):
            extra = ""
            keep = {
                k: v for k, v in s.attrs.items()
                if isinstance(v, (int, float, str, bool)) and k != "wall_s"
            }
            if keep:
                extra = "  " + ", ".join(
                    f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                    for k, v in sorted(keep.items())
                )
            flag = "" if s.status == "ok" else f"  [{s.status}]"
            lines.append(
                f"{'  ' * depth}{s.name:<24s} "
                f"+{(s.t0 - t_base) * 1e3:8.2f}ms "
                f"{s.wall_s * 1e3:8.2f}ms{flag}{extra}"
            )
            walk(s.span_id, depth + 1)

    walk(None, 0)
    return "\n".join(lines)


def format_trace(trace_id: str) -> str:
    """One trace's span tree as indented text (name, start offset,
    duration, scalar attrs) — offsets are relative to the tree's first
    span.  For a request id ``i`` pass ``f"req-{i}"``; shared chunk spans
    and their SS/greedy children are included."""
    if trace_id.startswith("req-"):
        spans_ = _tracer.spans_for_request(int(trace_id[4:]))
    else:
        spans_ = _tracer.spans(trace_id=trace_id)
    return _render(spans_)


def trace_summary(request: int | None = None) -> str:
    """The span tree of request ``request`` — default: the most recently
    traced request (the quickstart's one-request trace summary)."""
    if request is None:
        reqs = [
            s for s in _tracer.spans() if s.trace_id.startswith("req-")
        ]
        if not reqs:
            return "(no request spans recorded — is tracing enabled?)"
        request = int(reqs[-1].trace_id[4:])
    return f"trace req-{request}\n" + format_trace(f"req-{request}")
