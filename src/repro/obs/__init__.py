"""Unified observability for the reproduction stack (docs/observability.md).

Three layers, one import surface:

- :mod:`repro.obs.trace` — structured per-request span trees (admission →
  lane queue → chunk exec → SS rounds → greedy selection → recovery /
  degradation attempts) recorded host-side around jitted calls into a
  bounded in-process ring buffer.  Off by default (``configure(trace=True)``
  or ``REPRO_TRACE=1``); when off the ``span()`` hooks are near-zero-cost
  no-ops and telemetry-on results are bit-identical to telemetry-off
  (tests/test_obs.py pins this on oracle and pallas).
- :mod:`repro.obs.metrics` — process-wide counters / gauges / histograms
  with Prometheus text-format and JSON exporters (``repro.api.metrics()``;
  :func:`repro.obs.metrics.start_metrics_server` for a pull endpoint).
- :mod:`repro.obs.events` — the unified event bus every subsystem's audit
  records ride (fault draws, recovery/degradation records, session audit
  events, WAL truncations) with one global ordering and shared
  request/session ids, plus the bounded :class:`RingLog` that replaced the
  unbounded in-memory audit lists.
"""

from repro.obs.events import Event, EventBus, RingLog, get_bus
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_registry,
    start_metrics_server,
)
from repro.obs.trace import (
    Span,
    Tracer,
    configure,
    format_trace,
    get_tracer,
    span,
    trace_enabled,
    trace_summary,
)

__all__ = [
    "Counter",
    "Event",
    "EventBus",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "RingLog",
    "Span",
    "Tracer",
    "configure",
    "format_trace",
    "get_bus",
    "get_registry",
    "reset",
    "span",
    "start_metrics_server",
    "trace_enabled",
    "trace_summary",
]


def reset() -> None:
    """Clear every global observability sink (tracer ring, metrics registry,
    event bus) — the test/bench isolation hook.  Configuration (trace
    enabled/disabled, capacities) is preserved; only recorded data is
    dropped."""
    get_tracer().clear()
    get_registry().clear()
    get_bus().clear()
