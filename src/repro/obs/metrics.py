"""Process-wide metrics registry: counters, gauges, histograms, exporters.

The registry is always on (an observation is a locked dict update — cost
is negligible next to any traced region) and purely an *observer*: nothing
in the stack reads a metric back to make a decision, so enabling export
can never perturb results (tests/test_obs.py pins bit-identity).

Naming follows Prometheus conventions: ``repro_<subsystem>_<what>_<unit>``
(``_total`` for counters, ``_seconds`` for time histograms).  The full
metric table lives in docs/observability.md.

Exporters:

- :meth:`MetricsRegistry.to_prometheus` — Prometheus text exposition
  format (``# HELP`` / ``# TYPE`` + one line per labeled series; histogram
  ``_bucket``/``_sum``/``_count`` series with cumulative ``le`` buckets);
- :meth:`MetricsRegistry.to_json` — the same data as one JSON-serializable
  dict (``repro.api.metrics(fmt="json")``);
- :func:`start_metrics_server` — a stdlib pull endpoint serving
  ``/metrics`` (text format) and ``/metrics.json`` from a daemon thread.
"""

from __future__ import annotations

import json
import threading
from typing import Any, Iterable

#: Default histogram bounds (seconds): 0.1ms .. ~100s, log-spaced — wide
#: enough for WAL fsyncs at the bottom and chunk executions at the top.
DEFAULT_BUCKETS = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 100.0,
)


def _label_key(labels: tuple[str, ...], kv: dict) -> tuple[str, ...]:
    missing = set(labels) - set(kv)
    extra = set(kv) - set(labels)
    if missing or extra:
        raise ValueError(
            f"metric labels mismatch: declared {labels}, got {tuple(kv)}"
        )
    return tuple(str(kv[name]) for name in labels)


def _fmt_labels(labels: tuple[str, ...], values: tuple[str, ...],
                extra: str = "") -> str:
    parts = [f'{n}="{v}"' for n, v in zip(labels, values)]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help_: str, labels: tuple[str, ...]):
        self.name = name
        self.help = help_
        self.labels = tuple(labels)
        self._lock = threading.Lock()


class Counter(_Metric):
    """Monotonically increasing per-label-set totals."""

    kind = "counter"

    def __init__(self, name: str, help_: str, labels: tuple[str, ...] = ()):
        super().__init__(name, help_, labels)
        self._values: dict[tuple[str, ...], float] = {}

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        if amount < 0:
            raise ValueError(f"counters only go up; got {amount}")
        key = _label_key(self.labels, labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels: Any) -> float:
        with self._lock:
            return self._values.get(_label_key(self.labels, labels), 0.0)

    def snapshot(self) -> dict[tuple[str, ...], float]:
        with self._lock:
            return dict(self._values)


class Gauge(_Metric):
    """A value that goes up and down (queue depth, occupancy)."""

    kind = "gauge"

    def __init__(self, name: str, help_: str, labels: tuple[str, ...] = ()):
        super().__init__(name, help_, labels)
        self._values: dict[tuple[str, ...], float] = {}

    def set(self, value: float, **labels: Any) -> None:
        with self._lock:
            self._values[_label_key(self.labels, labels)] = float(value)

    def add(self, amount: float, **labels: Any) -> None:
        key = _label_key(self.labels, labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels: Any) -> float:
        with self._lock:
            return self._values.get(_label_key(self.labels, labels), 0.0)

    def snapshot(self) -> dict[tuple[str, ...], float]:
        with self._lock:
            return dict(self._values)


class Histogram(_Metric):
    """Fixed-bound histogram (Prometheus semantics: cumulative ``le``
    buckets plus ``_sum``/``_count``)."""

    kind = "histogram"

    def __init__(self, name: str, help_: str, labels: tuple[str, ...] = (),
                 buckets: Iterable[float] = DEFAULT_BUCKETS):
        super().__init__(name, help_, labels)
        self.bounds = tuple(sorted(float(b) for b in buckets))
        if not self.bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self._counts: dict[tuple[str, ...], list[int]] = {}
        self._sums: dict[tuple[str, ...], float] = {}
        self._totals: dict[tuple[str, ...], int] = {}

    def observe(self, value: float, **labels: Any) -> None:
        key = _label_key(self.labels, labels)
        with self._lock:
            counts = self._counts.get(key)
            if counts is None:
                counts = self._counts[key] = [0] * len(self.bounds)
            for i, bound in enumerate(self.bounds):
                if value <= bound:
                    counts[i] += 1
                    break
            self._sums[key] = self._sums.get(key, 0.0) + value
            self._totals[key] = self._totals.get(key, 0) + 1

    def stats(self, **labels: Any) -> dict:
        """``{count, sum, mean}`` for one label set."""
        key = _label_key(self.labels, labels)
        with self._lock:
            count = self._totals.get(key, 0)
            total = self._sums.get(key, 0.0)
        return {
            "count": count, "sum": total,
            "mean": total / count if count else 0.0,
        }

    def snapshot(self) -> dict[tuple[str, ...], dict]:
        with self._lock:
            return {
                key: {
                    "buckets": list(self._counts[key]),
                    "sum": self._sums[key],
                    "count": self._totals[key],
                }
                for key in self._counts
            }


class MetricsRegistry:
    """Create-or-get metric factory + the export surface."""

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help_: str, labels: tuple[str, ...],
             **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help_, labels, **kw)
                return m
        if not isinstance(m, cls) or m.labels != tuple(labels):
            raise ValueError(
                f"metric {name!r} already registered as {m.kind} with "
                f"labels {m.labels}"
            )
        return m

    def counter(self, name: str, help_: str = "",
                labels: tuple[str, ...] = ()) -> Counter:
        return self._get(Counter, name, help_, labels)

    def gauge(self, name: str, help_: str = "",
              labels: tuple[str, ...] = ()) -> Gauge:
        return self._get(Gauge, name, help_, labels)

    def histogram(self, name: str, help_: str = "",
                  labels: tuple[str, ...] = (),
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help_, labels, buckets=buckets)

    def get(self, name: str) -> _Metric | None:
        with self._lock:
            return self._metrics.get(name)

    def clear(self) -> None:
        """Drop every registered metric (test/bench isolation hook)."""
        with self._lock:
            self._metrics.clear()

    # -- exporters ---------------------------------------------------------
    def to_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        with self._lock:
            metrics = sorted(self._metrics.values(), key=lambda m: m.name)
        lines: list[str] = []
        for m in metrics:
            lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            if isinstance(m, (Counter, Gauge)):
                snap = m.snapshot()
                if not snap and not m.labels:
                    snap = {(): 0.0}
                for key, v in sorted(snap.items()):
                    lines.append(
                        f"{m.name}{_fmt_labels(m.labels, key)} {v:g}"
                    )
            elif isinstance(m, Histogram):
                for key, s in sorted(m.snapshot().items()):
                    cum = 0
                    for bound, c in zip(m.bounds, s["buckets"]):
                        cum += c
                        le = 'le="%g"' % bound
                        lines.append(
                            f"{m.name}_bucket"
                            f"{_fmt_labels(m.labels, key, le)} {cum}"
                        )
                    inf = 'le="+Inf"'
                    lines.append(
                        f"{m.name}_bucket"
                        f"{_fmt_labels(m.labels, key, inf)} {s['count']}"
                    )
                    lines.append(
                        f"{m.name}_sum{_fmt_labels(m.labels, key)}"
                        f" {s['sum']:g}"
                    )
                    lines.append(
                        f"{m.name}_count{_fmt_labels(m.labels, key)}"
                        f" {s['count']}"
                    )
        return "\n".join(lines) + "\n"

    def to_json(self) -> dict:
        """Everything in one JSON-serializable dict keyed by metric name;
        per-metric: kind, help, labels, and a series list."""
        with self._lock:
            metrics = sorted(self._metrics.values(), key=lambda m: m.name)
        out: dict[str, Any] = {}
        for m in metrics:
            series = []
            if isinstance(m, (Counter, Gauge)):
                for key, v in sorted(m.snapshot().items()):
                    series.append(
                        {"labels": dict(zip(m.labels, key)), "value": v}
                    )
            elif isinstance(m, Histogram):
                for key, s in sorted(m.snapshot().items()):
                    series.append({
                        "labels": dict(zip(m.labels, key)),
                        "count": s["count"], "sum": s["sum"],
                        "bounds": list(m.bounds),
                        "buckets": s["buckets"],
                    })
            out[m.name] = {
                "kind": m.kind, "help": m.help,
                "labels": list(m.labels), "series": series,
            }
        return out


_registry = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide registry every subsystem records into."""
    return _registry


def start_metrics_server(port: int = 0, host: str = "127.0.0.1"):
    """A minimal pull endpoint: ``GET /metrics`` serves the Prometheus
    text format, ``GET /metrics.json`` the JSON dump.  Returns the
    ``http.server`` instance (``server.server_address[1]`` is the bound
    port — pass ``port=0`` for an ephemeral one); it runs in a daemon
    thread until ``server.shutdown()``."""
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 - http.server API
            if self.path.startswith("/metrics.json"):
                body = json.dumps(_registry.to_json()).encode()
                ctype = "application/json"
            elif self.path.startswith("/metrics"):
                body = _registry.to_prometheus().encode()
                ctype = "text/plain; version=0.0.4; charset=utf-8"
            else:
                self.send_error(404)
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):  # silence per-request stderr noise
            pass

    server = ThreadingHTTPServer((host, port), Handler)
    thread = threading.Thread(
        target=server.serve_forever, name="repro-metrics", daemon=True
    )
    thread.start()
    return server
