"""The unified event bus + the bounded ring log (docs/observability.md).

Before PR 10 the stack's audit records lived in four disconnected, mostly
unbounded mechanisms: ``FaultPlan.log`` (fault draws), per-response
``recovery``/``degradation`` dicts, ``SessionEngine.events`` (session
audit), and ad-hoc ``stats()`` dicts.  This module gives them one spine:

- :class:`RingLog` — a bounded, thread-safe, list-like append log with a
  drop counter.  ``FaultPlan.log`` and ``SessionEngine.events`` are
  RingLogs now, so a long-lived chaos run can no longer grow them without
  limit; everything a reader could do with the old lists (iterate, index,
  ``len``) still works, and ``dropped`` says how much history aged out.
- :class:`EventBus` — the process-wide ordered stream every subsystem
  emits onto.  Each :class:`Event` carries a global monotonic ``seq`` (one
  ordering across subsystems), a wall-clock and a monotonic timestamp, the
  emitting ``subsystem``, a ``kind``, and the shared correlation ids:
  ``request_ids`` (``Ticket.index`` values) and ``session_id``.  One
  seeded chaos run's fault draws, recovery records, degradation records,
  and session audit events all land here with consistent ids
  (tests/test_obs.py pins this).
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import deque
from typing import Any, Iterator

DEFAULT_CAPACITY = 4096


class RingLog:
    """Bounded append-only log: the newest ``capacity`` entries, list-like
    reads, and a counter of how many older entries were dropped."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1; got {capacity}")
        self.capacity = capacity
        self._items: deque = deque(maxlen=capacity)
        self._dropped = 0
        self._lock = threading.Lock()

    def append(self, item: Any) -> None:
        with self._lock:
            if len(self._items) == self.capacity:
                self._dropped += 1
            self._items.append(item)

    @property
    def dropped(self) -> int:
        """Entries evicted off the old end since construction."""
        with self._lock:
            return self._dropped

    def list(self) -> list:
        """A consistent snapshot of the retained entries (oldest first)."""
        with self._lock:
            return list(self._items)

    def clear(self) -> None:
        with self._lock:
            self._items.clear()
            self._dropped = 0

    def __iter__(self) -> Iterator:
        return iter(self.list())

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    def __getitem__(self, i):
        return self.list()[i]

    def __bool__(self) -> bool:
        return len(self) > 0

    def __eq__(self, other) -> bool:
        """Compare by retained contents — drop-in for code (and tests)
        that held these audit trails as plain lists."""
        if isinstance(other, RingLog):
            return self.list() == other.list()
        if isinstance(other, list):
            return self.list() == other
        return NotImplemented

    def __repr__(self) -> str:
        return (
            f"RingLog(capacity={self.capacity}, len={len(self)}, "
            f"dropped={self.dropped})"
        )


@dataclasses.dataclass(frozen=True)
class Event:
    """One bus record.  ``seq`` is the global order (monotonic across every
    subsystem); ``t`` is ``time.perf_counter()`` (the same clock spans use,
    so events interleave with span timings), ``t_wall`` is epoch seconds."""

    seq: int
    t: float
    t_wall: float
    subsystem: str              # service | sessions | faults | wal | ...
    kind: str                   # fault | recovery | degradation | session ...
    request_ids: tuple[int, ...]
    session_id: str | None
    data: dict

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["request_ids"] = list(self.request_ids)
        return d


class EventBus:
    """Process-wide ordered event stream (bounded ring + drop counter)."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self._ring = RingLog(capacity)
        self._seq = itertools.count()
        self._lock = threading.Lock()

    def emit(
        self,
        kind: str,
        *,
        subsystem: str,
        request_ids: tuple[int, ...] = (),
        session_id: str | None = None,
        **data: Any,
    ) -> Event:
        with self._lock:
            seq = next(self._seq)
        ev = Event(
            seq=seq, t=time.perf_counter(), t_wall=time.time(),
            subsystem=subsystem, kind=kind,
            request_ids=tuple(int(i) for i in request_ids),
            session_id=session_id, data=data,
        )
        self._ring.append(ev)
        return ev

    def events(
        self,
        kind: str | None = None,
        subsystem: str | None = None,
        *,
        request_id: int | None = None,
        session_id: str | None = None,
    ) -> list[Event]:
        """Retained events in ``seq`` order, optionally filtered."""
        return [
            e for e in self._ring
            if (kind is None or e.kind == kind)
            and (subsystem is None or e.subsystem == subsystem)
            and (request_id is None or request_id in e.request_ids)
            and (session_id is None or e.session_id == session_id)
        ]

    @property
    def dropped(self) -> int:
        return self._ring.dropped

    def export(self) -> list[dict]:
        """JSON-serializable dump of the retained events."""
        return [e.to_dict() for e in self._ring]

    def clear(self) -> None:
        self._ring.clear()


_bus = EventBus()


def get_bus() -> EventBus:
    """The process-wide bus every subsystem emits onto."""
    return _bus
