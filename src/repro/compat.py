"""Version-compat shims for jax APIs that moved between releases.

The repo targets a range of jax versions: newer ones expose ``jax.shard_map``
/ ``jax.sharding.AxisType`` / ``pltpu.CompilerParams``, older ones the
``jax.experimental.shard_map`` / ``pltpu.TPUCompilerParams`` spellings.  All
call sites go through these helpers so the rest of the codebase stays
version-agnostic.
"""

from __future__ import annotations

import inspect
from typing import Any, Sequence

import jax

try:  # jax >= 0.5-ish
    from jax.sharding import AxisType as _AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    _AxisType = None


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> jax.sharding.Mesh:
    """jax.make_mesh with Auto axis types where the argument exists."""
    if _AxisType is not None:
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(_AxisType.Auto,) * len(axes),
        )
    return jax.make_mesh(tuple(shape), tuple(axes))


def shard_map(f, mesh, in_specs, out_specs, axis_names=None):
    """shard_map with replication checking off, across API generations.

    ``axis_names`` (optional) lists the mesh axes that are *manual* inside
    the body (the new-API meaning); None means all of them.  On old jax this
    is translated to the complementary ``auto`` set.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    params = inspect.signature(sm).parameters
    kw: dict[str, Any] = {}
    if "check_vma" in params:
        kw["check_vma"] = False
    elif "check_rep" in params:
        kw["check_rep"] = False
    if axis_names is not None:
        if "axis_names" in params:
            kw["axis_names"] = set(axis_names)
        else:
            kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def set_mesh(mesh):
    """``with set_mesh(mesh):`` — jax.set_mesh where available, else the
    classic ``with mesh:`` context (which old with_sharding_constraint
    resolves P() specs against)."""
    sm = getattr(jax, "set_mesh", None)
    if sm is not None:
        return sm(mesh)
    return mesh  # Mesh is itself a context manager on older jax


def get_abstract_mesh():
    """The ambient mesh for sharding constraints, or None when no mesh
    context is active (or the running jax predates abstract meshes)."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        try:
            return get()
        except Exception:  # pragma: no cover - defensive
            return None
    try:
        from jax._src.mesh import thread_resources

        mesh = thread_resources.env.physical_mesh
        return mesh if mesh.axis_names else None
    except Exception:  # pragma: no cover - defensive
        return None


def pallas_tpu_compiler_params(**kw):
    """pltpu.CompilerParams (new) / pltpu.TPUCompilerParams (old)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kw)
