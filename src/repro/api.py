"""``repro.api`` — the stable public facade over the summarization stack.

Three verbs cover the serving surface (docs/serving.md has the migration
table from the pre-PR-7 scattered kwargs):

- :func:`summarize` — one query, one call: build the objective from a raw
  payload, run SS → compact greedy through the service execution core, and
  return the :class:`SummarizeResponse`.  Compile caches are module-level,
  so repeated calls stay warm.
- :func:`serve` — construct a :class:`SummarizeService` from a
  :class:`RunConfig` (``scheduler="async"`` for the deadline-driven
  background flusher; the service is a context manager).
- :func:`submit` — fire-and-forget onto a process-wide default *async*
  service; returns the :class:`Ticket` future.

All knobs that are not per-query live on one object — :class:`RunConfig` —
threaded end-to-end (service admission → batched SS → compact greedy).
Per-query knobs (payload, ``k``, ``key``, objective config, ``deadline_s``)
live on :class:`SummarizeRequest`.

The *streaming* surface (docs/streaming.md) maintains a crash-safe live
summary per session over unbounded element streams:

- :func:`sessions` — construct a :class:`SessionEngine` (pass a ``root``
  directory for the WAL + snapshot durability contract);
- :func:`open_session` / :func:`append` / :func:`summary` — the per-session
  verbs, routed to a process-wide default engine when none is given.

The *observability* surface (docs/observability.md):

- :func:`stats` — one consistent snapshot of a service's serving counters
  (defaults to the process-wide service, if one exists);
- :func:`metrics` — the process-wide metrics registry rendered as
  Prometheus text (default) or a JSON-serializable dict; pair with
  :func:`repro.obs.start_metrics_server` for a pull endpoint and
  ``repro.obs.configure(trace=True)`` / ``REPRO_TRACE=1`` for request
  span trees (:func:`repro.obs.trace_summary`).
"""

from __future__ import annotations

import dataclasses
import threading

from repro import obs
from repro.serve.faults import FaultPlan
from repro.serve.sessions import (
    SessionConfig,
    SessionEngine,
    SessionSummary,
)
from repro.serve.summarize_service import (
    LADDER_STEPS,
    ChunkTimeout,
    DeadlineExceeded,
    MalformedResult,
    RunConfig,
    ServiceOverloaded,
    ServiceRestarted,
    SummarizeRequest,
    SummarizeResponse,
    SummarizeService,
    Ticket,
    TicketPending,
)
from repro.serve.wal import WALCorrupt, WALTruncated

__all__ = [
    "LADDER_STEPS",
    "ChunkTimeout",
    "DeadlineExceeded",
    "FaultPlan",
    "MalformedResult",
    "RunConfig",
    "ServiceOverloaded",
    "ServiceRestarted",
    "SessionConfig",
    "SessionEngine",
    "SessionSummary",
    "SummarizeRequest",
    "SummarizeResponse",
    "SummarizeService",
    "Ticket",
    "TicketPending",
    "WALCorrupt",
    "WALTruncated",
    "append",
    "default_engine",
    "default_service",
    "metrics",
    "open_session",
    "serve",
    "sessions",
    "stats",
    "submit",
    "summarize",
    "summary",
]

_default_service: SummarizeService | None = None
_default_engine: SessionEngine | None = None
_default_lock = threading.Lock()


def serve(
    config: RunConfig | None = None, *, faults: FaultPlan | None = None
) -> SummarizeService:
    """A fresh :class:`SummarizeService` under ``config`` (default
    ``RunConfig()`` — synchronous scheduler).  Compile caches are shared
    process-wide, so new services start warm for shapes any prior service
    has executed.  ``faults`` threads a seeded :class:`FaultPlan` into the
    executor — the chaos-testing hook (docs/serving.md "Failure
    semantics"); production callers leave it None."""
    return SummarizeService(config or RunConfig(), faults=faults)


def default_service(config: RunConfig | None = None) -> SummarizeService:
    """The process-wide service :func:`submit` targets — created on first
    use (``RunConfig(scheduler="async")`` unless ``config`` overrides at
    creation).  Passing a different config once it exists is an error: use
    :func:`serve` for a separately-configured instance."""
    global _default_service
    with _default_lock:
        if _default_service is None:
            cfg = config or RunConfig(scheduler="async")
            if cfg.scheduler != "async":
                cfg = dataclasses.replace(cfg, scheduler="async")
            _default_service = SummarizeService(cfg)
        elif config is not None and config != dataclasses.replace(
            _default_service.config, scheduler=config.scheduler
        ):
            raise ValueError(
                "the default service is already configured; use "
                "repro.api.serve(config) for a differently-configured one"
            )
        return _default_service


def submit(
    request: SummarizeRequest, service: SummarizeService | None = None
) -> Ticket:
    """Admit one request to ``service`` (default: the process-wide async
    :func:`default_service`) and return its :class:`Ticket` future."""
    return (service or default_service()).submit(request)


def summarize(
    features=None,
    k: int = 10,
    key=0,
    *,
    sim=None,
    objective: str = "coverage",
    phi: str = "sqrt",
    kernel: str = "cosine",
    use_ss: bool = True,
    config: RunConfig | None = None,
) -> SummarizeResponse:
    """One-call single-query summarization through the service execution
    core (identical results to ``ss_sparsify`` + ``greedy`` under the same
    key — the micro-batching contract with B=1).

    ``features`` is the (n, F) payload (FeatureCoverage, or the similarity
    kernel input for ``objective="fl"``); ``sim`` a precomputed (n, n)
    similarity instead.  Everything execution-level rides ``config``.
    """
    cfg = config or RunConfig()
    if cfg.scheduler != "sync":
        cfg = dataclasses.replace(cfg, scheduler="sync")
    svc = SummarizeService(cfg)
    req = SummarizeRequest(
        k=k, key=key, features=features, sim=sim, objective=objective,
        phi=phi, kernel=kernel, use_ss=use_ss,
    )
    return svc.run([req])[0]


# ------------------------------------------------------------- streaming ----

def sessions(
    config: SessionConfig | None = None,
    root: str | None = None,
    *,
    faults: FaultPlan | None = None,
) -> SessionEngine:
    """A fresh :class:`SessionEngine` — the durable multi-session streaming
    tier (docs/streaming.md).  ``root=None`` runs volatile; a directory
    arms the WAL + snapshot durability contract, and constructing a new
    engine on the same root recovers every session bit-identically.
    ``faults`` is the chaos hook (``crash``/``restart`` kinds included)."""
    return SessionEngine(config or SessionConfig(), root, faults=faults)


def default_engine(
    config: SessionConfig | None = None, root: str | None = None
) -> SessionEngine:
    """The process-wide engine the session verbs target — created on first
    use (volatile unless ``root`` is given then).  A crashed or closed
    default is replaced on the next call; passing a different config *or a
    different root* while one is live is an error — use :func:`sessions`
    instead.  (Silently returning the live engine on a root mismatch would
    let a caller who asked for durability believe volatile acks survive a
    crash.)"""
    global _default_engine
    with _default_lock:
        eng = _default_engine
        if eng is None or eng._dead is not None or eng._closed:
            _default_engine = SessionEngine(config or SessionConfig(), root)
        elif config is not None and config != eng.config:
            raise ValueError(
                "the default session engine is already configured; use "
                "repro.api.sessions(config) for a differently-configured one"
            )
        elif root is not None and root != eng.root:
            raise ValueError(
                "the default session engine is already rooted at "
                f"{eng.root!r} (None = volatile, appends are NOT durable); "
                "use repro.api.sessions(root=...) for a differently-rooted "
                "engine"
            )
        return _default_engine


def open_session(
    sid: str | None = None, *, key: int = 0,
    engine: SessionEngine | None = None,
) -> str:
    """Create a streaming session on ``engine`` (default: the process-wide
    :func:`default_engine`); returns the session id."""
    return (engine or default_engine()).open_session(sid, key=key)


def append(sid: str, row, engine: SessionEngine | None = None) -> int:
    """Ingest one (F,) feature row into session ``sid``; returns the WAL
    sequence number — on a durable engine the element survives any crash
    from the moment this returns."""
    return (engine or default_engine()).append(sid, row)


def summary(sid: str, engine: SessionEngine | None = None) -> SessionSummary:
    """The session's current k-element summary (flushes pending appends,
    then greedy over the SS-pruned retained buffer)."""
    return (engine or default_engine()).summary(sid)


# --------------------------------------------------------- observability ----

def stats(service: SummarizeService | None = None) -> dict:
    """One consistent snapshot of ``service``'s serving counters
    (:meth:`SummarizeService.stats` — taken entirely under the service's
    settle lock, so no count can tear against the aggregate derived from
    it).  Defaults to the process-wide :func:`default_service` when one
    already exists; raises when there is neither an argument nor a default
    service (an empty implicit one would silently report zeros)."""
    if service is None:
        with _default_lock:
            service = _default_service
        if service is None:
            raise RuntimeError(
                "no default service exists yet; pass the service whose "
                "stats you want (or submit something first)"
            )
    return service.stats()


def metrics(fmt: str = "prometheus"):
    """The process-wide metrics registry — every subsystem's counters,
    gauges and histograms (scheduler, recovery, degradation, sessions,
    WAL; docs/observability.md has the metric table).  ``fmt="prometheus"``
    returns the text exposition format; ``fmt="json"`` a JSON-serializable
    dict."""
    reg = obs.get_registry()
    if fmt == "prometheus":
        return reg.to_prometheus()
    if fmt == "json":
        return reg.to_json()
    raise ValueError(f"fmt must be 'prometheus' or 'json'; got {fmt!r}")
