"""Batched serving engine: prefill + decode with a static KV/recurrent cache.

The engine jit-compiles two functions per (batch, prompt_len, max_len)
signature:

  * ``prefill_fn``  — full-sequence forward that emits the first sampled
    token and the populated cache (what the ``prefill_32k`` cells lower);
  * ``decode_fn``   — one-token step against the cache (what ``decode_32k``
    / ``long_500k`` lower).

Sampling is greedy (argmax) or temperature/top-k via a PRNG key.  Requests
are a fixed batch of equal-length prompts (static shapes; continuous
batching would slot new requests into finished rows — the cache layout here
is already slot-addressed to allow that, see ``reset_rows``).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, decode_step, init_cache, prefill

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0      # 0 => greedy argmax
    top_k: int = 0                # 0 => no truncation


def _sample(logits: Array, key: Array | None, sc: ServeConfig) -> Array:
    """logits (B, 1, V) or (B, 1, K, V) -> next tokens (B, 1[, K])."""
    if sc.temperature <= 0.0 or key is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / sc.temperature
    if sc.top_k > 0:
        kth = jax.lax.top_k(scaled, sc.top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, -1e30, scaled)
    flat = scaled.reshape(-1, scaled.shape[-1])
    toks = jax.random.categorical(key, flat, axis=-1)
    return toks.reshape(scaled.shape[:-1]).astype(jnp.int32)


class Engine:
    def __init__(self, cfg: ModelConfig, params, sc: ServeConfig = ServeConfig()):
        self.cfg = cfg
        self.params = params
        self.sc = sc

        self._prefill = jax.jit(
            lambda p, toks, patches: prefill(
                cfg, p, toks, patches, max_len=sc.max_len
            )
        )
        self._decode = jax.jit(
            lambda p, toks, cache, n, pos: decode_step(cfg, p, toks, cache, n, pos)
        )

    def generate(
        self,
        tokens: Array,                  # (B, S[, K]) prompt
        num_new: int,
        patches: Array | None = None,
        key: Array | None = None,
    ) -> tuple[Array, dict]:
        """Returns (generated tokens (B, num_new[, K]), final cache)."""
        cfg, sc = self.cfg, self.sc
        B, S = tokens.shape[0], tokens.shape[1]
        assert S + num_new <= sc.max_len, "increase ServeConfig.max_len"

        logits, cache = self._prefill(self.params, tokens, patches)
        outs = []
        tok = _sample(logits, key, sc)
        outs.append(tok)
        n = jnp.int32(S)
        for i in range(num_new - 1):
            if key is not None:
                key, sub = jax.random.split(key)
            else:
                sub = None
            logits, cache = self._decode(self.params, tok, cache, n, n)
            tok = _sample(logits, sub, sc)
            outs.append(tok)
            n = n + 1
        return jnp.concatenate(outs, axis=1), cache

    def prefill(self, tokens: Array, patches: Array | None = None):
        """Run the prefill forward pass; returns (first-token logits, the
        populated KV/recurrent cache).  Public so callers that manage their
        own decode loop (e.g. the KV-pruning example) ride the engine's
        compiled signatures instead of re-jitting ``models.prefill``."""
        return self._prefill(self.params, tokens, patches)

    def decode_with_cache(self, tok, cache, cache_len, pos=None):
        """One raw decode step (used by the KV-pruning path)."""
        return self._decode(
            self.params, tok, cache, cache_len,
            cache_len if pos is None else pos,
        )

    def prune_kv(self, cache: dict, seq_len: int, key: Array, kv=None):
        """Compact the KV cache to ``kv.budget`` representative positions
        via submodular selection (the ``repro.api`` execution surface:
        ``KVSelectConfig.run`` is a ``RunConfig``).  Returns
        (new_cache, new_cache_len, kept positions) —
        see :func:`repro.serve.kv_select.prune_cache`."""
        from repro.serve.kv_select import KVSelectConfig, prune_cache

        return prune_cache(
            self.cfg, cache, seq_len, kv or KVSelectConfig(), key
        )
