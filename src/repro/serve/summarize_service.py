"""Micro-batched multi-query summarization service: the request-level layer
over SS + greedy, with an SLO-aware asynchronous scheduler and a
fault-tolerance layer (retry / failover / degradation).

Every caller so far invoked ``ss_sparsify``/``greedy`` one ground set at a
time.  This module is the serving engine the ROADMAP north star asks for: it
accepts per-query requests (a feature or similarity payload, a budget k, an
objective config, a per-query PRNG key, an optional latency deadline),
admits them into per-lane queues, micro-batches compatible queries into
**bucketed static shapes** — the ``bucket_schedule`` idea applied to the
batch dimension (and optionally the ground-set dimension), so each
(n, B-bucket, k) signature compiles once and stays warm — and executes the
full SS → compact-greedy pipeline for the whole batch as one compiled loop
via the first-class batched entry points ``ss_sparsify_batched`` /
``greedy_batched`` (repro.core).

Scheduling (PR 7): with ``RunConfig(scheduler="async")`` a background
flusher owns execution — the caller never calls ``flush()``.  A lane fires
when it is **full** (``max_batch`` queued), when a queued request's
**deadline slack** runs out (absolute deadline minus the lane's EWMA
execution estimate minus ``slack_s``), or when the oldest request has
waited **max_wait_s** — whichever comes first.  Between firings the flusher
sleeps on a condition variable; an empty-queue tick is a no-op.  Batching
is *continuous*: the flusher pulls at most ``max_batch`` requests from the
head of one lane per firing, so arrivals during an in-flight batch refill
the next bucket instead of waiting for a whole-queue drain.  The default
``scheduler="sync"`` keeps the PR-5 contract surface: admission policy
belongs to the caller, ``flush()`` drains everything queued.

Failure semantics (PR 8 — docs/serving.md "Failure semantics"): a chunk
execution error no longer permanently fails its tickets.  The executor runs
every chunk through a recovery loop: bounded-exponential-backoff **retries**
on the primary backend (``max_retries`` / ``retry_backoff_s``), per-chunk
**failover** to ``failover_backend`` (default ``pallas → oracle``), and
finally per-query **isolation** — the chunk is re-run one query at a time so
a single poisoned query can no longer take down its chunk-mates.  A
**watchdog** (``chunk_timeout_s``) bounds chunk wall time: a hung attempt is
abandoned (its late results are discarded by the tickets' first-wins
settle), the hung signature is not retried, and only that chunk's recovery
path is affected — the flusher stays alive.  Recovered responses carry a
``recovery`` record; results after a same-backend retry are bit-identical
to a fault-free run (execution is deterministic given lane + keys), and
failed-over results select identically up to backend numerics.

Degradation ladder (PR 8): when a lane's EWMA predicts a queued deadline
will be missed at full quality — or under ``max_pending`` admission
pressure (``ladder_pressure``) — the executor walks ``RunConfig.ladder``, a
declared sequence of paper-grounded quality steps: ``"stochastic_greedy"``
(exact greedy → *lazier than lazy* stochastic greedy, 1409.7938),
``"bump_c"`` (×4 SS ``c``: faster shrink, fewer rounds, looser guarantee),
``"shrink_r"`` (halve SS probe multiplier ``r``).  Step cost is predicted
with :func:`repro.core.ss_cost_model` until a per-(lane, level) EWMA takes
over.  Every degraded response carries a ``degradation`` record (steps
applied, config actually run, why) — degraded answers are auditable, never
silent.  The ladder is off by default and full-quality results are
bit-identical to a ladder-free service.

Correctness contract (unchanged): micro-batching — and now scheduling and
recovery — is a pure execution strategy.  Each query's ``selected`` /
``gains`` / ``value`` (and SS ``vprime`` / ``eps_hat``) are *identical* to
a sequential single-query ``ss_sparsify(fn, key)`` + ``greedy(fn, k,
alive=vprime)`` run under the same per-query key — regardless of which
queries it was batched with, the batch bucket padding, mixed n / k in the
same flush, which trigger fired the batch, or how many recovery attempts it
took (tests/test_serve_service.py, tests/test_serve_async.py and
tests/test_serve_faults.py pin this query-for-query).

Failure isolation: :class:`Ticket` is a real future — ``result(timeout)`` /
``done()`` / ``exception()`` — and captures per-request errors, so a
malformed or already-expired request fails its own ticket at admission
(``validate_payloads`` rejects NaN/Inf payloads and ``k < 1`` at
``submit()``) instead of corrupting the compiled chunk that would have
carried it.  A ticket still in flight when a wait times out raises
:class:`TicketPending` naming its state instead of blocking forever.

Accounting: the service tracks queue delay per query (submit → execution
start), per-batch execution wall time, padding waste (slots burned rounding
a lane chunk up to its batch bucket), firing-trigger counts, missed
deadlines, and the recovery counters (retries, failovers, isolated queries,
chunk timeouts, degraded queries) — the numbers a capacity planner needs to
tune ``max_batch`` / ``max_wait_s`` / the ladder against traffic.

Optional ground-set padding (``RunConfig.n_buckets``): queries whose n is
not in the bucket list are zero-padded up to the next bucket with the
padding rows dead-masked, collapsing many distinct-n compile signatures
into a few.  Padding changes the PRNG frame of SS (an (n_bucket,) Gumbel
draw), so a padded query matches the sequential run *on the padded ground
set*, not on the raw one — exact-n lanes (the default) keep the strict
contract.  Pure-greedy queries (``use_ss=False``) are padding-invariant
either way: dead rows can never win an argmax.
"""

from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import (
    FacilityLocation,
    FeatureCoverage,
    GreedyResult,
    SSResult,
    bucket_schedule,
    greedy_batched,
    resolve_backend,
    ss_cost_model,
    ss_live_bound,
    ss_sparsify_batched,
    stochastic_greedy_batched,
)
from repro.serve.faults import FaultInjected, FaultPlan

Array = jax.Array


def ewma_update(prev: float | None, sample: float, alpha: float = 0.5) -> float:
    """The service's execution-estimate EWMA: the first sample seeds the
    estimate, after which ``alpha`` weights the newest sample.  Exposed at
    module level so tests can pin the deadline flusher's convergence
    independently of a live service."""
    return sample if prev is None else (1.0 - alpha) * prev + alpha * sample


def _lane_label(lane: tuple) -> str:
    """A low-cardinality metrics label for a lane tuple: objective / ground
    size / budget (the full tuple would explode label cardinality)."""
    return f"{lane[0]}/n{lane[2][0]}/k{lane[3]}"


class DeadlineExceeded(RuntimeError):
    """The request's latency budget was already spent at admission."""


class ServiceOverloaded(RuntimeError):
    """Backpressure: the service's pending-queue cap was hit at admission."""


class ChunkTimeout(RuntimeError):
    """A chunk attempt exceeded ``RunConfig.chunk_timeout_s`` and was
    abandoned by the watchdog (the flusher moves on; the hung attempt's late
    results, if any, are discarded by the tickets' first-wins settle)."""


class MalformedResult(RuntimeError):
    """Chunk execution produced non-finite gains/values — treated as a
    recoverable execution fault (retried / failed over), never returned."""


class TicketPending(TimeoutError):
    """A ticket wait timed out while its query is still queued or executing
    (e.g. after ``drain(timeout)`` gave up on an in-flight chunk).  Subclasses
    TimeoutError, so pre-PR-8 ``except TimeoutError`` callers still work."""


class ServiceRestarted(RuntimeError):
    """The engine died (``crash``) or restarted (``restart``) while work was
    in flight.  Every in-flight ticket settles with this error — never hangs
    in :class:`TicketPending` — and after a ``crash`` new submissions are
    rejected with it too (the process is gone; build a new service).  The
    durable session tier (``repro.serve.sessions``) raises the same type
    when its engine crashes; there, recovery = reopen the engine and replay
    snapshot + WAL."""


# ------------------------------------------------------------- run config ----

#: Valid degradation-ladder steps, in the order the docs discuss them.
LADDER_STEPS = ("stochastic_greedy", "bump_c", "shrink_r")


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """The one end-to-end execution config (stable surface: ``repro.api``).

    Consolidates what used to be scattered across ``ServiceConfig``,
    ``ss_sparsify`` kwargs, and ``greedy`` kwargs — per-query knobs
    (payload, k, key, objective, deadline) stay on the request.

    Execution: ``backend`` selects the repro.core.backend (None = env
    default); ``compact`` is the compact-selection policy threaded to
    ``greedy_batched`` (None = auto: the static SS live bound).  SS:
    probe multiplier ``r``, accuracy/speed ``c``.  ``eps`` is the
    stochastic-greedy sample-size parameter (used by facade helpers and by
    the ``"stochastic_greedy"`` ladder step).

    Batching: ``max_batch`` caps a micro-batch; ``batch_c`` shapes the
    B-bucket schedule; ``n_buckets`` opts into ground-set padding.

    Scheduling: ``scheduler`` is ``"sync"`` (manual ``flush()``, the PR-5
    contract) or ``"async"`` (background deadline-driven flusher);
    ``max_wait_s`` bounds how long an admitted request may sit queued
    before its lane fires anyway; ``slack_s`` is extra safety margin
    subtracted from deadlines when scheduling; ``max_pending`` (None =
    unbounded) is the admission backpressure cap; ``stream_steps`` streams
    greedy selections back to tickets step-by-step as they commit.

    Fault tolerance: ``max_retries`` same-backend re-attempts per stage with
    ``retry_backoff_s``·2^(attempt−1) sleeps between them;
    ``failover_backend`` the per-chunk fallback backend (None disables; a
    fallback resolving to the primary is skipped); ``isolate_on_failure``
    re-runs an exhausted multi-query chunk one query at a time so a poisoned
    query fails alone; ``chunk_timeout_s`` arms the watchdog (None = off);
    ``validate_payloads`` rejects NaN/Inf payloads at admission.

    Degradation: ``ladder`` is the ordered tuple of quality steps
    (subset of ``LADDER_STEPS``) the executor may walk; empty = never
    degrade.  ``ladder_pressure`` is the ``max_pending`` fill fraction at
    which every chunk runs fully degraded; ``ladder_force`` (test/bench
    hook) forces that many steps on every chunk regardless of deadlines.
    """

    backend: Any = None             # str | Backend | None (repro.core.backend)
    r: int = 8                      # SS probe multiplier
    c: float = 8.0                  # SS accuracy/speed tradeoff
    eps: float = 0.1                # stochastic-greedy sample-size parameter
    compact: "bool | int | None" = None   # compact-selection policy
    max_batch: int = 8              # admission cap per micro-batch
    batch_c: float = 4.0            # B-bucket shrink factor (buckets =
    #                                 bucket_schedule(max_batch, batch_c, 1))
    n_buckets: tuple[int, ...] | None = None  # opt-in ground-set padding
    scheduler: str = "sync"         # "sync" | "async"
    max_wait_s: float = 0.05        # max queue residency before a lane fires
    slack_s: float = 0.0            # safety margin under deadlines
    max_pending: int | None = None  # admission backpressure cap
    stream_steps: bool = False      # stream greedy steps to tickets
    # -- fault tolerance (PR 8) -------------------------------------------
    max_retries: int = 2            # same-backend re-attempts per stage
    retry_backoff_s: float = 0.02   # backoff base: base * 2^(attempt-1)
    failover_backend: Any = "oracle"  # per-chunk fallback (None = disabled)
    isolate_on_failure: bool = True  # exhausted chunk -> per-query re-run
    chunk_timeout_s: float | None = None  # watchdog bound on chunk wall time
    validate_payloads: bool = True  # reject NaN/Inf payloads at submit()
    # -- degradation ladder (PR 8) ----------------------------------------
    ladder: tuple[str, ...] = ()    # ordered quality steps (LADDER_STEPS)
    ladder_pressure: float = 0.8    # max_pending fill fraction -> full ladder
    ladder_force: int | None = None  # test/bench hook: force N steps

    def __post_init__(self):
        if self.scheduler not in ("sync", "async"):
            raise ValueError(
                f"scheduler must be 'sync' or 'async'; got {self.scheduler!r}"
            )
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0; got {self.max_retries}")
        if self.chunk_timeout_s is not None and self.chunk_timeout_s <= 0:
            raise ValueError(
                f"chunk_timeout_s must be positive; got {self.chunk_timeout_s}"
            )
        object.__setattr__(self, "ladder", tuple(self.ladder))
        bad = [s for s in self.ladder if s not in LADDER_STEPS]
        if bad:
            raise ValueError(
                f"unknown ladder step(s) {bad}; valid steps: {LADDER_STEPS}"
            )
        if not 0.0 < self.ladder_pressure <= 1.0:
            raise ValueError(
                f"ladder_pressure must be in (0, 1]; got {self.ladder_pressure}"
            )


def ServiceConfig(**kwargs) -> RunConfig:  # noqa: N802 - legacy class name
    """Deprecated alias for :class:`RunConfig` (one-release warning).

    The PR-5 spelling ``ServiceConfig(backend=..., max_batch=...)`` maps
    field-for-field onto ``RunConfig``.
    """
    warnings.warn(
        "ServiceConfig is deprecated; use repro.api.RunConfig "
        "(same field names)",
        DeprecationWarning,
        stacklevel=2,
    )
    return RunConfig(**kwargs)


def batch_buckets(max_batch: int, c: float = 4.0) -> tuple[int, ...]:
    """Static batch-dimension buckets — ``bucket_schedule`` applied to B
    (tile=1: the batch axis needs no kernel-grid alignment).  A lane chunk
    of j queries pads up to the smallest bucket >= j, so each (lane,
    B-bucket) signature compiles once and stays warm."""
    return bucket_schedule(max_batch, c, tile=1)


# ----------------------------------------------------------- request API ----

@dataclasses.dataclass(frozen=True)
class SummarizeRequest:
    """One summarization query.

    ``features`` is the (n, F) nonnegative row-feature payload (FeatureCoverage
    for ``objective="coverage"``; the similarity kernel input for
    ``objective="fl"``).  ``sim`` passes a precomputed (n, n) similarity for
    ``objective="fl"`` instead.  ``key`` is the query's PRNG key (an int seed
    is accepted); ``use_ss=False`` skips SS and greedy-selects on the full
    ground set.  ``deadline_s`` is the request's latency budget in seconds
    from submission: the async flusher fires the lane early enough (minus
    the lane's execution estimate and ``RunConfig.slack_s``) to try to make
    it; a budget that is already <= 0 at admission fails the ticket with
    :class:`DeadlineExceeded`, and a missed-but-served deadline is reported
    via ``SummarizeResponse.deadline_missed`` rather than dropped.
    """

    k: int
    key: Any
    features: Array | None = None
    sim: Array | None = None
    objective: str = "coverage"     # coverage | fl
    phi: str = "sqrt"               # FeatureCoverage concave transform
    kernel: str = "cosine"          # FacilityLocation feature kernel
    use_ss: bool = True
    deadline_s: float | None = None  # latency budget from submit (seconds)

    def prng_key(self) -> Array:
        if isinstance(self.key, int):
            return jax.random.PRNGKey(self.key)
        return jnp.asarray(self.key)


@dataclasses.dataclass(frozen=True)
class SummarizeResponse:
    """Per-query result + serving metadata.

    Results are query-for-query identical to the sequential single-query
    pipeline under the same key.  ``queue_delay_s`` is submit → execution
    start; ``exec_s`` the wall time of the micro-batch this query rode in
    (shared by its batch mates); ``batch_size``/``batch_bucket`` how full
    that batch was vs its padded static shape.  ``trigger`` names what fired
    the batch (``manual`` / ``full`` / ``deadline`` / ``max_wait`` /
    ``drain``); ``deadline_missed`` is None when the request carried no
    deadline, else whether the batch finished past it.

    ``degradation`` is None for a full-quality answer, else the audit
    record of the ladder walk that produced this response: ``steps``
    applied, the ``r`` / ``c`` / ``selector`` actually run, the ladder
    ``level``, and the ``reason`` (``deadline`` / ``pressure`` /
    ``forced``).  ``recovery`` is None for a first-attempt success, else
    ``{"retries", "stage", "backends", "isolated"}`` describing the
    recovery path that served it.
    """

    selected: Array                 # (k,) int32 ground indices
    gains: Array                    # (k,) marginal gains
    value: float                    # f(S)
    vprime_size: int | None         # |V'| after SS (None when use_ss=False)
    eps_hat: float | None           # SS certificate (None when use_ss=False)
    rounds: int | None              # SS rounds executed
    lane: tuple                     # static signature this query batched under
    batch_size: int                 # real queries in the micro-batch
    batch_bucket: int               # padded static batch dimension
    queue_delay_s: float
    exec_s: float
    trigger: str = "manual"         # what fired this micro-batch
    deadline_missed: bool | None = None
    degradation: dict | None = None  # ladder audit record (None = full quality)
    recovery: dict | None = None    # recovery audit record (None = 1st attempt)


# ------------------------------------------------------- functional core ----

def build_batch_objective(requests: list[SummarizeRequest], n_pad: int | None):
    """Stack one lane's payloads into a batched objective (+ alive mask when
    ground-set padding is active).  All requests must share a lane."""
    req0 = requests[0]
    if req0.objective == "coverage":
        Ws = [jnp.asarray(r.features) for r in requests]
        if n_pad is not None:
            Ws = [
                jnp.zeros((n_pad, W.shape[1]), W.dtype).at[: W.shape[0]].set(W)
                for W in Ws
            ]
        fn = FeatureCoverage(W=jnp.stack(Ws), phi=req0.phi)
    elif req0.objective == "fl":
        if req0.sim is not None:
            sims = [jnp.asarray(r.sim) for r in requests]
            if n_pad is not None:
                sims = [
                    jnp.zeros((n_pad, n_pad), s.dtype)
                    .at[: s.shape[0], : s.shape[1]].set(s)
                    for s in sims
                ]
            sim_b = jnp.stack(sims)
        else:
            Xs = [jnp.asarray(r.features) for r in requests]
            if n_pad is not None:
                Xs = [
                    jnp.zeros((n_pad, X.shape[1]), X.dtype)
                    .at[: X.shape[0]].set(X)
                    for X in Xs
                ]
            sim_b = jax.vmap(
                lambda X: FacilityLocation.from_features(
                    X, kernel=req0.kernel
                ).sim
            )(jnp.stack(Xs))
            if n_pad is not None:
                # Zero the padded rows/columns of the *similarity*: zero sim
                # is inert for any kernel, while e.g. the rbf similarity of
                # a zero feature row is not.
                valid = jnp.stack([
                    jnp.arange(n_pad) < r.features.shape[0] for r in requests
                ])
                sim_b = sim_b * (
                    valid[:, :, None] & valid[:, None, :]
                ).astype(sim_b.dtype)
        fn = FacilityLocation(sim=sim_b)
    else:
        raise ValueError(f"unknown objective {req0.objective!r}")
    if n_pad is None:
        return fn, None
    # Per-row dead-padding mask: one padded lane can mix different real n.
    n_reals = [
        (r.features if r.sim is None else r.sim).shape[0] for r in requests
    ]
    alive = jnp.stack(
        [jnp.arange(n_pad) < n_real for n_real in n_reals]
    )
    return fn, alive


def summarize_batch(
    fn,
    k: int,
    keys: Array,
    *,
    r: int = 8,
    c: float = 8.0,
    use_ss: bool = True,
    alive: Array | None = None,
    backend=None,
    compact: "bool | int | None" = None,
    on_step=None,
    selector: str = "greedy",
    eps: float = 0.1,
    s: int | None = None,
) -> tuple[GreedyResult, SSResult | None]:
    """The service's execution core: batched SS → batched compact selection
    on a stacked objective.  Row b is identical to the sequential
    single-query pipeline under ``keys[b]``.  Shared with the KV-cache
    pruning path (repro.serve.kv_select), which feeds it one lane per decode
    batch.  ``compact`` = None auto-derives the static SS live bound (the
    tracer-safe default); ``on_step`` streams greedy steps (see
    :func:`repro.core.greedy_batched`).

    ``selector`` picks the selection stage: ``"greedy"`` (exact, the
    default) or ``"stochastic"`` (the degradation ladder's *lazier than
    lazy* step, :func:`repro.core.stochastic_greedy_batched` with sample
    size from ``eps`` / ``s``).  The stochastic selector draws from
    ``fold_in(keys[b], 1)`` so its sample stream never collides with the SS
    probe stream that already consumed ``keys[b]`` — a sequential reference
    run must fold the same way (tests/test_serve_faults.py pins this).
    """
    be = resolve_backend(backend)
    ss = None
    sel_alive = alive
    if use_ss:
        ss = ss_sparsify_batched(fn, keys, r=r, c=c, alive=alive, backend=be)
        sel_alive = ss.vprime
        if compact is None:
            # Static O(log² n) bound on |V'|: with a concrete mask the engine
            # still host-reads the exact live count, but under jit/vmap
            # (tracer vprime — e.g. a compiled decode loop pruning its KV
            # cache) this keeps the post-SS greedy on the compact path
            # instead of silently degrading to full-width O(n) steps.
            n = jax.tree.map(lambda x: x[0], fn).n
            compact = ss_live_bound(n, r, c)
    if selector == "greedy":
        res = greedy_batched(
            fn, k, alive=sel_alive, backend=be, compact=compact,
            on_step=on_step,
        )
    elif selector == "stochastic":
        sel_keys = jax.vmap(lambda kk: jax.random.fold_in(kk, 1))(keys)
        res = stochastic_greedy_batched(
            fn, k, sel_keys, s=s, alive=sel_alive, backend=be,
            compact=compact, eps=eps, on_step=on_step,
        )
    else:
        raise ValueError(
            f"selector must be 'greedy' or 'stochastic'; got {selector!r}"
        )
    return res, ss


# ------------------------------------------------------------ the ticket ----

class Ticket:
    """Future-style handle returned by :meth:`SummarizeService.submit`.

    ``result(timeout=None)`` blocks until the scheduler executes the query
    and returns its :class:`SummarizeResponse` — or re-raises the error
    captured for *this* request (admission failures like
    :class:`DeadlineExceeded` / a malformed payload, or the execution error
    of the chunk it rode in after recovery was exhausted).  A wait that
    times out raises :class:`TicketPending` naming the ticket's state
    (``queued`` / ``executing``) so a caller who gave up on ``drain``
    sees *why* the ticket is unresolved instead of blocking forever.
    ``done()`` / ``exception()`` mirror ``concurrent.futures.Future``.
    With ``RunConfig.stream_steps`` the committed greedy prefix is readable
    mid-flight via :meth:`partial`.

    Settlement is first-wins and idempotent (:meth:`_settle`): when the
    watchdog abandons a hung attempt and the recovery path re-runs the
    chunk, whichever attempt finishes first owns the ticket — the loser's
    late results are discarded, so a ticket can never be resolved twice or
    flap between a response and an error.
    """

    __slots__ = (
        "index", "_submit_t", "_deadline_t", "_event", "_response", "_error",
        "_steps", "_lock", "_state",
    )

    def __init__(self, index: int, submit_t: float,
                 deadline_t: float | None = None):
        self.index = index
        self._submit_t = submit_t
        self._deadline_t = deadline_t
        self._event = threading.Event()
        self._response: SummarizeResponse | None = None
        self._error: BaseException | None = None
        self._steps: list[tuple[int, float]] = []
        self._lock = threading.Lock()
        self._state = "queued"      # queued | executing | done | failed

    def done(self) -> bool:
        """True once the ticket holds a response or a captured error."""
        return self._event.is_set()

    def state(self) -> str:
        """Lifecycle state: ``queued`` → ``executing`` → ``done``/``failed``."""
        return self._state

    def result(self, timeout: float | None = None) -> SummarizeResponse:
        """Block until resolved; returns the response or re-raises the
        captured per-request error.  Raises :class:`TicketPending` (a
        TimeoutError) if ``timeout`` elapses first — the query stays in
        flight and a later wait can still succeed."""
        if not self._event.wait(timeout):
            raise TicketPending(
                f"ticket {self.index} still {self._state} after {timeout}s "
                "(its micro-batch has not resolved; drain() or a longer "
                "timeout will settle it)"
            )
        if self._error is not None:
            raise self._error
        return self._response

    def exception(self, timeout: float | None = None) -> BaseException | None:
        """The captured error (None on success); blocks like ``result``."""
        if not self._event.wait(timeout):
            raise TicketPending(
                f"ticket {self.index} still {self._state} after {timeout}s "
                "(its micro-batch has not resolved; drain() or a longer "
                "timeout will settle it)"
            )
        return self._error

    def partial(self) -> list[tuple[int, float]]:
        """Committed (ground index, gain) greedy steps streamed so far —
        populated mid-execution when ``RunConfig.stream_steps`` is on, and
        always consistent with the final ``selected``/``gains`` prefix."""
        return list(self._steps)

    def _settle(self, response: SummarizeResponse | None = None,
                error: BaseException | None = None) -> bool:
        """Resolve the ticket exactly once (first caller wins).  Returns
        False when the ticket was already settled — the caller (a retried,
        failed-over, or watchdog-abandoned attempt) must then discard its
        results and account for nothing."""
        with self._lock:
            if self._event.is_set():
                return False
            self._response = response
            self._error = error
            self._state = "done" if error is None else "failed"
            self._event.set()
            return True

    def _fulfill(self, response: SummarizeResponse) -> None:
        self._settle(response=response)

    def _fail(self, error: BaseException) -> None:
        self._settle(error=error)


@dataclasses.dataclass
class _QueueItem:
    ticket: Ticket
    request: SummarizeRequest
    lane: tuple
    submit_t: float
    deadline_t: float | None


# ------------------------------------------------------------ the service ----

class SummarizeService:
    """Queue-fed micro-batching engine over :func:`summarize_batch`.

    ``submit`` admits a request and returns a :class:`Ticket` future.  With
    the default ``RunConfig(scheduler="sync")`` execution happens on
    ``flush()`` — the queue is drained, queries grouped by *lane* (the
    static compile signature: ground-set size, payload shape, k, objective
    config, use_ss), chunked at ``max_batch``, each chunk padded up to its
    batch bucket (padding rows repeat row 0 and are discarded) and executed
    as one batched pipeline.

    With ``scheduler="async"`` a daemon flusher thread owns execution: lanes
    fire on (full ∨ deadline-slack ∨ max-wait), continuous batching pulls at
    most ``max_batch`` from a lane's head per firing so arrivals refill the
    next bucket while a batch is in flight, and ``drain()`` force-fires the
    backlog and blocks until every outstanding ticket resolves.  ``run`` is
    submit-all + drain on either scheduler.  The service is a context
    manager: leaving the ``with`` block drains and stops the flusher.

    Every chunk executes through the recovery loop described in the module
    docstring (retry → failover → per-query isolation, watchdog-bounded)
    and, when ``RunConfig.ladder`` is set, through the degradation planner.
    ``faults`` threads a seeded :class:`repro.serve.faults.FaultPlan` into
    the executor — the test/bench chaos hook; production services leave it
    None (zero overhead: one attribute check per chunk).
    """

    def __init__(self, config: RunConfig | None = None, *,
                 faults: "FaultPlan | None" = None, **legacy_kwargs):
        if config is None:
            config = RunConfig()
        if not isinstance(config, RunConfig):
            raise TypeError(
                f"SummarizeService takes a RunConfig; got {type(config)!r}"
            )
        if legacy_kwargs:
            warnings.warn(
                "passing ServiceConfig-style kwargs to SummarizeService is "
                "deprecated; use SummarizeService(RunConfig(...))",
                DeprecationWarning,
                stacklevel=2,
            )
            config = dataclasses.replace(config, **legacy_kwargs)
        self.config = config
        self._faults = faults
        self._buckets = batch_buckets(config.max_batch, config.batch_c)
        self._cond = threading.Condition()
        self._lanes: dict[tuple, list[_QueueItem]] = {}
        self._pending = 0               # queued, not yet executing
        self._outstanding = 0           # queued or executing
        self._exec_est: dict[tuple, float] = {}   # keyed (lane, ladder level)
        self._ladder_cache: dict[tuple, list[dict]] = {}
        self._drain_requested = False
        self._stop = False
        self._killed = False            # a drawn ``crash`` fault fired
        self._thread: threading.Thread | None = None
        self._n_submitted = 0
        self._stats = {
            "queries": 0,
            "batches": 0,
            "padded_slots": 0,
            "slots": 0,
            "queue_delay_s_sum": 0.0,
            "queue_delay_s_max": 0.0,
            "exec_s_sum": 0.0,
            "lanes": set(),
            "triggers": {},
            "deadlines_missed": 0,
            "failed": 0,
            "retries": 0,
            "failovers": 0,
            "isolated_queries": 0,
            "chunk_timeouts": 0,
            "degraded": 0,
            "restarts": 0,
        }
        if config.scheduler == "async":
            self.start()

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> None:
        """Start the background flusher (idempotent; async scheduler only)."""
        if self.config.scheduler != "async":
            raise RuntimeError("start() requires RunConfig(scheduler='async')")
        if self._thread is not None:
            return
        self._stop = False
        self._thread = threading.Thread(
            target=self._flusher, name="summarize-flusher", daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        """Drain outstanding work, then stop the flusher thread."""
        if self._thread is None:
            return
        self.drain()
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        self._thread.join()
        self._thread = None

    def __enter__(self) -> "SummarizeService":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- admission ---------------------------------------------------------
    def submit(self, request: SummarizeRequest) -> Ticket:
        """Admit one request.  Admission failures — malformed payload (a
        missing / NaN / Inf payload, ``k < 1``), an already-spent deadline,
        queue backpressure — fail the returned ticket immediately instead
        of raising, so one bad request never blocks its batch mates (and
        never corrupts the compiled chunk it would have ridden in)."""
        now = time.perf_counter()
        deadline_t = (
            None if request.deadline_s is None else now + request.deadline_s
        )
        ticket = Ticket(self._n_submitted, now, deadline_t)
        self._n_submitted += 1
        try:
            if self._killed:
                raise ServiceRestarted(
                    "the service crashed (injected crash fault); in-flight "
                    "tickets were settled with ServiceRestarted and new "
                    "submissions are rejected — construct a new service"
                )
            lane = self._lane(request)
            if request.k < 1:
                raise ValueError(f"k must be >= 1; got k={request.k}")
            if self.config.validate_payloads:
                payload = (
                    request.sim if request.sim is not None else request.features
                )
                if not bool(jnp.all(jnp.isfinite(jnp.asarray(payload)))):
                    raise ValueError(
                        "payload contains non-finite values (NaN/Inf); "
                        "rejected at admission (RunConfig.validate_payloads)"
                    )
            if request.deadline_s is not None and request.deadline_s <= 0:
                raise DeadlineExceeded(
                    f"deadline_s={request.deadline_s} already spent at "
                    "admission"
                )
            with self._cond:
                cap = self.config.max_pending
                if cap is not None and self._pending >= cap:
                    raise ServiceOverloaded(
                        f"{self._pending} requests pending >= "
                        f"max_pending={cap}"
                    )
                self._lanes.setdefault(lane, []).append(
                    _QueueItem(ticket, request, lane, now, deadline_t)
                )
                self._pending += 1
                self._outstanding += 1
                self._cond.notify_all()
        except Exception as e:  # noqa: BLE001 - captured on the ticket
            with self._cond:
                self._stats["failed"] += 1
            ticket._settle(error=e)
            obs.get_registry().counter(
                "repro_service_requests_total", "admitted requests by outcome",
                labels=("outcome",),
            ).inc(outcome="rejected")
            tr = obs.get_tracer()
            if tr.enabled:
                tr.record(
                    "request.admit", now, time.perf_counter(),
                    trace_id=f"req-{ticket.index}", status="error",
                    error=type(e).__name__,
                )
            return ticket
        obs.get_registry().counter(
            "repro_service_requests_total", "admitted requests by outcome",
            labels=("outcome",),
        ).inc(outcome="admitted")
        tr = obs.get_tracer()
        if tr.enabled:
            tr.record(
                "request.admit", now, time.perf_counter(),
                trace_id=f"req-{ticket.index}", lane=_lane_label(lane),
            )
        return ticket

    def _lane(self, req: SummarizeRequest) -> tuple:
        payload = req.sim if req.sim is not None else req.features
        if payload is None:
            raise ValueError("request needs a features or sim payload")
        kind = "sim" if req.sim is not None else "features"
        shape = tuple(payload.shape)
        n = shape[0]
        n_pad = None
        if self.config.n_buckets is not None:
            fits = [b for b in self.config.n_buckets if b >= n]
            if not fits:
                raise ValueError(
                    f"query n={n} exceeds every configured n bucket "
                    f"{self.config.n_buckets}"
                )
            n_pad = min(fits)
            shape = (n_pad,) + shape[1:] if req.sim is None else (n_pad, n_pad)
        # ``kind`` keeps sim-payload and feature-payload queries in separate
        # lanes: a (n, n) feature matrix must not stack with a (n, n) sim.
        return (
            req.objective, kind, shape, req.k, req.phi, req.kernel,
            req.use_ss, n_pad,
        )

    # -- scheduling --------------------------------------------------------
    def _next_fire(self, now: float):
        """The flusher's policy: the most urgent (lane, fire time, trigger)
        among non-empty lanes, or (None, None, None) on an empty queue.

        A lane fires *now* when full (``max_batch`` queued) or when a drain
        was requested; otherwise at the earlier of (oldest submit +
        ``max_wait_s``) and, per queued deadline, (deadline − lane EWMA
        execution estimate − ``slack_s``).  Must be called with the lock
        held."""
        best = (None, None, None)
        for lane, items in self._lanes.items():
            if not items:
                continue
            if len(items) >= self.config.max_batch:
                return lane, now, "full"
            if self._drain_requested:
                return lane, now, "drain"
            fire_t = items[0].submit_t + self.config.max_wait_s
            trigger = "max_wait"
            est = self._exec_est.get((lane, 0), 0.0)
            for it in items:
                if it.deadline_t is None:
                    continue
                t = it.deadline_t - est - self.config.slack_s
                if t < fire_t:
                    fire_t, trigger = t, "deadline"
            if best[0] is None or fire_t < best[1]:
                best = (lane, fire_t, trigger)
        return best

    def _flusher(self) -> None:
        """Background consumer loop (async scheduler): sleep until the next
        firing time, pull ≤ max_batch from the fired lane's head, execute,
        repeat — submissions during execution land in the lane queues and
        refill the next bucket (continuous batching).  Chunk failures and
        timeouts are absorbed by the recovery loop / :meth:`_resolve_err`,
        so nothing propagates out of this thread."""
        while True:
            with self._cond:
                if self._stop:
                    return
                now = time.perf_counter()
                lane, fire_t, trigger = self._next_fire(now)
                if lane is None:
                    if self._drain_requested:
                        # Queue is empty: the drain is satisfied once
                        # in-flight work lands (tracked by _outstanding).
                        self._drain_requested = False
                        self._cond.notify_all()
                    self._cond.wait()
                    continue
                if fire_t > now:
                    self._cond.wait(timeout=fire_t - now)
                    continue
                items = self._lanes[lane][: self.config.max_batch]
                del self._lanes[lane][: self.config.max_batch]
                self._pending -= len(items)
            self._run_chunk(lane, items, trigger)

    def drain(self, timeout: float | None = None) -> None:
        """Force-fire everything queued and block until every admitted
        ticket has resolved.  On the sync scheduler this is ``flush()``.
        Raises TimeoutError when ``timeout`` elapses with tickets still in
        flight — those tickets stay live (``result`` on one raises
        :class:`TicketPending` until its chunk lands)."""
        if self._thread is None:
            self.flush(trigger="drain")
            return
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._cond:
            self._drain_requested = True
            self._cond.notify_all()
            while self._outstanding > 0:
                left = (
                    None if deadline is None
                    else deadline - time.perf_counter()
                )
                if left is not None and left <= 0:
                    raise TimeoutError(
                        f"{self._outstanding} tickets unresolved after drain "
                        f"timeout {timeout}s"
                    )
                self._cond.wait(timeout=left)

    # -- execution ---------------------------------------------------------
    def flush(self, trigger: str = "manual") -> list[SummarizeResponse | None]:
        """Synchronously drain everything queued now (sync scheduler's
        execution entry; also usable while the async flusher is stopped).
        Returns responses in submission order — entries are None for
        tickets whose chunk failed (the error lives on the ticket)."""
        with self._cond:
            pending: list[_QueueItem] = []
            for items in self._lanes.values():
                pending.extend(items)
            self._lanes = {}
            self._pending -= len(pending)
        pending.sort(key=lambda it: it.ticket.index)
        lanes: dict[tuple, list[_QueueItem]] = {}
        for it in pending:
            lanes.setdefault(it.lane, []).append(it)
        for lane, items in lanes.items():
            for lo in range(0, len(items), self.config.max_batch):
                self._run_chunk(
                    lane, items[lo: lo + self.config.max_batch], trigger
                )
        return [it.ticket._response for it in pending]

    def run(
        self, requests: list[SummarizeRequest]
    ) -> list[SummarizeResponse]:
        """Convenience wrapper: submit everything, drain, and return the
        responses in request order — re-raising the first captured
        per-request error, if any (read the tickets individually via
        ``submit`` to handle partial failure)."""
        tickets = [self.submit(r) for r in requests]
        self.drain()
        return [t.result(timeout=0) for t in tickets]

    # -- recovery ----------------------------------------------------------
    def _run_chunk(
        self, lane: tuple, items: list[_QueueItem], trigger: str
    ) -> None:
        """Execute one popped chunk through the recovery loop; whatever
        happens, every ticket in ``items`` ends settled."""
        for it in items:
            it.ticket._state = "executing"
        try:
            degradation = self._degradation_plan(lane, items)
            if degradation is not None:
                obs.get_bus().emit(
                    "degradation", subsystem="service",
                    request_ids=tuple(it.ticket.index for it in items),
                    level=degradation["level"], reason=degradation["reason"],
                    steps=degradation["steps"],
                    selector=degradation["selector"],
                )
                obs.get_registry().counter(
                    "repro_service_degraded_chunks_total",
                    "chunks planned at a degraded ladder level",
                    labels=("level", "reason"),
                ).inc(level=degradation["level"],
                      reason=degradation["reason"])
            self._execute_with_recovery(lane, items, trigger, degradation)
        except Exception as e:  # noqa: BLE001 - captured on the tickets
            self._resolve_err(items, e)

    def _execute_with_recovery(
        self, lane: tuple, items: list[_QueueItem], trigger: str,
        degradation: dict | None,
    ) -> None:
        """Retry → failover → per-query isolation.

        Per stage (primary backend, then ``failover_backend`` when it
        resolves to a different backend): ``max_retries + 1`` attempts with
        ``retry_backoff_s``·2^(attempt−1) sleeps between them.  A
        :class:`ChunkTimeout` skips the remaining retries of its stage (a
        hung signature is not re-run) but still fails over.  When every
        stage is exhausted and the chunk has >1 query,
        ``isolate_on_failure`` re-runs it one query at a time on the last
        stage's backend — the poisoned query fails alone, its chunk-mates
        complete.  Attempts that already lost their tickets to a faster
        attempt are no-ops (first-wins settle)."""
        cfg = self.config
        primary = resolve_backend(cfg.backend)
        stages = [("primary", primary)]
        if cfg.failover_backend is not None:
            fallback = resolve_backend(cfg.failover_backend)
            if fallback.name != primary.name:
                stages.append(("failover", fallback))
        failures = 0
        tried: list[str] = []
        last_err: Exception | None = None
        idxs = tuple(it.ticket.index for it in items)
        reg = obs.get_registry()
        bus = obs.get_bus()
        for stage, be in stages:
            if be.name not in tried:
                tried.append(be.name)
            if stage == "failover":
                with self._cond:
                    self._stats["failovers"] += 1
                reg.counter(
                    "repro_service_failovers_total",
                    "chunks that reached the failover backend",
                ).inc()
                bus.emit(
                    "recovery", subsystem="service", request_ids=idxs,
                    step="failover", backend=be.name,
                    error=type(last_err).__name__ if last_err else None,
                )
            for attempt in range(cfg.max_retries + 1):
                if attempt > 0:
                    time.sleep(cfg.retry_backoff_s * (2 ** (attempt - 1)))
                recovery = None
                if failures > 0:
                    with self._cond:
                        self._stats["retries"] += 1
                    reg.counter(
                        "repro_service_retries_total",
                        "chunk attempts after a failure",
                        labels=("stage",),
                    ).inc(stage=stage)
                    bus.emit(
                        "recovery", subsystem="service", request_ids=idxs,
                        step="retry", stage=stage, backend=be.name,
                        attempt=attempt, failures=failures,
                    )
                    recovery = {
                        "retries": failures,
                        "stage": stage,
                        "backends": tuple(tried),
                        "isolated": False,
                    }
                try:
                    self._attempt_with_watchdog(
                        lambda be=be, stage=stage, recovery=recovery:
                        self._exec_chunk(
                            lane, items, trigger, backend=be, stage=stage,
                            degradation=degradation, recovery=recovery,
                        )
                    )
                    return
                except ChunkTimeout as e:
                    last_err = e
                    failures += 1
                    with self._cond:
                        self._stats["chunk_timeouts"] += 1
                    reg.counter(
                        "repro_service_chunk_timeouts_total",
                        "watchdog-abandoned chunk attempts",
                    ).inc()
                    bus.emit(
                        "recovery", subsystem="service", request_ids=idxs,
                        step="chunk_timeout", stage=stage, backend=be.name,
                    )
                    break  # hung signature: don't re-run it in this stage
                except ServiceRestarted:
                    # The engine died mid-attempt: every ticket is already
                    # settled with the error; retry/failover/isolation would
                    # be theater on a dead process.
                    raise
                except Exception as e:  # noqa: BLE001 - recovery continues
                    last_err = e
                    failures += 1
        if cfg.isolate_on_failure and len(items) > 1:
            stage_be = stages[-1][1]
            reg.counter(
                "repro_service_isolations_total",
                "chunks re-run one query at a time",
            ).inc()
            bus.emit(
                "recovery", subsystem="service", request_ids=idxs,
                step="isolate", backend=stage_be.name, failures=failures,
            )
            for it in items:
                recovery = {
                    "retries": failures,
                    "stage": "isolated",
                    "backends": tuple(tried),
                    "isolated": True,
                }
                try:
                    self._attempt_with_watchdog(
                        lambda it=it, recovery=recovery: self._exec_chunk(
                            lane, [it], trigger, backend=stage_be,
                            stage="isolated", degradation=degradation,
                            recovery=recovery,
                        )
                    )
                except Exception as e:  # noqa: BLE001 - this query fails alone
                    self._resolve_err([it], e)
            return
        raise last_err

    def _attempt_with_watchdog(self, call: Callable[[], None]) -> None:
        """Run one chunk attempt, bounded by ``chunk_timeout_s``.

        With the watchdog armed the attempt runs in a daemon worker thread;
        if it outlives the budget the attempt is abandoned with
        :class:`ChunkTimeout` — the worker keeps running (a genuinely hung
        device call cannot be interrupted from Python) but its late results
        are discarded by the tickets' first-wins settle and it accounts for
        nothing."""
        timeout = self.config.chunk_timeout_s
        if timeout is None:
            call()
            return
        box: dict[str, BaseException] = {}
        done = threading.Event()

        def worker():
            try:
                call()
            except BaseException as e:  # noqa: BLE001 - re-raised below
                box["error"] = e
            finally:
                done.set()

        t = threading.Thread(
            target=worker, name="summarize-chunk", daemon=True
        )
        t.start()
        if not done.wait(timeout):
            raise ChunkTimeout(
                f"chunk attempt exceeded chunk_timeout_s={timeout}s; "
                "abandoned (late results are discarded)"
            )
        err = box.get("error")
        if err is not None:
            raise err

    # -- degradation ladder ------------------------------------------------
    def _ladder_levels(self, lane: tuple) -> list[dict]:
        """The lane's resolved ladder: per level, the cumulative (r, c,
        selector) actually run and the predicted cost ratio vs the previous
        level (``ss_cost_model`` for SS-side steps; 1.0 — i.e. "unknown,
        keep walking" — for the selection-side stochastic step until its
        (lane, level) EWMA seeds)."""
        levels = self._ladder_cache.get(lane)
        if levels is not None:
            return levels
        cfg = self.config
        n = lane[2][0]
        use_ss = lane[6]
        r, c, selector = cfg.r, cfg.c, "greedy"
        levels = []
        for step in cfg.ladder:
            base = ss_cost_model(n, r, c) if use_ss else None
            if step == "bump_c":
                c = c * 4.0
            elif step == "shrink_r":
                r = max(1, r // 2)
            else:  # stochastic_greedy
                selector = "stochastic"
            ratio = 1.0
            if base is not None and step in ("bump_c", "shrink_r"):
                ratio = ss_cost_model(n, r, c) / base
            levels.append({
                "step": step, "r": r, "c": c, "selector": selector,
                "ratio": ratio,
            })
        self._ladder_cache[lane] = levels
        return levels

    def _degradation_plan(
        self, lane: tuple, items: list[_QueueItem]
    ) -> dict | None:
        """Decide how degraded this chunk runs (None = full quality).

        ``ladder_force`` (test/bench hook) short-circuits to a fixed level.
        Under admission pressure (outstanding work — queued or executing —
        ≥ ``ladder_pressure`` × ``max_pending``) the chunk runs fully
        degraded: the queue is the deadline.  Otherwise the planner walks the ladder while the level's
        execution estimate (measured (lane, level) EWMA, else the previous
        level's estimate × the predicted cost ratio) exceeds the chunk's
        tightest deadline budget.  Cold lanes (no level-0 sample yet) never
        degrade on the deadline path: the first compile is unpredictable
        and a served-late-but-full-quality answer is the better default."""
        cfg = self.config
        if not cfg.ladder:
            return None
        levels = self._ladder_levels(lane)
        n_steps = 0
        reason = None
        if cfg.ladder_force is not None:
            n_steps = max(0, min(cfg.ladder_force, len(levels)))
            reason = "forced"
        else:
            with self._cond:
                outstanding = self._outstanding
                est0 = self._exec_est.get((lane, 0))
                ests = {
                    lv: self._exec_est.get((lane, lv))
                    for lv in range(1, len(levels) + 1)
                }
            cap = cfg.max_pending
            if cap is not None and outstanding >= cfg.ladder_pressure * cap:
                n_steps = len(levels)
                reason = "pressure"
            elif est0 is not None:
                deadlines = [
                    it.deadline_t for it in items if it.deadline_t is not None
                ]
                if deadlines:
                    budget = (
                        min(deadlines) - time.perf_counter() - cfg.slack_s
                    )
                    est = est0
                    while n_steps < len(levels) and est > budget:
                        ratio = levels[n_steps]["ratio"]
                        n_steps += 1
                        measured = ests.get(n_steps)
                        est = measured if measured is not None else est * ratio
                    reason = "deadline"
        if n_steps == 0:
            return None
        lv = levels[n_steps - 1]
        return {
            "steps": tuple(cfg.ladder[:n_steps]),
            "level": n_steps,
            "r": lv["r"],
            "c": lv["c"],
            "selector": lv["selector"],
            "reason": reason,
        }

    # -- chunk execution ---------------------------------------------------
    def _exec_chunk(
        self, lane: tuple, items: list[_QueueItem], trigger: str, *,
        backend=None, stage: str = "primary",
        degradation: dict | None = None, recovery: dict | None = None,
    ) -> None:
        cfg = self.config
        be = resolve_backend(cfg.backend if backend is None else backend)
        fault = None
        if self._faults is not None:
            fault = self._faults.draw(
                tickets=tuple(it.ticket.index for it in items),
                lane=lane, backend=be.name, stage=stage,
            )
        if fault is not None and fault.kind in ("crash", "restart"):
            raise self._simulate_restart(kill=fault.kind == "crash")
        if fault is not None and fault.kind == "exec_error":
            raise FaultInjected(
                f"injected exec error on tickets "
                f"{[it.ticket.index for it in items]} ({stage}/{be.name})"
            )
        if fault is not None and fault.kind in ("latency", "hang"):
            time.sleep(fault.delay_s)

        reqs = [it.request for it in items]
        n_real = len(reqs)
        bucket = min(b for b in self._buckets if b >= n_real)
        # Pad the batch dimension by repeating row 0 (results discarded) so
        # the (lane, bucket) signature is the only thing that compiles.
        padded = reqs + [reqs[0]] * (bucket - n_real)
        _, _, _, k, _, _, use_ss, n_pad = lane

        on_step = None
        if cfg.stream_steps:
            for it in items:
                it.ticket._steps.clear()    # a retried attempt restarts it

            def on_step(step, v, g, ok):
                for i, it in enumerate(items):
                    if bool(ok[i]):
                        it.ticket._steps.append((int(v[i]), float(g[i])))

        deg = degradation
        t_start = time.perf_counter()
        tr = obs.get_tracer()
        if tr.enabled:
            # Queue residency is only known retroactively — record each
            # item's wait span from its admission timestamp now that
            # execution starts.
            for it in items:
                tr.record(
                    "queue.wait", it.submit_t, t_start,
                    trace_id=f"req-{it.ticket.index}",
                    lane=_lane_label(lane), trigger=trigger,
                )
        with tr.span(
            "chunk.exec", trace_id=f"req-{items[0].ticket.index}",
            request_ids=tuple(it.ticket.index for it in items),
            lane=_lane_label(lane), backend=be.name, stage=stage,
            trigger=trigger, bucket=bucket, batch=n_real,
            degraded=0 if deg is None else deg["level"],
        ):
            fn, alive = build_batch_objective(padded, n_pad)
            keys = jnp.stack([r.prng_key() for r in padded])
            res, ss = summarize_batch(
                fn, k, keys,
                r=cfg.r if deg is None else deg["r"],
                c=cfg.c if deg is None else deg["c"],
                use_ss=use_ss, alive=alive,
                backend=be, compact=cfg.compact, on_step=on_step,
                selector="greedy" if deg is None else deg["selector"],
                eps=cfg.eps,
            )
            jax.block_until_ready(res.value)
            if fault is not None and fault.kind == "malformed":
                res = res._replace(gains=jnp.full_like(res.gains, jnp.nan))
            finite = bool(
                jnp.all(jnp.isfinite(res.gains[:n_real]))
                & jnp.all(jnp.isfinite(res.value[:n_real]))
            )
            if not finite:
                raise MalformedResult(
                    f"non-finite gains/value in chunk results "
                    f"({stage}/{be.name})"
                )
            t_end = time.perf_counter()
            exec_s = t_end - t_start

        vp_sizes = (
            None if ss is None else jnp.sum(ss.vprime, axis=1)
        )
        responses = []
        for i, it in enumerate(items):
            deadline_missed = (
                None if it.deadline_t is None else t_end > it.deadline_t
            )
            responses.append(SummarizeResponse(
                selected=res.selected[i],
                gains=res.gains[i],
                value=float(res.value[i]),
                vprime_size=None if ss is None else int(vp_sizes[i]),
                eps_hat=None if ss is None else float(ss.eps_hat[i]),
                rounds=None if ss is None else int(ss.rounds[i]),
                lane=lane,
                batch_size=n_real,
                batch_bucket=bucket,
                queue_delay_s=t_start - it.submit_t,
                exec_s=exec_s,
                trigger=trigger,
                deadline_missed=deadline_missed,
                degradation=deg,
                recovery=recovery,
            ))
        # Settle before accounting: first-wins — a watchdog-abandoned
        # attempt finishing late loses every ticket here and must account
        # for nothing; and drain()'s _outstanding==0 then guarantees every
        # ticket is already resolved (no settle/drain race).
        settled = [
            (it, resp) for it, resp in zip(items, responses)
            if it.ticket._settle(response=resp)
        ]
        if not settled:
            return
        missed = sum(bool(r.deadline_missed) for _, r in settled)
        with self._cond:
            st = self._stats
            st["batches"] += 1
            st["queries"] += len(settled)
            st["slots"] += bucket
            st["padded_slots"] += bucket - n_real
            st["exec_s_sum"] += exec_s
            st["lanes"].add((lane, bucket))
            st["triggers"][trigger] = st["triggers"].get(trigger, 0) + 1
            st["deadlines_missed"] += missed
            if deg is not None:
                st["degraded"] += len(settled)
            if stage == "isolated":
                st["isolated_queries"] += len(settled)
            for _, resp in settled:
                st["queue_delay_s_sum"] += resp.queue_delay_s
                st["queue_delay_s_max"] = max(
                    st["queue_delay_s_max"], resp.queue_delay_s
                )
            # EWMA execution estimate drives the deadline-slack trigger and
            # the degradation planner; keyed (lane, ladder level) so a
            # degraded sample never corrupts the full-quality estimate.
            # The first sample seeds it (before that the estimate is 0 — a
            # deadline shorter than the first compile is simply served late
            # and flagged, never dropped).
            est_key = (lane, 0 if deg is None else deg["level"])
            self._exec_est[est_key] = ewma_update(
                self._exec_est.get(est_key), exec_s
            )
            self._outstanding -= len(settled)
            pending_now, outstanding_now = self._pending, self._outstanding
            self._cond.notify_all()
        lane_lbl = _lane_label(lane)
        reg = obs.get_registry()
        reg.histogram(
            "repro_service_exec_seconds", "chunk execution wall time",
            labels=("lane", "backend", "stage"),
        ).observe(exec_s, lane=lane_lbl, backend=be.name, stage=stage)
        delay_h = reg.histogram(
            "repro_service_queue_delay_seconds",
            "per-query admission-to-execution delay", labels=("lane",),
        )
        for _, resp in settled:
            delay_h.observe(resp.queue_delay_s, lane=lane_lbl)
        reg.counter(
            "repro_service_queries_total", "queries served",
        ).inc(len(settled))
        reg.counter(
            "repro_service_batches_total", "chunks executed by trigger",
            labels=("trigger",),
        ).inc(trigger=trigger)
        reg.counter(
            "repro_service_slots_total", "executed batch slots",
        ).inc(bucket)
        reg.counter(
            "repro_service_padded_slots_total",
            "slots burned padding chunks up to their batch bucket",
        ).inc(bucket - n_real)
        if missed:
            reg.counter(
                "repro_service_deadlines_missed_total",
                "settled queries past their deadline",
            ).inc(missed)
        reg.counter(
            "repro_service_degradation_level_total",
            "queries served per ladder level (level 0 = full quality)",
            labels=("level",),
        ).inc(len(settled), level=0 if deg is None else deg["level"])
        reg.gauge(
            "repro_service_pending", "requests queued, not yet executing",
        ).set(pending_now)
        reg.gauge(
            "repro_service_outstanding", "requests queued or executing",
        ).set(outstanding_now)

    def _simulate_restart(self, *, kill: bool) -> ServiceRestarted:
        """A drawn ``crash``/``restart`` fault: the in-memory engine dies.

        Every queued item is drained and — like the in-flight chunk, whose
        items settle when the caller raises the returned error — settled
        with :class:`ServiceRestarted`, so no ticket ever hangs in
        ``TicketPending`` across a restart.  ``kill=True`` (crash) also
        poisons admission: subsequent :meth:`submit` calls fail their
        tickets with the same error.  ``kill=False`` (restart) keeps the
        service serving new submissions — the restarted process comes back
        with empty queues."""
        what = "crashed" if kill else "restarted"
        err = ServiceRestarted(
            f"the service {what} while this request was in flight; "
            "in-memory state (queues, in-flight chunks) was lost"
        )
        with self._cond:
            drained: list[_QueueItem] = []
            for lane_items in self._lanes.values():
                drained.extend(lane_items)
            self._lanes.clear()
            self._pending = 0
            self._stats["restarts"] += 1
            if kill:
                self._killed = True
        obs.get_bus().emit(
            "restart", subsystem="service",
            request_ids=tuple(it.ticket.index for it in drained),
            kill=kill,
        )
        obs.get_registry().counter(
            "repro_service_restarts_total", "simulated engine restarts",
        ).inc()
        self._resolve_err(drained, err)
        return err

    def _resolve_err(
        self, items: list[_QueueItem], error: BaseException
    ) -> None:
        """Fail every not-yet-settled ticket in ``items`` with ``error`` and
        account only for the ones this call actually settled."""
        settled = [it for it in items if it.ticket._settle(error=error)]
        if not settled:
            return
        with self._cond:
            self._stats["failed"] += len(settled)
            self._outstanding -= len(settled)
            self._cond.notify_all()

    # -- accounting --------------------------------------------------------
    def stats(self) -> dict:
        """Aggregate serving counters: query/batch totals, padding waste
        (fraction of executed slots burned on bucket padding), queue-delay
        mean/max, distinct compiled signatures, firing-trigger counts,
        missed deadlines, failed (admission- or execution-errored) tickets,
        and the fault-tolerance counters — retried attempts, chunks that
        reached failover, queries served from per-query isolation, watchdog
        chunk timeouts, and queries served degraded."""
        # The whole snapshot — including every derived value — is computed
        # under the ticket-settle lock, so the returned dict is one
        # consistent point in time: ``queries`` can never disagree with the
        # ``queue_delay_s_sum`` it divides (the old read-then-derive path
        # could tear between a settle and the division).
        with self._cond:
            st = self._stats
            q = max(st["queries"], 1)
            return {
                "queries": st["queries"],
                "batches": st["batches"],
                "padding_waste_frac": (
                    st["padded_slots"] / max(st["slots"], 1)
                ),
                "queue_delay_s_mean": st["queue_delay_s_sum"] / q,
                "queue_delay_s_max": st["queue_delay_s_max"],
                "exec_s_total": st["exec_s_sum"],
                "compiled_signatures": len(st["lanes"]),
                "triggers": dict(st["triggers"]),
                "deadlines_missed": st["deadlines_missed"],
                "failed": st["failed"],
                "retries": st["retries"],
                "failovers": st["failovers"],
                "isolated_queries": st["isolated_queries"],
                "chunk_timeouts": st["chunk_timeouts"],
                "degraded": st["degraded"],
                "restarts": st["restarts"],
            }
