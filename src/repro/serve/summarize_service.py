"""Micro-batched multi-query summarization service: the request-level layer
over SS + greedy.

Every caller so far invoked ``ss_sparsify``/``greedy`` one ground set at a
time.  This module is the serving engine the ROADMAP north star asks for: it
accepts per-query requests (a feature or similarity payload, a budget k, an
objective config, a per-query PRNG key), admits them into a queue,
micro-batches compatible queries into **bucketed static shapes** — the
``bucket_schedule`` idea applied to the batch dimension (and optionally the
ground-set dimension), so each (n, B-bucket, k) signature compiles once and
stays warm — and executes the full SS → compact-greedy pipeline for the
whole batch as one compiled loop via the first-class batched entry points
``ss_sparsify_batched`` / ``greedy_batched`` (repro.core).

Correctness contract: micro-batching is a pure execution strategy.  Each
query's ``selected`` / ``gains`` / ``value`` (and SS ``vprime`` /
``eps_hat``) are *identical* to a sequential single-query
``ss_sparsify(fn, key)`` + ``greedy(fn, k, alive=vprime)`` run under the
same per-query key — regardless of which queries it was batched with, the
batch bucket padding, or mixed n / k in the same flush
(tests/test_serve_service.py pins this query-for-query).

Accounting: the service tracks queue delay per query (submit → execution
start), per-batch execution wall time, and padding waste (slots burned
rounding a lane chunk up to its batch bucket) — the numbers a capacity
planner needs to tune ``max_batch`` against traffic.

Optional ground-set padding (``ServiceConfig.n_buckets``): queries whose n
is not in the bucket list are zero-padded up to the next bucket with the
padding rows dead-masked, collapsing many distinct-n compile signatures
into a few.  Padding changes the PRNG frame of SS (an (n_bucket,) Gumbel
draw), so a padded query matches the sequential run *on the padded ground
set*, not on the raw one — exact-n lanes (the default) keep the strict
contract.  Pure-greedy queries (``use_ss=False``) are padding-invariant
either way: dead rows can never win an argmax.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import (
    FacilityLocation,
    FeatureCoverage,
    GreedyResult,
    SSResult,
    bucket_schedule,
    greedy_batched,
    resolve_backend,
    ss_live_bound,
    ss_sparsify_batched,
)

Array = jax.Array


# ----------------------------------------------------------- request API ----

@dataclasses.dataclass(frozen=True)
class SummarizeRequest:
    """One summarization query.

    ``features`` is the (n, F) nonnegative row-feature payload (FeatureCoverage
    for ``objective="coverage"``; the similarity kernel input for
    ``objective="fl"``).  ``sim`` passes a precomputed (n, n) similarity for
    ``objective="fl"`` instead.  ``key`` is the query's PRNG key (an int seed
    is accepted); ``use_ss=False`` skips SS and greedy-selects on the full
    ground set.
    """

    k: int
    key: Any
    features: Array | None = None
    sim: Array | None = None
    objective: str = "coverage"     # coverage | fl
    phi: str = "sqrt"               # FeatureCoverage concave transform
    kernel: str = "cosine"          # FacilityLocation feature kernel
    use_ss: bool = True

    def prng_key(self) -> Array:
        if isinstance(self.key, int):
            return jax.random.PRNGKey(self.key)
        return jnp.asarray(self.key)


@dataclasses.dataclass(frozen=True)
class SummarizeResponse:
    """Per-query result + serving metadata.

    Results are query-for-query identical to the sequential single-query
    pipeline under the same key.  ``queue_delay_s`` is submit → execution
    start; ``exec_s`` the wall time of the micro-batch this query rode in
    (shared by its batch mates); ``batch_size``/``batch_bucket`` how full
    that batch was vs its padded static shape.
    """

    selected: Array                 # (k,) int32 ground indices
    gains: Array                    # (k,) marginal gains
    value: float                    # f(S)
    vprime_size: int | None         # |V'| after SS (None when use_ss=False)
    eps_hat: float | None           # SS certificate (None when use_ss=False)
    rounds: int | None              # SS rounds executed
    lane: tuple                     # static signature this query batched under
    batch_size: int                 # real queries in the micro-batch
    batch_bucket: int               # padded static batch dimension
    queue_delay_s: float
    exec_s: float


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Service-level knobs (per-query knobs live on the request)."""

    backend: Any = None             # str | Backend | None (repro.core.backend)
    r: int = 8                      # SS probe multiplier
    c: float = 8.0                  # SS accuracy/speed tradeoff
    max_batch: int = 8              # admission cap per micro-batch
    batch_c: float = 4.0            # B-bucket shrink factor (buckets =
    #                                 bucket_schedule(max_batch, batch_c, 1))
    n_buckets: tuple[int, ...] | None = None  # opt-in ground-set padding


def batch_buckets(max_batch: int, c: float = 4.0) -> tuple[int, ...]:
    """Static batch-dimension buckets — ``bucket_schedule`` applied to B
    (tile=1: the batch axis needs no kernel-grid alignment).  A lane chunk
    of j queries pads up to the smallest bucket >= j, so each (lane,
    B-bucket) signature compiles once and stays warm."""
    return bucket_schedule(max_batch, c, tile=1)


# ------------------------------------------------------- functional core ----

def build_batch_objective(requests: list[SummarizeRequest], n_pad: int | None):
    """Stack one lane's payloads into a batched objective (+ alive mask when
    ground-set padding is active).  All requests must share a lane."""
    req0 = requests[0]
    if req0.objective == "coverage":
        Ws = [jnp.asarray(r.features) for r in requests]
        if n_pad is not None:
            Ws = [
                jnp.zeros((n_pad, W.shape[1]), W.dtype).at[: W.shape[0]].set(W)
                for W in Ws
            ]
        fn = FeatureCoverage(W=jnp.stack(Ws), phi=req0.phi)
    elif req0.objective == "fl":
        if req0.sim is not None:
            sims = [jnp.asarray(r.sim) for r in requests]
            if n_pad is not None:
                sims = [
                    jnp.zeros((n_pad, n_pad), s.dtype)
                    .at[: s.shape[0], : s.shape[1]].set(s)
                    for s in sims
                ]
            sim_b = jnp.stack(sims)
        else:
            Xs = [jnp.asarray(r.features) for r in requests]
            if n_pad is not None:
                Xs = [
                    jnp.zeros((n_pad, X.shape[1]), X.dtype)
                    .at[: X.shape[0]].set(X)
                    for X in Xs
                ]
            sim_b = jax.vmap(
                lambda X: FacilityLocation.from_features(
                    X, kernel=req0.kernel
                ).sim
            )(jnp.stack(Xs))
            if n_pad is not None:
                # Zero the padded rows/columns of the *similarity*: zero sim
                # is inert for any kernel, while e.g. the rbf similarity of
                # a zero feature row is not.
                valid = jnp.stack([
                    jnp.arange(n_pad) < r.features.shape[0] for r in requests
                ])
                sim_b = sim_b * (
                    valid[:, :, None] & valid[:, None, :]
                ).astype(sim_b.dtype)
        fn = FacilityLocation(sim=sim_b)
    else:
        raise ValueError(f"unknown objective {req0.objective!r}")
    if n_pad is None:
        return fn, None
    # Per-row dead-padding mask: one padded lane can mix different real n.
    n_reals = [
        (r.features if r.sim is None else r.sim).shape[0] for r in requests
    ]
    alive = jnp.stack(
        [jnp.arange(n_pad) < n_real for n_real in n_reals]
    )
    return fn, alive


def summarize_batch(
    fn,
    k: int,
    keys: Array,
    *,
    r: int = 8,
    c: float = 8.0,
    use_ss: bool = True,
    alive: Array | None = None,
    backend=None,
) -> tuple[GreedyResult, SSResult | None]:
    """The service's execution core: batched SS → batched compact greedy on
    a stacked objective.  Row b is identical to the sequential single-query
    pipeline under ``keys[b]``.  Shared with the KV-cache pruning path
    (repro.serve.kv_select), which feeds it one lane per decode batch."""
    be = resolve_backend(backend)
    ss = None
    sel_alive = alive
    compact: "bool | int | None" = None
    if use_ss:
        ss = ss_sparsify_batched(fn, keys, r=r, c=c, alive=alive, backend=be)
        sel_alive = ss.vprime
        # Static O(log² n) bound on |V'|: with a concrete mask the engine
        # still host-reads the exact live count, but under jit/vmap (tracer
        # vprime — e.g. a compiled decode loop pruning its KV cache) this
        # keeps the post-SS greedy on the compact path instead of silently
        # degrading to full-width O(n) steps.
        n = jax.tree.map(lambda x: x[0], fn).n
        compact = ss_live_bound(n, r, c)
    res = greedy_batched(fn, k, alive=sel_alive, backend=be, compact=compact)
    return res, ss


# ------------------------------------------------------------ the service ----

class Ticket:
    """Handle returned by :meth:`SummarizeService.submit`; ``result`` is
    populated by the flush that executes the query."""

    __slots__ = ("index", "result", "_submit_t")

    def __init__(self, index: int, submit_t: float):
        self.index = index
        self.result: SummarizeResponse | None = None
        self._submit_t = submit_t

    @property
    def done(self) -> bool:
        return self.result is not None


class SummarizeService:
    """Queue-fed micro-batching engine over :func:`summarize_batch`.

    ``submit`` enqueues a request and returns a :class:`Ticket`; ``flush``
    drains the queue — grouping queries by *lane* (the static compile
    signature: ground-set size, payload shape, k, objective config, use_ss),
    chunking each lane at ``max_batch``, padding each chunk up to its batch
    bucket (padding rows repeat row 0 and are discarded) — and executes one
    batched pipeline per chunk.  ``run`` is submit-all + flush.

    The service is deliberately synchronous: admission policy (when to
    flush) belongs to the caller's event loop; everything below — lane
    formation, bucketing, padding accounting, warm compile caches — lives
    here.
    """

    def __init__(self, config: ServiceConfig = ServiceConfig()):
        self.config = config
        self._queue: list[tuple[Ticket, SummarizeRequest]] = []
        self._buckets = batch_buckets(config.max_batch, config.batch_c)
        self._stats = {
            "queries": 0,
            "batches": 0,
            "padded_slots": 0,
            "slots": 0,
            "queue_delay_s_sum": 0.0,
            "queue_delay_s_max": 0.0,
            "exec_s_sum": 0.0,
            "lanes": set(),
        }

    # -- admission ---------------------------------------------------------
    def submit(self, request: SummarizeRequest) -> Ticket:
        ticket = Ticket(len(self._queue), time.perf_counter())
        self._queue.append((ticket, request))
        return ticket

    def _lane(self, req: SummarizeRequest) -> tuple:
        payload = req.sim if req.sim is not None else req.features
        if payload is None:
            raise ValueError("request needs a features or sim payload")
        kind = "sim" if req.sim is not None else "features"
        shape = tuple(payload.shape)
        n = shape[0]
        n_pad = None
        if self.config.n_buckets is not None:
            fits = [b for b in self.config.n_buckets if b >= n]
            if not fits:
                raise ValueError(
                    f"query n={n} exceeds every configured n bucket "
                    f"{self.config.n_buckets}"
                )
            n_pad = min(fits)
            shape = (n_pad,) + shape[1:] if req.sim is None else (n_pad, n_pad)
        # ``kind`` keeps sim-payload and feature-payload queries in separate
        # lanes: a (n, n) feature matrix must not stack with a (n, n) sim.
        return (
            req.objective, kind, shape, req.k, req.phi, req.kernel,
            req.use_ss, n_pad,
        )

    # -- execution ---------------------------------------------------------
    def flush(self) -> list[SummarizeResponse]:
        """Drain the queue; returns responses in submission order."""
        pending, self._queue = self._queue, []
        lanes: dict[tuple, list[tuple[Ticket, SummarizeRequest]]] = {}
        for ticket, req in pending:
            lanes.setdefault(self._lane(req), []).append((ticket, req))

        for lane, items in lanes.items():
            for lo in range(0, len(items), self.config.max_batch):
                self._run_chunk(lane, items[lo: lo + self.config.max_batch])
        return [t.result for t, _ in pending]

    def run(self, requests: list[SummarizeRequest]) -> list[SummarizeResponse]:
        tickets = [self.submit(r) for r in requests]
        self.flush()
        return [t.result for t in tickets]

    def _run_chunk(
        self, lane: tuple, items: list[tuple[Ticket, SummarizeRequest]]
    ) -> None:
        cfg = self.config
        reqs = [r for _, r in items]
        n_real = len(reqs)
        bucket = min(b for b in self._buckets if b >= n_real)
        # Pad the batch dimension by repeating row 0 (results discarded) so
        # the (lane, bucket) signature is the only thing that compiles.
        padded = reqs + [reqs[0]] * (bucket - n_real)
        _, _, _, k, _, _, use_ss, n_pad = lane

        t_start = time.perf_counter()
        fn, alive = build_batch_objective(padded, n_pad)
        keys = jnp.stack([r.prng_key() for r in padded])
        res, ss = summarize_batch(
            fn, k, keys, r=cfg.r, c=cfg.c, use_ss=use_ss, alive=alive,
            backend=cfg.backend,
        )
        jax.block_until_ready(res.value)
        t_end = time.perf_counter()
        exec_s = t_end - t_start

        vp_sizes = (
            None if ss is None else jnp.sum(ss.vprime, axis=1)
        )
        st = self._stats
        st["batches"] += 1
        st["queries"] += n_real
        st["slots"] += bucket
        st["padded_slots"] += bucket - n_real
        st["exec_s_sum"] += exec_s
        st["lanes"].add((lane, bucket))
        for i, (ticket, _) in enumerate(items):
            delay = t_start - ticket._submit_t
            st["queue_delay_s_sum"] += delay
            st["queue_delay_s_max"] = max(st["queue_delay_s_max"], delay)
            ticket.result = SummarizeResponse(
                selected=res.selected[i],
                gains=res.gains[i],
                value=float(res.value[i]),
                vprime_size=None if ss is None else int(vp_sizes[i]),
                eps_hat=None if ss is None else float(ss.eps_hat[i]),
                rounds=None if ss is None else int(ss.rounds[i]),
                lane=lane,
                batch_size=n_real,
                batch_bucket=bucket,
                queue_delay_s=delay,
                exec_s=exec_s,
            )

    # -- accounting --------------------------------------------------------
    def stats(self) -> dict:
        """Aggregate serving counters: query/batch totals, padding waste
        (fraction of executed slots burned on bucket padding), queue-delay
        mean/max, and the number of distinct compiled signatures."""
        st = self._stats
        q = max(st["queries"], 1)
        return {
            "queries": st["queries"],
            "batches": st["batches"],
            "padding_waste_frac": st["padded_slots"] / max(st["slots"], 1),
            "queue_delay_s_mean": st["queue_delay_s_sum"] / q,
            "queue_delay_s_max": st["queue_delay_s_max"],
            "exec_s_total": st["exec_s_sum"],
            "compiled_signatures": len(st["lanes"]),
        }
