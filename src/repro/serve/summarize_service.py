"""Micro-batched multi-query summarization service: the request-level layer
over SS + greedy, with an SLO-aware asynchronous scheduler.

Every caller so far invoked ``ss_sparsify``/``greedy`` one ground set at a
time.  This module is the serving engine the ROADMAP north star asks for: it
accepts per-query requests (a feature or similarity payload, a budget k, an
objective config, a per-query PRNG key, an optional latency deadline),
admits them into per-lane queues, micro-batches compatible queries into
**bucketed static shapes** — the ``bucket_schedule`` idea applied to the
batch dimension (and optionally the ground-set dimension), so each
(n, B-bucket, k) signature compiles once and stays warm — and executes the
full SS → compact-greedy pipeline for the whole batch as one compiled loop
via the first-class batched entry points ``ss_sparsify_batched`` /
``greedy_batched`` (repro.core).

Scheduling (PR 7): with ``RunConfig(scheduler="async")`` a background
flusher owns execution — the caller never calls ``flush()``.  A lane fires
when it is **full** (``max_batch`` queued), when a queued request's
**deadline slack** runs out (absolute deadline minus the lane's EWMA
execution estimate minus ``slack_s``), or when the oldest request has
waited **max_wait_s** — whichever comes first.  Between firings the flusher
sleeps on a condition variable; an empty-queue tick is a no-op.  Batching
is *continuous*: the flusher pulls at most ``max_batch`` requests from the
head of one lane per firing, so arrivals during an in-flight batch refill
the next bucket instead of waiting for a whole-queue drain.  The default
``scheduler="sync"`` keeps the PR-5 contract surface: admission policy
belongs to the caller, ``flush()`` drains everything queued.

Correctness contract (unchanged): micro-batching — and now scheduling — is
a pure execution strategy.  Each query's ``selected`` / ``gains`` /
``value`` (and SS ``vprime`` / ``eps_hat``) are *identical* to a sequential
single-query ``ss_sparsify(fn, key)`` + ``greedy(fn, k, alive=vprime)`` run
under the same per-query key — regardless of which queries it was batched
with, the batch bucket padding, mixed n / k in the same flush, or which
trigger fired the batch (tests/test_serve_service.py and
tests/test_serve_async.py pin this query-for-query).

Failure isolation: :class:`Ticket` is a real future — ``result(timeout)`` /
``done()`` / ``exception()`` — and captures per-request errors, so a
malformed or already-expired request fails its own ticket at admission
instead of aborting the flush that would have carried it; an execution
error fails only the tickets of the chunk that raised.

Accounting: the service tracks queue delay per query (submit → execution
start), per-batch execution wall time, padding waste (slots burned rounding
a lane chunk up to its batch bucket), firing-trigger counts, and missed
deadlines — the numbers a capacity planner needs to tune ``max_batch`` /
``max_wait_s`` against traffic.

Optional ground-set padding (``RunConfig.n_buckets``): queries whose n is
not in the bucket list are zero-padded up to the next bucket with the
padding rows dead-masked, collapsing many distinct-n compile signatures
into a few.  Padding changes the PRNG frame of SS (an (n_bucket,) Gumbel
draw), so a padded query matches the sequential run *on the padded ground
set*, not on the raw one — exact-n lanes (the default) keep the strict
contract.  Pure-greedy queries (``use_ss=False``) are padding-invariant
either way: dead rows can never win an argmax.
"""

from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import (
    FacilityLocation,
    FeatureCoverage,
    GreedyResult,
    SSResult,
    bucket_schedule,
    greedy_batched,
    resolve_backend,
    ss_live_bound,
    ss_sparsify_batched,
)

Array = jax.Array


class DeadlineExceeded(RuntimeError):
    """The request's latency budget was already spent at admission."""


class ServiceOverloaded(RuntimeError):
    """Backpressure: the service's pending-queue cap was hit at admission."""


# ------------------------------------------------------------- run config ----

@dataclasses.dataclass(frozen=True)
class RunConfig:
    """The one end-to-end execution config (stable surface: ``repro.api``).

    Consolidates what used to be scattered across ``ServiceConfig``,
    ``ss_sparsify`` kwargs, and ``greedy`` kwargs — per-query knobs
    (payload, k, key, objective, deadline) stay on the request.

    Execution: ``backend`` selects the repro.core.backend (None = env
    default); ``compact`` is the compact-selection policy threaded to
    ``greedy_batched`` (None = auto: the static SS live bound).  SS:
    probe multiplier ``r``, accuracy/speed ``c``.  ``eps`` is the
    stochastic-greedy sample-size parameter used by facade helpers that
    select stochastically.

    Batching: ``max_batch`` caps a micro-batch; ``batch_c`` shapes the
    B-bucket schedule; ``n_buckets`` opts into ground-set padding.

    Scheduling: ``scheduler`` is ``"sync"`` (manual ``flush()``, the PR-5
    contract) or ``"async"`` (background deadline-driven flusher);
    ``max_wait_s`` bounds how long an admitted request may sit queued
    before its lane fires anyway; ``slack_s`` is extra safety margin
    subtracted from deadlines when scheduling; ``max_pending`` (None =
    unbounded) is the admission backpressure cap; ``stream_steps`` streams
    greedy selections back to tickets step-by-step as they commit.
    """

    backend: Any = None             # str | Backend | None (repro.core.backend)
    r: int = 8                      # SS probe multiplier
    c: float = 8.0                  # SS accuracy/speed tradeoff
    eps: float = 0.1                # stochastic-greedy sample-size parameter
    compact: "bool | int | None" = None   # compact-selection policy
    max_batch: int = 8              # admission cap per micro-batch
    batch_c: float = 4.0            # B-bucket shrink factor (buckets =
    #                                 bucket_schedule(max_batch, batch_c, 1))
    n_buckets: tuple[int, ...] | None = None  # opt-in ground-set padding
    scheduler: str = "sync"         # "sync" | "async"
    max_wait_s: float = 0.05        # max queue residency before a lane fires
    slack_s: float = 0.0            # safety margin under deadlines
    max_pending: int | None = None  # admission backpressure cap
    stream_steps: bool = False      # stream greedy steps to tickets

    def __post_init__(self):
        if self.scheduler not in ("sync", "async"):
            raise ValueError(
                f"scheduler must be 'sync' or 'async'; got {self.scheduler!r}"
            )


def ServiceConfig(**kwargs) -> RunConfig:  # noqa: N802 - legacy class name
    """Deprecated alias for :class:`RunConfig` (one-release warning).

    The PR-5 spelling ``ServiceConfig(backend=..., max_batch=...)`` maps
    field-for-field onto ``RunConfig``.
    """
    warnings.warn(
        "ServiceConfig is deprecated; use repro.api.RunConfig "
        "(same field names)",
        DeprecationWarning,
        stacklevel=2,
    )
    return RunConfig(**kwargs)


def batch_buckets(max_batch: int, c: float = 4.0) -> tuple[int, ...]:
    """Static batch-dimension buckets — ``bucket_schedule`` applied to B
    (tile=1: the batch axis needs no kernel-grid alignment).  A lane chunk
    of j queries pads up to the smallest bucket >= j, so each (lane,
    B-bucket) signature compiles once and stays warm."""
    return bucket_schedule(max_batch, c, tile=1)


# ----------------------------------------------------------- request API ----

@dataclasses.dataclass(frozen=True)
class SummarizeRequest:
    """One summarization query.

    ``features`` is the (n, F) nonnegative row-feature payload (FeatureCoverage
    for ``objective="coverage"``; the similarity kernel input for
    ``objective="fl"``).  ``sim`` passes a precomputed (n, n) similarity for
    ``objective="fl"`` instead.  ``key`` is the query's PRNG key (an int seed
    is accepted); ``use_ss=False`` skips SS and greedy-selects on the full
    ground set.  ``deadline_s`` is the request's latency budget in seconds
    from submission: the async flusher fires the lane early enough (minus
    the lane's execution estimate and ``RunConfig.slack_s``) to try to make
    it; a budget that is already <= 0 at admission fails the ticket with
    :class:`DeadlineExceeded`, and a missed-but-served deadline is reported
    via ``SummarizeResponse.deadline_missed`` rather than dropped.
    """

    k: int
    key: Any
    features: Array | None = None
    sim: Array | None = None
    objective: str = "coverage"     # coverage | fl
    phi: str = "sqrt"               # FeatureCoverage concave transform
    kernel: str = "cosine"          # FacilityLocation feature kernel
    use_ss: bool = True
    deadline_s: float | None = None  # latency budget from submit (seconds)

    def prng_key(self) -> Array:
        if isinstance(self.key, int):
            return jax.random.PRNGKey(self.key)
        return jnp.asarray(self.key)


@dataclasses.dataclass(frozen=True)
class SummarizeResponse:
    """Per-query result + serving metadata.

    Results are query-for-query identical to the sequential single-query
    pipeline under the same key.  ``queue_delay_s`` is submit → execution
    start; ``exec_s`` the wall time of the micro-batch this query rode in
    (shared by its batch mates); ``batch_size``/``batch_bucket`` how full
    that batch was vs its padded static shape.  ``trigger`` names what fired
    the batch (``manual`` / ``full`` / ``deadline`` / ``max_wait`` /
    ``drain``); ``deadline_missed`` is None when the request carried no
    deadline, else whether the batch finished past it.
    """

    selected: Array                 # (k,) int32 ground indices
    gains: Array                    # (k,) marginal gains
    value: float                    # f(S)
    vprime_size: int | None         # |V'| after SS (None when use_ss=False)
    eps_hat: float | None           # SS certificate (None when use_ss=False)
    rounds: int | None              # SS rounds executed
    lane: tuple                     # static signature this query batched under
    batch_size: int                 # real queries in the micro-batch
    batch_bucket: int               # padded static batch dimension
    queue_delay_s: float
    exec_s: float
    trigger: str = "manual"         # what fired this micro-batch
    deadline_missed: bool | None = None


# ------------------------------------------------------- functional core ----

def build_batch_objective(requests: list[SummarizeRequest], n_pad: int | None):
    """Stack one lane's payloads into a batched objective (+ alive mask when
    ground-set padding is active).  All requests must share a lane."""
    req0 = requests[0]
    if req0.objective == "coverage":
        Ws = [jnp.asarray(r.features) for r in requests]
        if n_pad is not None:
            Ws = [
                jnp.zeros((n_pad, W.shape[1]), W.dtype).at[: W.shape[0]].set(W)
                for W in Ws
            ]
        fn = FeatureCoverage(W=jnp.stack(Ws), phi=req0.phi)
    elif req0.objective == "fl":
        if req0.sim is not None:
            sims = [jnp.asarray(r.sim) for r in requests]
            if n_pad is not None:
                sims = [
                    jnp.zeros((n_pad, n_pad), s.dtype)
                    .at[: s.shape[0], : s.shape[1]].set(s)
                    for s in sims
                ]
            sim_b = jnp.stack(sims)
        else:
            Xs = [jnp.asarray(r.features) for r in requests]
            if n_pad is not None:
                Xs = [
                    jnp.zeros((n_pad, X.shape[1]), X.dtype)
                    .at[: X.shape[0]].set(X)
                    for X in Xs
                ]
            sim_b = jax.vmap(
                lambda X: FacilityLocation.from_features(
                    X, kernel=req0.kernel
                ).sim
            )(jnp.stack(Xs))
            if n_pad is not None:
                # Zero the padded rows/columns of the *similarity*: zero sim
                # is inert for any kernel, while e.g. the rbf similarity of
                # a zero feature row is not.
                valid = jnp.stack([
                    jnp.arange(n_pad) < r.features.shape[0] for r in requests
                ])
                sim_b = sim_b * (
                    valid[:, :, None] & valid[:, None, :]
                ).astype(sim_b.dtype)
        fn = FacilityLocation(sim=sim_b)
    else:
        raise ValueError(f"unknown objective {req0.objective!r}")
    if n_pad is None:
        return fn, None
    # Per-row dead-padding mask: one padded lane can mix different real n.
    n_reals = [
        (r.features if r.sim is None else r.sim).shape[0] for r in requests
    ]
    alive = jnp.stack(
        [jnp.arange(n_pad) < n_real for n_real in n_reals]
    )
    return fn, alive


def summarize_batch(
    fn,
    k: int,
    keys: Array,
    *,
    r: int = 8,
    c: float = 8.0,
    use_ss: bool = True,
    alive: Array | None = None,
    backend=None,
    compact: "bool | int | None" = None,
    on_step=None,
) -> tuple[GreedyResult, SSResult | None]:
    """The service's execution core: batched SS → batched compact greedy on
    a stacked objective.  Row b is identical to the sequential single-query
    pipeline under ``keys[b]``.  Shared with the KV-cache pruning path
    (repro.serve.kv_select), which feeds it one lane per decode batch.
    ``compact`` = None auto-derives the static SS live bound (the tracer-
    safe default); ``on_step`` streams greedy steps (see
    :func:`repro.core.greedy_batched`)."""
    be = resolve_backend(backend)
    ss = None
    sel_alive = alive
    if use_ss:
        ss = ss_sparsify_batched(fn, keys, r=r, c=c, alive=alive, backend=be)
        sel_alive = ss.vprime
        if compact is None:
            # Static O(log² n) bound on |V'|: with a concrete mask the engine
            # still host-reads the exact live count, but under jit/vmap
            # (tracer vprime — e.g. a compiled decode loop pruning its KV
            # cache) this keeps the post-SS greedy on the compact path
            # instead of silently degrading to full-width O(n) steps.
            n = jax.tree.map(lambda x: x[0], fn).n
            compact = ss_live_bound(n, r, c)
    res = greedy_batched(
        fn, k, alive=sel_alive, backend=be, compact=compact, on_step=on_step
    )
    return res, ss


# ------------------------------------------------------------ the ticket ----

class Ticket:
    """Future-style handle returned by :meth:`SummarizeService.submit`.

    ``result(timeout=None)`` blocks until the scheduler executes the query
    and returns its :class:`SummarizeResponse` — or re-raises the error
    captured for *this* request (admission failures like
    :class:`DeadlineExceeded` / a malformed payload, or the execution error
    of the chunk it rode in).  ``done()`` / ``exception()`` mirror
    ``concurrent.futures.Future``.  With ``RunConfig.stream_steps`` the
    committed greedy prefix is readable mid-flight via :meth:`partial`.
    """

    __slots__ = (
        "index", "_submit_t", "_deadline_t", "_event", "_response", "_error",
        "_steps",
    )

    def __init__(self, index: int, submit_t: float,
                 deadline_t: float | None = None):
        self.index = index
        self._submit_t = submit_t
        self._deadline_t = deadline_t
        self._event = threading.Event()
        self._response: SummarizeResponse | None = None
        self._error: BaseException | None = None
        self._steps: list[tuple[int, float]] = []

    def done(self) -> bool:
        """True once the ticket holds a response or a captured error."""
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> SummarizeResponse:
        """Block until resolved; returns the response or re-raises the
        captured per-request error.  Raises TimeoutError if ``timeout``
        elapses first (the query stays in flight)."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"ticket {self.index} unresolved after {timeout}s"
            )
        if self._error is not None:
            raise self._error
        return self._response

    def exception(self, timeout: float | None = None) -> BaseException | None:
        """The captured error (None on success); blocks like ``result``."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"ticket {self.index} unresolved after {timeout}s"
            )
        return self._error

    def partial(self) -> list[tuple[int, float]]:
        """Committed (ground index, gain) greedy steps streamed so far —
        populated mid-execution when ``RunConfig.stream_steps`` is on, and
        always consistent with the final ``selected``/``gains`` prefix."""
        return list(self._steps)

    def _fulfill(self, response: SummarizeResponse) -> None:
        self._response = response
        self._event.set()

    def _fail(self, error: BaseException) -> None:
        self._error = error
        self._event.set()


@dataclasses.dataclass
class _QueueItem:
    ticket: Ticket
    request: SummarizeRequest
    lane: tuple
    submit_t: float
    deadline_t: float | None


# ------------------------------------------------------------ the service ----

class SummarizeService:
    """Queue-fed micro-batching engine over :func:`summarize_batch`.

    ``submit`` admits a request and returns a :class:`Ticket` future.  With
    the default ``RunConfig(scheduler="sync")`` execution happens on
    ``flush()`` — the queue is drained, queries grouped by *lane* (the
    static compile signature: ground-set size, payload shape, k, objective
    config, use_ss), chunked at ``max_batch``, each chunk padded up to its
    batch bucket (padding rows repeat row 0 and are discarded) and executed
    as one batched pipeline.

    With ``scheduler="async"`` a daemon flusher thread owns execution: lanes
    fire on (full ∨ deadline-slack ∨ max-wait), continuous batching pulls at
    most ``max_batch`` from a lane's head per firing so arrivals refill the
    next bucket while a batch is in flight, and ``drain()`` force-fires the
    backlog and blocks until every outstanding ticket resolves.  ``run`` is
    submit-all + drain on either scheduler.  The service is a context
    manager: leaving the ``with`` block drains and stops the flusher.
    """

    def __init__(self, config: RunConfig | None = None, **legacy_kwargs):
        if config is None:
            config = RunConfig()
        if not isinstance(config, RunConfig):
            raise TypeError(
                f"SummarizeService takes a RunConfig; got {type(config)!r}"
            )
        if legacy_kwargs:
            warnings.warn(
                "passing ServiceConfig-style kwargs to SummarizeService is "
                "deprecated; use SummarizeService(RunConfig(...))",
                DeprecationWarning,
                stacklevel=2,
            )
            config = dataclasses.replace(config, **legacy_kwargs)
        self.config = config
        self._buckets = batch_buckets(config.max_batch, config.batch_c)
        self._cond = threading.Condition()
        self._lanes: dict[tuple, list[_QueueItem]] = {}
        self._pending = 0               # queued, not yet executing
        self._outstanding = 0           # queued or executing
        self._exec_est: dict[tuple, float] = {}
        self._drain_requested = False
        self._stop = False
        self._thread: threading.Thread | None = None
        self._n_submitted = 0
        self._stats = {
            "queries": 0,
            "batches": 0,
            "padded_slots": 0,
            "slots": 0,
            "queue_delay_s_sum": 0.0,
            "queue_delay_s_max": 0.0,
            "exec_s_sum": 0.0,
            "lanes": set(),
            "triggers": {},
            "deadlines_missed": 0,
            "failed": 0,
        }
        if config.scheduler == "async":
            self.start()

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> None:
        """Start the background flusher (idempotent; async scheduler only)."""
        if self.config.scheduler != "async":
            raise RuntimeError("start() requires RunConfig(scheduler='async')")
        if self._thread is not None:
            return
        self._stop = False
        self._thread = threading.Thread(
            target=self._flusher, name="summarize-flusher", daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        """Drain outstanding work, then stop the flusher thread."""
        if self._thread is None:
            return
        self.drain()
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        self._thread.join()
        self._thread = None

    def __enter__(self) -> "SummarizeService":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- admission ---------------------------------------------------------
    def submit(self, request: SummarizeRequest) -> Ticket:
        """Admit one request.  Admission failures — malformed payload, an
        already-spent deadline, queue backpressure — fail the returned
        ticket immediately instead of raising, so one bad request never
        blocks its batch mates."""
        now = time.perf_counter()
        deadline_t = (
            None if request.deadline_s is None else now + request.deadline_s
        )
        ticket = Ticket(self._n_submitted, now, deadline_t)
        self._n_submitted += 1
        try:
            lane = self._lane(request)
            if request.deadline_s is not None and request.deadline_s <= 0:
                raise DeadlineExceeded(
                    f"deadline_s={request.deadline_s} already spent at "
                    "admission"
                )
            with self._cond:
                cap = self.config.max_pending
                if cap is not None and self._pending >= cap:
                    raise ServiceOverloaded(
                        f"{self._pending} requests pending >= "
                        f"max_pending={cap}"
                    )
                self._lanes.setdefault(lane, []).append(
                    _QueueItem(ticket, request, lane, now, deadline_t)
                )
                self._pending += 1
                self._outstanding += 1
                self._cond.notify_all()
        except Exception as e:  # noqa: BLE001 - captured on the ticket
            with self._cond:
                self._stats["failed"] += 1
            ticket._fail(e)
        return ticket

    def _lane(self, req: SummarizeRequest) -> tuple:
        payload = req.sim if req.sim is not None else req.features
        if payload is None:
            raise ValueError("request needs a features or sim payload")
        kind = "sim" if req.sim is not None else "features"
        shape = tuple(payload.shape)
        n = shape[0]
        n_pad = None
        if self.config.n_buckets is not None:
            fits = [b for b in self.config.n_buckets if b >= n]
            if not fits:
                raise ValueError(
                    f"query n={n} exceeds every configured n bucket "
                    f"{self.config.n_buckets}"
                )
            n_pad = min(fits)
            shape = (n_pad,) + shape[1:] if req.sim is None else (n_pad, n_pad)
        # ``kind`` keeps sim-payload and feature-payload queries in separate
        # lanes: a (n, n) feature matrix must not stack with a (n, n) sim.
        return (
            req.objective, kind, shape, req.k, req.phi, req.kernel,
            req.use_ss, n_pad,
        )

    # -- scheduling --------------------------------------------------------
    def _next_fire(self, now: float):
        """The flusher's policy: the most urgent (lane, fire time, trigger)
        among non-empty lanes, or (None, None, None) on an empty queue.

        A lane fires *now* when full (``max_batch`` queued) or when a drain
        was requested; otherwise at the earlier of (oldest submit +
        ``max_wait_s``) and, per queued deadline, (deadline − lane EWMA
        execution estimate − ``slack_s``).  Must be called with the lock
        held."""
        best = (None, None, None)
        for lane, items in self._lanes.items():
            if not items:
                continue
            if len(items) >= self.config.max_batch:
                return lane, now, "full"
            if self._drain_requested:
                return lane, now, "drain"
            fire_t = items[0].submit_t + self.config.max_wait_s
            trigger = "max_wait"
            est = self._exec_est.get(lane, 0.0)
            for it in items:
                if it.deadline_t is None:
                    continue
                t = it.deadline_t - est - self.config.slack_s
                if t < fire_t:
                    fire_t, trigger = t, "deadline"
            if best[0] is None or fire_t < best[1]:
                best = (lane, fire_t, trigger)
        return best

    def _flusher(self) -> None:
        """Background consumer loop (async scheduler): sleep until the next
        firing time, pull ≤ max_batch from the fired lane's head, execute,
        repeat — submissions during execution land in the lane queues and
        refill the next bucket (continuous batching)."""
        while True:
            with self._cond:
                if self._stop:
                    return
                now = time.perf_counter()
                lane, fire_t, trigger = self._next_fire(now)
                if lane is None:
                    if self._drain_requested:
                        # Queue is empty: the drain is satisfied once
                        # in-flight work lands (tracked by _outstanding).
                        self._drain_requested = False
                        self._cond.notify_all()
                    self._cond.wait()
                    continue
                if fire_t > now:
                    self._cond.wait(timeout=fire_t - now)
                    continue
                items = self._lanes[lane][: self.config.max_batch]
                del self._lanes[lane][: self.config.max_batch]
                self._pending -= len(items)
            self._run_chunk(lane, items, trigger)

    def drain(self, timeout: float | None = None) -> None:
        """Force-fire everything queued and block until every admitted
        ticket has resolved.  On the sync scheduler this is ``flush()``."""
        if self._thread is None:
            self.flush(trigger="drain")
            return
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._cond:
            self._drain_requested = True
            self._cond.notify_all()
            while self._outstanding > 0:
                left = (
                    None if deadline is None
                    else deadline - time.perf_counter()
                )
                if left is not None and left <= 0:
                    raise TimeoutError(
                        f"{self._outstanding} tickets unresolved after drain "
                        f"timeout {timeout}s"
                    )
                self._cond.wait(timeout=left)

    # -- execution ---------------------------------------------------------
    def flush(self, trigger: str = "manual") -> list[SummarizeResponse | None]:
        """Synchronously drain everything queued now (sync scheduler's
        execution entry; also usable while the async flusher is stopped).
        Returns responses in submission order — entries are None for
        tickets whose chunk failed (the error lives on the ticket)."""
        with self._cond:
            pending: list[_QueueItem] = []
            for items in self._lanes.values():
                pending.extend(items)
            self._lanes = {}
            self._pending -= len(pending)
        pending.sort(key=lambda it: it.ticket.index)
        lanes: dict[tuple, list[_QueueItem]] = {}
        for it in pending:
            lanes.setdefault(it.lane, []).append(it)
        for lane, items in lanes.items():
            for lo in range(0, len(items), self.config.max_batch):
                self._run_chunk(
                    lane, items[lo: lo + self.config.max_batch], trigger
                )
        return [it.ticket._response for it in pending]

    def run(
        self, requests: list[SummarizeRequest]
    ) -> list[SummarizeResponse]:
        """Convenience wrapper: submit everything, drain, and return the
        responses in request order — re-raising the first captured
        per-request error, if any (read the tickets individually via
        ``submit`` to handle partial failure)."""
        tickets = [self.submit(r) for r in requests]
        self.drain()
        return [t.result(timeout=0) for t in tickets]

    def _run_chunk(
        self, lane: tuple, items: list[_QueueItem], trigger: str
    ) -> None:
        try:
            self._exec_chunk(lane, items, trigger)
        except Exception as e:  # noqa: BLE001 - captured on the tickets
            with self._cond:
                self._stats["failed"] += len(items)
                self._outstanding -= len(items)
                self._cond.notify_all()
            for it in items:
                it.ticket._fail(e)

    def _exec_chunk(
        self, lane: tuple, items: list[_QueueItem], trigger: str
    ) -> None:
        cfg = self.config
        reqs = [it.request for it in items]
        n_real = len(reqs)
        bucket = min(b for b in self._buckets if b >= n_real)
        # Pad the batch dimension by repeating row 0 (results discarded) so
        # the (lane, bucket) signature is the only thing that compiles.
        padded = reqs + [reqs[0]] * (bucket - n_real)
        _, _, _, k, _, _, use_ss, n_pad = lane

        on_step = None
        if cfg.stream_steps:
            def on_step(step, v, g, ok):
                for i, it in enumerate(items):
                    if bool(ok[i]):
                        it.ticket._steps.append((int(v[i]), float(g[i])))

        t_start = time.perf_counter()
        fn, alive = build_batch_objective(padded, n_pad)
        keys = jnp.stack([r.prng_key() for r in padded])
        res, ss = summarize_batch(
            fn, k, keys, r=cfg.r, c=cfg.c, use_ss=use_ss, alive=alive,
            backend=cfg.backend, compact=cfg.compact, on_step=on_step,
        )
        jax.block_until_ready(res.value)
        t_end = time.perf_counter()
        exec_s = t_end - t_start

        vp_sizes = (
            None if ss is None else jnp.sum(ss.vprime, axis=1)
        )
        responses = []
        missed = 0
        for i, it in enumerate(items):
            deadline_missed = (
                None if it.deadline_t is None else t_end > it.deadline_t
            )
            missed += bool(deadline_missed)
            responses.append(SummarizeResponse(
                selected=res.selected[i],
                gains=res.gains[i],
                value=float(res.value[i]),
                vprime_size=None if ss is None else int(vp_sizes[i]),
                eps_hat=None if ss is None else float(ss.eps_hat[i]),
                rounds=None if ss is None else int(ss.rounds[i]),
                lane=lane,
                batch_size=n_real,
                batch_bucket=bucket,
                queue_delay_s=t_start - it.submit_t,
                exec_s=exec_s,
                trigger=trigger,
                deadline_missed=deadline_missed,
            ))
        with self._cond:
            st = self._stats
            st["batches"] += 1
            st["queries"] += n_real
            st["slots"] += bucket
            st["padded_slots"] += bucket - n_real
            st["exec_s_sum"] += exec_s
            st["lanes"].add((lane, bucket))
            st["triggers"][trigger] = st["triggers"].get(trigger, 0) + 1
            st["deadlines_missed"] += missed
            for resp in responses:
                st["queue_delay_s_sum"] += resp.queue_delay_s
                st["queue_delay_s_max"] = max(
                    st["queue_delay_s_max"], resp.queue_delay_s
                )
            # EWMA execution estimate drives the deadline-slack trigger; the
            # first sample seeds it (before that the estimate is 0 — a
            # deadline shorter than the first compile is simply served late
            # and flagged, never dropped).
            prev = self._exec_est.get(lane)
            self._exec_est[lane] = (
                exec_s if prev is None else 0.5 * prev + 0.5 * exec_s
            )
            self._outstanding -= len(items)
            self._cond.notify_all()
        for it, resp in zip(items, responses):
            it.ticket._fulfill(resp)

    # -- accounting --------------------------------------------------------
    def stats(self) -> dict:
        """Aggregate serving counters: query/batch totals, padding waste
        (fraction of executed slots burned on bucket padding), queue-delay
        mean/max, distinct compiled signatures, firing-trigger counts,
        missed deadlines, and failed (admission- or execution-errored)
        tickets."""
        with self._cond:
            st = dict(self._stats)
            st["triggers"] = dict(self._stats["triggers"])
        q = max(st["queries"], 1)
        return {
            "queries": st["queries"],
            "batches": st["batches"],
            "padding_waste_frac": st["padded_slots"] / max(st["slots"], 1),
            "queue_delay_s_mean": st["queue_delay_s_sum"] / q,
            "queue_delay_s_max": st["queue_delay_s_max"],
            "exec_s_total": st["exec_s_sum"],
            "compiled_signatures": len(st["lanes"]),
            "triggers": st["triggers"],
            "deadlines_missed": st["deadlines_missed"],
            "failed": st["failed"],
        }
