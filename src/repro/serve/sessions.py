"""Durable multi-session streaming: crash-safe sieve×SS ingestion tier.

The millions-of-users north star needs a *live summary per user* over an
unbounded element stream.  This module is that tier:

- Each session owns a full Badanidiyuru multi-threshold sieve
  (:class:`repro.core.sieve.StreamSieveState` — the promoted threshold-set
  algorithm, constant memory per update) deciding *online* which elements
  matter at all.
- Accepted elements' raw feature rows land in a bounded retained buffer;
  when the buffer accumulates ``resparsify_every`` inserts (or fills), SS
  (:func:`repro.core.sparsify.ss_sparsify_batched`) prunes it back down —
  the paper's pruning applied as periodic compaction of a stream's memory.
- :meth:`SessionEngine.summary` runs greedy over the (pruned) buffer for
  the session's current k-element summary.

Appends from many sessions execute as *waves* through the same bucketed
micro-batch machinery as the summarize service: one pending element per
session per wave, sessions stacked with ``jax.vmap`` and padded to a
``batch_buckets`` size so every wave shares a compile signature.  The
repo-wide batching contract (batched execution is row-for-row bit-identical
to sequential execution) is what lets a crash recovery replay a single
session at B=1 and still reproduce exactly what a B=8 live wave computed.

Durability contract (docs/streaming.md):

- **WAL first.**  Every ``append`` writes an APPEND record (seq, raw f32
  row, crc32) to the session's write-ahead log via
  :class:`repro.serve.wal.WalWriter` *before* acknowledging; session
  creation writes an OPEN record carrying the PRNG key and the engine
  config signature.
- **Snapshots.**  After ``snapshot_every`` applied appends (policy), or on
  demand / at close, the full :class:`SessionState` — threshold state,
  retained buffer, PRNG key, element counter — checkpoints to an atomic
  ``snap-<applied_seq>.npz`` (tmp + ``os.replace``).
- **Recovery = snapshot + WAL tail.**  Rehydration loads the newest
  loadable snapshot (a corrupt one falls back to the previous, loudly, via
  an auditable event) and replays WAL records with ``seq > applied_seq``
  through the *same* wave kernels.  A recovered session is **bit-identical**
  — thresholds, retained set, PRNG key state, element counter, summary —
  to one that never crashed, on either backend.
- **Fail loudly.**  A checksum or framing violation mid-WAL raises
  :class:`repro.serve.wal.WALCorrupt`; acknowledged records are never
  silently dropped.  Only the torn tail a crash leaves mid-write (by
  definition unacknowledged) may be skipped, and only by explicit opt-in
  (``SessionConfig.tolerate_torn_tail``); recovery then *truncates* the
  partial bytes off the file (auditable ``wal_truncate`` event) so later
  appends land on a clean record boundary — otherwise the next append
  would follow garbage and misframe every subsequent scan.

Memory pressure reuses the PR-8 degradation-record convention: when more
than ``max_live_sessions`` sessions are hydrated, the least-recently-used
idle session is evicted — snapshot, then release device state — and lazily
rehydrated on its next append/summary.  Every rung emits an auditable
event (``engine.events``): ``{"step": "evict", ...}`` down,
``{"step": "rehydrate", ...}`` back up.

Chaos hook: the PR-8 :class:`repro.serve.faults.FaultPlan` threads in via
``faults=``.  Beyond the existing kinds (``exec_error`` aborts the wave
with pending intact — nothing is lost, the next flush retries;
``latency``/``hang`` stall it), two new kinds exercise the durability
story: ``crash`` kills the engine (all in-memory state gone, every further
call raises :class:`ServiceRestarted`; recovery = construct a new engine
on the same root) and ``restart`` simulates kill + immediate reopen (the
engine drops its in-memory state and lazily rehydrates from disk — no
acknowledged element is lost).  Because those two kinds presume durable
storage to recover from, a plan that schedules them is rejected at
construction on a volatile engine (``root=None``) — there, acknowledged
appends would be silently lost.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import re
import time
from collections import deque
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    STREAM_PHIS,
    FeatureCoverage,
    greedy,
    resolve_backend,
    ss_sparsify_batched,
    stream_sieve_init,
    stream_sieve_update,
)
from repro import obs
from repro.serve import wal as _wal
from repro.serve.faults import FaultInjected, FaultPlan
from repro.serve.summarize_service import ServiceRestarted, batch_buckets

Array = jax.Array

#: Snapshot / WAL-OPEN payload schema version.
SCHEMA_VERSION = 1

_SID_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]{0,63}$")

#: SessionConfig fields that determine the state *trajectory* — two engines
#: agreeing on these replay a WAL to bit-identical states.  (``backend`` is
#: deliberately excluded: it is an execution strategy, pinned identical
#: across oracle/pallas by the kernel parity tests.)
_SIG_FIELDS = (
    "k", "eps", "n_features", "phi", "buffer_cap",
    "resparsify_every", "ss_r", "ss_c",
)


# ------------------------------------------------------------- config -------

@dataclasses.dataclass(frozen=True)
class SessionConfig:
    """Engine-wide configuration (one config governs every session).

    ``k``/``eps`` parameterize the per-session sieve (geometric threshold
    grid); ``buffer_cap``/``resparsify_every``/``ss_r``/``ss_c`` govern the
    retained buffer and its periodic SS compaction; ``max_batch``/
    ``batch_c`` shape the wave buckets (same convention as ``RunConfig``);
    ``snapshot_every``/``wal_fsync``/``tolerate_torn_tail`` set the
    durability policy and ``max_live_sessions`` arms the eviction ladder
    (both need a durable ``root``)."""

    k: int = 8
    eps: float = 0.2
    n_features: int = 64
    phi: str = "sqrt"
    buffer_cap: int = 128
    resparsify_every: int = 32
    ss_r: int = 4
    ss_c: float = 8.0
    backend: Any = None
    max_batch: int = 8
    batch_c: float = 4.0
    flush_every: int | None = None      # pending appends per auto-flush
    snapshot_every: int | None = 64     # applied appends per snapshot
    wal_fsync: bool = False
    tolerate_torn_tail: bool = False
    max_live_sessions: int | None = None

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"k must be >= 1; got {self.k}")
        if self.eps <= 0:
            raise ValueError(f"eps must be positive; got {self.eps}")
        if self.n_features < 1:
            raise ValueError(f"n_features must be >= 1; got {self.n_features}")
        if self.phi not in STREAM_PHIS:
            raise ValueError(
                f"session phi must be one of {STREAM_PHIS}; got {self.phi!r}"
            )
        if self.buffer_cap < self.k:
            raise ValueError(
                f"buffer_cap must be >= k; got {self.buffer_cap} < {self.k}"
            )
        for name in ("resparsify_every", "ss_r", "max_batch"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name} must be >= 1")
        for name in ("flush_every", "snapshot_every", "max_live_sessions"):
            v = getattr(self, name)
            if v is not None and v < 1:
                raise ValueError(f"{name} must be None or >= 1")

    def signature(self) -> str:
        """The trajectory-determining config, canonically serialized —
        stamped into every OPEN record and snapshot, checked at recovery
        (replaying under a different config would *silently* produce a
        different, equally-plausible state)."""
        return json.dumps(
            {f: getattr(self, f) for f in _SIG_FIELDS}, sort_keys=True
        )


# ------------------------------------------------------------- state --------

class SessionState(NamedTuple):
    """Everything one session is, as a pytree of arrays — exactly what a
    snapshot persists and what recovery must reproduce bit-for-bit."""

    sieve: Any      # StreamSieveState (thresholds, coverage, counters)
    buf: Array      # (cap, F) retained raw feature rows
    buf_ids: Array  # (cap,) int32 element ids (stream positions); -1 = empty
    buf_len: Array  # () int32 occupied slots
    inserts: Array  # () int32 buffer inserts since the last SS compaction
    n_ss: Array     # () int32 SS compactions so far (the PRNG fold counter)
    drops: Array    # () int32 sieve-accepted elements lost to a full buffer
    key: Array      # (2,) uint32 base PRNG key (fold_in(key, n_ss) per SS)


def _fresh_state(cfg: SessionConfig, key: Array) -> SessionState:
    return SessionState(
        sieve=stream_sieve_init(cfg.k, cfg.n_features, cfg.eps),
        buf=jnp.zeros((cfg.buffer_cap, cfg.n_features), jnp.float32),
        buf_ids=jnp.full((cfg.buffer_cap,), -1, jnp.int32),
        buf_len=jnp.int32(0),
        inserts=jnp.int32(0),
        n_ss=jnp.int32(0),
        drops=jnp.int32(0),
        key=jnp.asarray(key, jnp.uint32),
    )


_STATE_KEYS = (
    "sieve_jidx", "sieve_lg", "sieve_cov", "sieve_vals", "sieve_counts",
    "sieve_sel", "sieve_m", "sieve_t",
    "buf", "buf_ids", "buf_len", "inserts", "n_ss", "drops", "key",
)


def _state_arrays(state: SessionState) -> dict[str, np.ndarray]:
    sv = state.sieve
    vals = (
        sv.jidx, sv.lg, sv.cov, sv.vals, sv.counts, sv.sel, sv.m, sv.t,
        state.buf, state.buf_ids, state.buf_len, state.inserts,
        state.n_ss, state.drops, state.key,
    )
    return {k: np.asarray(v) for k, v in zip(_STATE_KEYS, vals)}


def _arrays_state(z) -> SessionState:
    a = {k: jnp.asarray(z[k]) for k in _STATE_KEYS}
    from repro.core.sieve import StreamSieveState
    sieve = StreamSieveState(
        jidx=a["sieve_jidx"], lg=a["sieve_lg"], cov=a["sieve_cov"],
        vals=a["sieve_vals"], counts=a["sieve_counts"], sel=a["sieve_sel"],
        m=a["sieve_m"], t=a["sieve_t"],
    )
    return SessionState(
        sieve=sieve, buf=a["buf"], buf_ids=a["buf_ids"],
        buf_len=a["buf_len"], inserts=a["inserts"], n_ss=a["n_ss"],
        drops=a["drops"], key=a["key"],
    )


# ------------------------------------------------------------- kernels ------

@partial(jax.jit, static_argnames=("phi",))
def _wave_kernel(states, rows, valid, phi):
    """One wave: each stacked session consumes one element (vmapped).

    ``valid`` masks bucket-padding slots — a padded slot's sieve/buffer
    state passes through untouched, so padding never perturbs the
    trajectory (the replay-exactness linchpin: live B>1 waves and B=1
    recovery replay compute identical per-session states)."""
    def one(st, row, ok):
        cap = st.buf.shape[0]
        eid = st.sieve.t                       # this element's stream id
        new_sieve, accepted = stream_sieve_update(st.sieve, row, phi)
        take = accepted & ok
        has_room = st.buf_len < cap
        ins = take & has_room
        pos = jnp.minimum(st.buf_len, cap - 1)
        buf = st.buf.at[pos].set(jnp.where(ins, row, st.buf[pos]))
        ids = st.buf_ids.at[pos].set(jnp.where(ins, eid, st.buf_ids[pos]))
        sieve = jax.tree_util.tree_map(
            lambda a, b: jnp.where(ok, a, b), new_sieve, st.sieve
        )
        return SessionState(
            sieve=sieve, buf=buf, buf_ids=ids,
            buf_len=st.buf_len + ins.astype(jnp.int32),
            inserts=st.inserts + ins.astype(jnp.int32),
            n_ss=st.n_ss,
            drops=st.drops + (take & ~has_room).astype(jnp.int32),
            key=st.key,
        ), take, ins

    return jax.vmap(one)(states, rows, valid)


@jax.jit
def _compact_kernel(states, keep):
    """Compact each stacked buffer down to its SS-surviving rows (vmapped);
    resets the insert counter and bumps the PRNG fold counter."""
    def one(st, kp):
        cap = st.buf.shape[0]
        idx = jnp.where(kp, size=cap, fill_value=cap)[0]
        cnt = jnp.sum(kp).astype(jnp.int32)
        occ = jnp.arange(cap) < cnt
        buf = jnp.take(st.buf, idx, axis=0, mode="fill", fill_value=0.0)
        buf = jnp.where(occ[:, None], buf, 0.0)
        ids = jnp.take(st.buf_ids, idx, mode="fill", fill_value=-1)
        ids = jnp.where(occ, ids, -1)
        return st._replace(
            buf=buf, buf_ids=ids, buf_len=cnt,
            inserts=jnp.int32(0), n_ss=st.n_ss + 1,
        )

    return jax.vmap(one)(states, keep)


_fold_keys = jax.jit(jax.vmap(jax.random.fold_in))


# ------------------------------------------------------------- summary ------

@dataclasses.dataclass(frozen=True)
class SessionSummary:
    """One session's current summary: greedy over the SS-pruned buffer."""

    sid: str
    selected: np.ndarray    # (<=k,) int32 element ids (stream positions)
    gains: np.ndarray       # (<=k,) float32 greedy marginal gains
    value: float            # f(summary) over the retained buffer
    sieve_value: float      # best online sieve value (the (1/2-eps) bound)
    retained: int           # buffer occupancy after pruning
    seen: int               # elements consumed since open
    drops: int              # accepted elements lost to a full buffer
    resparsifies: int       # SS compactions so far


# ------------------------------------------------------------- engine -------

class SessionEngine:
    """Durable multi-session streaming engine (sieve × SS × WAL).

    ``root=None`` runs volatile (no WAL, no snapshots — state dies with the
    process); pass a directory to get the full durability contract.  One
    subdirectory per session holds ``wal.log`` plus ``snap-*.npz``
    checkpoints.  Construct a new engine on the same root to recover after
    a crash — sessions rehydrate lazily on first touch.

    The engine is a context manager; exit flushes, snapshots every live
    session, and closes the WAL writers."""

    def __init__(
        self,
        config: SessionConfig | None = None,
        root: str | None = None,
        *,
        faults: FaultPlan | None = None,
    ):
        self.config = config or SessionConfig()
        if not isinstance(self.config, SessionConfig):
            raise TypeError(
                f"SessionEngine takes a SessionConfig; got {type(config)!r}"
            )
        if root is None and self.config.max_live_sessions is not None:
            raise ValueError(
                "max_live_sessions (eviction ladder) requires a durable "
                "root: eviction releases state that must be rehydratable"
            )
        if root is None and faults is not None:
            durable_kinds = sorted(
                {f.kind for f in faults.schedule.values()}
                & {"crash", "restart"}
            )
            if durable_kinds:
                raise ValueError(
                    f"FaultPlan schedules {durable_kinds} faults but the "
                    "engine is volatile (root=None): there is no WAL to "
                    "recover from, so acknowledged appends would be "
                    "silently lost — pass a durable root to inject "
                    "crash/restart"
                )
        self.root = root
        self._sig = self.config.signature()
        self._faults = faults
        self._buckets = batch_buckets(
            self.config.max_batch, self.config.batch_c
        )
        self._live: dict[str, SessionState] = {}
        self._pending: dict[str, deque] = {}    # sid -> deque[(seq, row)]
        self._writers: dict[str, _wal.WalWriter] = {}
        self._next_seq: dict[str, int] = {}
        self._applied_seq: dict[str, int] = {}
        self._since_snap: dict[str, int] = {}
        self._order: dict[str, int] = {}        # LRU clock per session
        self._clock = 0
        self._n_opened = 0
        self._dead: str | None = None
        self._closed = False
        # Bounded audit log (a long-lived multi-session stream previously
        # grew this without limit); every entry is mirrored onto the
        # unified event bus with its session id.
        self.events: obs.RingLog = obs.RingLog()
        self._stats = {
            "appends": 0, "waves": 0, "wave_slots": 0, "padded_slots": 0,
            "resparsifies": 0, "snapshots": 0, "snapshot_fallbacks": 0,
            "rehydrations": 0, "evictions": 0, "restarts": 0, "crashes": 0,
            "wal_truncations": 0,
        }
        self._known: set[str] = set()
        if root is not None:
            os.makedirs(root, exist_ok=True)
            for d in sorted(os.listdir(root)):
                if os.path.isfile(os.path.join(root, d, "wal.log")):
                    self._known.add(d)

    # -- lifecycle ---------------------------------------------------------
    def __enter__(self) -> "SessionEngine":
        return self

    def __exit__(self, *exc) -> None:
        if self._dead is None and not self._closed:
            self.close()

    def close(self) -> None:
        """Flush, snapshot every hydrated session, release WAL writers."""
        self._check_alive()
        self._apply_waves(None, faults=False)
        if self.root is not None:
            for sid in sorted(self._live):
                self._snapshot(sid)
        for w in self._writers.values():
            w.close()
        self._writers.clear()
        self._closed = True

    def _check_alive(self) -> None:
        if self._dead is not None:
            raise ServiceRestarted(self._dead)
        if self._closed:
            raise RuntimeError("the session engine is closed")

    def _touch(self, sid: str) -> None:
        self._clock += 1
        self._order[sid] = self._clock

    def _event(self, step: str, *, sid: str | None = None, **data) -> None:
        """Audit one lifecycle event: append to the bounded ``events`` log
        (same dict shape readers always saw) and mirror it onto the unified
        bus keyed by session id."""
        ev = {"step": step}
        if sid is not None:
            ev["sid"] = sid
        ev.update(data)
        self.events.append(ev)
        obs.get_bus().emit(step, subsystem="sessions", session_id=sid, **data)
        obs.get_registry().counter(
            "repro_sessions_events_total", "session audit events by step",
            labels=("step",),
        ).inc(step=step)

    # -- session lifecycle -------------------------------------------------
    def open_session(self, sid: str | None = None, *, key: int = 0) -> str:
        """Create a session; returns its id.  Durable engines write the
        OPEN record (schema, PRNG key, config signature) before returning —
        the session exists once this acks, even across a crash."""
        self._check_alive()
        if sid is None:
            while True:
                sid = f"s{self._n_opened:06d}"
                self._n_opened += 1
                if sid not in self._known and sid not in self._live:
                    break
        if not _SID_RE.match(sid):
            raise ValueError(
                f"session id must match {_SID_RE.pattern}; got {sid!r}"
            )
        if sid in self._known or sid in self._live:
            raise ValueError(f"session {sid!r} already exists")
        state = _fresh_state(self.config, jax.random.PRNGKey(key))
        if self.root is not None:
            os.makedirs(os.path.join(self.root, sid), exist_ok=True)
            meta = {
                "schema": SCHEMA_VERSION,
                "sig": self._sig,
                "key": np.asarray(state.key).tolist(),
            }
            self._writer(sid).append(
                _wal.OPEN, 0, json.dumps(meta).encode()
            )
        self._known.add(sid)
        self._live[sid] = state
        self._pending[sid] = deque()
        self._next_seq[sid] = 1
        self._applied_seq[sid] = 0
        self._since_snap[sid] = 0
        self._touch(sid)
        self._enforce_memory()
        return sid

    def sessions(self) -> list[str]:
        """Every known session id (hydrated or on disk)."""
        return sorted(self._known)

    # -- ingestion ---------------------------------------------------------
    def append(self, sid: str, row) -> int:
        """Ingest one element into ``sid``; returns its WAL sequence number.

        Durable engines acknowledge only after the APPEND record is in the
        OS page cache (``wal_fsync=True`` for the device) — from that point
        the element survives any crash.  Application to the sieve is
        deferred to the next wave (``flush``); appends auto-flush once
        ``flush_every`` (default ``max_batch``) elements are pending."""
        self._check_alive()
        if sid not in self._known:
            raise KeyError(f"unknown session {sid!r}")
        row = np.asarray(row, np.float32)
        if row.shape != (self.config.n_features,):
            raise ValueError(
                f"row must have shape ({self.config.n_features},); "
                f"got {row.shape}"
            )
        if not np.all(np.isfinite(row)) or np.any(row < 0):
            raise ValueError(
                "rows must be finite and nonnegative (coverage objectives); "
                "rejected at admission"
            )
        self._hydrate(sid)
        seq = self._next_seq[sid]
        if self.root is not None:
            self._writer(sid).append(_wal.APPEND, seq, row.tobytes())
        self._pending[sid].append((seq, row))
        self._next_seq[sid] = seq + 1
        self._stats["appends"] += 1
        self._touch(sid)
        threshold = self.config.flush_every or self.config.max_batch
        if sum(len(q) for q in self._pending.values()) >= threshold:
            self.flush()
        return seq

    def flush(self) -> None:
        """Apply every pending element (waves), run due SS compactions,
        take due snapshots, then enforce the memory ladder."""
        self._check_alive()
        self._apply_waves(None, faults=True)
        cfg = self.config
        if self.root is not None and cfg.snapshot_every is not None:
            for sid in sorted(self._live):
                if self._since_snap.get(sid, 0) >= cfg.snapshot_every:
                    self._snapshot(sid)
        self._enforce_memory()

    # -- wave execution ----------------------------------------------------
    def _apply_waves(self, only, *, faults: bool) -> None:
        """Drain pending elements: one element per session per wave,
        sessions chunked to ``max_batch`` and padded to a bucket.

        Invariant: a session that is *due* for SS compaction is compacted
        before its next element applies (checked before and after every
        wave).  That pins the compaction points to the state trajectory
        itself — a wave aborted by an injected fault and retried later
        still compacts at the same element count, which is what makes WAL
        replay (``faults=False``) land bit-identical."""
        cfg = self.config
        while True:
            sids = [
                s for s in sorted(self._pending)
                if self._pending[s] and (only is None or s in only)
            ]
            if not sids:
                return
            restarted = False
            for i in range(0, len(sids), cfg.max_batch):
                chunk = sids[i:i + cfg.max_batch]
                for s in chunk:
                    self._hydrate(s)
                if (
                    self._maybe_resparsify(chunk, faults) == "restarted"
                    or self._exec_wave(chunk, faults) == "restarted"
                    or self._maybe_resparsify(chunk, faults) == "restarted"
                ):
                    restarted = True
                    break
            if restarted:
                continue

    def _draw_fault(self, chunk: list[str], stage: str, faults: bool):
        """Draw (and handle the terminal kinds of) one scheduled fault.
        Returns "restarted" when a restart consumed this attempt, the
        fault for the caller to apply, or None for a clean attempt."""
        if not faults or self._faults is None:
            return None
        be = resolve_backend(self.config.backend)
        fault = self._faults.draw(
            tickets=(), lane=("sessions", tuple(chunk)),
            backend=be.name, stage=stage,
        )
        if fault is None:
            return None
        if fault.kind in ("latency", "hang"):
            time.sleep(fault.delay_s)
            return None
        if fault.kind == "crash":
            self._die()                      # raises ServiceRestarted
        if fault.kind == "restart":
            self._restart()
            return "restarted"
        raise FaultInjected(
            f"injected {fault.kind} on session {stage} {tuple(chunk)}"
        )

    def _exec_wave(self, chunk: list[str], faults: bool):
        if self._draw_fault(chunk, "wave", faults) == "restarted":
            return "restarted"
        cfg = self.config
        states = [self._live[s] for s in chunk]
        rows = [self._pending[s][0][1] for s in chunk]
        B = len(chunk)
        bucket = min(b for b in self._buckets if b >= B)
        pad = bucket - B
        states = states + [states[0]] * pad
        rows = rows + [np.zeros(cfg.n_features, np.float32)] * pad
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)
        valid = jnp.array([True] * B + [False] * pad)
        t0 = time.perf_counter()
        new_states, _, _ = _wave_kernel(
            stacked, jnp.asarray(np.stack(rows)), valid, phi=cfg.phi
        )
        tr = obs.get_tracer()
        if tr.enabled:
            # Host-side timing around the jitted wave only; the sync is
            # opt-in with tracing (the default path stays fully async).
            jax.block_until_ready(new_states)
            t1 = time.perf_counter()
            tr.record("sessions.wave", t0, t1, B=B, bucket=bucket, pad=pad)
            obs.get_registry().histogram(
                "repro_sessions_wave_seconds", "sieve wave execution wall",
            ).observe(t1 - t0)
        for j, s in enumerate(chunk):
            self._live[s] = jax.tree_util.tree_map(
                lambda x, j=j: x[j], new_states
            )
            seq, _ = self._pending[s].popleft()
            self._applied_seq[s] = seq
            self._since_snap[s] = self._since_snap.get(s, 0) + 1
        self._stats["waves"] += 1
        self._stats["wave_slots"] += bucket
        self._stats["padded_slots"] += pad
        return None

    def _maybe_resparsify(self, chunk: list[str], faults: bool):
        cfg = self.config
        due = []
        for s in chunk:
            st = self._live[s]
            if int(st.buf_len) > 0 and (
                int(st.inserts) >= cfg.resparsify_every
                or int(st.buf_len) >= cfg.buffer_cap
            ):
                due.append(s)
        if not due:
            return None
        be = resolve_backend(cfg.backend)
        for i in range(0, len(due), cfg.max_batch):
            grp = due[i:i + cfg.max_batch]
            if self._draw_fault(grp, "resparsify", faults) == "restarted":
                return "restarted"
            t0 = time.perf_counter()
            states = [self._live[s] for s in grp]
            B = len(grp)
            bucket = min(b for b in self._buckets if b >= B)
            states = states + [states[0]] * (bucket - B)
            stacked = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *states
            )
            alive = (
                jnp.arange(cfg.buffer_cap)[None, :]
                < stacked.buf_len[:, None]
            )
            fnb = FeatureCoverage(W=stacked.buf, phi=cfg.phi)
            keys = _fold_keys(stacked.key, stacked.n_ss)
            ss = ss_sparsify_batched(
                fnb, keys, r=cfg.ss_r, c=cfg.ss_c, alive=alive, backend=be
            )
            keep = jnp.logical_and(ss.vprime, alive)
            new_states = _compact_kernel(stacked, keep)
            tr = obs.get_tracer()
            if tr.enabled:
                jax.block_until_ready(new_states)
                tr.record(
                    "sessions.resparsify", t0, time.perf_counter(),
                    B=B, bucket=bucket, sessions=tuple(grp),
                )
            for j, s in enumerate(grp):
                self._live[s] = jax.tree_util.tree_map(
                    lambda x, j=j: x[j], new_states
                )
            self._stats["resparsifies"] += len(grp)
        return None

    # -- faults ------------------------------------------------------------
    def _die(self) -> None:
        msg = (
            "the session engine crashed (injected crash fault); all "
            "in-memory state is gone — construct a new SessionEngine on "
            "the same root to recover from snapshot + WAL"
        )
        for w in self._writers.values():
            w.close()
        self._writers.clear()
        self._live.clear()
        self._pending.clear()
        self._next_seq.clear()
        self._applied_seq.clear()
        self._since_snap.clear()
        self._stats["crashes"] += 1
        self._event("crash", reason="fault")
        self._dead = msg
        raise ServiceRestarted(msg)

    def _restart(self) -> None:
        """Kill + reopen in place: in-memory state dropped, sessions
        rehydrate lazily from snapshot + WAL on next touch.  Pending
        elements were WAL-acknowledged, so none are lost — they simply
        replay during rehydration.  (Only reachable on durable engines:
        a volatile engine rejects crash/restart plans at construction,
        precisely because there its acks would not survive this.)"""
        for w in self._writers.values():
            w.close()
        self._writers.clear()
        self._live.clear()
        self._pending.clear()
        self._next_seq.clear()
        self._applied_seq.clear()
        self._since_snap.clear()
        self._stats["restarts"] += 1
        self._event("restart", reason="fault", sessions=sorted(self._known))

    # -- durability --------------------------------------------------------
    def _writer(self, sid: str) -> _wal.WalWriter:
        w = self._writers.get(sid)
        if w is None:
            w = _wal.WalWriter(
                os.path.join(self.root, sid, "wal.log"),
                fsync=self.config.wal_fsync,
            )
            self._writers[sid] = w
        return w

    def _snapshot(self, sid: str) -> str:
        """Atomically checkpoint ``sid``'s full state (applied elements
        only — call after waves drained).  Keeps the two newest snapshots
        so a corrupt latest still recovers from its predecessor."""
        sdir = os.path.join(self.root, sid)
        seq = self._applied_seq[sid]
        meta = {
            "schema": SCHEMA_VERSION, "sig": self._sig, "applied_seq": seq,
        }
        final = os.path.join(sdir, f"snap-{seq:012d}.npz")
        tmp = final + ".tmp"
        t0 = time.perf_counter()
        with open(tmp, "wb") as f:
            np.savez(
                f,
                _meta=np.frombuffer(json.dumps(meta).encode(), np.uint8),
                **_state_arrays(self._live[sid]),
            )
        os.replace(tmp, final)
        t1 = time.perf_counter()
        obs.get_registry().histogram(
            "repro_sessions_snapshot_seconds",
            "atomic state-checkpoint wall (write + rename)",
        ).observe(t1 - t0)
        tr = obs.get_tracer()
        if tr.enabled:
            tr.record("sessions.snapshot", t0, t1, session=sid, seq=seq)
        self._since_snap[sid] = 0
        self._stats["snapshots"] += 1
        for name in sorted(self._snapshot_names(sid), reverse=True)[2:]:
            os.unlink(os.path.join(sdir, name))
        return final

    def snapshot(self, sid: str) -> str:
        """Flush ``sid`` and checkpoint it now; returns the snapshot path."""
        self._check_alive()
        if self.root is None:
            raise RuntimeError("snapshots require a durable root")
        self._hydrate(sid)
        self._apply_waves({sid}, faults=True)
        return self._snapshot(sid)

    def _snapshot_names(self, sid: str) -> list[str]:
        sdir = os.path.join(self.root, sid)
        return [
            n for n in os.listdir(sdir)
            if n.startswith("snap-") and n.endswith(".npz")
        ]

    def _load_snapshot(self, sid: str):
        """Newest loadable snapshot, or (None, 0).  A snapshot that fails
        to load (torn tmp-rename never produces one, but bit rot / a
        truncated copy can) falls back to its predecessor — loudly, via a
        ``snapshot_fallback`` event — at the price of a longer WAL replay.
        A snapshot that loads but was written under a *different config*
        raises: replaying on top of it would fabricate a plausible wrong
        state."""
        sdir = os.path.join(self.root, sid)
        for name in sorted(self._snapshot_names(sid), reverse=True):
            path = os.path.join(sdir, name)
            try:
                with np.load(path) as z:
                    meta = json.loads(bytes(z["_meta"]).decode())
                    state = _arrays_state(z)
            except Exception as e:  # noqa: BLE001 - corrupt file: fall back
                self._stats["snapshot_fallbacks"] += 1
                self._event(
                    "snapshot_fallback", sid=sid, snapshot=name,
                    error=repr(e),
                )
                continue
            if meta.get("schema") != SCHEMA_VERSION:
                raise ValueError(
                    f"{path}: snapshot schema {meta.get('schema')} != "
                    f"{SCHEMA_VERSION}"
                )
            if meta.get("sig") != self._sig:
                raise ValueError(
                    f"{path}: snapshot was written under a different "
                    "SessionConfig; refusing to replay on top of it"
                )
            return state, int(meta["applied_seq"])
        return None, 0

    def _hydrate(self, sid: str) -> SessionState:
        """The lazy-rehydration rung of the memory ladder: return the live
        state, recovering it from snapshot + WAL tail if it was evicted,
        restarted away, or belongs to a previous process."""
        self._touch(sid)
        st = self._live.get(sid)
        if st is not None:
            return st
        if sid not in self._known:
            raise KeyError(f"unknown session {sid!r}")
        if self.root is None:
            raise RuntimeError(
                f"session {sid!r} was lost (volatile engine restarted; "
                "pass a durable root to survive restarts)"
            )
        replayed = self._recover(sid)
        self._stats["rehydrations"] += 1
        self._event("rehydrate", sid=sid, reason="access", replayed=replayed)
        return self._live[sid]

    def _recover(self, sid: str) -> int:
        """Recovery = newest loadable snapshot + WAL-tail replay through
        the same wave kernels (B=1, faults off).  Verifies the OPEN
        record, the config signature, and strict seq contiguity — a gap
        means acknowledged records vanished, which must never be papered
        over."""
        t0 = time.perf_counter()
        with obs.span("sessions.recover", session=sid) as sp:
            replayed = self._recover_inner(sid)
            sp.set(replayed=replayed)
        obs.get_registry().histogram(
            "repro_sessions_recover_seconds",
            "snapshot-load + WAL-tail-replay wall per rehydration",
        ).observe(time.perf_counter() - t0)
        return replayed

    def _recover_inner(self, sid: str) -> int:
        cfg = self.config
        wal_path = os.path.join(self.root, sid, "wal.log")
        scan = _wal.scan_wal(
            wal_path, tolerate_torn_tail=cfg.tolerate_torn_tail
        )
        records = scan.records
        if scan.torn_bytes:
            # Physically remove the tolerated torn tail.  The writer opens
            # in append mode, so leaving the partial bytes would put the
            # next acknowledged record after garbage and every later scan
            # would misframe at this offset — acknowledged data written
            # post-recovery would become unrecoverable.
            w = self._writers.pop(sid, None)
            if w is not None:
                w.close()
            with open(wal_path, "r+b") as f:
                f.truncate(scan.valid_end)
            self._stats["wal_truncations"] += 1
            self._event(
                "wal_truncate", sid=sid, valid_end=scan.valid_end,
                dropped_bytes=scan.torn_bytes,
            )
        if not records or records[0].rtype != _wal.OPEN:
            raise _wal.WALCorrupt(
                f"{wal_path}: missing OPEN record at sequence 0"
            )
        meta = json.loads(records[0].payload.decode())
        if meta.get("schema") != SCHEMA_VERSION:
            raise ValueError(
                f"{wal_path}: WAL schema {meta.get('schema')} != "
                f"{SCHEMA_VERSION}"
            )
        if meta.get("sig") != self._sig:
            raise ValueError(
                f"{wal_path}: session was written under a different "
                "SessionConfig; replaying it here would silently produce "
                "a different state"
            )
        for i, rec in enumerate(records):
            if rec.seq != i or (i > 0 and rec.rtype != _wal.APPEND):
                raise _wal.WALCorrupt(
                    f"{wal_path}: sequence gap or bad record type at "
                    f"position {i} (seq={rec.seq}, type={rec.rtype}) — "
                    "acknowledged records are missing"
                )
        state, snap_seq = self._load_snapshot(sid)
        if state is None:
            state = _fresh_state(
                cfg, jnp.asarray(np.asarray(meta["key"], np.uint32))
            )
            snap_seq = 0
        self._live[sid] = state
        self._applied_seq[sid] = snap_seq
        self._next_seq[sid] = records[-1].seq + 1
        pend = self._pending.setdefault(sid, deque())
        pend.clear()
        n_bytes = 4 * cfg.n_features
        for rec in records[1:]:
            if rec.seq <= snap_seq:
                continue
            if len(rec.payload) != n_bytes:
                raise _wal.WALCorrupt(
                    f"{wal_path}: APPEND seq={rec.seq} payload is "
                    f"{len(rec.payload)} bytes, expected {n_bytes}"
                )
            pend.append((rec.seq, np.frombuffer(rec.payload, np.float32)))
        replayed = len(pend)
        self._apply_waves({sid}, faults=False)
        self._since_snap[sid] = replayed
        return replayed

    # -- memory ladder -----------------------------------------------------
    def _enforce_memory(self) -> None:
        """Eviction rung: past ``max_live_sessions``, snapshot + release
        the least-recently-used idle session (pending elements pin a
        session live — they are applied first)."""
        cap = self.config.max_live_sessions
        if cap is None or self.root is None:
            return
        while len(self._live) > cap:
            idle = [s for s in self._live if not self._pending.get(s)]
            if not idle:
                return
            victim = min(idle, key=lambda s: self._order.get(s, 0))
            self._snapshot(victim)
            del self._live[victim]
            w = self._writers.pop(victim, None)
            if w is not None:
                w.close()
            self._stats["evictions"] += 1
            self._event(
                "evict", sid=victim, reason="pressure",
                live=len(self._live),
            )

    # -- read side ---------------------------------------------------------
    def state(self, sid: str) -> SessionState:
        """The session's applied state (flushes its pending first; no
        fault draws — this is the introspection/assertion surface)."""
        self._check_alive()
        self._hydrate(sid)
        self._apply_waves({sid}, faults=False)
        st = self._live[sid]
        # Reads hydrate too — a read-heavy sweep over many sessions must
        # not grow past the cap between flushes.
        self._enforce_memory()
        return st

    def summary(self, sid: str) -> SessionSummary:
        """Current k-element summary: flush, then greedy over the
        SS-pruned retained buffer (ids are stream positions)."""
        self._check_alive()
        self._hydrate(sid)
        self._apply_waves({sid}, faults=True)
        cfg = self.config
        st = self._live[sid]
        n_live = int(st.buf_len)
        sieve_value = float(jnp.max(st.sieve.vals))
        if n_live == 0:
            out = SessionSummary(
                sid=sid, selected=np.zeros(0, np.int32),
                gains=np.zeros(0, np.float32), value=0.0,
                sieve_value=sieve_value, retained=0,
                seen=int(st.sieve.t), drops=int(st.drops),
                resparsifies=int(st.n_ss),
            )
            self._enforce_memory()
            return out
        fn = FeatureCoverage(W=st.buf, phi=cfg.phi)
        alive = jnp.arange(cfg.buffer_cap) < st.buf_len
        res = greedy(
            fn, cfg.k, alive=alive, backend=resolve_backend(cfg.backend)
        )
        n_sel = min(cfg.k, n_live)
        slots = np.asarray(res.selected)[:n_sel]
        out = SessionSummary(
            sid=sid,
            selected=np.asarray(st.buf_ids)[slots].astype(np.int32),
            gains=np.asarray(res.gains)[:n_sel].astype(np.float32),
            value=float(res.value),
            sieve_value=sieve_value,
            retained=n_live,
            seen=int(st.sieve.t),
            drops=int(st.drops),
            resparsifies=int(st.n_ss),
        )
        self._enforce_memory()
        return out

    def stats(self) -> dict:
        """Engine counters: appends acknowledged, waves/slots/padding, SS
        compactions, snapshots (+ fallbacks), rehydrations, evictions,
        restarts, crashes — plus live/known session counts."""
        st = dict(self._stats)
        st["live_sessions"] = len(self._live)
        st["known_sessions"] = len(self._known)
        st["pending"] = sum(len(q) for q in self._pending.values())
        st["events_dropped"] = self.events.dropped
        return st
