"""Write-ahead-log record framing for the durable session tier.

One WAL file per session (``repro.serve.sessions``), append-only.  Every
record is::

    header (21 bytes, little-endian)          payload (plen bytes)
    ┌────────┬──────┬─────────┬────────┬──────────┐
    │ magic  │ type │ seq u64 │ plen   │ crc32    │ payload...
    │ u32    │ u8   │         │ u32    │ u32      │
    └────────┴──────┴─────────┴────────┴──────────┘

``crc32`` covers (type, seq, payload), so a flipped bit anywhere in a
record — header fields included, since a corrupted type/seq changes the
digest input and a corrupted plen misframes the payload — fails the check.

Durability contract (docs/streaming.md):

- A record is the unit of durability: :meth:`WalWriter.append` returns only
  after the bytes reached the OS (``flush``), optionally the device
  (``fsync=True``) — the caller acknowledges the mutation only then.
- Reads **fail loudly**: a checksum or framing violation raises
  :class:`WALCorrupt`; valid records after a corrupt one are *never*
  silently dropped (acknowledged data would vanish).  The only narrower
  failure is a **torn tail** — end-of-file in the middle of the final
  record, exactly what a crash mid-``write`` leaves behind.  That raises
  the :class:`WALTruncated` subclass, and :func:`scan_wal` can be told to
  accept it (``tolerate_torn_tail=True``): the partial trailing record was
  by definition never acknowledged, so dropping *it alone* loses nothing.
- A caller that tolerates a torn tail **must truncate the file to**
  :attr:`WalScan.valid_end` **before appending again**: :class:`WalWriter`
  opens in append mode, so a record written after leftover partial bytes
  would misframe every later read at the torn offset
  (``repro.serve.sessions`` does this during recovery).
"""

from __future__ import annotations

import dataclasses
import os
import struct
import time
import zlib

from repro import obs

_MAGIC = 0x314C4157                    # "WAL1", little-endian
_HEADER = struct.Struct("<IBQII")      # magic, type, seq, plen, crc32

#: Record types.
OPEN = 0      # session creation: JSON meta payload (config signature, key)
APPEND = 1    # one stream element: float32 feature-row bytes

_MAX_PLEN = 64 * 1024 * 1024           # framing sanity bound (64 MiB)


class WALCorrupt(RuntimeError):
    """A WAL record failed its checksum or framing — recovery must stop
    and surface the damage instead of replaying a silently-edited
    history."""


class WALTruncated(WALCorrupt):
    """End-of-file in the middle of the *final* record — the torn tail a
    crash mid-write leaves.  Recoverable by explicit opt-in only
    (``scan_wal(..., tolerate_torn_tail=True)``)."""


@dataclasses.dataclass(frozen=True)
class WalRecord:
    rtype: int
    seq: int
    payload: bytes


@dataclasses.dataclass(frozen=True)
class WalScan:
    """The result of :func:`scan_wal`: every verified record plus where the
    verified prefix ends on disk.  ``torn_bytes > 0`` means a torn tail was
    tolerated — the file still holds that many partial-record bytes past
    ``valid_end``, and the caller must truncate to ``valid_end`` before any
    further append."""

    records: list[WalRecord]
    valid_end: int      # byte offset of the end of the verified prefix
    torn_bytes: int     # partial-record bytes dropped past valid_end


def _crc(rtype: int, seq: int, payload: bytes) -> int:
    head = struct.pack("<BQ", rtype, seq)
    return zlib.crc32(payload, zlib.crc32(head)) & 0xFFFFFFFF


class WalWriter:
    """Append-only writer; one instance owns one session's WAL file."""

    def __init__(self, path: str, fsync: bool = False):
        self.path = path
        self.fsync = fsync
        self._f = open(path, "ab")

    def append(self, rtype: int, seq: int, payload: bytes) -> None:
        """Write one record durably (flushed; fsync'd when configured).
        Returns only when the record is on its way to disk — the caller's
        acknowledgement point."""
        crc = _crc(rtype, seq, payload)
        t0 = time.perf_counter()
        self._f.write(_HEADER.pack(_MAGIC, rtype, seq, len(payload), crc))
        self._f.write(payload)
        self._f.flush()
        if self.fsync:
            t_sync = time.perf_counter()
            os.fsync(self._f.fileno())
            obs.get_registry().histogram(
                "repro_wal_fsync_seconds", "WAL fsync latency per append",
            ).observe(time.perf_counter() - t_sync)
        obs.get_registry().histogram(
            "repro_wal_append_seconds",
            "WAL append latency (write + flush + optional fsync)",
        ).observe(time.perf_counter() - t0)

    def tell(self) -> int:
        return self._f.tell()

    def close(self) -> None:
        self._f.close()


def scan_wal(path: str, tolerate_torn_tail: bool = False) -> WalScan:
    """Read and verify every record of a WAL file.

    Raises :class:`WALCorrupt` on any checksum/framing violation with data
    after it, and :class:`WALTruncated` on a torn final record — unless
    ``tolerate_torn_tail`` accepts the (never-acknowledged) partial tail,
    in which case the complete prefix is returned with
    :attr:`WalScan.torn_bytes` counting the dropped partial bytes (the
    caller must truncate the file to :attr:`WalScan.valid_end` before it
    appends again)."""
    records: list[WalRecord] = []
    with open(path, "rb") as f:
        data = f.read()
    off = 0
    size = len(data)
    while off < size:
        if size - off < _HEADER.size:
            if tolerate_torn_tail:
                return WalScan(records, valid_end=off, torn_bytes=size - off)
            raise WALTruncated(
                f"{path}: torn tail — {size - off} trailing bytes are a "
                f"partial record header at offset {off} (crash mid-write); "
                "pass tolerate_torn_tail=True to accept losing the "
                "unacknowledged final record"
            )
        magic, rtype, seq, plen, crc = _HEADER.unpack_from(data, off)
        if magic != _MAGIC or plen > _MAX_PLEN:
            raise WALCorrupt(
                f"{path}: bad record framing at offset {off} "
                f"(magic={magic:#x}, plen={plen}) — refusing to skip; "
                "records after this point would be silently lost"
            )
        body_off = off + _HEADER.size
        if body_off + plen > size:
            if tolerate_torn_tail:
                return WalScan(records, valid_end=off, torn_bytes=size - off)
            raise WALTruncated(
                f"{path}: torn tail — record seq={seq} at offset {off} "
                f"declares {plen} payload bytes but only "
                f"{size - body_off} remain (crash mid-write); pass "
                "tolerate_torn_tail=True to accept losing the "
                "unacknowledged final record"
            )
        payload = data[body_off: body_off + plen]
        if _crc(rtype, seq, payload) != crc:
            raise WALCorrupt(
                f"{path}: checksum mismatch on record seq={seq} at offset "
                f"{off} — the log is damaged; refusing to silently drop it "
                "or anything after it"
            )
        records.append(WalRecord(rtype=rtype, seq=seq, payload=payload))
        off = body_off + plen
    return WalScan(records, valid_end=off, torn_bytes=0)
