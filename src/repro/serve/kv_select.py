"""SS-based KV-cache pruning (beyond-paper application of the technique).

When a decode context outgrows its budget, we treat the cached positions as
the *ground set* of a submodular summarization problem — exactly the paper's
setting, with positions as "sentences" and key vectors as features — run
Submodular Sparsification to shrink the candidate set, then greedy-select a
``budget``-sized set of representative positions.  All attention layers are
compacted to those positions; generation continues at the true sequence
position (``decode_step(..., pos=true_pos)`` keeps RoPE honest).

Objectives:
  * ``coverage`` (default, scalable): FeatureCoverage over |key| features
    pooled across layers and kv-heads — O(L·F) memory.
  * ``fl``: FacilityLocation on cosine similarity of pooled keys — O(L²),
    higher fidelity for short contexts.

This is the serving-side twin of the training-data coreset stage: the decode
batch's rows are **one lane of the summarization service** — the same
batched execution core (:func:`repro.serve.summarize_service.summarize_batch`,
i.e. ``ss_sparsify_batched`` + ``greedy_batched``) that serves standalone
summarization queries selects the kept positions for every row in one
compiled loop.  Execution knobs (backend, SS ``r``/``c``) ride the unified
``RunConfig`` (``KVSelectConfig.run``; the batched engine runs per-query
ground sets, so only dense backends — oracle / pallas — apply here, and
the default pins ``backend="oracle"``).
"""

from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp

from repro.core import FacilityLocation, FeatureCoverage
from repro.serve.summarize_service import RunConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class KVSelectConfig:
    """KV-pruning selection config.  Execution-level knobs live on ``run``
    (the unified :class:`repro.api.RunConfig`); ``r``/``c``/``backend`` are
    deprecated one-release aliases folded into ``run`` with a warning."""

    budget: int = 256          # positions kept
    objective: str = "coverage"  # coverage | fl
    use_ss: bool = True        # False: greedy on the full ground set (ablation)
    run: RunConfig = dataclasses.field(
        default_factory=lambda: RunConfig(backend="oracle")
    )
    # Deprecated pre-RunConfig spellings (None = unset):
    r: int | None = None
    c: float | None = None
    backend: str | None = None

    def __post_init__(self):
        legacy = {
            name: getattr(self, name)
            for name in ("r", "c", "backend")
            if getattr(self, name) is not None
        }
        if legacy:
            warnings.warn(
                "KVSelectConfig(r=..., c=..., backend=...) is deprecated; "
                "pass run=RunConfig(...) instead",
                DeprecationWarning,
                stacklevel=3,
            )
            object.__setattr__(
                self, "run", dataclasses.replace(self.run, **legacy)
            )


def pooled_keys(cache: dict, seq_len: int) -> Array:
    """Mean |key| features over all attention layers & kv heads.

    Returns (B, seq_len, head_dim)."""
    ks = []
    for name, grp in cache.get("blocks", {}).items():
        if isinstance(grp, dict) and "k" in grp:
            k = grp["k"]                      # (G, B, L, KV, hd)
            ks.append(jnp.mean(jnp.abs(k.astype(jnp.float32)), axis=(0, 3)))
    for name, c in cache.get("rem", {}).items():
        if isinstance(c, dict) and "k" in c:
            ks.append(jnp.mean(jnp.abs(c["k"].astype(jnp.float32)), axis=2))
    assert ks, "cache has no attention layers to prune"
    pooled = sum(ks) / len(ks)                # (B, L, hd)
    return pooled[:, :seq_len]


def _batch_objective(feats: Array, kv: KVSelectConfig):
    """Stacked objective over the (B, L, F) pooled features — one service
    lane per decode batch."""
    if kv.objective == "coverage":
        return FeatureCoverage(W=feats, phi="sqrt")
    if kv.objective == "fl":
        sims = jax.vmap(
            lambda X: FacilityLocation.from_features(X, kernel="cosine").sim
        )(feats)
        return FacilityLocation(sim=sims)
    raise ValueError(kv.objective)


def select_positions_batched(
    feats: Array,              # (B, L, F) nonnegative features per row
    kv: KVSelectConfig,
    keys: Array,               # (B, 2) per-row PRNG keys
) -> Array:
    """SS + greedy position selection for the whole decode batch through the
    summarization service's execution core — one compiled loop, row results
    identical to per-row single-query runs under the same keys.  Returns
    sorted (B, budget) int32 indices."""
    from repro.serve.summarize_service import summarize_batch

    fn = _batch_objective(feats, kv)
    run = kv.run
    res, _ = summarize_batch(
        fn, kv.budget, keys, r=run.r, c=run.c, use_ss=kv.use_ss,
        backend=run.backend, compact=run.compact,
    )
    return jnp.sort(res.selected, axis=1)


def select_positions(
    feats: Array,              # (L, F) nonnegative features for one row
    kv: KVSelectConfig,
    key: Array,
) -> Array:
    """Single-row convenience wrapper over the batched service path.
    Returns sorted (budget,) int32 indices."""
    return select_positions_batched(feats[None], kv, key[None])[0]


def prune_cache(
    cfg,
    cache: dict,
    seq_len: int,
    kv: KVSelectConfig,
    key: Array,
) -> tuple[dict, Array, Array]:
    """Compact every attention layer's cache to the SS-selected positions.

    Returns (new_cache, new_cache_len (= budget), kept (B, budget) positions).
    Non-attention state (SSM/RG-LRU) is untouched — it is already O(1).
    """
    feats = pooled_keys(cache, seq_len)              # (B, L, hd)
    B = feats.shape[0]
    keys = jax.random.split(key, B)
    kept = select_positions_batched(feats, kv, keys)

    def compact(leaf_path, leaf):
        names = [p.key for p in leaf_path if hasattr(p, "key")]
        if names[-1] not in ("k", "v"):
            return leaf
        if leaf.ndim == 5:        # (G, B, L, KV, hd) stacked groups
            def per_row(row, idx):   # row (L, KV, hd)
                sel = row[idx]
                return jnp.zeros_like(row).at[: idx.shape[0]].set(sel)
            return jax.vmap(                 # over G
                lambda grp: jax.vmap(per_row)(grp, kept)
            )(leaf)
        # (B, L, KV, hd) remainder layer
        def per_row(row, idx):
            sel = row[idx]
            return jnp.zeros_like(row).at[: idx.shape[0]].set(sel)
        return jax.vmap(per_row)(leaf, kept)

    new_cache = jax.tree_util.tree_map_with_path(compact, cache)
    return new_cache, jnp.int32(kv.budget), kept
