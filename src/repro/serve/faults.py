"""Seeded fault injection for the serving stack (test/bench hook).

A :class:`FaultPlan` is a *deterministic* schedule mapping chunk execution
attempts (a global, service-wide attempt counter) to faults:

- ``exec_error``   — the attempt raises :class:`FaultInjected` before any
  work runs (a poisoned kernel launch / OOM / device loss stand-in);
- ``latency``      — the attempt sleeps ``delay_s`` before executing
  (a transient stall: contended device, GC pause, noisy neighbor);
- ``hang``         — like ``latency`` but with a wall time chosen to exceed
  ``RunConfig.chunk_timeout_s``: the watchdog abandons the attempt and the
  injected sleep is what the abandoned worker burns (a wedged kernel);
- ``malformed``    — the attempt executes but its results are corrupted to
  NaN before the executor's result validation, which must catch them
  (:class:`repro.serve.summarize_service.MalformedResult`) and retry;
- ``crash``        — the process dies mid-stream: the engine drawing the
  fault kills itself (in-memory state discarded, every in-flight ticket
  settled with :class:`~repro.serve.summarize_service.ServiceRestarted`,
  all further calls rejected) — recovery means constructing a fresh engine,
  which for the durable session tier (repro.serve.sessions) replays
  snapshot + WAL back to the exact pre-crash state;
- ``restart``      — a crash immediately followed by an in-place recovery:
  in-memory state is discarded and reloaded from durable storage (sessions
  engine), or in-flight tickets are settled with ``ServiceRestarted`` while
  the service itself keeps serving new submissions (summarize service).

The plan is threaded into :class:`~repro.serve.summarize_service.
SummarizeService` via the ``faults=`` constructor hook; production services
never construct one.  Because the flusher (async) / caller (sync) executes
chunks serially, the attempt counter — and therefore the fault sequence —
is deterministic for a fixed submission order, and :attr:`FaultPlan.log`
records every draw with the ticket indices it hit, so tests can assert
exact fault-to-ticket attribution (tests/test_serve_faults.py).

``FaultPlan.seeded(seed, ...)`` builds a schedule from per-kind rates with
``numpy.random.default_rng(seed)`` — the same seed always yields the same
schedule, independent of execution timing.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Mapping

import numpy as np

from repro import obs


class FaultInjected(RuntimeError):
    """An injected execution error (the harness's stand-in for a poisoned
    kernel launch); recoverable — the executor retries / fails over."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault: ``kind`` plus the sleep it injects (``delay_s``
    is only meaningful for ``latency`` / ``hang``)."""

    kind: str        # exec_error | latency | hang | malformed | crash | restart
    delay_s: float = 0.0

    KINDS = ("exec_error", "latency", "hang", "malformed", "crash", "restart")

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(
                f"fault kind must be one of {self.KINDS}; got {self.kind!r}"
            )


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One fault actually drawn by the executor — the attribution record."""

    attempt: int                # global execution-attempt index
    fault: Fault
    tickets: tuple[int, ...]    # Ticket.index of every request in the chunk
    lane: Any
    backend: str                # backend name the attempt ran under
    stage: str                  # primary | failover | isolated


class FaultPlan:
    """Deterministic attempt-indexed fault schedule + attribution log.

    ``schedule`` maps a global execution-attempt index (0-based, counted
    across every chunk attempt the service makes, including retries and
    per-query isolation sub-chunks) to the :class:`Fault` injected on that
    attempt.  Attempts not in the schedule run clean.
    """

    def __init__(self, schedule: Mapping[int, Fault]):
        self.schedule = {int(i): f for i, f in schedule.items()}
        for i, f in self.schedule.items():
            if i < 0:
                raise ValueError(f"attempt index must be >= 0; got {i}")
            if not isinstance(f, Fault):
                raise TypeError(f"schedule values must be Fault; got {f!r}")
        # Bounded: a long chaos run ages old draws out instead of growing
        # without limit; ``log.dropped`` counts the evicted history.
        self.log: obs.RingLog = obs.RingLog()
        self._attempts = 0
        self._lock = threading.Lock()

    @classmethod
    def seeded(
        cls,
        seed: int,
        n_attempts: int = 64,
        *,
        p_exec_error: float = 0.0,
        p_latency: float = 0.0,
        p_hang: float = 0.0,
        p_malformed: float = 0.0,
        p_crash: float = 0.0,
        p_restart: float = 0.0,
        latency_s: float = 0.05,
        hang_s: float = 5.0,
    ) -> "FaultPlan":
        """A schedule over the first ``n_attempts`` execution attempts with
        per-attempt fault probabilities, drawn once at construction from
        ``default_rng(seed)`` — fully reproducible, timing-independent."""
        probs = {
            "exec_error": p_exec_error,
            "latency": p_latency,
            "hang": p_hang,
            "malformed": p_malformed,
            "crash": p_crash,
            "restart": p_restart,
        }
        if sum(probs.values()) > 1.0:
            raise ValueError(f"fault probabilities sum past 1: {probs}")
        rng = np.random.default_rng(seed)
        kinds = list(probs) + [None]
        weights = list(probs.values())
        weights.append(1.0 - sum(weights))
        schedule: dict[int, Fault] = {}
        for i in range(n_attempts):
            kind = rng.choice(kinds, p=weights)
            if kind is None:
                continue
            delay = {"latency": latency_s, "hang": hang_s}.get(kind, 0.0)
            schedule[i] = Fault(kind=str(kind), delay_s=delay)
        return cls(schedule)

    @property
    def attempts(self) -> int:
        """Execution attempts drawn against this plan so far."""
        with self._lock:
            return self._attempts

    def draw(
        self, *, tickets: tuple[int, ...], lane: Any, backend: str, stage: str
    ) -> Fault | None:
        """Consume one attempt index; returns the scheduled fault (logged
        with full attribution) or None for a clean attempt."""
        with self._lock:
            i = self._attempts
            self._attempts += 1
            fault = self.schedule.get(i)
            if fault is not None:
                self.log.append(FaultEvent(
                    attempt=i, fault=fault, tickets=tuple(tickets),
                    lane=lane, backend=backend, stage=stage,
                ))
        if fault is not None:
            obs.get_bus().emit(
                "fault", subsystem="faults", request_ids=tuple(tickets),
                attempt=i, fault_kind=fault.kind, delay_s=fault.delay_s,
                backend=backend, stage=stage,
            )
            obs.get_registry().counter(
                "repro_faults_injected_total", "fault draws by kind",
                labels=("kind",),
            ).inc(kind=fault.kind)
        return fault

    def events(self, kind: str | None = None) -> list[FaultEvent]:
        """The attribution log, optionally filtered to one fault kind."""
        with self._lock:
            return [
                e for e in self.log
                if kind is None or e.fault.kind == kind
            ]
