"""Serving substrate: batched prefill/decode engine, SS-based KV-cache
pruning for long contexts, and the SLO-aware micro-batched multi-query
summarization service (repro.serve.summarize_service).  The stable public
surface is re-exported as ``repro.api``."""

from repro.serve.engine import Engine, ServeConfig
from repro.serve.faults import (
    Fault,
    FaultEvent,
    FaultInjected,
    FaultPlan,
)
from repro.serve.kv_select import (
    KVSelectConfig,
    prune_cache,
    select_positions,
    select_positions_batched,
)
from repro.serve.sessions import (
    SessionConfig,
    SessionEngine,
    SessionState,
    SessionSummary,
)
from repro.serve.summarize_service import (
    LADDER_STEPS,
    ChunkTimeout,
    DeadlineExceeded,
    MalformedResult,
    RunConfig,
    ServiceConfig,
    ServiceOverloaded,
    ServiceRestarted,
    SummarizeRequest,
    SummarizeResponse,
    SummarizeService,
    Ticket,
    TicketPending,
    batch_buckets,
    summarize_batch,
)
from repro.serve.wal import (
    WALCorrupt,
    WALTruncated,
    WalRecord,
    WalScan,
    WalWriter,
    scan_wal,
)
