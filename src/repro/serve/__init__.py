"""Serving substrate: batched prefill/decode engine, SS-based KV-cache
pruning for long contexts, and the micro-batched multi-query summarization
service (repro.serve.summarize_service)."""

from repro.serve.engine import Engine, ServeConfig
from repro.serve.kv_select import (
    KVSelectConfig,
    prune_cache,
    select_positions,
    select_positions_batched,
)
from repro.serve.summarize_service import (
    ServiceConfig,
    SummarizeRequest,
    SummarizeResponse,
    SummarizeService,
    batch_buckets,
    summarize_batch,
)
