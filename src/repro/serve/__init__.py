"""Serving substrate: batched prefill/decode engine and SS-based KV-cache
pruning for long contexts."""

from repro.serve.engine import Engine, ServeConfig
from repro.serve.kv_select import KVSelectConfig, prune_cache, select_positions
