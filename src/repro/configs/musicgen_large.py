"""musicgen-large [audio] — decoder-only LM over EnCodec tokens,
arXiv:2306.05284 (hf tier).  48L, d_model 2048, 32 heads (MHA: kv=32),
d_ff 8192, vocab 2048 per codebook, 4 parallel codebooks (delay pattern).

The EnCodec audio frontend is a STUB: ``input_specs`` feeds the 4 discrete
token streams directly (B, S, 4); embeddings are the sum of 4 codebook
embeddings; output is 4 parallel 2048-way heads.  Adaptation note: the
reference uses a non-gated GELU MLP (mlp_gated=False) and learned positional
embeddings — we keep RoPE (recorded in DESIGN.md).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    mlp_gated=False,
    mlp_act="gelu",
    input_mode="codebooks",
    num_codebooks=4,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="musicgen-large-smoke",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=64,
    mlp_gated=False,
    mlp_act="gelu",
    input_mode="codebooks",
    num_codebooks=4,
    tie_embeddings=False,
    param_dtype="float32",
    compute_dtype="float32",
)
