"""The paper's own workload configuration (§4 of the paper).

Submodular sparsification hyperparameters and the synthetic-corpus stand-ins
for the NYT / DUC2001 / SumMe experiments (offline container — see
DESIGN.md §7).  These defaults follow the paper: r = 8, c = 8 (shrink rate
1/sqrt(8) ≈ 0.354, i.e. ~64.6% pruned per round), k = 10 for the utility
study, 50 sieve thresholds, feature-based sqrt-coverage objective.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class SSWorkload:
    r: int = 8                 # probe multiplier (paper: r = 8)
    c: float = 8.0             # accuracy/speed tradeoff (paper: c = 8)
    k: int = 10                # summary budget for the utility study
    sieve_thresholds: int = 50  # paper: "50 trials" -> memory 50k
    phi: str = "sqrt"          # concave transform of the coverage objective

    # synthetic NYT-like news corpus (per "day")
    news_days: int = 64            # scaled-down stand-in for 3823 days
    news_sentences: tuple = (1000, 20000)   # n range per day
    news_features: int = 1024      # hashed-TFIDF feature dim
    news_zipf: float = 1.07        # token Zipf exponent

    # synthetic SumMe-like video corpus
    video_count: int = 25
    video_frames: tuple = (950, 9721)
    video_features: int = 512      # pHoG/GIST-like descriptor dim
    summary_frac: float = 0.15     # k = 0.15 |V| (paper §5.13)


DEFAULT = SSWorkload()
