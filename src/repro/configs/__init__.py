"""Architecture registry: ``get(arch_id)`` / ``smoke(arch_id)`` /
``input_specs(cfg, shape)``.

Every assigned architecture is a module in this package exposing ``CONFIG``
(the exact published dims) and ``SMOKE`` (a reduced same-family variant for
CPU tests).  ``input_specs`` builds the ShapeDtypeStruct stand-ins that the
multi-pod dry-run lowers against — weak-type-correct, shardable, and never
allocated.
"""

from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.models.config import SHAPES, ModelConfig, ShapeConfig, cell_supported

_MODULES = {
    "internvl2-76b": "internvl2_76b",
    "mamba2-780m": "mamba2_780m",
    "musicgen-large": "musicgen_large",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "llama3.2-3b": "llama3_2_3b",
    "qwen3-4b": "qwen3_4b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen2-7b": "qwen2_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
}

ARCHS = tuple(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {list(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def smoke(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def input_specs(cfg: ModelConfig, shp: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the lowered step.

    train  -> {tokens, labels[, patches]}
    prefill-> {tokens[, patches]}
    decode -> {tokens}  (cache/cache_len specs come from models.abstract_cache)
    """
    B, S = shp.global_batch, shp.seq_len
    i32 = jnp.int32
    tok_shape = {
        "tokens": (B, S) if cfg.num_codebooks == 1 else (B, S, cfg.num_codebooks),
        "decode": (B, 1) if cfg.num_codebooks == 1 else (B, 1, cfg.num_codebooks),
    }
    sds = jax.ShapeDtypeStruct
    if shp.kind == "train":
        specs = {
            "tokens": sds(tok_shape["tokens"], i32),
            "labels": sds(tok_shape["tokens"], i32),
        }
        if cfg.input_mode == "tokens+patches":
            specs["patches"] = sds(
                (B, cfg.num_patches, cfg.d_model), jnp.bfloat16
            )
        return specs
    if shp.kind == "prefill":
        specs = {"tokens": sds(tok_shape["tokens"], i32)}
        if cfg.input_mode == "tokens+patches":
            specs["patches"] = sds(
                (B, cfg.num_patches, cfg.d_model), jnp.bfloat16
            )
        return specs
    if shp.kind == "decode":
        return {"tokens": sds(tok_shape["decode"], i32)}
    raise ValueError(shp.kind)


def all_cells():
    """Every (arch, shape) pair with its supported/skip status."""
    for arch in ARCHS:
        cfg = get(arch)
        for sname, shp in SHAPES.items():
            ok, why = cell_supported(cfg, shp)
            yield arch, sname, ok, why


__all__ = [
    "ARCHS",
    "SHAPES",
    "get",
    "smoke",
    "shape",
    "input_specs",
    "all_cells",
]
