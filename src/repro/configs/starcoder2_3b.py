"""starcoder2-3b [dense] — arXiv:2402.19173 (hf tier).  30L, d_model 3072,
24 heads (GQA kv=2), d_ff 12288, vocab 49152, RoPE, QKV bias, classic
(non-gated) GELU MLP.  ~3.0B params.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    qkv_bias=True,
    mlp_gated=False,
    mlp_act="gelu",
    rope_theta=100_000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="starcoder2-3b-smoke",
    num_layers=4,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    head_dim=8,
    d_ff=192,
    vocab_size=173,
    qkv_bias=True,
    mlp_gated=False,
    mlp_act="gelu",
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
)
