"""recurrentgemma-2b [hybrid] — Griffin, arXiv:2402.19427 (hf tier).
26L, d_model 2560, pattern (RG-LRU, RG-LRU, local-attn) 1:2, 10 heads
(MQA kv=1, head_dim 256), d_ff 7680 (GeGLU), vocab 256000, local window 2048.
26 = 8 full patterns + 2 trailing recurrent blocks.  Runs long_500k
(recurrent state + windowed KV are O(1) in context).  ~2.7B params.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "local"),
    local_window=2048,
    rnn_width=2560,
    mlp_act="gelu",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke",
    num_layers=5,
    d_model=64,
    num_heads=2,
    num_kv_heads=1,
    head_dim=32,
    d_ff=192,
    vocab_size=211,
    block_pattern=("rglru", "rglru", "local"),
    local_window=8,
    rnn_width=64,
    mlp_act="gelu",
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
)
