"""llama3.2-3b [dense] — hf:meta-llama/Llama-3.2-3B (unverified tier).
28L, d_model 3072, 24 heads (GQA kv=8), d_ff 8192, vocab 128256, tied
embeddings, rope theta 500k.  ~3.2B params.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500_000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="llama3.2-3b-smoke",
    num_layers=4,
    d_model=64,
    num_heads=6,
    num_kv_heads=2,
    head_dim=8,
    d_ff=128,
    vocab_size=161,
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
)
