"""mamba2-780m [ssm] — SSD (state-space duality), arXiv:2405.21060.

48L, d_model 1536, attention-free, vocab 50280, ssm_state 128.
d_inner = 2*1536 = 3072, headdim 64 -> 48 SSD heads, 1 B/C group.
Runs the long_500k cell (constant-size recurrent state).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    num_layers=48,
    d_model=1536,
    num_heads=1,           # unused (attention-free)
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    block_pattern=("mamba2",),
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_groups=1,
    ssm_chunk=256,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-780m-smoke",
    num_layers=4,
    d_model=64,
    num_heads=1,
    num_kv_heads=1,
    head_dim=16,
    d_ff=0,
    vocab_size=211,
    block_pattern=("mamba2",),
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=16,
    ssm_groups=1,
    ssm_chunk=8,
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
)
