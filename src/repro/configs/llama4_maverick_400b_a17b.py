"""llama4-maverick-400b-a17b [moe] — 48L, d_model 5120, 40 heads (GQA kv=8),
d_ff 8192, vocab 202048, MoE 128 experts top-1, early fusion.

Source: hf:meta-llama/Llama-4-* (unverified tier).  The one-line spec
(48L x 128e) would be ~773B total if *every* layer were MoE; the published
400B/17B-active figures correspond to interleaved MoE (every other layer) plus
a shared expert — we use block_pattern ("attn", "attn_moe") and a shared
expert, which lands at ~398B total / ~17B active (see DESIGN.md
§Arch-applicability for the reconciliation).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    block_pattern=("attn", "attn_moe"),
    num_experts=128,
    top_k=1,
    d_ff_expert=8192,
    shared_expert=True,
    rope_theta=500_000.0,
    tie_embeddings=False,
    param_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="llama4-maverick-smoke",
    num_layers=4,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    head_dim=8,
    d_ff=128,
    vocab_size=256,
    block_pattern=("attn", "attn_moe"),
    num_experts=8,
    top_k=1,
    d_ff_expert=128,
    shared_expert=True,
    tie_embeddings=False,
    capacity_factor=4.0,
    param_dtype="float32",
    compute_dtype="float32",
)
