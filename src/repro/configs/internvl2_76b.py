"""internvl2-76b [vlm] — InternViT frontend (STUB) + InternLM2-76B backbone.

Source: arXiv:2404.16821 (unverified tier).  The assignment specifies the
transformer BACKBONE only: 80L, d_model 8192, 64 heads (GQA kv=8),
d_ff 28672, vocab 128256.  The ViT frontend is a stub — ``input_specs``
supplies precomputed patch embeddings (B, 256, d_model) that early-fuse into
the first 256 token positions.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=1_000_000.0,
    input_mode="tokens+patches",
    num_patches=256,
    tie_embeddings=False,
    param_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="internvl2-76b-smoke",
    num_layers=4,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    head_dim=8,
    d_ff=160,
    vocab_size=199,
    input_mode="tokens+patches",
    num_patches=4,
    tie_embeddings=False,
    param_dtype="float32",
    compute_dtype="float32",
)
