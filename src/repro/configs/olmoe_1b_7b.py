"""olmoe-1b-7b [moe] — arXiv:2409.02060 (hf tier).  16L, d_model 2048,
16 heads (kv=16), 64 experts top-8, expert d_ff 1024, vocab 50304, qk-norm.
~6.9B total / ~1.3B active.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    block_pattern=("attn_moe",),
    num_experts=64,
    top_k=8,
    d_ff_expert=1024,
    qk_norm=True,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="olmoe-1b-7b-smoke",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=32,
    vocab_size=128,
    block_pattern=("attn_moe",),
    num_experts=8,
    top_k=4,
    d_ff_expert=32,
    qk_norm=True,
    tie_embeddings=False,
    capacity_factor=4.0,
    param_dtype="float32",
    compute_dtype="float32",
)
