"""qwen2-7b [dense] — arXiv:2407.10671 (hf tier).  28L, d_model 3584,
28 heads (GQA kv=4), d_ff 18944, vocab 152064, QKV bias, untied embeddings.
~7.6B params.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="qwen2-7b-smoke",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=157,
    qkv_bias=True,
    tie_embeddings=False,
    param_dtype="float32",
    compute_dtype="float32",
)
