"""qwen3-4b [dense] — hf:Qwen/Qwen3-4B (hf tier).  36L, d_model 2560,
32 heads (GQA kv=8), decoupled head_dim 128 (q_dim 4096 != d_model),
d_ff 9728, vocab 151936, qk-norm, tied embeddings.  ~4.0B params.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen3-4b-smoke",
    num_layers=4,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    head_dim=16,     # decoupled: q_dim 128 != d_model 64
    d_ff=128,
    vocab_size=151,
    qk_norm=True,
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
)
