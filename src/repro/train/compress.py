"""Error-feedback gradient compression for the pod (DCN) axis.

Between pods the links are ~10x slower than intra-pod ICI, so the cross-pod
gradient all-reduce is the one collective worth compressing.  We implement
**error feedback with per-block top-k sparsification**:

    m_t   = g_t + e_t                (add the carried compression error)
    c_t   = topk_blocks(m_t)         (keep the largest-|.| fraction per block)
    e_t+1 = m_t - c_t                (carry what was dropped)
    g̃_t  = all_reduce(c_t, axis=pod) / n_pods

Error feedback makes biased compressors convergent (Karimireddy et al. 2019);
the carried error state shards exactly like the gradients.

The pod reduction must be *manual* (GSPMD would otherwise fuse an exact
all-reduce into the backward), so the compressed step wraps the gradient
computation in ``jax.shard_map`` manual over **only** the pod axis
(``axis_names={"pod"}``) — data/model parallelism inside stays GSPMD-managed.

Top-k is per fixed-size block (1024) rather than per-leaf: O(n) one-pass
work and a static selected count, so the buffer stays dense-with-zeros (what
an SPMD all-reduce needs).  On a real DCN the wire saving comes from sparse
encoding of that buffer; we surface the achieved density as a metric.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.models.sharding import POD, batch_axes

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CompressConfig:
    ratio: float = 0.05      # fraction of entries kept per block
    block: int = 1024


def topk_block_sparsify(x: Array, ratio: float, block: int) -> Array:
    """Keep the top-⌈ratio·block⌉ |entries| of every ``block`` chunk of the
    flattened array; zero the rest.  Shape-preserving."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    if n <= block:
        k = max(1, int(ratio * n))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        return jnp.where(jnp.abs(flat) >= thresh, flat, 0.0).reshape(x.shape)
    pad = (-n) % block
    fp = jnp.pad(flat, (0, pad)).reshape(-1, block)
    k = max(1, int(ratio * block))
    kth = jax.lax.top_k(jnp.abs(fp), k)[0][:, -1:]
    out = jnp.where(jnp.abs(fp) >= kth, fp, 0.0).reshape(-1)[:n]
    return out.reshape(x.shape)


def init_error_state(params) -> dict:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_and_reduce(grads, error, cc: CompressConfig, axis_name: str = POD):
    """EF top-k + mean all-reduce over ``axis_name`` (must be bound).

    Returns (reduced_grads, new_error, density_metric)."""
    n = jax.lax.psum(1.0, axis_name)

    def leaf(g, e):
        m = g.astype(jnp.float32) + e
        c = topk_block_sparsify(m, cc.ratio, cc.block)
        return jax.lax.psum(c, axis_name) / n, m - c, jnp.sum(c != 0.0), c.size

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    reduced = treedef.unflatten([o[0] for o in out])
    new_error = treedef.unflatten([o[1] for o in out])
    nnz = sum(o[2] for o in out)
    tot = sum(o[3] for o in out)
    return reduced, new_error, nnz / tot


def make_compressed_train_step(mesh: Mesh, cfg, tc, cc: CompressConfig):
    """Pod-compressed variant of ``trainer.make_train_step``.

    The returned step takes/returns state with an extra ``error`` field
    (init with ``init_error_state``).  Params and optimizer state are
    replicated across pods; the batch's leading dim is split across
    pod x data as usual.  Inside the pod-manual shard_map, gradients are
    computed under GSPMD over (data, model), EF-compressed, psum'd over pod,
    then the optimizer update runs identically on every pod.
    """
    assert POD in mesh.axis_names, "compressed step needs a pod axis"
    from repro.train.optimizer import make_optimizer
    from repro.train.trainer import _loss_fn, _global_norm, lr_schedule

    opt = make_optimizer(
        tc.optimizer,
        **({"weight_decay": tc.weight_decay} if tc.optimizer == "adamw" else {}),
    )

    def step(state, batch):
        params = state["params"]
        (_, metrics), grads = jax.value_and_grad(
            lambda p: _loss_fn(cfg, tc, p, batch), has_aux=True
        )(params)
        grads, new_err, density = compress_and_reduce(grads, state["error"], cc)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, POD), metrics)

        gnorm = _global_norm(grads)
        scale = jnp.minimum(1.0, tc.grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
        lr = lr_schedule(tc, state["step"])
        new_params, new_opt = opt.update(
            grads, state["opt"], params, lr, state["step"]
        )
        metrics = dict(
            metrics, grad_norm=gnorm, lr=lr, compress_density=density
        )
        return (
            {"params": new_params, "opt": new_opt, "error": new_err,
             "step": state["step"] + 1},
            metrics,
        )

    def wrap(state, batch):
        state_specs = jax.tree.map(lambda _: P(), state)
        batch_specs = jax.tree.map(lambda _: P(POD), batch)
        metric_specs = {
            "loss": P(), "aux": P(), "grad_norm": P(), "lr": P(),
            "compress_density": P(),
        }
        return shard_map(
            step,
            mesh=mesh,
            in_specs=(state_specs, batch_specs),
            out_specs=(state_specs, metric_specs),
            axis_names={POD},
        )(state, batch)

    return wrap
