"""Training substrate: optimizers, sharded train step, checkpointing,
fault-tolerance runtime, pod-axis gradient compression."""

from repro.train.checkpoint import Checkpointer
from repro.train.compress import (
    CompressConfig,
    init_error_state,
    make_compressed_train_step,
    topk_block_sparsify,
)
from repro.train.optimizer import Adafactor, AdamW, make_optimizer
from repro.train.runtime import (
    LoopReport,
    PreemptionGuard,
    StragglerGuard,
    resume_or_init,
    run,
)
from repro.train.trainer import (
    TrainConfig,
    abstract_train_state,
    lr_schedule,
    make_train_state,
    make_train_step,
    shard_train_step,
    state_spec_tree,
)
