"""Hand-rolled optimizers (no optax in this environment): AdamW and Adafactor.

Both are functional: ``init(params) -> state`` and
``update(grads, state, params, lr, step) -> (new_params, new_state)``.
States are plain pytrees that shard exactly like their parameters
(Adafactor's factored second moments drop one axis — their specs are derived
in ``state_spec_tree``).

Adafactor (Shazeer & Stern 2018) is the memory-sane choice for the 400B MoE
config: second moments of any large rank>=2 leaf are stored as a row/col
outer product (O(n+m) instead of O(nm)); no first moment; the update RMS is
clipped at ``clip_threshold``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array


def _map_zip(fn, *trees):
    """tree.map over parallel trees where non-first trees may have dict
    leaves: walks the first tree's structure."""
    flat0, treedef = jax.tree.flatten(trees[0])
    rest = [treedef.flatten_up_to(t) for t in trees[1:]]
    out = [fn(*args) for args in zip(flat0, *rest)]
    return treedef, out


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params) -> dict:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
        }

    def update(self, grads, state, params, lr: Array, step: Array):
        b1, b2 = self.b1, self.b2
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * gf
            v_new = b2 * v + (1 - b2) * gf * gf
            delta = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + self.eps)
            if p.ndim >= 2:  # no decay on norms/biases
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

        treedef, out = _map_zip(upd, grads, state["mu"], state["nu"], params)
        new_params = treedef.unflatten([o[0] for o in out])
        new_mu = treedef.unflatten([o[1] for o in out])
        new_nu = treedef.unflatten([o[2] for o in out])
        return new_params, {"mu": new_mu, "nu": new_nu}

    def state_spec_tree(self, param_specs, params_shape) -> dict:
        return {"mu": param_specs, "nu": param_specs}


# ---------------------------------------------------------------------------
# Adafactor
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Adafactor:
    decay: float = 0.8           # \hat{beta2}_t = 1 - t^{-decay}
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    min_dim_factored: int = 128  # only factor axes at least this large

    def _factored(self, p) -> bool:
        return (
            p.ndim >= 2
            and p.shape[-1] >= self.min_dim_factored
            and p.shape[-2] >= self.min_dim_factored
        )

    def init(self, params) -> dict:
        def leaf_state(p):
            if self._factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"v": jax.tree.map(leaf_state, params)}

    def update(self, grads, state, params, lr: Array, step: Array):
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - t ** (-self.decay)

        def upd(g, s, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + self.eps
            if "vr" in s:
                vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), self.eps)
                u = gf * jax.lax.rsqrt(
                    (vr / denom)[..., None] * vc[..., None, :] + self.eps
                )
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = gf * jax.lax.rsqrt(v + self.eps)
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u) + self.eps)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            pf = p.astype(jnp.float32)
            if self.weight_decay > 0.0 and p.ndim >= 2:
                u = u + self.weight_decay * pf
            return (pf - lr * u).astype(p.dtype), new_s

        treedef, out = _map_zip(upd, grads, state["v"], params)
        new_params = treedef.unflatten([o[0] for o in out])
        new_state = treedef.unflatten([o[1] for o in out])
        return new_params, {"v": new_state}

    def state_spec_tree(self, param_specs, params_shape) -> Any:
        """Specs for the factored state: vr drops the last param axis, vc the
        second-to-last.  Decided per-leaf from the param shapes so it matches
        ``init`` exactly."""

        def leaf(spec, p):
            if self._factored(p):
                return {
                    "vr": P(*spec[:-1]),
                    "vc": P(*spec[:-2], spec[-1]),
                }
            return {"v": spec}

        treedef, out = _map_zip(
            lambda s, p: leaf(s, p),
            param_specs, params_shape,
        )
        return {"v": treedef.unflatten(out)}


def make_optimizer(name: str, **kw):
    if name == "adamw":
        return AdamW(**kw)
    if name == "adafactor":
        return Adafactor(**kw)
    raise ValueError(name)
