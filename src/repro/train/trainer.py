"""Training step factory: loss + grad with microbatch accumulation, global
clip, LR schedule, optimizer update — all inside one jit with explicit
in/out shardings, so the same function serves CPU tests, the 512-device
dry-run, and a real cluster.

Microbatching is a ``lax.scan`` over ``num_microbatches`` slices of the
global batch: the per-microbatch backward (remat'd scan-over-layers) reuses
one activation footprint while gradients accumulate in f32 — this is what
bounds activation memory to ``(B/µ) * S * D * L_pattern`` on the big train
cells.  Gradient reduction across data/model happens inside the backward
(GSPMD); the optional pod-axis *compressed* reduction lives in compress.py.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import (
    ModelConfig,
    data_spec,
    forward,
    init_params,
    lm_loss,
    param_spec_tree,
)
from repro.models.sharding import batch_axes
from repro.train.optimizer import make_optimizer

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0
    num_microbatches: int = 1
    remat: str = "nothing"
    aux_coef: float = 0.01       # MoE load-balance weight
    weight_decay: float = 0.1


def lr_schedule(tc: TrainConfig, step: Array) -> Array:
    """Linear warmup -> cosine decay to min_lr_frac."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(1.0, tc.warmup_steps)
    prog = jnp.clip(
        (s - tc.warmup_steps) / jnp.maximum(1.0, tc.total_steps - tc.warmup_steps),
        0.0, 1.0,
    )
    cos = tc.min_lr_frac + (1 - tc.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return tc.lr * jnp.minimum(warm, 1.0) * jnp.where(s < tc.warmup_steps, 1.0, cos)


def make_train_state(key: Array, cfg: ModelConfig, tc: TrainConfig) -> dict:
    opt = make_optimizer(
        tc.optimizer,
        **({"weight_decay": tc.weight_decay} if tc.optimizer == "adamw" else {}),
    )
    params = init_params(key, cfg)
    return {"params": params, "opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(cfg: ModelConfig, tc: TrainConfig) -> dict:
    return jax.eval_shape(lambda: make_train_state(jax.random.PRNGKey(0), cfg, tc))


def state_spec_tree(
    cfg: ModelConfig, tc: TrainConfig, state_shape: dict, mesh: Mesh
) -> dict:
    opt = make_optimizer(tc.optimizer)
    pspecs = param_spec_tree(cfg, state_shape["params"], mesh)
    return {
        "params": pspecs,
        "opt": opt.state_spec_tree(pspecs, state_shape["params"]),
        "step": P(),
    }


def _loss_fn(cfg: ModelConfig, tc: TrainConfig, params, batch) -> tuple[Array, dict]:
    logits, aux = forward(
        cfg, params, batch["tokens"], batch.get("patches"), remat=tc.remat
    )
    labels = batch["labels"]
    if cfg.input_mode == "tokens+patches":
        # patch positions carry no next-token target
        pmask = jnp.arange(labels.shape[1]) < cfg.num_patches
        labels = jnp.where(pmask[None, :], -1, labels)
    loss = lm_loss(cfg, logits, labels)
    total = loss + tc.aux_coef * aux
    return total, {"loss": loss, "aux": aux}


def _global_norm(tree) -> Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def make_train_step(cfg: ModelConfig, tc: TrainConfig) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""
    opt = make_optimizer(
        tc.optimizer,
        **({"weight_decay": tc.weight_decay} if tc.optimizer == "adamw" else {}),
    )

    def train_step(state: dict, batch: dict):
        params = state["params"]
        mu = tc.num_microbatches

        # Gradient buffer dtype: f32 for f32-param models; for bf16-param
        # models (the 70B+/400B configs) the accumulator + grads in f32 are
        # 2x the parameter memory — use bf16 buffers there (the standard
        # production trade; Adafactor's update math still runs in f32
        # per-leaf).  Scale-by-µ *before* summing to keep bf16 headroom.
        acc_dt = (jnp.float32 if cfg.param_dtype == "float32"
                  else jnp.dtype(cfg.param_dtype))

        if mu == 1:
            (_, metrics), grads = jax.value_and_grad(
                lambda p: _loss_fn(cfg, tc, p, batch), has_aux=True
            )(params)
        else:
            def slice_mb(x, i):
                b = x.shape[0] // mu
                return jax.lax.dynamic_slice_in_dim(x, i * b, b, axis=0)

            def mb_step(carry, i):
                acc, metrics_acc = carry
                mb = jax.tree.map(lambda x: slice_mb(x, i), batch)
                (_, m), g = jax.value_and_grad(
                    lambda p: _loss_fn(cfg, tc, p, mb), has_aux=True
                )(params)
                acc = jax.tree.map(
                    lambda a, gg: a + (gg.astype(jnp.float32) / mu).astype(acc_dt),
                    acc, g,
                )
                metrics_acc = jax.tree.map(lambda a, b_: a + b_, metrics_acc, m)
                return (acc, metrics_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params
            )
            m0 = {"loss": jnp.zeros(()), "aux": jnp.zeros(())}
            (grads, msum), _ = jax.lax.scan(
                mb_step, (zeros, m0), jnp.arange(mu)
            )
            metrics = jax.tree.map(lambda x: x / mu, msum)

        gnorm = _global_norm(grads)
        scale = jnp.minimum(1.0, tc.grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale)
                             .astype(acc_dt), grads)

        lr = lr_schedule(tc, state["step"])
        new_params, new_opt = opt.update(
            grads, state["opt"], params, lr, state["step"]
        )
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return (
            {"params": new_params, "opt": new_opt, "step": state["step"] + 1},
            metrics,
        )

    return train_step


def shard_train_step(
    mesh: Mesh, cfg: ModelConfig, tc: TrainConfig, state_shape: dict
):
    """jit the train step with explicit in/out shardings for ``mesh``.

    Returns (jitted_fn, state_shardings, batch_shardings).
    """
    specs = state_spec_tree(cfg, tc, state_shape, mesh)
    state_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )

    def batch_sharding(leaf):
        return NamedSharding(mesh, data_spec(mesh, leaf.shape))

    train_step = make_train_step(cfg, tc)
    fn = jax.jit(
        train_step,
        in_shardings=(state_sh, None),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    )
    return fn, state_sh, batch_sharding
