"""Mesh-agnostic, atomic, optionally-async checkpointing.

Design for the 1000+-node case (adapted to this single-host container):

* **Logical addressing** — leaves are stored under their pytree *path*, and
  sharding is re-derived from the axis-name rules at restore time, never from
  device ids.  A checkpoint written on a (2,16,16) mesh restores onto (16,16),
  (4,8), or 1 device unchanged (tested by round-tripping across mesh shapes).
* **Atomicity** — writes go to ``<dir>/tmp.<step>`` and are renamed to
  ``step_<n>`` only after an fsync'd ``COMMIT`` marker is written; restore
  ignores directories without the marker, so a preemption mid-write can never
  corrupt the latest checkpoint.
* **Async** — ``save_async`` snapshots to host memory (device_get) on the
  caller's thread (cheap, overlapped with the next step's dispatch) and does
  file IO on a background thread.  ``wait()`` joins before the next save.
* **GC** — ``keep`` most recent checkpoints are retained.

On a real multi-host cluster the np.save calls would be replaced by
per-host shard writes (jax array serialization); the manifest/commit/restore
logic — the part this module owns — is identical.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

Array = jax.Array


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state) -> str:
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        return self._write(step, host_state)

    def save_async(self, step: int, state) -> None:
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self._thread = threading.Thread(
            target=self._write, args=(step, host_state), daemon=True
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state) -> str:
        names, leaves, _ = _flatten_with_names(host_state)
        tmp = os.path.join(self.dir, f"tmp.{step}")
        final = os.path.join(self.dir, f"step_{step:010d}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": []}
        arrays = {}
        for i, (name, leaf) in enumerate(zip(names, leaves)):
            arrays[f"a{i}"] = leaf
            manifest["leaves"].append(
                {"name": name, "key": f"a{i}",
                 "shape": list(leaf.shape), "dtype": str(leaf.dtype)}
            )
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write("ok")
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(
                os.path.join(self.dir, f"step_{s:010d}"), ignore_errors=True
            )

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in sorted(os.listdir(self.dir)):
            if d.startswith("step_") and os.path.exists(
                os.path.join(self.dir, d, "COMMIT")
            ):
                out.append(int(d[len("step_"):]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_shape, step: int | None = None, shardings=None):
        """Rebuild the state pytree.  ``state_shape`` provides structure and
        (optionally) target dtypes; ``shardings`` (same structure, or None)
        device_puts each leaf to its NamedSharding — this is the elastic
        restore path: any mesh whose axis names match the sharding rules."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = np.load(os.path.join(d, "arrays.npz"))
        by_name = {
            leaf["name"]: arrays[leaf["key"]] for leaf in manifest["leaves"]
        }
        names, ref_leaves, treedef = _flatten_with_names(state_shape)
        missing = [n for n in names if n not in by_name]
        if missing:
            raise KeyError(f"checkpoint {d} missing leaves: {missing[:5]}...")
        out_leaves = []
        sh_leaves = (
            jax.tree.leaves(shardings) if shardings is not None
            else [None] * len(names)
        )
        for name, ref, sh in zip(names, ref_leaves, sh_leaves):
            arr = by_name[name]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"{name}: checkpoint shape {arr.shape} != expected {ref.shape}"
                )
            arr = arr.astype(ref.dtype)
            out_leaves.append(
                jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr)
            )
        return treedef.unflatten(out_leaves), step
