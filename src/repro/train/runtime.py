"""Fault-tolerance runtime: preemption-safe training loop with periodic +
on-signal checkpointing, automatic restart from the latest commit, and
straggler mitigation for the host-side data path.

Scale story (what each piece maps to at 1000+ nodes):

* ``PreemptionGuard`` — SIGTERM/SIGINT handler that flips a flag; the loop
  checkpoints and exits cleanly at the next step boundary.  On TPU pods this
  is how maintenance preemptions are absorbed (the scheduler re-launches and
  ``run`` resumes from the latest commit).
* ``resume_or_init`` — idempotent start: restore the newest *committed*
  checkpoint if any (half-written ones are invisible by construction),
  otherwise initialize.  Works across mesh shapes (elastic restart).
* ``StragglerGuard`` — wraps the host data iterator with a deadline; a shard
  that misses it is *skipped* and the batch is re-drawn from the next shard
  (the distributed analogue: reassign the lagging host's file range).  Skips
  are counted and surfaced in metrics.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Iterator

import jax

from repro.train.checkpoint import Checkpointer


class PreemptionGuard:
    """Flips ``should_stop`` on SIGTERM/SIGINT.  Context manager restores the
    previous handlers."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.signals = signals
        self.should_stop = False
        self._prev = {}

    def _handler(self, signum, frame):
        self.should_stop = True

    def __enter__(self):
        for s in self.signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False


class StragglerGuard:
    """Deadline-enforcing wrapper around a data iterator.

    ``next_fn()`` must return the next batch for the *current* shard;
    ``skip_fn()`` advances to the next shard.  If ``next_fn`` exceeds
    ``deadline_s``, the batch is dropped and re-drawn after ``skip_fn``.
    """

    def __init__(self, next_fn: Callable, skip_fn: Callable,
                 deadline_s: float = 30.0, max_skips: int = 16):
        self.next_fn = next_fn
        self.skip_fn = skip_fn
        self.deadline_s = deadline_s
        self.max_skips = max_skips
        self.skipped = 0

    def __call__(self):
        for _ in range(self.max_skips):
            t0 = time.monotonic()
            batch = self.next_fn()
            if time.monotonic() - t0 <= self.deadline_s:
                return batch
            self.skipped += 1
            self.skip_fn()
        raise TimeoutError(
            f"data path missed the {self.deadline_s}s deadline "
            f"{self.max_skips} times in a row"
        )


def resume_or_init(
    ckpt: Checkpointer, state_shape, init_fn: Callable, shardings=None
):
    """Restore the latest committed checkpoint or build a fresh state."""
    if ckpt.latest_step() is not None:
        state, step = ckpt.restore(state_shape, shardings=shardings)
        return state, step, True
    return init_fn(), 0, False


@dataclasses.dataclass
class LoopReport:
    steps_done: int
    final_step: int
    preempted: bool
    straggler_skips: int
    metrics_history: list


def run(
    state,
    train_step: Callable,
    batches: Iterator | Callable,
    ckpt: Checkpointer,
    *,
    num_steps: int,
    start_step: int = 0,
    ckpt_every: int = 100,
    log_every: int = 10,
    log_fn: Callable = print,
    straggler: StragglerGuard | None = None,
) -> tuple[object, LoopReport]:
    """Preemption-safe training loop."""
    next_batch = (
        straggler if straggler is not None
        else (batches if callable(batches) else lambda it=iter(batches): next(it))
    )
    history = []
    done = 0
    preempted = False
    with PreemptionGuard() as guard:
        for step in range(start_step, num_steps):
            batch = next_batch()
            state, metrics = train_step(state, batch)
            done += 1
            if log_every and (step % log_every == 0 or step == num_steps - 1):
                m = {k: float(v) for k, v in metrics.items()}
                history.append({"step": step, **m})
                log_fn(
                    f"step {step:6d} loss {m.get('loss', float('nan')):.4f} "
                    f"lr {m.get('lr', 0):.2e} gnorm {m.get('grad_norm', 0):.3f}"
                )
            stop = guard.should_stop
            if ckpt_every and ((step + 1) % ckpt_every == 0 or stop):
                ckpt.save_async(step + 1, state)
            if stop:
                preempted = True
                break
    ckpt.wait()
    final = start_step + done
    if preempted or (ckpt_every and final % ckpt_every != 0):
        ckpt.save(final, state)
    return state, LoopReport(
        steps_done=done,
        final_step=final,
        preempted=preempted,
        straggler_skips=straggler.skipped if straggler else 0,
        metrics_history=history,
    )
