"""Mamba2 (SSD — state-space duality) mixer block, arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm: the sequence is split into
chunks of length Q; within a chunk the output is a masked quadratic
("attention-like") contraction, across chunks a single recurrent state of
shape (H, P, N) is carried by a scan.  This is the paper's own TPU/GPU-
friendly matmul formulation — O(S·Q) work with MXU-shaped einsums, O(S/Q)
sequential steps.

Decode keeps the (H, P, N) state and applies the exact recurrence
``h = a h + dt·x ⊗ B;  y = h C + D x`` per token — O(1) in context length,
which is what makes the ``long_500k`` cell runnable for this family.

Layout: d_inner = expand * d_model, H = d_inner / headdim heads, G B/C groups
(G=1 for mamba2-780m), state size N.  A short causal depthwise conv runs over
the (x, B, C) channels, as in the reference implementation.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init, dtype_of, rmsnorm
from repro.models.sharding import DATA, MODEL, POD, constrain

Array = jax.Array


def mamba2_init(key: Array, cfg) -> Params:
    dtype = dtype_of(cfg.param_dtype)
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * g * n
    ks = jax.random.split(key, 6)
    # A in [1, 16) as in the reference init; dt bias ~ softplus^-1(U[1e-3, 1e-1])
    a_init = jnp.exp(
        jax.random.uniform(ks[4], (h,), jnp.float32,
                           minval=math.log(1.0), maxval=math.log(16.0))
    )
    dt = jnp.exp(
        jax.random.uniform(ks[5], (h,), jnp.float32,
                           minval=math.log(1e-3), maxval=math.log(1e-1))
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "w_in_zx": dense_init(ks[0], d, 2 * di, dtype),
        "w_in_bc": dense_init(ks[1], d, 2 * g * n, dtype),
        "w_in_dt": dense_init(ks[2], d, h, dtype),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_width, conv_dim),
                                     jnp.float32) / math.sqrt(cfg.conv_width)
                   ).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(a_init),                      # (H,) f32
        "dt_bias": dt_bias,                            # (H,) f32
        "D": jnp.ones((h,), jnp.float32),              # skip connection
        "norm": jnp.ones((di,), dtype),                # gated RMSNorm scale
        "w_out": dense_init(jax.random.fold_in(ks[3], 1), di, d, dtype,
                            scale=1.0 / math.sqrt(di)),
    }


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over (B, S, C) with kernel (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W)
    )
    return out + b[None, None, :]


def _split_proj(p: Params, cfg, u: Array):
    """u (B, S, D) -> z, xbc(conved) pieces, dt.  All in compute dtype."""
    cdt = dtype_of(cfg.compute_dtype)
    di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    uc = u.astype(cdt)
    zx = uc @ p["w_in_zx"].astype(cdt)                  # (B, S, 2*di)
    z, x = jnp.split(zx, 2, axis=-1)
    bc = uc @ p["w_in_bc"].astype(cdt)                  # (B, S, 2*g*n)
    dt_raw = uc @ p["w_in_dt"].astype(cdt)              # (B, S, H)
    return z, x, bc, dt_raw


def _gated_out(p: Params, cfg, y: Array, z: Array) -> Array:
    """y, z (B, S, di) -> (B, S, D): gated RMSNorm then out projection."""
    cdt = dtype_of(cfg.compute_dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rmsnorm({"scale": p["norm"]}, y, 1e-6)
    return y.astype(cdt) @ p["w_out"].astype(cdt)


def mamba2_forward(p: Params, cfg, u: Array, return_cache: bool = False):
    """Chunked SSD over the full sequence.  u: (B, S, D) -> (B, S, D).

    With ``return_cache`` also returns the decode cache (final SSM state +
    conv tail), so prefill seeds subsequent O(1) decoding."""
    B, S, D = u.shape
    g, n, h, pd = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    di = cfg.d_inner
    # largest chunk <= cfg.ssm_chunk that divides S (exactness over speed for
    # odd test lengths; production shapes are powers of two)
    Q = min(cfg.ssm_chunk, S)
    while S % Q != 0:
        Q -= 1
    nc = S // Q

    z, x, bc, dt_raw = _split_proj(p, cfg, u)
    x = constrain(x, (POD, DATA), None, MODEL)        # d_inner over model
    dt_raw = constrain(dt_raw, (POD, DATA), None, MODEL)  # heads over model
    xbc_raw = jnp.concatenate([x, bc], axis=-1)
    xbc = jax.nn.silu(
        _causal_conv(xbc_raw, p["conv_w"].astype(xbc_raw.dtype),
                     p["conv_b"].astype(xbc_raw.dtype)).astype(jnp.float32)
    )
    x = xbc[..., :di]
    Bm = xbc[..., di : di + g * n].reshape(B, S, g, n)
    Cm = xbc[..., di + g * n :].reshape(B, S, g, n)

    # per-head decay: a_t = exp(-dt_t * A_h), dt = softplus(raw + bias)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = jnp.exp(p["A_log"])                                           # (H,)
    log_a = -dt * A                                                   # (B,S,H) <= 0

    # chunk views — scanned one chunk at a time so the quadratic intra-chunk
    # tensors are O(B·Q²·H), not O(B·S·Q·H)  (the memory hot spot; the Pallas
    # kernel target fuses this tile in VMEM, the XLA path scans it)
    xh = x.astype(jnp.float32).reshape(B, nc, Q, h, pd)
    Bc = Bm.astype(jnp.float32).reshape(B, nc, Q, g, n)
    Cc = Cm.astype(jnp.float32).reshape(B, nc, Q, g, n)
    dtc = dt.reshape(B, nc, Q, h)
    lac = log_a.reshape(B, nc, Q, h)

    rep = h // g  # heads per B/C group
    causal = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_step(h_prev, inp):
        xh_c, B_c, C_c, dt_c, la_c = inp       # (B,Q,H,P) (B,Q,G,N) ...
        cum = jnp.cumsum(la_c, axis=1)          # (B,Q,H)
        total = cum[:, -1]                      # (B,H)
        xdt = xh_c * dt_c[..., None]            # (B,Q,H,P)

        # intra-chunk: decay-masked quadratic term
        seg = cum[:, :, None, :] - cum[:, None, :, :]          # (B,Q,Q,H)
        decay = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
        scores = jnp.einsum("bqgn,bsgn->bqsg", C_c, B_c)       # (B,Q,Q,G)
        scores = jnp.repeat(scores, rep, axis=-1)              # -> H
        y_c = jnp.einsum("bqsh,bshp->bqhp", scores * decay, xdt)

        # inter-chunk: contribution of the carried state
        Ch = jnp.repeat(C_c, rep, axis=2)                      # (B,Q,H,N)
        y_c = y_c + jnp.einsum("bqhn,bhpn->bqhp", Ch, h_prev) \
            * jnp.exp(cum)[..., None]

        # carry update
        Bh = jnp.repeat(B_c, rep, axis=2)                      # (B,Q,H,N)
        decay_to_end = jnp.exp(total[:, None, :] - cum)        # (B,Q,H)
        st = jnp.einsum("bqhn,bqhp->bhpn",
                        Bh * decay_to_end[..., None], xdt)
        h_new = h_prev * jnp.exp(total)[:, :, None, None] + st
        return h_new, y_c

    h0 = jnp.zeros((B, h, pd, n), jnp.float32)
    to_scan = jax.tree.map(
        lambda a: jnp.moveaxis(a, 1, 0), (xh, Bc, Cc, dtc, lac)
    )
    # remat the chunk body: backward recomputes the O(Q²·H) intra-chunk
    # tensors per chunk instead of stashing them for all S/Q chunks
    h_last, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0, to_scan)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, h, pd)            # (B,S,H,P)
    y = y + xh.reshape(B, S, h, pd) * p["D"][None, None, :, None]
    y = y.reshape(B, S, di).astype(u.dtype)
    out = _gated_out(p, cfg, y, z)
    if not return_cache:
        return out
    W = cfg.conv_width
    conv_tail = xbc_raw[:, -(W - 1):, :] if S >= W - 1 else jnp.pad(
        xbc_raw, ((0, 0), (W - 1 - S, 0), (0, 0))
    )
    return out, {"conv": conv_tail.astype(dtype_of(cfg.compute_dtype)),
                 "ssm": h_last}


# ---------------------------------------------------------------------------
# Decode (recurrent) path
# ---------------------------------------------------------------------------

def mamba2_cache_init(cfg, batch: int) -> dict:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim),
                          dtype_of(cfg.compute_dtype)),
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
                         jnp.float32),
    }


def mamba2_decode(p: Params, cfg, u: Array, cache: dict) -> tuple[Array, dict]:
    """One token.  u: (B, 1, D).  Exact recurrence, O(1) in context."""
    B = u.shape[0]
    g, n, h, pd = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    di = cfg.d_inner

    z, x, bc, dt_raw = _split_proj(p, cfg, u)
    xbc = jnp.concatenate([x, bc], axis=-1)                    # (B, 1, conv_dim)
    window = jnp.concatenate([cache["conv"], xbc], axis=1)     # (B, W, conv_dim)
    conv_out = jnp.sum(
        window * p["conv_w"].astype(window.dtype)[None], axis=1
    ) + p["conv_b"].astype(window.dtype)                       # (B, conv_dim)
    xbc1 = jax.nn.silu(conv_out.astype(jnp.float32))
    new_conv = window[:, 1:, :]

    xt = xbc1[:, :di].reshape(B, h, pd)
    Bt = xbc1[:, di : di + g * n].reshape(B, g, n)
    Ct = xbc1[:, di + g * n :].reshape(B, g, n)
    rep = h // g
    Bt = jnp.repeat(Bt, rep, axis=1)                           # (B, H, N)
    Ct = jnp.repeat(Ct, rep, axis=1)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(-dt * jnp.exp(p["A_log"]))                     # (B, H)
    hs = cache["ssm"] * a[:, :, None, None] + jnp.einsum(
        "bhp,bhn->bhpn", xt * dt[..., None], Bt
    )
    y = jnp.einsum("bhpn,bhn->bhp", hs, Ct) + xt * p["D"][None, :, None]
    y = y.reshape(B, 1, di).astype(u.dtype)
    out = _gated_out(p, cfg, y, z)
    return out, {"conv": new_conv, "ssm": hs}
