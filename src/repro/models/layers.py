"""Shared neural building blocks: init helpers, RMSNorm, RoPE, embeddings,
SwiGLU FFN.  Pure functional JAX — params are plain nested dicts of arrays.

Dtype policy: parameters are stored in ``cfg.param_dtype``; matmuls run in
``cfg.compute_dtype`` (bf16 on TPU); normalization statistics, RoPE phases,
softmax and the final logits are computed in float32.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.sharding import DATA, MODEL, POD, constrain

Array = jax.Array
Params = dict


def dtype_of(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key: Array, d_in: int, d_out: int, dtype, scale: float | None = None) -> Array:
    """Variance-scaling (fan-in) normal init, the LLaMA/ Gemma default."""
    std = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def embed_init(key: Array, vocab: int, d: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 1.0).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: Array, eps: float) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * rms).astype(dt) * p["scale"].astype(dt)


def rmsnorm_headwise(scale: Array, x: Array, eps: float) -> Array:
    """qk-norm: normalize the trailing head_dim of (..., H, hd)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * rms).astype(dt) * scale.astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    """(head_dim/2,) inverse frequencies, float32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotate (..., S, H, hd) by per-position phases.  ``positions`` is (S,)
    or broadcastable (B, S).  Computed in f32, cast back."""
    dt = x.dtype
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                              # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv     # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # insert head axis: (..., S, 1, hd/2)
    cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# SwiGLU FFN
# ---------------------------------------------------------------------------

def ffn_init(key: Array, d: int, f: int, dtype, gated: bool = True) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(k2, d, f, dtype),
        "w_down": dense_init(k3, f, d, dtype, scale=1.0 / math.sqrt(f)),
    }
    if gated:
        p["w_gate"] = dense_init(k1, d, f, dtype)
    return p


def ffn(p: Params, x: Array, compute_dtype, act: str = "silu") -> Array:
    xc = x.astype(compute_dtype)
    a = jax.nn.silu if act == "silu" else jax.nn.gelu
    u = xc @ p["w_up"].astype(compute_dtype)
    if "w_gate" in p:
        g = xc @ p["w_gate"].astype(compute_dtype)
        return (a(g) * u) @ p["w_down"].astype(compute_dtype)
    return a(u) @ p["w_down"].astype(compute_dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embedding_init(key: Array, cfg) -> Params:
    dtype = dtype_of(cfg.param_dtype)
    keys = jax.random.split(key, cfg.num_codebooks + 1)
    p: Params = {
        "tok": jnp.stack(
            [embed_init(keys[i], cfg.vocab_size, cfg.d_model, dtype)
             for i in range(cfg.num_codebooks)]
        )  # (K, V, D)
    }
    if not cfg.tie_embeddings:
        p["unembed"] = jnp.stack(
            [dense_init(keys[-1], cfg.d_model, cfg.vocab_size, dtype)
             for _ in range(cfg.num_codebooks)]
        )  # (K, D, V)
    return p


def embed_tokens(p: Params, cfg, tokens: Array) -> Array:
    """tokens: (B, S) for K=1, (B, S, K) for codebooks.  Returns (B, S, D)."""
    cdt = dtype_of(cfg.compute_dtype)
    tok = p["tok"].astype(cdt)                    # (K, V, D)
    if cfg.num_codebooks == 1:
        t = tokens if tokens.ndim == 2 else tokens[..., 0]
        out = tok[0][t]
    else:
        # sum of codebook embeddings (musicgen-style parallel streams)
        out = sum(tok[k][tokens[..., k]] for k in range(cfg.num_codebooks))
    # activations: batch over pod x data, d_model replicated (TP happens
    # inside the mixers/FFNs)
    return constrain(out, (POD, DATA), None, None)


def unembed(p: Params, cfg, x: Array) -> Array:
    """x: (B, S, D) -> logits (B, S, V) or (B, S, K, V). float32."""
    xf = x.astype(dtype_of(cfg.compute_dtype))
    if cfg.tie_embeddings:
        w = p["tok"].astype(dtype_of(cfg.compute_dtype))       # (K, V, D)
        logits = jnp.einsum("bsd,kvd->bskv", xf, w)
    else:
        w = p["unembed"].astype(dtype_of(cfg.compute_dtype))   # (K, D, V)
        logits = jnp.einsum("bsd,kdv->bskv", xf, w)
    # vocab stays sharded over model, batch over pod x data — without this
    # pin GSPMD replicates the (B, S, V) logits (tens of GB at 128k vocab)
    logits = constrain(logits, (POD, DATA), None, None, MODEL)
    logits = logits.astype(jnp.float32)
    if cfg.num_codebooks == 1:
        return logits[..., 0, :]
    return logits
