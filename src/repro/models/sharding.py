"""Partition-spec rules: map every parameter / activation / cache leaf to a
``PartitionSpec`` over the (pod, data, model) production mesh.

Philosophy (DESIGN.md §6): 2-D sharding.  The ``model`` axis carries tensor
parallelism (attention heads, FFN hidden, experts, vocab); the ``data`` axis
carries FSDP (the other matrix dimension of every weight + the batch dimension
of every activation); the ``pod`` axis is pure data parallelism (weights
replicated across pods, batch split, gradients all-reduced — with optional
compression, see repro/train/compress.py).

Head counts that do not divide the 16-way model axis (llama4's 40 q-heads,
qwen2's 28, recurrentgemma's 10...) are legal: the model forward uses
jit/GSPMD sharding constraints, and GSPMD pads uneven dimensions internally.
kv-head axes smaller than the model axis are *replicated* instead (classic
MQA/GQA practice) by routing the rule through ``maybe_model``.

Rules key off leaf *names* (the param dicts use stable names exactly so this
table stays small).  Stacked group params get a leading ``None`` axis
automatically.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

Array = jax.Array

DATA, MODEL, POD = "data", "model", "pod"


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _spec_axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    size = 1
    for a in axes:
        size *= _axis_size(mesh, a)
    return size


def fit_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop spec axes whose mesh size does not divide the dimension —
    pjit arguments must shard evenly; the dropped dimension is replicated.
    Also drops axes not present in the mesh (single-pod vs multi-pod)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        if not axes:
            out.append(None)
            continue
        entry2 = axes if len(axes) > 1 else axes[0]
        if i < len(shape) and shape[i] % _spec_axis_size(mesh, entry2) == 0:
            out.append(entry2)
        else:
            out.append(None)
    return P(*out)


# name -> spec for the *unstacked* parameter
_PARAM_RULES: dict[str, P] = {
    # embeddings
    "tok": P(None, MODEL, DATA),          # (K, V, D)
    "unembed": P(None, DATA, MODEL),      # (K, D, V)
    # norms / small vectors — replicated
    "scale": P(),
    "q_norm": P(), "k_norm": P(),
    "A_log": P(), "dt_bias": P(), "D": P(), "norm": P(), "lam": P(),
    "gate_a_w": P(), "gate_a_b": P(), "gate_i_w": P(), "gate_i_b": P(),
    "conv_w": P(), "conv_b": P(),
    # attention
    "w_q": P(DATA, MODEL), "w_k": P(DATA, MODEL), "w_v": P(DATA, MODEL),
    "w_o": P(MODEL, DATA),
    "b_q": P(MODEL), "b_k": P(MODEL), "b_v": P(MODEL),
    # dense FFN / shared expert
    "w_gate": P(DATA, MODEL), "w_up": P(DATA, MODEL), "w_down": P(MODEL, DATA),
    # moe (expert banks are matched by name+rank below)
    "router": P(DATA, None),
    # mamba2
    "w_in_zx": P(DATA, MODEL), "w_in_bc": P(DATA, None),
    "w_in_dt": P(DATA, MODEL),
    "w_out": P(MODEL, DATA),
    # rglru
    "w_y": P(DATA, MODEL), "w_x": P(DATA, MODEL),
}

# expert banks: (E, D, F) / (E, F, D) — experts over MODEL, D over DATA
_EXPERT_RULES = {
    "w_gate": P(MODEL, DATA, None),
    "w_up": P(MODEL, DATA, None),
    "w_down": P(MODEL, None, DATA),
}


def param_spec_tree(cfg: ModelConfig, params_shape, mesh: Mesh) -> dict:
    """PartitionSpec pytree parallel to the params pytree.

    ``params_shape`` is the params pytree (arrays or ShapeDtypeStructs);
    specs are fitted to ``mesh`` (non-dividing dims fall back to replication
    — e.g. mamba2's 50280 vocab on a 16-way model axis).
    """

    def spec_for(path, leaf) -> P:
        keys = [p.key for p in path if hasattr(p, "key")]
        name = keys[-1]
        in_moe = "moe" in keys
        in_shared = "shared" in keys
        stacked = keys and keys[0] == "blocks"
        if in_moe and not in_shared and name in _EXPERT_RULES and leaf.ndim >= 3:
            spec = _EXPERT_RULES[name]
        elif name in _PARAM_RULES:
            spec = _PARAM_RULES[name]
        else:
            raise KeyError(f"no sharding rule for param {'/'.join(keys)}")
        expected = len(spec) + (1 if stacked else 0)
        if leaf.ndim != expected:
            # rank mismatch (e.g. scalar) -> replicate
            return P(*([None] * leaf.ndim))
        if stacked:
            spec = P(None, *spec)
        return fit_spec(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def batch_axes(mesh: Mesh) -> tuple:
    """The composite mesh axes that shard the global batch."""
    return (POD, DATA) if POD in mesh.axis_names else (DATA,)


def data_spec(mesh: Mesh, shape_or_ndim) -> P:
    """Spec for a (B, ...) data array: batch over pod x data.  Falls back to
    replication when B does not divide the batch axes (e.g. long_500k B=1)."""
    if isinstance(shape_or_ndim, int):
        return P(batch_axes(mesh), *([None] * (shape_or_ndim - 1)))
    shape = tuple(shape_or_ndim)
    spec = P(batch_axes(mesh), *([None] * (len(shape) - 1)))
    return fit_spec(spec, shape, mesh)


def cache_spec_tree(cfg: ModelConfig, cache_shape, mesh: Mesh) -> dict:
    """KV/SSM cache sharding: batch over (pod, data); the head axis over
    model when it divides, else head_dim over model (GQA kv-counts below 16
    would otherwise force replication of the dominant decode-memory term),
    else replicated."""
    baxes = batch_axes(mesh)
    msize = _axis_size(mesh, MODEL)

    def spec_for(path, leaf) -> P:
        keys = [p.key for p in path if hasattr(p, "key")]
        name = keys[-1]
        stacked = keys and keys[0] == "blocks"
        lead = (None,) if stacked else ()
        if name in ("k", "v"):      # (B, L, KV, hd)
            kv, hd = leaf.shape[-2], leaf.shape[-1]
            if kv % msize == 0:
                spec = (baxes, None, MODEL, None)
            elif hd % msize == 0:
                spec = (baxes, None, None, MODEL)
            else:
                spec = (baxes, None, None, None)
        elif name == "ssm":          # (B, H, P, N)
            spec = (baxes, MODEL, None, None)
        elif name == "conv":         # (B, W-1, C)
            spec = (baxes, None, None)
        elif name == "h":            # (B, dr)
            spec = (baxes, MODEL)
        else:
            raise KeyError(f"no cache rule for {'/'.join(keys)}")
        full = P(*lead, *spec)
        return fit_spec(full, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def usable_axes(mesh) -> set:
    """Mesh axes legal in a with_sharding_constraint here: present and not
    Manual (inside a shard_map body the manual axes are already bound)."""
    try:
        from jax.sharding import AxisType

        return {
            n for n, t in zip(mesh.axis_names, mesh.axis_types)
            if t != AxisType.Manual
        }
    except Exception:
        return set(mesh.axis_names)


def constrain(x, *entries):
    """``with_sharding_constraint`` against the ambient mesh, as a no-op when
    no mesh context is active (CPU unit tests) and with axes dropped when
    absent from the mesh, manual (bound by an enclosing shard_map), or
    non-dividing.  This is how the model code pins activation shardings
    (batch over pod x data, vocab/heads over model) without hard-coding a
    mesh."""
    from repro.compat import get_abstract_mesh

    mesh = get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    ok = usable_axes(mesh)
    cleaned = []
    for e in entries:
        if e is None:
            cleaned.append(None)
            continue
        axes = tuple(a for a in (e if isinstance(e, tuple) else (e,))
                     if a in ok)
        cleaned.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    spec = fit_spec(P(*cleaned), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, spec)


def batch_spec_entry():
    return (POD, DATA)
