"""Mixture-of-Experts FFN with top-k routing, written the TPU way.

Two paths, both fully static-shaped:

* **train/prefill** — capacity-bounded *slot dispatch*: within each batch row
  (the natural sharded group), every (token, k) pair gets a deterministic slot
  ``expert * C + position-within-expert`` computed with a cumsum; tokens are
  moved with one 1-D scatter + gather instead of the classic ``(T, E, C)``
  one-hot einsum, so dispatch memory is O(T·D) not O(T·E·C).  The expert
  matmuls are dense block-diagonal einsums over the (E, C, D) buffer — MXU
  food, sharded expert-parallel over the ``model`` axis.
* **decode** (S == 1) — tokens * experts is tiny but top-k is sparse, so the
  roofline cost is *reading the chosen expert weights*: we gather the K
  selected experts' matrices per token and apply them directly, which touches
  exactly the active parameters instead of all E.

Routing: top-1 with a sigmoid gate + always-on shared expert (llama4) or
top-k softmax-renormalized gates (olmoe).  A Switch-style load-balancing aux
loss is returned for training.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init, dtype_of, ffn, ffn_init
from repro.models.sharding import DATA, MODEL, POD, constrain

Array = jax.Array


def moe_init(key: Array, cfg) -> Params:
    dtype = dtype_of(cfg.param_dtype)
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.num_experts
    ks = jax.random.split(key, 5)
    std = 1.0 / math.sqrt(d)

    def bank(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)

    p: Params = {
        "router": dense_init(ks[0], d, e, dtype, scale=std),
        "w_gate": bank(ks[1], (e, d, f), std),
        "w_up": bank(ks[2], (e, d, f), std),
        "w_down": bank(ks[3], (e, f, d), 1.0 / math.sqrt(f)),
    }
    if cfg.shared_expert:
        p["shared"] = ffn_init(ks[4], d, f, dtype)
    return p


def _route(p: Params, cfg, xt: Array):
    """xt (T, D) -> (top_idx (T, K) int32, gates (T, K) f32, aux scalar)."""
    cdt = dtype_of(cfg.compute_dtype)
    E, K = cfg.num_experts, cfg.top_k
    logits = (xt.astype(cdt) @ p["router"].astype(cdt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    if K == 1:
        gate_vals, top_idx = jax.lax.top_k(logits, 1)
        gates = jax.nn.sigmoid(gate_vals)          # llama4 sigmoid gate
    else:
        gate_vals, top_idx = jax.lax.top_k(probs, K)
        gates = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
        )
    # Switch aux: E * sum_e mean(dispatch_e) * mean(prob_e)
    onehot = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)
    load = jnp.mean(jnp.sum(onehot, axis=1), axis=0)
    importance = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(load * importance)
    return top_idx.astype(jnp.int32), gates, aux


def _dispatch_group(xg: Array, eg: Array, E: int, K: int, C: int):
    """One group's slot assignment.  xg (S, D); eg (S, K) expert choices.

    Returns (buf (E*C, D) dispatch buffer, key (S*K,) slot index per (t, k),
    with dropped pairs pointing at the trash slot E*C)."""
    S, D = xg.shape
    TK = S * K
    flat_e = eg.reshape(-1)                                    # (S*K,)
    eo = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)            # (TK, E)
    # position of each (t, k) within its expert, in (t, k) order
    pos = jnp.take_along_axis(
        jnp.cumsum(eo, axis=0) - eo, flat_e[:, None], axis=1
    )[:, 0]
    keep = pos < C
    key = jnp.where(keep, flat_e * C + pos, E * C)             # trash = E*C
    # slot -> source token (TK = "zero row" for unfilled slots)
    slot_tok = jnp.full((E * C + 1,), TK, jnp.int32).at[key].set(
        jnp.arange(TK, dtype=jnp.int32)
    )
    xflat = xg[jnp.arange(TK) // K]                            # (TK, D)
    xpad = jnp.concatenate([xflat, jnp.zeros((1, D), xg.dtype)], axis=0)
    buf = xpad[slot_tok[: E * C]]                              # (E*C, D)
    return buf, key


def moe_forward(
    p: Params,
    cfg,
    x: Array,                 # (B, S, D)
    *,
    capacity_factor: float | None = None,
) -> tuple[Array, Array]:
    """Returns (output (B, S, D), aux load-balance loss scalar)."""
    cdt = dtype_of(cfg.compute_dtype)
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    if capacity_factor is None:
        capacity_factor = cfg.capacity_factor

    top_idx, gates, aux = _route(p, cfg, x.reshape(B * S, D))
    top_idx = top_idx.reshape(B, S, K)
    gates = gates.reshape(B, S, K)

    if S == 1:
        out = _decode_path(p, cfg, x, top_idx, gates)
    else:
        C = max(1, math.ceil(capacity_factor * K * S / E))
        buf, key = jax.vmap(
            lambda xg, eg: _dispatch_group(xg, eg, E, K, C)
        )(x.astype(cdt), top_idx)                              # (B, E*C, D), (B, S*K)
        buf = buf.reshape(B, E, C, D)
        # expert parallelism: E over model; batch over pod x data — the
        # reshard of buf is the all-to-all of the MoE dispatch
        buf = constrain(buf, (POD, DATA), MODEL, None, None)

        g = jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(cdt))
        u = jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(cdt))
        y = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u,
                       p["w_down"].astype(cdt))                # (B, E, C, D)
        y = constrain(y, (POD, DATA), MODEL, None, None)

        def combine_group(yg, keyg, gg):
            ypad = jnp.concatenate(
                [yg.reshape(E * C, D), jnp.zeros((1, D), yg.dtype)], axis=0
            )
            contrib = ypad[keyg] * gg.reshape(-1)[:, None].astype(yg.dtype)
            return contrib.reshape(S, K, D).sum(axis=1)        # (S, D)

        out = jax.vmap(combine_group)(y, key, gates)           # (B, S, D)

    if cfg.shared_expert:
        out = out + ffn(p["shared"], x, cdt, cfg.mlp_act)
    return out.astype(x.dtype), aux


def _decode_path(p: Params, cfg, x: Array, top_idx: Array, gates: Array) -> Array:
    """Decode-step MoE: gather the chosen experts' weights per token."""
    cdt = dtype_of(cfg.compute_dtype)
    B, S, D = x.shape           # S == 1
    xt = x.reshape(B, D).astype(cdt)
    idx = top_idx.reshape(B, -1)                               # (B, K)
    wg = p["w_gate"].astype(cdt)[idx]                          # (B, K, D, F)
    wu = p["w_up"].astype(cdt)[idx]
    wd = p["w_down"].astype(cdt)[idx]                          # (B, K, F, D)
    g = jnp.einsum("bd,bkdf->bkf", xt, wg)
    u = jnp.einsum("bd,bkdf->bkf", xt, wu)
    y = jnp.einsum("bkf,bkfd->bkd", jax.nn.silu(g) * u, wd)    # (B, K, D)
    out = jnp.sum(y * gates.reshape(B, -1, 1).astype(cdt), axis=1)
    return out.reshape(B, S, D)
