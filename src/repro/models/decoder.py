"""Decoder-only LM assembly for every assigned architecture.

Params layout (plain nested dicts):

    {"embed":  {...},
     "blocks": {"p0": <stacked over groups>, "p1": ..., ...},   # scanned
     "rem":    {"r0": ..., ...},                                # unrolled tail
     "final_norm": {...}}

``blocks.p<i>`` holds the i-th entry of ``cfg.block_pattern`` stacked over the
``cfg.num_groups`` pattern repetitions, so the forward pass is a
``lax.scan`` over groups — HLO size is O(len(pattern)), not O(num_layers),
which keeps 80-layer compiles cheap and is the right structure for 512-way
SPMD anyway.  Each group body is wrapped in ``jax.checkpoint`` (remat) with a
configurable policy.

Three entry points:
  - ``forward(cfg, params, tokens, ...)``        full-sequence (train/prefill)
  - ``init_cache(cfg, params, batch, max_len)``  decode cache pytree
  - ``decode_step(cfg, params, tokens, cache, cache_len)`` one-token decode
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.models.sharding import DATA, MODEL, POD, constrain
from repro.models.layers import (
    Params,
    dtype_of,
    embed_tokens,
    embedding_init,
    ffn,
    ffn_init,
    rmsnorm,
    rmsnorm_init,
    unembed,
)

Array = jax.Array


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _block_init(key: Array, cfg: ModelConfig, btype: str) -> Params:
    pdt = dtype_of(cfg.param_dtype)
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    p: Params = {"ln1": rmsnorm_init(d, pdt)}
    if btype in ("attn", "attn_moe", "local"):
        p["attn"] = attn.attention_init(k1, cfg)
        p["ln2"] = rmsnorm_init(d, pdt)
        if btype == "attn_moe":
            p["moe"] = moe_lib.moe_init(k2, cfg)
        else:
            p["ffn"] = ffn_init(k2, d, cfg.d_ff, pdt, gated=cfg.mlp_gated)
    elif btype == "mamba2":
        p["mixer"] = ssm_lib.mamba2_init(k1, cfg)
    elif btype == "rglru":
        p["mixer"] = rglru_lib.rglru_init(k1, cfg)
        p["ln2"] = rmsnorm_init(d, pdt)
        p["ffn"] = ffn_init(k2, d, cfg.d_ff, pdt, gated=cfg.mlp_gated)
    else:
        raise ValueError(btype)
    return p


def init_params(key: Array, cfg: ModelConfig) -> Params:
    pdt = dtype_of(cfg.param_dtype)
    k_embed, k_blocks, k_rem = jax.random.split(key, 3)
    params: Params = {"embed": embedding_init(k_embed, cfg)}

    blocks: Params = {}
    G = cfg.num_groups
    for i, btype in enumerate(cfg.block_pattern):
        keys = jax.random.split(jax.random.fold_in(k_blocks, i), G)
        blocks[f"p{i}"] = jax.vmap(
            lambda k, bt=btype: _block_init(k, cfg, bt)
        )(keys)
    params["blocks"] = blocks

    rem: Params = {}
    for i, btype in enumerate(cfg.remainder_blocks):
        rem[f"r{i}"] = _block_init(jax.random.fold_in(k_rem, i), cfg, btype)
    params["rem"] = rem

    params["final_norm"] = rmsnorm_init(cfg.d_model, pdt)
    return params


def abstract_params(cfg: ModelConfig) -> Params:
    """ShapeDtypeStruct pytree of the params — no allocation (dry-run)."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _block_apply(
    cfg: ModelConfig, btype: str, p: Params, x: Array, positions: Array
) -> tuple[Array, Array]:
    """One block.  Returns (x, aux_loss)."""
    cdt = dtype_of(cfg.compute_dtype)
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if btype in ("attn", "attn_moe", "local"):
        window = cfg.local_window if btype == "local" else 0
        x = x + attn.attention_forward(p["attn"], cfg, h, positions, window=window)
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if btype == "attn_moe":
            y, aux = moe_lib.moe_forward(p["moe"], cfg, h2)
        else:
            y = ffn(p["ffn"], h2, cdt, cfg.mlp_act)
        x = x + y
    elif btype == "mamba2":
        x = x + ssm_lib.mamba2_forward(p["mixer"], cfg, h)
    elif btype == "rglru":
        x = x + rglru_lib.rglru_forward(p["mixer"], cfg, h)
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + ffn(p["ffn"], h2, cdt, cfg.mlp_act)
    return x, aux


_REMAT_POLICIES = {
    "none": None,
    "nothing": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


def forward(
    cfg: ModelConfig,
    params: Params,
    tokens: Array,                 # (B, S) or (B, S, K) codebooks
    patches: Array | None = None,  # (B, P, D) for tokens+patches mode
    *,
    remat: str = "nothing",
    logits_slice: int = 0,         # >0: only last N positions get logits
) -> tuple[Array, Array]:
    """Full-sequence forward.  Returns (logits, aux_loss)."""
    cdt = dtype_of(cfg.compute_dtype)
    x = embed_tokens(params["embed"], cfg, tokens)
    if cfg.input_mode == "tokens+patches":
        assert patches is not None
        P = patches.shape[1]
        x = jnp.concatenate([patches.astype(cdt), x[:, P:]], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)

    def group_body(carry, group_params):
        x, aux = carry
        x = constrain(x, (POD, DATA), None, None)
        for i, btype in enumerate(cfg.block_pattern):
            x, a = _block_apply(cfg, btype, group_params[f"p{i}"], x, positions)
            aux = aux + a
        return (x, aux), None

    carry0 = (x, jnp.zeros((), jnp.float32))
    if remat == "nested" and cfg.num_groups >= 4:
        # Two-level checkpointing: scan over ~sqrt(G) outer chunks, each an
        # inner remat'd scan over G/chunks groups.  The residual stash drops
        # from G layer-inputs to (chunks + G/chunks): internvl2's 80 saved
        # carries (10.7 GB — XLA stores them f32) become 8 + 10.  Cost: one
        # extra forward of the inner chunk during backward (~ +30% FLOPs).
        G = cfg.num_groups
        outer = max(2, int(math.sqrt(G)))
        while G % outer != 0:
            outer -= 1
        inner = G // outer
        nested_params = jax.tree.map(
            lambda a: a.reshape((outer, inner) + a.shape[1:]),
            params["blocks"],
        )
        inner_body = jax.checkpoint(
            group_body, policy=_REMAT_POLICIES["nothing"]
        )

        def outer_body(carry, chunk_params):
            out, _ = jax.lax.scan(inner_body, carry, chunk_params,
                                  length=inner)
            return out, None

        outer_body = jax.checkpoint(
            outer_body, policy=_REMAT_POLICIES["nothing"]
        )
        (x, aux), _ = jax.lax.scan(outer_body, carry0, nested_params,
                                   length=outer)
    else:
        policy = _REMAT_POLICIES[remat if remat != "nested" else "nothing"]
        if policy is not None:
            group_body = jax.checkpoint(group_body, policy=policy)
        elif remat != "none":
            raise ValueError(remat)
        (x, aux), _ = jax.lax.scan(
            group_body, carry0, params["blocks"], length=cfg.num_groups
        )
    for i, btype in enumerate(cfg.remainder_blocks):
        x, a = _block_apply(cfg, btype, params["rem"][f"r{i}"], x, positions)
        aux = aux + a

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if logits_slice > 0:
        x = x[:, -logits_slice:]
    logits = unembed(params["embed"], cfg, x)
    return logits, aux


def lm_loss(
    cfg: ModelConfig,
    logits: Array,       # (B, S, V) or (B, S, K, V)
    labels: Array,       # (B, S) or (B, S, K) int32; negatives are masked
) -> Array:
    """Mean next-token cross entropy over unmasked positions (f32).

    Written without gathers on the vocab axis (``take_along_axis`` forces
    GSPMD to replicate the (B, S, V) logits across the model axis — a 30+ GB
    regression on the 128k-vocab configs).  logsumexp and the one-hot
    contraction are plain reductions over V, so vocab sharding survives."""
    valid = labels >= 0
    labels_safe = jnp.maximum(labels, 0)
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    onehot = (
        labels_safe[..., None] == jnp.arange(lf.shape[-1])[None, ...]
    )
    label_logit = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    nll = lse - label_logit
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)


# ---------------------------------------------------------------------------
# prefill (forward + populated decode cache)
# ---------------------------------------------------------------------------

def _block_prefill(
    cfg: ModelConfig, btype: str, p: Params, x: Array, positions: Array,
    max_len: int,
):
    cdt = dtype_of(cfg.compute_dtype)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if btype in ("attn", "attn_moe", "local"):
        window = cfg.local_window if btype == "local" else 0
        y, cache = attn.attention_prefill(
            p["attn"], cfg, h, positions, max_len, window=window
        )
        x = x + y
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if btype == "attn_moe":
            y2, _ = moe_lib.moe_forward(p["moe"], cfg, h2)
        else:
            y2 = ffn(p["ffn"], h2, cdt, cfg.mlp_act)
        x = x + y2
    elif btype == "mamba2":
        y, cache = ssm_lib.mamba2_forward(p["mixer"], cfg, h, return_cache=True)
        x = x + y
    elif btype == "rglru":
        y, cache = rglru_lib.rglru_forward(p["mixer"], cfg, h, return_cache=True)
        x = x + y
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + ffn(p["ffn"], h2, cdt, cfg.mlp_act)
    else:
        raise ValueError(btype)
    return x, cache


def prefill(
    cfg: ModelConfig,
    params: Params,
    tokens: Array,
    patches: Array | None = None,
    *,
    max_len: int,
) -> tuple[Array, Params]:
    """Full-sequence forward that also populates the decode cache.

    Returns (last-position logits (B, 1, V...), cache).  This is the step the
    ``prefill_32k`` cells lower."""
    cdt = dtype_of(cfg.compute_dtype)
    x = embed_tokens(params["embed"], cfg, tokens)
    if cfg.input_mode == "tokens+patches":
        assert patches is not None
        P = patches.shape[1]
        x = jnp.concatenate([patches.astype(cdt), x[:, P:]], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)

    def group_body(x, group_params):
        caches = {}
        x = constrain(x, (POD, DATA), None, None)
        for i, btype in enumerate(cfg.block_pattern):
            x, c = _block_prefill(
                cfg, btype, group_params[f"p{i}"], x, positions, max_len
            )
            caches[f"p{i}"] = c
        return x, caches

    x, block_caches = jax.lax.scan(
        group_body, x, params["blocks"], length=cfg.num_groups
    )
    rem_caches = {}
    for i, btype in enumerate(cfg.remainder_blocks):
        x, c = _block_prefill(
            cfg, btype, params["rem"][f"r{i}"], x, positions, max_len
        )
        rem_caches[f"r{i}"] = c

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], cfg, x[:, -1:])
    return logits, {"blocks": block_caches, "rem": rem_caches}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _block_cache_init(cfg: ModelConfig, btype: str, batch: int, max_len: int):
    if btype in ("attn", "attn_moe"):
        return attn.kv_cache_init(cfg, batch, max_len)
    if btype == "local":
        return attn.kv_cache_init(cfg, batch, max_len, window=cfg.local_window)
    if btype == "mamba2":
        return ssm_lib.mamba2_cache_init(cfg, batch)
    if btype == "rglru":
        return rglru_lib.rglru_cache_init(cfg, batch)
    raise ValueError(btype)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    """Decode cache pytree, stacked over groups like the params."""
    cache: Params = {"blocks": {}, "rem": {}}
    G = cfg.num_groups
    for i, btype in enumerate(cfg.block_pattern):
        one = _block_cache_init(cfg, btype, batch, max_len)
        cache["blocks"][f"p{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (G,) + a.shape).copy(), one
        )
    for i, btype in enumerate(cfg.remainder_blocks):
        cache["rem"][f"r{i}"] = _block_cache_init(cfg, btype, batch, max_len)
    return cache


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def _block_decode(
    cfg: ModelConfig, btype: str, p: Params, x: Array, cache, cache_len: Array,
    pos: Array | None = None,
):
    aux_window = cfg.local_window if btype == "local" else 0
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    cdt = dtype_of(cfg.compute_dtype)
    if btype in ("attn", "attn_moe", "local"):
        y, cache = attn.attention_decode(
            p["attn"], cfg, h, cache, cache_len, window=aux_window, pos=pos
        )
        x = x + y
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if btype == "attn_moe":
            y2, _ = moe_lib.moe_forward(p["moe"], cfg, h2)
        else:
            y2 = ffn(p["ffn"], h2, cdt, cfg.mlp_act)
        x = x + y2
    elif btype == "mamba2":
        y, cache = ssm_lib.mamba2_decode(p["mixer"], cfg, h, cache)
        x = x + y
    elif btype == "rglru":
        y, cache = rglru_lib.rglru_decode(p["mixer"], cfg, h, cache)
        x = x + y
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + ffn(p["ffn"], h2, cdt, cfg.mlp_act)
    return x, cache


def decode_step(
    cfg: ModelConfig,
    params: Params,
    tokens: Array,      # (B, 1) or (B, 1, K)
    cache: Params,
    cache_len: Array,   # scalar int32
    pos: Array | None = None,  # true sequence position (after KV pruning)
) -> tuple[Array, Params]:
    """One-token decode.  Returns (logits (B, 1, V...), updated cache)."""
    x = embed_tokens(params["embed"], cfg, tokens)

    def group_body(x, scanned):
        group_params, group_cache = scanned
        # barrier: stops the CPU backend hoisting the bf16->f32 dot-operand
        # conversion of the *entire stacked* KV cache out of the layer loop
        # (12.8 GB of f32 temps on musicgen decode_32k; TPU MXUs take bf16
        # operands natively, so the conversion does not exist there at all)
        group_cache = jax.lax.optimization_barrier(group_cache)
        new_caches = {}
        for i, btype in enumerate(cfg.block_pattern):
            x, c = _block_decode(
                cfg, btype, group_params[f"p{i}"], x,
                group_cache[f"p{i}"], cache_len, pos,
            )
            new_caches[f"p{i}"] = c
        return x, new_caches

    x, new_block_cache = jax.lax.scan(
        group_body, x, (params["blocks"], cache["blocks"]),
        length=cfg.num_groups,
    )
    new_rem = {}
    for i, btype in enumerate(cfg.remainder_blocks):
        x, c = _block_decode(
            cfg, btype, params["rem"][f"r{i}"], x, cache["rem"][f"r{i}"],
            cache_len, pos,
        )
        new_rem[f"r{i}"] = c

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], cfg, x)
    return logits, {"blocks": new_block_cache, "rem": new_rem}
