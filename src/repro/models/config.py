"""Model configuration for the assigned architectures.

One frozen dataclass describes every architecture in the pool: dense GQA
transformers, MoE transformers, Mamba2 (SSD) stacks, and the RG-LRU/local-
attention hybrid.  A model is a repeating ``block_pattern`` of typed blocks:

  - ``attn``   : full causal GQA attention  + dense SwiGLU FFN
  - ``attn_moe``: full causal GQA attention + MoE FFN (top-k routing)
  - ``local``  : sliding-window causal attention + dense FFN
  - ``rglru``  : RG-LRU recurrent mixer (Griffin) + dense FFN
  - ``mamba2`` : Mamba2 SSD mixer, no separate FFN

``num_layers`` need not be a multiple of ``len(block_pattern)``: the decoder
scans over the full pattern groups and unrolls the remainder (e.g.
recurrentgemma's 26 = 8 x (rglru, rglru, local) + (rglru, rglru)).

Input modes (modality frontends are stubs per the assignment):
  - ``tokens``        : ordinary token ids (B, S)
  - ``codebooks``     : K parallel EnCodec token streams (B, S, K); the
                        embedding is the sum of K codebook embeddings and the
                        output is K parallel vocab heads (musicgen).
  - ``tokens+patches``: token ids (B, S) plus precomputed ViT patch embeddings
                        (B, num_patches, d_model) that replace (early-fusion)
                        the first ``num_patches`` token positions (internvl2).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

BlockType = Literal["attn", "attn_moe", "local", "rglru", "mamba2"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    block_pattern: tuple[BlockType, ...] = ("attn",)

    # -- attention ----------------------------------------------------------
    rope_theta: float = 10000.0
    qk_norm: bool = False          # per-head RMSNorm on q and k (qwen3)
    qkv_bias: bool = False         # bias on q/k/v projections (qwen2)
    local_window: int = 2048       # window for ``local`` blocks
    attn_logit_softcap: float = 0.0  # 0 = off

    # -- FFN ------------------------------------------------------------------
    mlp_gated: bool = True         # SwiGLU/GeGLU (False: classic 2-matrix MLP)
    mlp_act: str = "silu"          # silu | gelu

    # -- MoE ------------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0           # expert hidden width (may differ from d_ff)
    shared_expert: bool = False    # llama4-style always-on shared expert
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01
    capacity_factor: float = 1.25

    # -- Mamba2 (SSD) ---------------------------------------------------------
    ssm_state: int = 128
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 256           # SSD chunk length for training
    conv_width: int = 4

    # -- RG-LRU ---------------------------------------------------------------
    rnn_width: int = 0             # 0 -> d_model

    # -- io / modality --------------------------------------------------------
    input_mode: str = "tokens"     # tokens | codebooks | tokens+patches
    num_codebooks: int = 1
    num_patches: int = 0
    tie_embeddings: bool = True

    # -- numerics -------------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-6

    # ------------------------------------------------------------------------
    def __post_init__(self):
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, "GQA grouping"
        for b in self.block_pattern:
            assert b in ("attn", "attn_moe", "local", "rglru", "mamba2"), b
        if "attn_moe" in self.block_pattern:
            assert self.num_experts > 0 and self.top_k > 0

    # -- derived sizes --------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        assert self.d_inner % self.ssm_headdim == 0
        return self.d_inner // self.ssm_headdim

    @property
    def d_rnn(self) -> int:
        return self.rnn_width or self.d_model

    @property
    def num_groups(self) -> int:
        """Full repetitions of the block pattern (scanned)."""
        return self.num_layers // len(self.block_pattern)

    @property
    def remainder_blocks(self) -> tuple[BlockType, ...]:
        """Trailing blocks that do not fill a whole pattern (unrolled)."""
        rem = self.num_layers % len(self.block_pattern)
        return self.block_pattern[:rem]

    @property
    def layer_types(self) -> tuple[BlockType, ...]:
        return self.block_pattern * self.num_groups + self.remainder_blocks

    def block_params_m(self, block: BlockType) -> float:
        """Approximate parameter count (in millions) of one block."""
        d = self.d_model
        attn = d * self.q_dim * 2 + d * self.kv_dim * 2
        ffn = (3 if self.mlp_gated else 2) * d * self.d_ff
        if block == "attn":
            return (attn + ffn) / 1e6
        if block == "local":
            return (attn + ffn) / 1e6
        if block == "attn_moe":
            e = 3 * d * self.d_ff_expert
            total = attn + self.num_experts * e + d * self.num_experts
            if self.shared_expert:
                total += e
            return total / 1e6
        if block == "mamba2":
            di, g, n, h = self.d_inner, self.ssm_groups, self.ssm_state, self.ssm_heads
            return (d * 2 * di + d * 2 * g * n + d * h + di * d) / 1e6
        if block == "rglru":
            dr = self.d_rnn
            return (d * dr * 2 + dr * d + 3 * dr + ffn) / 1e6
        raise ValueError(block)

    def param_count(self) -> int:
        """Total parameters (embeddings + blocks + final norm)."""
        total = self.vocab_size * self.d_model * self.num_codebooks  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * self.d_model * self.num_codebooks
        for b in self.layer_types:
            total += int(self.block_params_m(b) * 1e6)
        total += self.d_model  # final norm
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared only)."""
        if self.num_experts == 0:
            return self.param_count()
        total = self.param_count()
        for b in self.layer_types:
            if b == "attn_moe":
                unused = (self.num_experts - self.top_k) * 3 * self.d_model * self.d_ff_expert
                total -= unused
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell: what gets lowered in the dry-run."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def is_subquadratic(cfg: ModelConfig) -> bool:
    """True if every mixer is O(S) in context length (SSM / RG-LRU / local)."""
    return all(b in ("mamba2", "rglru", "local") for b in cfg.block_pattern)


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable dry-run cell, and why not if not.

    Per the assignment: ``long_500k`` needs sub-quadratic context handling —
    run it for SSM/hybrid archs, skip (and document) for pure full-attention.
    """
    if shape.name == "long_500k" and not is_subquadratic(cfg):
        return False, (
            "skip: 524288-token dense KV decode is the quadratic-attention "
            "failure case; arch has full-attention blocks"
        )
    return True, ""
