"""GQA attention: full-causal and sliding-window, with a blockwise
(flash-style, online-softmax) formulation so the S x S score matrix is never
materialized — required for the 32k prefill cells and the right structure for
TPU (VMEM-sized working sets; XLA fuses each block's QK^T / softmax / PV).

Supports: RoPE, qk-norm (qwen3), QKV bias (qwen2), GQA with any
heads/kv-heads ratio, logit soft-capping, decode with a static KV cache.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.layers import (
    Params,
    apply_rope,
    dense_init,
    dtype_of,
    rmsnorm_headwise,
)
from repro.models.sharding import DATA, MODEL, POD, constrain

Array = jax.Array


def _constrain_heads(x: Array) -> Array:
    """(B, S, H, hd): heads over model — uneven head counts are legal for
    constraints (GSPMD pads, e.g. llama4's 40 heads -> 3/device on 16) and
    strictly better than sharding head_dim, which puts the QK/PV contraction
    dimension on the model axis and forces an all-reduce of every score block
    (measured: 16.5 TB/chip of collective traffic on llama4 prefill_32k)."""
    from repro.compat import get_abstract_mesh
    from repro.models.sharding import usable_axes

    mesh = get_abstract_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return x
    ok = usable_axes(mesh)
    if MODEL not in ok:
        return x
    from jax.sharding import PartitionSpec as P

    batch = tuple(a for a in (POD, DATA) if a in ok)
    if not batch or x.shape[0] % _prod(mesh.shape[a] for a in batch):
        batch_entry = None
    else:
        batch_entry = batch if len(batch) > 1 else batch[0]
    # deliberately NOT fit_spec'd: uneven H sharding is the point
    return jax.lax.with_sharding_constraint(
        x, P(batch_entry, None, MODEL, None)
    )


def _prod(it):
    out = 1
    for v in it:
        out *= v
    return out

NEG_INF = -1e30  # finite: avoids NaN from all-masked softmax rows


def attention_init(key: Array, cfg) -> Params:
    dtype = dtype_of(cfg.param_dtype)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 5)
    p: Params = {
        "w_q": dense_init(ks[0], d, qd, dtype),
        "w_k": dense_init(ks[1], d, kvd, dtype),
        "w_v": dense_init(ks[2], d, kvd, dtype),
        "w_o": dense_init(ks[3], qd, d, dtype, scale=1.0 / math.sqrt(qd)),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((qd,), dtype)
        p["b_k"] = jnp.zeros((kvd,), dtype)
        p["b_v"] = jnp.zeros((kvd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), dtype)
        p["k_norm"] = jnp.ones((cfg.head_dim,), dtype)
    return p


def _project_qkv(p: Params, cfg, x: Array, positions: Array):
    """x (B, S, D) -> q (B, S, H, hd), k/v (B, S, KV, hd), roped + normed."""
    cdt = dtype_of(cfg.compute_dtype)
    B, S, _ = x.shape
    xc = x.astype(cdt)
    q = xc @ p["w_q"].astype(cdt)
    k = xc @ p["w_k"].astype(cdt)
    v = xc @ p["w_v"].astype(cdt)
    if cfg.qkv_bias:
        q = q + p["b_q"].astype(cdt)
        k = k + p["b_k"].astype(cdt)
        v = v + p["b_v"].astype(cdt)
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm_headwise(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_headwise(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # q: heads over model (padded when uneven).  k/v: REPLICATED across model
    # — kv_dim is ~1 KB/token, and sharding so few heads makes GSPMD permute
    # kv shards on every block step of the attention loop (measured 2.5 TB/
    # chip on llama4 prefill); replication turns the GQA head expansion into
    # a local slice.
    return (
        _constrain_heads(q),
        constrain(k, (POD, DATA), None, None, None),
        constrain(v, (POD, DATA), None, None, None),
    )


def _softcap(logits: Array, cap: float) -> Array:
    if cap <= 0.0:
        return logits
    return cap * jnp.tanh(logits / cap)


# ---------------------------------------------------------------------------
# Blockwise causal attention (training / prefill)
# ---------------------------------------------------------------------------

def blockwise_attention(
    q: Array,            # (B, S, H, hd)
    k: Array,            # (B, S, KV, hd)
    v: Array,            # (B, S, KV, hd)
    *,
    causal: bool = True,
    window: int = 0,     # 0 = unbounded; else sliding window (causal)
    block_q: int = 512,
    block_k: int = 1024,
    softcap: float = 0.0,
) -> Array:
    """Online-softmax attention over (q-block x k-block) tiles.

    Memory: O(B * H * block_q * block_k) live scores instead of O(S^2).

    GQA layout note: k/v are *expanded* to the full H heads per k-block (a
    fused broadcast, ~bk*H*hd per block) instead of computing on a split
    (KV, G) head layout.  Every tensor then carries one uniform H axis that
    shards over the model mesh axis — evenly or with GSPMD padding (llama4's
    40 heads) — whereas the (KV, G) form either breaks the sharding on the
    reshape or (worse) puts the contraction on head_dim and all-reduces every
    score block.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)

    bq = min(block_q, S)
    bk = min(block_k, S)
    # pad S to a multiple of both blocks
    Sq = -(-S // bq) * bq
    Sk = -(-S // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, Sq - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Sk - S), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Sk - S), (0, 0), (0, 0)))

    # GQA expansion as a static gather (head -> kv-head map), ONCE per call:
    # with k/v replicated across model each device materializes only its own
    # H-shard of the expanded keys/values (`_constrain_heads` pins that), so
    # the expansion is local, collective-free, and amortized over all
    # (q-block x k-block) steps.  broadcast+reshape instead creates a
    # (KV, G)-structured temp GSPMD cannot re-tile without permutes, and
    # per-block expansion re-reads the kv heads nq*nk times.
    head_map = jnp.arange(H) // G
    kx = _constrain_heads(jnp.take(kp, head_map, axis=2))     # (B, Sk, H, hd)
    vx = _constrain_heads(jnp.take(vp, head_map, axis=2))

    nq, nk = Sq // bq, Sk // bk
    # Head-major (B, H, blocks, blk, hd) layout, transposed ONCE: the block
    # einsums then consume operands in their native layout — the per-block
    # transpose_copy fusions this removes were ~half the attention HBM
    # traffic (measured 2.0e13 B/chip on llama4 prefill_32k).
    qb = qp.reshape(B, nq, bq, H, hd).transpose(0, 3, 1, 2, 4)
    kb = kx.reshape(B, nk, bk, H, hd).transpose(0, 3, 1, 2, 4)
    vb = vx.reshape(B, nk, bk, H, hd).transpose(0, 3, 1, 2, 4)

    q_pos = jnp.arange(Sq).reshape(nq, bq)
    k_pos = jnp.arange(Sk).reshape(nk, bk)
    k_valid = k_pos < S                                       # (nk, bk)

    def q_block(i, qi):
        # qi: (B, H, bq, hd)
        def k_step(carry, j):
            acc, m, lse = carry
            kj, vj = kb[:, :, j], vb[:, :, j]                 # (B, H, bk, hd)
            s = jnp.einsum(
                "bhqd,bhsd->bhqs", qi, kj,
                preferred_element_type=jnp.float32,
            ) * scale                                         # (B, H, bq, bk)
            s = _softcap(s, softcap)
            mask = k_valid[j][None, None, None, :]
            if causal:
                dq = q_pos[i][:, None] - k_pos[j][None, :]    # (bq, bk)
                cm = dq >= 0
                if window > 0:
                    cm = cm & (dq < window)
                mask = mask & cm[None, None, :, :]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))       # (B, H, bq)
            p_ = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            lse_new = lse * alpha + jnp.sum(p_, axis=-1)
            pv = jnp.einsum(
                "bhqs,bhsd->bhqd", p_.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * alpha[..., None] + pv
            return (acc_new, m_new, lse_new), None

        acc0 = jnp.zeros((B, H, bq, hd), jnp.float32)
        m0 = jnp.full((B, H, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, bq), jnp.float32)
        # remat the k-step: the backward recomputes the (bq, bk) score tiles
        # instead of stashing the full S×S attention matrix (flash-attention
        # memory behaviour, expressed as scan + checkpoint)
        (acc, m, lse), _ = jax.lax.scan(
            jax.checkpoint(k_step), (acc0, m0, l0), jnp.arange(nk)
        )
        out = acc / jnp.maximum(lse[..., None], 1e-30)
        return out  # (B, H, bq, hd)

    outs = jax.lax.map(lambda i: q_block(i, qb[:, :, i]), jnp.arange(nq))
    # (nq, B, H, bq, hd) -> (B, S, H, hd)
    out = (
        jnp.moveaxis(outs, 0, 1)           # (B, nq, H, bq, hd)
        .transpose(0, 1, 3, 2, 4)          # (B, nq, bq, H, hd)
        .reshape(B, Sq, H, hd)[:, :S]
    )
    return out.astype(q.dtype)


def attention_forward(
    p: Params, cfg, x: Array, positions: Array, *, window: int = 0
) -> Array:
    """Full training/prefill attention sublayer (no cache). x: (B, S, D)."""
    cdt = dtype_of(cfg.compute_dtype)
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    out = blockwise_attention(
        q, k, v, causal=True, window=window, softcap=cfg.attn_logit_softcap
    )
    out = out.reshape(B, S, cfg.q_dim)
    return out @ p["w_o"].astype(cdt)


def attention_prefill(
    p: Params, cfg, x: Array, positions: Array, max_len: int, *, window: int = 0
) -> tuple[Array, dict]:
    """Prefill: full attention over (B, S, D) AND the populated KV cache.

    Full attention caches all S positions padded to ``max_len``; local
    attention caches only the trailing ``window`` positions as a ring buffer
    laid out exactly as ``attention_decode`` expects (slot = pos % window).
    """
    cdt = dtype_of(cfg.compute_dtype)
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    out = blockwise_attention(
        q, k, v, causal=True, window=window, softcap=cfg.attn_logit_softcap
    )
    out = out.reshape(B, S, cfg.q_dim) @ p["w_o"].astype(cdt)

    if window > 0:
        L = min(window, max_len)
        if S >= L:
            tail_k, tail_v = k[:, -L:], v[:, -L:]
            # position S-L+j lives at slot (S-L+j) % L = (S+j) % L
            ck = jnp.roll(tail_k, S % L, axis=1)
            cv = jnp.roll(tail_v, S % L, axis=1)
        else:
            pad = ((0, 0), (0, L - S), (0, 0), (0, 0))
            ck, cv = jnp.pad(k, pad), jnp.pad(v, pad)
        return out, {"k": ck.astype(cdt), "v": cv.astype(cdt)}
    pad = ((0, 0), (0, max_len - S), (0, 0), (0, 0))
    return out, {"k": jnp.pad(k, pad).astype(cdt),
                 "v": jnp.pad(v, pad).astype(cdt)}


# ---------------------------------------------------------------------------
# Decode with KV cache
# ---------------------------------------------------------------------------

def kv_cache_init(cfg, batch: int, max_len: int, window: int = 0) -> dict:
    """Static cache for one attention layer.  ``window > 0`` allocates only a
    ring buffer of ``window`` slots (local attention / recurrentgemma)."""
    L = min(window, max_len) if window > 0 else max_len
    cdt = dtype_of(cfg.compute_dtype)
    shape = (batch, L, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, cdt), "v": jnp.zeros(shape, cdt)}


def attention_decode(
    p: Params,
    cfg,
    x: Array,          # (B, 1, D)
    cache: dict,       # {"k","v"}: (B, L, KV, hd)
    cache_len: Array,  # scalar int32 — tokens already in the cache
    *,
    window: int = 0,
    pos: Array | None = None,  # RoPE position override (defaults to cache_len)
) -> tuple[Array, dict]:
    """One decode step.  Writes the new k/v at position ``cache_len`` (ring
    slot ``cache_len % window`` for local attention), attends to the valid
    prefix, returns (output (B, 1, D), updated cache).

    ``pos`` decouples the rotary position of the new token from the cache
    slot — used after SS KV-cache pruning, where the cache is compacted but
    generation continues at the true sequence position."""
    cdt = dtype_of(cfg.compute_dtype)
    B = x.shape[0]
    L = cache["k"].shape[1]
    rope_pos = cache_len if pos is None else pos
    posb = jnp.full((B, 1), rope_pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, posb)

    slot = (cache_len % L).astype(jnp.int32) if window > 0 else cache_len
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)

    KV, H, hd = cfg.num_kv_heads, cfg.num_heads, cfg.head_dim
    G = H // KV
    head_map = jnp.arange(H) // G
    kx = jnp.take(k, head_map, axis=2)
    vx = jnp.take(v, head_map, axis=2)
    s = jnp.einsum(
        "bqhd,bshd->bhqs", q, kx, preferred_element_type=jnp.float32
    ) / math.sqrt(hd)                               # (B, H, 1, L)
    s = _softcap(s, cfg.attn_logit_softcap)

    idx = jnp.arange(L)
    if window > 0:
        # ring buffer: valid slots are the last min(cache_len+1, L) writes
        n_valid = jnp.minimum(cache_len + 1, L)
        age = (slot - idx) % L          # 0 = newest
        valid = age < n_valid
    else:
        valid = idx <= cache_len
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)

    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(cdt)
    out = jnp.einsum("bhqs,bshd->bqhd", w, vx)
    out = out.reshape(B, 1, cfg.q_dim) @ p["w_o"].astype(cdt)
    # barrier: the decode scan stacks this cache as its ys — without the
    # barrier XLA folds the attention einsum's f32 upcast into that buffer
    # and materializes the whole stacked KV cache in f32 *and* bf16
    # (measured 18.4 GB vs 6.4 GB on musicgen decode_32k)
    k, v = jax.lax.optimization_barrier((k, v))
    return out, {"k": k, "v": v}
