"""RG-LRU recurrent mixer (Griffin / RecurrentGemma, arXiv:2402.19427).

Block structure (the paper's "recurrent block"):

    x ──> W_y ──> GeLU ─────────────────────────┐
    x ──> W_x ──> causal conv1d(4) ──> RG-LRU ──┤⊙──> W_out ──> out

RG-LRU recurrence (per channel, diagonal):

    r_t = sigmoid(w_a ⊙ u_t + b_a)        recurrence gate
    i_t = sigmoid(w_i ⊙ u_t + b_i)        input gate
    a_t = exp(-c * softplus(Λ) * r_t)     decay in (0, 1),  c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ u_t)

Training uses an associative scan over the (a_t, b_t) linear-recurrence pairs
— O(log S) depth, fully parallel, which is what makes the 500k-token cell
tractable.  Decode is the exact O(1) per-token recurrence.

TPU adaptation note (DESIGN.md §3): the reference model computes the gates
with block-diagonal linears of ``num_heads`` blocks; 10 heads does not divide
a 16-way model axis, so we use *diagonal* (per-channel) gate projections —
channel-separable, hence any sharding of d_rnn is legal.  Parameter-count
delta is ~0.1% of the block.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init, dtype_of
from repro.models.sharding import DATA, MODEL, POD, constrain

Array = jax.Array

_C = 8.0  # Griffin's fixed decay sharpness


def rglru_init(key: Array, cfg) -> Params:
    dtype = dtype_of(cfg.param_dtype)
    d, dr = cfg.d_model, cfg.d_rnn
    ks = jax.random.split(key, 4)
    # Λ init so that a^c = exp(-c softplus(Λ)) is uniform in [0.9, 0.999]
    u = jax.random.uniform(ks[3], (dr,), jnp.float32, minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log(u)/c)
    return {
        "w_y": dense_init(ks[0], d, dr, dtype),
        "w_x": dense_init(ks[1], d, dr, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, dr), jnp.float32)
                   / math.sqrt(cfg.conv_width)).astype(dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "gate_a_w": jnp.zeros((dr,), jnp.float32),
        "gate_a_b": jnp.zeros((dr,), jnp.float32),
        "gate_i_w": jnp.zeros((dr,), jnp.float32),
        "gate_i_b": jnp.zeros((dr,), jnp.float32),
        "lam": lam,                                   # (dr,) f32
        "w_out": dense_init(jax.random.fold_in(ks[2], 7), dr, d, dtype,
                            scale=1.0 / math.sqrt(dr)),
    }


def _gates(p: Params, u: Array):
    """u (..., dr) f32 -> (a, b) of the linear recurrence h = a h + b."""
    r = jax.nn.sigmoid(p["gate_a_w"] * u + p["gate_a_b"])
    i = jax.nn.sigmoid(p["gate_i_w"] * u + p["gate_i_b"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r          # (..., dr) <= 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * u)
    return a, b


def _conv(x: Array, w: Array, b: Array, state: Array | None = None):
    """Causal depthwise conv over (B, S, dr); optional carry-in state
    (B, W-1, dr).  Returns (out, new_state)."""
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :]
              for i in range(W))
    return out + b[None, None, :], xp[:, -(W - 1):, :]


def rglru_forward(p: Params, cfg, x: Array, return_cache: bool = False):
    """Full-sequence recurrent block.  x: (B, S, D) -> (B, S, D).

    With ``return_cache`` also returns the decode cache (final hidden state +
    conv tail) so prefill seeds O(1) decoding."""
    cdt = dtype_of(cfg.compute_dtype)
    xc = x.astype(cdt)
    y = jax.nn.gelu(constrain(xc @ p["w_y"].astype(cdt), (POD, DATA), None, MODEL))
    x_in = constrain(xc @ p["w_x"].astype(cdt), (POD, DATA), None, MODEL)
    u, _ = _conv(x_in, p["conv_w"].astype(cdt), p["conv_b"].astype(cdt))
    uf = u.astype(jnp.float32)
    a, b = _gates(p, uf)                                  # (B, S, dr)

    # associative scan over the diagonal linear recurrence
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    del a_s
    out = ((h.astype(cdt) * y) @ p["w_out"].astype(cdt)).astype(x.dtype)
    if not return_cache:
        return out
    W = cfg.conv_width
    S = x.shape[1]
    conv_tail = x_in[:, -(W - 1):, :] if S >= W - 1 else jnp.pad(
        x_in, ((0, 0), (W - 1 - S, 0), (0, 0))
    )
    return out, {"conv": conv_tail, "h": h[:, -1].astype(jnp.float32)}


def rglru_cache_init(cfg, batch: int) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_rnn),
                          dtype_of(cfg.compute_dtype)),
        "h": jnp.zeros((batch, cfg.d_rnn), jnp.float32),
    }


def rglru_decode(p: Params, cfg, x: Array, cache: dict) -> tuple[Array, dict]:
    """One token.  x: (B, 1, D)."""
    cdt = dtype_of(cfg.compute_dtype)
    xc = x.astype(cdt)
    y = jax.nn.gelu(xc @ p["w_y"].astype(cdt))            # (B, 1, dr)
    u, new_conv = _conv(xc @ p["w_x"].astype(cdt),
                        p["conv_w"].astype(cdt), p["conv_b"].astype(cdt),
                        state=cache["conv"])
    uf = u[:, 0].astype(jnp.float32)                      # (B, dr)
    a, b = _gates(p, uf)
    h = a * cache["h"] + b
    out = (h[:, None, :].astype(cdt) * y) @ p["w_out"].astype(cdt)
    return out.astype(x.dtype), {"conv": new_conv, "h": h}
