"""Model zoo: config-driven decoder-only LMs (dense GQA / MoE / Mamba2 SSD /
RG-LRU hybrid) with scan-over-layers, remat, KV/SSM decode caches, and
mesh-aware partition specs."""

from repro.models.config import (
    SHAPES,
    ModelConfig,
    ShapeConfig,
    cell_supported,
    is_subquadratic,
)
from repro.models.decoder import (
    abstract_cache,
    abstract_params,
    decode_step,
    forward,
    init_cache,
    init_params,
    lm_loss,
    prefill,
)
from repro.models.sharding import (
    cache_spec_tree,
    data_spec,
    named,
    param_spec_tree,
)

__all__ = [
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "cell_supported",
    "is_subquadratic",
    "abstract_cache",
    "abstract_params",
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "lm_loss",
    "prefill",
    "cache_spec_tree",
    "data_spec",
    "named",
    "param_spec_tree",
]
