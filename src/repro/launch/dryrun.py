import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST run before any other import: jax locks the device count on first
# init, and the production meshes below need 512 host placeholder devices.

"""Multi-pod dry-run: ``.lower().compile()`` every (architecture x input
shape) cell on the single-pod (16, 16) mesh and the 2-pod (2, 16, 16) mesh,
then record memory_analysis / cost_analysis / collective traffic per cell.

    PYTHONPATH=src python -m repro.launch.dryrun                  # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b  # one arch
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multipod  # 512 chips
    PYTHONPATH=src python -m repro.launch.dryrun --force          # recompile

Results are cached per-cell as JSON under results/dryrun/<mesh>/ so the full
sweep is resumable; EXPERIMENTS.md §Dry-run and the roofline table read them.
"""

import argparse
import json
import time
import traceback

import jax

from repro import configs
from repro.launch import hlo_analysis, hlo_cost
from repro.launch.mesh import HW, make_production_mesh
from repro.launch.steps import build_cell, model_flops

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun")


def _get(d: dict, *names, default=0.0):
    for n in names:
        if n in d:
            return d[n]
    return default


def _analytic_state_bytes(cell) -> int:
    """Exact per-device bytes of the cell's persistent arguments (params,
    optimizer state, KV cache) from their NamedShardings — the
    hardware-honest HBM floor.  CPU `memory_analysis` additionally carries
    f32 copies of every bf16 dot operand (the CPU backend has no bf16
    matmul), which a TPU executable does not."""
    total = 0
    args_flat = jax.tree.leaves(
        cell.args, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
    )
    sh_flat = jax.tree.leaves(
        cell.in_shardings,
        is_leaf=lambda x: hasattr(x, "shard_shape"),
    )
    for a, sh in zip(args_flat, sh_flat):
        if not isinstance(a, jax.ShapeDtypeStruct) or a.ndim == 0:
            continue
        try:
            local = sh.shard_shape(a.shape)
        except Exception:
            local = a.shape
        n = 1
        for d in local:
            n *= d
        total += n * a.dtype.itemsize
    return total


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str) -> dict:
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh)
    fn = jax.jit(
        cell.fn,
        in_shardings=cell.in_shardings,
        out_shardings=cell.out_shardings,
        donate_argnums=cell.donate_argnums,
    )
    from repro.compat import set_mesh
    with set_mesh(mesh):   # activates the P()-based constraints
        lowered = fn.lower(*cell.args)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, (list, tuple)):
        xla_cost = xla_cost[0]
    # Trip-count-aware analysis (XLA's cost_analysis counts while bodies
    # once — useless for scan-over-layers programs; see hlo_cost.py).
    cost = hlo_cost.analyze(compiled.as_text())

    chips = mesh.devices.size
    flops_per_chip = float(cost["flops"])
    bytes_per_chip = float(cost["bytes"])
    coll_per_chip = float(cost["coll_bytes"])
    stats_by_op = cost["coll_by_op"]
    pod_fraction = 0.0
    if "pod" in mesh.axis_names:
        # conservatively assume gradients/activations crossing pods are the
        # all-reduce share (pure DP on the pod axis)
        ar = stats_by_op.get("all-reduce", 0)
        pod_fraction = 0.0 if coll_per_chip == 0 else min(
            1.0, 0.5 * ar / coll_per_chip
        )
    rl = hlo_analysis.roofline(
        flops_per_chip, bytes_per_chip, coll_per_chip, HW,
        pod_fraction=pod_fraction,
    )
    mflops = model_flops(arch, shape_name)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": int(chips),
        "status": "ok",
        "compile_s": round(t_compile, 1),
        "kind": cell.meta.get("kind"),
        "meta": cell.meta,
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "peak_bytes": int(
                mem.argument_size_in_bytes + mem.temp_size_in_bytes
            ),
            "fits_16gb": bool(
                mem.argument_size_in_bytes + mem.temp_size_in_bytes
                < HW["hbm_per_chip"]
            ),
            "analytic_state_bytes": _analytic_state_bytes(cell),
        },
        "cost": {
            "flops_per_chip": flops_per_chip,
            "bytes_per_chip": bytes_per_chip,
            "collective_bytes_per_chip": coll_per_chip,
            "collectives": stats_by_op,
            "collective_counts": cost["coll_counts"],
            "xla_cost_analysis_flops": float(_get(xla_cost, "flops")),
        },
        "roofline": rl,
        "model_flops_total": mflops,
        "model_flops_per_chip": mflops / chips,
        "useful_flops_ratio": (
            mflops / chips / flops_per_chip if flops_per_chip else 0.0
        ),
    }
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multipod", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=RESULTS)
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multipod", "both"):
        meshes.append(("multipod_2x16x16", make_production_mesh(multi_pod=True)))

    failures = 0
    for mesh_name, mesh in meshes:
        outdir = os.path.join(args.out, mesh_name)
        os.makedirs(outdir, exist_ok=True)
        for arch, shape_name, ok, why in configs.all_cells():
            if args.arch and arch != args.arch:
                continue
            if args.shape and shape_name != args.shape:
                continue
            path = os.path.join(outdir, f"{arch}__{shape_name}.json")
            if not ok:
                rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                       "status": "skipped", "reason": why}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"[{mesh_name}] {arch:28s} {shape_name:12s} SKIP ({why[:60]})")
                continue
            if os.path.exists(path) and not args.force:
                with open(path) as f:
                    prev = json.load(f)
                if prev.get("status") == "ok":
                    print(f"[{mesh_name}] {arch:28s} {shape_name:12s} cached")
                    continue
            try:
                rec = run_cell(arch, shape_name, mesh, mesh_name)
                rl = rec["roofline"]
                print(
                    f"[{mesh_name}] {arch:28s} {shape_name:12s} OK "
                    f"compile={rec['compile_s']:7.1f}s "
                    f"peak={rec['memory']['peak_bytes']/1e9:6.2f}GB "
                    f"dominant={rl['dominant']:10s} "
                    f"bound={rl['step_time_lower_bound_s']:.3e}s",
                    flush=True,
                )
            except Exception as e:  # a failing cell is a bug: record + count
                failures += 1
                rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                       "status": "error", "error": str(e)[:2000],
                       "traceback": traceback.format_exc()[-4000:]}
                print(f"[{mesh_name}] {arch:28s} {shape_name:12s} "
                      f"FAIL {str(e)[:120]}", flush=True)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
