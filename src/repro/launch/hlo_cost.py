"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**, so any
scan-over-layers / microbatch-scan / blockwise-attention program (i.e. all of
ours) is underestimated by the trip count.  This module re-derives the
roofline inputs directly from the partitioned HLO text:

  * builds the computation call graph (entry -> while bodies, fusions, calls),
  * extracts each while loop's trip count from its condition computation
    (canonical scan form: ``compare(induction, constant(N)), direction=LT``),
  * FLOPs: 2 * result_elems * contraction_size for every ``dot`` (+ rare
    convs), counted wherever they appear (including inside fusions),
  * bytes: operand + result sizes of top-level ops per computation —
    post-fusion this approximates actual HBM traffic (a fusion kernel reads
    its operands and writes its result once); bookkeeping ops (tuple, gte,
    parameter, bitcast, constant) are free,
  * collective bytes: operand sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute — trip-count multiplied
    like everything else.

All numbers are per-partition (the compiled module is the per-device
program), matching the roofline convention in hlo_analysis.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.launch.hlo_analysis import DTYPE_BYTES

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_TRIP_CFG = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OP_HEAD = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OP_AFTER_TYPE = re.compile(r"\s*([\w\-]+)\((.*)$")
_SIMPLE_TYPE = re.compile(r"^[\w]+\[[^\]]*\](?:\{[^}]*\})?")


def _parse_op_line(line: str):
    """Parse '%name = TYPE opcode(rest' with balanced-paren tuple types.

    Returns (name, type_str, opcode, rest) or None."""
    m = _OP_HEAD.match(line)
    if not m:
        return None
    name = m.group(1)
    s = line[m.end():]
    if s.startswith("("):           # tuple type: find the balanced close
        depth = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        else:
            return None
        type_str, tail = s[: i + 1], s[i + 1:]
    else:
        mt = _SIMPLE_TYPE.match(s)
        if not mt:
            return None
        type_str, tail = mt.group(0), s[mt.end():]
    ma = _OP_AFTER_TYPE.match(tail)
    if not ma:
        return None
    return name, type_str, ma.group(1), ma.group(2)
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALLED = re.compile(r"(?:condition|body|to_apply|calls)=%?([\w.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_FREE_OPS = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota", "custom-call",
    "get-dimension-size", "opt-barrier",
}

# Ops that the TPU backend fuses into their producers/consumers: counting
# their operand+result bytes would model every elementwise link in a chain
# as an HBM round-trip, which the CPU-compiled HLO (weak fusion) is full of.
# The memory term instead charges only "materializing" ops — matmuls,
# explicit fusions, data movement, reshapes/copies, gathers/scatters — which
# matches TPU executables, where elementwise chains live in VMEM/registers.
_FUSABLE_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "rsqrt", "sqrt", "tanh", "logistic", "sine", "cosine", "sign", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "power", "remainder",
    "and", "or", "xor", "not", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "compare", "select", "clamp", "convert",
    "broadcast", "reshape", "reduce", "reduce-window", "map", "slice",
    "concatenate", "pad", "reverse", "is-finite", "atan2", "expm1", "log1p",
    "cbrt", "erf", "tan", "stochastic-convert", "dynamic-slice",
}
_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start",
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str            # operand list + attributes (raw tail of the line)


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)   # op name -> type string


def parse_hlo(text: str) -> tuple[dict, str]:
    """Returns ({name: Computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR.match(stripped)
            if m and stripped.endswith("{"):
                cur = Computation(m.group(1))
                if stripped.startswith("ENTRY"):
                    entry = cur.name
                continue
        else:
            if stripped == "}" or stripped.startswith("}"):
                comps[cur.name] = cur
                cur = None
                continue
            parsed = _parse_op_line(line)
            if parsed:
                op = Op(*parsed)
                cur.ops.append(op)
                cur.symbols[op.name] = op.type_str
    if cur is not None:
        comps[cur.name] = cur
    if entry is None and comps:
        entry = next(iter(comps))
    return comps, entry


def _operand_names(rest: str) -> list[str]:
    """Names of the top-level operands in 'a, %b, f32[2]{0} %c), attr=...'."""
    # cut at the matching close paren of the operand list
    depth = 1
    out = []
    cur = []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            cur.append(ch)
    arglist = "".join(cur)
    for piece in re.split(r",(?![^{]*\})", arglist):
        names = re.findall(r"%([\w.\-]+)", piece)
        if names:
            out.append(names[-1])
        else:
            p = piece.strip().split(" ")[-1]
            if p:
                out.append(p.lstrip("%"))
    return out


def _dot_flops(op: Op, comp: Computation) -> float:
    result_elems = 1
    for d in _shape_dims(op.type_str):
        result_elems *= d
    operands = _operand_names(op.rest)
    lhs_t = comp.symbols.get(operands[0], "") if operands else ""
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    contraction = 1
    if mc and lhs_t:
        dims = _shape_dims(lhs_t)
        for i in mc.group(1).split(","):
            if i and int(i) < len(dims):
                contraction *= dims[int(i)]
    return 2.0 * result_elems * contraction


def _trip_count(cond: Computation) -> int:
    """Max integer constant in the condition computation (canonical scans:
    ``compare(i, constant(N)), direction=LT``).  Falls back to 1."""
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.match(r"\s*(\d+)\s*\)", op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: dict = field(default_factory=dict)
    coll_counts: dict = field(default_factory=dict)

    def scaled(self, k: float) -> "Cost":
        return Cost(
            self.flops * k, self.bytes * k, self.coll_bytes * k,
            {o: b * k for o, b in self.coll_by_op.items()},
            {o: c * k for o, c in self.coll_counts.items()},
        )

    def add(self, other: "Cost") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        self.coll_bytes += other.coll_bytes
        for o, b in other.coll_by_op.items():
            self.coll_by_op[o] = self.coll_by_op.get(o, 0.0) + b
        for o, c in other.coll_counts.items():
            self.coll_counts[o] = self.coll_counts.get(o, 0.0) + c


def _collective_operand_bytes(op: Op) -> float:
    size = _shape_bytes(op.type_str)
    g = 1
    gm = _GROUPS_RE.search(op.rest)
    if gm:
        g = gm.group(1).count(",") + 1
    else:
        gi = _GROUPS_IOTA_RE.search(op.rest)
        if gi:
            g = int(gi.group(2))
    g = max(g, 1)
    base = op.opcode.removesuffix("-start")
    if base == "all-gather":
        return size / g
    if base == "reduce-scatter":
        return size * g
    return float(size)


def _analyze_comp(
    name: str, comps: dict, memo: dict, fusion_flops: dict
) -> Cost:
    if name in memo:
        return memo[name]
    comp = comps.get(name)
    cost = Cost()
    if comp is None:
        memo[name] = cost
        return cost
    for op in comp.ops:
        if op.opcode == "while":
            called = dict(
                (k, v) for k, v in re.findall(
                    r"(condition|body)=%?([\w.\-]+)", op.rest
                )
            )
            body = called.get("body")
            condn = called.get("condition")
            mt = _TRIP_CFG.search(op.rest)
            if mt:  # XLA's own loop analysis — authoritative when present
                trips = int(mt.group(1))
            else:
                trips = _trip_count(comps[condn]) if condn in comps else 1
            if body:
                cost.add(_analyze_comp(body, comps, memo, fusion_flops)
                         .scaled(trips))
            continue
        if op.opcode in ("fusion", "call", "conditional", "map", "reduce",
                         "reduce-window", "sort", "scatter", "select-and-scatter"):
            for sub in _CALLED.findall(op.rest):
                # fusions/calls execute once per encounter; nested dots counted
                cost.add(_analyze_comp(sub, comps, memo, fusion_flops))
        if op.opcode == "dot":
            cost.flops += _dot_flops(op, comp)
        if op.opcode.endswith("-done"):
            continue
        if op.opcode in _COLLECTIVES:
            b = _collective_operand_bytes(op)
            base = op.opcode.removesuffix("-start")
            cost.coll_bytes += b
            cost.coll_by_op[base] = cost.coll_by_op.get(base, 0.0) + b
            cost.coll_counts[base] = cost.coll_counts.get(base, 0.0) + 1
        if (op.opcode not in _FREE_OPS and op.opcode not in _FUSABLE_OPS
                and op.opcode != "while"):
            rb = _shape_bytes(op.type_str)
            ob = sum(
                _shape_bytes(comp.symbols.get(o, ""))
                for o in _operand_names(op.rest)
            )
            cost.bytes += rb + ob
    memo[name] = cost
    return cost


def analyze(hlo_text: str) -> dict:
    """Trip-count-aware per-partition cost of the compiled module."""
    comps, entry = parse_hlo(hlo_text)
    # cache: sub-computations reused under different multipliers are fine —
    # memo stores the *unscaled* cost of each computation.
    memo: dict[str, Cost] = {}
    cost = _analyze_comp(entry, comps, memo, {})
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "coll_bytes": cost.coll_bytes,
        "coll_by_op": cost.coll_by_op,
        "coll_counts": cost.coll_counts,
    }


def top_contributors(hlo_text: str, n: int = 15) -> dict:
    """Hillclimb profiler: the heaviest individual ops by (trip-scaled) bytes
    and by collective traffic, with their metadata op_name when present."""
    comps, entry = parse_hlo(hlo_text)

    # walk the call graph accumulating a multiplier per computation
    mult: dict[str, float] = {entry: 1.0}
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        for op in comp.ops:
            if op.opcode == "while":
                called = dict(re.findall(r"(condition|body)=%?([\w.\-]+)",
                                         op.rest))
                mt = _TRIP_CFG.search(op.rest)
                trips = int(mt.group(1)) if mt else (
                    _trip_count(comps[called.get("condition", "")])
                    if called.get("condition") in comps else 1)
                body = called.get("body")
                if body:
                    mult[body] = mult.get(body, 0.0) + mult[cname] * trips
                    if body not in seen:
                        seen.add(body)
                        order.append(body)
            else:
                for sub in _CALLED.findall(op.rest):
                    mult[sub] = mult.get(sub, 0.0) + mult[cname]
                    if sub not in seen:
                        seen.add(sub)
                        order.append(sub)

    rows_bytes, rows_coll = [], []
    for cname, m in mult.items():
        comp = comps.get(cname)
        if comp is None:
            continue
        for op in comp.ops:
            meta = re.search(r'op_name="([^"]+)"', op.rest)
            label = meta.group(1)[:90] if meta else op.name
            if op.opcode in _COLLECTIVES and not op.opcode.endswith("-done"):
                b = _collective_operand_bytes(op) * m
                rows_coll.append((b, op.opcode, op.type_str[:40], label))
            if (op.opcode not in _FREE_OPS and op.opcode not in _FUSABLE_OPS
                    and op.opcode not in _COLLECTIVES
                    and op.opcode != "while"):
                rb = _shape_bytes(op.type_str)
                ob = sum(_shape_bytes(comp.symbols.get(o, ""))
                         for o in _operand_names(op.rest))
                rows_bytes.append(((rb + ob) * m, op.opcode,
                                   op.type_str[:40], label))
    rows_bytes.sort(key=lambda r: -r[0])
    rows_coll.sort(key=lambda r: -r[0])
    return {"bytes": rows_bytes[:n], "collectives": rows_coll[:n]}
