"""Post-SPMD HLO analysis: collective traffic + roofline terms.

``collective_bytes`` parses the compiled (partitioned) HLO text and sums the
**operand** sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, per the assignment's accounting.  Operand
sizes are recovered from the result shape and the replica-group size
(all-gather result = operand x group; reduce-scatter result = operand /
group; the others move their operand size).

``roofline`` turns (cost_analysis, collective bytes) into the three terms:

    compute    = FLOPs / (chips x peak)        [s]
    memory     = bytes / (chips x HBM bw)      [s]
    collective = coll_bytes / (chips x link bw)  [s]

Conventions: XLA's cost_analysis on the compiled SPMD executable reports the
**per-partition** program; we report per-chip terms directly (dividing the
per-chip quantity by one chip's peak), which equals the spec's
whole-job/(chips x peak) under even sharding.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\w+\[[\d,]*\][^\s]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: dict = field(default_factory=dict)
    count_by_op: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective in partitioned HLO text."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_t, op, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":   # the -start already counted this op
            continue
        size = _shape_bytes(result_t)
        g = None
        gm = _GROUPS_RE.search(line)
        if gm:
            g = gm.group(1).count(",") + 1
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                g = int(gi.group(2))
        if g is None or g < 1:
            g = 1
        if op == "all-gather":
            operand = size // g
        elif op == "reduce-scatter":
            operand = size * g
        else:  # all-reduce, all-to-all, collective-permute move operand-size
            operand = size
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + operand
        stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
    return stats


def roofline(
    flops_per_chip: float,
    bytes_per_chip: float,
    coll_bytes_per_chip: float,
    hw: dict,
    *,
    pod_fraction: float = 0.0,   # fraction of collective bytes on DCN links
) -> dict:
    compute_s = flops_per_chip / hw["peak_flops_bf16"]
    memory_s = bytes_per_chip / hw["hbm_bw"]
    ici = coll_bytes_per_chip * (1.0 - pod_fraction) / hw["ici_bw"]
    dcn = coll_bytes_per_chip * pod_fraction / hw["dcn_bw"]
    collective_s = ici + dcn
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    return dict(
        terms,
        dominant=dominant.removesuffix("_s"),
        step_time_lower_bound_s=bound,
        # fraction of the bound spent doing useful math
        roofline_fraction=(compute_s / bound) if bound > 0 else 0.0,
    )
