"""Builders for the lowerable step functions of every dry-run cell.

One place defines, for each (arch, shape) cell:
  * the step callable (train_step / prefill / decode_step),
  * its abstract arguments (ShapeDtypeStructs — nothing allocated),
  * the in/out shardings on a given mesh.

Both the dry-run and the roofline tool consume these.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.models import (
    ModelConfig,
    ShapeConfig,
    abstract_cache,
    abstract_params,
    cache_spec_tree,
    data_spec,
    decode_step,
    param_spec_tree,
    prefill,
)
from repro.train.trainer import (
    TrainConfig,
    abstract_train_state,
    make_train_step,
    state_spec_tree,
)

Array = jax.Array


def train_config_for(
    cfg: ModelConfig, shp: ShapeConfig, batch_shards: int = 16
) -> TrainConfig:
    """Per-cell training hyperparameters: optimizer + microbatching chosen by
    model scale (Adafactor above ~10B params; microbatches bound activation
    memory on the 16 GB v5e).

    ``batch_shards`` is the product of the mesh axes that shard the batch
    (pod x data).  µ is capped at B/batch_shards: a microbatch smaller than
    the shard count stops dividing evenly and GSPMD silently *replicates*
    the whole remat stash (measured: 172 GB/device on internvl2 multipod)."""
    big = cfg.param_count() > 10e9
    # The remat stash per device is num_layers saved layer-inputs:
    #   stash ≈ L * (B/µ/shards) * S * D * 2 bytes   (bf16 carries)
    # Pick µ (a power of 2, ≤ B/shards) so stash fits in ~5 GB of the 16 GB
    # HBM (params/grads/optimizer take the rest on the big configs).
    per_dev_tokens = shp.global_batch * shp.seq_len / batch_shards
    stash = cfg.num_layers * per_dev_tokens * cfg.d_model * 2
    mu = 1
    # stash target 1.5 GB: the per-µbatch *working set* (f32 mixer internals,
    # MoE dispatch buffers) scales with B/µ too, and is what actually fills
    # HBM on the small-d_model archs (mamba2 measured 21.8 GB at µ=1)
    while mu < shp.global_batch // batch_shards and stash / mu > 1.5e9:
        mu *= 2
    # when µ is maxed out and the stash still doesn't fit, switch to
    # two-level remat (stash sqrt(L) carries instead of L, ~+30% FLOPs)
    remat = "nested" if stash / mu > 2.5e9 else "nothing"
    return TrainConfig(
        optimizer="adafactor" if big else "adamw",
        num_microbatches=mu,
        remat=remat,
    )


@dataclasses.dataclass
class Cell:
    arch: str
    shape_name: str
    fn: Callable           # the function handed to jax.jit
    args: tuple            # abstract args
    in_shardings: Any
    out_shardings: Any
    static_argnums: tuple = ()
    donate_argnums: tuple = ()
    meta: dict = dataclasses.field(default_factory=dict)


def named(mesh: Mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def build_cell(arch: str, shape_name: str, mesh: Mesh) -> Cell:
    cfg = configs.get(arch)
    shp = configs.shape(shape_name)
    specs = configs.input_specs(cfg, shp)

    if shp.kind == "train":
        from repro.models.sharding import batch_axes

        shards = 1
        for a in batch_axes(mesh):
            shards *= mesh.shape[a]
        tc = train_config_for(cfg, shp, batch_shards=shards)
        state_shape = abstract_train_state(cfg, tc)
        state_sh = named(mesh, state_spec_tree(cfg, tc, state_shape, mesh))
        batch_sh = {
            k: NamedSharding(mesh, data_spec(mesh, v.shape))
            for k, v in specs.items()
        }
        step = make_train_step(cfg, tc)
        return Cell(
            arch, shape_name, step,
            (state_shape, specs),
            (state_sh, batch_sh),
            (state_sh, None),
            donate_argnums=(0,),
            meta={"kind": "train", "microbatches": tc.num_microbatches,
                  "optimizer": tc.optimizer,
                  "tokens": shp.global_batch * shp.seq_len},
        )

    params_shape = abstract_params(cfg)
    params_sh = named(mesh, param_spec_tree(cfg, params_shape, mesh))

    if shp.kind == "prefill":
        fn = lambda p, toks, patches=None: prefill(
            cfg, p, toks, patches, max_len=shp.seq_len
        )
        batch_sh = {
            k: NamedSharding(mesh, data_spec(mesh, v.shape))
            for k, v in specs.items()
        }
        args = (params_shape, specs["tokens"])
        in_sh = (params_sh, batch_sh["tokens"])
        if "patches" in specs:
            args = args + (specs["patches"],)
            in_sh = in_sh + (batch_sh["patches"],)
        cache_shape = abstract_cache(cfg, shp.global_batch, shp.seq_len)
        cache_sh = named(mesh, cache_spec_tree(cfg, cache_shape, mesh))
        logit_shape = (
            (shp.global_batch, 1, cfg.vocab_size) if cfg.num_codebooks == 1
            else (shp.global_batch, 1, cfg.num_codebooks, cfg.vocab_size)
        )
        return Cell(
            arch, shape_name, fn, args, in_sh,
            (NamedSharding(mesh, data_spec(mesh, logit_shape)), cache_sh),
            meta={"kind": "prefill",
                  "tokens": shp.global_batch * shp.seq_len},
        )

    # decode: one new token against a seq_len-deep cache
    B = shp.global_batch
    cache_shape = abstract_cache(cfg, B, shp.seq_len)
    cache_sh = named(mesh, cache_spec_tree(cfg, cache_shape, mesh))
    fn = lambda p, toks, cache, n: decode_step(cfg, p, toks, cache, n)
    tok_spec = specs["tokens"]
    args = (params_shape, tok_spec, cache_shape,
            jax.ShapeDtypeStruct((), jnp.int32))
    in_sh = (params_sh, NamedSharding(mesh, data_spec(mesh, tok_spec.shape)),
             cache_sh, NamedSharding(mesh, P()))
    logits_shape = (B, 1, cfg.vocab_size) if cfg.num_codebooks == 1 else (
        B, 1, cfg.num_codebooks, cfg.vocab_size)
    out_sh = (NamedSharding(mesh, data_spec(mesh, logits_shape)), cache_sh)
    return Cell(
        arch, shape_name, fn, args, in_sh, out_sh,
        donate_argnums=(2,),
        meta={"kind": "decode", "tokens": B},
    )


def model_flops(arch: str, shape_name: str) -> float:
    """6·N_active·tokens (train) / 2·N_active·tokens (inference) — the
    "useful" FLOPs yardstick for the roofline ratio."""
    cfg = configs.get(arch)
    shp = configs.shape(shape_name)
    n_active = cfg.active_param_count()
    if shp.kind == "train":
        return 6.0 * n_active * shp.global_batch * shp.seq_len
    if shp.kind == "prefill":
        return 2.0 * n_active * shp.global_batch * shp.seq_len
    return 2.0 * n_active * shp.global_batch  # decode: one token per row
