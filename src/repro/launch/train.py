"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \\
        --steps 50 --batch 8 --seq 128 --selection ss

Wires every substrate together: config registry -> SS-selected data pipeline
-> sharded train step -> checkpointed, preemption-safe loop.  On this CPU
container use ``--smoke`` (reduced config); the same driver with the full
config and a TPU mesh is the production entry point.
"""

from __future__ import annotations

import argparse
import os

import jax

from repro import configs
from repro.data import DataConfig, Pipeline
from repro.launch.mesh import make_test_mesh
from repro.train import (
    Checkpointer,
    StragglerGuard,
    TrainConfig,
    abstract_train_state,
    make_train_state,
    resume_or_init,
    run,
    shard_train_step,
)

Array = jax.Array


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default=None, choices=[None, "adamw", "adafactor"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--selection", default="ss",
                    choices=["none", "uniform", "greedy", "ss"])
    ap.add_argument("--pool-factor", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--mesh", default="1x1",
                    help="dataxmodel, e.g. 2x2 (requires that many devices)")
    ap.add_argument("--straggler-deadline", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    tc = TrainConfig(
        optimizer=args.optimizer
        or ("adafactor" if cfg.param_count() > 10e9 else "adamw"),
        lr=args.lr,
        warmup_steps=max(2, args.steps // 10),
        total_steps=args.steps,
        num_microbatches=args.microbatches,
    )
    dshape = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_test_mesh(dshape, ("data", "model"))

    dc = DataConfig(
        batch_size=args.batch,
        seq_len=args.seq,
        vocab_size=cfg.vocab_size,
        selection=args.selection,
        pool_factor=args.pool_factor,
        num_codebooks=cfg.num_codebooks,
        patch_count=cfg.num_patches if cfg.input_mode == "tokens+patches" else 0,
        d_model=cfg.d_model,
    )
    pipe = Pipeline(dc, seed=args.seed)

    state_shape = abstract_train_state(cfg, tc)
    from repro.compat import set_mesh
    with set_mesh(mesh):
        step_fn, state_sh, batch_sharding = shard_train_step(
            mesh, cfg, tc, state_shape
        )
        ckpt = Checkpointer(os.path.join(args.ckpt_dir, cfg.name))
        state, start, resumed = resume_or_init(
            ckpt, state_shape,
            lambda: make_train_state(jax.random.PRNGKey(args.seed), cfg, tc),
            shardings=state_sh,
        )
        if resumed:
            print(f"resumed from step {start}")

        next_batch = pipe
        if args.straggler_deadline > 0:
            next_batch = StragglerGuard(
                pipe, lambda: None, deadline_s=args.straggler_deadline
            )
        state, report = run(
            state, step_fn, next_batch, ckpt,
            num_steps=args.steps, start_step=start,
            ckpt_every=args.ckpt_every, log_every=max(1, args.steps // 20),
        )
    print(
        f"done: {report.steps_done} steps"
        + (" (preempted)" if report.preempted else "")
        + (f", {report.straggler_skips} straggler skips"
           if report.straggler_skips else "")
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
