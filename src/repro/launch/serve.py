"""Serving driver: batched generation with optional SS KV-cache pruning.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \\
        --batch 4 --prompt-len 64 --gen 32 --kv-budget 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import init_params
from repro.serve import Engine, KVSelectConfig, ServeConfig, prune_cache


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-budget", type=int, default=0,
                    help=">0: SS-prune the KV cache to this many positions "
                         "after prefill (attention archs only)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    sc = ServeConfig(
        max_len=args.prompt_len + args.gen + 8, temperature=args.temperature
    )
    eng = Engine(cfg, params, sc)

    B, S = args.batch, args.prompt_len
    shape = (B, S) if cfg.num_codebooks == 1 else (B, S, cfg.num_codebooks)
    toks = jax.random.randint(key, shape, 0, cfg.vocab_size)

    t0 = time.time()
    if args.kv_budget > 0:
        logits, cache = eng._prefill(params, toks, None)
        kv = KVSelectConfig(budget=args.kv_budget)
        cache, clen, kept = prune_cache(cfg, cache, S, kv, key)
        print(f"KV cache pruned {S} -> {args.kv_budget} positions "
              f"(kept head: {kept[0][:8].tolist()}...)")
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        outs = [tok]
        pos = jnp.int32(S)
        n = clen
        for _ in range(args.gen - 1):
            logits, cache = eng._decode(params, tok, cache, n, pos)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            outs.append(tok)
            n, pos = n + 1, pos + 1
        out = jnp.concatenate(outs, axis=1)
    else:
        out, _ = eng.generate(toks, args.gen, key=key if args.temperature else None)
    dt = time.time() - t0
    toks_out = out.size
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({toks_out / dt:.1f} tok/s on CPU)")
    print("first row:", out[0].reshape(-1)[:16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
