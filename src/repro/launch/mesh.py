"""Production meshes.  Defined as FUNCTIONS so importing this module never
touches jax device state (device count is locked at first jax init — the
dry-run sets XLA_FLAGS before importing anything)."""

from __future__ import annotations

from jax.sharding import Mesh

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The assignment's target: 16x16 = 256 chips per pod; 2 pods = 512."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Small explicit meshes for CPU tests (e.g. (1,1), (2,2), (2,2,2))."""
    return make_mesh(shape, axes)


def single_device_mesh() -> Mesh:
    return make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis (per chip).
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link (~ per-chip usable DCN is far less;
                                 # the pod axis models DCN at ~1/10 of this)
    "dcn_bw": 5e9,
    "hbm_per_chip": 16e9,        # v5e: 16 GB
}
