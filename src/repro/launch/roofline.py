"""Roofline report: read the dry-run JSON cache and print the per-cell
three-term table (EXPERIMENTS.md §Roofline is generated from this).

    PYTHONPATH=src python -m repro.launch.roofline [--mesh single_16x16]
    PYTHONPATH=src python -m repro.launch.roofline --markdown
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.launch.dryrun import RESULTS


def load_records(mesh: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS, mesh, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}µs"
    if x < 1.0:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def table(recs: list[dict], markdown: bool = False) -> str:
    rows = []
    hdr = ["arch", "shape", "compute", "memory", "collective", "dominant",
           "roofline%", "useful%", "peakGB", "fits"]
    for r in recs:
        if r.get("status") == "skipped":
            rows.append([r["arch"], r["shape"], "—", "—", "—", "skip",
                         "—", "—", "—", "—"])
            continue
        if r.get("status") != "ok":
            rows.append([r["arch"], r["shape"], "ERR", "", "", "", "", "", "", ""])
            continue
        rl = r["roofline"]
        mfrac = r["model_flops_per_chip"] / max(
            rl["step_time_lower_bound_s"] * 197e12, 1e-30
        )
        rows.append([
            r["arch"], r["shape"],
            _fmt_s(rl["compute_s"]), _fmt_s(rl["memory_s"]),
            _fmt_s(rl["collective_s"]), rl["dominant"],
            f"{100*mfrac:.1f}",
            f"{100*r['useful_flops_ratio']:.0f}",
            f"{r['memory']['peak_bytes']/1e9:.2f}",
            "y" if r["memory"]["fits_16gb"] else "N",
        ])
    widths = [max(len(str(row[i])) for row in [hdr] + rows)
              for i in range(len(hdr))]
    lines = []
    sep = " | " if markdown else "  "
    line = sep.join(h.ljust(w) for h, w in zip(hdr, widths))
    lines.append(("| " + line + " |") if markdown else line)
    if markdown:
        lines.append("|" + "|".join("-" * (w + 2) for w in widths) + "|")
    for row in rows:
        line = sep.join(str(c).ljust(w) for c, w in zip(row, widths))
        lines.append(("| " + line + " |") if markdown else line)
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single_16x16")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    recs = load_records(args.mesh)
    if not recs:
        print(f"no dry-run results for mesh {args.mesh}; run "
              f"`python -m repro.launch.dryrun` first")
        return
    print(f"# Roofline — mesh {args.mesh} "
          f"({recs[0].get('chips', '?')} chips, TPU v5e terms)")
    print(table(recs, markdown=args.markdown))
    print(
        "\nroofline% = MODEL_FLOPs / (chips × peak × bound)  — the score; "
        "useful% = MODEL_FLOPs / HLO_FLOPs (remat/padding waste)."
    )


if __name__ == "__main__":
    main()
