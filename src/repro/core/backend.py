"""Execution backends for the submodular-maximization hot paths.

Every algorithm in :mod:`repro.core` evaluates the same few primitives —
``gains`` / ``gains_compact`` (greedy's inner loop, full-width and restricted
to a compacted candidate buffer), ``pairwise_gains`` and ``divergence`` /
``divergence_compact`` (the SS round, paper Def. 2) — but *how* those are
executed depends on where the code runs.  This module is the single dispatch
point:

- ``oracle``  — plain jnp (XLA) on whatever the default device is.  The
  reference semantics; always available.
- ``pallas``  — the fused TPU kernels in :mod:`repro.kernels` (interpret mode
  on CPU).  Every shipped objective provides kernels for every configuration
  (FeatureCoverage with and without ``feat_w``, FacilityLocation, and the
  matrix-free StreamingFacilityLocation, whose kernels compute similarity
  tiles on the fly from embedding rows — see :mod:`repro.kernels.fl_stream`);
  the oracle fallback remains only as the safety net for *future* objectives
  that have not implemented the hooks yet.
- ``sharded`` — shard_map over a device mesh: the whole SS loop runs
  distributed via the per-shard function views declared on the objective
  (see :mod:`repro.core.distributed`).

Selection is by a ``backend=`` argument accepted throughout the stack: a
string (registry lookup), a :class:`Backend` instance (e.g. a
:class:`ShardedBackend` carrying a specific mesh), or None for the default
(the ``REPRO_SS_BACKEND`` environment variable, else ``oracle``).  Backends
are hashable frozen dataclasses so they ride through ``jax.jit`` as static
arguments.

Adding a backend: subclass :class:`Backend`, override the primitives you
accelerate (anything left alone inherits the oracle semantics), then
``register_backend("name", factory)``.  See docs/backends.md for the full
contract, including what a new *objective* must implement to be reachable
from each backend.
"""

from __future__ import annotations

import abc
import dataclasses
import os
from typing import Callable

import jax

from repro.core import graph
from repro.core.functions import SubmodularFunction

Array = jax.Array


def default_pallas_interpret() -> bool:
    """Pallas interpret mode unless we are actually on TPU.

    ``REPRO_PALLAS_INTERPRET=1`` forces interpret mode (CI / CPU correctness
    path); ``=0`` forces the compiled kernel.
    """
    if os.environ.get("REPRO_PALLAS_INTERPRET"):
        return os.environ["REPRO_PALLAS_INTERPRET"] == "1"
    return jax.default_backend() != "tpu"


@dataclasses.dataclass(frozen=True)
class Backend(abc.ABC):
    """Execution strategy for the submodular primitives.

    The base class implements every primitive with the jnp oracle; subclasses
    override what they accelerate.  Instances are immutable and hashable so
    they can be jit-static.
    """

    name = "oracle"

    # -- primitives --------------------------------------------------------
    def gains(self, fn: SubmodularFunction, state, **kw) -> Array:
        """f(v|S) for all v.  Shape (n,)."""
        return fn.gains(state)

    def gains_compact(
        self, fn: SubmodularFunction, state, cand_idx: Array, **kw
    ) -> Array:
        """f(v|S) for the compacted candidate buffer ``cand_idx`` (k,).

        Returns (k,) gains, elementwise equal to ``gains(...)[cand_idx]``.
        The compact selection engine (repro.core.greedy) calls this once per
        greedy step with a bucket-sized static buffer of post-SS survivors so
        per-step cost tracks |V'| instead of n.  The base implementation
        routes through the objective's ``gains_compact`` (whose default is a
        full-width gather — the always-correct oracle fallback)."""
        return fn.gains_compact(state, cand_idx)

    def pairwise_gains(
        self, fn: SubmodularFunction, probes: Array, state=None, **kw
    ) -> Array:
        """f(v | S + u) for u in probes.  Shape (r, n)."""
        return fn.pairwise_gains(probes, state)

    def divergence(
        self,
        fn: SubmodularFunction,
        probes: Array,
        probe_mask: Array | None = None,
        residual: Array | None = None,
        state=None,
        **kw,
    ) -> Array:
        """w_{U,v} = min_{u in U} [f(v|S+u) - f(u|V\\u)] for all v.  (n,)."""
        return graph.divergence(fn, probes, probe_mask, residual, state)

    def divergence_compact(
        self,
        fn: SubmodularFunction,
        probes: Array,
        cand_idx: Array,
        probe_mask: Array | None = None,
        residual: Array | None = None,
        state=None,
        **kw,
    ) -> Array:
        """w_{U,v} for the compacted candidate buffer ``cand_idx`` (k,).

        Returns (k,) divergences, elementwise equal to
        ``divergence(...)[cand_idx]``.  The shrink-aware SS loop calls this
        with a bucket-sized static buffer of live candidates so round cost
        tracks the live count instead of n (see repro.core.sparsify).  The
        base implementation routes through the objective's
        ``pairwise_gains_compact`` (whose default is a full-width gather —
        the always-correct oracle fallback)."""
        return graph.divergence_compact(
            fn, probes, cand_idx, probe_mask, residual, state
        )

    # -- batched primitives (micro-batched serving) ------------------------
    def divergence_batched(
        self,
        fn: SubmodularFunction,
        probes: Array,
        cand_idx: Array | None = None,
        residual: Array | None = None,
        state=None,
        **kw,
    ) -> Array:
        """w_{U_b,v} per batch row for a *stacked* objective.  Shape (B, k).

        ``probes`` is (B, r), ``cand_idx`` (B, k) (full width when None),
        ``residual`` the stacked (B, n) block.  Row b is elementwise equal
        to the *oracle* ``divergence(...)`` / ``divergence_compact(...)`` on
        that row alone — the batched SS loop (repro.core.sparsify) is built
        on this invariance.  The base implementation routes through the
        objective's ``pairwise_gains_batched`` (cache-blocked probe-chunk
        scans on both shipped objectives; the always-correct ``lax.map``
        fallback otherwise).  No backend overrides it yet: a native
        batch-grid pallas kernel is an open ROADMAP item, and on CPU the
        blocked jnp formulation is already the fastest execution of this
        arithmetic.  The interpret-mode kernels happen to match it bitwise
        at shipped feature widths (the parity tests compare exactly);
        compiled-kernel sequential runs are only guaranteed fp-close."""
        return graph.divergence_batched(fn, probes, cand_idx, residual, state)

    def gains_batched(
        self, fn: SubmodularFunction, state, cand_idx: Array | None, **kw
    ) -> Array:
        """f(v|S_b) per batch row for a *stacked* objective and stacked
        states.  Shape (B, k); row b equals ``gains_compact(state[b],
        cand_idx[b])`` (full-width ``gains`` when ``cand_idx`` is None)."""
        return fn.gains_batched(state, cand_idx)

    # -- whole-loop entry points -------------------------------------------
    def sparsify(self, fn: SubmodularFunction, key: Array, **kw):
        """Run SS (Algorithm 1) under this backend.  Returns an SSResult.

        The default runs the dense single-process loop with this backend's
        ``divergence``; the sharded backend overrides the whole loop.
        """
        from repro.core.sparsify import _sparsify_dense

        return _sparsify_dense(fn, key, backend=self, **kw)

    def sparsify_batched(self, fn: SubmodularFunction, keys: Array, **kw):
        """Run SS for B same-shape queries (a *stacked* objective) as one
        compiled loop.  Returns a batched SSResult (leading B axis on every
        field); row b is identical to ``sparsify`` on that query alone under
        the same key.  The sharded backend owns the whole mesh per query and
        does not batch."""
        from repro.core.sparsify import _sparsify_batched

        return _sparsify_batched(fn, keys, backend=self, **kw)

    def greedy(self, fn: SubmodularFunction, k: int, **kw):
        """Run exact greedy under this backend.  Returns a GreedyResult.

        The default resolves the compact-selection plan and runs the dense
        per-step loop with this backend's ``gains`` / ``gains_compact``; the
        sharded backend overrides the whole loop with the distributed argmax
        (see repro.core.distributed.greedy_sharded).
        """
        from repro.core.greedy import _greedy_dense

        return _greedy_dense(fn, k, backend=self, **kw)

    def stochastic_greedy(self, fn: SubmodularFunction, k: int, key: Array, **kw):
        """Run stochastic greedy [Mirzasoleiman et al.] under this backend.

        The default runs the dense single-process loop (compact candidate
        buffer when ``alive`` is sparse) with this backend's ``gains`` /
        ``gains_compact``; the sharded backend overrides the whole loop with
        the distributed sampler.  Returns a GreedyResult.
        """
        from repro.core.greedy import _stochastic_greedy_dense

        return _stochastic_greedy_dense(fn, k, key, backend=self, **kw)


@dataclasses.dataclass(frozen=True)
class OracleBackend(Backend):
    """Reference jnp semantics — inherits every primitive unchanged."""

    name = "oracle"


@dataclasses.dataclass(frozen=True)
class PallasBackend(Backend):
    """Fused Pallas kernels.

    ``interpret=None`` auto-detects (interpret mode off-TPU, honoring
    ``REPRO_PALLAS_INTERPRET``).  Objectives advertise kernel support via
    their ``pallas_divergence`` / ``pallas_gains`` hooks; both shipped
    objectives implement them for every configuration, so nothing falls back
    in-tree — a ``None`` return from an objective that has no kernel still
    drops to the oracle path, keeping the backend always safe to select.
    """

    name = "pallas"
    interpret: bool | None = None

    def _interpret(self) -> bool:
        if self.interpret is None:
            return default_pallas_interpret()
        return self.interpret

    def gains(self, fn: SubmodularFunction, state, **kw) -> Array:
        out = fn.pallas_gains(state, interpret=self._interpret(), **kw)
        return fn.gains(state) if out is None else out

    def gains_compact(
        self, fn: SubmodularFunction, state, cand_idx: Array, **kw
    ) -> Array:
        out = fn.pallas_gains(
            state, interpret=self._interpret(), cand_idx=cand_idx, **kw
        )
        return fn.gains_compact(state, cand_idx) if out is None else out

    def divergence(
        self,
        fn: SubmodularFunction,
        probes: Array,
        probe_mask: Array | None = None,
        residual: Array | None = None,
        state=None,
        **kw,
    ) -> Array:
        if residual is None:
            residual = fn.residual_gains()
        out = fn.pallas_divergence(
            probes, residual, state, probe_mask,
            interpret=self._interpret(), **kw,
        )
        if out is None:
            return graph.divergence(fn, probes, probe_mask, residual, state)
        return out

    def divergence_compact(
        self,
        fn: SubmodularFunction,
        probes: Array,
        cand_idx: Array,
        probe_mask: Array | None = None,
        residual: Array | None = None,
        state=None,
        **kw,
    ) -> Array:
        if residual is None:
            residual = fn.residual_gains()
        out = fn.pallas_divergence(
            probes, residual, state, probe_mask,
            interpret=self._interpret(), cand_idx=cand_idx, **kw,
        )
        if out is None:
            return graph.divergence_compact(
                fn, probes, cand_idx, probe_mask, residual, state
            )
        return out


@dataclasses.dataclass(frozen=True)
class ShardedBackend(Backend):
    """shard_map execution over a device mesh.

    ``sparsify`` runs the whole SS loop distributed (collectives over
    ``data_axis``; optional per-pod hierarchy over ``pod_axis``) — see
    :func:`repro.core.distributed.ss_sparsify_sharded`.  The per-call
    primitives (``gains`` etc.) inherit the oracle path: after SS the
    surviving ground set is polylog-sized, so greedy's inner loop does not
    benefit from sharding.

    ``mesh=None`` builds a 1-D mesh over all visible devices at call time.
    """

    name = "sharded"
    mesh: jax.sharding.Mesh | None = None
    data_axis: str = "data"
    pod_axis: str | None = None
    bins: int = 512

    def _mesh(self) -> jax.sharding.Mesh:
        if self.mesh is not None:
            return self.mesh
        from repro.compat import make_mesh

        return make_mesh((jax.device_count(),), (self.data_axis,))

    def sparsify(self, fn: SubmodularFunction, key: Array, **kw):
        from repro.core import distributed

        return distributed.ss_sparsify_sharded(
            fn, key, self._mesh(),
            data_axis=self.data_axis, pod_axis=self.pod_axis,
            bins=self.bins, **kw,
        )

    def sparsify_batched(self, fn: SubmodularFunction, keys: Array, **kw):
        raise NotImplementedError(
            "the sharded backend owns the whole mesh per query and does not "
            "micro-batch; use backend='oracle' or 'pallas' for the batched "
            "serving path"
        )

    def greedy(self, fn: SubmodularFunction, k: int, **kw):
        from repro.core import distributed

        alive = kw.get("alive")
        mesh = None if self.pod_axis else self._mesh()
        if (
            mesh is None
            or not fn.supports_shard_greedy
            or fn.n % mesh.shape[self.data_axis] != 0
            or isinstance(alive, jax.core.Tracer)
        ):
            # Distributed exact greedy needs the shard selection hooks, a
            # shard-divisible ground set, and a concrete mask (the live count
            # sizes its static buffers), and is single-level; otherwise fall
            # back to the dense loop — the pre-distributed behavior, always
            # correct.
            return super().greedy(fn, k, **kw)
        return distributed.greedy_sharded(
            fn, k, mesh, data_axis=self.data_axis, **kw
        )

    def stochastic_greedy(self, fn: SubmodularFunction, k: int, key: Array, **kw):
        from repro.core import distributed

        if self.pod_axis:
            raise NotImplementedError(
                "sharded stochastic greedy is single-level (the selection "
                "stage is global); use a data-axis-only ShardedBackend"
            )
        return distributed.stochastic_greedy_sharded(
            fn, k, key, self._mesh(), data_axis=self.data_axis, **kw
        )


# -- registry ---------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], Backend]] = {}
_INSTANCES: dict[str, Backend] = {}


def register_backend(name: str, factory: Callable[[], Backend]) -> None:
    """Register (or replace) a backend factory under ``name``."""
    _REGISTRY[name] = factory
    _INSTANCES.pop(name, None)


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_backend(name: str) -> Backend:
    """Singleton backend instance for a registered name."""
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown backend {name!r}; available: {available_backends()}"
        )
    if name not in _INSTANCES:
        _INSTANCES[name] = _REGISTRY[name]()
    return _INSTANCES[name]


def resolve_backend(spec: "str | Backend | None" = None) -> Backend:
    """Resolve a ``backend=`` argument: Backend instance (as-is), registry
    name, or None -> ``$REPRO_SS_BACKEND`` else ``oracle``."""
    if isinstance(spec, Backend):
        return spec
    if spec is None:
        spec = os.environ.get("REPRO_SS_BACKEND", "oracle")
    if isinstance(spec, str):
        return get_backend(spec)
    raise TypeError(f"backend must be a name, Backend, or None; got {spec!r}")


register_backend("oracle", OracleBackend)
register_backend("pallas", PallasBackend)
register_backend("sharded", ShardedBackend)
