"""Submodular objective functions with batched, TPU-friendly marginal-gain APIs.

Every function here exposes the same vectorized protocol, built around a compact
*state* that summarizes the current solution set ``S`` so that marginal gains
``f(v|S)`` for **all** candidates ``v`` are computed in one dense, matmul-shaped
operation (no per-element Python loops — the TPU adaptation of the paper's
per-pair function evaluations, see DESIGN.md §3):

- ``empty_state()``             -> state for S = ∅
- ``value(state)``              -> f(S)
- ``gains(state)``              -> (n,) vector of f(v|S) for every v in V
- ``add(state, v)``             -> state for S + v          (rank-1 update)
- ``add_many(state, mask)``     -> state for S + {v : mask[v]}
- ``pairwise_gains(probes, state)`` -> (r, n) matrix of f(v | S + u) for u in probes
- ``residual_gains()``          -> (n,) vector of f(v | V \\ v)
- ``singleton_gains()``         -> (n,) vector of f(v)  ( = gains(empty_state()) )

``pairwise_gains`` + ``residual_gains`` are exactly the ingredients of the
submodularity-graph edge weight  w_{u->v} = f(v|u) - f(u|V\\u)  (paper Eq. 3) and
its conditional version w_{uv|S} (paper Eq. 4).

Implemented objectives:

- :class:`FeatureCoverage` — the paper's experimental objective
  ``f(S) = sum_feat phi(c_feat(S))`` with ``c_feat(S) = sum_{v in S} W[v,feat]``
  and a concave ``phi`` (sqrt by default).  With ``phi="setcover"`` this is
  weighted set cover; with ``phi="satcov"`` it is saturated coverage
  ``min(c, alpha * c_total)``.
- :class:`FacilityLocation` — ``f(S) = sum_i max_{s in S} sim(i, s)``.

All classes are registered pytrees, so they can be passed through jit/shard_map
boundaries; static (non-array) config lives in the pytree aux data.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array

# Large-but-finite negative used to mask out dead candidates in argmax/min ops.
# (Using -inf can poison min/where chains under fast-math; this is safer.)
NEG = -1e30


def _phi(kind: str, c: Array, cap: Array | None) -> Array:
    """Concave scalar transforms phi(c), applied elementwise to coverage."""
    if kind == "sqrt":
        return jnp.sqrt(jnp.maximum(c, 0.0))
    if kind == "log1p":
        return jnp.log1p(jnp.maximum(c, 0.0))
    if kind == "setcover":
        return jnp.minimum(c, 1.0)
    if kind == "satcov":
        assert cap is not None
        return jnp.minimum(c, cap)
    if kind == "linear":  # modular (for testing: submodular with equality)
        return c
    raise ValueError(f"unknown concave transform {kind!r}")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class FeatureCoverage:
    """Feature-based concave-over-modular coverage function (paper §4).

    f(S) = sum_f  w_f * phi( c_f(S) ),   c_f(S) = sum_{v in S} W[v, f]

    ``W`` is the (n, n_features) nonnegative affinity matrix (e.g. TFIDF).
    ``feat_w`` optionally weights features.  ``phi`` is one of
    {"sqrt", "log1p", "setcover", "satcov", "linear"}.

    The *state* is the coverage vector c in R^{n_features}.
    """

    W: Array                    # (n, F) nonnegative
    feat_w: Array | None = None  # (F,) or None
    phi: str = "sqrt"
    alpha: float = 0.2          # saturation fraction for phi="satcov"

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        return (self.W, self.feat_w), (self.phi, self.alpha)

    @classmethod
    def tree_unflatten(cls, aux, children):
        W, feat_w = children
        phi, alpha = aux
        return cls(W=W, feat_w=feat_w, phi=phi, alpha=alpha)

    # -- protocol ----------------------------------------------------------
    @property
    def n(self) -> int:
        return self.W.shape[0]

    def _cap(self) -> Array | None:
        if self.phi != "satcov":
            return None
        return self.alpha * jnp.sum(self.W, axis=0)

    def _wsum(self, x: Array) -> Array:
        """Weighted sum over the trailing feature axis."""
        if self.feat_w is not None:
            x = x * self.feat_w
        return jnp.sum(x, axis=-1)

    def empty_state(self) -> Array:
        return jnp.zeros((self.W.shape[1],), dtype=self.W.dtype)

    def value(self, state: Array) -> Array:
        return self._wsum(_phi(self.phi, state, self._cap()))

    def gains(self, state: Array) -> Array:
        """f(v|S) for all v: sum_f [phi(c + W_v) - phi(c)].  Shape (n,)."""
        cap = self._cap()
        return self._wsum(
            _phi(self.phi, state[None, :] + self.W, cap)
            - _phi(self.phi, state[None, :], cap)
        )

    def add(self, state: Array, v: Array) -> Array:
        return state + self.W[v]

    def add_many(self, state: Array, mask: Array) -> Array:
        return state + mask.astype(self.W.dtype) @ self.W

    def pairwise_gains(self, probes: Array, state: Array | None = None) -> Array:
        """f(v | S + u) for u in probes (r,), all v.  Shape (r, n).

        This is the hot spot of submodular sparsification: an (r, n, F)
        computation reduced over F.  The Pallas kernel in
        ``repro.kernels.ss_weights`` fuses it with the edge-weight min; this
        jnp version is the oracle / CPU path.
        """
        base = self.empty_state() if state is None else state
        cap = self._cap()
        cu = base[None, :] + self.W[probes]                      # (r, F)
        phi_cu = self._wsum(_phi(self.phi, cu, cap))             # (r,)
        # (r, n, F) intermediate — fused away in the Pallas kernel.
        both = cu[:, None, :] + self.W[None, :, :]
        out = self._wsum(_phi(self.phi, both, cap)) - phi_cu[:, None]
        # Set semantics: f(u | S + u) = 0 (coverage state is a sum, so the
        # diagonal v == probe would otherwise double-count W[u]).
        v_eq_u = probes[:, None] == jnp.arange(self.n)[None, :]
        return jnp.where(v_eq_u, 0.0, out)

    def residual_gains(self) -> Array:
        """f(v | V \\ v) = sum_f [phi(C) - phi(C - W_v)] for all v.  Shape (n,)."""
        cap = self._cap()
        C = jnp.sum(self.W, axis=0)                              # (F,)
        return self._wsum(
            _phi(self.phi, C[None, :], cap)
            - _phi(self.phi, C[None, :] - self.W, cap)
        )

    def singleton_gains(self) -> Array:
        return self.gains(self.empty_state())


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class FacilityLocation:
    """Facility location: f(S) = sum_i max(0, max_{s in S} sim[i, s]).

    ``sim`` is the (n, n) similarity matrix (assumed nonnegative for
    monotonicity; negative entries are clipped at 0 by the implicit "serve
    yourself at 0" baseline, which also normalizes f(∅)=0).

    The *state* is the per-row current best coverage m in R^n,
    m_i = max(0, max_{s in S} sim[i, s]).
    """

    sim: Array  # (n, n)

    def tree_flatten(self):
        return (self.sim,), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(sim=children[0])

    @classmethod
    def from_features(cls, X: Array, kernel: str = "dot") -> "FacilityLocation":
        if kernel == "dot":
            sim = jnp.maximum(X @ X.T, 0.0)
        elif kernel == "rbf":
            d2 = (
                jnp.sum(X * X, axis=1)[:, None]
                - 2.0 * X @ X.T
                + jnp.sum(X * X, axis=1)[None, :]
            )
            sim = jnp.exp(-d2 / jnp.maximum(jnp.mean(d2), 1e-9))
        elif kernel == "cosine":
            Xn = X / jnp.maximum(jnp.linalg.norm(X, axis=1, keepdims=True), 1e-9)
            sim = jnp.maximum(Xn @ Xn.T, 0.0)
        else:
            raise ValueError(kernel)
        return cls(sim=sim)

    @property
    def n(self) -> int:
        return self.sim.shape[0]

    def empty_state(self) -> Array:
        return jnp.zeros((self.sim.shape[0],), dtype=self.sim.dtype)

    def value(self, state: Array) -> Array:
        return jnp.sum(state)

    def gains(self, state: Array) -> Array:
        # f(v|S) = sum_i max(sim[i, v] - m_i, 0) -> column reduction of (n, n)
        return jnp.sum(jnp.maximum(self.sim - state[:, None], 0.0), axis=0)

    def add(self, state: Array, v: Array) -> Array:
        return jnp.maximum(state, self.sim[:, v])

    def add_many(self, state: Array, mask: Array) -> Array:
        masked = jnp.where(mask[None, :], self.sim, NEG)
        return jnp.maximum(state, jnp.max(masked, axis=1))

    def pairwise_gains(self, probes: Array, state: Array | None = None) -> Array:
        base = self.empty_state() if state is None else state
        mu = jnp.maximum(base[None, :], self.sim[:, probes].T)   # (r, n) rows=probe cov
        # f(v | S+u) = sum_i max(sim[i, v] - mu[u, i], 0)
        return jnp.sum(
            jnp.maximum(self.sim.T[None, :, :] - mu[:, None, :], 0.0), axis=-1
        )

    def residual_gains(self) -> Array:
        # f(V) - f(V \ v) per v: only rows where v is the unique argmax lose,
        # dropping to the second-best. Use top-2 per row.
        top2 = jax.lax.top_k(self.sim, 2)[0]                     # (n, 2)
        best, second = top2[:, 0], top2[:, 1]
        is_best = self.sim >= best[:, None]                      # ties: no loss
        tie = jnp.sum(is_best, axis=1) > 1
        loss_per_row = jnp.where(tie, 0.0, jnp.maximum(best, 0.0) - jnp.maximum(second, 0.0))
        return jnp.sum(jnp.where(is_best, loss_per_row[:, None], 0.0), axis=0)

    def singleton_gains(self) -> Array:
        return self.gains(self.empty_state())


SubmodularFunction = Any  # structural protocol: FeatureCoverage | FacilityLocation
