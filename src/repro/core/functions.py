"""Submodular objective functions with batched, TPU-friendly marginal-gain APIs.

Every objective subclasses :class:`SubmodularFunction`, a formal abstract base
built around a compact *state* that summarizes the current solution set ``S``
so that marginal gains ``f(v|S)`` for **all** candidates ``v`` are computed in
one dense, matmul-shaped operation (no per-element Python loops — the TPU
adaptation of the paper's per-pair function evaluations, see DESIGN.md §3):

- ``empty_state()``             -> state for S = ∅
- ``value(state)``              -> f(S)
- ``gains(state)``              -> (n,) vector of f(v|S) for every v in V
- ``add(state, v)``             -> state for S + v          (rank-1 update)
- ``add_many(state, mask)``     -> state for S + {v : mask[v]}
- ``pairwise_gains(probes, state)`` -> (r, n) matrix of f(v | S + u) for u in probes
- ``residual_gains()``          -> (n,) vector of f(v | V \\ v)
- ``singleton_gains()``         -> (n,) vector of f(v)  ( = gains(empty_state()) )

``pairwise_gains`` + ``residual_gains`` are exactly the ingredients of the
submodularity-graph edge weight  w_{u->v} = f(v|u) - f(u|V\\u)  (paper Eq. 3) and
its conditional version w_{uv|S} (paper Eq. 4).

Beyond the core protocol, the base class defines two groups of *optional*
execution hooks consumed by :mod:`repro.core.backend` (see docs/backends.md):

- **Pallas hooks** (``pallas_divergence`` / ``pallas_gains``) let an objective
  provide a fused-kernel implementation of the SS hot spots; returning ``None``
  (the default) makes the pallas backend fall back to the jnp oracle.
- **Shard hooks** (``shard_pack`` / ``local_n`` / ``shard_init`` /
  ``shard_residuals`` / ``shard_payloads`` / ``shard_payload_gains``) describe
  a per-shard *function view*: how the objective's arrays are partitioned over
  a mesh and how each device computes residuals and probe-conditioned gains for
  its local slice of the ground set.  Any objective implementing them runs
  under the sharded SS loop in :mod:`repro.core.distributed` unchanged.

Implemented objectives:

- :class:`FeatureCoverage` — the paper's experimental objective
  ``f(S) = sum_feat phi(c_feat(S))`` with ``c_feat(S) = sum_{v in S} W[v,feat]``
  and a concave ``phi`` (sqrt by default).  With ``phi="setcover"`` this is
  weighted set cover; with ``phi="satcov"`` it is saturated coverage
  ``min(c, alpha * c_total)``.
- :class:`FacilityLocation` — ``f(S) = sum_i max_{s in S} sim(i, s)``.
- :class:`StreamingFacilityLocation` — the same objective, matrix-free: it
  stores only the (n, d) embedding rows and computes similarity tiles
  ``relu(X_blk @ X_blkᵀ)`` on the fly inside every reduction
  (:mod:`repro.kernels.fl_stream`), so no path ever materializes ``(n, n)``.
  This is the objective for 64k+ ground sets where dense
  ``FacilityLocation.from_features`` cannot even allocate its sim matrix.

All classes are registered pytrees, so they can be passed through jit/shard_map
boundaries; static (non-array) config lives in the pytree aux data.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array

# Large-but-finite negative used to mask out dead candidates in argmax/min ops.
# (Using -inf can poison min/where chains under fast-math; this is safer.)
NEG = -1e30


def _map_pairwise_rows(fn, probes, cand_idx, state, row_call):
    """``lax.map`` a per-row probe-gains computation over a *stacked*
    objective — one row in flight at a time, so peak memory matches the
    sequential path.  ``row_call(fn_row, probes_row, cand_row|None,
    state_row|None)`` does the per-row work; None-valued cand_idx/state are
    threaded as None rather than mapped."""
    def row(args):
        fn_b, rest = args[0], args[1:]
        ci = rest[1] if cand_idx is not None else None
        st = rest[-1] if state is not None else None
        return row_call(fn_b, rest[0], ci, st)

    xs: tuple = (fn, probes)
    if cand_idx is not None:
        xs = xs + (cand_idx,)
    if state is not None:
        xs = xs + (state,)
    return jax.lax.map(row, xs)


def _phi(kind: str, c: Array, cap: Array | None) -> Array:
    """Concave scalar transforms phi(c), applied elementwise to coverage."""
    if kind == "sqrt":
        return jnp.sqrt(jnp.maximum(c, 0.0))
    if kind == "log1p":
        return jnp.log1p(jnp.maximum(c, 0.0))
    if kind == "setcover":
        return jnp.minimum(c, 1.0)
    if kind == "satcov":
        assert cap is not None
        return jnp.minimum(c, cap)
    if kind == "linear":  # modular (for testing: submodular with equality)
        return c
    raise ValueError(f"unknown concave transform {kind!r}")


class SubmodularFunction(abc.ABC):
    """Abstract base for monotone submodular objectives over n ground elements.

    Subclasses must be registered pytrees (array leaves, static config in aux)
    so instances cross jit / shard_map boundaries.  The abstract core protocol
    is what every algorithm in :mod:`repro.core` consumes; the ``pallas_*`` and
    ``shard_*`` hooks are optional capability extensions used by the execution
    backends in :mod:`repro.core.backend`.
    """

    # -- core protocol (required) ------------------------------------------
    @property
    @abc.abstractmethod
    def n(self) -> int:
        """Ground-set size."""

    @abc.abstractmethod
    def empty_state(self) -> Any:
        """Summary state for S = ∅."""

    @abc.abstractmethod
    def value(self, state: Any) -> Array:
        """f(S) from the summary state."""

    @abc.abstractmethod
    def gains(self, state: Any) -> Array:
        """f(v|S) for all v.  Shape (n,)."""

    @abc.abstractmethod
    def add(self, state: Any, v: Array) -> Any:
        """State for S + v (rank-1 update)."""

    @abc.abstractmethod
    def add_many(self, state: Any, mask: Array) -> Any:
        """State for S + {v : mask[v]}."""

    @abc.abstractmethod
    def pairwise_gains(self, probes: Array, state: Any | None = None) -> Array:
        """f(v | S + u) for u in probes (r,), all v.  Shape (r, n)."""

    @abc.abstractmethod
    def residual_gains(self) -> Array:
        """f(v | V \\ v) for all v.  Shape (n,)."""

    def singleton_gains(self) -> Array:
        """f(v) for all v ( = gains on the empty state)."""
        return self.gains(self.empty_state())

    # -- compaction (optional override, always correct) --------------------
    # The SS loop's live set shrinks geometrically; the compacted execution
    # path (see repro.core.sparsify) evaluates probe-conditioned gains only
    # for a gathered buffer of surviving candidates.  The base implementation
    # computes the full (r, n) block and gathers — correct for any objective;
    # override it to actually skip the dead-candidate work (both shipped
    # objectives do).

    def pairwise_gains_compact(
        self, probes: Array, cand_idx: Array, state: Any | None = None
    ) -> Array:
        """f(v | S + u) for u in probes (r,) and v = cand_idx (k,).  (r, k).

        ``cand_idx`` holds ground indices of the compacted candidate buffer
        (padding entries may repeat a valid index; callers mask them out).
        """
        return jnp.take(self.pairwise_gains(probes, state), cand_idx, axis=1)

    def gains_compact(self, state: Any, cand_idx: Array) -> Array:
        """f(v|S) for v = cand_idx (k,).  Shape (k,).

        The selection-engine analogue of ``pairwise_gains_compact``: greedy's
        per-step gains restricted to the compacted candidate buffer (ground
        indices; padding entries may repeat a valid index — callers mask).
        The base implementation is a full-width compute + gather — always
        correct; override it so per-step cost scales with k, not n (both
        shipped objectives do)."""
        return jnp.take(self.gains(state), cand_idx)

    # -- micro-batching (optional override, always correct) ----------------
    # The serving engine (repro.serve.summarize_service) runs B independent
    # queries of identical shape as one *stacked* objective: the same pytree
    # class with a leading batch axis on every array leaf.  A stacked
    # instance is NOT a valid single objective (``n`` etc. read the wrong
    # axis); only the ``*_batched`` hooks below may be called on it.  The
    # base implementations map the per-row compact hooks over the batch with
    # ``lax.map`` — one row in flight at a time, so peak memory matches the
    # sequential path — and are therefore always correct for any objective.
    # Both shipped objectives override with probe-chunked row computations
    # that stay cache-resident (never materializing the (r, k, F) block),
    # which is what makes the batched engine faster than a sequential loop
    # of per-query calls on every platform.

    def pairwise_gains_batched(
        self, probes: Array, cand_idx: Array | None, state: Any | None = None
    ) -> Array:
        """f(v | S_b + u) per batch row b, probes u (B, r), candidates
        v = cand_idx (B, k) (or the full ground set when None).  (B, r, k).

        ``self`` is a stacked objective.  Row semantics are exactly
        ``pairwise_gains_compact(probes[b], cand_idx[b], state[b])``."""
        return _map_pairwise_rows(
            self, probes, cand_idx, state,
            lambda f, p, ci, st: (
                f.pairwise_gains(p, st) if ci is None
                else f.pairwise_gains_compact(p, ci, st)
            ),
        )

    def gains_batched(self, state: Any, cand_idx: Array | None) -> Array:
        """f(v|S_b) per batch row b for v = cand_idx (B, k) (full ground set
        when None).  Shape (B, k).  ``self`` is a stacked objective; row
        semantics are exactly ``gains_compact(state[b], cand_idx[b])``."""
        if cand_idx is None:
            return jax.vmap(lambda f, s: f.gains(s))(self, state)
        return jax.vmap(lambda f, s, ci: f.gains_compact(s, ci))(
            self, state, cand_idx
        )

    # -- pallas hooks (optional) -------------------------------------------
    # Returning None means "no fused kernel for this configuration"; the
    # pallas backend then falls back to the jnp oracle.  ``interpret`` selects
    # Pallas interpret mode (CPU correctness path) vs. the compiled TPU kernel.

    def pallas_divergence(
        self,
        probes: Array,
        residual: Array,
        state: Any | None = None,
        probe_mask: Array | None = None,
        *,
        interpret: bool,
        cand_idx: Array | None = None,
        **block_kw,
    ) -> Array | None:
        """Fused divergence w_{U,v} (paper Def. 2) for all v, or None.

        With ``cand_idx`` (k,) the output is restricted to the compacted
        candidate buffer — shape (k,) instead of (n,) — and the kernel grid
        should only cover the gathered candidates.  Returning None for a
        non-None ``cand_idx`` drops the pallas backend to the oracle gather
        path (always correct, never faster)."""
        return None

    def pallas_gains(
        self,
        state: Any,
        *,
        interpret: bool,
        cand_idx: Array | None = None,
        **block_kw,
    ) -> Array | None:
        """Fused greedy gains f(v|S) for all v, or None.

        With ``cand_idx`` (k,) the output is restricted to the compacted
        candidate buffer — shape (k,) — and the kernel grid should only
        cover the gathered candidates.  Returning None for a non-None
        ``cand_idx`` drops the pallas backend to the oracle
        ``gains_compact`` path (always correct, never faster)."""
        return None

    # -- shard hooks (optional) --------------------------------------------
    # Together these define a per-shard *function view*: `shard_pack` says how
    # the objective's arrays are laid out over the mesh; the remaining hooks
    # are called *inside* shard_map on the rebuilt local view, where array
    # leaves hold only this device's slice of the ground set.

    #: whether per-pod hierarchical sharding (a standalone ground set per pod)
    #: is supported — requires the objective's arrays to be row-local.
    supports_pod_sharding: bool = False

    #: whether the local view supports candidate restriction via
    #: :meth:`shard_take` — required for the sharded loop's live-set
    #: compaction (the loop silently runs uncompacted otherwise).
    supports_shard_compact: bool = False

    #: whether the local view supports the sharded *selection* stage
    #: (:func:`repro.core.distributed.stochastic_greedy_sharded`) — requires
    #: :meth:`shard_gains` / :meth:`shard_add` over a *replicated* summary
    #: state, plus :meth:`shard_take`.
    supports_shard_greedy: bool = False

    def shard_pack(
        self, axes: Sequence[str]
    ) -> tuple[tuple[Array, ...], tuple[P, ...], Callable[..., "SubmodularFunction"]]:
        """(arrays, partition specs, rebuild) for entering shard_map.

        ``arrays`` are the objective's array leaves, ``specs`` their
        PartitionSpecs over mesh ``axes`` (candidate dimension sharded), and
        ``rebuild(*local_arrays)`` reconstructs the local function view inside
        the shard_map body.
        """
        raise NotImplementedError(
            f"{type(self).__name__} does not implement the sharded protocol"
        )

    def local_n(self) -> int:
        """Number of *local* candidates held by this shard view."""
        raise NotImplementedError

    def shard_init(self, axis: str) -> Any:
        """One-time collective setup: pod-global context (psum/all_gather over
        ``axis``) reused by shard_residuals / shard_payload_gains."""
        raise NotImplementedError

    def shard_residuals(self, ctx: Any) -> Array:
        """f(u | V \\ u) for the local candidates.  Shape (n_local,)."""
        raise NotImplementedError

    def shard_payloads(self, idx: Array, state: Any | None = None) -> Array:
        """Payload rows for local candidate indices ``idx`` (k,) — a compact
        description of each probe sufficient for any shard to evaluate
        probe-conditioned gains.  Shape (k, payload_dim).

        ``state`` (a *replicated* summary state, or None for S = ∅) folds the
        conditional context into the payload, so ``shard_payload_gains`` on
        a state-conditioned payload evaluates f(v | S + u) — the sharded
        analogue of ``pairwise_gains(probes, state)``."""
        raise NotImplementedError

    def shard_payload_gains(self, payloads: Array, ctx: Any) -> Array:
        """f(v | S + u) for gathered probe ``payloads`` (m, payload_dim) and
        all local candidates v, where S is whatever state the payloads were
        built with (∅ by default).  Shape (m, n_local)."""
        raise NotImplementedError

    def shard_take(self, cand_idx: Array) -> "SubmodularFunction":
        """Local view restricted to the local candidate subset ``cand_idx``
        (k,) — ``shard_payload_gains`` on the returned view must produce the
        (m, k) gather of the full view's (m, n_local) output.  Must be
        collective-free (it runs inside data-dependent ``lax.switch``
        branches).  Only required when ``supports_shard_compact``."""
        raise NotImplementedError

    def shard_gains(self, state: Any, ctx: Any) -> Array:
        """f(v|S) for the local candidates, from a *replicated* summary state.

        Must be elementwise identical arithmetic to the dense ``gains`` /
        ``gains_compact`` (the sharded selection loop asserts same-key
        selection parity against the dense compact path).  ``ctx`` is the
        ``shard_init`` context (pod-global quantities such as the satcov
        cap).  Shape (n_local,).  Only required when
        ``supports_shard_greedy``."""
        raise NotImplementedError

    def shard_add(self, state: Any, v: Array, ctx: Any) -> Any:
        """Replicated state for S + v, ``v`` a *local* candidate index.
        Must match the dense ``add`` on the corresponding ground index
        bitwise.  Only required when ``supports_shard_greedy``."""
        raise NotImplementedError


def _row_spec(axes: Sequence[str]) -> P:
    return P(tuple(axes) if len(axes) > 1 else axes[0], None)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class FeatureCoverage(SubmodularFunction):
    """Feature-based concave-over-modular coverage function (paper §4).

    f(S) = sum_f  w_f * phi( c_f(S) ),   c_f(S) = sum_{v in S} W[v, f]

    ``W`` is the (n, n_features) nonnegative affinity matrix (e.g. TFIDF).
    ``feat_w`` optionally weights features.  ``phi`` is one of
    {"sqrt", "log1p", "setcover", "satcov", "linear"}.

    The *state* is the coverage vector c in R^{n_features}.
    """

    W: Array                    # (n, F) nonnegative
    feat_w: Array | None = None  # (F,) or None
    phi: str = "sqrt"
    alpha: float = 0.2          # saturation fraction for phi="satcov"

    supports_pod_sharding = True
    supports_shard_compact = True
    supports_shard_greedy = True

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        return (self.W, self.feat_w), (self.phi, self.alpha)

    @classmethod
    def tree_unflatten(cls, aux, children):
        W, feat_w = children
        phi, alpha = aux
        return cls(W=W, feat_w=feat_w, phi=phi, alpha=alpha)

    # -- protocol ----------------------------------------------------------
    @property
    def n(self) -> int:
        return self.W.shape[0]

    def _cap(self) -> Array | None:
        if self.phi != "satcov":
            return None
        return self.alpha * jnp.sum(self.W, axis=0)

    def _wsum(self, x: Array) -> Array:
        """Weighted sum over the trailing feature axis."""
        if self.feat_w is not None:
            x = x * self.feat_w
        return jnp.sum(x, axis=-1)

    def empty_state(self) -> Array:
        return jnp.zeros((self.W.shape[1],), dtype=self.W.dtype)

    def value(self, state: Array) -> Array:
        return self._wsum(_phi(self.phi, state, self._cap()))

    def gains(self, state: Array) -> Array:
        """f(v|S) for all v: sum_f [phi(c + W_v) - phi(c)].  Shape (n,)."""
        cap = self._cap()
        return self._wsum(
            _phi(self.phi, state[None, :] + self.W, cap)
            - _phi(self.phi, state[None, :], cap)
        )

    def add(self, state: Array, v: Array) -> Array:
        return state + self.W[v]

    def add_many(self, state: Array, mask: Array) -> Array:
        return state + mask.astype(self.W.dtype) @ self.W

    def pairwise_gains(self, probes: Array, state: Array | None = None) -> Array:
        """f(v | S + u) for u in probes (r,), all v.  Shape (r, n).

        This is the hot spot of submodular sparsification: an (r, n, F)
        computation reduced over F.  The Pallas kernel in
        ``repro.kernels.ss_weights`` fuses it with the edge-weight min; this
        jnp version is the oracle / CPU path.
        """
        base = self.empty_state() if state is None else state
        cap = self._cap()
        cu = base[None, :] + self.W[probes]                      # (r, F)
        phi_cu = self._wsum(_phi(self.phi, cu, cap))             # (r,)
        # (r, n, F) intermediate — fused away in the Pallas kernel.
        both = cu[:, None, :] + self.W[None, :, :]
        out = self._wsum(_phi(self.phi, both, cap)) - phi_cu[:, None]
        # Set semantics: f(u | S + u) = 0 (coverage state is a sum, so the
        # diagonal v == probe would otherwise double-count W[u]).
        v_eq_u = probes[:, None] == jnp.arange(self.n)[None, :]
        return jnp.where(v_eq_u, 0.0, out)

    def residual_gains(self) -> Array:
        """f(v | V \\ v) = sum_f [phi(C) - phi(C - W_v)] for all v.  Shape (n,)."""
        cap = self._cap()
        C = jnp.sum(self.W, axis=0)                              # (F,)
        return self._wsum(
            _phi(self.phi, C[None, :], cap)
            - _phi(self.phi, C[None, :] - self.W, cap)
        )

    def pairwise_gains_compact(
        self, probes: Array, cand_idx: Array, state: Array | None = None
    ) -> Array:
        """Compact (r, k, F) block — per-element identical arithmetic to the
        full ``pairwise_gains`` restricted to ``cand_idx``, so the compacted
        SS loop prunes bit-identically to the uncompacted one."""
        base = self.empty_state() if state is None else state
        cap = self._cap()
        cu = base[None, :] + self.W[probes]                      # (r, F)
        phi_cu = self._wsum(_phi(self.phi, cu, cap))             # (r,)
        Wc = jnp.take(self.W, cand_idx, axis=0)                  # (k, F)
        both = cu[:, None, :] + Wc[None, :, :]
        out = self._wsum(_phi(self.phi, both, cap)) - phi_cu[:, None]
        v_eq_u = probes[:, None] == cand_idx[None, :]
        return jnp.where(v_eq_u, 0.0, out)

    def gains_compact(self, state: Array, cand_idx: Array) -> Array:
        """Per-step greedy gains over the gathered candidate rows only —
        per-element identical arithmetic to ``gains`` restricted to
        ``cand_idx``, so compact and full selection pick identical sets."""
        cap = self._cap()
        Wc = jnp.take(self.W, cand_idx, axis=0)                  # (k, F)
        return self._wsum(
            _phi(self.phi, state[None, :] + Wc, cap)
            - _phi(self.phi, state[None, :], cap)
        )

    def _pairwise_gains_chunked(
        self,
        probes: Array,
        cand_idx: Array | None,
        state: Array | None = None,
        probe_chunk: int = 8,
    ) -> Array:
        """Probe-chunked row computation for the batched engine: identical
        per-element arithmetic to ``pairwise_gains_compact``, but the (r, k,
        F) block is never materialized — a ``lax.scan`` over probe chunks
        keeps each (chunk, k, F) slab cache-resident, which on CPU beats the
        full-block formulation severalfold at serving shapes."""
        base = self.empty_state() if state is None else state
        cap = self._cap()
        Wc = self.W if cand_idx is None else jnp.take(self.W, cand_idx, axis=0)
        cand = jnp.arange(self.W.shape[0]) if cand_idx is None else cand_idx
        cu = base[None, :] + self.W[probes]                      # (r, F)
        phi_cu = self._wsum(_phi(self.phi, cu, cap))             # (r,)
        r = probes.shape[0]
        rp = -(-r // probe_chunk) * probe_chunk
        pad = rp - r
        cu_p = jnp.concatenate([cu, jnp.repeat(cu[:1], pad, axis=0)])
        phicu_p = jnp.concatenate([phi_cu, jnp.repeat(phi_cu[:1], pad)])
        probes_p = jnp.concatenate([probes, jnp.repeat(probes[:1], pad)])

        def chunk(_, inp):
            cu_j, phicu_j, probes_j = inp
            both = cu_j[:, None, :] + Wc[None, :, :]             # (PC, k, F)
            out = self._wsum(_phi(self.phi, both, cap)) - phicu_j[:, None]
            v_eq_u = probes_j[:, None] == cand[None, :]
            return None, jnp.where(v_eq_u, 0.0, out)

        _, rows = jax.lax.scan(chunk, None, (
            cu_p.reshape(-1, probe_chunk, cu.shape[-1]),
            phicu_p.reshape(-1, probe_chunk),
            probes_p.reshape(-1, probe_chunk),
        ))
        return rows.reshape(rp, -1)[:r]

    def pairwise_gains_batched(
        self, probes: Array, cand_idx: Array | None, state: Array | None = None
    ) -> Array:
        """(B, r, k) batched block via the cache-blocked chunked rows."""
        return _map_pairwise_rows(
            self, probes, cand_idx, state,
            lambda f, p, ci, st: f._pairwise_gains_chunked(p, ci, st),
        )

    # -- pallas hooks ------------------------------------------------------
    def pallas_divergence(
        self,
        probes: Array,
        residual: Array,
        state: Array | None = None,
        probe_mask: Array | None = None,
        *,
        interpret: bool,
        cand_idx: Array | None = None,
        **block_kw,
    ) -> Array | None:
        from repro.kernels.ss_weights import ss_divergence_kernel

        base = self.empty_state() if state is None else state
        cap = self._cap()
        CU = base[None, :] + self.W[probes]                      # (r, F)
        # The kernel carries feat_w through the phi-reduction, so the probe
        # baseline must be the same weighted sum.
        phi_cu = self._wsum(_phi(self.phi, CU.astype(jnp.float32), cap))
        resid = residual[probes]
        if probe_mask is not None:
            # Masked probes use the kernel's pad-row convention: phi_cu = -INF
            # makes their edge weight +INF, so they never win the min.
            phi_cu = jnp.where(probe_mask, phi_cu, NEG)
            resid = jnp.where(probe_mask, resid, 0.0)
        return ss_divergence_kernel(
            self.W, CU, phi_cu, resid, cap, self.feat_w, cand_idx,
            phi=self.phi, interpret=interpret, **block_kw,
        )

    def pallas_gains(
        self,
        state: Array,
        *,
        interpret: bool,
        cand_idx: Array | None = None,
        **block_kw,
    ) -> Array | None:
        from repro.kernels.feature_gains import feature_gains_kernel

        cap = self._cap()
        phi_c = self._wsum(_phi(self.phi, state.astype(jnp.float32), cap))
        return feature_gains_kernel(
            self.W, state, phi_c, cap, self.feat_w, cand_idx,
            phi=self.phi, interpret=interpret, **block_kw,
        )

    # -- shard hooks (row-sharded: each device owns a block of W's rows) ----
    def shard_pack(self, axes):
        spec = _row_spec(axes)
        if self.feat_w is None:
            return (self.W,), (spec,), (
                lambda W_loc: dataclasses.replace(self, W=W_loc)
            )
        return (self.W, self.feat_w), (spec, P(None)), (
            lambda W_loc, fw: dataclasses.replace(self, W=W_loc, feat_w=fw)
        )

    def local_n(self) -> int:
        return self.W.shape[0]

    def shard_init(self, axis: str):
        # Pod-global coverage totals: everything downstream is local given C.
        C = jax.lax.psum(jnp.sum(self.W, axis=0), axis)          # (F,)
        cap = self.alpha * C if self.phi == "satcov" else None
        phiC = self._wsum(_phi(self.phi, C, cap))
        return (C, cap, phiC)

    def shard_residuals(self, ctx) -> Array:
        C, cap, phiC = ctx
        return phiC - self._wsum(_phi(self.phi, C[None, :] - self.W, cap))

    def shard_payloads(self, idx: Array, state: Array | None = None) -> Array:
        # The payload *is* the probe's conditional coverage row c(S + u):
        # shard_payload_gains computes phi(payload + W_v) - phi(payload),
        # which is exactly f(v | S + u) — same arithmetic as the dense
        # pairwise_gains with a state.
        if state is None:
            return self.W[idx]                                   # (k, F)
        return state[None, :] + self.W[idx]

    def shard_payload_gains(self, payloads: Array, ctx) -> Array:
        _, cap, _ = ctx
        phi_cu = self._wsum(_phi(self.phi, payloads, cap))       # (m,)
        both = payloads[:, None, :] + self.W[None, :, :]         # (m, nl, F)
        return self._wsum(_phi(self.phi, both, cap)) - phi_cu[:, None]

    def shard_take(self, cand_idx: Array) -> "FeatureCoverage":
        return dataclasses.replace(self, W=jnp.take(self.W, cand_idx, axis=0))

    def shard_gains(self, state: Array, ctx) -> Array:
        # Same expression as the dense gains, with the pod-global satcov cap
        # from ctx (the local W slice would under-saturate it).
        _, cap, _ = ctx
        return self._wsum(
            _phi(self.phi, state[None, :] + self.W, cap)
            - _phi(self.phi, state[None, :], cap)
        )

    def shard_add(self, state: Array, v: Array, ctx) -> Array:
        return state + self.W[v]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class FacilityLocation(SubmodularFunction):
    """Facility location: f(S) = sum_i max(0, max_{s in S} sim[i, s]).

    ``sim`` is the (n, n) similarity matrix (assumed nonnegative for
    monotonicity; negative entries are clipped at 0 by the implicit "serve
    yourself at 0" baseline, which also normalizes f(∅)=0).

    The *state* is the per-row current best coverage m in R^n,
    m_i = max(0, max_{s in S} sim[i, s]).
    """

    sim: Array  # (n, n)

    supports_shard_compact = True
    supports_shard_greedy = True

    def tree_flatten(self):
        return (self.sim,), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(sim=children[0])

    #: from_features refuses to materialize (n, n) above this many rows
    #: unless explicitly overridden — 16k is already a 1 GiB f32 sim matrix.
    N_THRESHOLD = 16384

    @classmethod
    def from_features(
        cls,
        X: Array,
        kernel: str = "dot",
        *,
        n_threshold: int | None = N_THRESHOLD,
    ) -> "FacilityLocation":
        n = X.shape[0]
        if n_threshold is not None and n > n_threshold:
            raise ValueError(
                f"FacilityLocation.from_features would materialize an "
                f"(n, n) = ({n}, {n}) similarity matrix "
                f"({4 * n * n / 2**30:.1f} GiB of f32). For kernel="
                f"'dot'/'cosine' use the matrix-free equivalent instead:\n"
                f"    StreamingFacilityLocation.from_features(X, "
                f"kernel={kernel!r})\n"
                f"which stores only the (n, d) embeddings and computes "
                f"similarity tiles on the fly. Pass n_threshold=None to "
                f"force the dense construction anyway."
            )
        if kernel == "dot":
            sim = jnp.maximum(X @ X.T, 0.0)
        elif kernel == "rbf":
            d2 = (
                jnp.sum(X * X, axis=1)[:, None]
                - 2.0 * X @ X.T
                + jnp.sum(X * X, axis=1)[None, :]
            )
            sim = jnp.exp(-d2 / jnp.maximum(jnp.mean(d2), 1e-9))
        elif kernel == "cosine":
            Xn = X / jnp.maximum(jnp.linalg.norm(X, axis=1, keepdims=True), 1e-9)
            sim = jnp.maximum(Xn @ Xn.T, 0.0)
        else:
            raise ValueError(kernel)
        return cls(sim=sim)

    @property
    def n(self) -> int:
        return self.sim.shape[0]

    def empty_state(self) -> Array:
        return jnp.zeros((self.sim.shape[0],), dtype=self.sim.dtype)

    def value(self, state: Array) -> Array:
        return jnp.sum(state)

    def gains(self, state: Array) -> Array:
        # f(v|S) = sum_i max(sim[i, v] - m_i, 0) -> column reduction of (n, n)
        return jnp.sum(jnp.maximum(self.sim - state[:, None], 0.0), axis=0)

    def add(self, state: Array, v: Array) -> Array:
        return jnp.maximum(state, self.sim[:, v])

    def add_many(self, state: Array, mask: Array) -> Array:
        masked = jnp.where(mask[None, :], self.sim, NEG)
        return jnp.maximum(state, jnp.max(masked, axis=1))

    def pairwise_gains(self, probes: Array, state: Array | None = None) -> Array:
        base = self.empty_state() if state is None else state
        mu = jnp.maximum(base[None, :], self.sim[:, probes].T)   # (r, n) rows=probe cov
        # f(v | S+u) = sum_i max(sim[i, v] - mu[u, i], 0)
        return jnp.sum(
            jnp.maximum(self.sim.T[None, :, :] - mu[:, None, :], 0.0), axis=-1
        )

    def residual_gains(self) -> Array:
        # f(V) - f(V \ v) per v: only rows where v is the unique argmax lose,
        # dropping to the second-best. Use top-2 per row.
        top2 = jax.lax.top_k(self.sim, 2)[0]                     # (n, 2)
        best, second = top2[:, 0], top2[:, 1]
        is_best = self.sim >= best[:, None]                      # ties: no loss
        tie = jnp.sum(is_best, axis=1) > 1
        loss_per_row = jnp.where(tie, 0.0, jnp.maximum(best, 0.0) - jnp.maximum(second, 0.0))
        return jnp.sum(jnp.where(is_best, loss_per_row[:, None], 0.0), axis=0)

    def pairwise_gains_compact(
        self, probes: Array, cand_idx: Array, state: Array | None = None
    ) -> Array:
        """Compact hinge block: the served-row reduction still spans all n
        rows (that is f's definition); only the candidate axis is gathered."""
        base = self.empty_state() if state is None else state
        mu = jnp.maximum(base[None, :], self.sim[:, probes].T)   # (r, n)
        simc = jnp.take(self.sim, cand_idx, axis=1)              # (n, k)
        return jnp.sum(
            jnp.maximum(simc.T[None, :, :] - mu[:, None, :], 0.0), axis=-1
        )

    def gains_compact(self, state: Array, cand_idx: Array) -> Array:
        """f(v|S) over the gathered candidate columns only (the served-row
        reduction still spans all n rows — that is f's definition)."""
        simc = jnp.take(self.sim, cand_idx, axis=1)              # (n, k)
        return jnp.sum(jnp.maximum(simc - state[:, None], 0.0), axis=0)

    def _pairwise_gains_chunked(
        self,
        probes: Array,
        cand_idx: Array | None,
        state: Array | None = None,
        probe_chunk: int = 8,
    ) -> Array:
        """Probe-chunked row computation for the batched engine — identical
        per-element hinge arithmetic to ``pairwise_gains_compact``, with the
        (r, k, n) block replaced by cache-resident (chunk, k, n) slabs."""
        base = self.empty_state() if state is None else state
        mu = jnp.maximum(base[None, :], self.sim[:, probes].T)   # (r, n)
        simc = (self.sim if cand_idx is None
                else jnp.take(self.sim, cand_idx, axis=1))       # (n, k)
        r = probes.shape[0]
        rp = -(-r // probe_chunk) * probe_chunk
        mu_p = jnp.concatenate([mu, jnp.repeat(mu[:1], rp - r, axis=0)])

        def chunk(_, mu_j):
            out = jnp.sum(
                jnp.maximum(simc.T[None, :, :] - mu_j[:, None, :], 0.0),
                axis=-1,
            )
            return None, out                                     # (PC, k)

        _, rows = jax.lax.scan(
            chunk, None, mu_p.reshape(-1, probe_chunk, mu.shape[-1])
        )
        return rows.reshape(rp, -1)[:r]

    def pairwise_gains_batched(
        self, probes: Array, cand_idx: Array | None, state: Array | None = None
    ) -> Array:
        """(B, r, k) batched block via the cache-blocked chunked rows."""
        return _map_pairwise_rows(
            self, probes, cand_idx, state,
            lambda f, p, ci, st: f._pairwise_gains_chunked(p, ci, st),
        )

    # -- pallas hooks ------------------------------------------------------
    def pallas_divergence(
        self,
        probes: Array,
        residual: Array,
        state: Array | None = None,
        probe_mask: Array | None = None,
        *,
        interpret: bool,
        cand_idx: Array | None = None,
        **block_kw,
    ) -> Array | None:
        from repro.kernels.fl_divergence import fl_divergence_kernel

        base = self.empty_state() if state is None else state
        MU = jnp.maximum(base[None, :], self.sim[:, probes].T)   # (r, n)
        resid = residual[probes]
        if probe_mask is not None:
            # Kernel pad-row convention: resid = -INF makes the edge weight
            # +INF, so masked probes never win the min.
            resid = jnp.where(probe_mask, resid, NEG)
        return fl_divergence_kernel(
            self.sim, MU, resid, cand_idx, interpret=interpret, **block_kw
        )

    def pallas_gains(
        self,
        state: Array,
        *,
        interpret: bool,
        cand_idx: Array | None = None,
        **block_kw,
    ) -> Array | None:
        from repro.kernels.fl_divergence import fl_gains_kernel

        return fl_gains_kernel(
            self.sim, state, cand_idx, interpret=interpret, **block_kw
        )

    # -- shard hooks (column-sharded: each device owns a block of candidate
    # columns, with the full set of served rows) ---------------------------
    # A probe's payload is its n-dim coverage column, so any shard can
    # evaluate f(v | ∅ + u) against it locally.  Pod hierarchy would need
    # row-local views too, hence supports_pod_sharding = False.

    def shard_pack(self, axes):
        if len(axes) > 1:
            raise NotImplementedError(
                "FacilityLocation shards candidates only (no pod hierarchy): "
                "its served rows span the full ground set"
            )
        return (self.sim,), (P(None, axes[0]),), (
            lambda sim_loc: dataclasses.replace(self, sim=sim_loc)
        )

    def local_n(self) -> int:
        return self.sim.shape[1]

    def shard_init(self, axis: str):
        # Global per-row top-2 similarities (for residuals): gather each
        # shard's local top-2 and reduce.
        k2 = min(2, self.sim.shape[1])
        loc_top = jax.lax.top_k(self.sim, k2)[0]                 # (n, k2)
        allt = jax.lax.all_gather(loc_top, axis)                 # (S, n, k2)
        allt = jnp.moveaxis(allt, 0, 1).reshape(self.sim.shape[0], -1)
        pad = jnp.full((self.sim.shape[0], 2), NEG, allt.dtype)
        top2 = jax.lax.top_k(jnp.concatenate([allt, pad], axis=1), 2)[0]
        best, second = top2[:, 0], top2[:, 1]
        # ties: number of global columns achieving the per-row max
        cnt = jax.lax.psum(
            jnp.sum(self.sim >= best[:, None], axis=1), axis
        )
        loss = jnp.where(
            cnt > 1, 0.0, jnp.maximum(best, 0.0) - jnp.maximum(second, 0.0)
        )
        return (best, loss)

    def shard_residuals(self, ctx) -> Array:
        best, loss = ctx
        is_best = self.sim >= best[:, None]                      # (n, n_loc)
        return jnp.sum(jnp.where(is_best, loss[:, None], 0.0), axis=0)

    def shard_payloads(self, idx: Array, state: Array | None = None) -> Array:
        # Probe coverage columns mu_u = max(state, sim[:, u]) — (k, n); with
        # S = ∅ the baseline is the implicit serve-yourself-at-0 coverage.
        base = jnp.zeros((self.sim.shape[0],)) if state is None else state
        return jnp.maximum(base[None, :], self.sim[:, idx].T)

    def shard_payload_gains(self, payloads: Array, ctx) -> Array:
        # f(v | ∅+u) = sum_i max(sim[i, v] - mu[u, i], 0) for local columns v.
        return jnp.sum(
            jnp.maximum(self.sim.T[None, :, :] - payloads[:, None, :], 0.0),
            axis=-1,
        )

    def shard_take(self, cand_idx: Array) -> "FacilityLocation":
        # Candidates are columns; the served rows stay whole.
        return dataclasses.replace(
            self, sim=jnp.take(self.sim, cand_idx, axis=1)
        )

    def shard_gains(self, state: Array, ctx) -> Array:
        # The replicated state is the (n,) served-row coverage; the local sim
        # slice holds this shard's candidate columns over all served rows, so
        # this is exactly the dense gains reduction on the local columns.
        return jnp.sum(jnp.maximum(self.sim - state[:, None], 0.0), axis=0)

    def shard_add(self, state: Array, v: Array, ctx) -> Array:
        return jnp.maximum(state, self.sim[:, v])


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class StreamingFacilityLocation(SubmodularFunction):
    """Matrix-free facility location over embedding rows (ISSUE 6 tentpole).

    Same objective as :class:`FacilityLocation` with the "dot" kernel —
    ``sim[i, v] = max(x_i . x_v, 0)`` — but the (n, n) similarity matrix is
    *never* materialized: only the ``(n, d)`` feature rows are stored, and
    every reduction streams similarity tiles ``relu(X_blk @ X_blkᵀ)`` through
    the block primitives in :mod:`repro.kernels.fl_stream` (lax.scan block
    references on the oracle path, fused flash-style kernels on the pallas
    path).  The cosine kernel is dot after one-time row normalization, so it
    shares the same machinery.

    ``X`` holds the *candidate* rows.  ``Xs`` (None for the global objective,
    where served == candidates) holds the *served* rows and exists so the
    sharded local views — candidate rows sharded, served rows replicated —
    and compacted views keep serving the full ground set while restricting
    the candidate axis.  The *state* is the served-row coverage
    ``m_i = max(0, max_{s in S} sim[i, s])``, exactly the dense state.

    Parity contract: for the same features this objective matches dense
    ``FacilityLocation.from_features(X, kernel="dot"|"cosine")`` on every
    primitive up to f32 tile-summation order (block partial sums vs. one
    full-width reduction), which is inside the repo's 1e-4 parity tolerance.
    """

    X: Array                 # (n, d) candidate embedding rows
    Xs: Array | None = None  # (ni, d) served rows; None = X (global objective)

    supports_pod_sharding = False
    supports_shard_compact = True
    supports_shard_greedy = True

    def tree_flatten(self):
        return (self.X, self.Xs), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(X=children[0], Xs=children[1])

    @classmethod
    def from_features(
        cls, X: Array, kernel: str = "dot"
    ) -> "StreamingFacilityLocation":
        X = jnp.asarray(X, jnp.float32)
        if kernel == "dot":
            pass
        elif kernel == "cosine":
            # Identical normalization to the dense cosine path, done once;
            # afterwards cosine *is* dot.
            X = X / jnp.maximum(
                jnp.linalg.norm(X, axis=1, keepdims=True), 1e-9
            )
        else:
            raise ValueError(
                f"StreamingFacilityLocation supports kernel='dot'/'cosine' "
                f"(similarities factor through the embedding rows); "
                f"got {kernel!r}"
            )
        return cls(X=X)

    def _served(self) -> Array:
        return self.X if self.Xs is None else self.Xs

    @property
    def n(self) -> int:
        return self.X.shape[0]

    def empty_state(self) -> Array:
        return jnp.zeros((self._served().shape[0],), dtype=jnp.float32)

    def value(self, state: Array) -> Array:
        return jnp.sum(state)

    def _probe_mu(self, probes: Array, state: Array | None) -> Array:
        """Probe coverage rows mu_u = max(state, relu(Xs @ x_u)).  (r, ni) —
        an (r, d) gather plus a thin matmul, never anything O(n^2)."""
        from repro.kernels.fl_stream import fl_stream_pair_ref  # noqa: F401

        base = self.empty_state() if state is None else state
        cols = jnp.maximum(
            self._served().astype(jnp.float32)
            @ jnp.take(self.X, probes, axis=0).astype(jnp.float32).T,
            0.0,
        )                                                        # (ni, r)
        return jnp.maximum(base[None, :], cols.T)

    def gains(self, state: Array) -> Array:
        from repro.kernels.fl_stream import fl_stream_pair_ref

        return fl_stream_pair_ref(
            self._served(), state.astype(jnp.float32)[None, :], Xc=self.X
        )[0]

    def add(self, state: Array, v: Array) -> Array:
        col = jnp.maximum(
            self._served().astype(jnp.float32) @ self.X[v].astype(jnp.float32),
            0.0,
        )
        return jnp.maximum(state, col)

    def add_many(self, state: Array, mask: Array) -> Array:
        from repro.kernels.fl_stream import fl_stream_col_max

        return jnp.maximum(
            state, fl_stream_col_max(self._served(), self.X, mask)
        )

    def pairwise_gains(self, probes: Array, state: Array | None = None) -> Array:
        from repro.kernels.fl_stream import fl_stream_pair_ref

        return fl_stream_pair_ref(
            self._served(), self._probe_mu(probes, state), Xc=self.X
        )

    def residual_gains(self) -> Array:
        from repro.kernels.fl_stream import fl_stream_residuals

        return fl_stream_residuals(self._served(), self.X)

    def pairwise_gains_compact(
        self, probes: Array, cand_idx: Array, state: Array | None = None
    ) -> Array:
        """Compact streaming block: ``cand_idx`` gathers candidate *feature
        rows* (k, d) — a tiny gather — while the served-row reduction still
        spans all rows (that is f's definition)."""
        from repro.kernels.fl_stream import fl_stream_pair_ref

        return fl_stream_pair_ref(
            self._served(), self._probe_mu(probes, state), cand_idx, Xc=self.X
        )

    def gains_compact(self, state: Array, cand_idx: Array) -> Array:
        from repro.kernels.fl_stream import fl_stream_pair_ref

        return fl_stream_pair_ref(
            self._served(), state.astype(jnp.float32)[None, :], cand_idx,
            Xc=self.X,
        )[0]

    # The inherited *_batched defaults lax.map the compact hooks above — the
    # rows are already streaming/memory-bounded, so they are the batched
    # implementation too (one row's block scan in flight at a time).

    # -- pallas hooks ------------------------------------------------------
    def pallas_divergence(
        self,
        probes: Array,
        residual: Array,
        state: Array | None = None,
        probe_mask: Array | None = None,
        *,
        interpret: bool,
        cand_idx: Array | None = None,
        **block_kw,
    ) -> Array | None:
        from repro.kernels.fl_stream import fl_stream_divergence_kernel

        MU = self._probe_mu(probes, state)                       # (r, ni)
        resid = residual[probes]
        if probe_mask is not None:
            # Kernel pad-row convention: resid = -INF makes the edge weight
            # +INF, so masked probes never win the min.
            resid = jnp.where(probe_mask, resid, NEG)
        return fl_stream_divergence_kernel(
            self._served(), MU, resid, cand_idx, self.X,
            interpret=interpret, **block_kw,
        )

    def pallas_gains(
        self,
        state: Array,
        *,
        interpret: bool,
        cand_idx: Array | None = None,
        **block_kw,
    ) -> Array | None:
        from repro.kernels.fl_stream import fl_stream_gains_kernel

        return fl_stream_gains_kernel(
            self._served(), state, cand_idx, self.X,
            interpret=interpret, **block_kw,
        )

    # -- shard hooks (row-sharded candidates, replicated served rows) ------
    # Each device owns a contiguous block of candidate rows of X; the (n, d)
    # served rows are replicated (tiny — that is the whole point of the
    # matrix-free objective).  Payloads are (k, n) probe coverage rows, the
    # same wire format as the dense column-sharded FacilityLocation, so the
    # sharded SS loop in repro.core.distributed runs unchanged.

    def shard_pack(self, axes):
        if len(axes) > 1:
            raise NotImplementedError(
                "StreamingFacilityLocation shards candidates only (no pod "
                "hierarchy): its served rows span the full ground set"
            )
        return (self.X, self._served()), (P(axes[0], None), P(None, None)), (
            lambda X_loc, Xs_all: dataclasses.replace(
                self, X=X_loc, Xs=Xs_all
            )
        )

    def local_n(self) -> int:
        return self.X.shape[0]

    def shard_init(self, axis: str):
        from repro.kernels.fl_stream import (
            fl_stream_count_best,
            fl_stream_top2,
        )

        served = self._served()
        loc_top = fl_stream_top2(served, self.X)                 # (ni, 2)
        allt = jax.lax.all_gather(loc_top, axis)                 # (S, ni, 2)
        allt = jnp.moveaxis(allt, 0, 1).reshape(served.shape[0], -1)
        pad = jnp.full((served.shape[0], 2), NEG, allt.dtype)
        top2 = jax.lax.top_k(jnp.concatenate([allt, pad], axis=1), 2)[0]
        best, second = top2[:, 0], top2[:, 1]
        cnt = jax.lax.psum(fl_stream_count_best(served, self.X, best), axis)
        loss = jnp.where(
            cnt > 1, 0.0, jnp.maximum(best, 0.0) - jnp.maximum(second, 0.0)
        )
        return (best, loss)

    def shard_residuals(self, ctx) -> Array:
        from repro.kernels.fl_stream import fl_stream_best_loss_sum

        best, loss = ctx
        return fl_stream_best_loss_sum(self._served(), self.X, best, loss)

    def shard_payloads(self, idx: Array, state: Array | None = None) -> Array:
        return self._probe_mu(idx, state)                        # (k, ni)

    def shard_payload_gains(self, payloads: Array, ctx) -> Array:
        from repro.kernels.fl_stream import fl_stream_pair_ref

        return fl_stream_pair_ref(self._served(), payloads, Xc=self.X)

    def shard_take(self, cand_idx: Array) -> "StreamingFacilityLocation":
        # Candidates are rows of X; pin Xs so the served set stays whole.
        return dataclasses.replace(
            self,
            X=jnp.take(self.X, cand_idx, axis=0),
            Xs=self._served(),
        )

    def shard_gains(self, state: Array, ctx) -> Array:
        from repro.kernels.fl_stream import fl_stream_pair_ref

        return fl_stream_pair_ref(
            self._served(), state.astype(jnp.float32)[None, :], Xc=self.X
        )[0]

    def shard_add(self, state: Array, v: Array, ctx) -> Array:
        return self.add(state, v)
