"""Greedy maximizers: jit-compiled masked greedy, lazy greedy, stochastic
greedy, and bidirectional randomized greedy (for the non-monotone Eq. 9).

TPU adaptation (DESIGN.md §3): the classic lazy-greedy priority queue is a
pointer structure with data-dependent control flow — poison for accelerators.
On TPU the efficient formulation is *incremental dense recomputation*: keep the
summary state, recompute all masked gains with one fused op per step, and take
a masked argmax.  The per-step gains call is dispatched through the execution
backend layer (``backend="pallas"`` routes it to the fused Pallas kernel; the
default oracle is plain jnp — see repro.core.backend).  Lazy greedy is still
provided (host/numpy) because it is the paper's wall-clock baseline on CPU.

Compact selection engine: after SS the live set is |V'| = O(log² n) ≪ n, yet
a full-width step would still pay n gains + an n argmax.  When ``alive`` is a
concrete sparse mask (the post-SS default), ``greedy`` / ``stochastic_greedy``
gather the live set once into a static bucket-sized candidate buffer (the SS
shrink schedule's :func:`repro.core.sparsify.bucket_schedule` sizes), run
every per-step gain / argmax / Gumbel draw in compact index space via the
``gains_compact`` backend primitive, and map selections back to ground
indices — so per-step cost tracks |V'|, not n.  Compact and full-width
selection pick identical sets under the same key (tests/test_compact_greedy).
"""

from __future__ import annotations

import heapq
import logging
import math
import time
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.backend import Backend, resolve_backend
from repro.core.functions import NEG, SubmodularFunction

Array = jax.Array

logger = logging.getLogger("repro.core.greedy")


def _traceable(*objs) -> bool:
    """Telemetry eligibility: tracing is on AND every input is concrete.
    Under jit/vmap (tracer inputs — e.g. greedy called from the compiled
    KV-pruning loop) the hooks must vanish: host reads are impossible there,
    and the compiled-code-safety contract (docs/observability.md) forbids
    injecting a sync into a traced region."""
    if not obs.trace_enabled():
        return False
    return not any(
        isinstance(leaf, jax.core.Tracer)
        for leaf in jax.tree_util.tree_leaves(objs)
    )


def _record_greedy(sp, res: "GreedyResult", k: int, backend: str,
                   wall_s: float, *, selector: str, batched: bool) -> None:
    """Fill a selection span + metrics from a finished (host-read) result.
    The per-step gain trajectory is read from ``GreedyResult.gains`` *after*
    the compiled loop returns — pure observation, never an in-loop sync."""
    gains = np.asarray(res.gains)
    value = np.asarray(res.value)
    if batched:
        sp.set(
            B=int(gains.shape[0]),
            value=[float(v) for v in value],
            gains=[[float(g) for g in row] for row in gains],
        )
    else:
        sp.set(value=float(value), gains=[float(g) for g in gains])
    sp.set(k=k, backend=backend, selector=selector)
    obs.get_registry().histogram(
        "repro_greedy_wall_seconds", "greedy selection wall time per call",
        labels=("backend", "selector"),
    ).observe(wall_s, backend=backend, selector=selector)


class GreedyResult(NamedTuple):
    selected: Array      # (k,) int32 indices, in selection order
    gains: Array         # (k,) marginal gain at each step
    value: Array         # scalar f(S)
    state: Array         # final summary state


# ------------------------------------------------------- selection planning --

def selection_bucket(
    n: int, live: int, c: float = 8.0, tile: int = 128
) -> int | None:
    """Static compact candidate-buffer size for the selection stage.

    The smallest :func:`repro.core.sparsify.bucket_schedule` size that holds
    ``live`` candidates, or None when only the full-width bucket fits —
    compaction would then be pure gather/scatter overhead.  Reusing the SS
    schedule means the selection grids share the SS compaction grid shapes
    (same kernel specializations, no extra compile cache pressure).
    """
    from repro.core.sparsify import bucket_schedule

    size = min(b for b in bucket_schedule(n, c, tile) if b >= live)
    return None if size >= n else size


def auto_sample_size(
    n: int, k: int, eps: float = 0.1, live: int | None = None
) -> int:
    """Stochastic-greedy sample size s = ceil((n'/k)·ln(1/eps)) — the
    "lazier than lazy greedy" heuristic [Mirzasoleiman et al. 2015] — with
    n' the live count (post-SS |V'|) when known, else the ground-set size."""
    base = (n if live is None else live) / max(k, 1)
    return max(1, int(math.ceil(base * math.log(1.0 / eps))))


_PATHS_LOGGED: set[tuple[str, bool]] = set()


def _log_path(kind: str, n: int, live: int | None, size: int | None) -> None:
    """One log line per (entry point, path) pair — benchmarks and long
    pipelines see which engine their selection stage actually ran on."""
    tag = (kind, size is not None)
    if tag in _PATHS_LOGGED:
        return
    _PATHS_LOGGED.add(tag)
    if size is None:
        logger.info("%s: full-width selection (n=%d, live=%s)", kind, n, live)
    else:
        logger.info(
            "%s: compact selection, bucket=%d (n=%d, live=%d)",
            kind, size, n, live,
        )


def _compact_plan(
    n: int, alive, compact, kind: str
) -> tuple[int | None, int | None]:
    """Resolve the compact-selection decision outside the jit boundary.

    Returns ``(bucket_size, live)``: the static compact buffer size (None =
    full-width path) and the best-known live count (None when ``alive`` is a
    tracer and no bound was given — the s=None heuristic then falls back to
    n).  ``compact`` semantics:

    - None / True — auto: compact when ``alive`` is a *concrete* mask whose
      live count (one host read) fits a sub-n bucket;
    - False — force the full-width path;
    - int — a static upper bound on the live count, usable when ``alive`` is
      a tracer (greedy under jit/vmap, where the mask cannot be host-read) —
      e.g. the O(log² n) SS retained-set bound m·(max_rounds+1).
    """
    if compact is False or alive is None:
        # Full-width path — but still report the live count when the mask is
        # host-readable, so the s=None sample-size heuristic (and the sharded
        # sampler, which resolves the same plan) sees |alive|, not n.
        live = (
            None
            if alive is None or isinstance(alive, jax.core.Tracer)
            else int(jnp.sum(alive))
        )
        _log_path(kind, n, live, None)
        return None, live
    if compact is None or isinstance(compact, bool):
        if isinstance(alive, jax.core.Tracer):
            # No host-readable live count inside jit/vmap: stay full-width
            # (pass an int live bound via ``compact`` to opt in under
            # tracing).
            _log_path(kind, n, None, None)
            return None, None
        live = int(jnp.sum(alive))
        size = selection_bucket(n, live)
        _log_path(kind, n, live, size)
        return size, live
    bound = int(compact)
    if not 0 <= bound <= n:
        raise ValueError(
            f"compact live bound must be in [0, n={n}]; got {bound}"
        )
    if not isinstance(alive, jax.core.Tracer):
        live = int(jnp.sum(alive))
        if live > bound:
            # A bucket sized from the bound would silently truncate the
            # candidate buffer (jnp.where(..., size=...) drops overflow) and
            # selections would be wrong — fail loudly instead.
            raise ValueError(
                f"compact live bound {bound} < |alive| = {live}; pass a "
                "correct bound (or compact=True to derive it from the mask)"
            )
        # The mask is host-readable: size the bucket from the exact live
        # count, not the (possibly loose) bound — we already paid the read.
        bound = live
    size = selection_bucket(n, bound)
    _log_path(kind, n, bound, size)
    return size, bound


# ------------------------------------------------------------------ greedy --

def greedy(
    fn: SubmodularFunction,
    k: int,
    alive: Array | None = None,
    backend: "str | Backend | None" = None,
    state: Array | None = None,
    compact: "bool | int | None" = None,
) -> GreedyResult:
    """Standard greedy under a cardinality constraint, restricted to ``alive``.

    Runs exactly k steps (static).  Once the alive set is exhausted the
    remaining slots record index 0 with gain forced to 0 — the returned value
    is still f of the alive selections only, because exhausted steps never
    touch the state.  ``state`` starts the run conditionally from an existing
    summary state (S ≠ ∅); recorded gains are marginals on top of it and
    ``value`` is f of the combined set.  ``backend`` selects the execution
    path for the per-step gains (repro.core.backend); it is resolved here,
    outside the jit boundary, so the env-var default is honored per call
    rather than baked into the first trace.

    ``compact`` controls the compact selection engine (see module docstring):
    None/True auto-compacts when ``alive`` is a concrete sparse mask (one
    host read of the live count), False forces the full-width path, and an
    int supplies a static live-count bound so tracer masks (greedy under
    jit/vmap) can compact too.  Compact and full-width runs select identical
    sets.

    The whole loop dispatches through the backend: ``backend="sharded"``
    runs the distributed exact argmax of
    :func:`repro.core.distributed.greedy_sharded` (selection-identical to
    the dense path) when the objective implements the shard selection hooks.
    """
    be = resolve_backend(backend)
    if not _traceable(fn, alive, state):
        return be.greedy(fn, k, alive=alive, state=state, compact=compact)
    with obs.span("greedy.select") as sp:
        t0 = time.perf_counter()
        res = be.greedy(fn, k, alive=alive, state=state, compact=compact)
        jax.block_until_ready(res.selected)
        wall = time.perf_counter() - t0
        _record_greedy(sp, res, k, be.name, wall,
                       selector="greedy", batched=False)
    return res


def _greedy_dense(
    fn: SubmodularFunction,
    k: int,
    alive: Array | None = None,
    state: Array | None = None,
    compact: "bool | int | None" = None,
    backend: Backend | None = None,
) -> GreedyResult:
    """Dense greedy entry (Backend.greedy default): resolves the compact
    plan outside jit, then runs the full-width or compact loop."""
    be = backend if backend is not None else resolve_backend(None)
    size, _ = _compact_plan(fn.n, alive, compact, "greedy")
    if size is None:
        return _greedy(fn, k, alive, state, be)
    return _greedy_compact(fn, k, size, alive, state, be)


@partial(jax.jit, static_argnames=("k", "backend"))
def _greedy(
    fn: SubmodularFunction, k: int, alive: Array | None, state: Array | None,
    backend: Backend,
) -> GreedyResult:
    be = backend
    n = fn.n
    alive = jnp.ones((n,), bool) if alive is None else alive
    state0 = fn.empty_state() if state is None else state

    def step(carry, _):
        st, avail = carry
        g = jnp.where(avail, be.gains(fn, st), NEG)
        v = jnp.argmax(g)
        ok = avail[v]
        new_state = jax.tree.map(
            lambda a, b: jnp.where(ok, a, b), fn.add(st, v), st
        )
        return (new_state, avail.at[v].set(False)), (v, jnp.where(ok, g[v], 0.0))

    (final, _), (sel, gains) = jax.lax.scan(
        step, (state0, alive), None, length=k
    )
    return GreedyResult(sel.astype(jnp.int32), gains, fn.value(final), final)


@partial(jax.jit, static_argnames=("k", "size", "backend"))
def _greedy_compact(
    fn: SubmodularFunction, k: int, size: int, alive: Array,
    state: Array | None, backend: Backend,
) -> GreedyResult:
    """Compact-engine greedy: gains/argmax in (size,)-slot index space.

    ``cand_idx`` (ascending ground indices — the same order the full-width
    argmax breaks ties in) is gathered once; every step dispatches the
    ``gains_compact`` backend primitive over it.  Exhausted steps record
    ground index 0 / gain 0, exactly like the full-width path.
    """
    be = backend
    cand_idx = jnp.where(alive, size=size, fill_value=0)[0]
    avail0 = jnp.arange(size) < jnp.sum(alive)       # padding slots are dead
    state0 = fn.empty_state() if state is None else state

    def step(carry, _):
        st, avail = carry
        g = jnp.where(avail, be.gains_compact(fn, st, cand_idx), NEG)
        vc = jnp.argmax(g)
        v = cand_idx[vc]
        ok = avail[vc]
        new_state = jax.tree.map(
            lambda a, b: jnp.where(ok, a, b), fn.add(st, v), st
        )
        return (new_state, avail.at[vc].set(False)), (
            jnp.where(ok, v, 0), jnp.where(ok, g[vc], 0.0),
        )

    (final, _), (sel, gains) = jax.lax.scan(
        step, (state0, avail0), None, length=k
    )
    return GreedyResult(sel.astype(jnp.int32), gains, fn.value(final), final)


# --------------------------------------------------------- batched greedy --

def greedy_batched(
    fn: SubmodularFunction,
    k: int,
    alive: Array | None = None,
    backend: "str | Backend | None" = None,
    state: Array | None = None,
    compact: "bool | int | None" = None,
    on_step: "StepCallback | None" = None,
) -> GreedyResult:
    """Exact greedy for B same-shape queries as **one** compiled loop.

    ``fn`` is a *stacked* objective (the same pytree class with a leading
    batch axis on every array leaf — see the micro-batching hooks in
    repro.core.functions); ``alive`` is (B, n) (or None = everything live)
    and ``state`` a stacked conditional start.  Returns a batched
    GreedyResult (leading B axis on every field).

    Row b selects *identically* to ``greedy(fn_b, k, alive=alive_b, ...)`` —
    batching is a pure execution strategy (tests/test_serve_service.py pins
    this).  ``compact`` mirrors :func:`greedy`: None/True host-reads the
    per-row live counts of a concrete mask and compacts every row into one
    shared bucket sized by the batch max (per-row parity holds for any
    bucket that fits — the compact-selection contract), False forces
    full-width, an int supplies a static shared live-count bound for tracer
    masks.

    ``on_step`` opts into *streamed selection*: greedy is sequential per
    step anyway, so instead of one ``lax.scan`` over k steps the loop runs
    k launches of the same jit-compiled step and calls
    ``on_step(step, selected (B,), gains (B,), ok (B,))`` after each commits
    — the serving layer uses this to stream partial summaries back to
    tickets while later steps still run.  Both paths execute the identical
    per-step arithmetic (the scan body *is* the compiled step function), so
    selections match the un-streamed call (tests/test_serve_async.py pins
    this).  ``on_step`` requires concrete inputs (it is a host callback).
    """
    be = resolve_backend(backend)
    if alive is not None and alive.ndim != 2:
        raise ValueError(f"greedy_batched needs a (B, n) alive mask; "
                         f"got shape {alive.shape}")
    n = jax.tree.map(lambda x: x[0], fn).n
    size, _ = _batched_compact_plan(n, alive, compact)

    def _run():
        if on_step is None:
            return _greedy_batched(fn, k, size, alive, state, be)
        return _greedy_batched_stepped(fn, k, size, alive, state, be, on_step)

    if not _traceable(fn, alive, state):
        return _run()
    with obs.span("greedy.select_batched", n=n, bucket=size) as sp:
        t0 = time.perf_counter()
        res = _run()
        jax.block_until_ready(res.selected)
        wall = time.perf_counter() - t0
        _record_greedy(sp, res, k, be.name, wall,
                       selector="greedy", batched=True)
    return res


# ``on_step(step_index, selected (B,), gains (B,), ok (B,))`` — arrays are
# concrete; exhausted rows carry index 0 / gain 0 with ok=False.
StepCallback = "Callable[[int, Array, Array, Array], None]"


def _batched_frame(
    fn: SubmodularFunction,
    size: int | None,
    alive: Array | None,
    state: Array | None,
) -> tuple[Array | None, Array, Array]:
    """Shared prologue of both batched loops: the (B, slots) availability
    frame, the compact candidate index map (None = ground index space), and
    the stacked start state."""
    B = jax.tree.leaves(fn)[0].shape[0]
    n = jax.tree.map(lambda x: x[0], fn).n
    if alive is None:
        cand_idx = None
        avail0 = jnp.ones((B, n), bool)
    elif size is None:
        cand_idx = None
        avail0 = alive
    else:
        cand_idx = jax.vmap(
            lambda a: jnp.where(a, size=size, fill_value=0)[0]
        )(alive)                                                  # (B, size)
        avail0 = jnp.arange(size)[None, :] < jnp.sum(alive, axis=1)[:, None]
    state0 = (
        jax.vmap(lambda f: f.empty_state())(fn) if state is None else state
    )
    return cand_idx, avail0, state0


def _batched_step(
    fn: SubmodularFunction,
    st,
    avail: Array,
    cand_idx: Array | None,
    backend: Backend,
):
    """One committed batched greedy step — the scan body of
    :func:`_greedy_batched` *and* the unit the streamed path launches k
    times, so both paths run the identical arithmetic.  Returns
    ``(state, avail, selected (B,), gains (B,), ok (B,))`` with exhausted
    rows recording index 0 / gain 0."""
    be = backend
    B = avail.shape[0]
    rows = jnp.arange(B)
    g = jnp.where(avail, be.gains_batched(fn, st, cand_idx), NEG)
    vc = jnp.argmax(g, axis=1)                                    # (B,)
    v = (
        vc
        if cand_idx is None
        else jnp.take_along_axis(cand_idx, vc[:, None], axis=1)[:, 0]
    )
    ok = avail[rows, vc]
    new_state = jax.vmap(lambda f, s, vv: f.add(s, vv))(fn, st, v)
    st = jax.tree.map(
        lambda a, b: jnp.where(
            ok.reshape((B,) + (1,) * (a.ndim - 1)), a, b
        ),
        new_state,
        st,
    )
    return (
        st,
        avail.at[rows, vc].set(False),
        jnp.where(ok, v, 0),
        jnp.where(ok, g[rows, vc], 0.0),
        ok,
    )


@partial(jax.jit, static_argnames=("k", "size", "backend"))
def _greedy_batched(
    fn: SubmodularFunction,
    k: int,
    size: int | None,
    alive: Array | None,
    state: Array | None,
    backend: Backend,
) -> GreedyResult:
    """The batched selection loop: every per-step gains/argmax runs over the
    whole (B, bucket) frame at once via the ``gains_batched`` backend
    primitive — one argmax launch for the batch instead of B."""
    cand_idx, avail0, state0 = _batched_frame(fn, size, alive, state)

    def step(carry, _):
        st, avail = carry
        st, avail, v, g, _ = _batched_step(fn, st, avail, cand_idx, backend)
        return (st, avail), (v, g)

    (final, _), (sel, gains) = jax.lax.scan(
        step, (state0, avail0), None, length=k
    )
    value = jax.vmap(lambda f, s: f.value(s))(fn, final)
    return GreedyResult(
        sel.T.astype(jnp.int32), gains.T, value, final
    )


_batched_step_jit = partial(jax.jit, static_argnames=("backend",))(
    _batched_step
)


@jax.jit
def _batched_value(fn: SubmodularFunction, state) -> Array:
    return jax.vmap(lambda f, s: f.value(s))(fn, state)


def _greedy_batched_stepped(
    fn: SubmodularFunction,
    k: int,
    size: int | None,
    alive: Array | None,
    state: Array | None,
    backend: Backend,
    on_step,
) -> GreedyResult:
    """Streamed batched greedy: k host-driven launches of the compiled
    :func:`_batched_step`, emitting each committed step through ``on_step``
    before the next one runs.  Greedy is sequential per step, so the extra
    dispatches cost launch overhead only; the arithmetic — and therefore
    the selections — are those of the ``lax.scan`` path."""
    cand_idx, avail, st = _batched_frame(fn, size, alive, state)
    sel, gains = [], []
    for i in range(k):
        st, avail, v, g, ok = _batched_step_jit(
            fn, st, avail, cand_idx, backend
        )
        # Host-sync the committed step so the callback observes real values
        # (the next launch proceeds immediately after).
        v, g, ok = jax.block_until_ready((v, g, ok))
        on_step(i, v, g, ok)
        sel.append(v)
        gains.append(g)
    return GreedyResult(
        jnp.stack(sel, axis=1).astype(jnp.int32),
        jnp.stack(gains, axis=1),
        _batched_value(fn, st),
        st,
    )


# --------------------------------------------------- batched stochastic greedy --

def stochastic_greedy_batched(
    fn: SubmodularFunction,
    k: int,
    keys: Array,
    s: int | None = None,
    alive: Array | None = None,
    backend: "str | Backend | None" = None,
    state: Array | None = None,
    compact: "bool | int | None" = None,
    eps: float = 0.1,
    on_step: "StepCallback | None" = None,
) -> GreedyResult:
    """Stochastic greedy for B same-shape queries as **one** compiled loop —
    the serving engine's degradation-ladder re-entry point (docs/serving.md
    "Failure semantics"): the same stacked-objective frame as
    :func:`greedy_batched`, but each step evaluates gains only on a
    per-row Gumbel-sampled subset of ``s`` candidate slots, so per-step cost
    tracks s instead of the compact bucket ("lazier than lazy greedy",
    Mirzasoleiman et al. 2015 — the paper-side cost of the quality step is
    the (1 - 1/e - eps) guarantee instead of (1 - 1/e)).

    Row b selects *identically* to the dense
    ``stochastic_greedy(fn_b, k, keys[b], s=s, alive=alive_b, ...)`` under
    the same per-row key **and the same resolved plan**: the Gumbel frame,
    sample set, per-element gain arithmetic, and tie order (sampled slots
    are sorted ascending before the argmax, reproducing the full-frame
    masked argmax's lowest-slot tie-break) all match
    (tests/test_serve_faults.py pins this).  Unlike exact greedy, the
    sampler's draws live in the compact frame, so the plan *is* part of the
    key: the batched loop shares one bucket (the batch max, like
    ``greedy_batched``) — pass ``compact=<that bucket's live bound>`` and
    the same effective ``s`` to the dense call when comparing rows.
    ``s=None`` derives the sample size from the batch-max live count;
    ``on_step`` streams committed steps exactly like
    :func:`greedy_batched`."""
    be = resolve_backend(backend)
    if alive is not None and alive.ndim != 2:
        raise ValueError(
            f"stochastic_greedy_batched needs a (B, n) alive mask; "
            f"got shape {alive.shape}"
        )
    n = jax.tree.map(lambda x: x[0], fn).n
    size, live = _batched_compact_plan(n, alive, compact)
    if s is None:
        s = auto_sample_size(n, k, eps, live=live)
    s = int(min(s, n if size is None else size))
    if s < 1:
        raise ValueError(f"sample size must be >= 1; got {s}")
    # Per-row per-step keys: exactly the dense loop's split(key, k), stacked
    # over rows, transposed to scan order (k, B, 2).
    step_keys = jnp.swapaxes(
        jax.vmap(lambda kk: jax.random.split(kk, k))(keys), 0, 1,
    )
    def _run():
        if on_step is None:
            return _stochastic_greedy_batched(
                fn, k, step_keys, s, size, alive, state, be
            )
        return _stochastic_greedy_batched_stepped(
            fn, k, step_keys, s, size, alive, state, be, on_step
        )

    if not _traceable(fn, keys, alive, state):
        return _run()
    with obs.span("greedy.stochastic_batched", n=n, bucket=size, s=s) as sp:
        t0 = time.perf_counter()
        res = _run()
        jax.block_until_ready(res.selected)
        wall = time.perf_counter() - t0
        _record_greedy(sp, res, k, be.name, wall,
                       selector="stochastic", batched=True)
    return res


def _batched_compact_plan(
    n: int, alive, compact
) -> tuple[int | None, int | None]:
    """The batched analogue of :func:`_compact_plan`: resolve the shared
    compact bucket (from the batch-max live count, or an int bound for
    tracer masks) outside the jit boundary.  Returns ``(size, live_max)``
    with both None when full-width / unknown."""
    if alive is None or compact is False:
        return None, None
    if isinstance(compact, (bool, type(None))):
        if isinstance(alive, jax.core.Tracer):
            return None, None
        live_max = int(jnp.max(jnp.sum(alive, axis=1)))
        return selection_bucket(n, live_max), live_max
    bound = int(compact)
    if not 0 <= bound <= n:
        raise ValueError(
            f"compact live bound must be in [0, n={n}]; got {bound}"
        )
    if not isinstance(alive, jax.core.Tracer):
        live_max = int(jnp.max(jnp.sum(alive, axis=1)))
        if live_max > bound:
            raise ValueError(
                f"compact live bound {bound} < max row |alive| = "
                f"{live_max}; pass a correct bound (or compact=True "
                "to derive it from the mask)"
            )
        bound = live_max
    return selection_bucket(n, bound), bound


def _sg_batched_step(
    fn: SubmodularFunction,
    st,
    avail: Array,
    cand_idx: Array | None,
    keys_i: Array,
    s: int,
    backend: Backend,
):
    """One committed batched stochastic-greedy step: per-row Gumbel top-s
    over available frame slots, gains on the gathered sample only, masked
    argmax back through the sample.  The sampled slots are sorted ascending
    so argmax ties break to the lowest frame slot — the same winner the
    dense loop's full-frame masked argmax picks."""
    be = backend
    B, width = avail.shape
    rows = jnp.arange(B)
    gumb = jax.vmap(lambda kk: jax.random.gumbel(kk, (width,)))(keys_i)
    gumb = gumb + jnp.where(avail, 0.0, NEG)
    cand = jnp.sort(jax.lax.top_k(gumb, s)[1], axis=1)            # (B, s)
    sub_avail = jnp.take_along_axis(avail, cand, axis=1)
    sub_idx = (
        cand if cand_idx is None
        else jnp.take_along_axis(cand_idx, cand, axis=1)
    )
    g = jnp.where(sub_avail, be.gains_batched(fn, st, sub_idx), NEG)
    vs = jnp.argmax(g, axis=1)                                    # (B,)
    vc = jnp.take_along_axis(cand, vs[:, None], axis=1)[:, 0]     # frame slot
    v = jnp.take_along_axis(sub_idx, vs[:, None], axis=1)[:, 0]   # ground idx
    ok = jnp.take_along_axis(sub_avail, vs[:, None], axis=1)[:, 0]
    new_state = jax.vmap(lambda f, ss, vv: f.add(ss, vv))(fn, st, v)
    st = jax.tree.map(
        lambda a, b: jnp.where(
            ok.reshape((B,) + (1,) * (a.ndim - 1)), a, b
        ),
        new_state,
        st,
    )
    return (
        st,
        avail.at[rows, vc].set(False),
        jnp.where(ok, v, 0),
        jnp.where(ok, jnp.take_along_axis(g, vs[:, None], axis=1)[:, 0], 0.0),
        ok,
    )


@partial(jax.jit, static_argnames=("k", "s", "size", "backend"))
def _stochastic_greedy_batched(
    fn: SubmodularFunction,
    k: int,
    step_keys: Array,
    s: int,
    size: int | None,
    alive: Array | None,
    state: Array | None,
    backend: Backend,
) -> GreedyResult:
    cand_idx, avail0, state0 = _batched_frame(fn, size, alive, state)

    def step(carry, keys_i):
        st, avail = carry
        st, avail, v, g, _ = _sg_batched_step(
            fn, st, avail, cand_idx, keys_i, s, backend
        )
        return (st, avail), (v, g)

    (final, _), (sel, gains) = jax.lax.scan(
        step, (state0, avail0), step_keys
    )
    value = jax.vmap(lambda f, st: f.value(st))(fn, final)
    return GreedyResult(sel.T.astype(jnp.int32), gains.T, value, final)


_sg_batched_step_jit = partial(jax.jit, static_argnames=("s", "backend"))(
    _sg_batched_step
)


def _stochastic_greedy_batched_stepped(
    fn: SubmodularFunction,
    k: int,
    step_keys: Array,
    s: int,
    size: int | None,
    alive: Array | None,
    state: Array | None,
    backend: Backend,
    on_step,
) -> GreedyResult:
    """Streamed batched stochastic greedy — k launches of the compiled step
    (the scan body), mirroring :func:`_greedy_batched_stepped`."""
    cand_idx, avail, st = _batched_frame(fn, size, alive, state)
    sel, gains = [], []
    for i in range(k):
        st, avail, v, g, ok = _sg_batched_step_jit(
            fn, st, avail, cand_idx, step_keys[i], s, backend
        )
        v, g, ok = jax.block_until_ready((v, g, ok))
        on_step(i, v, g, ok)
        sel.append(v)
        gains.append(g)
    return GreedyResult(
        jnp.stack(sel, axis=1).astype(jnp.int32),
        jnp.stack(gains, axis=1),
        _batched_value(fn, st),
        st,
    )


# ------------------------------------------------------------- lazy greedy --

@jax.jit
def _gain_at(fn: SubmodularFunction, state, v: Array) -> Array:
    """f(v|S) for one candidate — lazy greedy's re-evaluation primitive.

    Module-level so the trace cache is shared across ``lazy_greedy`` calls
    (a per-call ``jax.jit(lambda ...)`` wrapper would be a fresh cache every
    call and retrace on each one)."""
    return fn.gains(state)[v]


def lazy_greedy(
    fn: SubmodularFunction, k: int, alive: np.ndarray | None = None
) -> GreedyResult:
    """Minoux's accelerated greedy with a priority queue (host-side).

    Identical output to ``greedy`` (up to argmax tie order); used as the
    paper's CPU wall-clock baseline.  Function evaluations happen one
    candidate at a time via ``fn.gains`` restricted with a one-hot mask — the
    point here is counting/evaluating *lazily*, not vectorizing.
    """
    n = fn.n
    alive = np.ones((n,), bool) if alive is None else np.asarray(alive)
    state = fn.empty_state()
    # Initial upper bounds: singleton gains (computed vectorized — this is
    # also what a practical lazy greedy does for its first pass).
    ub = np.asarray(fn.gains(state))
    heap = [(-ub[v], int(v), 0) for v in range(n) if alive[v]]  # (-gain, v, stamp)
    heapq.heapify(heap)

    sel, gains, stamp = [], [], 0
    while heap and len(sel) < k:
        neg_g, v, s = heapq.heappop(heap)
        if s == stamp:                       # bound is current -> exact max
            sel.append(v)
            gains.append(-neg_g)
            state = fn.add(state, jnp.asarray(v))
            stamp += 1
        else:                                # stale: re-evaluate and push back
            g = float(_gain_at(fn, state, jnp.asarray(v)))
            heapq.heappush(heap, (-g, v, stamp))
    sel = np.asarray(sel + [0] * (k - len(sel)), np.int32)
    gains = np.asarray(gains + [0.0] * (k - len(gains)), np.float32)
    return GreedyResult(jnp.asarray(sel), jnp.asarray(gains), fn.value(state), state)


# ------------------------------------------------------- stochastic greedy --

def stochastic_greedy(
    fn: SubmodularFunction,
    k: int,
    key: Array,
    s: int | None = None,
    alive: Array | None = None,
    backend: "str | Backend | None" = None,
    state: Array | None = None,
    compact: "bool | int | None" = None,
    eps: float = 0.1,
) -> GreedyResult:
    """"Lazier than lazy greedy" [Mirzasoleiman et al. 2015]: per step, take
    the best element of a uniform random subset of size ``s``.

    ``s=None`` derives the sample size from the live count:
    s = ceil((|alive|/k)·ln(1/eps)) (:func:`auto_sample_size`) — post-SS this
    scales with |V'|, not n.  On the compact path (``compact``, same
    semantics as :func:`greedy`) the Gumbel noise is sampled directly in
    compact index space, so sampling cost also tracks |V'|.  The whole loop
    dispatches through the backend (``backend="sharded"`` runs the
    distributed sampler of :mod:`repro.core.distributed`, which matches this
    dense compact path selection-for-selection under the same key).
    """
    be = resolve_backend(backend)
    return be.stochastic_greedy(
        fn, k, key, s=s, alive=alive, state=state, compact=compact, eps=eps
    )


def _stochastic_greedy_dense(
    fn: SubmodularFunction,
    k: int,
    key: Array,
    s: int | None = None,
    alive: Array | None = None,
    state: Array | None = None,
    compact: "bool | int | None" = None,
    eps: float = 0.1,
    backend: Backend | None = None,
) -> GreedyResult:
    """Dense stochastic-greedy entry (Backend.stochastic_greedy default):
    resolves the compact plan and sample size outside jit, then runs the
    full-width or compact loop."""
    be = backend if backend is not None else resolve_backend(None)
    n = fn.n
    size, live = _compact_plan(n, alive, compact, "stochastic_greedy")
    if s is None:
        s = auto_sample_size(n, k, eps, live=live)
    s = int(min(s, n if size is None else size))
    if s < 1:
        raise ValueError(f"sample size must be >= 1; got {s}")
    if size is None:
        return _stochastic_greedy_full(fn, k, key, s, alive, state, be)
    return _stochastic_greedy_compact(fn, k, key, s, size, alive, state, be)


@partial(jax.jit, static_argnames=("k", "s", "backend"))
def _stochastic_greedy_full(
    fn: SubmodularFunction,
    k: int,
    key: Array,
    s: int,
    alive: Array | None,
    state: Array | None,
    backend: Backend,
) -> GreedyResult:
    be = backend
    n = fn.n
    alive = jnp.ones((n,), bool) if alive is None else alive
    state0 = fn.empty_state() if state is None else state

    def step(carry, key_i):
        st, avail = carry
        # Sample s candidates without replacement via Gumbel top-k on avail.
        gumb = jax.random.gumbel(key_i, (n,)) + jnp.where(avail, 0.0, NEG)
        cand = jax.lax.top_k(gumb, s)[1]
        sub_mask = jnp.zeros((n,), bool).at[cand].set(True) & avail
        g = jnp.where(sub_mask, be.gains(fn, st), NEG)
        v = jnp.argmax(g)
        ok = avail[v]
        new_state = jax.tree.map(
            lambda a, b: jnp.where(ok, a, b), fn.add(st, v), st
        )
        return (new_state, avail.at[v].set(False)), (v, jnp.where(ok, g[v], 0.0))

    (final, _), (sel, gains) = jax.lax.scan(
        step, (state0, alive), jax.random.split(key, k)
    )
    return GreedyResult(sel.astype(jnp.int32), gains, fn.value(final), final)


@partial(jax.jit, static_argnames=("k", "s", "size", "backend"))
def _stochastic_greedy_compact(
    fn: SubmodularFunction,
    k: int,
    key: Array,
    s: int,
    size: int,
    alive: Array,
    state: Array | None,
    backend: Backend,
) -> GreedyResult:
    """Compact-engine stochastic greedy: the Gumbel draw, top-k sampling,
    gains, and argmax all live in (size,)-slot index space — sampling noise
    is never materialized over the n dead candidates."""
    be = backend
    cand_idx = jnp.where(alive, size=size, fill_value=0)[0]
    avail0 = jnp.arange(size) < jnp.sum(alive)
    state0 = fn.empty_state() if state is None else state

    def step(carry, key_i):
        st, avail = carry
        gumb = jax.random.gumbel(key_i, (size,)) + jnp.where(avail, 0.0, NEG)
        cand = jax.lax.top_k(gumb, s)[1]
        sub = jnp.zeros((size,), bool).at[cand].set(True) & avail
        g = jnp.where(sub, be.gains_compact(fn, st, cand_idx), NEG)
        vc = jnp.argmax(g)
        v = cand_idx[vc]
        ok = avail[vc]
        new_state = jax.tree.map(
            lambda a, b: jnp.where(ok, a, b), fn.add(st, v), st
        )
        return (new_state, avail.at[vc].set(False)), (
            jnp.where(ok, v, 0), jnp.where(ok, g[vc], 0.0),
        )

    (final, _), (sel, gains) = jax.lax.scan(
        step, (state0, avail0), jax.random.split(key, k)
    )
    return GreedyResult(sel.astype(jnp.int32), gains, fn.value(final), final)


# ----------------------------------------------------- bidirectional greedy --

def bidirectional_greedy(
    gain_fn, n: int, key: Array, randomized: bool = True
) -> Array:
    """Buchbinder et al. (1/2)-approx for *unconstrained non-monotone*
    submodular maximization, used for the §3.4 post-reduction of V' via Eq. 9.

    ``gain_fn(mask_lo, mask_hi, v) -> (a, b)`` must return the marginal gains
    a = h(v | X) with X = {i : mask_lo[i]} and b = -h(v | Y - v) with
    Y = {i : mask_hi[i]}; it must be jax-traceable in all three arguments
    (``v`` arrives as a traced int32).  The n steps run as one
    ``lax.scan`` — a single compiled loop instead of n host iterations with
    two device round-trips each.  Returns the selected mask (n,) bool.
    """
    keys = jax.random.split(key, n)

    def step(carry, inp):
        lo, hi = carry
        v, key_v = inp
        a, b = gain_fn(lo, hi, v)
        a = jnp.asarray(a, jnp.float32)
        b = jnp.asarray(b, jnp.float32)
        if randomized:
            ap, bp = jnp.maximum(a, 0.0), jnp.maximum(b, 0.0)
            tot = ap + bp
            p = jnp.where(tot == 0.0, 1.0, ap / jnp.where(tot == 0.0, 1.0, tot))
            take = jax.random.bernoulli(key_v, p)
        else:
            take = a >= b
        lo = jnp.where(take, lo.at[v].set(True), lo)
        hi = jnp.where(take, hi, hi.at[v].set(False))
        return (lo, hi), None

    (lo, _), _ = jax.lax.scan(
        step,
        (jnp.zeros((n,), bool), jnp.ones((n,), bool)),
        (jnp.arange(n), keys),
    )
    return lo
