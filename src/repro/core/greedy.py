"""Greedy maximizers: jit-compiled masked greedy, lazy greedy, stochastic
greedy, and bidirectional randomized greedy (for the non-monotone Eq. 9).

TPU adaptation (DESIGN.md §3): the classic lazy-greedy priority queue is a
pointer structure with data-dependent control flow — poison for accelerators.
On TPU the efficient formulation is *incremental dense recomputation*: keep the
summary state, recompute all masked gains with one fused op per step, and take
a masked argmax.  The per-step gains call is dispatched through the execution
backend layer (``backend="pallas"`` routes it to the fused Pallas kernel; the
default oracle is plain jnp — see repro.core.backend).  Lazy greedy is still
provided (host/numpy) because it is the paper's wall-clock baseline on CPU.
"""

from __future__ import annotations

import heapq
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backend import Backend, resolve_backend
from repro.core.functions import NEG, SubmodularFunction

Array = jax.Array


class GreedyResult(NamedTuple):
    selected: Array      # (k,) int32 indices, in selection order
    gains: Array         # (k,) marginal gain at each step
    value: Array         # scalar f(S)
    state: Array         # final summary state


def greedy(
    fn: SubmodularFunction,
    k: int,
    alive: Array | None = None,
    backend: "str | Backend | None" = None,
) -> GreedyResult:
    """Standard greedy under a cardinality constraint, restricted to ``alive``.

    Runs exactly k steps (static).  If fewer than k alive elements exist the
    remaining slots select the best dead element with gain forced to 0 — the
    returned value is still f of the alive selections only, because dead
    elements are never added to the state.  ``backend`` selects the execution
    path for the per-step gains (repro.core.backend); it is resolved here,
    outside the jit boundary, so the env-var default is honored per call
    rather than baked into the first trace.
    """
    return _greedy(fn, k, alive, resolve_backend(backend))


@partial(jax.jit, static_argnames=("k", "backend"))
def _greedy(
    fn: SubmodularFunction, k: int, alive: Array | None, backend: Backend
) -> GreedyResult:
    be = backend
    n = fn.n
    alive = jnp.ones((n,), bool) if alive is None else alive

    def step(carry, _):
        state, avail = carry
        g = jnp.where(avail, be.gains(fn, state), NEG)
        v = jnp.argmax(g)
        ok = avail[v]
        new_state = jax.tree.map(
            lambda a, b: jnp.where(ok, a, b), fn.add(state, v), state
        )
        return (new_state, avail.at[v].set(False)), (v, jnp.where(ok, g[v], 0.0))

    (state, _), (sel, gains) = jax.lax.scan(
        step, (fn.empty_state(), alive), None, length=k
    )
    return GreedyResult(sel.astype(jnp.int32), gains, fn.value(state), state)


@jax.jit
def _gain_at(fn: SubmodularFunction, state, v: Array) -> Array:
    """f(v|S) for one candidate — lazy greedy's re-evaluation primitive.

    Module-level so the trace cache is shared across ``lazy_greedy`` calls
    (a per-call ``jax.jit(lambda ...)`` wrapper would be a fresh cache every
    call and retrace on each one)."""
    return fn.gains(state)[v]


def lazy_greedy(
    fn: SubmodularFunction, k: int, alive: np.ndarray | None = None
) -> GreedyResult:
    """Minoux's accelerated greedy with a priority queue (host-side).

    Identical output to ``greedy`` (up to argmax tie order); used as the
    paper's CPU wall-clock baseline.  Function evaluations happen one
    candidate at a time via ``fn.gains`` restricted with a one-hot mask — the
    point here is counting/evaluating *lazily*, not vectorizing.
    """
    n = fn.n
    alive = np.ones((n,), bool) if alive is None else np.asarray(alive)
    state = fn.empty_state()
    # Initial upper bounds: singleton gains (computed vectorized — this is
    # also what a practical lazy greedy does for its first pass).
    ub = np.asarray(fn.gains(state))
    heap = [(-ub[v], int(v), 0) for v in range(n) if alive[v]]  # (-gain, v, stamp)
    heapq.heapify(heap)

    sel, gains, stamp = [], [], 0
    while heap and len(sel) < k:
        neg_g, v, s = heapq.heappop(heap)
        if s == stamp:                       # bound is current -> exact max
            sel.append(v)
            gains.append(-neg_g)
            state = fn.add(state, jnp.asarray(v))
            stamp += 1
        else:                                # stale: re-evaluate and push back
            g = float(_gain_at(fn, state, jnp.asarray(v)))
            heapq.heappush(heap, (-g, v, stamp))
    sel = np.asarray(sel + [0] * (k - len(sel)), np.int32)
    gains = np.asarray(gains + [0.0] * (k - len(gains)), np.float32)
    return GreedyResult(jnp.asarray(sel), jnp.asarray(gains), fn.value(state), state)


def stochastic_greedy(
    fn: SubmodularFunction,
    k: int,
    key: Array,
    s: int,
    alive: Array | None = None,
    backend: "str | Backend | None" = None,
) -> GreedyResult:
    """"Lazier than lazy greedy" [Mirzasoleiman et al. 2015]: per step, take the
    best element of a uniform random subset of size ``s`` (≈ (n/k) log(1/eps)).
    """
    return _stochastic_greedy(fn, k, key, s, alive, resolve_backend(backend))


@partial(jax.jit, static_argnames=("k", "s", "backend"))
def _stochastic_greedy(
    fn: SubmodularFunction,
    k: int,
    key: Array,
    s: int,
    alive: Array | None,
    backend: Backend,
) -> GreedyResult:
    be = backend
    n = fn.n
    alive = jnp.ones((n,), bool) if alive is None else alive

    def step(carry, key_i):
        state, avail = carry
        # Sample s candidates without replacement via Gumbel top-k on avail.
        gumb = jax.random.gumbel(key_i, (n,)) + jnp.where(avail, 0.0, NEG)
        cand = jax.lax.top_k(gumb, s)[1]
        sub_mask = jnp.zeros((n,), bool).at[cand].set(True) & avail
        g = jnp.where(sub_mask, be.gains(fn, state), NEG)
        v = jnp.argmax(g)
        ok = avail[v]
        new_state = jax.tree.map(
            lambda a, b: jnp.where(ok, a, b), fn.add(state, v), state
        )
        return (new_state, avail.at[v].set(False)), (v, jnp.where(ok, g[v], 0.0))

    (state, _), (sel, gains) = jax.lax.scan(
        step, (fn.empty_state(), alive), jax.random.split(key, k)
    )
    return GreedyResult(sel.astype(jnp.int32), gains, fn.value(state), state)


def bidirectional_greedy(
    gain_fn, n: int, key: Array, randomized: bool = True
) -> Array:
    """Buchbinder et al. (1/2)-approx for *unconstrained non-monotone*
    submodular maximization, used for the §3.4 post-reduction of V' via Eq. 9.

    ``gain_fn(mask_lo, mask_hi, v) -> (a, b)`` must return the marginal gains
    a = h(v | X) with X = {i : mask_lo[i]} and b = -h(v | Y - v) with
    Y = {i : mask_hi[i]}.  Host loop (n is small post-SS).
    Returns the selected mask (n,) bool.
    """
    lo = np.zeros((n,), bool)
    hi = np.ones((n,), bool)
    keys = jax.random.split(key, n)
    for v in range(n):
        a, b = gain_fn(jnp.asarray(lo), jnp.asarray(hi), v)
        a, b = float(a), float(b)
        if randomized:
            ap, bp = max(a, 0.0), max(b, 0.0)
            p = 1.0 if ap + bp == 0.0 else ap / (ap + bp)
            take = bool(jax.random.bernoulli(keys[v], p))
        else:
            take = a >= b
        if take:
            lo[v] = True
        else:
            hi[v] = False
    return jnp.asarray(lo)
