"""Submodular Sparsification (SS) — Algorithm 1 of the paper, plus the §3.4
improvements (pre-pruning, importance sampling, bidirectional post-reduction).

TPU adaptation (DESIGN.md §3): the ground set never changes shape.  ``V`` is a
static n-slot tensor with a boolean ``alive`` mask; each SS round
  1. samples ``m = r·log2(n)`` probe indices from the live set (Gumbel top-k),
  2. moves them from ``alive`` into the retained mask ``vprime``,
  3. computes divergences w_{U,v} (paper Def. 2) for all live v in one fused
     (m, n, F) block, dispatched through the selected execution backend
     (jnp oracle, Pallas kernel, or shard_map — see repro.core.backend),
  4. drops the (1 - 1/sqrt(c)) fraction of live elements with the smallest
     *running* divergence (min over all probes sampled so far).
The loop runs under ``jax.lax.while_loop`` with fully static shapes, so the
whole sparsifier jit-compiles and can run inside the sharded data pipeline.

Backend selection: ``ss_sparsify(fn, key, backend="pallas")`` (or a
``Backend`` instance).  ``backend="sharded"`` swaps in the distributed loop
from :mod:`repro.core.distributed` — the whole round then runs under
shard_map over a mesh, for any objective implementing the shard hooks.

Quality certificate: ``eps_hat`` is max_{v pruned} w_{U,v} at prune time — an
upper bound on max_{v in V\\V'} w_{V',v} since the probe union only grows (the
running min only decreases).  By the paper's Theorem 1 argument,
f(greedy on V') >= (1 - 1/e)(f(S*) - k * eps_hat).
"""

from __future__ import annotations

import math
import time
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import graph
from repro.core.backend import Backend, resolve_backend
from repro.core.functions import NEG, SubmodularFunction
from repro.core.greedy import _traceable, bidirectional_greedy, greedy

Array = jax.Array
INF = -NEG  # +1e30


def _round_detail(
    trace: np.ndarray, n: int, r: int, c: float, live0: int, wall_s: float,
) -> list[dict]:
    """Per-round records derived *post-hoc* from ``SSResult.alive_trace`` —
    live count after the round, the compact bucket the round dispatched
    over, and a model-apportioned share of the measured total wall time
    (``wall_est_s``; the fused ``while_loop`` cannot be host-timed per
    round without a sync inside the traced scan, so per-round wall is an
    estimate weighted by probe-rows x bucket-slots work)."""
    m = min(probe_count(n, r), n)
    buckets = bucket_schedule(n, c)
    lives = [int(v) for v in trace if v >= 0]
    detail, weights, live_before = [], [], live0
    for live_after in lives:
        bucket = min((b for b in buckets if b >= live_before), default=n)
        weights.append(float(m * bucket))
        detail.append({"live": live_after, "bucket": bucket})
        live_before = live_after
    total_w = sum(weights) or 1.0
    for j, d in enumerate(detail):
        d["round"] = j
        d["wall_est_s"] = wall_s * weights[j] / total_w
    return detail


def _record_ss(
    sp, ss: "SSResult", n: int, r: int, c: float, backend: str,
    wall_s: float, live0, *, batched: bool,
) -> None:
    """Fill an SS span + metrics from a finished (host-read) result."""
    reg = obs.get_registry()
    trace = np.asarray(ss.alive_trace)
    rounds = np.asarray(ss.rounds)
    eps_hat = np.asarray(ss.eps_hat)
    vp = np.asarray(jnp.sum(ss.vprime, axis=-1))
    if batched:
        sp.set(
            B=int(trace.shape[0]),
            rounds=[int(x) for x in rounds],
            eps_hat=[float(x) for x in eps_hat],
            vprime_size=[int(x) for x in vp],
            rounds_detail=[
                _round_detail(row, n, r, c, int(l0), wall_s)
                for row, l0 in zip(trace, live0)
            ],
        )
        total_rounds = int(rounds.sum())
    else:
        sp.set(
            rounds=int(rounds), eps_hat=float(eps_hat),
            vprime_size=int(vp),
            rounds_detail=_round_detail(trace, n, r, c, int(live0), wall_s),
        )
        total_rounds = int(rounds)
    # wall_s is the measured compute wall (t0 -> block_until_ready), the
    # quantity the per-round estimates apportion; the span's own t0..t1
    # additionally covers this host-side readout.
    sp.set(n=n, r=r, c=c, backend=backend, wall_s=wall_s)
    reg.histogram(
        "repro_ss_wall_seconds", "ss_sparsify wall time per call",
        labels=("backend",),
    ).observe(wall_s, backend=backend)
    reg.counter(
        "repro_ss_rounds_total", "SS rounds executed", labels=("backend",),
    ).inc(total_rounds, backend=backend)


class SSResult(NamedTuple):
    vprime: Array      # (n,) bool — retained set V'
    divergence: Array  # (n,) running divergence w_{U,v} (INF where never probed)
    eps_hat: Array     # scalar — certificate: max divergence among pruned items
    rounds: Array      # scalar int32 — rounds executed
    alive_trace: Array  # (max_rounds,) int32 live count after each round (-1 pad)


def probe_count(n: int, r: int = 8) -> int:
    """m = r * log2(n) (paper samples ``r log n`` per round, log base 2)."""
    return max(1, int(r * math.log2(max(n, 2))))

def max_rounds(n: int, r: int = 8, c: float = 8.0) -> int:
    """log_{sqrt(c)}(n) rounds suffice (paper §3.2); +2 slack for rounding."""
    return max(1, int(math.ceil(math.log(max(n, 2)) / math.log(math.sqrt(c)))) + 2)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def ss_live_bound(n: int, r: int = 8, c: float = 8.0) -> int:
    """Static upper bound on the SS retained-set size |V'| — the paper's
    O(log² n): at most m = r·log2(n) probes per round for at most
    ``max_rounds`` rounds plus an m-sized tail.  Shared by postreduce's slot
    sizing, the vmapped KV-cache selection, and the serving engine's
    compact-greedy bound (anywhere a tracer mask needs a static |V'|)."""
    m = min(probe_count(n, r), n)
    return min(n, m * (max_rounds(n, r, c) + 1))


def bucket_schedule(n: int, c: float = 8.0, tile: int = 128) -> tuple[int, ...]:
    """Static compact-buffer sizes for the shrink-aware SS loop.

    The live set shrinks by 1/sqrt(c) per round, so round j's divergence only
    has ~ceil(n / c^{j/2}) live candidates.  Returns those sizes rounded up to
    ``tile`` multiples (kernel-grid alignment), clamped to n, deduplicated,
    descending — one ``lax.switch`` branch (one static shape) per bucket, so
    the loop never recompiles and never syncs to the host.
    """
    if c <= 1.0:
        raise ValueError(f"bucket_schedule needs c > 1 (got c={c}): the SS "
                         "live set shrinks by 1 - 1/sqrt(c) per round")
    if tile < 1:
        raise ValueError(f"tile must be >= 1 (got {tile})")
    sizes: list[int] = []
    j = 0
    while True:
        raw = math.ceil(n / (math.sqrt(c) ** j))
        s = min(n, _round_up(raw, tile))
        if not sizes or s < sizes[-1]:
            sizes.append(s)
        if raw <= tile:
            return tuple(sizes)
        j += 1


def predicted_live_counts(
    n: int, r: int = 8, c: float = 8.0, alive0: int | None = None
) -> list[int]:
    """The deterministic live-count-after-each-round sequence of Algorithm 1
    (exactly what ``SSResult.alive_trace`` records): each round removes m
    probes, then floor(live * (1 - 1/sqrt(c))) pruned elements."""
    m = min(probe_count(n, r), n)
    shrink = 1.0 - 1.0 / math.sqrt(c)
    live = n if alive0 is None else alive0
    out: list[int] = []
    for _ in range(max_rounds(n, r, c)):
        if live <= m:
            break
        live -= m
        live -= math.floor(live * shrink)
        out.append(live)
    return out


def ss_cost_model(
    n: int, r: int = 8, c: float = 8.0, alive0: int | None = None
) -> float:
    """Predicted SS divergence work — probe rows × compact candidate slots,
    summed over the deterministic round schedule of Algorithm 1 (the same
    recurrence as :func:`predicted_live_counts`, bucket-rounded like the
    shrink-aware executor actually dispatches).

    This is the *relative* cost model the serving degradation ladder uses
    (docs/serving.md "Failure semantics"): bumping ``c`` shrinks the live
    set faster (fewer, smaller rounds) and shrinking ``r`` cuts the probe
    rows, so ``ss_cost_model(n, r2, c2) / ss_cost_model(n, r1, c1)`` predicts
    the execution-time ratio of a degraded config before it has ever been
    measured.  Arbitrary units — only ratios are meaningful.
    """
    m = min(probe_count(n, r), n)
    buckets = bucket_schedule(n, c)
    shrink = 1.0 - 1.0 / math.sqrt(c)
    live = n if alive0 is None else alive0
    total = 0.0
    for _ in range(max_rounds(n, r, c)):
        if live <= m:
            break
        live -= m
        bucket = min((b for b in buckets if b >= live), default=n)
        total += m * bucket
        live -= math.floor(live * shrink)
    return max(total, 1.0)


def ss_sparsify(
    fn: SubmodularFunction,
    key: Array,
    r: int = 8,
    c: float = 8.0,
    alive: Array | None = None,
    state: Array | None = None,
    importance: bool = False,
    backend: "str | Backend | None" = None,
    compact: bool = True,
) -> SSResult:
    """Algorithm 1 (Submodular Sparsification).

    Args:
      fn: submodular objective over n ground elements.
      key: PRNG key for probe sampling.
      r: probe multiplier (paper uses r = 8 = c).
      c: accuracy/speed tradeoff; shrink rate is 1/sqrt(c) per round.
      alive: optional (n,) bool — initial live mask (e.g. after pre-pruning).
      state: optional summary state for *conditional* SS on G(V, E|S).
      importance: §3.4 improvement 2 — sample probes with probability
        proportional to f(u) + f(u|V\\u) instead of uniformly.
      backend: execution backend — "oracle" (default), "pallas", "sharded",
        or a Backend instance (repro.core.backend).
      compact: shrink-aware execution (default) — each round's divergence is
        dispatched over a compacted live-candidate buffer whose static size
        follows :func:`bucket_schedule`, so round cost tracks the live count
        instead of n.  ``compact=False`` forces the full-width path (the two
        produce identical ``vprime`` under the same key).
    """
    be = resolve_backend(backend)
    if not _traceable(fn, key, alive, state):
        return be.sparsify(
            fn, key, r=r, c=c, alive=alive, state=state,
            importance=importance, compact=compact,
        )
    with obs.span("ss.sparsify") as sp:
        t0 = time.perf_counter()
        ss = be.sparsify(
            fn, key, r=r, c=c, alive=alive, state=state,
            importance=importance, compact=compact,
        )
        jax.block_until_ready(ss.vprime)
        wall = time.perf_counter() - t0
        live0 = fn.n if alive is None else int(jnp.sum(alive))
        _record_ss(sp, ss, fn.n, r, c, be.name, wall, live0, batched=False)
    return ss


@partial(jax.jit, static_argnames=("r", "c", "importance", "backend", "compact"))
def _sparsify_dense(
    fn: SubmodularFunction,
    key: Array,
    r: int = 8,
    c: float = 8.0,
    alive: Array | None = None,
    state: Array | None = None,
    importance: bool = False,
    backend: Backend | None = None,
    compact: bool = True,
) -> SSResult:
    """The dense single-process SS loop; ``backend`` (an already-resolved
    Backend instance — callers go through ss_sparsify) supplies divergence.

    With ``compact`` (the default), each round gathers the surviving
    candidates into a static bucket-sized index buffer (one ``lax.switch``
    branch per :func:`bucket_schedule` size — no recompilation, no host
    sync), dispatches ``divergence_compact`` over the buffer, and
    scatter-mins the (k,) result back to ground indices.  Divergence entries
    of probe/dead slots are then *stale* rather than refreshed — the loop
    never reads them (pruning and eps_hat only consult live entries), so
    ``vprime``/``eps_hat`` are identical to the full-width path.
    """
    be = backend if backend is not None else resolve_backend(None)
    n = fn.n
    m = min(probe_count(n, r), n)  # tiny ground sets: everything is a probe
    rounds_cap = max_rounds(n, r, c)
    shrink = 1.0 - 1.0 / math.sqrt(c)
    buckets = bucket_schedule(n, c) if compact else None

    alive0 = jnp.ones((n,), bool) if alive is None else alive
    residual = fn.residual_gains()

    if importance:
        score = fn.singleton_gains() + residual
        logits = jnp.log(jnp.maximum(score, 1e-12))
    else:
        logits = jnp.zeros((n,))

    def _divergence(probes):
        return be.divergence(fn, probes, residual=residual, state=state)

    def _make_branch(size: int):
        if size >= n:
            # Full-width bucket (round 1, before any shrink): the gather +
            # scatter would be pure overhead over the plain divergence.
            def full(args):
                _, probes, div = args
                return jnp.minimum(div, _divergence(probes))
            return full

        # One static compact width: gather live candidates into a (size,)
        # buffer, compute their divergences, scatter-min back to ground.
        def branch(args):
            alive, probes, div = args
            cand_idx = jnp.where(alive, size=size, fill_value=0)[0]
            cand_mask = jnp.arange(size) < jnp.sum(alive)
            w = be.divergence_compact(
                fn, probes, cand_idx, residual=residual, state=state
            )
            # Padding slots repeat index 0 — masked to +INF, their
            # scatter-min is a no-op.
            w = jnp.where(cand_mask, w, INF)
            return div.at[cand_idx].min(w)
        return branch

    branches = [_make_branch(s) for s in buckets] if compact else None

    def cond(carry):
        alive, vprime, div, eps_hat, key, rnd, trace = carry
        return (jnp.sum(alive) > m) & (rnd < rounds_cap)

    def body(carry):
        alive, vprime, div, eps_hat, key, rnd, trace = carry
        key, k1 = jax.random.split(key)

        # (1) sample m probes from the live set (Gumbel top-k == uniform or
        # importance-weighted sampling without replacement).
        g = jax.random.gumbel(k1, (n,)) + logits + jnp.where(alive, 0.0, NEG)
        probes = jax.lax.top_k(g, m)[1]
        probe_hot = jnp.zeros((n,), bool).at[probes].set(True) & alive

        # (2) U moves from V to V'.
        vprime = vprime | probe_hot
        alive = alive & ~probe_hot

        # (3) running divergence against the union of all probes so far —
        # over the compacted live buffer (smallest bucket that fits the live
        # count) or the full width.
        if compact:
            barr = jnp.asarray(buckets)
            bidx = jnp.sum(barr >= jnp.sum(alive)) - 1
            div = jax.lax.switch(bidx, branches, (alive, probes, div))
        else:
            div = jnp.minimum(div, _divergence(probes))

        # (4) drop the (1 - 1/sqrt(c)) fraction of live items with smallest
        # divergence.  Rank via masked argsort (dead -> +INF sorts last).
        live = jnp.sum(alive)
        n_remove = jnp.floor(live * shrink).astype(jnp.int32)
        keyed = jnp.where(alive, div, INF)
        order = jnp.argsort(keyed)                       # ascending
        pos = jnp.zeros((n,), jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
        removed = alive & (pos < n_remove)
        eps_hat = jnp.maximum(
            eps_hat, jnp.max(jnp.where(removed, div, NEG))
        )
        alive = alive & ~removed
        trace = trace.at[rnd].set(jnp.sum(alive).astype(jnp.int32))
        return (alive, vprime, div, eps_hat, key, rnd + 1, trace)

    carry = (
        alive0,
        jnp.zeros((n,), bool),
        jnp.full((n,), INF),
        jnp.float32(NEG),
        key,
        jnp.int32(0),
        jnp.full((rounds_cap,), -1, jnp.int32),
    )
    alive, vprime, div, eps_hat, _, rnd, trace = jax.lax.while_loop(cond, body, carry)
    # Tail: remaining live elements all survive into V' (Algorithm 1, line 13).
    vprime = vprime | alive
    return SSResult(vprime, div, jnp.maximum(eps_hat, 0.0), rnd, trace)


def ss_sparsify_batched(
    fn: SubmodularFunction,
    keys: Array,
    r: int = 8,
    c: float = 8.0,
    alive: Array | None = None,
    state: Array | None = None,
    importance: bool = False,
    backend: "str | Backend | None" = None,
    compact: bool = True,
) -> SSResult:
    """Algorithm 1 for B same-shape queries as **one** compiled loop.

    ``fn`` is a *stacked* objective (the same pytree class with a leading
    batch axis on every array leaf — see the micro-batching hooks in
    repro.core.functions), ``keys`` the (B, 2) per-query PRNG keys, ``alive``
    an optional (B, n) mask and ``state`` an optional stacked conditional
    state.  Returns a batched SSResult (leading B axis on every field).

    Row b is *identical* to ``ss_sparsify(fn_b, keys[b], ...)`` on that
    query alone — same ``vprime``, ``eps_hat``, ``rounds`` and
    ``alive_trace`` under the same per-query key (micro-batching is a pure
    execution strategy; tests/test_serve_service.py pins this).  Rows that
    exhaust early freeze in place while the rest keep iterating.  As with
    the compacted single-query loop, ``divergence`` entries of probe/dead
    slots are stale by design; additionally the batched loop shares one
    bucket (the batch max) per round, so stale entries may differ from the
    single-query run — never read them at non-live indices.
    """
    be = resolve_backend(backend)
    if not _traceable(fn, keys, alive, state):
        return be.sparsify_batched(
            fn, keys, r=r, c=c, alive=alive, state=state,
            importance=importance, compact=compact,
        )
    with obs.span("ss.sparsify_batched") as sp:
        t0 = time.perf_counter()
        ss = be.sparsify_batched(
            fn, keys, r=r, c=c, alive=alive, state=state,
            importance=importance, compact=compact,
        )
        jax.block_until_ready(ss.vprime)
        wall = time.perf_counter() - t0
        n = jax.tree.map(lambda x: x[0], fn).n
        B = int(keys.shape[0])
        if alive is None:
            live0 = [n] * B
        else:
            live0 = [int(x) for x in np.asarray(jnp.sum(alive, axis=1))]
        _record_ss(sp, ss, n, r, c, be.name, wall, live0, batched=True)
    return ss


@partial(jax.jit, static_argnames=("r", "c", "importance", "backend", "compact"))
def _sparsify_batched(
    fn: SubmodularFunction,
    keys: Array,
    r: int = 8,
    c: float = 8.0,
    alive: Array | None = None,
    state: Array | None = None,
    importance: bool = False,
    backend: Backend | None = None,
    compact: bool = True,
) -> SSResult:
    """The batched dense SS loop (Backend.sparsify_batched default).

    Structure mirrors :func:`_sparsify_dense` exactly, with every per-query
    op vmapped over the batch and two shared pieces of control flow: one
    global round counter (rows that finish freeze via a per-row ``active``
    mask — their carry is reselected unchanged, so a frozen row's result
    cannot drift from its single-query run), and one compact bucket per
    round chosen from the max live count over *active* rows (per-row
    results are bucket-size-invariant by the compaction contract, so
    sharing the branch preserves row-for-row parity while keeping a single
    ``lax.switch`` — under vmapped control flow every branch would run).
    Divergence dispatches through the ``divergence_batched`` backend
    primitive: one cache-blocked launch for the whole batch per round.
    """
    be = backend if backend is not None else resolve_backend(None)
    fn0 = jax.tree.map(lambda x: x[0], fn)
    n = fn0.n
    B = keys.shape[0]
    m = min(probe_count(n, r), n)
    rounds_cap = max_rounds(n, r, c)
    shrink = 1.0 - 1.0 / math.sqrt(c)
    buckets = bucket_schedule(n, c) if compact else None

    alive0 = jnp.ones((B, n), bool) if alive is None else alive
    residual = jax.vmap(lambda f: f.residual_gains())(fn)        # (B, n)

    if importance:
        score = jax.vmap(lambda f: f.singleton_gains())(fn) + residual
        logits = jnp.log(jnp.maximum(score, 1e-12))
    else:
        logits = jnp.zeros((B, n))

    def _divergence(probes, cand_idx):
        return be.divergence_batched(
            fn, probes, cand_idx, residual=residual, state=state
        )

    def _make_branch(size: int):
        if size >= n:
            def full(args):
                _, probes, div = args
                return jnp.minimum(div, _divergence(probes, None))
            return full

        def branch(args):
            alive_b, probes, div = args
            cand_idx = jax.vmap(
                lambda a: jnp.where(a, size=size, fill_value=0)[0]
            )(alive_b)                                           # (B, size)
            cand_mask = (
                jnp.arange(size)[None, :] < jnp.sum(alive_b, axis=1)[:, None]
            )
            w = _divergence(probes, cand_idx)                    # (B, size)
            w = jnp.where(cand_mask, w, INF)
            return jax.vmap(lambda d, ci, ww: d.at[ci].min(ww))(
                div, cand_idx, w
            )
        return branch

    branches = [_make_branch(s) for s in buckets] if compact else None

    def row_active(alive_b, rnd_b):
        return (jnp.sum(alive_b, axis=1) > m) & (rnd_b < rounds_cap)

    def cond(carry):
        alive_b, vprime, div, eps, keys_b, rnd_b, trace = carry
        return jnp.any(row_active(alive_b, rnd_b))

    def body(carry):
        alive_b, vprime, div, eps, keys_b, rnd_b, trace = carry
        active = row_active(alive_b, rnd_b)                      # (B,)
        new_keys, k1 = jax.vmap(
            lambda kk: tuple(jax.random.split(kk))
        )(keys_b)

        # (1) per-row probe sampling — identical draws to the single-query
        # loop under the same per-row key.
        g = (
            jax.vmap(lambda kk: jax.random.gumbel(kk, (n,)))(k1)
            + logits
            + jnp.where(alive_b, 0.0, NEG)
        )
        probes = jax.lax.top_k(g, m)[1]                          # (B, m)
        probe_hot = (
            jnp.zeros((B, n), bool)
            .at[jnp.arange(B)[:, None], probes]
            .set(True)
            & alive_b
        )

        # (2) U moves from V to V'.
        new_vprime = vprime | probe_hot
        new_alive = alive_b & ~probe_hot

        # (3) running divergence over the shared bucket (batch max of the
        # active rows' live counts; inactive rows' results are discarded).
        if compact:
            barr = jnp.asarray(buckets)
            live_max = jnp.max(
                jnp.where(active, jnp.sum(new_alive, axis=1), 0)
            )
            bidx = jnp.sum(barr >= live_max) - 1
            new_div = jax.lax.switch(
                bidx, branches, (new_alive, probes, div)
            )
        else:
            new_div = jnp.minimum(div, _divergence(probes, None))

        # (4) per-row prune of the smallest-divergence fraction.
        live = jnp.sum(new_alive, axis=1)
        n_remove = jnp.floor(live * shrink).astype(jnp.int32)
        keyed = jnp.where(new_alive, new_div, INF)
        order = jnp.argsort(keyed, axis=1)
        pos = jnp.zeros((B, n), jnp.int32).at[
            jnp.arange(B)[:, None], order
        ].set(jnp.arange(n, dtype=jnp.int32)[None, :])
        removed = new_alive & (pos < n_remove[:, None])
        new_eps = jnp.maximum(
            eps, jnp.max(jnp.where(removed, new_div, NEG), axis=1)
        )
        new_alive = new_alive & ~removed
        new_trace = jax.vmap(
            lambda t, rr, a: t.at[rr].set(jnp.sum(a).astype(jnp.int32))
        )(trace, rnd_b, new_alive)

        # Frozen rows keep their entire carry — bit-identical to having
        # exited the single-query loop.
        sel = lambda new, old: jnp.where(
            active.reshape((B,) + (1,) * (new.ndim - 1)), new, old
        )
        return (
            sel(new_alive, alive_b),
            sel(new_vprime, vprime),
            sel(new_div, div),
            sel(new_eps, eps),
            sel(new_keys, keys_b),
            rnd_b + active.astype(jnp.int32),
            sel(new_trace, trace),
        )

    carry = (
        alive0,
        jnp.zeros((B, n), bool),
        jnp.full((B, n), INF),
        jnp.full((B,), NEG, jnp.float32),
        keys,
        jnp.zeros((B,), jnp.int32),
        jnp.full((B, rounds_cap), -1, jnp.int32),
    )
    alive_b, vprime, div, eps, _, rnd_b, trace = jax.lax.while_loop(
        cond, body, carry
    )
    vprime = vprime | alive_b
    return SSResult(vprime, div, jnp.maximum(eps, 0.0), rnd_b, trace)


def preprune_mask(fn: SubmodularFunction, k: int) -> Array:
    """Wei-et-al pre-pruning (§3.4 improvement 1): drop u whose singleton gain
    f(u) is below the k-th largest residual f(v|V\\v) — provably safe."""
    residual = fn.residual_gains()
    kth = jax.lax.top_k(residual, k)[0][-1]
    return fn.singleton_gains() >= kth


def postreduce(
    fn: SubmodularFunction,
    result: SSResult,
    eps: float,
    key: Array,
    max_members: "int | str | None" = None,
    r: int = 8,
    c: float = 8.0,
) -> Array:
    """§3.4 improvement 3: shrink V' further by (approximately) solving Eq. 9
    restricted to V' with bidirectional greedy.  Returns a new vprime mask.

    h(V') = |{v in V \\ V' : w_{V'v} <= eps}|  -  computed against the edge
    weights from V'-members to all pruned v.  Member bookkeeping is vectorized
    over a static block of slots (padded with -1) and scattered back to
    ground indices in one masked scatter — no per-element host loop.

    ``max_members`` is the static slot count.  The default (None) derives it
    from the paper's O(log² n) retained-set size: SS adds at most m =
    r·log2(n) probes per round for at most ``max_rounds`` rounds plus an
    m-sized tail, so m·(max_rounds+1) slots always fit V' (pass ``r``/``c``
    matching the SS run if non-default — a mismatch that would truncate V'
    raises).  ``max_members="exact"`` opts into one host-sync read of |V'|
    for the tightest block; an int pins the bound explicitly and is trusted
    *unchecked* (no sync — the caller owns the fit).  The reduction itself
    (bidirectional greedy) is a host-side loop by design — V' is
    polylog-sized after SS.
    """
    n = fn.n
    derived = max_members is None
    if max_members == "exact":
        max_members = int(jnp.sum(result.vprime))  # one sizing sync (opt-in)
    elif derived:
        max_members = ss_live_bound(n, r, c)
    slots = max(1, min(n, max_members))
    if derived and slots < n and int(jnp.sum(result.vprime)) > slots:
        # jnp.where(..., size=slots) would silently drop V' members and the
        # reduction would return a wrong mask — fail loudly instead.  (One
        # host read, only on the derived-default path and only when the block
        # is actually restrictive; an explicit int bound is trusted unchecked
        # precisely so callers can avoid this sync.)
        raise ValueError(
            f"postreduce slot bound {slots} < |V'|: the SS run used a "
            "different r/c than passed here — pass matching r/c, an explicit "
            "max_members, or max_members='exact'"
        )
    vp_idx = jnp.where(result.vprime, size=slots, fill_value=-1)[0]  # (slots,)
    valid = vp_idx >= 0
    members = jnp.where(valid, vp_idx, 0)
    residual = fn.residual_gains()
    # Edge weights from every V' member slot to every ground element:
    # (slots, n).  Invalid (padding) slots get +INF rows: they never cover.
    W = graph.edge_weights(fn, members, residual=residual)
    W = jnp.where(valid[:, None], W, INF)
    pruned = ~result.vprime

    def h_of(mask_members: Array) -> Array:
        # mask_members: (slots,) bool over member slots
        wmin = jnp.min(jnp.where(mask_members[:, None], W, INF), axis=0)
        covered = pruned & (wmin <= eps)
        return jnp.sum(covered) - 0.0  # |V'| term handled by caller's deltas

    def gain_fn(lo, hi, v):
        # marginal of adding v to lo, and of removing v from hi, under
        # h(X) = covered(X) - |X|  (Eq. 9 as coverage minus cardinality).
        a = h_of(lo.at[v].set(True)) - h_of(lo) - 1.0
        b = (h_of(hi.at[v].set(False)) - h_of(hi)) + 1.0
        # Padding slots cover nothing: a = -1 < 0 <= b = +1, never taken.
        return a, b

    keep_slots = bidirectional_greedy(gain_fn, slots, key)
    # Vectorized member-mask scatter: slot i keeps ground element vp_idx[i].
    # Invalid slots scatter to index n, dropped by out-of-bounds mode.
    target = jnp.where(valid & keep_slots, vp_idx, n)
    return jnp.zeros((n,), bool).at[target].set(True, mode="drop")


def summarize(
    fn: SubmodularFunction,
    k: int,
    key: Array,
    r: int = 8,
    c: float = 8.0,
    preprune: bool = False,
    importance: bool = False,
    backend: "str | Backend | None" = None,
    compact: bool = True,
):
    """End-to-end paper pipeline: (optional pre-prune) -> SS -> greedy on V'.

    ``backend`` selects the execution path for both stages.  ``compact``
    covers both stages too: shrink-aware SS rounds *and* the compact
    selection engine for the downstream greedy (post-SS |V'| ≪ n always fits
    a sub-n bucket, so the selection stage runs at |V'| cost by default).
    Returns (GreedyResult, SSResult).
    """
    alive = preprune_mask(fn, k) if preprune else None
    ss = ss_sparsify(
        fn, key, r=r, c=c, alive=alive, importance=importance, backend=backend,
        compact=compact,
    )
    res = greedy(fn, k, alive=ss.vprime, backend=backend, compact=compact)
    return res, ss
