"""The submodularity graph G(V, E, w) of Definition 1 and its divergences.

Edge weight (paper Eq. 3):        w_{u->v}   = f(v|u)   - f(u | V \\ u)
Conditional weight (paper Eq. 4): w_{u->v|S} = f(v|S+u) - f(u | V \\ u)
Divergence (Definition 2):        w_{V',v}   = min_{x in V'} w_{x->v}

Everything is computed in dense (r, n) blocks against a set of *probe* tail
nodes — the full n(n-1) graph is never materialized (that is the whole point
of the paper).  ``residual_gains`` ( = f(u|V\\u) for every u ) is computed once
and reused, exactly as the paper notes it can be ("may be precomputed once in
linear time").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.functions import NEG, SubmodularFunction

Array = jax.Array


def edge_weights(
    fn: SubmodularFunction,
    probes: Array,
    residual: Array | None = None,
    state: Array | None = None,
) -> Array:
    """Weights w_{u->v|S} for all probe tails u (r,) x all heads v.  Shape (r, n).

    ``residual`` is the precomputed f(u|V\\u) vector over the *full* ground set
    (n,); pass it to avoid recomputation across SS rounds.
    """
    if residual is None:
        residual = fn.residual_gains()
    pair = fn.pairwise_gains(probes, state)          # (r, n):  f(v | S + u)
    return pair - residual[probes][:, None]


def divergence(
    fn: SubmodularFunction,
    probes: Array,
    probe_mask: Array | None = None,
    residual: Array | None = None,
    state: Array | None = None,
) -> Array:
    """w_{U,v} = min_{u in U} w_{u->v|S} for all v.  Shape (n,).

    ``probe_mask`` (r,) marks which probe slots are valid (static-shape
    padding support); invalid probes are excluded from the min.
    """
    w = edge_weights(fn, probes, residual, state)    # (r, n)
    if probe_mask is not None:
        w = jnp.where(probe_mask[:, None], w, -NEG)  # +inf-ish: never the min
    return jnp.min(w, axis=0)


def edge_weights_compact(
    fn: SubmodularFunction,
    probes: Array,
    cand_idx: Array,
    residual: Array | None = None,
    state: Array | None = None,
) -> Array:
    """w_{u->v|S} for probe tails u (r,) x compacted heads v = cand_idx (k,).

    Shape (r, k).  The compacted analogue of :func:`edge_weights`: work scales
    with the live count k, not the ground-set size n (via the objective's
    ``pairwise_gains_compact`` — its base implementation is a full-width
    gather, so this is always correct, merely not always faster).
    """
    if residual is None:
        residual = fn.residual_gains()
    pair = fn.pairwise_gains_compact(probes, cand_idx, state)    # (r, k)
    return pair - residual[probes][:, None]


def divergence_compact(
    fn: SubmodularFunction,
    probes: Array,
    cand_idx: Array,
    probe_mask: Array | None = None,
    residual: Array | None = None,
    state: Array | None = None,
) -> Array:
    """w_{U,v} = min_{u in U} w_{u->v|S} for v = cand_idx (k,).  Shape (k,).

    Matches ``divergence(fn, probes, ...)[cand_idx]`` elementwise; padding
    entries of ``cand_idx`` (repeated valid indices) compute the divergence of
    whatever index they repeat — callers mask them before scattering back.
    """
    w = edge_weights_compact(fn, probes, cand_idx, residual, state)
    if probe_mask is not None:
        w = jnp.where(probe_mask[:, None], w, -NEG)
    return jnp.min(w, axis=0)


def divergence_batched(
    fn: SubmodularFunction,
    probes: Array,
    cand_idx: Array | None = None,
    residual: Array | None = None,
    state: Array | None = None,
) -> Array:
    """w_{U_b, v} per batch row b, for probes (B, r) and candidates
    cand_idx (B, k) (the full ground set when None).  Shape (B, k).

    ``fn`` is a *stacked* objective (leading batch axis on array leaves —
    see the micro-batching hooks in repro.core.functions); ``residual`` is
    the stacked (B, n) residual block.  Row b matches
    ``divergence_compact(fn[b], probes[b], cand_idx[b], ...)`` elementwise.
    """
    if residual is None:
        residual = jax.vmap(lambda f: f.residual_gains())(fn)
    pair = fn.pairwise_gains_batched(probes, cand_idx, state)    # (B, r, k)
    resid_p = jnp.take_along_axis(residual, probes, axis=1)      # (B, r)
    return jnp.min(pair - resid_p[:, :, None], axis=1)


def divergence_update(
    fn: SubmodularFunction,
    current: Array,
    probes: Array,
    probe_mask: Array | None = None,
    residual: Array | None = None,
    state: Array | None = None,
) -> Array:
    """min(current, w_{U,v}) — incremental divergence as V' grows.

    SS only ever needs the divergence against the *union* of all probe sets
    sampled so far; maintaining a running min turns each round into one
    (r, n) block instead of (|V'|, n).
    """
    return jnp.minimum(current, divergence(fn, probes, probe_mask, residual, state))


def full_edge_matrix(fn: SubmodularFunction, state: Array | None = None) -> Array:
    """All n x n edge weights (test/analysis only — O(n^2 F) memory/compute)."""
    n = fn.n
    return edge_weights(fn, jnp.arange(n), state=state)


def check_triangle_inequality(W: Array, atol: float = 1e-4) -> Array:
    """Max violation of Lemma 3:  w_vx <= w_vu + w_ux  over all *distinct*
    (v, u, x).  (The lemma's proof needs u ∉ {v, x}: it uses (v+x) ⊆ V∖u.)

    Returns max over valid triples of  w_vx - (w_vu + w_ux); should be
    <= atol for any submodular f.  Test utility (O(n^3)).
    """
    n = W.shape[0]
    # rhs[v, u, x] = W[v, u] + W[u, x]
    rhs = W[:, :, None] + W[None, :, :]
    lhs = W[:, None, :]
    i = jnp.arange(n)
    distinct = (
        (i[:, None, None] != i[None, :, None])   # v != u
        & (i[None, :, None] != i[None, None, :])  # u != x
        & (i[:, None, None] != i[None, None, :])  # v != x
    )
    return jnp.max(jnp.where(distinct, lhs - rhs, NEG))
