"""Distributed Submodular Sparsification: shard_map over the data axis.

This realizes the paper's "per-iteration computation ... is small and highly
parallelizable" claim on a TPU mesh.  The ground set's feature rows are
sharded over ``data``; each SS round is:

  1. **distributed probe sampling** — every device draws Gumbel scores for its
     live rows, proposes its local top-m, all-gathers the (m, F) candidate
     rows + scores, and takes the global top-m.  (Gumbel top-k == uniform
     sampling without replacement, so this is exactly Algorithm 1's sampler.)
  2. **local divergence** — the (m, F) probe block is tiny and replicated;
     each device computes w_{U,v} for its own rows only: the (m, n_local, F)
     contraction is embarrassingly parallel, as the paper promises.
  3. **distributed quantile prune** — instead of a global sort, a fixed-bin
     histogram of live divergences is psum'd and the (1 - 1/sqrt(c))-quantile
     threshold read off it.  We prune *at most* that fraction (the bin edge
     rounds down), preserving Proposition 4's safety direction.
  4. masks update locally; the loop is a ``lax.while_loop`` with fully static
     shapes inside one ``shard_map``.

**Hierarchical pod aggregation** (the composable-coreset pattern of paper
§1.2, with SS in place of per-machine greedy): when the mesh has a ``pod``
axis, every pod treats its own row range as a standalone ground set —
collectives bind only the ``data`` axis — and the returned V' is the union of
per-pod V' sets.  Cross-pod (DCN) traffic is zero during sparsification; only
the final (tiny) reduced set crosses pods.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.functions import NEG, FeatureCoverage
from repro.core.greedy import greedy
from repro.core.sparsify import max_rounds, probe_count

Array = jax.Array
INF = -NEG


def _phi(kind: str, c: Array) -> Array:
    if kind == "sqrt":
        return jnp.sqrt(jnp.maximum(c, 0.0))
    if kind == "log1p":
        return jnp.log1p(jnp.maximum(c, 0.0))
    if kind == "linear":
        return c
    raise ValueError(kind)


def ss_sparsify_sharded(
    W: Array,                  # (n, F) nonnegative feature rows (sharded in)
    key: Array,
    mesh: Mesh,
    *,
    data_axis: str = "data",
    pod_axis: str | None = None,
    r: int = 8,
    c: float = 8.0,
    phi: str = "sqrt",
    bins: int = 512,
):
    """Distributed Algorithm 1.  Returns (vprime (n,) bool, eps_hat scalar).

    ``W`` may live on host or device; it is placed row-sharded over
    (pod, data).  Each pod sparsifies its own row range independently
    (collectives over ``data`` only); the result is the union mask.
    """
    n, F = W.shape
    axes = (pod_axis, data_axis) if pod_axis else (data_axis,)
    nshards = 1
    for a in axes:
        nshards *= mesh.shape[a]
    ndata = mesh.shape[data_axis]
    npods = mesh.shape[pod_axis] if pod_axis else 1
    assert n % nshards == 0, f"n={n} must divide {nshards} shards (pad rows)"
    n_pod = n // npods                       # per-pod ground set size
    m = min(probe_count(n_pod, r), n_pod)    # probes per round (per pod)
    rounds_cap = max_rounds(n_pod, r, c)
    shrink = 1.0 - 1.0 / math.sqrt(c)

    in_spec = P(axes if len(axes) > 1 else axes[0], None)
    W = jax.device_put(W, NamedSharding(mesh, in_spec))
    keys = jax.random.split(key, npods)      # per-pod independent streams
    keys_spec = P(pod_axis) if pod_axis else P()
    if pod_axis:
        keys = jax.device_put(keys, NamedSharding(mesh, keys_spec))
    else:
        keys = keys[0]

    def kernel(W_loc: Array, key_loc: Array):
        # W_loc: (n_local, F) — this device's rows.  All collectives bind
        # data_axis only: pods run independently.
        if pod_axis:
            key_loc = key_loc[0]             # (1, 2) -> (2,)
        n_loc = W_loc.shape[0]
        didx = jax.lax.axis_index(data_axis)

        # residual gains f(u | V\u) against the *pod* ground set
        C = jax.lax.psum(jnp.sum(W_loc, axis=0), data_axis)       # (F,)
        phiC = jnp.sum(_phi(phi, C))
        residual = phiC - jnp.sum(_phi(phi, C[None, :] - W_loc), axis=-1)

        def cond(carry):
            alive, vprime, div, eps, k, rnd = carry
            total = jax.lax.psum(jnp.sum(alive), data_axis)
            return (total > m) & (rnd < rounds_cap)

        def body(carry):
            alive, vprime, div, eps, k, rnd = carry
            k, k1 = jax.random.split(k)
            # identical stream on every data shard; fold in the shard id for
            # distinct local gumbel draws
            g = (
                jax.random.gumbel(jax.random.fold_in(k1, didx), (n_loc,))
                + jnp.where(alive, 0.0, NEG)
            )
            loc_val, loc_idx = jax.lax.top_k(g, m)
            loc_rows = W_loc[loc_idx]                         # (m, F)
            all_val = jax.lax.all_gather(loc_val, data_axis).reshape(-1)
            all_rows = jax.lax.all_gather(loc_rows, data_axis).reshape(-1, F)
            top_val, top_pos = jax.lax.top_k(all_val, m)      # global top-m
            probes = all_rows[top_pos]                        # (m, F)

            # membership: my local row j became a probe iff its gumbel value
            # is among the global top-m (values are a.s. distinct)
            thresh_val = top_val[-1]
            probe_hot = alive & (g >= thresh_val)
            vprime = vprime | probe_hot
            alive = alive & ~probe_hot

            # local divergence w_{U, v} for my rows
            CU = probes                                        # S=∅: state 0
            phi_cu = jnp.sum(_phi(phi, CU), axis=-1)           # (m,)
            both = CU[:, None, :] + W_loc[None, :, :]          # (m, n_loc, F)
            pair = jnp.sum(_phi(phi, both), axis=-1) - phi_cu[:, None]
            # residual of each probe: recompute from the gathered rows
            resid_p = phiC - jnp.sum(_phi(phi, C[None, :] - CU), axis=-1)
            w = pair - resid_p[:, None]                        # (m, n_loc)
            div = jnp.minimum(div, jnp.min(w, axis=0))

            # distributed quantile: histogram of live divergences
            lo = jax.lax.pmin(
                jnp.min(jnp.where(alive, div, INF)), data_axis
            )
            hi = jax.lax.pmax(
                jnp.max(jnp.where(alive, div, -INF)), data_axis
            )
            width = jnp.maximum(hi - lo, 1e-9)
            bidx = jnp.clip(
                ((div - lo) / width * bins).astype(jnp.int32), 0, bins - 1
            )
            hist = jnp.zeros((bins,), jnp.int32).at[bidx].add(
                alive.astype(jnp.int32)
            )
            hist = jax.lax.psum(hist, data_axis)
            total = jnp.sum(hist)
            target = jnp.floor(total * shrink).astype(jnp.int32)
            cum = jnp.cumsum(hist)
            # largest bin edge with cumulative count <= target (prune <= frac)
            nbin = jnp.sum(cum <= target)                      # bins fully below
            thresh = lo + width * nbin.astype(jnp.float32) / bins
            removed = alive & (div < thresh)
            eps = jnp.maximum(
                eps, jax.lax.pmax(
                    jnp.max(jnp.where(removed, div, NEG)), data_axis
                )
            )
            alive = alive & ~removed
            return (alive, vprime, div, eps, k, rnd + 1)

        carry = (
            jnp.ones((n_loc,), bool),
            jnp.zeros((n_loc,), bool),
            jnp.full((n_loc,), INF),
            jnp.float32(NEG),
            key_loc,
            jnp.int32(0),
        )
        alive, vprime, div, eps, _, rnd = jax.lax.while_loop(cond, body, carry)
        vprime = vprime | alive
        eps = jnp.maximum(eps, 0.0)
        return vprime, (eps[None] if pod_axis else eps)

    out_mask_spec = P(axes if len(axes) > 1 else axes[0])
    eps_spec = P(pod_axis) if pod_axis else P()
    fn = jax.shard_map(
        kernel,
        mesh=mesh,
        in_specs=(in_spec, keys_spec),
        out_specs=(out_mask_spec, eps_spec),
        axis_names=set(axes),
        check_vma=False,
    )
    vprime, eps = fn(W, keys)
    eps_hat = jnp.max(eps) if pod_axis else eps
    return vprime, eps_hat


def summarize_sharded(
    W: Array,
    k: int,
    key: Array,
    mesh: Mesh,
    *,
    data_axis: str = "data",
    pod_axis: str | None = None,
    r: int = 8,
    c: float = 8.0,
    phi: str = "sqrt",
):
    """End-to-end distributed pipeline: sharded SS -> greedy on the union V'.

    The greedy stage sees only |V'| = O(log² n) rows — it runs on the full
    (replicated) objective like the paper's final stage.  Returns
    (selected (k,) indices into the original ground set, f(S), vprime mask).
    """
    vprime, eps = ss_sparsify_sharded(
        W, key, mesh, data_axis=data_axis, pod_axis=pod_axis, r=r, c=c, phi=phi
    )
    fn = FeatureCoverage(W=jnp.asarray(W), phi=phi)
    res = greedy(fn, k, alive=jnp.asarray(vprime))
    return res.selected, res.value, vprime, eps
