"""Distributed Submodular Sparsification: shard_map over the data axis.

This realizes the paper's "per-iteration computation ... is small and highly
parallelizable" claim on a TPU mesh, for **any** objective implementing the
shard hooks of :class:`repro.core.functions.SubmodularFunction` (per-shard
function views — no objective-specific math lives here).  Each SS round is:

  1. **distributed probe sampling** — every device draws Gumbel scores for its
     live candidates, proposes its local top-m, all-gathers the candidate
     (score, payload, residual) triples, and takes the global top-m.  (Gumbel
     top-k == uniform sampling without replacement, so this is exactly
     Algorithm 1's sampler.)  A probe's *payload* is whatever its objective
     declares sufficient for any shard to evaluate probe-conditioned gains —
     a coverage row for FeatureCoverage, a similarity column for
     FacilityLocation (which StreamingFacilityLocation reproduces from its
     embedding rows on the fly, so the wire format — and this loop — are
     identical for the matrix-free objective).
  2. **local divergence** — the (m, payload_dim) probe block is tiny and
     replicated; each device computes w_{U,v} for its own candidates only via
     ``fn.shard_payload_gains``: embarrassingly parallel, as the paper
     promises.
  3. **distributed quantile prune** — instead of a global sort, a fixed-bin
     histogram of live divergences is psum'd and the (1 - 1/sqrt(c))-quantile
     threshold read off it.  We prune *at most* that fraction (the bin edge
     rounds down), preserving Proposition 4's safety direction.
  4. masks update locally; the loop is a ``lax.while_loop`` with fully static
     shapes inside one ``shard_map``.

**Hierarchical pod aggregation** (the composable-coreset pattern of paper
§1.2, with SS in place of per-machine greedy): when the mesh has a ``pod``
axis, every pod treats its own row range as a standalone ground set —
collectives bind only the ``data`` axis — and the returned V' is the union of
per-pod V' sets.  Cross-pod (DCN) traffic is zero during sparsification; only
the final (tiny) reduced set crosses pods.  Pod hierarchy requires the
objective's arrays to be row-local (``supports_pod_sharding``): FeatureCoverage
qualifies, FacilityLocation (whose served rows span the full ground set) does
not.

Entry points: ``ss_sparsify(fn, key, backend="sharded")`` (via
:class:`repro.core.backend.ShardedBackend`) or :func:`ss_sparsify_sharded`
directly with an explicit mesh.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.functions import NEG, FeatureCoverage, SubmodularFunction
from repro.core.greedy import (
    GreedyResult,
    auto_sample_size,
    greedy,
    selection_bucket,
)
from repro.core.sparsify import SSResult, bucket_schedule, max_rounds, probe_count

Array = jax.Array
INF = -NEG


def _as_objective(fn, phi: str = "sqrt") -> SubmodularFunction:
    """Legacy entry point: a raw (n, F) feature array means FeatureCoverage."""
    if isinstance(fn, SubmodularFunction):
        return fn
    return FeatureCoverage(W=jnp.asarray(fn), phi=phi)


def ss_sparsify_sharded(
    fn,                        # SubmodularFunction or legacy (n, F) array
    key: Array,
    mesh: Mesh,
    *,
    data_axis: str = "data",
    pod_axis: str | None = None,
    r: int = 8,
    c: float = 8.0,
    phi: str = "sqrt",
    bins: int = 512,
    alive: Array | None = None,
    state: Array | None = None,
    importance: bool = False,
    compact: bool = True,
) -> SSResult:
    """Distributed Algorithm 1 over any shard-capable objective.

    The objective's arrays are placed candidate-sharded over (pod, data) via
    its ``shard_pack`` spec; each pod sparsifies its own candidate range
    independently (collectives over ``data`` only).  Returns a full
    :class:`SSResult` (``alive_trace`` is only recorded for single-level
    meshes; with a pod hierarchy it is -1, since pods run independent loops).

    ``state`` runs *conditional* SS on G(V, E|S): the replicated summary
    state is folded into each probe's payload (``shard_payloads(idx,
    state)``), so every shard evaluates f(v | S + u) with the exact dense
    arithmetic — residuals stay unconditional, matching the dense loop.
    ``importance`` (§3.4 improvement 2) weights each shard's Gumbel draws by
    log(f(u) + f(u|V\\u)) of its local candidates, computed via the
    ``shard_gains`` selection hook (requires ``supports_shard_greedy``).

    ``compact`` (default, for objectives with ``supports_shard_compact``)
    makes each shard gather its surviving candidates into a bucket-sized
    static buffer (``lax.switch`` over the per-shard :func:`bucket_schedule`)
    before evaluating payload gains — only the *grid* is rebalanced; the
    objective's sharded arrays never move.  The bucket index comes from the
    pmax of the per-shard live counts, so every shard of a pod takes the same
    branch and the branches stay collective-free.
    """
    fn = _as_objective(fn, phi)
    if importance and not fn.supports_shard_greedy:
        raise NotImplementedError(
            f"{type(fn).__name__} does not implement shard_gains — sharded "
            "importance sampling needs the per-shard singleton gains"
        )
    n = fn.n
    axes = (pod_axis, data_axis) if pod_axis else (data_axis,)
    nshards = 1
    for a in axes:
        nshards *= mesh.shape[a]
    ndata = mesh.shape[data_axis]
    npods = mesh.shape[pod_axis] if pod_axis else 1
    if pod_axis and not fn.supports_pod_sharding:
        raise NotImplementedError(
            f"{type(fn).__name__} does not support pod-hierarchical sharding"
        )
    assert n % nshards == 0, f"n={n} must divide {nshards} shards (pad rows)"
    n_pod = n // npods                       # per-pod ground set size
    n_loc = n // nshards                     # per-device candidate count
    m = min(probe_count(n_pod, r), n_pod)    # probes per round (per pod)
    # Each device proposes its local top-m_loc; proposing every local row is
    # enough when a shard holds fewer than m candidates (ndata * m_loc >= m).
    m_loc = min(m, n_loc)
    rounds_cap = max_rounds(n_pod, r, c)
    shrink = 1.0 - 1.0 / math.sqrt(c)
    # Per-shard compact buckets: jnp payload gains need no tile alignment, so
    # a fine-grained tile keeps compaction effective on small shards too.
    compact = compact and fn.supports_shard_compact
    buckets = bucket_schedule(n_loc, c, tile=8) if compact else None

    arrays, specs, rebuild = fn.shard_pack(axes)
    arrays = tuple(
        jax.device_put(a, NamedSharding(mesh, s)) for a, s in zip(arrays, specs)
    )
    mask_spec = P(axes if len(axes) > 1 else axes[0])
    alive0 = jnp.ones((n,), bool) if alive is None else jnp.asarray(alive)
    alive0 = jax.device_put(alive0, NamedSharding(mesh, mask_spec))
    has_state = state is not None

    keys = jax.random.split(key, npods)      # per-pod independent streams
    keys_spec = P(pod_axis) if pod_axis else P()
    if pod_axis:
        keys = jax.device_put(keys, NamedSharding(mesh, keys_spec))
    else:
        keys = keys[0]

    def kernel(key_loc: Array, alive_loc: Array, state_rep, *arrs):
        # All collectives bind data_axis only: pods run independently.
        fn_loc = rebuild(*arrs)
        if pod_axis:
            key_loc = key_loc[0]             # (1, 2) -> (2,)
        assert fn_loc.local_n() == n_loc
        didx = jax.lax.axis_index(data_axis)
        st = state_rep if has_state else None

        ctx = fn_loc.shard_init(data_axis)
        resid_loc = fn_loc.shard_residuals(ctx)       # (n_loc,)
        if importance:
            # §3.4: probe u with probability ∝ f(u) + f(u|V\u) — the same
            # logit expression as the dense loop, over local candidates.
            sing_loc = fn_loc.shard_gains(fn_loc.empty_state(), ctx)
            logits_loc = jnp.log(jnp.maximum(sing_loc + resid_loc, 1e-12))
        else:
            logits_loc = jnp.zeros((n_loc,))

        def cond(carry):
            alive, vprime, div, eps, k, rnd, trace = carry
            total = jax.lax.psum(jnp.sum(alive), data_axis)
            return (total > m) & (rnd < rounds_cap)

        def body(carry):
            alive, vprime, div, eps, k, rnd, trace = carry
            k, k1 = jax.random.split(k)
            # identical stream on every data shard; fold in the shard id for
            # distinct local gumbel draws
            g = (
                jax.random.gumbel(jax.random.fold_in(k1, didx), (n_loc,))
                + logits_loc
                + jnp.where(alive, 0.0, NEG)
            )
            loc_val, loc_idx = jax.lax.top_k(g, m_loc)
            loc_pay = fn_loc.shard_payloads(loc_idx, st)      # (m_loc, D)
            loc_res = resid_loc[loc_idx]                      # (m_loc,)
            all_val = jax.lax.all_gather(loc_val, data_axis).reshape(-1)
            all_pay = jax.lax.all_gather(loc_pay, data_axis)
            all_pay = all_pay.reshape(-1, all_pay.shape[-1])
            all_res = jax.lax.all_gather(loc_res, data_axis).reshape(-1)
            top_val, top_pos = jax.lax.top_k(all_val, m)      # global top-m
            payloads = all_pay[top_pos]                       # (m, D)
            resid_p = all_res[top_pos]                        # (m,)

            # membership: my local candidate j became a probe iff its gumbel
            # value is among the global top-m (values are a.s. distinct)
            thresh_val = top_val[-1]
            probe_hot = alive & (g >= thresh_val)
            vprime = vprime | probe_hot
            alive = alive & ~probe_hot

            # local divergence w_{U, v} for my candidates, via the per-shard
            # function view: f(v | U+u) from the replicated payload block.
            # Compacted: gather my live candidates into the smallest static
            # bucket that fits every shard's live count (pmax -> all shards
            # take the same collective-free branch), evaluate the (m, k)
            # block on the restricted view, scatter-min back.
            if compact:
                live_max = jax.lax.pmax(jnp.sum(alive), data_axis)
                bidx = jnp.sum(jnp.asarray(buckets) >= live_max) - 1

                def _make_branch(size):
                    if size >= n_loc:
                        def full(args):
                            _, payloads_b, resid_b, div_b = args
                            pair = fn_loc.shard_payload_gains(payloads_b, ctx)
                            w = pair - resid_b[:, None]
                            return jnp.minimum(div_b, jnp.min(w, axis=0))
                        return full

                    def branch(args):
                        alive_b, payloads_b, resid_b, div_b = args
                        cand_idx = jnp.where(alive_b, size=size, fill_value=0)[0]
                        cand_mask = jnp.arange(size) < jnp.sum(alive_b)
                        pair_c = fn_loc.shard_take(cand_idx).shard_payload_gains(
                            payloads_b, ctx
                        )                                     # (m, size)
                        w_c = jnp.min(pair_c - resid_b[:, None], axis=0)
                        w_c = jnp.where(cand_mask, w_c, INF)
                        return div_b.at[cand_idx].min(w_c)
                    return branch

                div = jax.lax.switch(
                    bidx,
                    [_make_branch(s) for s in buckets],
                    (alive, payloads, resid_p, div),
                )
            else:
                pair = fn_loc.shard_payload_gains(payloads, ctx)  # (m, n_loc)
                w = pair - resid_p[:, None]
                div = jnp.minimum(div, jnp.min(w, axis=0))

            # distributed quantile: histogram of live divergences
            lo = jax.lax.pmin(
                jnp.min(jnp.where(alive, div, INF)), data_axis
            )
            hi = jax.lax.pmax(
                jnp.max(jnp.where(alive, div, -INF)), data_axis
            )
            width = jnp.maximum(hi - lo, 1e-9)
            bidx = jnp.clip(
                ((div - lo) / width * bins).astype(jnp.int32), 0, bins - 1
            )
            hist = jnp.zeros((bins,), jnp.int32).at[bidx].add(
                alive.astype(jnp.int32)
            )
            hist = jax.lax.psum(hist, data_axis)
            total = jnp.sum(hist)
            target = jnp.floor(total * shrink).astype(jnp.int32)
            cum = jnp.cumsum(hist)
            # largest bin edge with cumulative count <= target (prune <= frac)
            nbin = jnp.sum(cum <= target)                      # bins fully below
            thresh = lo + width * nbin.astype(jnp.float32) / bins
            removed = alive & (div < thresh)
            eps = jnp.maximum(
                eps, jax.lax.pmax(
                    jnp.max(jnp.where(removed, div, NEG)), data_axis
                )
            )
            alive = alive & ~removed
            trace = trace.at[rnd].set(
                jax.lax.psum(jnp.sum(alive), data_axis).astype(jnp.int32)
            )
            return (alive, vprime, div, eps, k, rnd + 1, trace)

        carry = (
            alive_loc,
            jnp.zeros((n_loc,), bool),
            jnp.full((n_loc,), INF),
            jnp.float32(NEG),
            key_loc,
            jnp.int32(0),
            jnp.full((rounds_cap,), -1, jnp.int32),
        )
        alive, vprime, div, eps, _, rnd, trace = jax.lax.while_loop(
            cond, body, carry
        )
        vprime = vprime | alive
        eps = jnp.maximum(eps, 0.0)
        if pod_axis:
            return vprime, div, eps[None], rnd[None], trace[None]
        return vprime, div, eps, rnd, trace

    scalar_spec = P(pod_axis) if pod_axis else P()
    trace_spec = P(pod_axis, None) if pod_axis else P()
    state_in = state if has_state else jnp.zeros((1,), jnp.float32)
    fn_sm = shard_map(
        kernel,
        mesh=mesh,
        in_specs=(keys_spec, mask_spec, P()) + specs,
        out_specs=(mask_spec, mask_spec, scalar_spec, scalar_spec, trace_spec),
    )
    vprime, div, eps, rounds, trace = fn_sm(keys, alive0, state_in, *arrays)
    eps_hat = jnp.max(eps)
    rounds_out = jnp.max(rounds)
    if pod_axis:
        # Pods run independent loops of (possibly) different length — a single
        # global live-count trace is not well defined, so mark unrecorded.
        trace_out = jnp.full((rounds_cap,), -1, jnp.int32)
    else:
        trace_out = trace
    return SSResult(vprime, div, eps_hat, rounds_out, trace_out)


def stochastic_greedy_sharded(
    fn,                        # SubmodularFunction or legacy (n, F) array
    k: int,
    key: Array,
    mesh: Mesh,
    *,
    s: int | None = None,
    alive: Array | None = None,
    state: Array | None = None,
    compact: "bool | int | None" = None,
    data_axis: str = "data",
    c: float = 8.0,
    eps: float = 0.1,
    phi: str = "sqrt",
) -> GreedyResult:
    """Distributed stochastic greedy [Mirzasoleiman et al.] over the mesh —
    the selection-stage counterpart of :func:`ss_sparsify_sharded`.

    The sampler works in the same *frame* the dense path
    (:mod:`repro.core.greedy`) would pick for the same inputs, so the two are
    selection-for-selection identical under the same key in every case: when
    the live count fits a sub-n bucket (and ``compact`` is not False), the
    compact frame — candidates addressed by their rank among the
    initially-alive set, gathered once per shard into a static bucket-sized
    local buffer; otherwise the ground frame — candidates addressed by ground
    index, matching the dense full-width loop.  Each step:

    1. every shard draws the **identical** (B,)-sized Gumbel vector (the key
       is replicated and never folded with the shard id — this is what makes
       the sharded sampler selection-for-selection identical to the dense
       path under the same key) and computes the replicated top-s sample
       mask;
    2. each shard evaluates gains for its own sampled candidates only, via
       ``shard_take`` + ``shard_gains`` on the replicated summary state —
       compact per-shard work, embarrassingly parallel;
    3. the winner is a psum'd argmax: ``pmax`` of per-shard best gains, ties
       broken to the lowest compact position via ``pmin`` (matching the dense
       argmax tie order), and the replicated state advances by a one-hot
       ``psum`` of the winning shard's ``shard_add``.

    ``alive`` must be a *concrete* mask (the live count sizes the static
    buffers).  ``s=None`` derives the sample size from the live count.
    Requires the objective's ``supports_shard_greedy`` hooks.
    """
    return _select_sharded(
        fn, k, key, mesh, s=s, alive=alive, state=state, compact=compact,
        data_axis=data_axis, c=c, eps=eps, phi=phi, exact=False,
    )


def greedy_sharded(
    fn,                        # SubmodularFunction or legacy (n, F) array
    k: int,
    mesh: Mesh,
    *,
    alive: Array | None = None,
    state: Array | None = None,
    compact: "bool | int | None" = None,
    data_axis: str = "data",
    c: float = 8.0,
    phi: str = "sqrt",
) -> GreedyResult:
    """Distributed *exact* greedy over the mesh: the same compact frame and
    psum'd argmax as :func:`stochastic_greedy_sharded`, with every available
    candidate considered each step (no sampling, no PRNG key) — so
    ``greedy(backend="sharded")`` no longer evaluates gains on one process.

    Each step every shard evaluates gains for its own candidates on the
    replicated summary state (``shard_take`` + ``shard_gains``), the winner
    is the ``pmax`` of per-shard best gains (ties to the lowest frame
    position via ``pmin`` — the dense argmax tie order), and the replicated
    state advances by a one-hot ``psum`` of the winning shard's
    ``shard_add``.  Deterministic, and *selection-identical* to the dense
    ``greedy`` on the same inputs (pinned in tests/test_distributed.py).

    ``alive`` must be a concrete mask (the live count sizes the static
    buffers); requires the objective's ``supports_shard_greedy`` hooks.
    """
    return _select_sharded(
        fn, k, None, mesh, s=None, alive=alive, state=state, compact=compact,
        data_axis=data_axis, c=c, eps=0.1, phi=phi, exact=True,
    )


def _select_sharded(
    fn,
    k: int,
    key: Array | None,
    mesh: Mesh,
    *,
    s: int | None,
    alive: Array | None,
    state: Array | None,
    compact: "bool | int | None",
    data_axis: str,
    c: float,
    eps: float,
    phi: str,
    exact: bool,
) -> GreedyResult:
    """Shared distributed selection loop: exact greedy (``exact=True`` —
    every available candidate is a sample) and Gumbel-top-s stochastic
    greedy ride the identical frame/gains/argmax collectives."""
    fn = _as_objective(fn, phi)
    if not fn.supports_shard_greedy:
        raise NotImplementedError(
            f"{type(fn).__name__} does not implement the sharded selection "
            "hooks (shard_gains / shard_add)"
        )
    n = fn.n
    ndata = mesh.shape[data_axis]
    assert n % ndata == 0, f"n={n} must divide {ndata} shards (pad rows)"
    n_loc = n // ndata

    alive0 = jnp.ones((n,), bool) if alive is None else jnp.asarray(alive)
    alive_host = np.asarray(alive0)
    live = int(alive_host.sum())
    # Frame selection mirrors the dense plan exactly: compact frame iff the
    # dense path would compact (alive is concrete here, so an int ``compact``
    # bound reduces to the auto decision).
    bucket = None if compact is False else selection_bucket(n, live, c)
    compact_frame = bucket is not None
    B = bucket if compact_frame else n
    if compact_frame:
        # Static per-shard buffer: smallest fine-grained bucket holding every
        # shard's local live count (jnp gains need no tile alignment — tile=8
        # matches the sharded SS loop's compaction).
        loc_max = int(alive_host.reshape(ndata, n_loc).sum(axis=1).max())
        loc_fits = [
            b for b in bucket_schedule(n_loc, c, tile=8) if b >= loc_max
        ]
        loc_size = min(loc_fits) if loc_fits else n_loc
    else:
        loc_size = n_loc
    if exact:
        s = B
    elif s is None:
        s = auto_sample_size(n, k, eps, live=live)
    s = max(1, int(min(s, B)))
    state0 = fn.empty_state() if state is None else state

    arrays, specs, rebuild = fn.shard_pack((data_axis,))
    arrays = tuple(
        jax.device_put(a, NamedSharding(mesh, sp)) for a, sp in zip(arrays, specs)
    )
    mask_spec = P(data_axis)
    alive0 = jax.device_put(alive0, NamedSharding(mesh, mask_spec))
    BIG = jnp.int32(2**30)

    def kernel(alive_loc: Array, st0, *arrs):
        fn_loc = rebuild(*arrs)
        didx = jax.lax.axis_index(data_axis)
        if compact_frame:
            cnt = jnp.sum(alive_loc)
            counts = jax.lax.all_gather(cnt, data_axis)          # (S,)
            offset = jnp.sum(jnp.where(jnp.arange(ndata) < didx, counts, 0))
            # Local candidates and their global compact-frame positions:
            # shards own contiguous ground ranges, so ascending (shard, slot)
            # order is ascending ground order — position = alive-rank =
            # offset + slot.
            lidx = jnp.where(alive_loc, size=loc_size, fill_value=0)[0]
            lvalid = jnp.arange(loc_size) < cnt
            pos = (offset + jnp.arange(loc_size)).astype(jnp.int32)
            view = fn_loc.shard_take(lidx)
            avail0 = jnp.arange(B) < jax.lax.psum(cnt, data_axis)
        else:
            # Ground frame (the dense full-width loop's addressing): every
            # local slot is a candidate; dead slots are masked by the
            # replicated availability mask, exactly like the dense path.
            lidx = jnp.arange(loc_size)
            lvalid = jnp.ones((loc_size,), bool)
            pos = (didx * n_loc + jnp.arange(loc_size)).astype(jnp.int32)
            view = fn_loc
            avail0 = jax.lax.all_gather(alive_loc, data_axis).reshape(-1)
        pos_c = jnp.minimum(pos, B - 1)                          # safe gather
        ctx = fn_loc.shard_init(data_axis)

        def step(carry, key_i):
            st, avail = carry
            if exact:
                # Exact greedy: every available candidate is "sampled".
                sub = avail
            else:
                # (1) replicated Gumbel top-s over the compact frame.
                gumb = (
                    jax.random.gumbel(key_i, (B,))
                    + jnp.where(avail, 0.0, NEG)
                )
                cand = jax.lax.top_k(gumb, s)[1]
                sub = jnp.zeros((B,), bool).at[cand].set(True) & avail
            # (2) compact per-shard gains on the replicated state.
            g_loc = view.shard_gains(st, ctx)                    # (loc_size,)
            sub_loc = sub[pos_c] & lvalid
            g = jnp.where(sub_loc, g_loc, NEG)
            i_loc = jnp.argmax(g)
            gbest = g[i_loc]
            # (3) psum'd argmax: max gain, ties to the lowest position.
            gmax = jax.lax.pmax(gbest, data_axis)
            ok = gmax > NEG * 0.5
            pos_best = jnp.where(gbest >= gmax, pos[i_loc], BIG)
            pos_win = jax.lax.pmin(pos_best, data_axis)
            win = ok & (gbest >= gmax) & (pos[i_loc] == pos_win)
            ground = didx.astype(jnp.int32) * n_loc + lidx[i_loc]
            v = jax.lax.psum(jnp.where(win, ground, 0), data_axis)
            cand_state = fn_loc.shard_add(st, lidx[i_loc], ctx)
            summed = jax.tree.map(
                lambda x: jax.lax.psum(
                    jnp.where(win, x, jnp.zeros_like(x)), data_axis
                ),
                cand_state,
            )
            new_state = jax.tree.map(
                lambda sm, old: jnp.where(ok, sm, old), summed, st
            )
            avail = avail.at[jnp.where(ok, pos_win, B)].set(False, mode="drop")
            return (new_state, avail), (
                v.astype(jnp.int32), jnp.where(ok, gmax, 0.0),
            )

        xs = jnp.zeros((k, 2), jnp.uint32) if exact else jax.random.split(key, k)
        (st_f, _), (sel, gains) = jax.lax.scan(step, (st0, avail0), xs)
        return sel, gains, st_f

    fn_sm = shard_map(
        kernel,
        mesh=mesh,
        in_specs=(mask_spec, P()) + specs,
        out_specs=(P(), P(), P()),
    )
    sel, gains, st_f = fn_sm(alive0, state0, *arrays)
    return GreedyResult(sel, gains, fn.value(st_f), st_f)


def summarize_sharded(
    fn,                        # SubmodularFunction or legacy (n, F) array
    k: int,
    key: Array,
    mesh: Mesh,
    *,
    data_axis: str = "data",
    pod_axis: str | None = None,
    r: int = 8,
    c: float = 8.0,
    phi: str = "sqrt",
    bins: int = 512,
):
    """End-to-end distributed pipeline: sharded SS -> greedy on the union V'.

    The greedy stage sees only |V'| = O(log² n) live candidates — it runs on
    the full (replicated) objective like the paper's final stage.  Returns
    (selected (k,) indices into the original ground set, f(S), vprime mask,
    eps_hat certificate).
    """
    fn = _as_objective(fn, phi)
    ss = ss_sparsify_sharded(
        fn, key, mesh,
        data_axis=data_axis, pod_axis=pod_axis, r=r, c=c, bins=bins,
    )
    res = greedy(fn, k, alive=jnp.asarray(ss.vprime))
    return res.selected, res.value, ss.vprime, ss.eps_hat
