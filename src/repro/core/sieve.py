"""Sieve-streaming [Badanidiyuru et al., KDD 2014] — the paper's streaming
baseline (§4: "50 trials, leading to memory requirement of 50k").

One pass over the stream; T parallel threshold "sieves" (OPT guesses
v_j, log-spaced).  Element v is added to sieve j iff

    |S_j| < k   and   f(v | S_j) >= (v_j / 2 - f(S_j)) / (k - |S_j|)

Vectorized: sieve states are stacked (T, ...) and updated with one fused op
per stream element inside a lax.scan — no per-sieve Python loops.

Static-shape note: the original algorithm instantiates thresholds lazily from
the running max singleton m_t and *discards* sieves with v_j < m_t (a memory
optimization, not a quality one).  We keep a fixed log-spaced grid — sieves
that the original would not yet have instantiated are simply inactive until
m_t reaches them (same behaviour: earlier elements are never retroactively
added), and we do not discard low sieves (only improves quality, costs
k·T = the paper's quoted "50k" memory).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.functions import SubmodularFunction

Array = jax.Array


class SieveResult(NamedTuple):
    selected: Array    # (k,) indices of the best sieve's picks (pad = -1)
    value: Array       # f(S) of the best sieve
    best_sieve: Array  # index of winning threshold
    thresholds: Array  # (T,) the OPT guesses used


@partial(jax.jit, static_argnames=("k", "num_thresholds"))
def sieve_streaming(
    fn: SubmodularFunction,
    k: int,
    stream: Array | None = None,
    num_thresholds: int = 50,
    eps_grid: float | None = None,
) -> SieveResult:
    """Run sieve-streaming over ``stream`` (defaults to 0..n-1 order)."""
    n = fn.n
    stream = jnp.arange(n) if stream is None else stream
    T = num_thresholds

    # OPT in [m, k*m] with m = max singleton gain; guesses cover [m/2, 2*k*m].
    # The grid is laid out in *relative* log-space and anchored to the running
    # max m_t at scan time, which keeps the one-pass property.
    if eps_grid is None:
        ratios = jnp.logspace(jnp.log10(0.5), jnp.log10(2.0 * k), T)
    else:
        ratios = (1.0 + eps_grid) ** jnp.arange(T)

    empty = fn.empty_state()
    states0 = jax.tree.map(lambda x: jnp.broadcast_to(x, (T,) + x.shape).copy(), empty)
    sel0 = jnp.full((T, k), -1, jnp.int32)

    def gain_one(state, v):
        return fn.value(fn.add(state, v)) - fn.value(state)

    def step(carry, v):
        states, vals, counts, sel, m = carry
        g1 = gain_one(empty, v)                    # singleton gain of v
        m = jnp.maximum(m, g1)
        thr = ratios * m                           # (T,) OPT guesses, anchored
        g = jax.vmap(gain_one, in_axes=(0, None))(states, v)   # (T,)
        need = (thr / 2.0 - vals) / jnp.maximum(k - counts, 1)
        take = (counts < k) & (g >= need)
        new_states = jax.vmap(fn.add, in_axes=(0, None))(states, v)
        states = jax.tree.map(
            lambda ns, s: jnp.where(
                take.reshape((T,) + (1,) * (s.ndim - 1)), ns, s
            ),
            new_states,
            states,
        )
        sel = jnp.where(
            take[:, None] & (jnp.arange(k)[None, :] == counts[:, None]),
            v,
            sel,
        )
        vals = jnp.where(take, vals + g, vals)
        counts = counts + take.astype(jnp.int32)
        return (states, vals, counts, sel, m), None

    init = (states0, jnp.zeros((T,)), jnp.zeros((T,), jnp.int32), sel0, jnp.float32(0.0))
    (states, vals, counts, sel, m), _ = jax.lax.scan(step, init, stream)
    best = jnp.argmax(vals)
    return SieveResult(sel[best], vals[best], best, ratios * m)
