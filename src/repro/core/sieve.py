"""Sieve-streaming [Badanidiyuru et al., KDD 2014] — one-pass streaming
submodular maximization with the full geometric threshold set.

T parallel threshold "sieves" (OPT guesses v_j).  Element v is added to
sieve j iff

    |S_j| < k   and   f(v | S_j) >= (v_j / 2 - f(S_j)) / (k - |S_j|)

and the best sieve achieves f(S) >= (1/2 - eps) * OPT when the guesses are
the geometric lattice (1+eps)^j restricted to [m, 2*k*m] (m = running max
singleton) — ``eps`` is the lattice granularity and the guarantee's epsilon
(tests/test_sieve.py asserts the bound vs greedy, property-tested over
stream orderings).

The promoted geometric form implements the paper's *lazy instantiation*
with static shapes: a sieve is keyed to an **absolute** guess
v_j = (1+eps)^j that stays fixed for its whole lifetime (the analysis needs
this).  As m grows, guesses below m leave the window [m, 2·k·m] and their
slots are recycled — reset empty and re-keyed to the new guesses entering
at the top.  T = ceil(log(2k)/log(1+eps)) + 1 slots exactly cover the
window, so memory is static while the guess lattice slides with the stream.
The legacy form (``eps=None``) keeps the earlier fixed log-spaced ratio
grid anchored to m, unchanged surface.

Vectorized: sieve states are stacked (T, ...) and updated with one fused op
per stream element — no per-sieve Python loops.  The module exposes three
layers:

- :func:`sieve_streaming` — the one-shot API (a ``lax.scan`` over a fixed
  stream), unchanged surface from the earlier single-grid version;
- the **incremental** API — :func:`sieve_init` / :func:`sieve_update` /
  :func:`sieve_extend` / :func:`sieve_best` — the same arithmetic exposed
  per element, so long-lived callers (the streaming session engine,
  repro.serve.sessions) can persist a :class:`SieveState` between updates;
  ``sieve_extend(sieve_init(...), stream)`` is *bit-identical* to the
  one-shot run;
- the **row-streaming** sieve — :func:`stream_sieve_init` /
  :func:`stream_sieve_update` — for feature-coverage objectives over
  *unbounded* streams, where an element is its (F,) feature row and no
  ground set exists.  State per sieve is the coverage vector, so memory is
  O(T·(F + k)) regardless of stream length (the constant-memory property
  the paper's streaming baseline is quoted for).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.functions import SubmodularFunction, _phi

Array = jax.Array


class SieveResult(NamedTuple):
    selected: Array    # (k,) indices of the best sieve's picks (pad = -1)
    value: Array       # f(S) of the best sieve
    best_sieve: Array  # index of winning threshold
    thresholds: Array  # (T,) the OPT guesses used


class SieveState(NamedTuple):
    """Persistent state of an incremental sieve run (a pytree).

    Geometric mode (``jidx`` is an array): sieve j's OPT guess is the
    *absolute* value (1+eps)^jidx[j] (``lg`` = log(1+eps)), fixed while the
    slot lives in the window [m, 2·k·m] and recycled when m outgrows it.
    Legacy mode (``jidx`` is None): guesses are ``ratios * m`` — the
    fixed relative grid.  ``sel`` stores the stream values accepted by each
    sieve (pad = -1); ``t`` counts elements consumed."""

    ratios: Array   # (T,) legacy relative grid (initial guesses otherwise)
    states: Any     # (T, ...) per-sieve objective state
    vals: Array     # (T,) f(S_j)
    counts: Array   # (T,) int32 |S_j|
    sel: Array      # (T, k) int32 accepted elements (pad = -1)
    m: Array        # () f32 running max singleton gain
    t: Array        # () int32 elements consumed
    jidx: Any = None   # (T,) int32 absolute guess exponents (geometric mode)
    lg: Any = None     # () f32 log(1+eps) (geometric mode)


def threshold_grid(
    k: int, eps: float | None = None, num_thresholds: int | None = None
) -> Array:
    """The initial OPT-guess grid.

    With ``eps`` (the promoted geometric form): the lattice (1+eps)^j for
    j = 0..T-1 with T = ceil(log(2k)/log(1+eps)) + 1 — exactly enough slots
    to cover the active window [m, 2·k·m], since OPT ∈ [m, k·m] some guess
    lands within a (1+eps) factor below OPT and its sieve achieves
    (1/2 − eps)·OPT [Badanidiyuru et al., Thm. 4.1].  Without ``eps``: the
    legacy fixed-T log-spaced grid over [m/2, 2·k·m] relative to the
    running max (``num_thresholds`` defaults to the paper's "50 trials")."""
    if eps is not None:
        if eps <= 0:
            raise ValueError(f"eps must be positive; got {eps}")
        T = max(1, math.ceil(math.log(2.0 * k) / math.log1p(eps)) + 1)
        return (1.0 + eps) ** jnp.arange(T, dtype=jnp.float32)
    T = 50 if num_thresholds is None else num_thresholds
    return jnp.logspace(
        jnp.log10(0.5), jnp.log10(2.0 * k), T, dtype=jnp.float32
    )


def _slide_window(jidx: Array, lg: Array, m_prev: Array, m: Array):
    """Slide the absolute-guess window up to the new running max ``m``.

    Returns ``(jidx', thresholds, reset)``: slots whose guess fell below m
    are re-keyed T notches up (entering guesses at the top of [m, 2·k·m])
    and flagged for reset; on the very first element (m_prev == 0) the
    whole window anchors at m.  Slot identity is j mod T, so distinct
    exponents stay distinct through any number of recycles."""
    T = jidx.shape[0]
    jmin = jnp.where(
        m > 0,
        jnp.ceil(jnp.log(jnp.maximum(m, 1e-30)) / lg).astype(jnp.int32),
        jnp.int32(0),
    )
    first = m_prev <= 0
    base = jnp.where(first, jmin + jnp.arange(T, dtype=jnp.int32), jidx)
    expired = base < jmin
    wraps = (jmin - base + T - 1) // T
    new_jidx = jnp.where(expired, base + wraps * T, base)
    thr = jnp.exp(new_jidx.astype(jnp.float32) * lg)
    return new_jidx, thr, first | expired


def sieve_init(
    fn: SubmodularFunction,
    k: int,
    eps: float | None = None,
    num_thresholds: int | None = None,
) -> SieveState:
    """Fresh incremental sieve state for ``fn`` under budget ``k``."""
    ratios = threshold_grid(k, eps, num_thresholds)
    T = ratios.shape[0]
    empty = fn.empty_state()
    states0 = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (T,) + x.shape).copy(), empty
    )
    geometric = eps is not None
    return SieveState(
        ratios=ratios,
        states=states0,
        vals=jnp.zeros((T,), jnp.float32),
        counts=jnp.zeros((T,), jnp.int32),
        sel=jnp.full((T, k), -1, jnp.int32),
        m=jnp.float32(0.0),
        t=jnp.int32(0),
        jidx=jnp.arange(T, dtype=jnp.int32) if geometric else None,
        lg=jnp.float32(math.log1p(eps)) if geometric else None,
    )


def _sieve_step(fn: SubmodularFunction, state: SieveState, v: Array):
    """One element through every sieve — the shared scan body."""
    T = state.vals.shape[0]
    k = state.sel.shape[1]
    empty = fn.empty_state()

    def gain_one(s, u):
        return fn.value(fn.add(s, u)) - fn.value(s)

    g1 = gain_one(empty, v)                            # singleton gain of v
    m = jnp.maximum(state.m, g1)
    if state.jidx is None:                             # legacy relative grid
        jidx = None
        thr = state.ratios * m
        states_b, vals_b = state.states, state.vals
        counts_b, sel_b = state.counts, state.sel
    else:                                              # absolute guesses
        jidx, thr, reset = _slide_window(state.jidx, state.lg, state.m, m)
        states_b = jax.tree.map(
            lambda s, e: jnp.where(
                reset.reshape((T,) + (1,) * (s.ndim - 1)),
                jnp.broadcast_to(e, s.shape),
                s,
            ),
            state.states,
            empty,
        )
        vals_b = jnp.where(reset, 0.0, state.vals)
        counts_b = jnp.where(reset, 0, state.counts)
        sel_b = jnp.where(reset[:, None], -1, state.sel)
    g = jax.vmap(gain_one, in_axes=(0, None))(states_b, v)       # (T,)
    need = (thr / 2.0 - vals_b) / jnp.maximum(k - counts_b, 1)
    take = (counts_b < k) & (g >= need)
    new_states = jax.vmap(fn.add, in_axes=(0, None))(states_b, v)
    states = jax.tree.map(
        lambda ns, s: jnp.where(
            take.reshape((T,) + (1,) * (s.ndim - 1)), ns, s
        ),
        new_states,
        states_b,
    )
    sel = jnp.where(
        take[:, None] & (jnp.arange(k)[None, :] == counts_b[:, None]),
        v.astype(jnp.int32),
        sel_b,
    )
    return SieveState(
        ratios=state.ratios,
        states=states,
        vals=jnp.where(take, vals_b + g, vals_b),
        counts=counts_b + take.astype(jnp.int32),
        sel=sel,
        m=m,
        t=state.t + 1,
        jidx=jidx,
        lg=state.lg,
    )


@jax.jit
def sieve_update(
    fn: SubmodularFunction, state: SieveState, v: Array
) -> SieveState:
    """Consume one stream element (an index into ``fn``'s ground set).
    ``sieve_extend`` over a stream is bit-identical to calling this per
    element, which is bit-identical to the one-shot :func:`sieve_streaming`."""
    return _sieve_step(fn, state, jnp.asarray(v))


@jax.jit
def sieve_extend(
    fn: SubmodularFunction, state: SieveState, stream: Array
) -> SieveState:
    """Consume a stream of elements (one fused ``lax.scan``)."""
    def step(carry, v):
        return _sieve_step(fn, carry, v), None

    out, _ = jax.lax.scan(step, state, jnp.asarray(stream))
    return out


def sieve_best(state: SieveState) -> SieveResult:
    """The winning sieve's selections — the algorithm's output set."""
    best = jnp.argmax(state.vals)
    if state.jidx is None:
        thr = state.ratios * state.m
    else:
        thr = jnp.exp(state.jidx.astype(jnp.float32) * state.lg)
    return SieveResult(state.sel[best], state.vals[best], best, thr)


@partial(jax.jit, static_argnames=("k", "num_thresholds", "eps"))
def sieve_streaming(
    fn: SubmodularFunction,
    k: int,
    stream: Array | None = None,
    num_thresholds: int = 50,
    eps: float | None = None,
) -> SieveResult:
    """Run sieve-streaming over ``stream`` (defaults to 0..n-1 order).

    ``eps`` selects the geometric threshold set with the (1/2 − eps)
    guarantee (``num_thresholds`` is then ignored — T is derived from the
    window coverage); without it the legacy fixed-count log-spaced grid is
    used (the paper's 50-trial memory quote)."""
    stream = jnp.arange(fn.n) if stream is None else stream
    state = sieve_init(
        fn, k, eps=eps, num_thresholds=None if eps is not None else num_thresholds
    )
    return sieve_best(sieve_extend(fn, state, stream))


# ------------------------------------------------- row-streaming sieve ----
#
# The unbounded-stream form: elements are (F,) nonnegative feature rows of a
# concave-over-modular coverage objective f(S) = sum_f phi(c_f(S)) — no
# ground set, no n.  Per-sieve state is the coverage vector, so one update
# touches O(T·F) memory however long the stream runs.  This is the per-user
# primitive of the streaming ingestion tier (repro.serve.sessions).

#: phi transforms valid for the row-streaming sieve: phi(0) = 0 and no
#: ground-set-dependent saturation cap ("satcov" needs column sums over a
#: ground set that an unbounded stream does not have).
STREAM_PHIS = ("sqrt", "log1p", "setcover", "linear")


class StreamSieveState(NamedTuple):
    """Persistent per-stream sieve state (a pytree; all leaves are arrays so
    it snapshots to disk exactly — repro.serve.sessions).  Always geometric:
    sieve j's guess is the absolute (1+eps)^jidx[j], recycled as the window
    [m, 2·k·m] slides up with the running max."""

    jidx: Array     # (T,) int32 absolute guess exponents
    lg: Array       # () f32 log(1+eps)
    cov: Array      # (T, F) per-sieve coverage vectors
    vals: Array     # (T,) f(S_j)
    counts: Array   # (T,) int32 |S_j|
    sel: Array      # (T, k) int32 accepted stream positions (pad = -1)
    m: Array        # () f32 running max singleton gain
    t: Array        # () int32 elements consumed (the stream position)


def stream_sieve_init(
    k: int,
    n_features: int,
    eps: float = 0.2,
    dtype=jnp.float32,
) -> StreamSieveState:
    """Fresh row-streaming sieve state (geometric lattice from ``eps``)."""
    T = threshold_grid(k, eps).shape[0]
    return StreamSieveState(
        jidx=jnp.arange(T, dtype=jnp.int32),
        lg=jnp.float32(math.log1p(eps)),
        cov=jnp.zeros((T, n_features), dtype),
        vals=jnp.zeros((T,), jnp.float32),
        counts=jnp.zeros((T,), jnp.int32),
        sel=jnp.full((T, k), -1, jnp.int32),
        m=jnp.float32(0.0),
        t=jnp.int32(0),
    )


@partial(jax.jit, static_argnames=("phi",))
def stream_sieve_update(
    state: StreamSieveState, w: Array, phi: str = "sqrt"
) -> tuple[StreamSieveState, Array]:
    """Consume one stream element — its (F,) nonnegative feature row.

    Returns ``(new_state, accepted)`` where ``accepted`` is True iff any
    sieve took the element — the retention signal the session engine uses
    to decide whether the raw row enters the retained buffer (rejected
    elements are discarded forever: constant memory per update)."""
    if phi not in STREAM_PHIS:
        raise ValueError(
            f"stream sieve supports phi in {STREAM_PHIS}; got {phi!r}"
        )
    k = state.sel.shape[1]
    w = jnp.asarray(w)
    g1 = jnp.sum(_phi(phi, w, None))                  # singleton gain (phi(0)=0)
    m = jnp.maximum(state.m, g1)
    jidx, thr, reset = _slide_window(state.jidx, state.lg, state.m, m)
    cov_b = jnp.where(reset[:, None], 0.0, state.cov)
    vals_b = jnp.where(reset, 0.0, state.vals)
    counts_b = jnp.where(reset, 0, state.counts)
    sel_b = jnp.where(reset[:, None], -1, state.sel)
    g = jnp.sum(
        _phi(phi, cov_b + w[None, :], None) - _phi(phi, cov_b, None),
        axis=-1,
    )                                                  # (T,)
    need = (thr / 2.0 - vals_b) / jnp.maximum(k - counts_b, 1)
    take = (counts_b < k) & (g >= need)
    cov = jnp.where(take[:, None], cov_b + w[None, :], cov_b)
    sel = jnp.where(
        take[:, None] & (jnp.arange(k)[None, :] == counts_b[:, None]),
        state.t,
        sel_b,
    )
    new = StreamSieveState(
        jidx=jidx,
        lg=state.lg,
        cov=cov,
        vals=jnp.where(take, vals_b + g, vals_b),
        counts=counts_b + take.astype(jnp.int32),
        sel=sel,
        m=m,
        t=state.t + 1,
    )
    return new, jnp.any(take)


def stream_sieve_best(state: StreamSieveState) -> SieveResult:
    """The winning sieve's accepted stream positions and value."""
    best = jnp.argmax(state.vals)
    return SieveResult(
        state.sel[best], state.vals[best], best,
        jnp.exp(state.jidx.astype(jnp.float32) * state.lg),
    )
