"""Core reproduction of "Scaling Submodular Maximization via Pruned
Submodularity Graphs": objectives, the submodularity graph, SS (Algorithm 1),
and the greedy / streaming baselines."""

from repro.core.functions import FacilityLocation, FeatureCoverage
from repro.core.graph import divergence, edge_weights, full_edge_matrix
from repro.core.greedy import (
    GreedyResult,
    bidirectional_greedy,
    greedy,
    lazy_greedy,
    stochastic_greedy,
)
from repro.core.sieve import SieveResult, sieve_streaming
from repro.core.sparsify import (
    SSResult,
    preprune_mask,
    probe_count,
    ss_sparsify,
    summarize,
)

__all__ = [
    "FacilityLocation",
    "FeatureCoverage",
    "divergence",
    "edge_weights",
    "full_edge_matrix",
    "GreedyResult",
    "bidirectional_greedy",
    "greedy",
    "lazy_greedy",
    "stochastic_greedy",
    "SieveResult",
    "sieve_streaming",
    "SSResult",
    "preprune_mask",
    "probe_count",
    "ss_sparsify",
    "summarize",
]
