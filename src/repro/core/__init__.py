"""Core reproduction of "Scaling Submodular Maximization via Pruned
Submodularity Graphs": objectives, the submodularity graph, SS (Algorithm 1),
the greedy / streaming baselines, and the execution-backend dispatch layer
(oracle / pallas / sharded — see repro.core.backend and docs/backends.md)."""

from repro.core.backend import (
    Backend,
    OracleBackend,
    PallasBackend,
    ShardedBackend,
    available_backends,
    get_backend,
    register_backend,
    resolve_backend,
)
from repro.core.functions import (
    FacilityLocation,
    FeatureCoverage,
    StreamingFacilityLocation,
    SubmodularFunction,
)
from repro.core.graph import (
    divergence,
    divergence_compact,
    edge_weights,
    edge_weights_compact,
    full_edge_matrix,
)
from repro.core.greedy import (
    GreedyResult,
    auto_sample_size,
    bidirectional_greedy,
    greedy,
    greedy_batched,
    lazy_greedy,
    selection_bucket,
    stochastic_greedy,
    stochastic_greedy_batched,
)
from repro.core.sieve import SieveResult, sieve_streaming
from repro.core.sparsify import (
    SSResult,
    bucket_schedule,
    predicted_live_counts,
    preprune_mask,
    probe_count,
    ss_cost_model,
    ss_live_bound,
    ss_sparsify,
    ss_sparsify_batched,
    summarize,
)

__all__ = [
    "Backend",
    "OracleBackend",
    "PallasBackend",
    "ShardedBackend",
    "available_backends",
    "get_backend",
    "register_backend",
    "resolve_backend",
    "SubmodularFunction",
    "FacilityLocation",
    "FeatureCoverage",
    "StreamingFacilityLocation",
    "divergence",
    "divergence_compact",
    "edge_weights",
    "edge_weights_compact",
    "full_edge_matrix",
    "GreedyResult",
    "auto_sample_size",
    "bidirectional_greedy",
    "greedy",
    "greedy_batched",
    "lazy_greedy",
    "selection_bucket",
    "stochastic_greedy",
    "stochastic_greedy_batched",
    "SieveResult",
    "sieve_streaming",
    "SSResult",
    "bucket_schedule",
    "predicted_live_counts",
    "preprune_mask",
    "probe_count",
    "ss_cost_model",
    "ss_live_bound",
    "ss_sparsify",
    "ss_sparsify_batched",
    "summarize",
]
