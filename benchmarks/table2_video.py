"""Paper Table 2 (+ Figures 8-11): video summarization on 25 synthetic
SumMe-like videos — per-video |V'|, wall time for lazy greedy vs
sieve-streaming vs SS, and windowed F1/recall against a ground-truth
importance reference."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import frame_f1, save, timed
from repro.core import FacilityLocation, FeatureCoverage, greedy, sieve_streaming
from repro.core.sparsify import ss_sparsify
from repro.data import video

# paper Table 2 frame counts (we mirror the range, scaled 1/4 for CPU time)
PAPER_FRAMES = [4494, 4729, 3341, 3064, 5131, 4382, 5075, 9046, 1286, 4971,
                9721, 1612, 950, 3187, 4608, 6096, 2574, 3120, 3065, 6683,
                2221, 1751, 3863, 9672, 5178]


def _reference(X: np.ndarray, frac: float = 0.15) -> np.ndarray:
    """Ground-truth 'user' summary: frames farthest from their local temporal
    context (scene changes / unique moments), SumMe's voting proxy."""
    w = 24
    n = len(X)
    pad = np.pad(X, ((w, w), (0, 0)), mode="edge")
    local = np.stack([pad[i : i + 2 * w + 1].mean(0) for i in range(n)])
    novelty = np.linalg.norm(X - local, axis=1)
    k = max(1, int(frac * n))
    return np.argsort(-novelty)[:k]


def run(scale: float = 0.25, seed: int = 0, objective: str = "coverage") -> dict:
    key = jax.random.PRNGKey(seed)
    rows = []
    for vid, frames in enumerate(PAPER_FRAMES):
        n = max(200, int(frames * scale))
        X = video(seed * 100 + vid, n, n_features=256)
        k = max(1, int(0.15 * n))
        if objective == "fl":
            fn = FacilityLocation.from_features(jnp.asarray(X), kernel="cosine")
        else:
            fn = FeatureCoverage(W=jnp.asarray(X), phi="sqrt")

        res_g, t_g = timed(lambda: jax.block_until_ready(greedy(fn, k)))

        def run_ss():
            ss = ss_sparsify(fn, key, r=8, c=8.0)
            return jax.block_until_ready(greedy(fn, k, alive=ss.vprime)), ss

        (res_ss, ss), t_ss = timed(run_ss)
        res_sv, t_sv = timed(
            lambda: jax.block_until_ready(
                sieve_streaming(fn, k, num_thresholds=10)
            )
        )

        ref = _reference(X)
        f1 = {
            "greedy": frame_f1(np.asarray(res_g.selected), ref, n),
            "ss": frame_f1(np.asarray(res_ss.selected), ref, n),
            "sieve": frame_f1(
                np.asarray([i for i in np.asarray(res_sv.selected) if i >= 0]),
                ref, n),
            "first15": frame_f1(np.arange(k), ref, n),
        }
        rows.append({
            "video": vid, "frames": n, "k": k,
            "vprime": int(jnp.sum(ss.vprime)),
            "rel_ss": float(res_ss.value / res_g.value),
            "rel_sieve": float(res_sv.value / res_g.value),
            "t_greedy_s": t_g, "t_ss_s": t_ss, "t_sieve_s": t_sv,
            **{f"f1_{m}": v for m, v in f1.items()},
        })
        r = rows[-1]
        print(f"table2 vid={vid:2d} n={n:5d} |V'|={r['vprime']:5d} "
              f"rel_ss={r['rel_ss']:.4f} f1 g/ss/sv/first={f1['greedy']:.3f}/"
              f"{f1['ss']:.3f}/{f1['sieve']:.3f}/{f1['first15']:.3f} "
              f"t={t_g:.2f}/{t_ss:.2f}/{t_sv:.2f}s", flush=True)

    agg = {
        "rel_ss_mean": float(np.mean([r["rel_ss"] for r in rows])),
        "f1": {m: float(np.mean([r[f"f1_{m}"] for r in rows]))
               for m in ("greedy", "ss", "sieve", "first15")},
        "t_greedy_total": float(np.sum([r["t_greedy_s"] for r in rows])),
        "t_ss_total": float(np.sum([r["t_ss_s"] for r in rows])),
        "frames_removed_frac": float(
            np.mean([1 - r["vprime"] / r["frames"] for r in rows])
        ),
    }
    save("table2_video", {"rows": rows, "aggregate": agg})
    print("table2 aggregate:", agg)
    return {"rows": rows, "aggregate": agg}


if __name__ == "__main__":
    run()
