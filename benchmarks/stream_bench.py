"""Durable streaming-session benchmark: ingest throughput, snapshot cost,
and crash-recovery wall time for the sieve×SS session tier (PR 9).

A synthetic drifting stream (element magnitudes grow, so the sieve's
absolute-guess window keeps sliding and SS compaction actually fires)
drives ``sessions`` concurrent sessions on a durable
:class:`repro.serve.sessions.SessionEngine`:

- **append** — ``appends`` elements per session, interleaved round-robin so
  waves batch across sessions; recorded as ``stream/append-{backend}-...``
  rows with ``wall_s`` = seconds *per append* (WAL write + amortized wave
  execution + due SS compactions + due snapshots) and ``appends_per_s``.
- **snapshot** — one forced :meth:`SessionEngine.snapshot` per session;
  ``stream/snapshot-{backend}-...`` rows record ``wall_s`` per snapshot and
  ``snapshot_bytes`` (the npz on disk).
- **recover** — a fresh engine on the same root rehydrates every session
  (newest snapshot + WAL-tail replay through the same wave kernels);
  ``stream/recover-{backend}-...`` rows record ``wall_s`` = recovery
  seconds *per session*, plus ``wal_bytes``/``snapshot_bytes`` per session
  and the mean replayed-record count.

Correctness rides the bench (hard gate, not a timing): every recovered
session's state must be **bit-identical** — every leaf: thresholds,
retained buffer, PRNG key, counters — to the live engine's state at kill
time, the acceptance pin of docs/streaming.md.  A mismatch fails the run
with exit 1 regardless of wall times.

``--smoke`` runs the CI shape; ``--json`` / ``--baseline`` share
``kernel_bench.check_regression`` (``BENCH_stream.json`` at the repo root
is the committed baseline — the ``stream-chaos`` CI job gates recovery
wall time and ingest throughput against it).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import numpy as np

from benchmarks.kernel_bench import check_regression
from repro import obs
from repro.serve.sessions import SessionConfig, SessionEngine


def drift_rows(seed: int, n: int, n_features: int, drift: float = 6.0):
    r = np.random.default_rng(seed)
    scale = 1.0 + drift * np.arange(n, dtype=np.float32) / n
    return r.random((n, n_features)).astype(np.float32) * scale[:, None]


def _state_leaves(state):
    import jax

    return [np.asarray(x) for x in jax.tree_util.tree_leaves(state)]


def _dir_bytes(root: str, sid: str, prefix: str) -> int:
    sdir = os.path.join(root, sid)
    return sum(
        os.path.getsize(os.path.join(sdir, f))
        for f in os.listdir(sdir) if f.startswith(prefix)
    )


def run_backend(
    backend: str, sessions: int, appends: int, n_features: int,
    cfg_kw: dict, workdir: str,
) -> tuple[list[dict], int]:
    """One backend's append/snapshot/recover measurement; returns (rows,
    n_mismatched_sessions)."""
    cfg = SessionConfig(backend=backend, n_features=n_features, **cfg_kw)
    shape = f"{backend}-S{sessions}xN{appends}-F{n_features}"
    root = os.path.join(workdir, shape)
    # ingest measures the first ``appends`` elements; ``tail`` more land
    # after the forced snapshots so recovery has a real WAL tail to replay
    tail = cfg.resparsify_every
    streams = {
        f"u{i:03d}": drift_rows(i, appends + tail, n_features)
        for i in range(sessions)
    }

    eng = SessionEngine(cfg, root)
    for i, sid in enumerate(streams):
        eng.open_session(sid=sid, key=i)
    # warm the wave/compaction signatures so the timed loop measures
    # steady-state ingest, not jit compiles
    warm = SessionEngine(cfg, os.path.join(workdir, shape + "-warm"))
    for i, sid in enumerate(streams):
        warm.open_session(sid=sid, key=i)
    for t in range(min(appends, 2 * cfg.resparsify_every)):
        for sid, R in streams.items():
            warm.append(sid, R[t])
    warm.flush()
    del warm   # dropped cold (no close → no snapshot): the warm recovery
    # below replays its full WAL, compiling the B=1 replay signature too
    warm_rec = SessionEngine(cfg, os.path.join(workdir, shape + "-warm"))
    for sid in streams:
        warm_rec.state(sid)

    t0 = time.perf_counter()
    for t in range(appends):
        for sid, R in streams.items():
            eng.append(sid, R[t])
    eng.flush()
    ingest_wall = time.perf_counter() - t0
    n_app = sessions * appends
    st = eng.stats()
    rows = [{
        "bench_key": f"stream/append-{shape}",
        "wall_s": ingest_wall / n_app,
        "appends_per_s": n_app / ingest_wall,
        "waves": st["waves"],
        "resparsifies": st["resparsifies"],
        "snapshots": st["snapshots"],
        "backend": backend,
    }]

    # With tracing on, the per-snapshot / per-recovery walls are read back
    # off the engine's own sessions.snapshot / sessions.recover spans
    # instead of a second set of perf_counter books around the calls.
    tr = obs.get_tracer()
    snap_mark = len(tr.spans(name="sessions.snapshot"))
    t0 = time.perf_counter()
    for sid in streams:
        eng.snapshot(sid)
    if tr.enabled:
        snap_wall = float(np.mean([
            s.wall_s for s in tr.spans(name="sessions.snapshot")[snap_mark:]
        ]))
    else:
        snap_wall = (time.perf_counter() - t0) / sessions
    snap_bytes = int(np.mean(
        [_dir_bytes(root, sid, "snap-") for sid in streams]
    ))
    rows.append({
        "bench_key": f"stream/snapshot-{shape}",
        "wall_s": snap_wall,
        "snapshot_bytes": snap_bytes,
        "backend": backend,
    })

    # post-snapshot tail: recovery must do real WAL replay, not just a load
    for t in range(appends, appends + tail):
        for sid, R in streams.items():
            eng.append(sid, R[t])
    eng.flush()
    live = {sid: _state_leaves(eng.state(sid)) for sid in streams}
    wal_bytes = int(np.mean(
        [_dir_bytes(root, sid, "wal.log") for sid in streams]
    ))

    # the crash: the engine object is dropped cold, a fresh one recovers
    del eng
    rec_mark = len(tr.spans(name="sessions.recover"))
    t0 = time.perf_counter()
    rec = SessionEngine(cfg, root)
    for sid in streams:
        rec.state(sid)              # forces snapshot load + WAL-tail replay
    if tr.enabled:
        rec_wall = float(np.mean([
            s.wall_s for s in tr.spans(name="sessions.recover")[rec_mark:]
        ]))
    else:
        rec_wall = (time.perf_counter() - t0) / sessions
    replayed = [e["replayed"] for e in rec.events if e["step"] == "rehydrate"]
    rows.append({
        "bench_key": f"stream/recover-{shape}",
        "wall_s": rec_wall,
        "wal_bytes": wal_bytes,
        "snapshot_bytes": snap_bytes,
        "replayed_mean": float(np.mean(replayed)) if replayed else 0.0,
        "backend": backend,
    })

    mismatched = 0
    for sid in streams:
        got = _state_leaves(rec.state(sid))
        if not all(np.array_equal(a, b) for a, b in zip(live[sid], got)):
            print(f"recovery-gate: session {sid} ({backend}) recovered to a "
                  "DIFFERENT state than the live engine", file=sys.stderr)
            mismatched += 1
    return rows, mismatched


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__.split("\n")[0],
    )
    ap.add_argument("--smoke", action="store_true",
                    help="the CI shape: small counts, both backends")
    ap.add_argument("--sessions", type=int, default=8)
    ap.add_argument("--appends", type=int, default=256,
                    help="elements per session")
    ap.add_argument("--features", type=int, default=32)
    ap.add_argument("--backends", nargs="+", default=["oracle", "pallas"])
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the observability state (spans + bus events "
                    "+ metrics JSON) as one artifact after the run")
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="committed BENCH_stream.json to gate against")
    ap.add_argument("--max-ratio", type=float, default=2.0)
    ap.add_argument("--abs-floor", type=float, default=0.010)
    args = ap.parse_args()

    sessions, appends = args.sessions, args.appends
    if args.smoke:
        sessions, appends = 4, 96
    cfg_kw = dict(
        k=8, eps=0.2, buffer_cap=64, resparsify_every=16, ss_r=3,
        max_batch=4, snapshot_every=48,
    )

    rows: list[dict] = []
    mismatched = 0
    with tempfile.TemporaryDirectory(prefix="stream_bench_") as workdir:
        for backend in args.backends:
            r, bad = run_backend(
                backend, sessions, appends, args.features, cfg_kw, workdir,
            )
            rows += r
            mismatched += bad
            for row in r:
                extra = ", ".join(
                    f"{k}={v}" for k, v in row.items()
                    if k not in ("bench_key", "wall_s", "backend")
                )
                print(f"{row['bench_key']:44s} {row['wall_s']*1e3:8.2f}ms "
                      f"({extra})", flush=True)

    if mismatched:
        print(f"recovery-gate: {mismatched} session(s) failed bit-exact "
              "replay — recovery is broken, wall times are moot",
              file=sys.stderr)
        return 1
    print("recovery-gate: every recovered session bit-identical to the "
          "live engine", flush=True)

    if args.trace_out:
        tr = obs.get_tracer()
        bus = obs.get_bus()
        artifact = {
            "spans": tr.export(),
            "spans_dropped": tr.dropped,
            "events": bus.export(),
            "events_dropped": bus.dropped,
            "metrics": obs.get_registry().to_json(),
        }
        with open(args.trace_out, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"wrote trace artifact to {args.trace_out} "
              f"({len(artifact['spans'])} spans, "
              f"{len(artifact['events'])} events)", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows}, f, indent=1)
        print(f"wrote {len(rows)} rows to {args.json}", flush=True)
    if args.baseline:
        bad, unmeasured = check_regression(
            rows, args.baseline, args.max_ratio, args.abs_floor,
        )
        if bad or unmeasured:
            print(f"regression-gate: {bad} stream row(s) regressed "
                  f">{args.max_ratio}x and {unmeasured} baseline key(s) "
                  f"unmeasured vs {args.baseline}", file=sys.stderr)
            return 1
        print(f"regression-gate: all stream rows within {args.max_ratio}x "
              "of baseline", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
