"""Beyond-paper benchmark: SS as the training-data coreset stage — batch
coverage utility and selection wall-time for uniform / SS / full-greedy
selection policies (the integration the LM stack actually uses)."""

from __future__ import annotations

import dataclasses
import time

from benchmarks.common import save
from repro.data import DataConfig, Pipeline, selection_quality


def run(seed: int = 0) -> dict:
    cfg = DataConfig(batch_size=16, seq_len=128, vocab_size=50304,
                     pool_factor=6, feature_dim=512)
    quality = selection_quality(cfg, steps=4, seed=seed)
    times = {}
    for sel in ("uniform", "ss", "greedy"):
        pipe = Pipeline(dataclasses.replace(cfg, selection=sel), seed=seed)
        pipe()  # warm-up / compile
        t0 = time.perf_counter()
        for _ in range(3):
            pipe()
        times[sel] = (time.perf_counter() - t0) / 3
    out = {"coverage_utility": quality, "batch_time_s": times,
           "ss_vs_uniform": quality["ss"] / quality["uniform"],
           "ss_vs_greedy": quality["ss"] / quality["greedy"]}
    print("data_selection:", out)
    save("data_selection", out)
    return out


if __name__ == "__main__":
    run()
