"""Paper Figures 3-5: per-day news summarization statistics — relative
utility, ROUGE-2 and F1 against topic-structured references, over many
synthetic "days" of varying size (the 3823-day NYT study, scaled to this
container)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import TopicNews, rouge2, rouge2_f1, save, timed
from repro.core import FeatureCoverage, greedy, sieve_streaming
from repro.core.sparsify import ss_sparsify

K = 10


def run(days=16, n_range=(800, 6000), seed=0) -> dict:
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    rows = []
    for d in range(days):
        n = int(rng.integers(*n_range))
        day = TopicNews(seed * 1000 + d, n)
        fn = FeatureCoverage(W=jnp.asarray(day.features()), phi="sqrt")

        res_g, t_g = timed(lambda: jax.block_until_ready(greedy(fn, K)))

        def run_ss():
            ss = ss_sparsify(fn, key, r=8, c=8.0)
            return jax.block_until_ready(greedy(fn, K, alive=ss.vprime)), ss

        (res_ss, ss), t_ss = timed(run_ss)
        res_sv, t_sv = timed(
            lambda: jax.block_until_ready(sieve_streaming(fn, K))
        )

        fg = float(res_g.value)
        sel = {
            "greedy": np.asarray(res_g.selected),
            "ss": np.asarray(res_ss.selected),
            "sieve": np.asarray([i for i in np.asarray(res_sv.selected) if i >= 0]),
        }
        row = {"day": d, "n": n, "vprime": int(jnp.sum(ss.vprime)),
               "t_greedy_s": t_g, "t_ss_s": t_ss, "t_sieve_s": t_sv}
        for name, idx in sel.items():
            docs = [day.docs[i] for i in idx]
            row[f"rouge2_{name}"] = rouge2(docs, day.reference)
            row[f"f1_{name}"] = rouge2_f1(docs, day.reference)
        row["rel_ss"] = float(res_ss.value) / fg
        row["rel_sieve"] = float(res_sv.value) / fg
        rows.append(row)
        print(f"fig3 day={d:2d} n={n:5d} rel_ss={row['rel_ss']:.4f} "
              f"rel_sieve={row['rel_sieve']:.4f} "
              f"rouge2 g/ss/sv={row['rouge2_greedy']:.3f}/"
              f"{row['rouge2_ss']:.3f}/{row['rouge2_sieve']:.3f}", flush=True)

    agg = {
        "days": days,
        "rel_ss_mean": float(np.mean([r["rel_ss"] for r in rows])),
        "rel_ss_p10": float(np.percentile([r["rel_ss"] for r in rows], 10)),
        "rel_sieve_mean": float(np.mean([r["rel_sieve"] for r in rows])),
        "rouge2": {m: float(np.mean([r[f"rouge2_{m}"] for r in rows]))
                   for m in ("greedy", "ss", "sieve")},
        "f1": {m: float(np.mean([r[f"f1_{m}"] for r in rows]))
               for m in ("greedy", "ss", "sieve")},
        "speedup_vs_greedy": float(
            np.mean([r["t_greedy_s"] / max(r["t_ss_s"], 1e-9) for r in rows])
        ),
    }
    save("fig3_news", {"rows": rows, "aggregate": agg})
    print("fig3 aggregate:", agg)
    return {"rows": rows, "aggregate": agg}


if __name__ == "__main__":
    run()
