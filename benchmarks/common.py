"""Shared benchmark utilities: timing, result records, synthetic references.

Quality metrics on the synthetic corpora (offline stand-ins — DESIGN.md §7):
ROUGE-2 against a reference built from the generator's own topic structure,
and windowed F1 for video frame summaries.  Absolute values are not
comparable to the paper's (different corpora); the *relationships* the paper
claims (SS ≈ greedy ≫ sieve at a fraction of greedy's cost) are.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def timed(fn, *args, repeat: int = 1, **kw):
    """Returns (result, best_seconds)."""
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def save(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def bigrams(tokens) -> set:
    t = list(tokens)
    return set(zip(t[:-1], t[1:]))


def rouge2(candidate_docs, reference_docs) -> float:
    """ROUGE-2 recall: fraction of reference bigrams covered."""
    ref = set()
    for d in reference_docs:
        ref |= bigrams(d)
    if not ref:
        return 0.0
    cand = set()
    for d in candidate_docs:
        cand |= bigrams(d)
    return len(ref & cand) / len(ref)


def rouge2_f1(candidate_docs, reference_docs) -> float:
    ref = set()
    for d in reference_docs:
        ref |= bigrams(d)
    cand = set()
    for d in candidate_docs:
        cand |= bigrams(d)
    if not ref or not cand:
        return 0.0
    inter = len(ref & cand)
    p, r = inter / len(cand), inter / len(ref)
    return 0.0 if p + r == 0 else 2 * p * r / (p + r)


def frame_f1(selected, reference, n_frames: int, window: int = 16) -> float:
    """Windowed F1 between two frame-index summaries (SumMe-style voting
    tolerance: a selected frame matches a reference frame within ±window)."""
    sel = np.asarray(sorted(set(int(i) for i in selected)))
    ref = np.asarray(sorted(set(int(i) for i in reference)))
    if len(sel) == 0 or len(ref) == 0:
        return 0.0
    hit_sel = np.zeros(len(sel), bool)
    hit_ref = np.zeros(len(ref), bool)
    j = 0
    for i, s in enumerate(sel):
        dists = np.abs(ref - s)
        k = int(np.argmin(dists))
        if dists[k] <= window:
            hit_sel[i] = True
            hit_ref[k] = True
    p = hit_sel.mean()
    r = hit_ref.mean()
    return 0.0 if p + r == 0 else float(2 * p * r / (p + r))


class TopicNews:
    """Token-level synthetic news day with known topic structure, for
    ROUGE-scored summarization benchmarks (fig. 3 analogue)."""

    def __init__(self, seed: int, n_sentences: int, vocab: int = 2048,
                 n_topics: int = 10, sent_len: int = 18):
        rng = np.random.default_rng(seed)
        self.topics = rng.dirichlet(np.full(vocab, 0.03), size=n_topics)
        weights = rng.dirichlet(np.ones(n_topics) * 0.5)
        self.assign = rng.choice(n_topics, size=n_sentences, p=weights)
        self.docs = np.stack([
            rng.choice(vocab, size=sent_len, p=self.topics[t])
            for t in self.assign
        ])
        # reference summary: per major topic, the sentence with max topic prob
        counts = np.bincount(self.assign, minlength=n_topics)
        major = np.argsort(-counts)[: max(3, n_topics // 3)]
        refs = []
        for t in major:
            idx = np.where(self.assign == t)[0]
            scores = [self.topics[t][self.docs[i]].sum() for i in idx]
            refs.append(self.docs[idx[int(np.argmax(scores))]])
        self.reference = refs

    def features(self, n_features: int = 1024):
        from repro.data import hashed_features

        return hashed_features(self.docs, n_features=n_features, ngram=2)
