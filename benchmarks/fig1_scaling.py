"""Paper Figure 1: utility f(S) and wall time vs ground-set size n, for
lazy greedy, sieve-streaming, and SS(+greedy).  Synthetic NYT-like corpus.

``backend`` selects the execution path of the SS + greedy stages through the
unified dispatch layer (repro.core.backend): "oracle" (default), "pallas",
or "sharded".

CLI: ``python -m benchmarks.fig1_scaling --json PATH`` emits, per
(n, backend), a ``fig1/...`` row with a *warm* SS(+greedy) wall time
(``wall_s`` — best of ``--repeat`` runs, so jit tracing is amortized out of
the gated metric) plus ``greedy/...`` and ``stochastic_greedy/...`` rows
whose ``wall_s`` is the *post-SS selection stage alone* (the compact
selection engine's gated metric — each row also records which path the
engine took).  ``--baseline PATH`` gates every fresh row against a committed
JSON (``BENCH_e2e.json`` at the repo root is the CI baseline, sharing the
regression logic of ``benchmarks.kernel_bench``) and exits nonzero on a
wall-time regression.

``--objective fl_stream`` switches the sweep to the matrix-free
StreamingFacilityLocation over clustered unit-norm embeddings
(``data/synthetic.clustered_embeddings``) — the axis that runs at n where
dense FacilityLocation cannot allocate its (n, n) sim matrix (default size
65536 ≙ a 16 GiB matrix that is never built).  Rows gate under the same
baseline file, filtered to their own objective slice.
"""

from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp

from benchmarks.common import save, timed
from repro.core import (
    FeatureCoverage,
    StreamingFacilityLocation,
    greedy,
    lazy_greedy,
    selection_bucket,
    sieve_streaming,
    stochastic_greedy,
)
from repro.core.sparsify import ss_sparsify
from repro.data import clustered_embeddings, news_day

K = 10
R, C = 8, 8.0


def run(sizes=(512, 1024, 2048, 4096, 8192), n_features=512, seed=0,
        backend="oracle", repeat=1) -> dict:
    rows = []
    key = jax.random.PRNGKey(seed)
    for n in sizes:
        W = jnp.asarray(news_day(seed + n, n, n_features))
        fn = FeatureCoverage(W=W, phi="sqrt")

        res_g, t_full_g = timed(lambda: jax.block_until_ready(
            greedy(fn, K, backend=backend)))
        _, t_lazy = timed(lambda: lazy_greedy(fn, K))

        def run_ss():
            ss = ss_sparsify(fn, key, r=R, c=C, backend=backend)
            out = greedy(fn, K, alive=ss.vprime, backend=backend)
            return jax.block_until_ready(out), ss

        (res_ss, ss), t_ss = timed(run_ss, repeat=repeat)
        res_sv, t_sv = timed(
            lambda: jax.block_until_ready(sieve_streaming(fn, K))
        )

        # Post-SS selection stage alone — the compact selection engine's
        # gated metric (SS already shrank the live set to |V'| ≪ n; per-step
        # selection cost must track |V'|, not n).
        live = int(jnp.sum(ss.vprime))
        bucket = selection_bucket(n, live)
        path = "full" if bucket is None else f"compact-{bucket}"
        _, t_sel = timed(lambda: jax.block_until_ready(
            greedy(fn, K, alive=ss.vprime, backend=backend)), repeat=repeat)
        sg_key = jax.random.fold_in(key, 1)
        _, t_sg = timed(lambda: jax.block_until_ready(
            stochastic_greedy(fn, K, sg_key, alive=ss.vprime,
                              backend=backend)), repeat=repeat)

        fg = float(res_g.value)
        rows.append({
            "n": int(n),
            "backend": backend,
            "bench_key": f"fig1/{backend}-n{n}",
            "wall_s": t_ss,
            "f_greedy": fg,
            "rel_ss": float(res_ss.value) / fg,
            "rel_sieve": float(res_sv.value) / fg,
            "vprime": live,
            "selection_path": path,
            "t_greedy_s": t_sel,
            "t_sgreedy_s": t_sg,
            "t_full_greedy_s": t_full_g,
            "t_lazy_s": t_lazy,
            "t_ss_s": t_ss,
            "t_sieve_s": t_sv,
        })
        rows.append({
            "n": int(n), "backend": backend,
            "bench_key": f"greedy/{backend}-n{n}", "wall_s": t_sel,
            "vprime": live, "selection_path": path,
        })
        rows.append({
            "n": int(n), "backend": backend,
            "bench_key": f"stochastic_greedy/{backend}-n{n}", "wall_s": t_sg,
            "vprime": live, "selection_path": path,
        })
        print(f"fig1 n={n:6d} rel_ss={rows[-3]['rel_ss']:.4f} "
              f"rel_sieve={rows[-3]['rel_sieve']:.4f} |V'|={live:5d} "
              f"sel={path} t(greedy/lazy/ss/sel/sg/sieve)="
              f"{t_full_g:.2f}/{t_lazy:.2f}/{t_ss:.2f}/{t_sel:.2f}/"
              f"{t_sg:.2f}/{t_sv:.2f}s", flush=True)
    save("fig1_scaling", rows)
    return {"rows": rows}


def run_stream(sizes=(65536,), d=16, seed=0, backend="oracle", repeat=1,
               ss_r=2) -> dict:
    """The ``--objective fl_stream`` axis: SS(+greedy) on the matrix-free
    StreamingFacilityLocation at ground-set sizes where dense FL cannot even
    allocate its (n, n) sim matrix (the ``from_features`` guard trips at
    16384 rows; the default 65536 would be 16 GiB).  There is no full-greedy
    quality reference at these n — the rows pin wall time, |V'|, and f(S)
    instead; dense-parity of the underlying primitives is pinned at small n
    by tests/test_fl_stream.py and the ``fl_stream/...`` kernel rows."""
    rows = []
    key = jax.random.PRNGKey(seed)
    for n in sizes:
        X = jnp.asarray(clustered_embeddings(seed + n, n, d))
        fn = StreamingFacilityLocation.from_features(X, kernel="dot")

        def run_ss():
            ss = ss_sparsify(fn, key, r=ss_r, c=C, backend=backend)
            out = greedy(fn, K, alive=ss.vprime, backend=backend)
            return jax.block_until_ready(out), ss

        (res_ss, ss), t_ss = timed(run_ss, repeat=repeat)
        live = int(jnp.sum(ss.vprime))
        bucket = selection_bucket(n, live)
        path = "full" if bucket is None else f"compact-{bucket}"
        _, t_sel = timed(lambda: jax.block_until_ready(
            greedy(fn, K, alive=ss.vprime, backend=backend)), repeat=repeat)
        sg_key = jax.random.fold_in(key, 1)
        _, t_sg = timed(lambda: jax.block_until_ready(
            stochastic_greedy(fn, K, sg_key, alive=ss.vprime,
                              backend=backend)), repeat=repeat)

        rows.append({
            "n": int(n), "d": int(d), "backend": backend,
            "bench_key": f"fig1/fl_stream-{backend}-n{n}",
            "wall_s": t_ss,
            "f_ss": float(res_ss.value),
            "vprime": live,
            "rounds": int(ss.rounds),
            "selection_path": path,
            "t_ss_s": t_ss,
            "t_greedy_s": t_sel,
            "t_sgreedy_s": t_sg,
            "dense_sim_gib": 4.0 * n * n / 2**30,
            "stream_mib": 4.0 * n * d / 2**20,
        })
        rows.append({
            "n": int(n), "backend": backend,
            "bench_key": f"greedy/fl_stream-{backend}-n{n}", "wall_s": t_sel,
            "vprime": live, "selection_path": path,
        })
        rows.append({
            "n": int(n), "backend": backend,
            "bench_key": f"stochastic_greedy/fl_stream-{backend}-n{n}",
            "wall_s": t_sg, "vprime": live, "selection_path": path,
        })
        print(f"fig1[fl_stream] n={n:6d} f_ss={float(res_ss.value):.1f} "
              f"|V'|={live:5d} rounds={int(ss.rounds)} sel={path} "
              f"t(ss/sel/sg)={t_ss:.2f}/{t_sel:.2f}/{t_sg:.2f}s "
              f"(dense sim would be "
              f"{rows[-3]['dense_sim_gib']:.1f} GiB)", flush=True)
    save("fig1_scaling_fl_stream", rows)
    return {"rows": rows}


def main() -> int:
    from benchmarks.kernel_bench import check_regression

    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+",
                    default=[512, 1024, 2048, 4096, 8192])
    ap.add_argument("--backends", nargs="+", default=["oracle"])
    ap.add_argument("--objective", choices=["fc", "fl_stream"], default="fc",
                    help="fc: the paper's FeatureCoverage sweep; fl_stream: "
                    "matrix-free StreamingFacilityLocation at n past the "
                    "dense (n, n) wall (default size 65536)")
    ap.add_argument("--ss-r", type=int, default=2,
                    help="SS redundancy parameter r for the fl_stream axis "
                    "(probe count scales as r*log2(n); large-n rows keep it "
                    "small to bound single-core wall time)")
    ap.add_argument("--ss-d", type=int, default=16,
                    help="embedding dim for the fl_stream axis")
    ap.add_argument("--repeat", type=int, default=2,
                    help="timing repeats for the SS stage (>=2 gives warm "
                    "wall times — the gated metric)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write all rows (bench_key + warm SS wall_s) to PATH")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="committed baseline JSON (BENCH_e2e.json) to gate "
                    "SS wall times against")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail when wall_s exceeds baseline * this ratio")
    ap.add_argument("--abs-floor", type=float, default=0.25,
                    help="seconds over baseline a key must also regress by "
                    "(end-to-end timings carry more machine noise than the "
                    "kernel smoke, hence the higher floor)")
    args = ap.parse_args()

    rows = []
    for backend in args.backends:
        if args.objective == "fl_stream":
            rows += run_stream(sizes=tuple(args.sizes), d=args.ss_d,
                               backend=backend, repeat=args.repeat,
                               ss_r=args.ss_r)["rows"]
        else:
            rows += run(sizes=tuple(args.sizes), backend=backend,
                        repeat=args.repeat)["rows"]
    if len(args.backends) > 1:
        # run() saves its own backend's rows each call — rewrite the legacy
        # artifact with the combined set so no backend's rows are dropped.
        save("fig1_scaling" if args.objective == "fc"
             else "fig1_scaling_fl_stream", rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows}, f, indent=1)
        print(f"wrote {len(rows)} rows to {args.json}", flush=True)
    if args.baseline:
        # BENCH_e2e.json is shared by both objective axes; each invocation
        # gates only its own slice so the other axis's keys aren't counted
        # as unmeasured.
        key_ok = (lambda k: ("fl_stream" in k) == (args.objective
                                                  == "fl_stream"))
        bad, unmeasured = check_regression(rows, args.baseline,
                                           args.max_ratio, args.abs_floor,
                                           key_ok=key_ok)
        if bad or unmeasured:
            print(f"regression-gate: {bad} e2e row(s) regressed "
                  f">{args.max_ratio}x and {unmeasured} baseline key(s) "
                  f"unmeasured vs {args.baseline} (run all baseline "
                  "sizes/backends, or refresh the baseline)",
                  file=sys.stderr)
            return 1
        print(f"regression-gate: all e2e rows within {args.max_ratio}x "
              "of baseline", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
