"""Paper Figure 1: utility f(S) and wall time vs ground-set size n, for
lazy greedy, sieve-streaming, and SS(+greedy).  Synthetic NYT-like corpus.

``backend`` selects the execution path of the SS + greedy stages through the
unified dispatch layer (repro.core.backend): "oracle" (default), "pallas",
or "sharded".
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save, timed
from repro.core import FeatureCoverage, greedy, lazy_greedy, sieve_streaming
from repro.core.sparsify import ss_sparsify
from repro.data import news_day

K = 10
R, C = 8, 8.0


def run(sizes=(512, 1024, 2048, 4096, 8192), n_features=512, seed=0,
        backend="oracle") -> dict:
    rows = []
    key = jax.random.PRNGKey(seed)
    for n in sizes:
        W = jnp.asarray(news_day(seed + n, n, n_features))
        fn = FeatureCoverage(W=W, phi="sqrt")

        res_g, t_g = timed(lambda: jax.block_until_ready(
            greedy(fn, K, backend=backend)))
        _, t_lazy = timed(lambda: lazy_greedy(fn, K))

        def run_ss():
            ss = ss_sparsify(fn, key, r=R, c=C, backend=backend)
            out = greedy(fn, K, alive=ss.vprime, backend=backend)
            return jax.block_until_ready(out), ss

        (res_ss, ss), t_ss = timed(run_ss)
        res_sv, t_sv = timed(
            lambda: jax.block_until_ready(sieve_streaming(fn, K))
        )

        fg = float(res_g.value)
        rows.append({
            "n": int(n),
            "backend": backend,
            "f_greedy": fg,
            "rel_ss": float(res_ss.value) / fg,
            "rel_sieve": float(res_sv.value) / fg,
            "vprime": int(jnp.sum(ss.vprime)),
            "t_greedy_s": t_g,
            "t_lazy_s": t_lazy,
            "t_ss_s": t_ss,
            "t_sieve_s": t_sv,
        })
        print(f"fig1 n={n:6d} rel_ss={rows[-1]['rel_ss']:.4f} "
              f"rel_sieve={rows[-1]['rel_sieve']:.4f} |V'|={rows[-1]['vprime']:5d} "
              f"t(greedy/lazy/ss/sieve)="
              f"{t_g:.2f}/{t_lazy:.2f}/{t_ss:.2f}/{t_sv:.2f}s", flush=True)
    save("fig1_scaling", rows)
    return {"rows": rows}


if __name__ == "__main__":
    run()
