"""Paper Figure 1: utility f(S) and wall time vs ground-set size n, for
lazy greedy, sieve-streaming, and SS(+greedy).  Synthetic NYT-like corpus.

``backend`` selects the execution path of the SS + greedy stages through the
unified dispatch layer (repro.core.backend): "oracle" (default), "pallas",
or "sharded".

CLI: ``python -m benchmarks.fig1_scaling --json PATH`` emits, per
(n, backend), a ``fig1/...`` row with a *warm* SS(+greedy) wall time
(``wall_s`` — best of ``--repeat`` runs, so jit tracing is amortized out of
the gated metric) plus ``greedy/...`` and ``stochastic_greedy/...`` rows
whose ``wall_s`` is the *post-SS selection stage alone* (the compact
selection engine's gated metric — each row also records which path the
engine took).  ``--baseline PATH`` gates every fresh row against a committed
JSON (``BENCH_e2e.json`` at the repo root is the CI baseline, sharing the
regression logic of ``benchmarks.kernel_bench``) and exits nonzero on a
wall-time regression.
"""

from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp

from benchmarks.common import save, timed
from repro.core import (
    FeatureCoverage,
    greedy,
    lazy_greedy,
    selection_bucket,
    sieve_streaming,
    stochastic_greedy,
)
from repro.core.sparsify import ss_sparsify
from repro.data import news_day

K = 10
R, C = 8, 8.0


def run(sizes=(512, 1024, 2048, 4096, 8192), n_features=512, seed=0,
        backend="oracle", repeat=1) -> dict:
    rows = []
    key = jax.random.PRNGKey(seed)
    for n in sizes:
        W = jnp.asarray(news_day(seed + n, n, n_features))
        fn = FeatureCoverage(W=W, phi="sqrt")

        res_g, t_full_g = timed(lambda: jax.block_until_ready(
            greedy(fn, K, backend=backend)))
        _, t_lazy = timed(lambda: lazy_greedy(fn, K))

        def run_ss():
            ss = ss_sparsify(fn, key, r=R, c=C, backend=backend)
            out = greedy(fn, K, alive=ss.vprime, backend=backend)
            return jax.block_until_ready(out), ss

        (res_ss, ss), t_ss = timed(run_ss, repeat=repeat)
        res_sv, t_sv = timed(
            lambda: jax.block_until_ready(sieve_streaming(fn, K))
        )

        # Post-SS selection stage alone — the compact selection engine's
        # gated metric (SS already shrank the live set to |V'| ≪ n; per-step
        # selection cost must track |V'|, not n).
        live = int(jnp.sum(ss.vprime))
        bucket = selection_bucket(n, live)
        path = "full" if bucket is None else f"compact-{bucket}"
        _, t_sel = timed(lambda: jax.block_until_ready(
            greedy(fn, K, alive=ss.vprime, backend=backend)), repeat=repeat)
        sg_key = jax.random.fold_in(key, 1)
        _, t_sg = timed(lambda: jax.block_until_ready(
            stochastic_greedy(fn, K, sg_key, alive=ss.vprime,
                              backend=backend)), repeat=repeat)

        fg = float(res_g.value)
        rows.append({
            "n": int(n),
            "backend": backend,
            "bench_key": f"fig1/{backend}-n{n}",
            "wall_s": t_ss,
            "f_greedy": fg,
            "rel_ss": float(res_ss.value) / fg,
            "rel_sieve": float(res_sv.value) / fg,
            "vprime": live,
            "selection_path": path,
            "t_greedy_s": t_sel,
            "t_sgreedy_s": t_sg,
            "t_full_greedy_s": t_full_g,
            "t_lazy_s": t_lazy,
            "t_ss_s": t_ss,
            "t_sieve_s": t_sv,
        })
        rows.append({
            "n": int(n), "backend": backend,
            "bench_key": f"greedy/{backend}-n{n}", "wall_s": t_sel,
            "vprime": live, "selection_path": path,
        })
        rows.append({
            "n": int(n), "backend": backend,
            "bench_key": f"stochastic_greedy/{backend}-n{n}", "wall_s": t_sg,
            "vprime": live, "selection_path": path,
        })
        print(f"fig1 n={n:6d} rel_ss={rows[-3]['rel_ss']:.4f} "
              f"rel_sieve={rows[-3]['rel_sieve']:.4f} |V'|={live:5d} "
              f"sel={path} t(greedy/lazy/ss/sel/sg/sieve)="
              f"{t_full_g:.2f}/{t_lazy:.2f}/{t_ss:.2f}/{t_sel:.2f}/"
              f"{t_sg:.2f}/{t_sv:.2f}s", flush=True)
    save("fig1_scaling", rows)
    return {"rows": rows}


def main() -> int:
    from benchmarks.kernel_bench import check_regression

    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+",
                    default=[512, 1024, 2048, 4096, 8192])
    ap.add_argument("--backends", nargs="+", default=["oracle"])
    ap.add_argument("--repeat", type=int, default=2,
                    help="timing repeats for the SS stage (>=2 gives warm "
                    "wall times — the gated metric)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write all rows (bench_key + warm SS wall_s) to PATH")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="committed baseline JSON (BENCH_e2e.json) to gate "
                    "SS wall times against")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail when wall_s exceeds baseline * this ratio")
    ap.add_argument("--abs-floor", type=float, default=0.25,
                    help="seconds over baseline a key must also regress by "
                    "(end-to-end timings carry more machine noise than the "
                    "kernel smoke, hence the higher floor)")
    args = ap.parse_args()

    rows = []
    for backend in args.backends:
        rows += run(sizes=tuple(args.sizes), backend=backend,
                    repeat=args.repeat)["rows"]
    if len(args.backends) > 1:
        # run() saves its own backend's rows each call — rewrite the legacy
        # artifact with the combined set so no backend's rows are dropped.
        save("fig1_scaling", rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows}, f, indent=1)
        print(f"wrote {len(rows)} rows to {args.json}", flush=True)
    if args.baseline:
        bad, unmeasured = check_regression(rows, args.baseline,
                                           args.max_ratio, args.abs_floor)
        if bad or unmeasured:
            print(f"regression-gate: {bad} e2e row(s) regressed "
                  f">{args.max_ratio}x and {unmeasured} baseline key(s) "
                  f"unmeasured vs {args.baseline} (run all baseline "
                  "sizes/backends, or refresh the baseline)",
                  file=sys.stderr)
            return 1
        print(f"regression-gate: all e2e rows within {args.max_ratio}x "
              "of baseline", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
