"""Serving-engine throughput/latency benchmark: the micro-batched
summarization service vs the sequential single-query loop.

A synthetic load generator builds ``num`` summarization queries (news_day
feature payloads, per-query PRNG keys), which are served two ways:

- **sequential loop** — the pre-service calling pattern: per query, one
  ``ss_sparsify`` + ``greedy`` invocation (default settings, warm jit
  caches), timed per query.  Recorded per backend as ``serve/seq-...`` rows.
- **micro-batched service** — all queries submitted to a
  :class:`repro.serve.summarize_service.SummarizeService` with
  ``max_batch=B`` and flushed; per-query latency = queue delay + the wall
  time of the micro-batch the query rode in.  Recorded as
  ``serve/batch-...`` rows.

Every row carries a stable ``bench_key`` and ``wall_s`` = seconds *per
query* (so the shared ``check_regression`` gate reads it like any other
wall time), plus ``qps`` and p50/p99 latency.  Batched rows also record
``speedup_vs_seq_same_backend`` and ``speedup_vs_seq_oracle`` (the default
sequential loop a pre-service caller runs).

CPU-container note (measured, 2 cores): at n=1024 the interpret-mode pallas
sequential loop is already within ~1.4x of the machine's arithmetic floor
for SS's probe-divergence work, so the batched engine's win *over that
specific loop* is modest here (~1.3x); against the default (oracle)
sequential loop the batched pallas service clears 3x with room.  On TPU the
batched organization is the one that amortizes kernel launches and keeps
grids full — re-record the baseline there once a runner exists.

**Poisson open-loop mode** (``--poisson``, PR 7): a seeded Poisson arrival
process drives the *async* scheduler at a fraction of the measured
saturation rate (saturation = max_batch / full-batch execution time), and
two flusher policies serve the identical arrival trace:

- ``deadline`` — the SLO-aware policy: ``scheduler="async"`` with
  ``max_wait_s`` ≈ half a full-batch execution and a per-request
  ``deadline_s`` of 3 executions, so lanes fire on (full ∨ deadline-slack ∨
  max-wait);
- ``flush_on_full`` — the pre-PR-7 behavior as a policy: lanes fire only
  when full (``max_wait_s`` effectively infinite), leftovers on drain;
- ``deadline_ladder`` (PR 8) — the ``deadline`` policy plus the
  degradation ladder ``("bump_c", "shrink_r")``: when a lane's EWMA
  predicts a deadline miss the service trades SS accuracy (paper
  Theorem 1's c/r knobs) for execution time instead of missing.  Degraded
  signatures are warmed up front so the first ladder firing is not a
  compile.  Soft gate: at >= 0.8x load the ladder policy must not miss
  *more* deadlines than the plain deadline policy on the same trace
  (warn-only — miss counts ride runner noise; the hard acceptance pin
  lives in tests/test_serve_faults.py).

Per-query latency (queue delay + batch execution) is recorded as
``serve/poisson-{policy}-load{..}-...`` rows at 0.5x and 0.8x saturation;
the ``deadline`` rows also record ``p99_vs_flush_on_full`` — the
acceptance pin is that this ratio stays < 1 at 0.8x load (bounded queue
residency beats waiting for a full bucket once arrival gaps stretch).

**Fault-injection mode** (``--faults``, PR 8): a seeded
:class:`repro.serve.FaultPlan` (exec errors + latency spikes + malformed
results at fixed per-attempt rates) is threaded into a closed-loop sync
run; the recovery path (bounded retry → backend failover → per-query
isolation) must serve every query anyway.  Recorded as
``serve/faults-{backend}-...`` rows whose ``wall_s`` (seconds/query *with*
recovery overhead) rides the same regression gate, alongside
``completion_rate`` (hard-gated at 1.0 — fault schedules are
deterministic, so a lost ticket is a recovery bug, not noise), p50/p99,
and the recovery counters.

``--smoke`` runs the acceptance shape (n=1024, B=8) with a small query
count; ``--json`` / ``--baseline`` share ``kernel_bench.check_regression``
(``BENCH_serve.json`` at the repo root is the committed CI baseline; a run
gates only the slices it measured — skip ``--poisson`` / ``--faults`` and
those baseline keys are exempted, not counted unmeasured).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save
from repro import obs
from repro.core import FeatureCoverage, greedy, ss_sparsify
from repro.data import news_day
from repro.serve import (
    FaultPlan,
    RunConfig,
    SummarizeRequest,
    SummarizeService,
    batch_buckets,
)

K = 10

# The degradation ladder the ``deadline_ladder`` poisson policy runs.  On
# this container's CPU sizes the stochastic_greedy step saves nothing
# (selection is not the bottleneck at n~1e3), so the bench exercises the
# two SS-side steps — measured degraded/full execution ratio ~0.55-0.6.
LADDER = ("bump_c", "shrink_r")

# Per-attempt fault rates for ``--faults`` (roughly one faulted attempt
# per 3-4 chunk executions, mixing all recoverable kinds; hangs are
# exercised in the chaos tests, not the bench — a watchdog timeout would
# put seconds of injected sleep into the gated wall time).
FAULT_RATES = dict(p_exec_error=0.15, p_latency=0.1, p_malformed=0.05)


def make_queries(num: int, n: int, n_features: int, k: int = K,
                 seed: int = 0) -> list[SummarizeRequest]:
    """Synthetic load: ``num`` single-day news corpora with distinct seeds
    and per-query PRNG keys."""
    return [
        SummarizeRequest(
            k=k,
            key=jax.random.PRNGKey(seed * 10_000 + i),
            features=jnp.asarray(news_day(seed * 10_000 + i, n, n_features)),
        )
        for i in range(num)
    ]


def _pctl(lat: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(lat), q))


def run_sequential(queries, backend: str) -> dict:
    """The pre-service loop: one ss_sparsify + greedy call per query.

    With tracing enabled (``REPRO_TRACE=1`` / ``--obs-overhead``) the
    per-query latencies are read back off the ``bench.query`` trace spans
    instead of a bespoke ``perf_counter`` list — the bench consumes the
    same timing surface it is benchmarking (docs/observability.md)."""
    def one(q):
        fn = FeatureCoverage(W=q.features, phi="sqrt")
        ss = ss_sparsify(fn, q.prng_key(), backend=backend)
        res = greedy(fn, q.k, alive=ss.vprime, backend=backend)
        return jax.block_until_ready(res.value)

    one(queries[0])                       # warm the jit caches
    tr = obs.get_tracer()
    lat = []
    t0 = time.perf_counter()
    if tr.enabled:
        for i, q in enumerate(queries):
            with tr.span("bench.query", query=i, backend=backend,
                         mode="sequential"):
                one(q)
        wall = time.perf_counter() - t0
        lat = [
            s.wall_s for s in tr.spans(name="bench.query")
            if s.attrs.get("backend") == backend
            and s.attrs.get("mode") == "sequential"
        ][-len(queries):]
    else:
        for q in queries:
            t = time.perf_counter()
            one(q)
            lat.append(time.perf_counter() - t)
        wall = time.perf_counter() - t0
    return {
        "wall_s": wall / len(queries),
        "qps": len(queries) / wall,
        "p50_s": _pctl(lat, 50),
        "p99_s": _pctl(lat, 99),
    }


def run_batched(queries, backend: str, max_batch: int) -> dict:
    """The service path: submit everything, flush, read per-query latency
    (queue delay + micro-batch execution) off the responses — or, when
    tracing is on, off each request's ``queue.wait`` + ``chunk.exec``
    spans (the service emits them anyway; the bench just stops keeping a
    parallel set of books)."""
    def serve():
        svc = SummarizeService(
            RunConfig(backend=backend, max_batch=max_batch)
        )
        t0 = time.perf_counter()
        responses = svc.run(queries)
        wall = time.perf_counter() - t0
        return svc, responses, wall

    serve()                               # warm the jit caches
    tr = obs.get_tracer()
    if tr.enabled:
        # Ticket indices restart at 0 per service, so drop the warm run's
        # spans before the measured one — req-i must resolve uniquely.
        tr.clear()
    svc, responses, wall = serve()
    if tr.enabled:
        lat = []
        for i in range(len(queries)):
            spans = tr.spans_for_request(i)
            wait = sum(s.wall_s for s in spans if s.name == "queue.wait")
            execs = sum(s.wall_s for s in spans if s.name == "chunk.exec")
            lat.append(wait + execs)
    else:
        lat = [r.queue_delay_s + r.exec_s for r in responses]
    st = svc.stats()
    return {
        "wall_s": wall / len(queries),
        "qps": len(queries) / wall,
        "p50_s": _pctl(lat, 50),
        "p99_s": _pctl(lat, 99),
        "batches": st["batches"],
        "padding_waste_frac": st["padding_waste_frac"],
        "queue_delay_s_mean": st["queue_delay_s_mean"],
    }


def _measure_exec_full(queries, backend: str, max_batch: int) -> float:
    """Warm every (lane, B-bucket) signature the open-loop run can hit, then
    measure one full-batch execution — the unit the load generator and both
    flusher policies are calibrated in."""
    svc = SummarizeService(RunConfig(backend=backend, max_batch=max_batch))
    for b in batch_buckets(max_batch):
        svc.run(queries[:b])
    full = svc.run(queries[:max_batch])
    return full[0].exec_s


def _warm_ladder_levels(queries, backend: str, max_batch: int) -> None:
    """Compile every degraded (level, B-bucket) signature the ladder can
    fire — compile caches are process-wide, so forcing each level through
    a throwaway service leaves the measured run's first degraded batch
    warm."""
    for level in range(1, len(LADDER) + 1):
        svc = SummarizeService(RunConfig(
            backend=backend, max_batch=max_batch,
            ladder=LADDER, ladder_force=level,
        ))
        for b in batch_buckets(max_batch):
            svc.run(queries[:b])


def run_faults_once(queries, backend: str, max_batch: int,
                    seed: int = 0) -> dict:
    """One closed-loop sync run under a seeded FaultPlan: every chunk
    attempt may draw an exec error / latency spike / malformed result, and
    the retry → failover → isolation path must serve every query anyway.
    ``wall_s`` is seconds/query *including* recovery overhead.

    Failover is pinned to the *other* backend (the default
    ``failover_backend="oracle"`` is a no-op when oracle IS the primary):
    with a real failover stage in play, reaching per-query isolation —
    where a single faulted attempt fails a query for good — takes six
    consecutive faulted attempts, which the seeded rates make
    vanishingly rare."""
    cfg = RunConfig(
        backend=backend, max_batch=max_batch,
        failover_backend="oracle" if backend != "oracle" else "pallas",
    )
    # Warm every signature recovery can reach: primary and failover
    # backends at every bucket (isolation serves B=1 chunks), so the gated
    # wall time measures recovery, not compiles.
    for be in dict.fromkeys((backend, cfg.failover_backend)):
        if be is None:
            continue
        warm = SummarizeService(RunConfig(backend=be, max_batch=max_batch))
        for b in batch_buckets(max_batch):
            warm.run(queries[:b])
    plan = FaultPlan.seeded(
        seed, n_attempts=max(256, 8 * len(queries)),
        latency_s=0.02, **FAULT_RATES,
    )
    svc = SummarizeService(cfg, faults=plan)
    t0 = time.perf_counter()
    tickets = [svc.submit(q) for q in queries]
    svc.drain()
    wall = time.perf_counter() - t0
    served = [
        t.result(timeout=0) for t in tickets
        if t.exception(timeout=0) is None
    ]
    lat = [r.queue_delay_s + r.exec_s for r in served]
    st = svc.stats()
    injected: dict[str, int] = {}
    for ev in plan.log:
        injected[ev.fault.kind] = injected.get(ev.fault.kind, 0) + 1
    return {
        "wall_s": wall / len(queries),
        "completion_rate": len(served) / len(queries),
        "p50_s": _pctl(lat, 50) if lat else float("nan"),
        "p99_s": _pctl(lat, 99) if lat else float("nan"),
        "failed": st["failed"],
        "retries": st["retries"],
        "failovers": st["failovers"],
        "isolated_queries": st["isolated_queries"],
        "faults_injected": injected,
    }


def run_faults(num: int = 32, n: int = 1024, n_features: int = 512,
               k: int = K, max_batch: int = 8,
               backends=("oracle", "pallas"), seed: int = 0) -> dict:
    """The fault-injection grid: one seeded chaos run per backend."""
    queries = make_queries(num, n, n_features, k, seed)
    rows = []
    for backend in backends:
        r = run_faults_once(queries, backend, max_batch, seed)
        rows.append({
            "mode": "faults", "backend": backend, "n": n, "k": k,
            "B": max_batch, "num_queries": num, "fault_seed": seed,
            "fault_rates": dict(FAULT_RATES),
            "bench_key": f"serve/faults-{backend}-n{n}-B{max_batch}-k{k}",
            **r,
        })
        print(
            f"serve fault [{backend}] n={n} B={max_batch}: "
            f"completion {r['completion_rate']:.2f}  "
            f"p99 {r['p99_s']*1e3:6.1f}ms  "
            f"(injected {r['faults_injected']}, retries {r['retries']}, "
            f"failovers {r['failovers']}, "
            f"isolated {r['isolated_queries']})", flush=True)
    save("serve_bench_faults", rows)
    return {"rows": rows}


def run_poisson_once(queries, backend: str, max_batch: int, load: float,
                     policy: str, exec_full: float, seed: int = 0) -> dict:
    """One open-loop run: Poisson arrivals at ``load`` x saturation against
    the async scheduler under ``policy`` (same seeded arrival trace for
    every policy, so the comparison is paired)."""
    saturation_qps = max_batch / exec_full
    qps = load * saturation_qps
    if policy == "deadline":
        cfg = RunConfig(
            backend=backend, max_batch=max_batch, scheduler="async",
            max_wait_s=0.5 * exec_full,
        )
        deadline_s = 3.0 * exec_full
    elif policy == "deadline_ladder":
        # The deadline policy plus the degradation ladder: same trace,
        # same SLO — but when a lane's EWMA predicts a miss the chunk
        # runs with bumped c / halved r instead of missing.
        cfg = RunConfig(
            backend=backend, max_batch=max_batch, scheduler="async",
            max_wait_s=0.5 * exec_full, ladder=LADDER,
        )
        deadline_s = 3.0 * exec_full
    elif policy == "flush_on_full":
        # The pre-PR-7 behavior as a policy: a lane fires only when full
        # (1e9 s ~ never for max_wait), leftovers fire on the final drain.
        cfg = RunConfig(
            backend=backend, max_batch=max_batch, scheduler="async",
            max_wait_s=1e9,
        )
        deadline_s = None
    else:
        raise ValueError(policy)
    gaps = np.random.default_rng(seed).exponential(1.0 / qps, len(queries))
    with SummarizeService(cfg) as svc:
        tickets = []
        for q, gap in zip(queries, gaps):
            time.sleep(gap)
            tickets.append(
                svc.submit(dataclasses.replace(q, deadline_s=deadline_s))
            )
        svc.drain()
        responses = [t.result(timeout=0) for t in tickets]
        st = svc.stats()
    lat = [r.queue_delay_s + r.exec_s for r in responses]
    return {
        "wall_s": float(np.mean(lat)),     # mean latency/query (gated key)
        "p50_s": _pctl(lat, 50),
        "p99_s": _pctl(lat, 99),
        "qps_offered": qps,
        "saturation_qps": saturation_qps,
        "batches": st["batches"],
        "triggers": st["triggers"],
        "deadlines_missed": st["deadlines_missed"],
        "degraded": st["degraded"],
    }


def run_poisson(num: int = 32, n: int = 1024, n_features: int = 512,
                k: int = K, max_batch: int = 8,
                backends=("oracle", "pallas"), loads=(0.5, 0.8),
                seed: int = 0,
                policies=("flush_on_full", "deadline",
                          "deadline_ladder")) -> dict:
    """The latency-vs-load grid: {backend} x {load} x {policy} rows."""
    queries = make_queries(num, n, n_features, k, seed)
    rows = []
    for backend in backends:
        exec_full = _measure_exec_full(queries, backend, max_batch)
        if "deadline_ladder" in policies:
            _warm_ladder_levels(queries, backend, max_batch)
        for load in loads:
            by_policy = {}
            row_of = {}
            for policy in policies:
                r = run_poisson_once(
                    queries, backend, max_batch, load, policy, exec_full,
                    seed,
                )
                by_policy[policy] = r
                tag = f"load{int(load * 100)}"
                row = {
                    "mode": "poisson", "policy": policy, "load": load,
                    "backend": backend, "n": n, "k": k, "B": max_batch,
                    "num_queries": num,
                    "bench_key": (
                        f"serve/poisson-{policy}-{tag}-{backend}"
                        f"-n{n}-B{max_batch}-k{k}"
                    ),
                    **r,
                }
                rows.append(row)
                row_of[policy] = row
            if {"deadline", "flush_on_full"} <= by_policy.keys():
                d, f = by_policy["deadline"], by_policy["flush_on_full"]
                row_of["deadline"]["p99_vs_flush_on_full"] = (
                    d["p99_s"] / f["p99_s"]
                )
            if {"deadline_ladder", "deadline"} <= by_policy.keys():
                # The miss-rate comparison the soft gate reads: the ladder
                # run must not miss more than plain deadline on this trace.
                row_of["deadline_ladder"]["deadline_policy_missed"] = (
                    by_policy["deadline"]["deadlines_missed"]
                )
            for policy, r in by_policy.items():
                print(
                    f"serve poisson [{backend}] load={load:.1f} "
                    f"{policy:>15}: p50 {r['p50_s']*1e3:6.1f}ms  "
                    f"p99 {r['p99_s']*1e3:6.1f}ms  "
                    f"({r['qps_offered']:.1f} qps offered, "
                    f"{r['batches']} batches, "
                    f"missed {r['deadlines_missed']}, "
                    f"degraded {r['degraded']}, "
                    f"triggers {r['triggers']})", flush=True)
    save("serve_bench_poisson", rows)
    return {"rows": rows}


def run(num: int = 16, n: int = 1024, n_features: int = 512, k: int = K,
        max_batch: int = 8, backends=("oracle", "pallas"),
        seed: int = 0) -> dict:
    queries = make_queries(num, n, n_features, k, seed)
    rows = []
    seq_qps: dict[str, float] = {}
    for backend in backends:
        r = run_sequential(queries, backend)
        seq_qps[backend] = r["qps"]
        rows.append({
            "mode": "sequential", "backend": backend, "n": n, "k": k,
            "num_queries": num,
            "bench_key": f"serve/seq-{backend}-n{n}-k{k}", **r,
        })
        print(f"serve seq   [{backend}] n={n} k={k}: "
              f"{r['qps']:6.1f} qps  p50 {r['p50_s']*1e3:6.1f}ms  "
              f"p99 {r['p99_s']*1e3:6.1f}ms", flush=True)
    for backend in backends:
        r = run_batched(queries, backend, max_batch)
        r["speedup_vs_seq_same_backend"] = r["qps"] / seq_qps[backend]
        if "oracle" in seq_qps:
            r["speedup_vs_seq_oracle"] = r["qps"] / seq_qps["oracle"]
        rows.append({
            "mode": "batched", "backend": backend, "n": n, "k": k,
            "B": max_batch, "num_queries": num,
            "bench_key": f"serve/batch-{backend}-n{n}-B{max_batch}-k{k}",
            **r,
        })
        print(f"serve batch [{backend}] n={n} B={max_batch}: "
              f"{r['qps']:6.1f} qps  p50 {r['p50_s']*1e3:6.1f}ms  "
              f"p99 {r['p99_s']*1e3:6.1f}ms  "
              f"x{r['speedup_vs_seq_same_backend']:.2f} vs own seq"
              + (f"  x{r['speedup_vs_seq_oracle']:.2f} vs oracle seq"
                 if "speedup_vs_seq_oracle" in r else ""),
              flush=True)
    save("serve_bench", rows)
    return {"rows": rows}


OBS_OVERHEAD_MAX = 1.1


def run_obs_overhead(num: int, n: int, n_features: int, k: int,
                     max_batch: int, backends) -> dict:
    """The observability overhead gate: the same seq+batched grid, traced
    vs untraced, in one process.  A first untraced pass warms every jit
    signature so both measured passes see identical cache state; the gate
    is ``wall(traced) <= OBS_OVERHEAD_MAX x wall(untraced)``
    (docs/observability.md "Overhead contract")."""
    was_enabled = obs.trace_enabled()
    obs.configure(trace=False)
    run(num=num, n=n, n_features=n_features, k=k,
        max_batch=max_batch, backends=backends)          # warm everything
    try:
        obs.configure(trace=True)
        obs.get_tracer().clear()
        t0 = time.perf_counter()
        run(num=num, n=n, n_features=n_features, k=k,
            max_batch=max_batch, backends=backends)
        wall_on = time.perf_counter() - t0
        n_spans = len(obs.get_tracer().export())
        obs.configure(trace=False)
        t0 = time.perf_counter()
        run(num=num, n=n, n_features=n_features, k=k,
            max_batch=max_batch, backends=backends)
        wall_off = time.perf_counter() - t0
    finally:
        obs.configure(trace=was_enabled)
    ratio = wall_on / wall_off
    row = {
        "mode": "obs_overhead", "n": n, "k": k, "B": max_batch,
        "num_queries": num, "backends": list(backends),
        "bench_key": f"serve/obs-overhead-n{n}-B{max_batch}-k{k}",
        "wall_on_s": wall_on, "wall_off_s": wall_off,
        "overhead_ratio": ratio, "spans_recorded": n_spans,
        "max_ratio": OBS_OVERHEAD_MAX,
    }
    print(
        f"serve obs-overhead: traced {wall_on:.2f}s vs untraced "
        f"{wall_off:.2f}s -> x{ratio:.3f} "
        f"(gate {OBS_OVERHEAD_MAX}x, {n_spans} spans)", flush=True)
    save("serve_bench_obs", [row])
    return {"rows": [row]}


def write_trace_artifact(path: str) -> None:
    """Dump the process-wide observability state (spans + bus events +
    metrics) as one JSON artifact — the trace upload the CI obs job
    attaches to each run."""
    tr = obs.get_tracer()
    bus = obs.get_bus()
    artifact = {
        "spans": tr.export(),
        "spans_dropped": tr.dropped,
        "events": bus.export(),
        "events_dropped": bus.dropped,
        "metrics": obs.get_registry().to_json(),
    }
    with open(path, "w") as f:
        json.dump(artifact, f, indent=1)
    print(
        f"wrote trace artifact to {path} ({len(artifact['spans'])} spans, "
        f"{len(artifact['events'])} events)", flush=True)


def main() -> int:
    from benchmarks.kernel_bench import check_regression

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate shape: n=1024, B=8, 16 queries")
    ap.add_argument("--num", type=int, default=32)
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--features", type=int, default=512)
    ap.add_argument("--k", type=int, default=K)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--backends", nargs="+", default=["oracle", "pallas"])
    ap.add_argument("--poisson", action="store_true",
                    help="also run the open-loop Poisson latency-vs-load "
                    "grid through the async flusher (deadline vs "
                    "flush-on-full vs deadline+degradation-ladder "
                    "policies)")
    ap.add_argument("--faults", action="store_true",
                    help="also run the seeded fault-injection grid: exec "
                    "errors + latency spikes + malformed results against "
                    "the retry/failover/isolation recovery path "
                    "(completion rate hard-gated at 1.0)")
    ap.add_argument("--loads", nargs="+", type=float, default=[0.5, 0.8],
                    help="offered-load fractions of measured saturation")
    ap.add_argument("--obs-overhead", action="store_true",
                    help="also run the tracing-overhead gate: the same grid "
                    "traced vs untraced (warm caches shared); fails if the "
                    f"traced wall exceeds {OBS_OVERHEAD_MAX}x untraced")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the observability state (spans + bus events "
                    "+ metrics JSON) as one artifact after the run")
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="committed baseline JSON (BENCH_serve.json) to gate "
                    "per-query wall times against")
    ap.add_argument("--max-ratio", type=float, default=2.0)
    ap.add_argument("--abs-floor", type=float, default=0.05,
                    help="seconds/query over baseline a key must also "
                    "regress by (service timings ride host wall clocks)")
    args = ap.parse_args()
    if args.smoke:
        args.num, args.n, args.batch = 16, 1024, 8

    rows = run(num=args.num, n=args.n, n_features=args.features, k=args.k,
               max_batch=args.batch, backends=tuple(args.backends))["rows"]
    if args.poisson:
        prows = run_poisson(
            num=2 * args.num, n=args.n, n_features=args.features, k=args.k,
            max_batch=args.batch, backends=tuple(args.backends),
            loads=tuple(args.loads),
        )["rows"]
        rows += prows
        worst = max(
            (r for r in prows
             if r["policy"] == "deadline" and r["load"] >= 0.8),
            key=lambda r: r["p99_vs_flush_on_full"], default=None,
        )
        if worst is not None and worst["p99_vs_flush_on_full"] >= 1.0:
            print(
                "poisson-gate: deadline-flusher p99 did not beat "
                f"flush-on-full at load {worst['load']} "
                f"({worst['backend']}): ratio "
                f"{worst['p99_vs_flush_on_full']:.2f}", file=sys.stderr)
            return 1
        for r in prows:
            # Soft gate (warn-only — miss counts ride runner noise; the
            # hard ladder acceptance pin is in tests/test_serve_faults.py):
            # at high load the ladder policy must not miss MORE deadlines
            # than plain deadline on the identical trace.
            if (r["policy"] == "deadline_ladder" and r["load"] >= 0.8
                    and r["deadlines_missed"] > r["deadline_policy_missed"]):
                print(
                    "ladder-gate (soft): deadline_ladder missed "
                    f"{r['deadlines_missed']} > deadline's "
                    f"{r['deadline_policy_missed']} at load {r['load']} "
                    f"({r['backend']})", file=sys.stderr)
    if args.faults:
        frows = run_faults(
            num=args.num, n=args.n, n_features=args.features, k=args.k,
            max_batch=args.batch, backends=tuple(args.backends),
        )["rows"]
        rows += frows
        lost = [r for r in frows if r["completion_rate"] < 1.0]
        if lost:
            # Fault schedules are seeded and chunk execution is serial, so
            # a lost ticket is a recovery-path bug, not runner noise.
            for r in lost:
                print(
                    "fault-gate: recovery lost queries under the seeded "
                    f"FaultPlan ({r['backend']}): completion rate "
                    f"{r['completion_rate']:.2f}, {r['failed']} failed",
                    file=sys.stderr)
            return 1
    obs_failed = False
    if args.obs_overhead:
        orows = run_obs_overhead(
            num=args.num, n=args.n, n_features=args.features, k=args.k,
            max_batch=args.batch, backends=tuple(args.backends),
        )["rows"]
        rows += orows
        for r in orows:
            if r["overhead_ratio"] > OBS_OVERHEAD_MAX:
                print(
                    "obs-overhead-gate: tracing-enabled wall is "
                    f"x{r['overhead_ratio']:.3f} the disabled wall "
                    f"(gate {OBS_OVERHEAD_MAX}x)", file=sys.stderr)
                obs_failed = True
    if args.trace_out:
        write_trace_artifact(args.trace_out)
    if obs_failed:
        return 1
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows}, f, indent=1)
        print(f"wrote {len(rows)} rows to {args.json}", flush=True)
    if args.baseline:
        # A run gates only the baseline slices it actually measured.
        skip = []
        if not args.poisson:
            skip.append("serve/poisson-")
        if not args.faults:
            skip.append("serve/faults-")
        key_ok = (
            (lambda key: not any(key.startswith(p) for p in skip))
            if skip else None
        )
        bad, unmeasured = check_regression(rows, args.baseline,
                                           args.max_ratio, args.abs_floor,
                                           key_ok=key_ok)
        if bad or unmeasured:
            print(f"regression-gate: {bad} serve row(s) regressed "
                  f">{args.max_ratio}x and {unmeasured} baseline key(s) "
                  f"unmeasured vs {args.baseline}", file=sys.stderr)
            return 1
        print(f"regression-gate: all serve rows within {args.max_ratio}x "
              "of baseline", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
