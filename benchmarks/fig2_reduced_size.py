"""Paper Figure 2: relative utility f(S)/f(S_greedy) and SS time vs the size
of the reduced set |V'| (drive by sweeping r in [2, 20] step 2)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import save, timed
from repro.core import FeatureCoverage, greedy
from repro.core.sparsify import ss_sparsify
from repro.data import news_day

K = 10


def run(n=4096, n_features=512, seed=0, rs=tuple(range(2, 21, 2))) -> dict:
    W = jnp.asarray(news_day(seed, n, n_features))
    fn = FeatureCoverage(W=W, phi="sqrt")
    ref = greedy(fn, K)
    fg = float(ref.value)
    key = jax.random.PRNGKey(seed)
    rows = []
    for r in rs:
        def run_ss():
            ss = ss_sparsify(fn, key, r=r, c=8.0)
            res = greedy(fn, K, alive=ss.vprime)
            return jax.block_until_ready((res, ss))

        (res, ss), t = timed(run_ss)
        rows.append({
            "r": int(r),
            "vprime": int(jnp.sum(ss.vprime)),
            "rel_utility": float(res.value) / fg,
            "eps_hat": float(ss.eps_hat),
            "t_ss_s": t,
        })
        print(f"fig2 r={r:2d} |V'|={rows[-1]['vprime']:5d} "
              f"rel={rows[-1]['rel_utility']:.4f} t={t:.2f}s", flush=True)
    save("fig2_reduced_size", rows)
    return {"rows": rows, "f_greedy": fg}


if __name__ == "__main__":
    run()
