"""Benchmark aggregator: one entry per paper table/figure + the beyond-paper
extras.  ``PYTHONPATH=src python -m benchmarks.run [--quick] [--json PATH]``.

``--json PATH`` writes every job's payload to one consolidated JSON — the
kernel jobs' rows carry the ``bench_key``/``wall_s`` fields consumed by the
CI bench-regression gate (``benchmarks.kernel_bench --baseline``).
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes (CI smoke)")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write all job payloads to one consolidated JSON")
    args = ap.parse_args()

    from benchmarks import (data_selection, fig1_scaling, fig2_reduced_size,
                            fig3_news, kernel_bench, table2_video)

    jobs = {
        "fig1": lambda: fig1_scaling.run(
            sizes=(512, 1024, 2048) if args.quick
            else (512, 1024, 2048, 4096, 8192)),
        "fig2": lambda: fig2_reduced_size.run(
            n=1024 if args.quick else 4096,
            rs=tuple(range(2, 13, 4)) if args.quick else tuple(range(2, 21, 2))),
        "fig3": lambda: fig3_news.run(days=4 if args.quick else 16),
        "table2": lambda: table2_video.run(
            scale=0.08 if args.quick else 0.25),
        "kernels": lambda: kernel_bench.run(smoke=args.quick),
        "kernels_fl": lambda: kernel_bench.run_fl(smoke=args.quick),
        "kernels_dispatch": lambda: kernel_bench.run_dispatch(smoke=args.quick),
        "kernels_flash": lambda: kernel_bench.run_flash(smoke=args.quick),
        "data_selection": data_selection.run,
    }
    only = set(args.only.split(",")) if args.only else None
    payloads = {}
    t00 = time.time()
    for name, job in jobs.items():
        if only and name not in only:
            continue
        print(f"\n=== {name} {'='*50}", flush=True)
        t0 = time.time()
        payloads[name] = job()
        print(f"=== {name} done in {time.time()-t0:.1f}s", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"quick": args.quick, "jobs": payloads}, f, indent=1,
                      default=str)
        print(f"\nwrote consolidated payloads to {args.json}")
    print(f"\nall benchmarks done in {time.time()-t00:.1f}s "
          f"(results under results/bench/)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
