"""Pallas kernel microbench: correctness (interpret mode vs jnp oracle) plus
the roofline-derived TPU expectations for the two SS hot-spot kernels.

On this CPU container the kernels cannot be *timed* on real hardware; we
(1) verify interpret-mode output against the oracle on a shape sweep,
(2) verify the unified backend dispatch layer (``repro.core.backend``) —
    oracle vs pallas divergence/gains through the same ``backend=`` routing
    every entry point uses, and
(3) report each kernel's arithmetic intensity and the v5e-roofline time its
BlockSpec tiling implies, next to the measured wall time of the jnp
reference path (the thing the kernel replaces).

``--smoke`` runs a single small shape per kernel — the CI regression gate.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save, timed
from repro.core import FeatureCoverage, get_backend
from repro.kernels.ref import feature_gains_ref, ss_divergence_ref
from repro.kernels.feature_gains import feature_gains_kernel
from repro.kernels.ss_weights import ss_divergence_kernel
from repro.launch.mesh import HW

SS_SHAPES = [(2048, 512, 64), (4096, 1024, 96), (8192, 512, 104)]
SS_SHAPES_SMOKE = [(512, 128, 24)]
FG_SHAPES = [(4096, 512), (16384, 1024)]
FG_SHAPES_SMOKE = [(512, 128)]


def run(seed: int = 0, smoke: bool = False) -> dict:
    key = jax.random.PRNGKey(seed)
    rows = []
    for (n, F, r) in (SS_SHAPES_SMOKE if smoke else SS_SHAPES):
        W = jax.random.uniform(key, (n, F))
        CU = jax.random.uniform(jax.random.fold_in(key, 1), (r, F))
        phi_cu = jnp.sum(jnp.sqrt(CU), axis=-1)
        resid = jax.random.uniform(jax.random.fold_in(key, 2), (r,))

        ref, t_ref = timed(lambda: jax.block_until_ready(
            ss_divergence_ref(W, CU, phi_cu, resid, None, "sqrt")))
        out, t_int = timed(lambda: jax.block_until_ready(
            ss_divergence_kernel(W, CU, phi_cu, resid, None,
                                 phi="sqrt", interpret=True)))
        err = float(jnp.max(jnp.abs(ref - out)))
        assert err < 1e-3, f"kernel/oracle divergence mismatch: {err}"

        # roofline for the kernel's HBM traffic: one read of W + CU + out
        bytes_moved = (n * F + r * F + n) * 4
        flops = 2.0 * r * n * F            # add + sqrt per (probe, cand, feat)
        t_mem = bytes_moved / HW["hbm_bw"]
        t_cmp = flops / HW["peak_flops_bf16"]
        rows.append({
            "kernel": "ss_divergence", "n": n, "F": F, "r": r,
            "max_err": err, "t_jnp_cpu_s": t_ref, "t_interp_s": t_int,
            "tpu_bytes": bytes_moved, "tpu_flops": flops,
            "tpu_roofline_s": max(t_mem, t_cmp),
            "arithmetic_intensity": flops / bytes_moved,
        })
        print(f"kernel ss_divergence n={n} F={F} r={r} err={err:.2e} "
              f"cpu_ref={t_ref*1e3:.1f}ms tpu_bound={max(t_mem, t_cmp)*1e6:.1f}µs",
              flush=True)

    for (n, F) in (FG_SHAPES_SMOKE if smoke else FG_SHAPES):
        W = jax.random.uniform(key, (n, F))
        c = jax.random.uniform(jax.random.fold_in(key, 3), (F,))
        phic = jnp.sum(jnp.sqrt(c))
        ref, t_ref = timed(lambda: jax.block_until_ready(
            feature_gains_ref(W, c, phic, None, "sqrt")))
        out, _ = timed(lambda: jax.block_until_ready(
            feature_gains_kernel(W, c, phic, None, phi="sqrt", interpret=True)))
        err = float(jnp.max(jnp.abs(ref - out)))
        assert err < 1e-3, f"feature_gains kernel mismatch: {err}"
        bytes_moved = (n * F + F + n) * 4
        flops = 2.0 * n * F
        rows.append({
            "kernel": "feature_gains", "n": n, "F": F,
            "max_err": err, "t_jnp_cpu_s": t_ref,
            "tpu_bytes": bytes_moved, "tpu_flops": flops,
            "tpu_roofline_s": max(bytes_moved / HW["hbm_bw"],
                                  flops / HW["peak_flops_bf16"]),
            "arithmetic_intensity": flops / bytes_moved,
        })
        print(f"kernel feature_gains n={n} F={F} err={err:.2e} "
              f"cpu_ref={t_ref*1e3:.1f}ms", flush=True)
    save("kernel_bench", rows)
    return {"rows": rows}


def run_dispatch(seed: int = 0, smoke: bool = False) -> dict:
    """Backend dispatch parity: oracle vs pallas through repro.core.backend —
    the exact routing ss_sparsify/greedy use — on real objectives."""
    n, F, r = (512, 128, 24) if smoke else (2048, 256, 64)
    key = jax.random.PRNGKey(seed)
    W = jax.random.uniform(key, (n, F))
    fn = FeatureCoverage(W=W, phi="sqrt")
    probes = jnp.arange(0, n, max(1, n // r))[:r]
    residual = fn.residual_gains()

    rows = []
    ref, t_o = timed(lambda: jax.block_until_ready(
        get_backend("oracle").divergence(fn, probes, residual=residual)))
    out, t_p = timed(lambda: jax.block_until_ready(
        get_backend("pallas").divergence(fn, probes, residual=residual)))
    live = np.ones((n,), bool)
    live[np.asarray(probes)] = False
    err = float(np.max(np.abs(np.asarray(ref)[live] - np.asarray(out)[live])))
    assert err < 1e-3, f"backend dispatch divergence mismatch: {err}"
    rows.append({"op": "divergence", "n": n, "F": F, "r": r,
                 "max_err": err, "t_oracle_s": t_o, "t_pallas_s": t_p})
    print(f"dispatch divergence n={n} F={F} r={r} err={err:.2e}", flush=True)

    state = fn.add_many(fn.empty_state(), jnp.arange(n) < 8)
    ref, t_o = timed(lambda: jax.block_until_ready(
        get_backend("oracle").gains(fn, state)))
    out, t_p = timed(lambda: jax.block_until_ready(
        get_backend("pallas").gains(fn, state)))
    err = float(jnp.max(jnp.abs(ref - out)))
    assert err < 1e-3, f"backend dispatch gains mismatch: {err}"
    rows.append({"op": "gains", "n": n, "F": F,
                 "max_err": err, "t_oracle_s": t_o, "t_pallas_s": t_p})
    print(f"dispatch gains n={n} F={F} err={err:.2e}", flush=True)
    save("kernel_dispatch", rows)
    return {"rows": rows}


def run_flash(seed: int = 0, smoke: bool = False) -> dict:
    """flash_attention kernel: correctness + v5e roofline of its tiling vs
    the XLA blockwise path's HBM-resident intermediates."""
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_attention_ref

    rows = []
    shapes = [(4, 256, 64)] if smoke else [(8, 512, 128), (4, 1024, 128)]
    for (BH, S, hd) in shapes:
        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (BH, S, hd), jnp.float32)
        k = jax.random.normal(ks[1], (BH, S, hd), jnp.float32)
        v = jax.random.normal(ks[2], (BH, S, hd), jnp.float32)
        ref, t_ref = timed(lambda: jax.block_until_ready(
            flash_attention_ref(q, k, v)))
        out, _ = timed(lambda: jax.block_until_ready(
            flash_attention(q, k, v, bq=256, bk=256, interpret=True)))
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-2, f"flash_attention kernel mismatch: {err}"
        # kernel HBM traffic: q+k+v read + out write (causal ~half the flops)
        io_bytes = 4 * BH * S * hd * 4
        flops = 2 * 2 * BH * S * S * hd / 2
        # XLA path additionally round-trips every (bq, bk) f32 score tile +
        # softmax temps: >= 3 extra writes/reads of S*S scores per head
        xla_extra = 3 * BH * S * S * 4
        rows.append({
            "kernel": "flash_attention", "BH": BH, "S": S, "hd": hd,
            "max_err": err, "t_jnp_cpu_s": t_ref,
            "tpu_bytes_kernel": io_bytes,
            "tpu_bytes_xla_path": io_bytes + xla_extra,
            "hbm_traffic_reduction": (io_bytes + xla_extra) / io_bytes,
            "tpu_roofline_s": max(io_bytes / HW["hbm_bw"],
                                  flops / HW["peak_flops_bf16"]),
        })
        print(f"kernel flash_attention BH={BH} S={S} hd={hd} err={err:.2e} "
              f"hbm_reduction={rows[-1]['hbm_traffic_reduction']:.1f}x",
              flush=True)
    save("kernel_flash", rows)
    return {"rows": rows}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one small shape per kernel (CI regression gate)")
    args = ap.parse_args()
    run(smoke=args.smoke)
    run_dispatch(smoke=args.smoke)
    run_flash(smoke=args.smoke)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
