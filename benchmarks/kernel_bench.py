"""Pallas kernel microbench: correctness (interpret mode vs jnp oracle) plus
the roofline-derived TPU expectations for the SS hot-spot kernels.

On this CPU container the kernels cannot be *timed* on real hardware; we
(1) verify interpret-mode output against the oracle on a shape sweep — the
    feature-coverage divergence/gains kernels (with and without ``feat_w``
    feature weights) and the facility-location divergence kernel,
(2) verify the unified backend dispatch layer (``repro.core.backend``) —
    oracle vs pallas divergence/gains through the same ``backend=`` routing
    every entry point uses, on both objective families, and
(3) report each kernel's arithmetic intensity and the v5e-roofline time its
    BlockSpec tiling implies, next to the measured wall time of the jnp
    reference path (the thing the kernel replaces).

``--smoke`` runs a single small shape per kernel — the CI regression gate.
``--json PATH`` writes every row (each carrying a stable ``bench_key`` and a
warm ``wall_s`` wall time) to PATH; ``--baseline PATH`` compares the fresh
rows against a previously committed JSON (``BENCH_kernels.json`` at the repo
root is the CI baseline) and exits nonzero on a >``--max-ratio`` per-kernel
wall-time regression.
"""

from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save, timed
from repro.core import (
    FacilityLocation,
    FeatureCoverage,
    bucket_schedule,
    get_backend,
)
from repro.kernels.feature_gains import feature_gains_kernel
from repro.kernels.fl_divergence import fl_divergence_kernel
from repro.kernels.ref import (
    feature_gains_ref,
    fl_divergence_ref,
    ss_divergence_ref,
)
from repro.kernels.ss_weights import ss_divergence_kernel
from repro.launch.mesh import HW

SS_SHAPES = [(2048, 512, 64), (4096, 1024, 96), (8192, 512, 104)]
SS_SHAPES_SMOKE = [(512, 128, 24)]
FG_SHAPES = [(4096, 512), (16384, 1024)]
FG_SHAPES_SMOKE = [(512, 128)]
# facility location: (n, r) — the sim matrix is (n, n)
FL_SHAPES = [(1024, 64), (1536, 48)]
FL_SHAPES_SMOKE = [(256, 16)]
# matrix-free facility location: (n, d, r) dense-parity shapes plus
# (n, d) streaming-only shapes at n past the dense from_features guard
FLS_SHAPES = [(1024, 16, 64), (1536, 16, 48)]
FLS_SHAPES_SMOKE = [(256, 16, 16)]
FLS_LARGE = [(65536, 16)]
FLS_LARGE_SMOKE = [(32768, 16)]


def _feat_w(F: int) -> jax.Array:
    return jnp.linspace(0.5, 1.5, F)


def run(seed: int = 0, smoke: bool = False) -> dict:
    key = jax.random.PRNGKey(seed)
    rows = []
    for (n, F, r) in (SS_SHAPES_SMOKE if smoke else SS_SHAPES):
        W = jax.random.uniform(key, (n, F))
        CU = jax.random.uniform(jax.random.fold_in(key, 1), (r, F))
        resid = jax.random.uniform(jax.random.fold_in(key, 2), (r,))
        for weighted in (False, True):
            fw = _feat_w(F) if weighted else None
            phis = jnp.sqrt(CU) if fw is None else jnp.sqrt(CU) * fw
            phi_cu = jnp.sum(phis, axis=-1)
            name = "ss_divergence_featw" if weighted else "ss_divergence"

            ref, t_ref = timed(lambda: jax.block_until_ready(
                ss_divergence_ref(W, CU, phi_cu, resid, None, "sqrt", fw)))
            out, t_int = timed(lambda: jax.block_until_ready(
                ss_divergence_kernel(W, CU, phi_cu, resid, None, fw,
                                     phi="sqrt", interpret=True)), repeat=3)
            err = float(jnp.max(jnp.abs(ref - out)))
            assert err < 1e-3, f"kernel/oracle divergence mismatch: {err}"

            # roofline for the kernel's HBM traffic: one read of W + CU + out
            bytes_moved = (n * F + r * F + n) * 4
            flops = 2.0 * r * n * F        # add + sqrt per (probe, cand, feat)
            t_mem = bytes_moved / HW["hbm_bw"]
            t_cmp = flops / HW["peak_flops_bf16"]
            rows.append({
                "kernel": name, "n": n, "F": F, "r": r,
                "bench_key": f"{name}/n{n}-F{F}-r{r}", "wall_s": t_int,
                "max_err": err, "t_jnp_cpu_s": t_ref, "t_interp_s": t_int,
                "tpu_bytes": bytes_moved, "tpu_flops": flops,
                "tpu_roofline_s": max(t_mem, t_cmp),
                "arithmetic_intensity": flops / bytes_moved,
            })
            print(f"kernel {name} n={n} F={F} r={r} err={err:.2e} "
                  f"cpu_ref={t_ref*1e3:.1f}ms "
                  f"tpu_bound={max(t_mem, t_cmp)*1e6:.1f}µs",
                  flush=True)

    for (n, F) in (FG_SHAPES_SMOKE if smoke else FG_SHAPES):
        W = jax.random.uniform(key, (n, F))
        c = jax.random.uniform(jax.random.fold_in(key, 3), (F,))
        for weighted in (False, True):
            fw = _feat_w(F) if weighted else None
            phic = jnp.sum(jnp.sqrt(c) if fw is None else jnp.sqrt(c) * fw)
            name = "feature_gains_featw" if weighted else "feature_gains"
            ref, t_ref = timed(lambda: jax.block_until_ready(
                feature_gains_ref(W, c, phic, None, "sqrt", fw)))
            out, t_int = timed(lambda: jax.block_until_ready(
                feature_gains_kernel(W, c, phic, None, fw,
                                     phi="sqrt", interpret=True)), repeat=3)
            err = float(jnp.max(jnp.abs(ref - out)))
            assert err < 1e-3, f"feature_gains kernel mismatch: {err}"
            bytes_moved = (n * F + F + n) * 4
            flops = 2.0 * n * F
            rows.append({
                "kernel": name, "n": n, "F": F,
                "bench_key": f"{name}/n{n}-F{F}", "wall_s": t_int,
                "max_err": err, "t_jnp_cpu_s": t_ref, "t_interp_s": t_int,
                "tpu_bytes": bytes_moved, "tpu_flops": flops,
                "tpu_roofline_s": max(bytes_moved / HW["hbm_bw"],
                                      flops / HW["peak_flops_bf16"]),
                "arithmetic_intensity": flops / bytes_moved,
            })
            print(f"kernel {name} n={n} F={F} err={err:.2e} "
                  f"cpu_ref={t_ref*1e3:.1f}ms", flush=True)
    save("kernel_bench", rows)
    return {"rows": rows}


def run_fl(seed: int = 0, smoke: bool = False) -> dict:
    """Facility-location divergence kernel: interpret-mode parity vs the jnp
    oracle + the v5e roofline of its (candidates x served rows) tiling."""
    key = jax.random.PRNGKey(seed)
    rows = []
    for (n, r) in (FL_SHAPES_SMOKE if smoke else FL_SHAPES):
        X = jax.random.normal(key, (n, 16))
        fn = FacilityLocation.from_features(X, kernel="cosine")
        probes = jnp.arange(0, n, max(1, n // r))[:r]
        MU = jnp.maximum(fn.sim[:, probes].T, 0.0)               # (r, n)
        resid = fn.residual_gains()[probes]

        ref, t_ref = timed(lambda: jax.block_until_ready(
            fl_divergence_ref(fn.sim, MU, resid)))
        out, t_int = timed(lambda: jax.block_until_ready(
            fl_divergence_kernel(fn.sim, MU, resid, interpret=True)),
            repeat=3)
        err = float(jnp.max(jnp.abs(ref - out)))
        assert err < 1e-3, f"fl_divergence kernel mismatch: {err}"

        # kernel HBM traffic: one read of sim + MU + the (n,) result; the
        # naive path round-trips the (r, n, n) max tensor through HBM.
        bytes_moved = (n * n + r * n + n) * 4
        flops = 2.0 * r * n * n            # compare + accumulate per element
        t_mem = bytes_moved / HW["hbm_bw"]
        t_cmp = flops / HW["peak_flops_bf16"]
        rows.append({
            "kernel": "fl_divergence", "n": n, "r": r,
            "bench_key": f"fl_divergence/n{n}-r{r}", "wall_s": t_int,
            "max_err": err, "t_jnp_cpu_s": t_ref, "t_interp_s": t_int,
            "tpu_bytes": bytes_moved, "tpu_flops": flops,
            "tpu_roofline_s": max(t_mem, t_cmp),
            "arithmetic_intensity": flops / bytes_moved,
            "naive_hbm_bytes": 8.0 * r * n * n,
        })
        print(f"kernel fl_divergence n={n} r={r} err={err:.2e} "
              f"cpu_ref={t_ref*1e3:.1f}ms tpu_bound={max(t_mem, t_cmp)*1e6:.1f}µs",
              flush=True)
    save("kernel_fl", rows)
    return {"rows": rows}


def run_fl_stream(seed: int = 0, smoke: bool = False) -> dict:
    """Matrix-free facility location (kernels/fl_stream.py):

    (1) streaming-vs-dense parity at dense-feasible n — the interpret-mode
        fl_stream kernel (similarity tiles computed on the fly from the
        (n, d) rows) against the dense fl_divergence_ref on the same
        features; wall_s is the interpret-mode kernel time, gated like
        every other kernel row;
    (2) streaming-only large-n rows timing the jitted lax.scan block
        reference at n past the dense ``from_features`` guard (a 4+ GiB
        sim matrix) — the regime the kernel exists for, so there is no
        dense reference; the row pins the oracle streaming path's wall
        time instead."""
    from repro.core import StreamingFacilityLocation
    from repro.data import clustered_embeddings
    from repro.kernels.fl_stream import (
        fl_stream_divergence_kernel,
        fl_stream_divergence_ref,
    )

    key = jax.random.PRNGKey(seed)
    rows = []
    for (n, d, r) in (FLS_SHAPES_SMOKE if smoke else FLS_SHAPES):
        X = jax.random.normal(key, (n, d))
        dense = FacilityLocation.from_features(X, kernel="cosine")
        sfl = StreamingFacilityLocation.from_features(X, kernel="cosine")
        probes = jnp.arange(0, n, max(1, n // r))[:r]
        MU = jnp.maximum(sfl.X @ sfl.X[probes].T, 0.0).T          # (r, n)
        resid = dense.residual_gains()[probes]

        ref, t_ref = timed(lambda: jax.block_until_ready(
            fl_divergence_ref(dense.sim, MU, resid)))
        out, t_int = timed(lambda: jax.block_until_ready(
            fl_stream_divergence_kernel(sfl.X, MU, resid, interpret=True)),
            repeat=3)
        err = float(jnp.max(jnp.abs(ref - out)))
        assert err < 1e-3, f"fl_stream kernel vs dense mismatch: {err}"

        # kernel HBM traffic: the embedding rows + MU + the (n,) result —
        # the (n, n) sim matrix never exists (dense fl_divergence reads it).
        bytes_moved = (2 * n * d + r * n + n) * 4
        flops = 2.0 * n * n * d + 2.0 * r * n * n  # tile matmul + hinge
        t_mem = bytes_moved / HW["hbm_bw"]
        t_cmp = flops / HW["peak_flops_bf16"]
        rows.append({
            "kernel": "fl_stream", "n": n, "d": d, "r": r,
            "bench_key": f"fl_stream/n{n}-d{d}-r{r}", "wall_s": t_int,
            "max_err": err, "t_jnp_dense_cpu_s": t_ref, "t_interp_s": t_int,
            "tpu_bytes": bytes_moved, "tpu_flops": flops,
            "tpu_roofline_s": max(t_mem, t_cmp),
            "arithmetic_intensity": flops / bytes_moved,
            "dense_hbm_bytes": (n * n + r * n + n) * 4.0,
        })
        print(f"kernel fl_stream n={n} d={d} r={r} err={err:.2e} "
              f"dense_ref={t_ref*1e3:.1f}ms "
              f"tpu_bound={max(t_mem, t_cmp)*1e6:.1f}µs", flush=True)

    for (n, d) in (FLS_LARGE_SMOKE if smoke else FLS_LARGE):
        r = 4
        X = jnp.asarray(clustered_embeddings(seed, n, d))
        sfl = StreamingFacilityLocation.from_features(X, kernel="dot")
        probes = jnp.arange(0, n, n // r)[:r]
        MU = jnp.maximum(sfl.X @ sfl.X[probes].T, 0.0).T          # (r, n)
        resid = jnp.zeros((r,), jnp.float32)
        div = jax.jit(fl_stream_divergence_ref)
        out, t_blk = timed(lambda: jax.block_until_ready(
            div(sfl.X, MU, resid)), repeat=2)
        assert out.shape == (n,) and bool(jnp.all(jnp.isfinite(out)))
        rows.append({
            "kernel": "fl_stream_large", "n": n, "d": d, "r": r,
            "bench_key": f"fl_stream_large/n{n}-d{d}", "wall_s": t_blk,
            "t_block_ref_s": t_blk,
            "dense_sim_bytes": 4.0 * n * n,   # what this row never allocates
            "stream_bytes": 4.0 * n * d,
        })
        print(f"kernel fl_stream_large n={n} d={d} block_ref={t_blk:.2f}s "
              f"(dense sim would be {4.0 * n * n / 2**30:.1f} GiB; "
              f"streaming holds {4.0 * n * d / 2**20:.1f} MiB)", flush=True)
    save("kernel_fl_stream", rows)
    return {"rows": rows}


def run_compact(seed: int = 0, smoke: bool = False) -> dict:
    """Shrink-aware compacted divergence + compact selection gains: wall time
    must track the live count (the bucket size), not the ground-set size n.

    For every bucket of the SS shrink schedule, gathers a live set of that
    size and times the compact-candidate kernel path through the backend
    dispatch (``divergence_compact`` for the SS round, ``gains_compact`` for
    the per-step cost of the compact selection engine — greedy and
    stochastic greedy share that primitive), asserting elementwise parity
    against the full-n output.  The ``*-full`` row is the same-process full-n
    reference the compacted ratios are taken against; at c = 8 the round-2+
    buckets (live <= n/sqrt(c)) are the acceptance shapes."""
    key = jax.random.PRNGKey(seed)
    be = get_backend("pallas")
    rows = []

    def bench_objective(fam: str, fn, r: int, extra: dict):
        n = fn.n
        probes = jnp.arange(0, n, max(1, n // r))[:r]
        residual = fn.residual_gains()
        full, t_full = timed(lambda: jax.block_until_ready(
            be.divergence(fn, probes, residual=residual)), repeat=3)
        shape_tag = "-".join(f"{k}{v}" for k, v in extra.items())
        rows.append({
            "kernel": f"{fam}_compact", **extra, "k": n,
            "bench_key": f"{fam}_compact/{shape_tag}-full", "wall_s": t_full,
            "ratio_vs_full": 1.0,
        })
        perm = jax.random.permutation(jax.random.fold_in(key, 11), n)
        live_pool = perm[~jnp.isin(perm, probes)]   # live set excludes probes
        for j, size in enumerate(bucket_schedule(n, 8.0)):
            if size >= n:
                continue
            cand_idx = jnp.sort(live_pool[:size])
            out, t_c = timed(lambda: jax.block_until_ready(
                be.divergence_compact(
                    fn, probes, cand_idx, residual=residual)), repeat=3)
            err = float(jnp.max(jnp.abs(out - full[cand_idx])))
            assert err < 1e-3, f"{fam} compact/full mismatch (k={size}): {err}"
            rows.append({
                "kernel": f"{fam}_compact", **extra, "k": int(size),
                "bench_key": f"{fam}_compact/{shape_tag}-k{size}",
                "wall_s": t_c, "max_err": err, "round_geq": j,
                "t_full_s": t_full, "ratio_vs_full": t_c / t_full,
            })
            print(f"kernel {fam}_compact {shape_tag} k={size} (round>={j}) "
                  f"err={err:.2e} {t_c*1e3:.1f}ms vs full {t_full*1e3:.1f}ms "
                  f"= {t_c / t_full:.2f}x", flush=True)

    def bench_gains(fam: str, fn, extra: dict):
        """Per-step selection cost: ``gains_compact`` vs full ``gains``
        through the backend dispatch — the exact call greedy/stochastic
        greedy issue every step on the compact path."""
        n = fn.n
        state = fn.add_many(fn.empty_state(), jnp.arange(n) < 8)
        full, t_full = timed(lambda: jax.block_until_ready(
            be.gains(fn, state)), repeat=3)
        shape_tag = "-".join(f"{k}{v}" for k, v in extra.items())
        rows.append({
            "kernel": "gains_compact", "objective": fam, **extra, "k": n,
            "bench_key": f"gains_compact/{fam}-{shape_tag}-full",
            "wall_s": t_full, "ratio_vs_full": 1.0,
        })
        perm = jax.random.permutation(jax.random.fold_in(key, 17), n)
        for j, size in enumerate(bucket_schedule(n, 8.0)):
            if size >= n:
                continue
            cand_idx = jnp.sort(perm[:size])
            out, t_c = timed(lambda: jax.block_until_ready(
                be.gains_compact(fn, state, cand_idx)), repeat=3)
            err = float(jnp.max(jnp.abs(out - full[cand_idx])))
            assert err < 1e-3, f"{fam} gains compact/full mismatch (k={size}): {err}"
            rows.append({
                "kernel": "gains_compact", "objective": fam, **extra,
                "k": int(size),
                "bench_key": f"gains_compact/{fam}-{shape_tag}-k{size}",
                "wall_s": t_c, "max_err": err, "round_geq": j,
                "t_full_s": t_full, "ratio_vs_full": t_c / t_full,
            })
            print(f"kernel gains_compact [{fam}] {shape_tag} k={size} "
                  f"err={err:.2e} {t_c*1e3:.1f}ms vs full {t_full*1e3:.1f}ms "
                  f"= {t_c / t_full:.2f}x", flush=True)

    for (n, F, r) in (SS_SHAPES_SMOKE if smoke else SS_SHAPES):
        W = jax.random.uniform(key, (n, F))
        bench_objective("ss_divergence", FeatureCoverage(W=W, phi="sqrt"), r,
                        {"n": n, "F": F, "r": r})
    for (n, r) in (FL_SHAPES_SMOKE if smoke else FL_SHAPES):
        X = jax.random.normal(jax.random.fold_in(key, 5), (n, 16))
        bench_objective("fl_divergence",
                        FacilityLocation.from_features(X, kernel="cosine"), r,
                        {"n": n, "r": r})

    for (n, F) in (FG_SHAPES_SMOKE if smoke else FG_SHAPES):
        W = jax.random.uniform(jax.random.fold_in(key, 19), (n, F))
        bench_gains("fc", FeatureCoverage(W=W, phi="sqrt"), {"n": n, "F": F})
    for (n, _) in (FL_SHAPES_SMOKE if smoke else FL_SHAPES):
        X = jax.random.normal(jax.random.fold_in(key, 23), (n, 16))
        bench_gains("fl", FacilityLocation.from_features(X, kernel="cosine"),
                    {"n": n})

    # feature_gains compact-grid path (greedy's inner loop over a live subset)
    for (n, F) in (FG_SHAPES_SMOKE if smoke else FG_SHAPES[:1]):
        W = jax.random.uniform(key, (n, F))
        c = jax.random.uniform(jax.random.fold_in(key, 3), (F,))
        phic = jnp.sum(jnp.sqrt(c))
        full, t_full = timed(lambda: jax.block_until_ready(
            feature_gains_kernel(W, c, phic, phi="sqrt", interpret=True)),
            repeat=3)
        size = bucket_schedule(n, 8.0)[1] if n > 128 else n
        cand_idx = jnp.sort(
            jax.random.permutation(jax.random.fold_in(key, 13), n)[:size])
        out, t_c = timed(lambda: jax.block_until_ready(
            feature_gains_kernel(W, c, phic, None, None, cand_idx,
                                 phi="sqrt", interpret=True)), repeat=3)
        err = float(jnp.max(jnp.abs(out - full[cand_idx])))
        assert err < 1e-3, f"feature_gains compact mismatch: {err}"
        rows.append({
            "kernel": "feature_gains_compact", "n": n, "F": F, "k": int(size),
            "bench_key": f"feature_gains_compact/n{n}-F{F}-k{size}",
            "wall_s": t_c, "max_err": err, "t_full_s": t_full,
            "ratio_vs_full": t_c / t_full,
        })
        print(f"kernel feature_gains_compact n={n} F={F} k={size} "
              f"err={err:.2e} {t_c / t_full:.2f}x vs full", flush=True)
    save("kernel_compact", rows)
    return {"rows": rows}


def run_dispatch(seed: int = 0, smoke: bool = False) -> dict:
    """Backend dispatch parity: oracle vs pallas through repro.core.backend —
    the exact routing ss_sparsify/greedy use — on real objectives, covering
    every objective family the pallas backend now fuses (plain and feat_w
    feature coverage, facility location)."""
    n, F, r = (512, 128, 24) if smoke else (2048, 256, 64)
    n_fl = 256 if smoke else 1024
    key = jax.random.PRNGKey(seed)
    W = jax.random.uniform(key, (n, F))
    objectives = {
        "fc": FeatureCoverage(W=W, phi="sqrt"),
        "fc_featw": FeatureCoverage(W=W, feat_w=_feat_w(F), phi="sqrt"),
        "fl": FacilityLocation.from_features(
            jax.random.normal(jax.random.fold_in(key, 7), (n_fl, 16)),
            kernel="cosine"),
    }

    rows = []
    for name, fn in objectives.items():
        probes = jnp.arange(0, fn.n, max(1, fn.n // r))[:r]
        residual = fn.residual_gains()
        ref, t_o = timed(lambda: jax.block_until_ready(
            get_backend("oracle").divergence(fn, probes, residual=residual)))
        out, t_p = timed(lambda: jax.block_until_ready(
            get_backend("pallas").divergence(fn, probes, residual=residual)),
            repeat=3)
        live = np.ones((fn.n,), bool)
        live[np.asarray(probes)] = False
        err = float(np.max(np.abs(
            np.asarray(ref)[live] - np.asarray(out)[live])))
        assert err < 1e-3, f"backend dispatch divergence mismatch ({name}): {err}"
        rows.append({"op": "divergence", "objective": name, "n": fn.n, "r": r,
                     "bench_key": f"dispatch_divergence/{name}-n{fn.n}-r{r}",
                     "wall_s": t_p,
                     "max_err": err, "t_oracle_s": t_o, "t_pallas_s": t_p})
        print(f"dispatch divergence [{name}] n={fn.n} r={r} err={err:.2e}",
              flush=True)

        state = fn.add_many(fn.empty_state(), jnp.arange(fn.n) < 8)
        ref, t_o = timed(lambda: jax.block_until_ready(
            get_backend("oracle").gains(fn, state)))
        out, t_p = timed(lambda: jax.block_until_ready(
            get_backend("pallas").gains(fn, state)), repeat=3)
        err = float(jnp.max(jnp.abs(ref - out)))
        assert err < 1e-3, f"backend dispatch gains mismatch ({name}): {err}"
        rows.append({"op": "gains", "objective": name, "n": fn.n,
                     "bench_key": f"dispatch_gains/{name}-n{fn.n}",
                     "wall_s": t_p,
                     "max_err": err, "t_oracle_s": t_o, "t_pallas_s": t_p})
        print(f"dispatch gains [{name}] n={fn.n} err={err:.2e}", flush=True)
    save("kernel_dispatch", rows)
    return {"rows": rows}


def run_flash(seed: int = 0, smoke: bool = False) -> dict:
    """flash_attention kernel: correctness + v5e roofline of its tiling vs
    the XLA blockwise path's HBM-resident intermediates."""
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_attention_ref

    rows = []
    shapes = [(4, 256, 64)] if smoke else [(8, 512, 128), (4, 1024, 128)]
    for (BH, S, hd) in shapes:
        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (BH, S, hd), jnp.float32)
        k = jax.random.normal(ks[1], (BH, S, hd), jnp.float32)
        v = jax.random.normal(ks[2], (BH, S, hd), jnp.float32)
        ref, t_ref = timed(lambda: jax.block_until_ready(
            flash_attention_ref(q, k, v)))
        out, t_int = timed(lambda: jax.block_until_ready(
            flash_attention(q, k, v, bq=256, bk=256, interpret=True)),
            repeat=3)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-2, f"flash_attention kernel mismatch: {err}"
        # kernel HBM traffic: q+k+v read + out write (causal ~half the flops)
        io_bytes = 4 * BH * S * hd * 4
        flops = 2 * 2 * BH * S * S * hd / 2
        # XLA path additionally round-trips every (bq, bk) f32 score tile +
        # softmax temps: >= 3 extra writes/reads of S*S scores per head
        xla_extra = 3 * BH * S * S * 4
        rows.append({
            "kernel": "flash_attention", "BH": BH, "S": S, "hd": hd,
            "bench_key": f"flash_attention/BH{BH}-S{S}-hd{hd}", "wall_s": t_int,
            "max_err": err, "t_jnp_cpu_s": t_ref,
            "tpu_bytes_kernel": io_bytes,
            "tpu_bytes_xla_path": io_bytes + xla_extra,
            "hbm_traffic_reduction": (io_bytes + xla_extra) / io_bytes,
            "tpu_roofline_s": max(io_bytes / HW["hbm_bw"],
                                  flops / HW["peak_flops_bf16"]),
        })
        print(f"kernel flash_attention BH={BH} S={S} hd={hd} err={err:.2e} "
              f"hbm_reduction={rows[-1]['hbm_traffic_reduction']:.1f}x",
              flush=True)
    save("kernel_flash", rows)
    return {"rows": rows}


def run_all(seed: int = 0, smoke: bool = False) -> list[dict]:
    """All kernel benches, flattened to one row list (the --json payload)."""
    rows = []
    rows += run(seed, smoke)["rows"]
    rows += run_fl(seed, smoke)["rows"]
    rows += run_fl_stream(seed, smoke)["rows"]
    rows += run_compact(seed, smoke)["rows"]
    rows += run_dispatch(seed, smoke)["rows"]
    rows += run_flash(seed, smoke)["rows"]
    return rows


def check_regression(
    rows: list[dict], baseline_path: str, max_ratio: float = 2.0,
    abs_floor: float = 0.010, key_ok=None,
) -> tuple[int, int]:
    """Compare fresh ``wall_s`` per ``bench_key`` against a committed baseline
    JSON.  Returns ``(regressed, unmeasured)``: kernels slower than
    ``max_ratio`` x baseline, and baseline keys the fresh run did not measure
    at all (a partial local run, or a kernel/shape that was removed) — kept
    separate so callers can report them honestly rather than as regressions.
    New fresh keys with no baseline are informational — they enter the
    trajectory on the next baseline refresh.

    ``key_ok`` (optional predicate on bench_key) restricts the comparison to
    a slice of the baseline — used by invocations that measure one axis of a
    shared baseline file (e.g. fig1's ``--objective`` split of
    BENCH_e2e.json), so keys belonging to the other axes don't count as
    unmeasured.

    A key fails only when it regresses both *relatively* (> max_ratio) and
    *absolutely* (> abs_floor seconds over baseline): sub-10ms interpret-mode
    timings are dominated by timer/machine noise, while the regressions the
    gate exists for (a fusion silently breaking, an accidental O(r n^2)
    materialization) blow wall time up by far more than the floor."""
    with open(baseline_path) as f:
        base = {row["bench_key"]: row for row in json.load(f)["rows"]
                if key_ok is None or key_ok(row["bench_key"])}
    fresh = {row["bench_key"]: row for row in rows
             if "bench_key" in row
             and (key_ok is None or key_ok(row["bench_key"]))}
    violations = 0
    unmeasured = 0
    for key in sorted(base):
        if key not in fresh:
            print(f"regression-gate: baseline key {key} not measured "
                  f"(partial run, or kernel removed / shapes changed?)",
                  flush=True)
            unmeasured += 1
            continue
        b, fr = base[key]["wall_s"], fresh[key]["wall_s"]
        ratio = fr / b if b > 0 else float("inf")
        bad = ratio > max_ratio and (fr - b) > abs_floor
        flag = "FAIL" if bad else (
            "ok (noise floor)" if ratio > max_ratio else "ok")
        print(f"regression-gate: {key:48s} {b*1e3:8.1f}ms -> {fr*1e3:8.1f}ms "
              f"({ratio:4.2f}x) {flag}", flush=True)
        if bad:
            violations += 1
    for key in sorted(set(fresh) - set(base)):
        print(f"regression-gate: new kernel {key} (no baseline yet)",
              flush=True)
    return violations, unmeasured


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one small shape per kernel (CI regression gate)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write all rows (bench_key + wall_s) to PATH")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="committed baseline JSON to gate wall times against")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail when wall_s exceeds baseline * this ratio")
    ap.add_argument("--abs-floor", type=float, default=0.010,
                    help="seconds over baseline a key must also regress by "
                    "before it can fail (noise floor for sub-10ms timings)")
    args = ap.parse_args()
    rows = run_all(smoke=args.smoke)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"smoke": args.smoke, "rows": rows}, f, indent=1)
        print(f"wrote {len(rows)} rows to {args.json}", flush=True)
    if args.baseline:
        bad, unmeasured = check_regression(rows, args.baseline,
                                           args.max_ratio, args.abs_floor)
        if bad or unmeasured:
            print(f"regression-gate: {bad} kernel(s) regressed "
                  f">{args.max_ratio}x and {unmeasured} baseline key(s) "
                  f"unmeasured vs {args.baseline}", file=sys.stderr)
            return 1
        print("regression-gate: all kernels within "
              f"{args.max_ratio}x of baseline", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
