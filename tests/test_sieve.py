"""Sieve-streaming (core/sieve.py): quality vs greedy, determinism, and
structural invariants — the module's first dedicated test file.

Badanidiyuru et al. guarantee a (1/2 - eps) approximation; on the synthetic
corpora the observed ratios sit comfortably above the theoretical floor, so
the quality pins assert the guarantee (with the paper's T=50 threshold
grid), not the incidental constants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FacilityLocation,
    FeatureCoverage,
    greedy,
    sieve_streaming,
)
from repro.data import news_day


def make_fc(seed=0, n=400, F=128):
    return FeatureCoverage(W=jnp.asarray(news_day(seed, n, F)), phi="sqrt")


def make_fl(seed=1, n=300, d=16):
    X = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    return FacilityLocation.from_features(X, kernel="cosine")


@pytest.mark.parametrize("mk,floor", [(make_fc, 0.6), (make_fl, 0.75)])
def test_sieve_quality_vs_greedy(mk, floor):
    """Sieve achieves its approximation guarantee against greedy on both
    shipped objective families (observed: ~0.69 FeatureCoverage, ~0.86
    FacilityLocation; the floors leave noise margin above the 1/2 bound)."""
    fn = mk()
    k = 8
    g = greedy(fn, k)
    sv = sieve_streaming(fn, k)
    ratio = float(sv.value / g.value)
    assert ratio >= floor, ratio
    assert float(sv.value) <= float(g.value) * (1.0 + 1e-5)  # greedy wins


def test_sieve_deterministic():
    """Identical inputs produce the identical SieveResult — there is no
    randomness in the algorithm (one pass, fixed threshold grid)."""
    fn = make_fc(seed=3, n=200, F=64)
    a = sieve_streaming(fn, 6)
    b = sieve_streaming(fn, 6)
    np.testing.assert_array_equal(np.asarray(a.selected),
                                  np.asarray(b.selected))
    assert float(a.value) == float(b.value)
    assert int(a.best_sieve) == int(b.best_sieve)
    np.testing.assert_array_equal(np.asarray(a.thresholds),
                                  np.asarray(b.thresholds))


def test_sieve_structure_and_value_consistency():
    """Selected indices are valid stream elements (pad = -1), distinct, at
    most k, and the reported value equals f of the selected set."""
    fn = make_fc(seed=5, n=150, F=48)
    k = 7
    sv = sieve_streaming(fn, k)
    sel = np.asarray(sv.selected)
    real = sel[sel >= 0]
    assert len(real) <= k
    assert len(set(real.tolist())) == len(real)
    assert (real < fn.n).all()
    mask = jnp.zeros((fn.n,), bool).at[jnp.asarray(real)].set(True)
    f_sel = float(fn.value(fn.add_many(fn.empty_state(), mask)))
    np.testing.assert_allclose(float(sv.value), f_sel, rtol=1e-4)
    assert sv.thresholds.shape == (50,)        # the paper's "50 trials"


def test_sieve_stream_order_changes_picks_not_validity():
    """A permuted stream is still a valid one-pass run: value stays within
    the guarantee band even though the picks differ."""
    fn = make_fc(seed=7, n=256, F=64)
    k = 8
    g = greedy(fn, k)
    perm = jax.random.permutation(jax.random.PRNGKey(2), fn.n)
    sv = sieve_streaming(fn, k, stream=perm)
    assert float(sv.value / g.value) >= 0.6
    sel = np.asarray(sv.selected)
    assert (sel[sel >= 0] < fn.n).all()


def test_sieve_small_k_and_small_stream():
    fn = make_fc(seed=9, n=40, F=16)
    sv = sieve_streaming(fn, 1)
    # k=1: the best sieve lands within the (1/2 - eps) guarantee of the best
    # singleton, where eps is the log-spaced threshold-grid granularity.
    best = float(jnp.max(fn.singleton_gains()))
    assert float(sv.value) >= 0.45 * best
    sv2 = sieve_streaming(fn, 5, stream=jnp.arange(10))
    sel = np.asarray(sv2.selected)
    assert (sel[sel >= 0] < 10).all()          # only streamed elements
