"""Sieve-streaming (core/sieve.py): quality vs greedy, determinism, and
structural invariants — the module's first dedicated test file.

Badanidiyuru et al. guarantee a (1/2 - eps) approximation; on the synthetic
corpora the observed ratios sit comfortably above the theoretical floor, so
the quality pins assert the guarantee (with the paper's T=50 threshold
grid), not the incidental constants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    STREAM_PHIS,
    FacilityLocation,
    FeatureCoverage,
    greedy,
    sieve_best,
    sieve_extend,
    sieve_init,
    sieve_streaming,
    sieve_update,
    stream_sieve_best,
    stream_sieve_init,
    stream_sieve_update,
    threshold_grid,
)
from repro.data import news_day


def make_fc(seed=0, n=400, F=128):
    return FeatureCoverage(W=jnp.asarray(news_day(seed, n, F)), phi="sqrt")


def make_fl(seed=1, n=300, d=16):
    X = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    return FacilityLocation.from_features(X, kernel="cosine")


@pytest.mark.parametrize("mk,floor", [(make_fc, 0.6), (make_fl, 0.75)])
def test_sieve_quality_vs_greedy(mk, floor):
    """Sieve achieves its approximation guarantee against greedy on both
    shipped objective families (observed: ~0.69 FeatureCoverage, ~0.86
    FacilityLocation; the floors leave noise margin above the 1/2 bound)."""
    fn = mk()
    k = 8
    g = greedy(fn, k)
    sv = sieve_streaming(fn, k)
    ratio = float(sv.value / g.value)
    assert ratio >= floor, ratio
    assert float(sv.value) <= float(g.value) * (1.0 + 1e-5)  # greedy wins


def test_sieve_deterministic():
    """Identical inputs produce the identical SieveResult — there is no
    randomness in the algorithm (one pass, fixed threshold grid)."""
    fn = make_fc(seed=3, n=200, F=64)
    a = sieve_streaming(fn, 6)
    b = sieve_streaming(fn, 6)
    np.testing.assert_array_equal(np.asarray(a.selected),
                                  np.asarray(b.selected))
    assert float(a.value) == float(b.value)
    assert int(a.best_sieve) == int(b.best_sieve)
    np.testing.assert_array_equal(np.asarray(a.thresholds),
                                  np.asarray(b.thresholds))


def test_sieve_structure_and_value_consistency():
    """Selected indices are valid stream elements (pad = -1), distinct, at
    most k, and the reported value equals f of the selected set."""
    fn = make_fc(seed=5, n=150, F=48)
    k = 7
    sv = sieve_streaming(fn, k)
    sel = np.asarray(sv.selected)
    real = sel[sel >= 0]
    assert len(real) <= k
    assert len(set(real.tolist())) == len(real)
    assert (real < fn.n).all()
    mask = jnp.zeros((fn.n,), bool).at[jnp.asarray(real)].set(True)
    f_sel = float(fn.value(fn.add_many(fn.empty_state(), mask)))
    np.testing.assert_allclose(float(sv.value), f_sel, rtol=1e-4)
    assert sv.thresholds.shape == (50,)        # the paper's "50 trials"


def test_sieve_stream_order_changes_picks_not_validity():
    """A permuted stream is still a valid one-pass run: value stays within
    the guarantee band even though the picks differ."""
    fn = make_fc(seed=7, n=256, F=64)
    k = 8
    g = greedy(fn, k)
    perm = jax.random.permutation(jax.random.PRNGKey(2), fn.n)
    sv = sieve_streaming(fn, k, stream=perm)
    assert float(sv.value / g.value) >= 0.6
    sel = np.asarray(sv.selected)
    assert (sel[sel >= 0] < fn.n).all()


def test_sieve_small_k_and_small_stream():
    fn = make_fc(seed=9, n=40, F=16)
    sv = sieve_streaming(fn, 1)
    # k=1: the best sieve lands within the (1/2 - eps) guarantee of the best
    # singleton, where eps is the log-spaced threshold-grid granularity.
    best = float(jnp.max(fn.singleton_gains()))
    assert float(sv.value) >= 0.45 * best
    sv2 = sieve_streaming(fn, 5, stream=jnp.arange(10))
    sel = np.asarray(sv2.selected)
    assert (sel[sel >= 0] < 10).all()          # only streamed elements


# ------------------------------------- promoted geometric threshold set ----

def test_threshold_grid_geometric_covers_window():
    """T = ceil(log(2k)/log(1+eps)) + 1 guesses at ratio (1+eps) span a
    factor >= 2k — the window [m, 2*k*m] the guarantee needs."""
    for k, eps in [(1, 0.2), (8, 0.2), (8, 0.5), (32, 0.1)]:
        g = np.asarray(threshold_grid(k, eps))
        assert g[0] == 1.0
        np.testing.assert_allclose(g[1:] / g[:-1], 1.0 + eps, rtol=1e-5)
        assert g[-1] >= 2.0 * k / (1.0 + eps)  # top guess reaches the window
    with pytest.raises(ValueError, match="eps"):
        threshold_grid(4, eps=-0.1)


@pytest.mark.parametrize("eps", [0.2, 0.5])
@pytest.mark.parametrize("mk", [make_fc, make_fl])
def test_sieve_geometric_guarantee_over_orderings(mk, eps):
    """The promoted (1/2 - eps) guarantee, property-tested over stream
    orderings: every permutation of the stream must clear the bound vs
    greedy (OPT >= greedy, so (1/2 - eps)*greedy is a valid floor)."""
    fn = mk()
    k = 8
    g = float(greedy(fn, k).value)
    for seed in range(5):
        perm = jax.random.permutation(jax.random.PRNGKey(seed), fn.n)
        sv = sieve_streaming(fn, k, stream=perm, eps=eps)
        ratio = float(sv.value) / g
        assert ratio >= 0.5 - eps, (seed, ratio)


def test_sieve_incremental_bit_identical_to_one_shot():
    """sieve_update per element == sieve_extend == one-shot, bitwise, in
    both grid modes — the property the durable session tier leans on."""
    fn = make_fc(seed=11, n=120, F=32)
    k = 6
    for eps in (None, 0.2):
        one = sieve_streaming(fn, k, eps=eps)
        st = sieve_init(fn, k, eps=eps)
        for v in range(fn.n):
            st = sieve_update(fn, st, v)
        inc = sieve_best(st)
        np.testing.assert_array_equal(np.asarray(one.selected),
                                      np.asarray(inc.selected))
        assert float(one.value) == float(inc.value)
        ext = sieve_best(
            sieve_extend(fn, sieve_init(fn, k, eps=eps), jnp.arange(fn.n))
        )
        assert float(ext.value) == float(inc.value)


def test_sieve_geometric_window_slides_and_recycles():
    """Feeding elements with growing singleton value slides the absolute
    guess window up: exponents are strictly increasing over time, stay
    distinct, and the recycled sieves restart empty (counts drop)."""
    fn = make_fc(seed=13, n=100, F=32)
    k = 5
    st = sieve_init(fn, k, eps=0.3)
    # order elements by singleton gain so m keeps growing
    order = np.argsort(np.asarray(fn.singleton_gains()))
    j_prev = None
    for v in order:
        st = sieve_update(fn, st, int(v))
        j = np.asarray(st.jidx)
        assert len(set(j.tolist())) == len(j)      # guesses stay distinct
        if j_prev is not None:
            assert (j >= j_prev.min()).all()
            assert j.min() >= j_prev.min()         # window never slides down
        j_prev = j
    assert j_prev.min() > 0                        # it actually slid


# --------------------------------------------------- row-streaming sieve ----

def _stream_rows(seed, n=80, F=24, drift=8.0):
    r = np.random.default_rng(seed)
    scale = 1.0 + drift * np.arange(n, dtype=np.float32) / n
    return (r.random((n, F)).astype(np.float32) * scale[:, None])


@pytest.mark.parametrize("phi", STREAM_PHIS)
def test_stream_sieve_matches_index_sieve(phi):
    """The row-streaming sieve is the same algorithm with coverage-vector
    state: identical accepted positions, values equal to reduction
    numerics, on every supported phi."""
    W = _stream_rows(3)
    fn = FeatureCoverage(W=jnp.asarray(W), phi=phi)
    k, eps = 5, 0.3
    st_i = sieve_init(fn, k, eps=eps)
    st_r = stream_sieve_init(k, W.shape[1], eps=eps)
    for t in range(W.shape[0]):
        st_i = sieve_update(fn, st_i, t)
        st_r, _ = stream_sieve_update(st_r, jnp.asarray(W[t]), phi=phi)
    a, b = sieve_best(st_i), stream_sieve_best(st_r)
    np.testing.assert_array_equal(np.asarray(a.selected),
                                  np.asarray(b.selected))
    np.testing.assert_allclose(float(a.value), float(b.value), rtol=1e-5)
    with pytest.raises(ValueError, match="phi"):
        stream_sieve_update(st_r, jnp.asarray(W[0]), phi="satcov")


def test_stream_sieve_guarantee_and_constant_memory():
    """Row-streaming guarantee vs greedy over the materialized stream, and
    the state never grows with the stream (same shapes throughout)."""
    W = _stream_rows(5, n=120)
    k, eps = 6, 0.5
    st = stream_sieve_init(k, W.shape[1], eps=eps)
    shapes0 = [x.shape for x in jax.tree.leaves(st)]
    accepted = 0
    for t in range(W.shape[0]):
        st, took = stream_sieve_update(st, jnp.asarray(W[t]))
        accepted += int(took)
    assert [x.shape for x in jax.tree.leaves(st)] == shapes0
    assert 0 < accepted < W.shape[0]       # selective, not degenerate
    fn = FeatureCoverage(W=jnp.asarray(W), phi="sqrt")
    g = float(greedy(fn, k).value)
    assert float(stream_sieve_best(st).value) >= (0.5 - eps) * g
    assert int(st.t) == W.shape[0]
