"""Observability contract tests (docs/observability.md).

The pins, in order of importance:

1. **Purity** — telemetry is a pure observer: `vprime` / `eps_hat` /
   `selected` / `gains` are bit-identical with tracing on and off, on
   oracle AND pallas, and under an outer `jit` the hooks vanish entirely.
2. **Fidelity** — what telemetry reports matches what the computation did:
   per-round SS records agree with `alive_trace`, the greedy gain
   trajectory with `GreedyResult.gains`, the obs histograms with the
   service's own `stats()` aggregates.
3. **One bus** — a seeded chaos run's fault draws, recovery steps and
   session audit events land on the unified bus with consistent
   request/session ids under one global ordering.
4. The plumbing itself: bounded ring logs, span trees, exporter formats,
   the pull endpoint, the EWMA helper the flusher estimates ride.
"""

import json
import threading
import time
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api, obs
from repro.core import FeatureCoverage, PallasBackend, greedy, ss_sparsify
from repro.data import news_day
from repro.serve import (
    FaultPlan,
    RunConfig,
    SummarizeRequest,
    SummarizeService,
)
from repro.serve.sessions import SessionConfig, SessionEngine
from repro.serve.summarize_service import ewma_update

BACKENDS = {
    "oracle": lambda: "oracle",
    "pallas": lambda: PallasBackend(interpret=True),
}


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts from empty global sinks and tracing off."""
    obs.reset()
    obs.configure(trace=False)
    yield
    obs.reset()
    obs.configure(trace=False)


def make_fn(n=256, F=64, seed=0):
    return FeatureCoverage(W=jnp.asarray(news_day(seed, n, F)), phi="sqrt")


def make_queries(num, n=256, F=64, k=5, seed=0):
    return [
        SummarizeRequest(
            k=k, key=jax.random.PRNGKey(seed * 1000 + i),
            features=jnp.asarray(news_day(seed * 1000 + i, n, F)),
        )
        for i in range(num)
    ]


# ------------------------------------------------------------- ring log ----

class TestRingLog:
    def test_bounded_with_drop_counter(self):
        log = obs.RingLog(capacity=4)
        for i in range(10):
            log.append(i)
        assert log.list() == [6, 7, 8, 9]
        assert log.dropped == 6
        assert len(log) == 4

    def test_list_compat(self):
        log = obs.RingLog(capacity=8)
        assert not log                      # empty is falsy, like a list
        assert log == []
        for i in range(3):
            log.append(i)
        assert log == [0, 1, 2]             # equality against a plain list
        assert log[1] == 1
        assert log[-1] == 2
        assert list(log) == [0, 1, 2]
        other = obs.RingLog(capacity=5)
        for i in range(3):
            other.append(i)
        assert log == other                 # and against another RingLog
        log.clear()
        assert log == [] and log.dropped == 0

    def test_capacity_validation(self):
        with pytest.raises(ValueError):
            obs.RingLog(capacity=0)


# ------------------------------------------------------------ event bus ----

class TestEventBus:
    def test_ordering_and_filters(self):
        bus = obs.get_bus()
        bus.emit("fault", subsystem="faults", request_ids=(1, 2), kind_x=1)
        bus.emit("recovery", subsystem="service", request_ids=(2,))
        bus.emit("rehydrate", subsystem="sessions", session_id="u1", n=3)
        seqs = [e.seq for e in bus.events()]
        assert seqs == sorted(seqs) and len(seqs) == 3
        assert [e.kind for e in bus.events(subsystem="service")] == [
            "recovery"
        ]
        assert [e.kind for e in bus.events(request_id=2)] == [
            "fault", "recovery"
        ]
        assert bus.events(session_id="u1")[0].data == {"n": 3}

    def test_export_is_json_serializable(self):
        bus = obs.get_bus()
        bus.emit("fault", subsystem="faults", request_ids=(7,), detail="x")
        dump = json.loads(json.dumps(bus.export()))
        assert dump[0]["request_ids"] == [7]
        assert dump[0]["subsystem"] == "faults"


# --------------------------------------------------------------- tracer ----

class TestTracer:
    def test_disabled_spans_are_noops(self):
        assert not obs.trace_enabled()
        with obs.span("anything", x=1) as sp:
            sp.set(y=2)
        assert obs.get_tracer().spans() == []

    def test_span_tree_parenting(self):
        obs.configure(trace=True)
        tr = obs.get_tracer()
        with tr.span("outer", trace_id="req-0") as outer:
            with tr.span("inner") as inner:
                pass
        spans = tr.spans(trace_id="req-0")
        assert [s.name for s in spans] == ["inner", "outer"]
        assert inner.parent_id == outer.span_id
        assert inner.trace_id == "req-0"    # inherited from the parent
        assert outer.t0 <= inner.t0 and inner.t1 <= outer.t1

    def test_error_status_and_retroactive_record(self):
        obs.configure(trace=True)
        tr = obs.get_tracer()
        with pytest.raises(RuntimeError):
            with tr.span("boom"):
                raise RuntimeError("x")
        assert tr.spans(name="boom")[0].status == "error"
        t0 = time.perf_counter() - 0.5
        tr.record("queue.wait", t0, t0 + 0.25, trace_id="req-3", lane="a")
        (sp,) = tr.spans(name="queue.wait")
        assert abs(sp.wall_s - 0.25) < 1e-9
        assert sp.trace_id == "req-3"

    def test_spans_for_request_includes_shared_and_descendants(self):
        obs.configure(trace=True)
        tr = obs.get_tracer()
        tr.record("request.admit", 0.0, 0.1, trace_id="req-1")
        with tr.span("chunk.exec", trace_id="batch", request_ids=(0, 1)):
            with tr.span("ss.sparsify"):
                pass
        names = {s.name for s in tr.spans_for_request(1)}
        assert names == {"request.admit", "chunk.exec", "ss.sparsify"}
        # request 2 rode no chunk: nothing leaks into its tree
        assert tr.spans_for_request(2) == []

    def test_ring_is_bounded(self):
        obs.configure(trace=True, capacity=8)
        try:
            tr = obs.get_tracer()
            for i in range(20):
                with tr.span(f"s{i}"):
                    pass
            assert len(tr.spans()) == 8
            assert tr.dropped == 12
        finally:
            obs.configure(capacity=obs.trace.DEFAULT_CAPACITY)

    def test_format_trace_renders_tree(self):
        obs.configure(trace=True)
        tr = obs.get_tracer()
        with tr.span("chunk.exec", trace_id="req-0", request_ids=(0,)):
            with tr.span("greedy.select", k=5):
                pass
        txt = obs.format_trace("req-0")
        assert "chunk.exec" in txt and "greedy.select" in txt
        assert "k=5" in txt
        assert obs.trace_summary().startswith("trace req-0")


# -------------------------------------------------------------- metrics ----

class TestMetrics:
    def test_counter_gauge_histogram(self):
        reg = obs.get_registry()
        c = reg.counter("t_total", "a counter", labels=("kind",))
        c.inc(kind="x")
        c.inc(2, kind="x")
        c.inc(kind="y")
        assert c.value(kind="x") == 3 and c.value(kind="y") == 1
        g = reg.gauge("t_gauge", "a gauge")
        g.set(5)
        g.add(-2)
        assert g.value() == 3
        h = reg.histogram("t_seconds", "a histogram")
        for v in (0.01, 0.02, 0.03):
            h.observe(v)
        st = h.stats()
        assert st["count"] == 3
        assert abs(st["sum"] - 0.06) < 1e-12
        assert abs(st["mean"] - 0.02) < 1e-12

    def test_create_or_get_rejects_mismatch(self):
        reg = obs.get_registry()
        reg.counter("t_total", "c", labels=("a",))
        assert reg.counter("t_total", "c", labels=("a",)) is reg.get(
            "t_total"
        )
        with pytest.raises(ValueError):
            reg.gauge("t_total", "now a gauge?")
        with pytest.raises(ValueError):
            reg.counter("t_total", "c", labels=("b",))

    def test_prometheus_exposition(self):
        reg = obs.get_registry()
        reg.counter("t_total", "hits", labels=("kind",)).inc(3, kind="x")
        reg.histogram("t_seconds", "lat").observe(0.5)
        text = reg.to_prometheus()
        assert "# HELP t_total hits" in text
        assert "# TYPE t_total counter" in text
        assert 't_total{kind="x"} 3' in text
        assert "# TYPE t_seconds histogram" in text
        assert 't_seconds_bucket{le="+Inf"} 1' in text
        assert "t_seconds_count 1" in text
        # buckets are cumulative: every le >= 0.5 counts the observation
        lines = [ln for ln in text.splitlines() if "t_seconds_bucket" in ln]
        counts = [int(float(ln.rsplit(" ", 1)[1])) for ln in lines]
        assert counts == sorted(counts)

    def test_json_export(self):
        reg = obs.get_registry()
        reg.counter("t_total", "hits", labels=("kind",)).inc(kind="x")
        reg.histogram("t_seconds", "lat").observe(0.5)
        dump = json.loads(json.dumps(reg.to_json()))
        m = dump["t_total"]
        assert m["kind"] == "counter"
        assert m["series"][0]["labels"] == {"kind": "x"}
        assert m["series"][0]["value"] == 1
        h = dump["t_seconds"]["series"][0]
        assert h["count"] == 1 and h["sum"] == 0.5
        assert len(h["buckets"]) == len(h["bounds"])

    def test_pull_endpoint(self):
        obs.get_registry().counter("t_total", "hits").inc(4)
        try:
            srv = obs.start_metrics_server(port=0)
        except OSError:
            pytest.skip("cannot bind a local port in this sandbox")
        try:
            host, port = srv.server_address[:2]
            text = urllib.request.urlopen(
                f"http://{host}:{port}/metrics", timeout=5
            ).read().decode()
            assert "t_total 4" in text
            blob = urllib.request.urlopen(
                f"http://{host}:{port}/metrics.json", timeout=5
            ).read().decode()
            assert "t_total" in json.loads(blob)
        finally:
            srv.shutdown()


# ------------------------------------------------- purity and fidelity ----

@pytest.mark.parametrize("backend", list(BACKENDS))
class TestTelemetryPurity:
    def test_results_bit_identical_traced_vs_untraced(self, backend):
        be = BACKENDS[backend]()
        fn = make_fn(n=256, F=64)
        key = jax.random.PRNGKey(0)

        ss0 = ss_sparsify(fn, key, r=3, backend=be)
        res0 = greedy(fn, 5, alive=ss0.vprime, backend=be)

        obs.configure(trace=True)
        ss1 = ss_sparsify(fn, key, r=3, backend=be)
        res1 = greedy(fn, 5, alive=ss1.vprime, backend=be)

        assert (np.asarray(ss0.vprime) == np.asarray(ss1.vprime)).all()
        assert np.asarray(ss0.eps_hat) == np.asarray(ss1.eps_hat)
        assert int(ss0.rounds) == int(ss1.rounds)
        assert (
            np.asarray(ss0.alive_trace) == np.asarray(ss1.alive_trace)
        ).all()
        assert (
            np.asarray(res0.selected) == np.asarray(res1.selected)
        ).all()
        assert (np.asarray(res0.gains) == np.asarray(res1.gains)).all()
        assert np.asarray(res0.value) == np.asarray(res1.value)

    def test_span_telemetry_matches_results(self, backend):
        be = BACKENDS[backend]()
        fn = make_fn(n=256, F=64)
        key = jax.random.PRNGKey(1)
        obs.configure(trace=True)
        ss = ss_sparsify(fn, key, r=3, backend=be)
        res = greedy(fn, 5, alive=ss.vprime, backend=be)

        (sp,) = obs.get_tracer().spans(name="ss.sparsify")
        assert sp.attrs["rounds"] == int(ss.rounds)
        assert sp.attrs["eps_hat"] == float(ss.eps_hat)
        assert sp.attrs["vprime_size"] == int(jnp.sum(ss.vprime))
        # per-round records derive from alive_trace: same live counts, and
        # the model-apportioned wall estimates sum to the measured total
        lives = [int(v) for v in np.asarray(ss.alive_trace) if v >= 0]
        detail = sp.attrs["rounds_detail"]
        assert [d["live"] for d in detail] == lives
        assert len(detail) == int(ss.rounds)
        # estimates apportion the measured compute wall exactly, and that
        # wall sits inside the span (which also covers the host readout)
        wall = sp.attrs["wall_s"]
        assert sum(d["wall_est_s"] for d in detail) == pytest.approx(wall)
        assert 0.0 < wall <= sp.wall_s

        (gp,) = obs.get_tracer().spans(name="greedy.select")
        assert gp.attrs["gains"] == [float(g) for g in np.asarray(res.gains)]
        assert gp.attrs["value"] == float(res.value)
        assert gp.attrs["selector"] == "greedy"

        reg = obs.get_registry()
        be_name = "oracle" if backend == "oracle" else "pallas"
        assert reg.get("repro_ss_wall_seconds").stats(
            backend=be_name
        )["count"] == 1
        assert reg.get("repro_ss_rounds_total").value(
            backend=be_name
        ) == int(ss.rounds)

    def test_hooks_vanish_under_jit(self, backend):
        be = BACKENDS[backend]()
        fn = make_fn(n=128, F=32)

        def pipeline(key):
            ss = ss_sparsify(fn, key, r=2, backend=be)
            return greedy(fn, 4, alive=ss.vprime, backend=be).selected

        key = jax.random.PRNGKey(2)
        eager = np.asarray(pipeline(key))
        obs.configure(trace=True)
        obs.get_tracer().clear()
        jitted = np.asarray(jax.jit(pipeline)(key))
        assert (eager == jitted).all()
        # inputs were tracers -> no span, and crucially no host sync inside
        # the compiled region (the call would have raised otherwise)
        assert obs.get_tracer().spans(name="ss.sparsify") == []


# ----------------------------------------------- service-layer metrics ----

class TestServiceTelemetry:
    def test_histograms_agree_with_stats(self):
        queries = make_queries(6, n=128, F=32, k=4)
        svc = SummarizeService(RunConfig(max_batch=4))
        svc.run(queries)
        st = svc.stats()
        reg = obs.get_registry()
        assert reg.get("repro_service_queries_total").value() == st[
            "queries"
        ]
        assert sum(
            reg.get("repro_service_batches_total").value(trigger=t)
            for t in st["triggers"]
        ) == st["batches"]
        ex = reg.get("repro_service_exec_seconds")
        total = sum(s["sum"] for s in ex.snapshot().values())
        assert abs(total - st["exec_s_total"]) < 1e-9
        qd = reg.get("repro_service_queue_delay_seconds")
        counts = sum(s["count"] for s in qd.snapshot().values())
        assert counts == st["queries"]
        slots = reg.get("repro_service_slots_total").value()
        padded = reg.get("repro_service_padded_slots_total").value()
        assert st["padding_waste_frac"] == padded / slots
        admitted = reg.get("repro_service_requests_total").value(
            outcome="admitted"
        )
        assert admitted == st["queries"]

    def test_request_spans_cover_the_request(self):
        obs.configure(trace=True)
        queries = make_queries(3, n=128, F=32, k=4)
        svc = SummarizeService(RunConfig(max_batch=4))
        responses = svc.run(queries)
        for i, resp in enumerate(responses):
            spans = obs.get_tracer().spans_for_request(i)
            names = [s.name for s in spans]
            assert "request.admit" in names
            assert "queue.wait" in names
            assert "chunk.exec" in names
            assert "ss.sparsify_batched" in names
            (wait,) = [s for s in spans if s.name == "queue.wait"]
            # the span IS the timing source (serve_bench reads it): it must
            # agree with the response's own queue-delay bookkeeping
            assert abs(wait.wall_s - resp.queue_delay_s) < 0.05

    def test_api_stats_and_metrics(self):
        queries = make_queries(2, n=128, F=32, k=4)
        svc = SummarizeService(RunConfig(max_batch=2))
        svc.run(queries)
        assert api.stats(svc) == svc.stats()
        text = api.metrics()
        assert isinstance(text, str)
        assert "repro_service_queries_total" in text
        blob = api.metrics(fmt="json")
        assert "repro_service_queries_total" in blob
        with pytest.raises(ValueError):
            api.metrics(fmt="yaml")

    def test_stats_snapshot_is_consistent_under_load(self):
        queries = make_queries(8, n=128, F=32, k=4)
        svc = SummarizeService(
            RunConfig(max_batch=4, scheduler="async", max_wait_s=0.01)
        )
        snaps = []
        stop = threading.Event()

        def hammer():
            while not stop.is_set():
                snaps.append(svc.stats())

        t = threading.Thread(target=hammer)
        t.start()
        try:
            with svc:
                for q in queries:
                    svc.submit(q)
                svc.drain()
        finally:
            stop.set()
            t.join()
        snaps.append(svc.stats())
        seen = 0
        for st in snaps:
            # monotone counters: no snapshot can tear backwards
            assert st["queries"] >= seen
            seen = st["queries"]
            # derived values are computed under the same lock as the
            # counters they divide — a torn snapshot would break this
            if st["queries"]:
                assert (
                    st["queue_delay_s_mean"]
                    <= st["queue_delay_s_max"] + 1e-12
                )
        assert snaps[-1]["queries"] == len(queries)


# ----------------------------------------------------------------- ewma ----

class TestEwma:
    def test_first_sample_initializes(self):
        assert ewma_update(None, 0.25) == 0.25

    def test_converges_to_constant_signal(self):
        est = None
        for _ in range(20):
            est = ewma_update(est, 0.05)
        assert abs(est - 0.05) < 1e-9

    def test_tracks_synthetic_exec_trace(self):
        # a synthetic exec-time trace that settles after a warmup spike —
        # the flusher's estimate must converge to the steady state
        trace = [0.5, 0.4] + [0.1] * 18
        est = None
        for s in trace:
            est = ewma_update(est, s)
        assert abs(est - 0.1) < 1e-3
        # alpha=0.5 halves the error each step: after the spike it takes
        # ~10 steps to shed the 0.4 delta below 1e-3
        assert ewma_update(0.2, 0.1) == pytest.approx(0.15)

    def test_service_estimate_matches_replayed_ewma(self):
        queries = make_queries(8, n=128, F=32, k=4)
        svc = SummarizeService(RunConfig(max_batch=4))
        responses = svc.run(queries)
        # one lane, serial chunks: the chunk execution sequence is the
        # deduplicated exec_s sequence of the in-order responses — replay
        # it through ewma_update and the flusher's estimate must match
        exec_by_chunk: list[float] = []
        for r in responses:
            if not exec_by_chunk or exec_by_chunk[-1] != r.exec_s:
                exec_by_chunk.append(r.exec_s)
        assert len(exec_by_chunk) == svc.stats()["batches"]
        expected = None
        for e in exec_by_chunk:
            expected = ewma_update(expected, e)
        (key,) = [k for k in svc._exec_est if k[1] == 0]
        assert svc._exec_est[key] == pytest.approx(expected)


# ---------------------------------------------------------- unified bus ----

class TestUnifiedBus:
    def test_chaos_run_lands_on_one_bus_with_consistent_ids(self):
        queries = make_queries(8, n=128, F=32, k=4)
        plan = FaultPlan.seeded(
            0, n_attempts=64, p_exec_error=0.3, p_latency=0.2,
            latency_s=0.005,
        )
        svc = SummarizeService(
            RunConfig(max_batch=4, failover_backend="oracle"), faults=plan,
        )
        responses = svc.run(queries)
        assert len(responses) == len(queries)
        bus = obs.get_bus()

        faults = bus.events(kind="fault", subsystem="faults")
        recoveries = bus.events(kind="recovery", subsystem="service")
        assert faults, "the seeded plan injected nothing?"
        assert recoveries, "faults were injected but no recovery ran?"
        # the same draws the legacy FaultPlan.log records are on the bus
        assert len(faults) == len(plan.log)
        for ev, legacy in zip(faults, plan.log):
            assert ev.data["fault_kind"] == legacy.fault.kind
            assert ev.data["attempt"] == legacy.attempt
            assert tuple(legacy.tickets) == ev.request_ids

        # consistent ids: every event's request_ids are real tickets, and
        # every recovery shares its ids with an earlier fault on the bus
        valid = set(range(len(queries)))
        for ev in faults + recoveries:
            assert set(ev.request_ids) <= valid
        for rec in recoveries:
            prior = [
                f for f in faults
                if f.seq < rec.seq and set(f.request_ids)
                & set(rec.request_ids)
            ]
            assert prior, f"recovery {rec.data} with no matching fault"

        # one global ordering across subsystems
        seqs = [e.seq for e in bus.events()]
        assert seqs == sorted(seqs)

        # and the counters tell the same story
        reg = obs.get_registry()
        injected = sum(
            reg.get("repro_faults_injected_total").value(kind=k)
            for k in {e.data["fault_kind"] for e in faults}
        )
        assert injected == len(faults)
        st = svc.stats()
        retr = reg.get("repro_service_retries_total")
        assert sum(retr.snapshot().values()) == st["retries"]

    def test_session_audit_events_join_the_bus(self, tmp_path):
        cfg = SessionConfig(
            k=4, n_features=16, buffer_cap=32, resparsify_every=8,
            max_batch=2, snapshot_every=16,
        )
        rng = np.random.default_rng(0)
        eng = SessionEngine(cfg, str(tmp_path))
        sid = eng.open_session(sid="u001", key=0)
        for _ in range(20):
            eng.append(sid, rng.random(16).astype(np.float32))
        eng.flush()
        del eng
        rec = SessionEngine(cfg, str(tmp_path))
        rec.state(sid)

        bus = obs.get_bus()
        (ev,) = bus.events(kind="rehydrate", subsystem="sessions")
        assert ev.session_id == sid
        assert ev.data["replayed"] >= 0
        # the legacy list-shaped audit surface still carries the same step
        assert any(e["step"] == "rehydrate" for e in rec.events)
        assert rec.stats()["events_dropped"] == 0
        assert obs.get_registry().get(
            "repro_sessions_events_total"
        ).value(step="rehydrate") == 1
        # host-side durability histograms are always on
        wal = obs.get_registry().get("repro_wal_append_seconds")
        assert wal is not None and wal.stats()["count"] >= 20
        snap = obs.get_registry().get("repro_sessions_snapshot_seconds")
        assert snap is not None and snap.stats()["count"] >= 1
        rcv = obs.get_registry().get("repro_sessions_recover_seconds")
        assert rcv is not None and rcv.stats()["count"] == 1
