"""Training substrate tests: optimizers, microbatch equivalence, checkpoint
atomicity + elastic restore, preemption, straggler guard."""

import os
import signal
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.train import (
    Adafactor,
    AdamW,
    Checkpointer,
    PreemptionGuard,
    StragglerGuard,
    TrainConfig,
    lr_schedule,
    make_train_state,
    make_train_step,
    resume_or_init,
    run,
)

CFG = configs.smoke("llama3.2-3b")


def batch_fn(B=4, S=32, seed=1):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0,
                              CFG.vocab_size)
    return {"tokens": toks, "labels": toks}


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    opt = AdamW(weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for i in range(300):
        g = {"w": 2 * params["w"]}          # grad of ||w||^2
        params, state = opt.update(g, state, params, jnp.float32(0.1),
                                   jnp.int32(i))
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_adafactor_factored_state_shapes():
    opt = Adafactor(min_dim_factored=4)
    params = {"big": jnp.zeros((8, 16)), "vec": jnp.zeros((8,))}
    st = opt.init(params)
    assert st["v"]["big"]["vr"].shape == (8,)
    assert st["v"]["big"]["vc"].shape == (16,)
    assert st["v"]["vec"]["v"].shape == (8,)


def test_adafactor_converges():
    opt = Adafactor(min_dim_factored=2)
    params = {"w": jnp.full((4, 8), 3.0)}
    state = opt.init(params)
    for i in range(200):
        g = {"w": 2 * params["w"]}
        params, state = opt.update(g, state, params, jnp.float32(0.05),
                                   jnp.int32(i))
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_lr_schedule_shape():
    tc = TrainConfig(lr=1.0, warmup_steps=10, total_steps=100,
                     min_lr_frac=0.1)
    assert float(lr_schedule(tc, jnp.int32(0))) == 0.0
    assert abs(float(lr_schedule(tc, jnp.int32(10))) - 1.0) < 1e-6
    assert float(lr_schedule(tc, jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)
    assert float(lr_schedule(tc, jnp.int32(55))) < 1.0


# ---------------------------------------------------------------------------
# microbatching
# ---------------------------------------------------------------------------

def test_microbatch_grad_equivalence():
    """µ=1 and µ=4 produce the same updates (same global batch)."""
    key = jax.random.PRNGKey(0)
    batch = batch_fn(B=8)
    states, metrics = [], []
    for mu in (1, 4):
        tc = TrainConfig(optimizer="adamw", lr=1e-3, warmup_steps=1,
                         total_steps=10, num_microbatches=mu)
        st = make_train_state(key, CFG, tc)
        st, m = jax.jit(make_train_step(CFG, tc))(st, batch)
        states.append(st)
        metrics.append(m)
    a, b = states
    for x, y in zip(jax.tree.leaves(a["params"]), jax.tree.leaves(b["params"])):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-4, atol=2e-5)
    assert abs(float(metrics[0]["loss"]) - float(metrics[1]["loss"])) < 1e-3


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_atomicity_and_gc():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        state = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 2))}}
        for s in (1, 2, 3):
            ck.save(s, state)
        assert ck.all_steps() == [2, 3]  # GC kept 2
        # a torn write (no COMMIT) must be invisible
        os.makedirs(os.path.join(d, "step_0000000009"))
        assert ck.latest_step() == 3
        restored, step = ck.restore(jax.eval_shape(lambda: state))
        assert step == 3
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(5))


def test_checkpoint_dtype_and_shape_guards():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(1, {"w": jnp.ones((3,), jnp.float32)})
        with pytest.raises(ValueError):
            ck.restore(jax.eval_shape(lambda: {"w": jnp.ones((4,))}))
        with pytest.raises(KeyError):
            ck.restore(jax.eval_shape(lambda: {"w2": jnp.ones((3,))}))


def test_elastic_restore_across_mesh_shapes():
    """Save on a 1-device 'mesh', restore sharded onto a 2x1... any mesh with
    the same axis names (here: degenerate CPU case exercises the API path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_test_mesh

    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        state = {"w": jnp.arange(16.0).reshape(4, 4)}
        ck.save(7, state)
        mesh = make_test_mesh((1, 1), ("data", "model"))
        sh = {"w": NamedSharding(mesh, P("data", None))}
        restored, step = ck.restore(jax.eval_shape(lambda: state),
                                    shardings=sh)
        assert step == 7
        assert restored["w"].sharding.is_equivalent_to(sh["w"], 2)


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_preemption_checkpoint_and_resume():
    tc = TrainConfig(optimizer="adamw", lr=1e-3, warmup_steps=1,
                     total_steps=50)
    step = jax.jit(make_train_step(CFG, tc))
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        state = make_train_state(jax.random.PRNGKey(0), CFG, tc)

        calls = {"n": 0}
        def batches():
            calls["n"] += 1
            if calls["n"] == 3:           # simulate SIGTERM mid-training
                os.kill(os.getpid(), signal.SIGTERM)
            return batch_fn()

        state, rep = run(state, step, batches, ck, num_steps=50,
                         ckpt_every=100, log_every=0)
        assert rep.preempted
        assert rep.steps_done == 3
        assert ck.latest_step() == 3      # on-signal checkpoint committed

        # a relaunched job resumes from the commit
        shape = jax.eval_shape(
            lambda: make_train_state(jax.random.PRNGKey(0), CFG, tc))
        st2, start, resumed = resume_or_init(
            ck, shape, lambda: make_train_state(jax.random.PRNGKey(0), CFG, tc))
        assert resumed and start == 3
        st2, rep2 = run(st2, step, batch_fn, ck, num_steps=6,
                        start_step=start, ckpt_every=2, log_every=0)
        assert rep2.final_step == 6
        assert int(st2["step"]) == 6


def test_straggler_guard_skips_slow_shard():
    calls = {"n": 0, "skips": 0}

    def next_fn():
        calls["n"] += 1
        if calls["n"] <= 2:
            time.sleep(0.15)              # two slow fetches
        return {"x": calls["n"]}

    guard = StragglerGuard(next_fn, lambda: calls.__setitem__(
        "skips", calls["skips"] + 1), deadline_s=0.05, max_skips=5)
    batch = guard()
    assert guard.skipped == 2
    assert calls["skips"] == 2
    assert batch == {"x": 3}


def test_straggler_guard_gives_up():
    guard = StragglerGuard(lambda: time.sleep(0.05) or {},
                           lambda: None, deadline_s=0.01, max_skips=2)
    with pytest.raises(TimeoutError):
        guard()
