"""Matrix-free StreamingFacilityLocation: parity against the dense
FacilityLocation (same features, same key) on every primitive and backend,
plus the one contract dense parity cannot check — that no intermediate of
size n*n ever appears in the jitted streaming computations (the jaxpr test).

Cross-backend coverage (oracle/pallas dispatch, greedy/SS parity) also runs
via the shared matrix in tests/test_backends.py ("fl_stream" entry);
multi-device sharded parity lives in tests/test_distributed.py.  This file
pins the streaming-vs-dense equivalence itself.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FacilityLocation,
    PallasBackend,
    StreamingFacilityLocation,
    greedy,
    ss_sparsify,
)

RTOL = ATOL = 1e-4


def pair(seed=0, n=200, d=12, kernel="cosine"):
    X = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    return (
        FacilityLocation.from_features(X, kernel=kernel),
        StreamingFacilityLocation.from_features(X, kernel=kernel),
    )


def close(a, b, rtol=RTOL, atol=ATOL):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


# ------------------------------------------------- dense parity: oracle ----
@pytest.mark.parametrize("kernel", ["dot", "cosine"])
def test_state_protocol_matches_dense(kernel):
    dense, sfl = pair(kernel=kernel)
    s_d, s_s = dense.empty_state(), sfl.empty_state()
    close(s_s, s_d)
    s_d, s_s = dense.add(s_d, jnp.asarray(7)), sfl.add(s_s, jnp.asarray(7))
    close(s_s, s_d)
    mask = jnp.arange(dense.n) % 5 == 0
    s_d, s_s = dense.add_many(s_d, mask), sfl.add_many(s_s, mask)
    close(s_s, s_d)
    close(sfl.value(s_s), dense.value(s_d))
    close(sfl.residual_gains(), dense.residual_gains())


@pytest.mark.parametrize("kernel", ["dot", "cosine"])
def test_four_primitives_match_dense(kernel):
    """pairwise_gains / gains / _compact / _batched — the four hot
    primitives of the acceptance criteria — against dense, same features."""
    dense, sfl = pair(kernel=kernel)
    probes = jnp.asarray([3, 50, 111, 166])
    state = dense.add_many(dense.empty_state(), jnp.arange(dense.n) < 7)
    ci = jnp.asarray([0, 5, 9, 100, 150, 199])

    close(sfl.pairwise_gains(probes), dense.pairwise_gains(probes))
    close(sfl.pairwise_gains(probes, state), dense.pairwise_gains(probes, state))
    close(sfl.gains(state), dense.gains(state))
    close(
        sfl.pairwise_gains_compact(probes, ci, state),
        dense.pairwise_gains_compact(probes, ci, state),
    )
    close(sfl.gains_compact(state, ci), dense.gains_compact(state, ci))

    def stack(mk):
        return jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[mk(seed=s) for s in (0, 1, 2)]
        )

    sd = stack(lambda seed: pair(seed=seed, kernel=kernel)[0])
    ss = stack(lambda seed: pair(seed=seed, kernel=kernel)[1])
    pb = jnp.tile(probes[None], (3, 1))
    cib = jnp.tile(ci[None], (3, 1))
    stb = jnp.tile(state[None], (3, 1))
    close(
        ss.pairwise_gains_batched(pb, cib, stb),
        sd.pairwise_gains_batched(pb, cib, stb),
    )
    close(ss.gains_batched(stb, cib), sd.gains_batched(stb, cib))


def test_rbf_kernel_rejected():
    X = jax.random.normal(jax.random.PRNGKey(0), (32, 4))
    with pytest.raises(ValueError, match="dot"):
        StreamingFacilityLocation.from_features(X, kernel="rbf")


# ------------------------------------------------- dense parity: pallas ----
def test_pallas_kernels_match_dense():
    dense, sfl = pair()
    probes = jnp.asarray([3, 50, 111, 166])
    residual = dense.residual_gains()
    ci = jnp.asarray([0, 5, 9, 100, 150, 199])
    state = dense.add_many(dense.empty_state(), jnp.arange(dense.n) < 7)

    for kw in ({}, {"cand_idx": ci}):
        out = sfl.pallas_divergence(probes, residual, state, interpret=True, **kw)
        ref = dense.pallas_divergence(probes, residual, state, interpret=True, **kw)
        close(out, ref)
        close(
            sfl.pallas_gains(state, interpret=True, **kw),
            dense.pallas_gains(state, interpret=True, **kw),
        )

    # probe_mask uses the resid=-INF pad convention
    mask = jnp.asarray([True, False, True, True])
    out = sfl.pallas_divergence(
        probes, residual, probe_mask=mask, interpret=True
    )
    ref = dense.pallas_divergence(
        probes, residual, probe_mask=mask, interpret=True
    )
    close(out, ref)


# ---------------------------------------------------- end-to-end parity ----
def test_ss_greedy_pipeline_matches_dense():
    """Same key => the streaming objective prunes and selects exactly the
    dense sets on both the oracle and pallas backends."""
    dense, sfl = pair()
    key = jax.random.PRNGKey(4)
    for backend in (None, PallasBackend(interpret=True)):
        ss_d = ss_sparsify(dense, key, r=6, c=8.0, backend=backend)
        ss_s = ss_sparsify(sfl, key, r=6, c=8.0, backend=backend)
        assert bool(jnp.all(ss_d.vprime == ss_s.vprime))
        r_d = greedy(dense, 8, alive=ss_d.vprime, backend=backend)
        r_s = greedy(sfl, 8, alive=ss_s.vprime, backend=backend)
        assert list(np.asarray(r_d.selected)) == list(np.asarray(r_s.selected))
        close(r_s.value, r_d.value, rtol=1e-5)


def test_sharded_backend_matches_dense_sharded():
    """Single-device mesh (same shard_map code path, collectives of size 1):
    the streaming shard hooks prune exactly like the dense column-sharded
    FacilityLocation hooks."""
    dense, sfl = pair(n=256)
    key = jax.random.PRNGKey(0)
    ss_d = ss_sparsify(dense, key, r=8, c=8.0, backend="sharded")
    ss_s = ss_sparsify(sfl, key, r=8, c=8.0, backend="sharded")
    assert 0 < int(jnp.sum(ss_s.vprime)) < sfl.n
    assert bool(jnp.all(ss_d.vprime == ss_s.vprime))
    v_d = float(greedy(dense, 8, alive=ss_d.vprime).value)
    v_s = float(greedy(sfl, 8, alive=ss_s.vprime).value)
    assert abs(v_s - v_d) / v_d < 1e-5, (v_s, v_d)


def test_pod_sharding_rejected():
    _, sfl = pair(n=64)
    assert not sfl.supports_pod_sharding
    with pytest.raises(NotImplementedError):
        sfl.shard_pack(("pod", "data"))


# ------------------------------------------------------ memory contract ----
def _max_intermediate_size(jaxpr) -> int:
    """Largest output aval (in elements) of any equation, recursing into
    scan/while/cond/pjit sub-jaxprs."""
    biggest = 0
    for eqn in jaxpr.eqns:
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            if aval is not None and hasattr(aval, "size"):
                biggest = max(biggest, int(aval.size))
        for v in eqn.params.values():
            for sub in jax.tree_util.tree_leaves(
                v, is_leaf=lambda x: isinstance(x, jax.extend.core.ClosedJaxpr)
            ):
                if isinstance(sub, jax.extend.core.ClosedJaxpr):
                    biggest = max(biggest, _max_intermediate_size(sub.jaxpr))
    return biggest


def test_no_quadratic_intermediate_in_jaxpr():
    """The contract dense parity can't check: the jitted streaming
    pairwise_gains / gains never build an intermediate of size n*n.  n is
    chosen so n*n (16.7M) exceeds the largest legitimate streaming slab
    (the (probe_chunk, bi, bn) hinge block — 8.4M at these defaults)."""
    n, d = 4096, 8
    X = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    sfl = StreamingFacilityLocation.from_features(X, kernel="dot")
    probes = jnp.asarray([1, 7, 100, 4000])
    state = sfl.empty_state()

    jx = jax.make_jaxpr(lambda f, p: f.pairwise_gains(p))(sfl, probes)
    assert _max_intermediate_size(jx.jaxpr) < n * n
    jx = jax.make_jaxpr(lambda f, s: f.gains(s))(sfl, state)
    assert _max_intermediate_size(jx.jaxpr) < n * n
    jx = jax.make_jaxpr(lambda f: f.residual_gains())(sfl)
    assert _max_intermediate_size(jx.jaxpr) < n * n

    # sanity: the same walk *does* flag the dense objective's n*n block
    dense = FacilityLocation.from_features(X, kernel="dot", n_threshold=None)
    jx = jax.make_jaxpr(lambda f, p: f.pairwise_gains(p))(dense, probes)
    assert _max_intermediate_size(jx.jaxpr) >= n * n


# -------------------------------------------------------- guard + data ----
def test_dense_from_features_threshold_guard():
    X = jax.random.normal(jax.random.PRNGKey(0), (128, 4))
    with pytest.raises(ValueError, match="StreamingFacilityLocation"):
        FacilityLocation.from_features(X, n_threshold=64)
    # escape hatch + default below-threshold path still work
    fn = FacilityLocation.from_features(X, n_threshold=None)
    assert fn.n == 128
    assert FacilityLocation.from_features(X).n == 128


def test_clustered_embeddings_generator():
    from repro.data import clustered_embeddings

    X = clustered_embeddings(0, 512, d=16, n_clusters=8)
    assert X.shape == (512, 16) and X.dtype == np.float32
    np.testing.assert_allclose(np.linalg.norm(X, axis=1), 1.0, rtol=1e-5)
    assert np.array_equal(X, clustered_embeddings(0, 512, d=16, n_clusters=8))
    # clustered => plenty of high-similarity pairs for SS to prune
    sims = X[:64] @ X[64:128].T
    assert float(sims.max()) > 0.8


def test_pipeline_ss_fl_selection():
    from repro.data import DataConfig, Pipeline

    cfg = DataConfig(
        batch_size=4, seq_len=32, vocab_size=503, selection="ss_fl",
        pool_factor=4, feature_dim=64,
    )
    batch = Pipeline(cfg)()
    assert batch["tokens"].shape == (4, 32)
    assert batch["labels"].shape == (4, 32)
