"""SLO-aware async scheduler, Ticket futures, streamed selection, and the
``repro.api`` facade (PR 7).

Contracts under test (docs/serving.md "Scheduler"):

- scheduling is a pure execution strategy: async-scheduled responses are
  query-for-query identical to the sequential single-query pipeline under
  the same keys, whatever trigger fired the batch;
- deadline edges: a request whose budget is already spent fails its own
  ticket at admission; a deadline shorter than the first compile is served
  late and flagged (never dropped); a flusher tick with an empty queue is a
  no-op; continuous batching refills buckets mid-flight;
- Ticket is a real future (``result(timeout)`` / ``done()`` /
  ``exception()``) with per-request error capture — one malformed request
  fails alone;
- the ``RunConfig`` facade threads end-to-end and the old spellings warn.
"""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import FeatureCoverage, greedy, greedy_batched, ss_sparsify
from repro.data import news_day
from repro.serve import (
    DeadlineExceeded,
    RunConfig,
    ServiceOverloaded,
    SummarizeRequest,
    SummarizeService,
)


def req(i, n=128, F=24, k=4, **kw):
    return SummarizeRequest(
        k=k, key=i, features=jnp.asarray(news_day(i, n, F)), **kw
    )


def assert_matches_sequential(request, resp):
    fn = FeatureCoverage(W=jnp.asarray(request.features), phi="sqrt")
    ss = ss_sparsify(fn, request.prng_key())
    ref = greedy(fn, request.k, alive=ss.vprime)
    assert (np.asarray(resp.selected) == np.asarray(ref.selected)).all()
    np.testing.assert_allclose(
        np.asarray(resp.gains), np.asarray(ref.gains), rtol=1e-5, atol=1e-6
    )


# ------------------------------------------------------------ ticket future --
def test_ticket_future_api():
    svc = SummarizeService(RunConfig(max_batch=2))
    t = svc.submit(req(0))
    assert not t.done()
    with pytest.raises(TimeoutError):
        t.result(timeout=0)
    svc.flush()
    assert t.done() and t.exception() is None
    assert_matches_sequential(req(0), t.result(timeout=0))


def test_malformed_request_fails_own_ticket():
    """Per-request error capture: the payload-less request fails its own
    ticket at admission — it never occupies a queue slot, and its batch
    mates complete untouched."""
    svc = SummarizeService(RunConfig(max_batch=4))
    good = svc.submit(req(1))
    bad = svc.submit(SummarizeRequest(k=4, key=2))        # no payload
    assert bad.done() and not good.done()                 # failed at admission
    out = svc.flush()
    assert len(out) == 1 and out[0] is not None           # only the good one
    with pytest.raises(ValueError, match="payload"):
        bad.result()
    assert isinstance(bad.exception(), ValueError)
    assert_matches_sequential(req(1), good.result())
    assert svc.stats()["failed"] == 1


def test_expired_at_admission():
    """A deadline already spent at admission fails the ticket immediately —
    it never occupies a batch slot."""
    svc = SummarizeService(RunConfig(max_batch=4))
    dead = svc.submit(req(3, deadline_s=0.0))
    live = svc.submit(req(4, deadline_s=30.0))
    assert dead.done()
    with pytest.raises(DeadlineExceeded):
        dead.result()
    svc.flush()
    resp = live.result()
    assert resp.deadline_missed is False
    assert svc.stats()["queries"] == 1


def test_backpressure_max_pending():
    svc = SummarizeService(RunConfig(max_batch=8, max_pending=2))
    t1, t2, t3 = (svc.submit(req(i)) for i in range(3))
    assert not t1.done() and not t2.done() and t3.done()
    with pytest.raises(ServiceOverloaded):
        t3.result()
    svc.flush()
    assert t1.result() is not None and t2.result() is not None


def test_execution_error_fails_only_its_chunk():
    """An execution-time error (here: an unknown objective that survives
    admission) fails the chunk's tickets with the captured error instead of
    propagating out of the scheduler."""
    svc = SummarizeService(RunConfig(max_batch=4))
    bad = svc.submit(
        SummarizeRequest(
            k=4, key=0, features=jnp.ones((32, 8)), objective="nope"
        )
    )
    good = svc.submit(req(5))
    svc.flush()
    with pytest.raises(ValueError, match="objective"):
        bad.result()
    assert_matches_sequential(req(5), good.result())


# --------------------------------------------------------- async scheduler --
def test_async_matches_sequential():
    """The headline pin: async-scheduled responses are identical to the
    sequential pipeline under the same keys."""
    with api.serve(
        RunConfig(scheduler="async", max_batch=4, max_wait_s=0.01)
    ) as svc:
        reqs = [req(10 + i) for i in range(6)]
        tickets = [svc.submit(r) for r in reqs]
        for r, t in zip(reqs, tickets):
            assert_matches_sequential(r, t.result(timeout=60))
    st = svc.stats()
    assert st["queries"] == 6 and st["failed"] == 0


def test_async_flush_on_full_trigger():
    """A lane at max_batch fires immediately (trigger "full") without
    waiting for max_wait."""
    with api.serve(
        RunConfig(scheduler="async", max_batch=2, max_wait_s=60.0)
    ) as svc:
        t1 = svc.submit(req(20))
        t2 = svc.submit(req(21))
        r1 = t1.result(timeout=60)
        r2 = t2.result(timeout=60)
    assert r1.trigger == "full" and r2.trigger == "full"
    assert r1.batch_size == 2


def test_async_max_wait_trigger():
    """A lone request fires after max_wait_s even though its lane never
    fills."""
    with api.serve(
        RunConfig(scheduler="async", max_batch=8, max_wait_s=0.02)
    ) as svc:
        t = svc.submit(req(22))
        resp = t.result(timeout=60)
    assert resp.trigger == "max_wait"
    assert resp.batch_size == 1


def test_async_deadline_trigger_preempts_max_wait():
    """A tight deadline fires the lane long before a large max_wait — the
    deadline-slack term of the flusher policy."""
    with api.serve(
        RunConfig(scheduler="async", max_batch=8, max_wait_s=60.0)
    ) as svc:
        t = svc.submit(req(23, deadline_s=0.1))
        resp = t.result(timeout=60)
    assert resp.trigger == "deadline"


def test_deadline_shorter_than_first_compile_is_flagged_not_dropped():
    """First execution of a fresh lane pays the compile; a deadline below
    that still gets served, with deadline_missed=True."""
    with api.serve(
        RunConfig(scheduler="async", max_batch=4, max_wait_s=60.0)
    ) as svc:
        # n=130 is a lane shape nothing else in the suite compiles.
        r = req(24, n=130, deadline_s=1e-4)
        t = svc.submit(r)
        resp = t.result(timeout=120)
    assert resp.deadline_missed is True
    assert resp.trigger == "deadline"
    assert_matches_sequential(r, resp)
    assert svc.stats()["deadlines_missed"] == 1


def test_flusher_tick_with_empty_queue():
    """An empty-queue tick is a no-op: the policy reports nothing to fire,
    the thread parks, and the service still serves what arrives later."""
    with api.serve(
        RunConfig(scheduler="async", max_batch=4, max_wait_s=0.01)
    ) as svc:
        with svc._cond:
            lane, fire_t, trigger = svc._next_fire(time.perf_counter())
        assert lane is None and fire_t is None and trigger is None
        svc.drain()                       # drain of an empty queue returns
        time.sleep(0.05)                  # let the flusher park on the cond
        t = svc.submit(req(25))
        assert t.result(timeout=60) is not None
    assert svc.stats()["batches"] == 1


def test_continuous_batching_refills_mid_flight():
    """Submissions that land while a batch executes form the next bucket:
    with max_batch=2 and 5 requests racing the flusher, every batch holds
    <= 2 and all five responses stay sequential-identical."""
    with api.serve(
        RunConfig(scheduler="async", max_batch=2, max_wait_s=0.005)
    ) as svc:
        reqs = [req(30 + i) for i in range(5)]
        tickets = []
        for r in reqs:
            tickets.append(svc.submit(r))
            time.sleep(0.002)             # interleave with executions
        responses = [t.result(timeout=60) for t in tickets]
    for r, resp in zip(reqs, responses):
        assert_matches_sequential(r, resp)
    st = svc.stats()
    assert st["queries"] == 5
    assert all(resp.batch_size <= 2 for resp in responses)
    assert st["batches"] >= 3             # 5 queries can't fit 2 batches of 2


def test_async_run_and_stats_triggers():
    with api.serve(
        RunConfig(scheduler="async", max_batch=4, max_wait_s=30.0)
    ) as svc:
        out = svc.run([req(40 + i) for i in range(3)])
    assert len(out) == 3
    # run() drains: the undersized lane fired on the drain request.
    assert out[0].trigger in ("drain", "full")
    assert sum(svc.stats()["triggers"].values()) == svc.stats()["batches"]


# ------------------------------------------------------- streamed selection --
def test_greedy_batched_on_step_matches_scan():
    """The streamed per-step path is the scan body relaunched k times: the
    emitted steps and the final result must equal the un-streamed call."""
    Ws = jnp.stack([jnp.asarray(news_day(50 + i, 96, 16)) for i in range(2)])
    fnb = FeatureCoverage(W=Ws, phi="sqrt")
    alive = jnp.stack([jnp.arange(96) < 80, jnp.arange(96) < 3])
    ref = greedy_batched(fnb, 5, alive=alive)
    seen = []
    res = greedy_batched(
        fnb, 5, alive=alive,
        on_step=lambda i, v, g, ok: seen.append(
            (i, np.asarray(v), np.asarray(g), np.asarray(ok))
        ),
    )
    np.testing.assert_array_equal(
        np.asarray(res.selected), np.asarray(ref.selected)
    )
    np.testing.assert_allclose(
        np.asarray(res.gains), np.asarray(ref.gains), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(res.value), np.asarray(ref.value), rtol=1e-6)
    assert [s[0] for s in seen] == list(range(5))
    for i, v, g, ok in seen:
        np.testing.assert_array_equal(v, np.asarray(ref.selected[:, i]))
        np.testing.assert_allclose(g, np.asarray(ref.gains[:, i]), rtol=1e-6)
    # row 1 exhausts after 3 picks: ok goes False, records become 0
    assert [bool(s[3][1]) for s in seen] == [True] * 3 + [False] * 2


def test_stream_steps_tickets_accumulate_partials():
    """stream_steps=True: tickets expose the committed greedy prefix; the
    final response is unchanged vs the non-streamed service."""
    r = req(60, n=96, F=16, k=5)
    plain = SummarizeService(RunConfig(max_batch=2)).run([r])[0]
    svc = SummarizeService(RunConfig(max_batch=2, stream_steps=True))
    t = svc.submit(r)
    assert t.partial() == []                              # nothing committed
    svc.flush()
    resp = t.result()
    assert (np.asarray(resp.selected) == np.asarray(plain.selected)).all()
    steps = t.partial()
    assert [v for v, _ in steps] == list(np.asarray(resp.selected))
    np.testing.assert_allclose(
        [g for _, g in steps], np.asarray(resp.gains), rtol=1e-6
    )


def test_stream_steps_observed_incrementally():
    """The partial prefix is readable from another thread while later steps
    still run — the streaming contract is per-step commit, not end-of-batch
    delivery."""
    done_event = threading.Event()
    svc = SummarizeService(RunConfig(max_batch=2, stream_steps=True))
    t = svc.submit(req(61, n=96, F=16, k=4))
    prefix_lengths = []

    def poll():
        while not done_event.is_set():
            prefix_lengths.append(len(t.partial()))
            time.sleep(0.0005)

    th = threading.Thread(target=poll)
    th.start()
    svc.flush()
    done_event.set()
    th.join()
    assert len(t.partial()) == 4
    # the poller's observations are a monotone prefix-growth sequence
    assert prefix_lengths == sorted(prefix_lengths)
    assert prefix_lengths[0] < 4                  # it looked before the end


# ------------------------------------------------------------ api facade ----
def test_api_summarize_matches_core():
    W = jnp.asarray(news_day(70, 128, 24))
    resp = api.summarize(W, k=4, key=70)
    fn = FeatureCoverage(W=W, phi="sqrt")
    ss = ss_sparsify(fn, jax.random.PRNGKey(70))
    ref = greedy(fn, 4, alive=ss.vprime)
    assert (np.asarray(resp.selected) == np.asarray(ref.selected)).all()
    # config threads end-to-end: no-SS run on the facade
    resp2 = api.summarize(W, k=4, key=70, use_ss=False)
    ref2 = greedy(fn, 4)
    assert (np.asarray(resp2.selected) == np.asarray(ref2.selected)).all()
    assert resp2.vprime_size is None


def test_api_serve_and_submit_default_service():
    svc = api.serve(RunConfig(max_batch=2))
    assert isinstance(svc, SummarizeService)
    assert svc.config.max_batch == 2
    t = api.submit(req(71), service=None)          # process default (async)
    assert_matches_sequential(req(71), t.result(timeout=120))
    assert api.default_service() is api.default_service()


def test_deprecated_spellings_warn_and_map():
    from repro.serve import ServiceConfig
    from repro.serve.kv_select import KVSelectConfig

    with pytest.warns(DeprecationWarning, match="RunConfig"):
        cfg = ServiceConfig(backend="oracle", max_batch=4)
    assert isinstance(cfg, RunConfig) and cfg.max_batch == 4
    with pytest.warns(DeprecationWarning, match="RunConfig"):
        svc = SummarizeService(RunConfig(), max_batch=2)
    assert svc.config.max_batch == 2
    with pytest.warns(DeprecationWarning, match="RunConfig"):
        kv = KVSelectConfig(budget=8, backend="oracle", r=4, c=4.0)
    assert kv.run.backend == "oracle" and kv.run.r == 4 and kv.run.c == 4.0
    # the new spelling is warning-free
    import warnings as _warnings
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        KVSelectConfig(budget=8, run=RunConfig(backend="oracle"))
        RunConfig(max_batch=4)


def test_runconfig_validates_scheduler():
    with pytest.raises(ValueError, match="scheduler"):
        RunConfig(scheduler="later")
