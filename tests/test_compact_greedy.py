"""Compact selection engine: the ``gains_compact`` backend primitive and the
compact greedy / stochastic-greedy paths (post-SS selection at |V'| cost).

The contract under test (docs/backends.md "Compact selection"): compaction is
a pure execution-strategy change — under the same inputs (and, for stochastic
greedy, the same PRNG key) the compact path must produce the *identical*
``selected`` / ``gains`` / ``value`` as the full-width path, on every
backend, including non-tile-multiple live counts, k > |alive| exhaustion,
and conditional (state != empty) starts.  The sharded stochastic-greedy loop
must match the dense compact path selection-for-selection under the same key
(multi-device coverage lives in tests/test_distributed.py; here a 1-device
mesh exercises the same kernel).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FacilityLocation,
    FeatureCoverage,
    PallasBackend,
    ShardedBackend,
    auto_sample_size,
    get_backend,
    greedy,
    selection_bucket,
    ss_sparsify,
    stochastic_greedy,
    summarize,
)


def make_fc(seed=0, n=300, F=48, phi="sqrt", feat_w=False):
    key = jax.random.PRNGKey(seed)
    W = jax.random.uniform(key, (n, F))
    fw = jnp.linspace(0.5, 1.5, F) if feat_w else None
    return FeatureCoverage(W=W, feat_w=fw, phi=phi)


def make_fl(seed=0, n=300, d=12):
    X = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    return FacilityLocation.from_features(X, kernel="cosine")


OBJECTIVES = {
    "fc": lambda n: make_fc(0, n=n),
    "fc_featw": lambda n: make_fc(1, n=n, feat_w=True),
    "fc_satcov": lambda n: make_fc(2, n=n, phi="satcov"),
    "fl": lambda n: make_fl(3, n=n),
}
BACKENDS = {
    "oracle": lambda: get_backend("oracle"),
    "pallas": lambda: PallasBackend(interpret=True),
    "sharded": lambda: "sharded",   # greedy's per-step gains inherit oracle
}


def _sparse_alive(fn, seed=11):
    ss = ss_sparsify(fn, jax.random.PRNGKey(seed), r=6, c=8.0)
    live = int(jnp.sum(ss.vprime))
    assert 0 < live < fn.n
    assert selection_bucket(fn.n, live) is not None, "alive not sparse enough"
    return ss.vprime


def _assert_equal_results(a, b, exact_gains=False):
    assert (np.asarray(a.selected) == np.asarray(b.selected)).all(), (
        a.selected, b.selected)
    if exact_gains:
        np.testing.assert_array_equal(np.asarray(a.gains), np.asarray(b.gains))
    else:
        np.testing.assert_allclose(
            np.asarray(a.gains), np.asarray(b.gains), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(a.value), float(b.value), rtol=1e-5)


# ------------------------------------------------ gains_compact primitive ----
@pytest.mark.parametrize("mk", sorted(OBJECTIVES))
@pytest.mark.parametrize("backend", ["oracle", "pallas"])
def test_gains_compact_matches_full_gather(mk, backend):
    fn = OBJECTIVES[mk](300)
    be = BACKENDS[backend]()
    state = fn.add_many(fn.empty_state(), jnp.arange(fn.n) < 7)
    cand_idx = jnp.asarray([0, 3, 64, 65, 150, 299])
    full = be.gains(fn, state)
    out = be.gains_compact(fn, state, cand_idx)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(full)[np.asarray(cand_idx)],
        rtol=1e-5, atol=1e-5,
    )


def test_gains_compact_default_is_gather():
    """The base-class fallback (full gains + gather) keeps out-of-tree
    objectives correct on the compact path, and the shipped overrides agree
    with it."""
    from repro.core.functions import SubmodularFunction

    fn = make_fc(3, n=120, F=16)
    state = fn.add_many(fn.empty_state(), jnp.arange(120) < 4)
    cand_idx = jnp.asarray([2, 50, 119])
    ref = np.asarray(fn.gains(state))[np.asarray(cand_idx)]
    out = SubmodularFunction.gains_compact(fn, state, cand_idx)
    np.testing.assert_allclose(np.asarray(out), ref)
    np.testing.assert_allclose(
        np.asarray(fn.gains_compact(state, cand_idx)), ref,
        rtol=1e-6, atol=1e-6,
    )


# ------------------------------------------------- greedy compact parity ----
@pytest.mark.parametrize("name", sorted(OBJECTIVES))
@pytest.mark.parametrize("backend", sorted(BACKENDS))
def test_greedy_compact_matches_full(name, backend):
    """Acceptance: compact and full-width greedy select identical sets on
    every backend, from a real post-SS alive mask."""
    fn = OBJECTIVES[name](256)
    be = BACKENDS[backend]()
    alive = _sparse_alive(fn)
    full = greedy(fn, 8, alive=alive, backend=be, compact=False)
    comp = greedy(fn, 8, alive=alive, backend=be, compact=True)
    _assert_equal_results(full, comp)
    # selections come from the alive set
    assert bool(jnp.all(alive[comp.selected]))


@pytest.mark.parametrize("n", [200, 300, 333])
@pytest.mark.parametrize("backend", ["oracle", "pallas"])
def test_greedy_compact_non_tile_multiple(n, backend):
    """Live counts and ground sizes that are not multiples of the 128 tile:
    the gathered bucket is tile-rounded, padding slots must stay inert."""
    fn = make_fc(5, n=n, F=24)
    be = BACKENDS[backend]()
    alive = jnp.isin(jnp.arange(n), jnp.arange(0, n, 2)[:137])  # 137 live
    full = greedy(fn, 6, alive=alive, backend=be, compact=False)
    comp = greedy(fn, 6, alive=alive, backend=be, compact=True)
    _assert_equal_results(full, comp)


@pytest.mark.parametrize("backend", ["oracle", "pallas"])
def test_greedy_compact_k_exceeds_alive(backend):
    """k > |alive|: exhausted steps record index 0 with gain 0 on both
    paths, and the value counts the alive selections only."""
    fn = make_fc(6, n=256, F=24)
    be = BACKENDS[backend]()
    alive = jnp.arange(256) < 5
    full = greedy(fn, 9, alive=alive, backend=be, compact=False)
    comp = greedy(fn, 9, alive=alive, backend=be, compact=True)
    _assert_equal_results(full, comp)
    assert np.allclose(np.asarray(comp.gains)[5:], 0.0)
    assert (np.asarray(comp.selected)[5:] == 0).all()


@pytest.mark.parametrize("backend", ["oracle", "pallas"])
def test_greedy_compact_conditional_state(backend):
    """Conditional (state != empty) starts: gains are marginals on top of the
    given state and parity still holds."""
    fn = make_fc(7, n=256, F=24)
    be = BACKENDS[backend]()
    alive = _sparse_alive(fn)
    state = fn.add_many(fn.empty_state(), jnp.arange(256) < 4)
    full = greedy(fn, 6, alive=alive, backend=be, state=state, compact=False)
    comp = greedy(fn, 6, alive=alive, backend=be, state=state, compact=True)
    _assert_equal_results(full, comp)
    # conditional value includes the initial state's coverage
    assert float(comp.value) > float(fn.value(state))


def test_greedy_compact_int_bound_and_tracer_fallback():
    """An int ``compact`` bound engages the compact path without host-reading
    alive (the jit/vmap case); a plain tracer mask falls back to full-width;
    a bound smaller than the live count fails loudly."""
    fn = make_fc(8, n=256, F=16)
    alive = jnp.arange(256) < 100
    ref = greedy(fn, 5, alive=alive, compact=False)
    out = greedy(fn, 5, alive=alive, compact=128)
    _assert_equal_results(ref, out)
    with pytest.raises(ValueError, match="live bound"):
        greedy(fn, 5, alive=alive, compact=50)

    # under vmap the mask is a tracer: auto falls back, int bound compacts
    masks = jnp.stack([alive, jnp.arange(256) < 60])
    sel_auto = jax.vmap(lambda a: greedy(fn, 5, alive=a).selected)(masks)
    sel_bound = jax.vmap(
        lambda a: greedy(fn, 5, alive=a, compact=128).selected)(masks)
    np.testing.assert_array_equal(np.asarray(sel_auto), np.asarray(sel_bound))


def test_summarize_routes_through_compact():
    """The end-to-end pipeline's downstream greedy runs compact by default
    and compact=False reproduces it exactly."""
    fn = make_fc(9, n=300, F=32)
    key = jax.random.PRNGKey(2)
    res_c, ss_c = summarize(fn, 8, key, r=6, c=8.0, compact=True)
    res_f, ss_f = summarize(fn, 8, key, r=6, c=8.0, compact=False)
    assert bool(jnp.all(ss_c.vprime == ss_f.vprime))
    _assert_equal_results(res_c, res_f)


# -------------------------------------------- stochastic greedy (compact) ----
def test_stochastic_compact_cross_backend_same_key():
    """Oracle and pallas produce identical selections under the same key on
    the compact path (the kernel output matches the oracle gather bitwise)."""
    fn = make_fc(10, n=300, F=32)
    alive = _sparse_alive(fn)
    key = jax.random.PRNGKey(4)
    o = stochastic_greedy(fn, 8, key, alive=alive, backend="oracle")
    p = stochastic_greedy(fn, 8, key, alive=alive,
                          backend=PallasBackend(interpret=True))
    _assert_equal_results(o, p)


def test_stochastic_compact_samples_in_compact_space():
    """s=None auto mode: the sample size derives from the live count, not n,
    and every selection is an alive element."""
    fn = make_fc(11, n=512, F=32)
    alive = _sparse_alive(fn)
    live = int(jnp.sum(alive))
    s_live = auto_sample_size(512, 8, eps=0.1, live=live)
    s_full = auto_sample_size(512, 8, eps=0.1)
    assert s_live < s_full                         # the point of the heuristic
    res = stochastic_greedy(fn, 8, jax.random.PRNGKey(5), alive=alive)
    sel = np.asarray(res.selected)
    assert len(set(sel.tolist())) == 8             # distinct selections
    assert bool(jnp.all(alive[res.selected]))
    assert float(res.value) > 0


def test_stochastic_compact_k_exceeds_alive_and_state():
    fn = make_fc(12, n=256, F=24)
    alive = jnp.arange(256) < 4
    key = jax.random.PRNGKey(6)
    res = stochastic_greedy(fn, 7, key, alive=alive)
    assert np.allclose(np.asarray(res.gains)[4:], 0.0)
    assert (np.asarray(res.selected)[4:] == 0).all()
    assert set(np.asarray(res.selected)[:4].tolist()) == {0, 1, 2, 3}
    # conditional start runs on the compact path too
    state = fn.add_many(fn.empty_state(), jnp.arange(256) < 4)
    alive2 = _sparse_alive(fn)
    res2 = stochastic_greedy(fn, 5, key, alive=alive2, state=state)
    assert float(res2.value) > float(fn.value(state))


def test_stochastic_quality_close_to_greedy():
    """Post-SS stochastic greedy with the auto sample size stays within a few
    percent of exact greedy on the same live set."""
    fn = make_fc(13, n=400, F=48)
    alive = _sparse_alive(fn)
    g = greedy(fn, 8, alive=alive)
    sg = stochastic_greedy(fn, 8, jax.random.PRNGKey(8), alive=alive, eps=0.05)
    assert float(sg.value) >= 0.9 * float(g.value)


# ------------------------------------------------ sharded stochastic greedy --
def test_sharded_stochastic_matches_dense_compact_1dev():
    """The distributed sampler is selection-for-selection identical to the
    dense compact path under the same key (1-device mesh; the 8-device case
    is pinned in tests/test_distributed.py)."""
    from repro.compat import make_mesh

    mesh = make_mesh((jax.device_count(),), ("data",))
    for fn in (make_fc(14, n=256, F=32), make_fl(15, n=256)):
        alive = _sparse_alive(fn)
        key = jax.random.PRNGKey(9)
        dense = stochastic_greedy(fn, 8, key, alive=alive, backend="oracle")
        shard = stochastic_greedy(fn, 8, key, alive=alive,
                                  backend=ShardedBackend(mesh=mesh))
        _assert_equal_results(dense, shard)


def test_sharded_stochastic_matches_dense_full_width():
    """When the dense plan is full-width (live count fits no sub-n bucket,
    or compact=False), the sharded sampler switches to the ground frame and
    still matches the dense path under the same key."""
    from repro.compat import make_mesh

    mesh = make_mesh((jax.device_count(),), ("data",))
    be = ShardedBackend(mesh=mesh)
    fn = make_fc(17, n=256, F=32)
    key = jax.random.PRNGKey(10)
    # 200/256 live: only the full bucket fits -> dense runs full-width
    dense_mask = jnp.arange(256) < 200
    assert selection_bucket(256, 200) is None
    d = stochastic_greedy(fn, 8, key, alive=dense_mask, backend="oracle")
    sh = stochastic_greedy(fn, 8, key, alive=dense_mask, backend=be)
    _assert_equal_results(d, sh)
    # compact=False forces the ground frame even on a sparse mask
    sparse = jnp.arange(256) < 60
    d = stochastic_greedy(fn, 8, key, alive=sparse, backend="oracle",
                          compact=False)
    sh = stochastic_greedy(fn, 8, key, alive=sparse, backend=be,
                           compact=False)
    _assert_equal_results(d, sh)
    # alive=None (everything live) matches too
    d = stochastic_greedy(fn, 6, key, backend="oracle")
    sh = stochastic_greedy(fn, 6, key, backend=be)
    _assert_equal_results(d, sh)


def test_stochastic_full_width_s_derives_from_live_count():
    """compact=False still host-reads a concrete mask for the s=None
    heuristic: the full-width and compact runs of the same sparse mask use
    the same live-count-derived sample size (and the compact run reproduces
    a loose int bound's selections once the mask is readable)."""
    fn = make_fc(18, n=300, F=24)
    alive = _sparse_alive(fn)
    key = jax.random.PRNGKey(11)
    a = greedy(fn, 6, alive=alive, compact=int(jnp.sum(alive)) + 50)
    b = greedy(fn, 6, alive=alive, compact=True)
    _assert_equal_results(a, b)


def test_sharded_stochastic_rejects_pod_axis():
    from repro.compat import make_mesh

    mesh = make_mesh((1, 1), ("pod", "data"))
    fn = make_fc(16, n=64, F=8)
    with pytest.raises(NotImplementedError, match="single-level"):
        stochastic_greedy(fn, 4, jax.random.PRNGKey(0),
                          backend=ShardedBackend(mesh=mesh, pod_axis="pod"))


# ------------------------------------------------------ planning helpers ----
def test_selection_bucket_properties():
    from repro.core.sparsify import bucket_schedule

    for n in (256, 300, 2048):
        buckets = bucket_schedule(n, 8.0, 128)
        for live in (1, 17, n // 4, n - 1, n):
            size = selection_bucket(n, live)
            if size is None:
                # only the full bucket fits
                assert all(b >= n or b < live for b in buckets)
            else:
                assert size >= live and size < n
                assert size in buckets


def test_auto_sample_size_bounds():
    assert auto_sample_size(1000, 10, eps=0.1, live=100) == 24  # 10*ln(10)
    assert auto_sample_size(1000, 10, eps=0.1) >= 230
    assert auto_sample_size(16, 64, eps=0.5) == 1               # floor at 1
