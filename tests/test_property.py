"""Property-based tests (hypothesis) on the system's submodular invariants:
diminishing returns, the graph lemmas (1-3), SS certificates, sieve bounds,
and the loss/optimizer numerics."""

import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st  # skips @given tests only

from repro.core import FacilityLocation, FeatureCoverage, greedy
from repro.core.graph import (
    check_triangle_inequality,
    divergence,
    edge_weights,
    full_edge_matrix,
)
from repro.core.sparsify import ss_sparsify
from repro.train.compress import topk_block_sparsify

SET = settings(max_examples=15, deadline=None)


def _fc(seed: int, n: int, F: int, phi: str = "sqrt") -> FeatureCoverage:
    rng = np.random.default_rng(seed)
    W = jnp.asarray(rng.random((n, F), np.float32))
    return FeatureCoverage(W=W, phi=phi)


@SET
@given(seed=st.integers(0, 10_000), n=st.integers(4, 24),
       F=st.integers(2, 16),
       phi=st.sampled_from(["sqrt", "log1p", "setcover"]))
def test_diminishing_returns(seed, n, F, phi):
    """f(v|A) >= f(v|B) for A ⊆ B — the defining inequality (paper eq. 1)."""
    fn = _fc(seed, n, F, phi)
    rng = np.random.default_rng(seed + 1)
    a = rng.random(n) < 0.3
    b = a | (rng.random(n) < 0.3)
    sa = fn.add_many(fn.empty_state(), jnp.asarray(a))
    sb = fn.add_many(fn.empty_state(), jnp.asarray(b))
    ga, gb = fn.gains(sa), fn.gains(sb)
    outside = ~jnp.asarray(b)
    assert bool(jnp.all(jnp.where(outside, ga - gb >= -1e-4, True)))


@SET
@given(seed=st.integers(0, 10_000), n=st.integers(4, 16),
       F=st.integers(2, 12))
def test_monotone_nonneg(seed, n, F):
    fn = _fc(seed, n, F)
    assert bool(jnp.all(fn.gains(fn.empty_state()) >= -1e-6))
    assert float(fn.value(fn.empty_state())) == 0.0


@SET
@given(seed=st.integers(0, 10_000), n=st.integers(4, 12),
       F=st.integers(2, 8))
def test_triangle_inequality_lemma3(seed, n, F):
    fn = _fc(seed, n, F)
    W = full_edge_matrix(fn)
    assert float(check_triangle_inequality(W)) <= 1e-3


@SET
@given(seed=st.integers(0, 10_000), n=st.integers(4, 12),
       F=st.integers(2, 8))
def test_lemma2_bound(seed, n, F):
    """f(v|S) <= f(u|S) + w_{uv|S} for all u != v (paper Lemma 2)."""
    fn = _fc(seed, n, F)
    rng = np.random.default_rng(seed)
    mask = jnp.asarray(rng.random(n) < 0.25)
    state = fn.add_many(fn.empty_state(), mask)
    g = fn.gains(state)                                # f(.|S)
    Wm = edge_weights(fn, jnp.arange(n), state=state)  # w_{u->v|S}
    lhs = g[None, :]                                   # f(v|S)
    rhs = g[:, None] + Wm
    off = ~jnp.eye(n, dtype=bool) & ~mask[None, :] & ~mask[:, None]
    assert bool(jnp.all(jnp.where(off, lhs <= rhs + 1e-3, True)))


@SET
@given(seed=st.integers(0, 10_000), n=st.integers(16, 48),
       F=st.integers(4, 16), r=st.integers(2, 6))
def test_ss_certificate(seed, n, F, r):
    """Every pruned element's divergence from V' is <= eps_hat."""
    fn = _fc(seed, n, F)
    key = jax.random.PRNGKey(seed)
    ss = ss_sparsify(fn, key, r=r, c=8.0)
    pruned = ~ss.vprime
    if not bool(jnp.any(pruned)):
        return
    vp_idx = jnp.where(ss.vprime, size=n, fill_value=0)[0]
    div = divergence(fn, vp_idx,
                     probe_mask=jnp.sort(ss.vprime)[::-1])
    viol = jnp.where(pruned, div - ss.eps_hat, -jnp.inf)
    assert float(jnp.max(viol)) <= 1e-3


@SET
@given(seed=st.integers(0, 10_000), n=st.integers(8, 32),
       F=st.integers(2, 12), k=st.integers(1, 6))
def test_greedy_value_equals_sum_of_gains(seed, n, F, k):
    fn = _fc(seed, n, F)
    res = greedy(fn, min(k, n))
    assert abs(float(jnp.sum(res.gains)) - float(res.value)) < 1e-3
    # gains are non-increasing (greedy + submodularity)
    g = np.asarray(res.gains)
    assert np.all(np.diff(g) <= 1e-4)


@SET
@given(seed=st.integers(0, 10_000), n=st.integers(6, 20))
def test_facility_location_invariants(seed, n):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.random((n, 4), np.float32))
    fn = FacilityLocation.from_features(X, kernel="cosine")
    W = full_edge_matrix(fn)
    assert float(check_triangle_inequality(W)) <= 1e-3
    g = fn.gains(fn.empty_state())
    assert bool(jnp.all(g >= -1e-5))


@SET
@given(seed=st.integers(0, 10_000),
       size=st.integers(2, 300),
       ratio=st.floats(0.05, 0.9),
       block=st.sampled_from([8, 32, 128]))
def test_topk_sparsifier_properties(seed, size, ratio, block):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=size).astype(np.float32))
    y = topk_block_sparsify(x, ratio, block)
    # kept entries are exact; zeros elsewhere
    kept = np.asarray(y) != 0
    np.testing.assert_array_equal(np.asarray(y)[kept], np.asarray(x)[kept])
    # error norm <= original norm (contraction; EF convergence condition)
    assert float(jnp.linalg.norm(x - y)) <= float(jnp.linalg.norm(x)) + 1e-6
    # at least ceil(ratio*block) kept per full block
    assert kept.sum() >= 1


@SET
@given(seed=st.integers(0, 10_000), n=st.integers(2, 10),
       v=st.integers(5, 50))
def test_lm_loss_matches_naive(seed, n, v):
    from repro.models import lm_loss
    from repro.models.config import ModelConfig

    cfg = ModelConfig(name="t", num_layers=1, d_model=8, num_heads=1,
                      num_kv_heads=1, head_dim=8, d_ff=8, vocab_size=v)
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(2, n, v)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, v, size=(2, n)).astype(np.int32))
    labels = labels.at[0, 0].set(-1)  # masked position
    got = float(lm_loss(cfg, logits, labels))
    lp = jax.nn.log_softmax(logits, -1)
    want, cnt = 0.0, 0
    for b in range(2):
        for t in range(n):
            if int(labels[b, t]) >= 0:
                want -= float(lp[b, t, int(labels[b, t])])
                cnt += 1
    assert abs(got - want / cnt) < 1e-4
