"""Data pipeline + serving integration tests."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import (
    DataConfig,
    Pipeline,
    hashed_features,
    lm_documents,
    news_day,
    selection_quality,
    video,
)
from repro.models import decode_step, init_params, prefill
from repro.serve import Engine, KVSelectConfig, ServeConfig, prune_cache


def test_synthetic_shapes_and_ranges():
    W = news_day(0, 200, 64)
    assert W.shape == (200, 64) and (W >= 0).all()
    assert np.allclose(np.linalg.norm(W, axis=1), 1.0, atol=1e-4)
    X = video(0, 500, 32)
    assert X.shape == (500, 32) and (X >= 0).all()
    docs = lm_documents(0, 50, 32, 500, dup_frac=0.4)
    assert docs.shape == (50, 32)
    assert docs.min() >= 0 and docs.max() < 500


def test_hashed_features_deterministic():
    docs = lm_documents(1, 10, 24, 100)
    a = hashed_features(docs, 64)
    b = hashed_features(docs, 64)
    np.testing.assert_array_equal(a, b)


def test_pipeline_batches_and_sharding():
    cfg = DataConfig(batch_size=4, seq_len=32, vocab_size=211,
                     selection="ss", pool_factor=3, feature_dim=64)
    p0 = Pipeline(cfg, shard_id=0, num_shards=2)
    p1 = Pipeline(cfg, shard_id=1, num_shards=2)
    b0, b1 = p0(), p1()
    assert b0["tokens"].shape == (4, 32)
    assert b0["labels"].shape == (4, 32)
    # disjoint shards draw different data
    assert not np.array_equal(np.asarray(b0["tokens"]),
                              np.asarray(b1["tokens"]))


def test_pipeline_codebooks_and_patches():
    cfg = DataConfig(batch_size=2, seq_len=16, vocab_size=64,
                     selection="none", num_codebooks=4)
    b = Pipeline(cfg)()
    assert b["tokens"].shape == (2, 16, 4)
    cfg2 = DataConfig(batch_size=2, seq_len=16, vocab_size=64,
                      selection="none", patch_count=4, d_model=32)
    b2 = Pipeline(cfg2)()
    assert b2["patches"].shape == (2, 4, 32)


def test_ss_selection_beats_uniform_coverage():
    cfg = DataConfig(batch_size=8, seq_len=48, vocab_size=499,
                     pool_factor=6, feature_dim=128, dup_frac=0.5)
    q = selection_quality(cfg, steps=2)
    assert q["ss"] >= q["uniform"], q
    assert q["ss"] >= 0.95 * q["greedy"], q


def test_engine_generate_shapes():
    cfg = configs.smoke("qwen3-4b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(max_len=48))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    out, cache = eng.generate(toks, 6)
    assert out.shape == (2, 6)
    assert jnp.all(out >= 0) and jnp.all(out < cfg.vocab_size)
    # sampled generation too
    eng2 = Engine(cfg, params, ServeConfig(max_len=48, temperature=0.8,
                                           top_k=10))
    out2, _ = eng2.generate(toks, 4, key=jax.random.PRNGKey(2))
    assert out2.shape == (2, 4)


def test_kv_pruning_end_to_end():
    cfg = configs.smoke("llama3.2-3b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S, budget = 2, 32, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    lg, cache = prefill(cfg, params, toks, max_len=S + 8)
    pruned, clen, kept = prune_cache(
        cfg, cache, S, KVSelectConfig(budget=budget), jax.random.PRNGKey(2))
    assert int(clen) == budget
    assert kept.shape == (B, budget)
    assert bool(jnp.all(kept < S)) and bool(jnp.all(kept >= 0))
    # rows remain strictly sorted (valid compaction)
    assert bool(jnp.all(jnp.diff(kept, axis=1) > 0))
    nxt = jnp.argmax(lg, -1).astype(jnp.int32)
    out, _ = decode_step(cfg, params, nxt, pruned, clen, pos=jnp.int32(S))
    assert jnp.isfinite(out).all()
    # pruned-cache decode approximates the full-cache decode better than
    # noise: correlation of logits should be clearly positive
    ref, _ = decode_step(cfg, params, nxt, cache, jnp.int32(S))
    c = np.corrcoef(np.asarray(ref).ravel(), np.asarray(out).ravel())[0, 1]
    assert c > 0.5, c
