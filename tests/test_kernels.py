"""Pallas kernel tests (deliverable c): shape/dtype sweeps in interpret mode
against the pure-jnp oracles in ref.py, plus integration through the backend
dispatch layer (ops.py / ss_sparsify(backend="pallas")).  Covers the
feature-coverage kernels (with and without feat_w feature weights) and the
facility-location divergence kernel across all phi kinds, non-multiple-of-tile
shapes, and r < 8 probe padding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FacilityLocation, FeatureCoverage, greedy
from repro.core.graph import divergence
from repro.core.sparsify import ss_sparsify
from repro.kernels import ops
from repro.kernels.feature_gains import feature_gains_kernel
from repro.kernels.fl_divergence import fl_divergence_kernel, fl_gains_kernel
from repro.kernels.ref import (
    feature_gains_ref,
    fl_divergence_ref,
    ss_divergence_ref,
)
from repro.kernels.ss_weights import ss_divergence_kernel


def _mk(seed, n, F, r, dtype):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    W = jax.random.uniform(ks[0], (n, F), dtype)
    CU = jax.random.uniform(ks[1], (r, F), jnp.float32)
    phi_cu = jnp.sum(jnp.sqrt(CU), axis=-1)
    resid = jax.random.uniform(ks[2], (r,), jnp.float32)
    return W, CU, phi_cu, resid


SHAPES = [(64, 32, 4), (130, 70, 9), (256, 128, 16), (513, 257, 33),
          (1024, 64, 40)]


@pytest.mark.parametrize("n,F,r", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("phi", ["sqrt", "log1p"])
def test_ss_divergence_kernel_matches_ref(n, F, r, dtype, phi):
    W, CU, phi_cu, resid = _mk(0, n, F, r, dtype)
    if phi == "log1p":
        phi_cu = jnp.sum(jnp.log1p(CU), axis=-1)
    ref = ss_divergence_ref(W, CU, phi_cu, resid, None, phi)
    out = ss_divergence_kernel(W, CU, phi_cu, resid, None, phi=phi,
                               interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("n,F", [(64, 32), (130, 70), (512, 256), (1000, 100)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_feature_gains_kernel_matches_ref(n, F, dtype):
    key = jax.random.PRNGKey(1)
    W = jax.random.uniform(key, (n, F), dtype)
    c = jax.random.uniform(jax.random.fold_in(key, 1), (F,))
    phic = jnp.sum(jnp.sqrt(c))
    ref = feature_gains_ref(W, c, phic, None, "sqrt")
    out = feature_gains_kernel(W, c, phic, None, phi="sqrt", interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


PHI_FW = {
    "sqrt": jnp.sqrt,
    "log1p": jnp.log1p,
    "setcover": lambda c: jnp.minimum(c, 1.0),
    "linear": lambda c: c,
}


@pytest.mark.parametrize("phi", sorted(PHI_FW) + ["satcov"])
@pytest.mark.parametrize("n,F,r", [(130, 70, 9), (256, 128, 3), (513, 257, 16)])
def test_ss_divergence_kernel_feat_w(n, F, r, phi):
    """feat_w rides through the phi-reduction for every phi kind (and r < 8
    exercises the probe-chunk pad rows)."""
    W, CU, _, resid = _mk(6, n, F, r, jnp.float32)
    fw = jnp.linspace(0.5, 1.5, F)
    if phi == "satcov":
        cap = 0.2 * jnp.sum(W, axis=0)
        phi_cu = jnp.sum(jnp.minimum(CU, cap) * fw, axis=-1)
    else:
        cap = None
        phi_cu = jnp.sum(PHI_FW[phi](CU) * fw, axis=-1)
    ref = ss_divergence_ref(W, CU, phi_cu, resid, cap, phi, fw)
    out = ss_divergence_kernel(W, CU, phi_cu, resid, cap, fw, phi=phi,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("phi", sorted(PHI_FW))
@pytest.mark.parametrize("n,F", [(130, 70), (512, 256)])
def test_feature_gains_kernel_feat_w(n, F, phi):
    key = jax.random.PRNGKey(9)
    W = jax.random.uniform(key, (n, F))
    c = jax.random.uniform(jax.random.fold_in(key, 1), (F,))
    fw = jnp.linspace(0.25, 2.0, F)
    phic = jnp.sum(PHI_FW[phi](c) * fw)
    ref = feature_gains_ref(W, c, phic, None, phi, fw)
    out = feature_gains_kernel(W, c, phic, None, fw, phi=phi, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_satcov_cap_path():
    n, F, r = 128, 64, 8
    W, CU, _, resid = _mk(2, n, F, r, jnp.float32)
    cap = 0.2 * jnp.sum(W, axis=0)
    phi_cu = jnp.sum(jnp.minimum(CU, cap), axis=-1)
    ref = ss_divergence_ref(W, CU, phi_cu, resid, cap, "satcov")
    out = ss_divergence_kernel(W, CU, phi_cu, resid, cap, phi="satcov",
                               interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_ops_divergence_matches_graph():
    """Kernel-backed divergence == core.graph.divergence on live candidates."""
    key = jax.random.PRNGKey(3)
    W = jax.random.uniform(key, (200, 64))
    fn = FeatureCoverage(W=W, phi="sqrt")
    probes = jnp.asarray([3, 77, 150])
    residual = fn.residual_gains()
    ref = divergence(fn, probes, residual=residual)
    out = ops.ss_divergence(fn, probes, residual)
    mask = jnp.ones((200,), bool).at[probes].set(False)
    np.testing.assert_allclose(np.asarray(out)[np.asarray(mask)],
                               np.asarray(ref)[np.asarray(mask)],
                               rtol=1e-4, atol=1e-4)


def test_ss_sparsify_kernel_path_equivalent_quality():
    key = jax.random.PRNGKey(4)
    W = jax.random.uniform(key, (512, 128))
    fn = FeatureCoverage(W=W, phi="sqrt")
    ss_ref = ss_sparsify(fn, key, r=6, c=8.0)
    ss_ker = ss_sparsify(fn, key, r=6, c=8.0, backend="pallas")
    f_ref = greedy(fn, 8, alive=ss_ref.vprime).value
    f_ker = greedy(fn, 8, alive=ss_ker.vprime).value
    # same PRNG stream => identical probe sets; divergences agree to fp error
    assert abs(float(f_ref) - float(f_ker)) / float(f_ref) < 1e-3


def test_feature_gains_integration_with_greedy():
    key = jax.random.PRNGKey(5)
    W = jax.random.uniform(key, (300, 80))
    fn = FeatureCoverage(W=W, phi="sqrt")
    state = fn.add_many(fn.empty_state(), jnp.arange(300) < 5)
    ref = fn.gains(state)
    out = ops.feature_gains(fn, state)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------- facility location kernel ----
def _mk_fl(seed, n, d=12, kernel="cosine"):
    X = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    return FacilityLocation.from_features(X, kernel=kernel)


# non-multiple-of-tile candidate/served dims; r < 8 exercises probe padding
FL_SHAPES = [(64, 3), (130, 5), (256, 16), (313, 9), (520, 24)]


@pytest.mark.parametrize("n,r", FL_SHAPES)
@pytest.mark.parametrize("kernel", ["cosine", "rbf"])
def test_fl_divergence_kernel_matches_ref(n, r, kernel):
    fn = _mk_fl(0, n, kernel=kernel)
    probes = jnp.arange(0, n, max(1, n // r))[:r]
    MU = jnp.maximum(fn.sim[:, probes].T, 0.0)
    resid = fn.residual_gains()[probes]
    ref = fl_divergence_ref(fn.sim, MU, resid)
    out = fl_divergence_kernel(fn.sim, MU, resid, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_fl_divergence_kernel_small_blocks():
    """Multi-tile grid on a small problem (block sizes below the defaults)."""
    fn = _mk_fl(1, 384)
    probes = jnp.asarray([0, 57, 200, 383])
    MU = jnp.maximum(fn.sim[:, probes].T, 0.0)
    resid = fn.residual_gains()[probes]
    ref = fl_divergence_ref(fn.sim, MU, resid)
    out = fl_divergence_kernel(fn.sim, MU, resid,
                               bn=128, bi=128, probe_chunk=4, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", [100, 256])
def test_fl_gains_kernel_matches_oracle(n):
    """fl_gains_kernel (single-probe divergence instance) == fn.gains."""
    fn = _mk_fl(2, n)
    state = fn.add_many(fn.empty_state(), jnp.arange(n) % 7 == 0)
    ref = fn.gains(state)
    out = fl_gains_kernel(fn.sim, state, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_fl_ops_divergence_matches_graph():
    """Kernel-backed FL divergence through the public ops entry point ==
    core.graph.divergence on live candidates (incl. conditional state)."""
    fn = _mk_fl(3, 200)
    probes = jnp.asarray([3, 77, 150])
    state = fn.add_many(fn.empty_state(), jnp.arange(200) < 5)
    residual = fn.residual_gains()
    ref = divergence(fn, probes, residual=residual, state=state)
    out = ops.ss_divergence(fn, probes, residual, state=state)
    mask = np.ones((200,), bool)
    mask[np.asarray(probes)] = False
    np.testing.assert_allclose(np.asarray(out)[mask], np.asarray(ref)[mask],
                               rtol=1e-4, atol=1e-4)


def test_fl_ss_sparsify_kernel_path_equivalent_quality():
    key = jax.random.PRNGKey(11)
    fn = _mk_fl(4, 512)
    ss_ref = ss_sparsify(fn, key, r=6, c=8.0)
    ss_ker = ss_sparsify(fn, key, r=6, c=8.0, backend="pallas")
    f_ref = greedy(fn, 8, alive=ss_ref.vprime).value
    f_ker = greedy(fn, 8, alive=ss_ker.vprime).value
    assert abs(float(f_ref) - float(f_ker)) / float(f_ref) < 1e-3


@pytest.mark.parametrize("S,hd,bq,bk", [(128, 64, 64, 64), (256, 128, 128, 64),
                                        (96, 32, 64, 32)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 32), (False, 0)])
def test_flash_attention_matches_ref(S, hd, bq, bk, causal, window):
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_attention_ref

    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 3)
    BH = 4
    q = jax.random.normal(ks[0], (BH, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (BH, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (BH, S, hd), jnp.float32)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          bq=bq, bk=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_matches_blockwise():
    """The Pallas kernel and the XLA blockwise path agree (same math)."""
    from repro.kernels.flash_attention import flash_attention
    from repro.models.attention import blockwise_attention

    key = jax.random.PRNGKey(8)
    B, S, H, KV, hd = 2, 128, 4, 2, 32
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    ref = blockwise_attention(q, k, v, causal=True, block_q=64, block_k=64)
    # expand kv to H heads and flatten (B, H) for the kernel
    head_map = np.arange(H) // (H // KV)
    kx = jnp.take(k, head_map, axis=2)
    vx = jnp.take(v, head_map, axis=2)
    fl = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    out = flash_attention(fl(q), fl(kx), fl(vx), causal=True,
                          bq=64, bk=64, interpret=True)
    out = out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
