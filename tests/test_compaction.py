"""Shrink-aware SS execution: the bucket schedule, the compacted divergence
dispatch (`divergence_compact` through every backend), and compacted-vs-
uncompacted SSResult parity on oracle / pallas / sharded.

The contract under test (docs/backends.md "Live-set compaction"): compaction
is a pure execution-strategy change — under the same PRNG key the compacted
loop must produce the *identical* retained set (``vprime``) and certificate
(``eps_hat``) as the full-width loop, on every backend, including ground-set
sizes that are not multiples of the kernel tile.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FacilityLocation,
    FeatureCoverage,
    PallasBackend,
    bucket_schedule,
    divergence,
    divergence_compact,
    get_backend,
    predicted_live_counts,
    probe_count,
    ss_sparsify,
)
from repro.core.sparsify import max_rounds


def make_fc(seed=0, n=300, F=48, phi="sqrt", feat_w=False):
    key = jax.random.PRNGKey(seed)
    W = jax.random.uniform(key, (n, F))
    fw = jnp.linspace(0.5, 1.5, F) if feat_w else None
    return FeatureCoverage(W=W, feat_w=fw, phi=phi)


def make_fl(seed=0, n=300, d=12):
    X = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    return FacilityLocation.from_features(X, kernel="cosine")


# ------------------------------------------------------- bucket schedule ----
def test_bucket_schedule_shape_properties():
    for n in (128, 300, 2048, 65536):
        buckets = bucket_schedule(n, c=8.0, tile=128)
        assert buckets[0] == n                       # full width first
        assert list(buckets) == sorted(set(buckets), reverse=True)
        for b in buckets:
            assert b == n or b % 128 == 0            # tile-aligned (or full)
            assert 0 < b <= n


def test_bucket_schedule_tracks_geometric_shrink():
    import math

    n, c = 65536, 8.0
    buckets = bucket_schedule(n, c=c, tile=128)
    # every geometric live width ceil(n / c^{j/2}) has a bucket that fits it
    # with at most one tile of slack (the round-up) — the schedule never
    # forces a round onto a grossly oversized grid
    j = 0
    while True:
        raw = math.ceil(n / (math.sqrt(c) ** j))
        fit = min(b for b in buckets if b >= raw)
        assert fit <= min(n, ((raw + 127) // 128) * 128)
        if raw <= 128:
            break
        j += 1


def test_bucket_schedule_rejects_degenerate_params():
    """c <= 1 means no shrink (the schedule would never terminate) and a
    non-positive tile can't align a grid — both must fail loudly."""
    with pytest.raises(ValueError):
        bucket_schedule(1024, c=1.0)
    with pytest.raises(ValueError):
        bucket_schedule(1024, c=0.5)
    with pytest.raises(ValueError):
        bucket_schedule(1024, c=8.0, tile=0)


def test_alive_trace_matches_predicted_live_counts():
    """The bucket schedule is sized from the same deterministic shrink
    recurrence the loop executes — alive_trace must match it exactly."""
    for n, r, c in ((2048, 8, 8.0), (1024, 6, 8.0), (512, 8, 4.0)):
        fn = make_fc(1, n=n, F=16)
        ss = ss_sparsify(fn, jax.random.PRNGKey(0), r=r, c=c)
        trace = [int(t) for t in np.asarray(ss.alive_trace) if t >= 0]
        assert trace == predicted_live_counts(n, r, c)


def test_bucket_schedule_covers_every_round_width():
    """Round j's compact buffer holds live_{j-1} - m candidates; the chosen
    bucket (smallest >= count) must always exist."""
    n, r, c = 4096, 8, 8.0
    buckets = bucket_schedule(n, c=c)
    m = min(probe_count(n, r), n)
    counts = [n] + predicted_live_counts(n, r, c)
    for prev in counts[:-1]:
        width = prev - m                      # live set at divergence time
        assert any(b >= width for b in buckets)


# ------------------------------------------- divergence_compact dispatch ----
@pytest.mark.parametrize("mk", [make_fc, make_fl])
@pytest.mark.parametrize("backend", ["oracle", "pallas"])
def test_divergence_compact_matches_full_gather(mk, backend):
    fn = mk()
    be = (PallasBackend(interpret=True) if backend == "pallas"
          else get_backend("oracle"))
    probes = jnp.asarray([3, 50, 111, 166])
    residual = fn.residual_gains()
    cand_idx = jnp.asarray([0, 7, 64, 65, 128, 200, 299])
    full = divergence(fn, probes, residual=residual)
    out = be.divergence_compact(fn, probes, cand_idx, residual=residual)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(full)[np.asarray(cand_idx)],
        rtol=1e-5, atol=1e-5,
    )


def test_divergence_compact_state_and_probe_mask():
    fn = make_fc(2)
    state = fn.add_many(fn.empty_state(), jnp.arange(fn.n) < 7)
    probes = jnp.asarray([20, 90, 150])
    mask = jnp.asarray([True, False, True])
    cand_idx = jnp.asarray([1, 33, 77, 240])
    residual = fn.residual_gains()
    ref = divergence(fn, probes, probe_mask=mask, residual=residual,
                     state=state)
    for be in (get_backend("oracle"), PallasBackend(interpret=True)):
        out = be.divergence_compact(
            fn, probes, cand_idx, probe_mask=mask, residual=residual,
            state=state,
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref)[np.asarray(cand_idx)],
            rtol=1e-4, atol=1e-4,
        )


def test_pairwise_gains_compact_default_is_gather():
    """The base-class fallback (full-width compute + gather) keeps
    out-of-tree objectives correct on the compacted path, and the shipped
    overrides agree with it."""
    from repro.core.functions import SubmodularFunction

    fn = make_fc(3, n=120, F=16)
    probes = jnp.asarray([5, 60])
    cand_idx = jnp.asarray([2, 50, 119])
    ref = fn.pairwise_gains(probes)[:, np.asarray(cand_idx)]
    out = SubmodularFunction.pairwise_gains_compact(fn, probes, cand_idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref))
    np.testing.assert_allclose(
        np.asarray(fn.pairwise_gains_compact(probes, cand_idx)),
        np.asarray(ref), rtol=1e-5, atol=1e-5,
    )


# ------------------------------------------- compact vs full loop parity ----
OBJECTIVES = {
    "fc": lambda n: make_fc(0, n=n, F=32),
    "fc_featw": lambda n: make_fc(1, n=n, F=32, feat_w=True),
    "fc_satcov": lambda n: FeatureCoverage(
        W=jax.random.uniform(jax.random.PRNGKey(2), (n, 32)),
        phi="satcov", alpha=0.3),
    "fl": lambda n: make_fl(3, n=n),
}


@pytest.mark.parametrize("name", sorted(OBJECTIVES))
@pytest.mark.parametrize("backend", ["oracle", "pallas", "sharded"])
def test_compact_and_full_vprime_identical(name, backend):
    """Acceptance: compacted and uncompacted SS produce identical vprime
    masks (and eps_hat) under the same PRNG key on all three backends."""
    fn = OBJECTIVES[name](256)
    be = (PallasBackend(interpret=True) if backend == "pallas" else backend)
    key = jax.random.PRNGKey(7)
    ss_c = ss_sparsify(fn, key, r=6, c=8.0, backend=be, compact=True)
    ss_u = ss_sparsify(fn, key, r=6, c=8.0, backend=be, compact=False)
    assert bool(jnp.all(ss_c.vprime == ss_u.vprime))
    assert int(ss_c.rounds) == int(ss_u.rounds)
    np.testing.assert_allclose(
        float(ss_c.eps_hat), float(ss_u.eps_hat), rtol=1e-6
    )
    assert 0 < int(jnp.sum(ss_c.vprime)) < fn.n


@pytest.mark.parametrize("n", [200, 300, 333])
@pytest.mark.parametrize("backend", ["oracle", "pallas"])
def test_compact_parity_non_tile_multiple_sizes(n, backend):
    """Ground sets that are not multiples of the 128 tile: the first bucket
    is clamped to n, later ones are tile-rounded — parity must still be
    exact."""
    fn = make_fc(5, n=n, F=24)
    assert bucket_schedule(n)[0] == n
    be = (PallasBackend(interpret=True) if backend == "pallas" else backend)
    key = jax.random.PRNGKey(9)
    ss_c = ss_sparsify(fn, key, r=6, c=8.0, backend=be, compact=True)
    ss_u = ss_sparsify(fn, key, r=6, c=8.0, backend=be, compact=False)
    assert bool(jnp.all(ss_c.vprime == ss_u.vprime))


def test_compact_importance_and_conditional_state():
    """The compacted loop composes with §3.4 importance sampling and
    conditional SS (state != empty)."""
    fn = make_fc(6, n=256, F=32)
    key = jax.random.PRNGKey(3)
    for kw in (dict(importance=True),
               dict(state=fn.add_many(fn.empty_state(),
                                      jnp.arange(fn.n) < 5))):
        ss_c = ss_sparsify(fn, key, r=6, c=8.0, compact=True, **kw)
        ss_u = ss_sparsify(fn, key, r=6, c=8.0, compact=False, **kw)
        assert bool(jnp.all(ss_c.vprime == ss_u.vprime))


def test_compact_respects_initial_alive():
    fn = make_fc(8, n=256, F=16)
    alive = jnp.arange(256) < 100
    ss = ss_sparsify(fn, jax.random.PRNGKey(0), alive=alive, compact=True)
    assert not bool(jnp.any(ss.vprime[100:]))


# --------------------------------------------------- compact kernel path ----
def test_kernel_cand_idx_paths_match_full():
    """The three kernel families' compact-candidate grids equal the gathered
    full grid (interpret mode)."""
    from repro.kernels.feature_gains import feature_gains_kernel
    from repro.kernels.fl_divergence import fl_divergence_kernel
    from repro.kernels.ss_weights import ss_divergence_kernel

    key = jax.random.PRNGKey(0)
    n, F, r, k = 384, 64, 12, 150          # k deliberately not tile-aligned
    cand_idx = jax.random.permutation(jax.random.fold_in(key, 1), n)[:k]

    W = jax.random.uniform(key, (n, F))
    CU = jax.random.uniform(jax.random.fold_in(key, 2), (r, F))
    phi_cu = jnp.sum(jnp.sqrt(CU), axis=-1)
    resid = jax.random.uniform(jax.random.fold_in(key, 3), (r,))
    full = ss_divergence_kernel(W, CU, phi_cu, resid, interpret=True)
    out = ss_divergence_kernel(W, CU, phi_cu, resid, None, None, cand_idx,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(full)[np.asarray(cand_idx)])

    sim = jnp.maximum(jax.random.normal(jax.random.fold_in(key, 4), (n, n)),
                      0.0)
    MU = jnp.maximum(sim[:, :r].T, 0.0)
    fl_full = fl_divergence_kernel(sim, MU, resid, interpret=True)
    fl_out = fl_divergence_kernel(sim, MU, resid, cand_idx, interpret=True)
    np.testing.assert_allclose(np.asarray(fl_out),
                               np.asarray(fl_full)[np.asarray(cand_idx)])

    c = jax.random.uniform(jax.random.fold_in(key, 5), (F,))
    phic = jnp.sum(jnp.sqrt(c))
    fg_full = feature_gains_kernel(W, c, phic, interpret=True)
    fg_out = feature_gains_kernel(W, c, phic, None, None, cand_idx,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(fg_out),
                               np.asarray(fg_full)[np.asarray(cand_idx)])


# ------------------------------------------------- postreduce static bound --
def test_postreduce_static_bound_fits_vprime():
    """The O(log^2 n) slot bound (m * (max_rounds + 1)) always covers |V'|,
    so the default postreduce needs no host sync."""
    from repro.core.sparsify import postreduce

    for n, r, c in ((300, 8, 8.0), (1024, 6, 8.0)):
        fn = make_fc(10, n=n, F=24)
        ss = ss_sparsify(fn, jax.random.PRNGKey(0), r=r, c=c)
        m = min(probe_count(n, r), n)
        bound = m * (max_rounds(n, r, c) + 1)
        assert int(jnp.sum(ss.vprime)) <= bound
        new_vp = postreduce(fn, ss, float(ss.eps_hat) + 1e-3,
                            jax.random.PRNGKey(1), r=r, c=c)
        assert bool(jnp.all(~new_vp | ss.vprime))
        assert 0 < int(jnp.sum(new_vp)) <= int(jnp.sum(ss.vprime))


def test_postreduce_raises_on_truncating_derived_bound():
    """When the derived default slot bound (sized from postreduce's r/c, not
    the SS run's) is smaller than |V'|, jnp.where would silently drop V'
    members — the default path must fail loudly instead.  An explicit int
    bound stays trusted/unchecked (the documented no-sync contract)."""
    from repro.core.sparsify import SSResult, postreduce

    n = 32768
    fn = make_fc(12, n=n, F=4)
    m = min(probe_count(n, 8), n)
    bound = m * (max_rounds(n, 8, 8.0) + 1)
    assert bound < n
    # An SSResult whose V' exceeds the default-r/c bound (as a run with much
    # larger r would produce).
    big = SSResult(
        vprime=jnp.arange(n) < bound + 7,
        divergence=jnp.zeros((n,)),
        eps_hat=jnp.float32(0.0),
        rounds=jnp.int32(1),
        alive_trace=jnp.full((1,), -1, jnp.int32),
    )
    with pytest.raises(ValueError, match="slot bound"):
        postreduce(fn, big, 0.1, jax.random.PRNGKey(1))


def test_postreduce_exact_optin_matches_default():
    from repro.core.sparsify import postreduce

    fn = make_fc(11, n=200, F=24)
    ss = ss_sparsify(fn, jax.random.PRNGKey(0), r=6, c=8.0)
    eps = float(ss.eps_hat) + 1e-3
    vp_static = postreduce(fn, ss, eps, jax.random.PRNGKey(2), r=6, c=8.0)
    vp_exact = postreduce(fn, ss, eps, jax.random.PRNGKey(2),
                          max_members="exact")
    # both paths must return valid nonempty subsets of V' (the slot counts
    # differ, so the randomized reductions need not pick identical members)
    assert bool(jnp.all(~vp_static | ss.vprime))
    assert bool(jnp.all(~vp_exact | ss.vprime))
    assert int(jnp.sum(vp_static)) > 0 and int(jnp.sum(vp_exact)) > 0
