"""Distribution tests: these need >1 device, so each runs in a subprocess
with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main pytest
process keeps the default 1 CPU device, per the dry-run isolation rule)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 520) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


def test_sharded_ss_matches_full_greedy():
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.core.distributed import summarize_sharded
        from repro.core import FeatureCoverage, greedy
        from repro.compat import make_mesh
        from repro.data import news_day

        W = news_day(0, 1024, 128)
        fn = FeatureCoverage(W=jnp.asarray(W), phi="sqrt")
        ref = greedy(fn, 8)
        mesh = make_mesh((8,), ("data",))
        sel, val, vp, eps = summarize_sharded(W, 8, jax.random.PRNGKey(0), mesh)
        ratio = float(val / ref.value)
        assert ratio > 0.95, ratio
        assert int(jnp.sum(vp)) < 1024
        print("RATIO", ratio)
    """)
    assert "RATIO" in out


def test_sharded_ss_hierarchical_pods():
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.core.distributed import summarize_sharded
        from repro.core import FeatureCoverage, greedy
        from repro.compat import make_mesh
        from repro.data import news_day

        W = news_day(1, 1024, 128)
        fn = FeatureCoverage(W=jnp.asarray(W), phi="sqrt")
        ref = greedy(fn, 8)
        mesh = make_mesh((2, 4), ("pod", "data"))
        sel, val, vp, eps = summarize_sharded(
            W, 8, jax.random.PRNGKey(0), mesh, pod_axis="pod")
        ratio = float(val / ref.value)
        assert ratio > 0.95, ratio
        print("OK", ratio)
    """)
    assert "OK" in out


def test_sharded_backend_facility_location_multidevice():
    """Acceptance: ss_sparsify(backend=...) runs FacilityLocation on a real
    multi-device CPU mesh through a ShardedBackend, and greedy on the sharded
    V' matches greedy on the oracle V' within 1e-3 relative."""
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.core import FacilityLocation, ShardedBackend, greedy, ss_sparsify
        from repro.compat import make_mesh

        X = jax.random.normal(jax.random.PRNGKey(1), (512, 16))
        fn = FacilityLocation.from_features(X, kernel="rbf")
        key = jax.random.PRNGKey(0)
        be = ShardedBackend(mesh=make_mesh((8,), ("data",)))
        ss_s = ss_sparsify(fn, key, r=8, c=8.0, backend=be)
        ss_o = ss_sparsify(fn, key, r=8, c=8.0, backend="oracle")
        v_s = float(greedy(fn, 8, alive=ss_s.vprime).value)
        v_o = float(greedy(fn, 8, alive=ss_o.vprime).value)
        rel = abs(v_s - v_o) / v_o
        assert rel < 1e-3, (v_s, v_o, rel)
        assert int(jnp.sum(ss_s.vprime)) < 512
        print("FL_PARITY", rel)
    """)
    assert "FL_PARITY" in out


def test_sharded_backend_fl_stream_multidevice():
    """Matrix-free StreamingFacilityLocation on a real 8-device mesh: the
    row-sharded embedding hooks (replicated served rows, (k, n) coverage
    payloads) prune exactly like the dense column-sharded FacilityLocation
    on the same features/key, and per-shard residuals match the dense
    oracle."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import (FacilityLocation, ShardedBackend,
                                StreamingFacilityLocation, greedy, ss_sparsify)
        from repro.compat import make_mesh, shard_map
        from jax.sharding import PartitionSpec as P

        mesh = make_mesh((8,), ("data",))
        X = jax.random.normal(jax.random.PRNGKey(1), (512, 16))
        dense = FacilityLocation.from_features(X, kernel="cosine")
        sfl = StreamingFacilityLocation.from_features(X, kernel="cosine")

        # per-shard residuals == dense oracle residuals
        arrays, specs, rebuild = sfl.shard_pack(("data",))
        def res_kernel(*arrs):
            loc = rebuild(*arrs)
            return loc.shard_residuals(loc.shard_init("data"))
        res = shard_map(res_kernel, mesh=mesh, in_specs=specs,
                        out_specs=P("data"))(*arrays)
        np.testing.assert_allclose(np.asarray(res),
                                   np.asarray(dense.residual_gains()),
                                   rtol=1e-4, atol=1e-4)

        key = jax.random.PRNGKey(0)
        be = ShardedBackend(mesh=mesh)
        ss_s = ss_sparsify(sfl, key, r=8, c=8.0, backend=be)
        ss_d = ss_sparsify(dense, key, r=8, c=8.0, backend=be)
        assert 0 < int(jnp.sum(ss_s.vprime)) < 512
        assert bool(jnp.all(ss_s.vprime == ss_d.vprime))
        v_s = float(greedy(sfl, 8, alive=ss_s.vprime).value)
        v_d = float(greedy(dense, 8, alive=ss_d.vprime).value)
        rel = abs(v_s - v_d) / v_d
        assert rel < 1e-5, (v_s, v_d, rel)
        print("FL_STREAM_PARITY", rel)
    """)
    assert "FL_STREAM_PARITY" in out


def test_sharded_backend_objective_generic():
    """The sharded loop is objective-generic: both objectives run through the
    same shard_map kernel via their shard hooks, and per-shard residuals
    match the dense oracle exactly."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import FacilityLocation, FeatureCoverage
        from repro.core.distributed import ss_sparsify_sharded
        from repro.compat import make_mesh, shard_map
        from jax.sharding import PartitionSpec as P

        mesh = make_mesh((8,), ("data",))
        key = jax.random.PRNGKey(0)
        W = jax.random.uniform(key, (512, 64))
        fns = [FeatureCoverage(W=W, phi="sqrt"),
               FeatureCoverage(W=W, phi="satcov", alpha=0.3),
               FeatureCoverage(W=W, feat_w=jnp.linspace(0.5, 1.5, 64)),
               FacilityLocation.from_features(
                   jax.random.normal(key, (512, 8)), kernel="cosine")]
        for fn in fns:
            # per-shard residuals == dense residuals
            arrays, specs, rebuild = fn.shard_pack(("data",))
            def res_kernel(*arrs):
                loc = rebuild(*arrs)
                return loc.shard_residuals(loc.shard_init("data"))
            res = shard_map(res_kernel, mesh=mesh, in_specs=specs,
                            out_specs=P("data"))(*arrays)
            np.testing.assert_allclose(np.asarray(res),
                                       np.asarray(fn.residual_gains()),
                                       rtol=1e-4, atol=1e-4)
            # and the full sharded loop runs
            ss = ss_sparsify_sharded(fn, key, mesh)
            assert 0 < int(jnp.sum(ss.vprime)) < fn.n
        print("GENERIC_OK")
    """)
    assert "GENERIC_OK" in out


def test_sharded_stochastic_greedy_matches_dense_compact():
    """Acceptance: the distributed stochastic-greedy sampler (per-shard
    compact gains, replicated Gumbel frame, psum'd argmax) selects the
    *identical* set as the dense compact path under the same key, on a real
    8-device mesh, for both objective families — including the k > |alive|
    exhausted tail."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import (FacilityLocation, FeatureCoverage,
                                ShardedBackend, ss_sparsify, stochastic_greedy)
        from repro.compat import make_mesh

        mesh = make_mesh((8,), ("data",))
        be = ShardedBackend(mesh=mesh)
        key = jax.random.PRNGKey(0)
        fns = [FeatureCoverage(W=jax.random.uniform(key, (512, 64))),
               FacilityLocation.from_features(
                   jax.random.normal(key, (512, 16)), kernel="cosine")]
        for i, fn in enumerate(fns):
            alive = ss_sparsify(fn, jax.random.fold_in(key, i), r=6).vprime
            k2 = jax.random.PRNGKey(7 + i)
            dense = stochastic_greedy(fn, 10, k2, alive=alive,
                                      backend="oracle")
            shard = stochastic_greedy(fn, 10, k2, alive=alive, backend=be)
            assert (np.asarray(dense.selected)
                    == np.asarray(shard.selected)).all(), (
                dense.selected, shard.selected)
            np.testing.assert_allclose(np.asarray(dense.gains),
                                       np.asarray(shard.gains),
                                       rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(float(dense.value),
                                       float(shard.value), rtol=1e-5)
        # exhausted tail: k > |alive|
        fn = fns[0]
        small = jnp.arange(512) < 6
        k3 = jax.random.PRNGKey(3)
        dense = stochastic_greedy(fn, 9, k3, alive=small, backend="oracle")
        shard = stochastic_greedy(fn, 9, k3, alive=small, backend=be)
        assert (np.asarray(dense.selected)
                == np.asarray(shard.selected)).all()
        # ground frame: a live count that fits no sub-n bucket makes the
        # dense plan full-width; the sharded sampler must match that too
        big = jax.random.permutation(jax.random.PRNGKey(4),
                                     jnp.arange(512) < 400)
        dense = stochastic_greedy(fn, 10, k3, alive=big, backend="oracle")
        shard = stochastic_greedy(fn, 10, k3, alive=big, backend=be)
        assert (np.asarray(dense.selected)
                == np.asarray(shard.selected)).all()
        print("STOCH_PARITY")
    """)
    assert "STOCH_PARITY" in out


def test_sharded_exact_greedy_matches_dense():
    """Acceptance: greedy(backend="sharded") runs the distributed exact
    argmax (psum'd max-gain, min-position tie-break) over the same compact
    frame as the stochastic sampler, and is *selection-identical* to the
    dense compact path — both objective families, full-width / exhausted /
    conditional-state edges, on a real 8-device mesh."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import (FacilityLocation, FeatureCoverage,
                                ShardedBackend, greedy, ss_sparsify)
        from repro.compat import make_mesh

        mesh = make_mesh((8,), ("data",))
        be = ShardedBackend(mesh=mesh)
        key = jax.random.PRNGKey(0)
        fns = [FeatureCoverage(W=jax.random.uniform(key, (512, 64))),
               FacilityLocation.from_features(
                   jax.random.normal(key, (512, 16)), kernel="cosine")]
        def check(fn, k, **kw):
            d = greedy(fn, k, backend="oracle", **kw)
            sh = greedy(fn, k, backend=be, **kw)
            assert (np.asarray(d.selected) == np.asarray(sh.selected)).all(), (
                d.selected, sh.selected)
            np.testing.assert_allclose(np.asarray(d.gains),
                                       np.asarray(sh.gains),
                                       rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(float(d.value), float(sh.value),
                                       rtol=1e-5)
        for i, fn in enumerate(fns):
            alive = ss_sparsify(fn, jax.random.fold_in(key, i), r=6).vprime
            check(fn, 10, alive=alive)          # compact frame
        fn = fns[0]
        check(fn, 6)                            # full width, alive=None
        check(fn, 7, alive=jnp.arange(512) < 4) # exhausted tail
        st = fn.add_many(fn.empty_state(), jnp.arange(512) < 3)
        alive = ss_sparsify(fn, key, r=6).vprime
        check(fn, 5, alive=alive, state=st)     # conditional start
        print("EXACT_PARITY")
    """)
    assert "EXACT_PARITY" in out


def test_sharded_ss_conditional_and_importance():
    """Conditional (state != empty) and importance-sampling SS run sharded
    (ROADMAP open item) with quality parity against the oracle backend: the
    greedy value on the sharded V' matches the oracle V' value closely
    (different probe streams — sampling variance, not arithmetic)."""
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.core import (FacilityLocation, FeatureCoverage,
                                ShardedBackend, greedy, ss_sparsify)
        from repro.compat import make_mesh

        mesh = make_mesh((8,), ("data",))
        be = ShardedBackend(mesh=mesh)
        key = jax.random.PRNGKey(0)
        fns = [FeatureCoverage(W=jax.random.uniform(key, (512, 64))),
               FacilityLocation.from_features(
                   jax.random.normal(key, (512, 16)), kernel="cosine")]
        for i, fn in enumerate(fns):
            st = fn.add_many(fn.empty_state(), jnp.arange(512) < 4)
            ss_s = ss_sparsify(fn, key, backend=be, state=st)
            ss_o = ss_sparsify(fn, key, backend="oracle", state=st)
            assert 0 < int(jnp.sum(ss_s.vprime)) < 512
            v_s = float(greedy(fn, 8, alive=ss_s.vprime, state=st).value)
            v_o = float(greedy(fn, 8, alive=ss_o.vprime, state=st).value)
            rel = abs(v_s - v_o) / abs(v_o)
            assert rel < 2e-2, (i, "state", v_s, v_o)
            ss_s = ss_sparsify(fn, key, backend=be, importance=True)
            ss_o = ss_sparsify(fn, key, backend="oracle", importance=True)
            v_s = float(greedy(fn, 8, alive=ss_s.vprime).value)
            v_o = float(greedy(fn, 8, alive=ss_o.vprime).value)
            rel = abs(v_s - v_o) / abs(v_o)
            assert rel < 2e-2, (i, "importance", v_s, v_o)
        print("COND_IMP_OK")
    """)
    assert "COND_IMP_OK" in out


@pytest.mark.xfail(
    strict=False,
    reason="container jax (0.4.37) lacks the partial-manual shard_map "
    "axis-type introspection the compressed pod train step needs "
    "(pre-existing since PR 1, see CHANGES.md); passes on newer jax",
)
def test_compressed_pod_training_converges():
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro import configs
        from repro.train import (TrainConfig, make_train_state, CompressConfig,
                                 init_error_state, make_compressed_train_step)

        from repro.compat import make_mesh
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = configs.smoke("llama3.2-3b")
        tc = TrainConfig(optimizer="adamw", lr=1e-3, warmup_steps=1,
                         total_steps=20)
        cc = CompressConfig(ratio=0.1, block=64)
        state = make_train_state(jax.random.PRNGKey(0), cfg, tc)
        state["error"] = init_error_state(state["params"])
        from repro.compat import set_mesh
        with set_mesh(mesh):
            step = jax.jit(make_compressed_train_step(mesh, cfg, tc, cc))
            toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                      cfg.vocab_size)
            batch = {"tokens": toks, "labels": toks}
            losses = []
            for _ in range(6):
                state, m = step(state, batch)
                losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses
        assert 0.0 < float(m["compress_density"]) <= 0.15
        print("LOSSES", [round(l, 3) for l in losses])
    """)
    assert "LOSSES" in out


def test_sharded_train_step_on_mesh():
    """The production train step lowers, compiles AND RUNS on a 2x2 mesh
    with real (tiny) data — catches sharding bugs execution-side."""
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro import configs
        from repro.train import (TrainConfig, abstract_train_state,
                                 make_train_state, shard_train_step)
        from repro.launch.mesh import make_test_mesh

        mesh = make_test_mesh((2, 2), ("data", "model"))
        cfg = configs.smoke("olmoe-1b-7b")      # MoE: the hardest layout
        tc = TrainConfig(optimizer="adafactor", num_microbatches=2,
                         warmup_steps=1, total_steps=8, lr=1e-3)
        shape = abstract_train_state(cfg, tc)
        from repro.compat import set_mesh
        with set_mesh(mesh):
            fn, state_sh, batch_sh = shard_train_step(mesh, cfg, tc, shape)
            state = make_train_state(jax.random.PRNGKey(0), cfg, tc)
            state = jax.device_put(state, state_sh)
            toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                      cfg.vocab_size)
            batch = {"tokens": toks, "labels": toks}
            l0 = lf = None
            for _ in range(4):
                state, m = fn(state, batch)
                l0 = l0 if l0 is not None else float(m["loss"])
                lf = float(m["loss"])
        assert lf < l0, (l0, lf)
        print("OK", l0, "->", lf)
    """)
    assert "OK" in out


def test_dryrun_cell_small_mesh():
    """The dry-run machinery end-to-end on a (2,2,2) multi-pod mesh with a
    reduced shape table — validates lower+compile+analysis off the 512-dev
    path."""
    out = run_sub("""
        import jax
        from repro.launch.mesh import make_test_mesh
        from repro.launch import dryrun
        from repro.models.config import SHAPES, ShapeConfig

        SHAPES["decode_32k"] = ShapeConfig("decode_32k", 512, 8, "decode")
        SHAPES["train_4k"] = ShapeConfig("train_4k", 128, 8, "train")
        mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
        for arch, shape in [("recurrentgemma-2b", "decode_32k"),
                            ("qwen3-4b", "train_4k")]:
            rec = dryrun.run_cell(arch, shape, mesh, "test")
            assert rec["status"] == "ok"
            assert rec["cost"]["flops_per_chip"] > 0
            assert rec["roofline"]["dominant"] in ("compute", "memory",
                                                   "collective")
        print("CELLS OK")
    """, timeout=540)
    assert "CELLS OK" in out
