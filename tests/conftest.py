"""Repo-wide pytest wiring: a per-test wall-clock timeout so a wedged
flusher (or a hung device call) fails the test fast instead of hanging the
whole runner.

CI installs the real ``pytest-timeout`` plugin and the ``timeout`` ini in
pyproject.toml configures it.  The local container does not ship the
plugin, so when it is absent this conftest provides a minimal fallback
honoring the same ``timeout`` ini and ``@pytest.mark.timeout(...)`` marker:
SIGALRM-based, main-thread only, POSIX only — enough to break a test
blocked on a ``Condition``/``Event`` wait.  With the plugin installed this
entire module is a no-op (the plugin owns the option and the marker)."""

import importlib.util
import signal
import threading

import pytest

_HAVE_PLUGIN = importlib.util.find_spec("pytest_timeout") is not None

if not _HAVE_PLUGIN:

    def pytest_addoption(parser):
        parser.addini(
            "timeout",
            "per-test timeout in seconds (SIGALRM fallback; the real "
            "pytest-timeout plugin takes over when installed)",
            default=None,
        )

    def pytest_configure(config):
        config.addinivalue_line(
            "markers",
            "timeout(seconds): per-test wall-clock timeout override "
            "(fallback implementation when pytest-timeout is absent)",
        )

    def _timeout_for(item):
        marker = item.get_closest_marker("timeout")
        if marker is not None and marker.args:
            return float(marker.args[0])
        ini = item.config.getini("timeout")
        return float(ini) if ini else None

    @pytest.hookimpl(hookwrapper=True)
    def pytest_runtest_call(item):
        timeout = _timeout_for(item)
        usable = (
            timeout
            and hasattr(signal, "SIGALRM")
            and threading.current_thread() is threading.main_thread()
        )
        if not usable:
            yield
            return

        def _alarm(signum, frame):
            raise TimeoutError(
                f"test exceeded per-test timeout of {timeout}s "
                "(tests/conftest.py SIGALRM fallback)"
            )

        old = signal.signal(signal.SIGALRM, _alarm)
        signal.setitimer(signal.ITIMER_REAL, timeout)
        try:
            yield
        finally:
            signal.setitimer(signal.ITIMER_REAL, 0.0)
            signal.signal(signal.SIGALRM, old)
