"""Unit + property tests for the submodular objectives (repro.core.functions)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # skips @given tests only
from repro.core.functions import FacilityLocation, FeatureCoverage

jax.config.update("jax_enable_x64", False)


def make_fc(seed: int, n: int = 24, F: int = 12, phi: str = "sqrt") -> FeatureCoverage:
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    W = jax.random.uniform(k1, (n, F)) * (jax.random.uniform(k2, (n, F)) < 0.4)
    return FeatureCoverage(W=W, phi=phi)


def make_fl(seed: int, n: int = 20, d: int = 6) -> FacilityLocation:
    X = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    return FacilityLocation.from_features(X, kernel="rbf")


def brute_value(fn, idx_set):
    """f(S) by state construction — reference path."""
    state = fn.empty_state()
    for v in idx_set:
        state = fn.add(state, jnp.asarray(v))
    return float(fn.value(state))


ALL_FNS = [
    lambda s: make_fc(s, phi="sqrt"),
    lambda s: make_fc(s, phi="log1p"),
    lambda s: make_fc(s, phi="setcover"),
    lambda s: make_fc(s, phi="satcov"),
    lambda s: make_fl(s),
]


@pytest.mark.parametrize("mk", ALL_FNS)
def test_normalized(mk):
    fn = mk(0)
    assert abs(float(fn.value(fn.empty_state()))) < 1e-6


@pytest.mark.parametrize("mk", ALL_FNS)
@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_diminishing_returns(mk, data):
    """Property (paper Eq. 1): f(v|A) >= f(v|B) whenever A ⊆ B, v ∉ B."""
    seed = data.draw(st.integers(0, 5))
    fn = mk(seed)
    n = fn.n
    items = data.draw(
        st.lists(st.integers(0, n - 1), min_size=3, max_size=8, unique=True)
    )
    v, rest = items[0], items[1:]
    cut = data.draw(st.integers(0, len(rest)))
    A, B = rest[:cut], rest
    sA = fn.empty_state()
    for x in A:
        sA = fn.add(sA, jnp.asarray(x))
    sB = fn.empty_state()
    for x in B:
        sB = fn.add(sB, jnp.asarray(x))
    gA = float(fn.gains(sA)[v])
    gB = float(fn.gains(sB)[v])
    assert gA >= gB - 1e-4


@pytest.mark.parametrize("mk", ALL_FNS)
@given(data=st.data())
@settings(max_examples=20, deadline=None)
def test_monotone(mk, data):
    seed = data.draw(st.integers(0, 5))
    fn = mk(seed)
    items = data.draw(
        st.lists(st.integers(0, fn.n - 1), min_size=1, max_size=6, unique=True)
    )
    vals = [brute_value(fn, items[:i]) for i in range(len(items) + 1)]
    assert all(b >= a - 1e-4 for a, b in zip(vals, vals[1:]))


@pytest.mark.parametrize("mk", ALL_FNS)
def test_gains_match_value_delta(mk):
    """gains(state)[v] == f(S+v) - f(S) for every v."""
    fn = mk(3)
    S = [1, 5, 7]
    state = fn.empty_state()
    for v in S:
        state = fn.add(state, jnp.asarray(v))
    base = float(fn.value(state))
    g = np.asarray(fn.gains(state))
    for v in range(fn.n):
        direct = float(fn.value(fn.add(state, jnp.asarray(v)))) - base
        assert abs(g[v] - direct) < 1e-4, (v, g[v], direct)


@pytest.mark.parametrize("mk", ALL_FNS)
def test_pairwise_gains_match(mk):
    """pairwise_gains(probes)[i, v] == f(v | {probes[i]})."""
    fn = mk(4)
    probes = jnp.asarray([0, 3, 9])
    P = np.asarray(fn.pairwise_gains(probes))
    for i, u in enumerate([0, 3, 9]):
        su = fn.add(fn.empty_state(), jnp.asarray(u))
        g = np.asarray(fn.gains(su))
        keep = np.arange(fn.n) != u  # v == u: set semantics give exactly 0
        np.testing.assert_allclose(P[i][keep], g[keep], atol=1e-4)
        assert abs(P[i][u]) < 1e-5


@pytest.mark.parametrize("mk", ALL_FNS)
def test_residual_gains_match(mk):
    """residual_gains()[v] == f(V) - f(V \\ v)."""
    fn = mk(5)
    n = fn.n
    full = brute_value(fn, list(range(n)))
    res = np.asarray(fn.residual_gains())
    for v in range(0, n, 5):
        without = brute_value(fn, [x for x in range(n) if x != v])
        assert abs(res[v] - (full - without)) < 1e-3, v


@pytest.mark.parametrize("mk", ALL_FNS)
def test_add_many_matches_sequential(mk):
    fn = mk(6)
    mask = np.zeros((fn.n,), bool)
    mask[[2, 4, 8, 11]] = True
    st_seq = fn.empty_state()
    for v in [2, 4, 8, 11]:
        st_seq = fn.add(st_seq, jnp.asarray(v))
    st_many = fn.add_many(fn.empty_state(), jnp.asarray(mask))
    assert abs(float(fn.value(st_seq)) - float(fn.value(st_many))) < 1e-4


def test_conditional_pairwise_gains():
    """pairwise_gains with a state == f(v | S + u)."""
    fn = make_fc(7)
    S = [2, 6]
    state = fn.empty_state()
    for v in S:
        state = fn.add(state, jnp.asarray(v))
    probes = jnp.asarray([1, 4])
    P = np.asarray(fn.pairwise_gains(probes, state))
    for i, u in enumerate([1, 4]):
        su = fn.add(state, jnp.asarray(u))
        g = np.asarray(fn.gains(su))
        keep = np.arange(fn.n) != u  # diagonal: set semantics give exactly 0
        np.testing.assert_allclose(P[i][keep], g[keep], atol=1e-4)


def test_linear_phi_is_modular():
    """phi='linear' makes the function modular: f(v|S) independent of S."""
    fn = make_fc(8, phi="linear")
    s0 = fn.empty_state()
    s1 = fn.add(fn.add(s0, jnp.asarray(0)), jnp.asarray(1))
    np.testing.assert_allclose(
        np.asarray(fn.gains(s0)), np.asarray(fn.gains(s1)), atol=1e-4
    )
