"""Per-architecture smoke tests (deliverable f): every assigned arch's
REDUCED config runs a forward + train step on CPU with correct output shapes
and no NaNs, and its decode path is consistent with the full forward."""

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_params,
    lm_loss,
    prefill,
)
from repro.train import TrainConfig, make_train_state, make_train_step

ARCHS = list(configs.ARCHS)


def _batch(cfg, key, B=2, S=16):
    if cfg.num_codebooks > 1:
        toks = jax.random.randint(key, (B, S, cfg.num_codebooks), 0,
                                  cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.input_mode == "tokens+patches":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.smoke(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S = 2, 16
    batch = _batch(cfg, key, B, S)
    logits, aux = forward(cfg, params, batch["tokens"], batch.get("patches"))
    if cfg.num_codebooks > 1:
        assert logits.shape == (B, S, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = configs.smoke(arch)
    tc = TrainConfig(optimizer="adamw", lr=1e-3, warmup_steps=1,
                     total_steps=8)
    key = jax.random.PRNGKey(0)
    state = make_train_state(key, cfg, tc)
    step = jax.jit(make_train_step(cfg, tc))
    batch = _batch(cfg, key)
    l0 = lf = None
    for i in range(4):
        state, m = step(state, batch)
        assert jnp.isfinite(m["loss"]), arch
        assert jnp.isfinite(m["grad_norm"]), arch
        l0 = l0 if l0 is not None else float(m["loss"])
        lf = float(m["loss"])
    assert lf < l0, f"{arch}: loss did not decrease ({l0} -> {lf})"
    assert int(state["step"]) == 4


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = configs.smoke(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    B, S = 2, 12
    batch = _batch(cfg, key, B, S)
    toks = batch["tokens"]
    logits_full, _ = forward(cfg, params, toks, batch.get("patches"))
    if cfg.input_mode == "tokens+patches":
        return  # patch fusion has no incremental-decode analogue for prompts
    cache = init_cache(cfg, B, S + 4)
    outs = []
    for t in range(S):
        lg, cache = decode_step(
            cfg, params, toks[:, t : t + 1], cache, jnp.int32(t))
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    assert jnp.max(jnp.abs(dec - logits_full)) < 5e-2, arch


@pytest.mark.parametrize("arch", ["qwen2-7b", "recurrentgemma-2b",
                                  "mamba2-780m", "olmoe-1b-7b"])
def test_prefill_seeds_decode(arch):
    cfg = configs.smoke(arch)
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    B, S = 2, 16
    toks = _batch(cfg, key, B, S)["tokens"]
    lg_pre, cache = prefill(cfg, params, toks, max_len=S + 8)
    lg_full, _ = forward(cfg, params, toks)
    assert jnp.max(jnp.abs(lg_pre - lg_full[:, -1:])) < 5e-3

    # one decode step from the prefilled cache matches decode-from-scratch
    nxt = jnp.argmax(lg_pre, -1).astype(jnp.int32)
    if cfg.num_codebooks > 1:
        nxt = nxt.reshape(B, 1, cfg.num_codebooks)
    lg_a, _ = decode_step(cfg, params, nxt, cache, jnp.int32(S))
    cache2 = init_cache(cfg, B, S + 8)
    for t in range(S):
        _, cache2 = decode_step(cfg, params, toks[:, t : t + 1], cache2,
                                jnp.int32(t))
    lg_b, _ = decode_step(cfg, params, nxt, cache2, jnp.int32(S))
    assert jnp.max(jnp.abs(lg_a - lg_b)) < 5e-3


def test_param_counts_match_published():
    expected_b = {
        "internvl2-76b": (70.0, 72.0),    # backbone only (ViT stubbed)
        "mamba2-780m": (0.75, 0.82),
        "llama4-maverick-400b-a17b": (390.0, 405.0),
        "olmoe-1b-7b": (6.5, 7.2),
        "llama3.2-3b": (3.0, 3.4),
        "qwen3-4b": (3.8, 4.2),
        "starcoder2-3b": (2.8, 3.2),
        "qwen2-7b": (7.3, 7.9),
        "recurrentgemma-2b": (2.4, 2.9),
    }
    for arch, (lo, hi) in expected_b.items():
        n = configs.get(arch).param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo}, {hi}]"


def test_active_params_moe():
    llama4 = configs.get("llama4-maverick-400b-a17b")
    active = llama4.active_param_count() / 1e9
    assert 12.0 <= active <= 18.0  # ~17B incl. embeddings
    olmoe = configs.get("olmoe-1b-7b")
    assert 1.0 <= olmoe.active_param_count() / 1e9 <= 1.5


def test_cell_skip_rules():
    cells = list(configs.all_cells())
    assert len(cells) == 40
    skipped = [(a, s) for a, s, ok, _ in cells if not ok]
    assert all(s == "long_500k" for _, s in skipped)
    assert {a for a, _ in skipped} == {
        "internvl2-76b", "musicgen-large", "llama4-maverick-400b-a17b",
        "olmoe-1b-7b", "llama3.2-3b", "qwen3-4b", "starcoder2-3b", "qwen2-7b",
    }
    runnable_500k = [a for a, s, ok, _ in cells if ok and s == "long_500k"]
    assert set(runnable_500k) == {"mamba2-780m", "recurrentgemma-2b"}
