"""Backend dispatch layer tests: registry contract + numerical parity of the
oracle / pallas (interpret) / sharded execution backends on both objectives
and all phi variants.  Every shipped configuration — FeatureCoverage with and
without feat_w feature weights, and FacilityLocation — now has a fused
kernel, so the pallas legs exercise real kernels, never the oracle fallback
(test_pallas_hooks_no_fallback pins that).

Multi-device sharded parity lives in test_distributed.py (needs forced host
devices); here the sharded backend runs on the default single-device mesh —
same shard_map code path, collectives of size 1.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Backend,
    FacilityLocation,
    FeatureCoverage,
    OracleBackend,
    StreamingFacilityLocation,
    PallasBackend,
    ShardedBackend,
    available_backends,
    get_backend,
    greedy,
    register_backend,
    resolve_backend,
    ss_sparsify,
)
from repro.core.graph import divergence


def make_fc(seed=0, n=200, F=64, phi="sqrt", feat_w=False, alpha=0.2):
    key = jax.random.PRNGKey(seed)
    W = jax.random.uniform(key, (n, F))
    fw = jnp.linspace(0.5, 1.5, F) if feat_w else None
    return FeatureCoverage(W=W, feat_w=fw, phi=phi, alpha=alpha)


def make_fl(seed=0, n=200, d=12, kernel="cosine"):
    X = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    return FacilityLocation.from_features(X, kernel=kernel)


def make_sfl(seed=0, n=200, d=12, kernel="cosine"):
    X = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    return StreamingFacilityLocation.from_features(X, kernel=kernel)


OBJECTIVES = {
    "fc_sqrt": lambda: make_fc(phi="sqrt"),
    "fc_log1p": lambda: make_fc(phi="log1p"),
    "fc_setcover": lambda: make_fc(phi="setcover"),
    "fc_satcov": lambda: make_fc(phi="satcov", alpha=0.3),
    "fc_linear": lambda: make_fc(phi="linear"),
    "fc_featw": lambda: make_fc(phi="sqrt", feat_w=True),
    "fc_featw_log1p": lambda: make_fc(phi="log1p", feat_w=True),
    "fc_featw_satcov": lambda: make_fc(phi="satcov", feat_w=True, alpha=0.3),
    "fl": lambda: make_fl(),
    "fl_rbf": lambda: make_fl(kernel="rbf"),
    "fl_stream": lambda: make_sfl(),
}


# ------------------------------------------------------------- registry ----
def test_registry_contract(monkeypatch):
    assert {"oracle", "pallas", "sharded"} <= set(available_backends())
    assert isinstance(get_backend("oracle"), OracleBackend)
    assert isinstance(resolve_backend("pallas"), PallasBackend)
    # None resolves to the env default (the CI matrix sets it), else oracle.
    monkeypatch.delenv("REPRO_SS_BACKEND", raising=False)
    assert resolve_backend(None).name == "oracle"
    be = PallasBackend(interpret=True)
    assert resolve_backend(be) is be
    with pytest.raises(KeyError):
        get_backend("no-such-backend")
    with pytest.raises(TypeError):
        resolve_backend(123)


def test_registry_extension():
    class EchoBackend(OracleBackend):
        name = "echo"

    register_backend("echo", EchoBackend)
    try:
        assert isinstance(get_backend("echo"), EchoBackend)
        assert "echo" in available_backends()
    finally:
        import repro.core.backend as B

        B._REGISTRY.pop("echo", None)
        B._INSTANCES.pop("echo", None)


def test_backends_are_jit_static():
    # hashable + eq so they ride through jax.jit static args
    assert hash(OracleBackend()) == hash(OracleBackend())
    assert PallasBackend(interpret=True) == PallasBackend(interpret=True)
    assert PallasBackend(interpret=True) != PallasBackend(interpret=False)


# --------------------------------------------------------- no fallback ----
@pytest.mark.parametrize("name", sorted(OBJECTIVES))
def test_pallas_hooks_no_fallback(name):
    """backend="pallas" is total: every shipped objective configuration
    provides both kernel hooks (a None return would silently re-route to the
    jnp oracle and the kernels would stop being exercised)."""
    fn = OBJECTIVES[name]()
    probes = jnp.asarray([1, 42, 99])
    out = fn.pallas_divergence(
        probes, fn.residual_gains(), interpret=True
    )
    assert out is not None and out.shape == (fn.n,)
    g = fn.pallas_gains(fn.empty_state(), interpret=True)
    assert g is not None and g.shape == (fn.n,)


# ------------------------------------------------------ divergence parity ----
@pytest.mark.parametrize("name", sorted(OBJECTIVES))
def test_divergence_parity_oracle_vs_pallas(name):
    fn = OBJECTIVES[name]()
    probes = jnp.asarray([3, 50, 111, 166])
    residual = fn.residual_gains()
    ref = get_backend("oracle").divergence(fn, probes, residual=residual)
    out = PallasBackend(interpret=True).divergence(
        fn, probes, residual=residual
    )
    live = np.ones((fn.n,), bool)
    live[np.asarray(probes)] = False  # probe entries are unspecified (owned by V')
    np.testing.assert_allclose(
        np.asarray(out)[live], np.asarray(ref)[live], rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("name", ["fc_sqrt", "fc_featw", "fl"])
def test_divergence_parity_with_state(name):
    fn = OBJECTIVES[name]()
    state = fn.add_many(fn.empty_state(), jnp.arange(fn.n) < 7)
    probes = jnp.asarray([20, 90, 150])
    residual = fn.residual_gains()
    ref = divergence(fn, probes, residual=residual, state=state)
    out = PallasBackend(interpret=True).divergence(
        fn, probes, residual=residual, state=state
    )
    live = np.ones((fn.n,), bool)
    live[np.asarray(probes)] = False
    np.testing.assert_allclose(
        np.asarray(out)[live], np.asarray(ref)[live], rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("name", ["fc_sqrt", "fc_featw", "fl"])
def test_divergence_parity_probe_mask(name):
    fn = OBJECTIVES[name]()
    probes = jnp.asarray([10, 60, 120])
    mask = jnp.asarray([True, False, True])
    residual = fn.residual_gains()
    ref = divergence(fn, probes, probe_mask=mask, residual=residual)
    out = PallasBackend(interpret=True).divergence(
        fn, probes, probe_mask=mask, residual=residual
    )
    live = np.ones((fn.n,), bool)
    live[[10, 120]] = False  # masked-out probe 60 stays a live candidate
    np.testing.assert_allclose(
        np.asarray(out)[live], np.asarray(ref)[live], rtol=1e-4, atol=1e-4
    )


# ----------------------------------------------------------- gains parity ----
@pytest.mark.parametrize("name", sorted(OBJECTIVES))
def test_gains_parity_oracle_vs_pallas(name):
    fn = OBJECTIVES[name]()
    state = fn.add_many(
        fn.empty_state(), jnp.zeros((fn.n,), bool).at[jnp.asarray([2, 5, 99])].set(True)
    )
    ref = get_backend("oracle").gains(fn, state)
    out = PallasBackend(interpret=True).gains(fn, state)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("name", sorted(OBJECTIVES))
def test_greedy_parity_across_backends(name):
    fn = OBJECTIVES[name]()
    ref = greedy(fn, 6)
    out = greedy(fn, 6, backend=PallasBackend(interpret=True))
    assert list(np.asarray(ref.selected)) == list(np.asarray(out.selected))
    np.testing.assert_allclose(
        float(ref.value), float(out.value), rtol=1e-4
    )


# ------------------------------------------------------- sparsify parity ----
@pytest.mark.parametrize(
    "name",
    ["fc_sqrt", "fc_satcov", "fc_featw", "fc_featw_satcov", "fl", "fl_stream"],
)
def test_ss_sparsify_oracle_pallas_identical(name):
    """Same PRNG stream => identical probe sets; divergences agree to fp
    error, so the retained sets match elementwise."""
    fn = OBJECTIVES[name]()
    key = jax.random.PRNGKey(4)
    ss_o = ss_sparsify(fn, key, r=6, c=8.0)
    ss_p = ss_sparsify(fn, key, r=6, c=8.0, backend=PallasBackend(interpret=True))
    assert bool(jnp.all(ss_o.vprime == ss_p.vprime))
    assert int(ss_o.rounds) == int(ss_p.rounds)


@pytest.mark.parametrize("mk,kw", [
    (make_fc, dict(phi="sqrt")),
    (make_fc, dict(phi="satcov", alpha=0.3)),
    (make_fc, dict(phi="sqrt", feat_w=True)),
    (make_fl, dict(kernel="rbf")),
])
def test_sharded_backend_matches_oracle_value(mk, kw):
    """Acceptance: ss_sparsify(..., backend="sharded") runs both objectives
    end-to-end on a CPU mesh; greedy on the sharded V' matches greedy on the
    oracle V' within 1e-3 relative."""
    fn = mk(n=256, **kw)
    key = jax.random.PRNGKey(0)
    ss_s = ss_sparsify(fn, key, r=8, c=8.0, backend="sharded")
    ss_o = ss_sparsify(fn, key, r=8, c=8.0)
    assert 0 < int(jnp.sum(ss_s.vprime)) < fn.n
    v_s = float(greedy(fn, 8, alive=ss_s.vprime).value)
    v_o = float(greedy(fn, 8, alive=ss_o.vprime).value)
    assert abs(v_s - v_o) / v_o < 1e-3, (v_s, v_o)


def test_sharded_backend_conditional_and_importance_run():
    """Conditional state and importance sampling are supported in the
    sharded SS loop as of PR 5 (quality-parity pins live in
    tests/test_distributed.py; here a 1-device mesh checks the plumbing)."""
    fn = make_fc(n=64, F=16)
    key = jax.random.PRNGKey(0)
    ss = ss_sparsify(fn, key, backend="sharded", importance=True)
    assert 0 < int(jnp.sum(ss.vprime)) <= 64
    state = fn.add_many(fn.empty_state(), jnp.arange(64) < 3)
    ss2 = ss_sparsify(fn, key, backend="sharded", state=state)
    assert 0 < int(jnp.sum(ss2.vprime)) <= 64


def test_sharded_backend_respects_alive():
    fn = make_fc(n=256, F=32)
    alive = jnp.arange(256) < 128
    ss = ss_sparsify(fn, jax.random.PRNGKey(0), alive=alive, backend="sharded")
    assert not bool(jnp.any(ss.vprime[128:]))


def test_fl_pod_sharding_rejected():
    fn = make_fl(n=64)
    assert not fn.supports_pod_sharding
    with pytest.raises(NotImplementedError):
        fn.shard_pack(("pod", "data"))


def test_env_default_backend(monkeypatch):
    monkeypatch.setenv("REPRO_SS_BACKEND", "pallas")
    assert resolve_backend(None).name == "pallas"
    monkeypatch.delenv("REPRO_SS_BACKEND")
    assert resolve_backend(None).name == "oracle"
