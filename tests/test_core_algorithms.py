"""Tests for greedy variants, sieve-streaming, and SS (Algorithm 1):
correctness against brute force, the paper's approximation guarantees as
executable assertions, and SS behavioural properties (shrink rate, |V'|,
certificate eps_hat)."""

import itertools
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FacilityLocation,
    FeatureCoverage,
    greedy,
    lazy_greedy,
    preprune_mask,
    probe_count,
    sieve_streaming,
    ss_sparsify,
    stochastic_greedy,
    summarize,
)
from repro.core.sparsify import max_rounds


def make_fc(seed, n=60, F=24):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    W = jax.random.uniform(k1, (n, F)) * (jax.random.uniform(k2, (n, F)) < 0.3)
    return FeatureCoverage(W=W)


def brute_force_opt(fn, k):
    best, best_set = -1.0, None
    for S in itertools.combinations(range(fn.n), k):
        state = fn.empty_state()
        for v in S:
            state = fn.add(state, jnp.asarray(v))
        val = float(fn.value(state))
        if val > best:
            best, best_set = val, S
    return best, best_set


# ---------------------------------------------------------------- greedy ----
def test_greedy_1_minus_1_over_e_vs_bruteforce():
    fn = make_fc(0, n=12, F=8)
    k = 3
    opt, _ = brute_force_opt(fn, k)
    g = greedy(fn, k)
    assert float(g.value) >= (1 - math.exp(-1)) * opt - 1e-5
    # In practice greedy is near-optimal on these instances.
    assert float(g.value) >= 0.9 * opt


def test_greedy_gains_monotone_decreasing():
    fn = make_fc(1)
    g = greedy(fn, 10)
    gains = np.asarray(g.gains)
    assert np.all(gains[:-1] >= gains[1:] - 1e-4)


def test_greedy_value_equals_sum_of_gains():
    fn = make_fc(2)
    g = greedy(fn, 8)
    assert abs(float(g.value) - float(np.sum(np.asarray(g.gains)))) < 1e-3


def test_greedy_respects_alive_mask():
    fn = make_fc(3)
    alive = jnp.zeros((fn.n,), bool).at[jnp.arange(10)].set(True)
    g = greedy(fn, 5, alive=alive)
    assert np.all(np.asarray(g.selected) < 10)


def test_lazy_greedy_matches_greedy():
    for seed in range(4):
        fn = make_fc(seed, n=40, F=16)
        g = greedy(fn, 6)
        lz = lazy_greedy(fn, 6)
        assert abs(float(g.value) - float(lz.value)) < 1e-3
        assert list(np.asarray(g.selected)) == list(np.asarray(lz.selected))


def test_stochastic_greedy_close_to_greedy():
    fn = make_fc(5, n=80)
    g = greedy(fn, 8)
    sg = stochastic_greedy(fn, 8, jax.random.PRNGKey(0), s=40)
    assert float(sg.value) >= 0.85 * float(g.value)


# ----------------------------------------------------------------- sieve ----
def test_sieve_streaming_half_guarantee():
    """Sieve-streaming guarantees (1/2 - eps) OPT; check against greedy
    (>= OPT(1-1/e)), so sieve >= ~0.5/(1) * greedy-ish. Use a loose bound."""
    for seed in range(3):
        fn = make_fc(seed, n=70)
        g = greedy(fn, 8)
        sv = sieve_streaming(fn, 8)
        assert float(sv.value) >= 0.45 * float(g.value)
        # and never better than greedy by much (sanity)
        assert float(sv.value) <= float(g.value) * 1.001


def test_sieve_selection_consistent_with_value():
    fn = make_fc(7, n=50)
    sv = sieve_streaming(fn, 6)
    sel = [int(v) for v in np.asarray(sv.selected) if v >= 0]
    state = fn.empty_state()
    for v in sel:
        state = fn.add(state, jnp.asarray(v))
    assert abs(float(fn.value(state)) - float(sv.value)) < 1e-3


def test_sieve_stream_order_invariance_of_guarantee():
    fn = make_fc(8, n=60)
    g = greedy(fn, 6)
    perm = jax.random.permutation(jax.random.PRNGKey(1), fn.n)
    sv = sieve_streaming(fn, 6, stream=perm)
    assert float(sv.value) >= 0.45 * float(g.value)


# -------------------------------------------------------------------- SS ----
def test_ss_runs_and_shrinks():
    fn = make_fc(9, n=400, F=32)
    ss = ss_sparsify(fn, jax.random.PRNGKey(0))
    n_vp = int(jnp.sum(ss.vprime))
    assert 0 < n_vp < fn.n
    assert int(ss.rounds) <= max_rounds(fn.n)


def test_ss_shrink_rate_per_round():
    """Each round removes ~ (1 - 1/sqrt(c)) of live elements + m probes."""
    n = 2048
    fn = make_fc(10, n=n, F=16)
    c = 8.0
    ss = ss_sparsify(fn, jax.random.PRNGKey(0), c=c)
    m = probe_count(n)
    trace = [t for t in np.asarray(ss.alive_trace) if t >= 0]
    live = n
    for t in trace:
        expected = (live - m) - math.floor((live - m) * (1 - 1 / math.sqrt(c)))
        assert abs(t - expected) <= 1, (t, expected)
        live = t


def test_ss_quality_against_greedy():
    """The paper's headline empirical claim: greedy on V' ~= greedy on V
    (relative utility >= 0.95 across seeds; paper reports >= 0.97-0.99)."""
    ratios = []
    for seed in range(5):
        fn = make_fc(seed, n=300, F=48)
        g = greedy(fn, 10)
        res, ss = summarize(fn, 10, jax.random.PRNGKey(seed))
        ratios.append(float(res.value) / float(g.value))
    assert min(ratios) >= 0.9
    assert float(np.mean(ratios)) >= 0.95


def test_ss_theorem1_certificate():
    """f(S') >= (1 - 1/e)(f(S*) - k*eps_hat) with eps_hat the SS certificate
    and f(S*) <= f(greedy)/(1-1/e) (so the test is conservative)."""
    fn = make_fc(11, n=200, F=32)
    k = 8
    g = greedy(fn, k)
    res, ss = summarize(fn, k, jax.random.PRNGKey(3))
    opt_ub = float(g.value) / (1 - math.exp(-1))
    bound = (1 - math.exp(-1)) * (opt_ub - k * float(ss.eps_hat))
    assert float(res.value) >= min(bound, float(g.value)) - 1e-3


def test_ss_vprime_includes_tail():
    """When |V| <= r log n the loop stops and the remainder joins V'."""
    fn = make_fc(12, n=40, F=16)  # 40 < 8*log2(40) ~ 42 -> 0 rounds
    ss = ss_sparsify(fn, jax.random.PRNGKey(0))
    assert int(ss.rounds) == 0
    assert bool(jnp.all(ss.vprime))


def test_ss_respects_initial_alive():
    fn = make_fc(13, n=300)
    alive = jnp.arange(fn.n) < 150
    ss = ss_sparsify(fn, jax.random.PRNGKey(0), alive=alive)
    assert not bool(jnp.any(ss.vprime[150:]))


def test_ss_importance_sampling_works():
    fn = make_fc(14, n=300)
    ss = ss_sparsify(fn, jax.random.PRNGKey(0), importance=True)
    g = greedy(fn, 10)
    res = greedy(fn, 10, alive=ss.vprime)
    assert float(res.value) >= 0.9 * float(g.value)


def test_postreduce_shrinks_and_covers():
    """§3.4 improvement 3: the bidirectional post-reduction returns a subset
    of V' whose members still eps-cover every pruned element that the chosen
    eps can cover (h is maximized by construction, and the scatter back to
    ground indices must be exact)."""
    from repro.core.sparsify import postreduce
    from repro.core import graph

    fn = make_fc(20, n=120, F=24)
    key = jax.random.PRNGKey(0)
    ss = ss_sparsify(fn, key, r=6, c=8.0)
    eps = float(ss.eps_hat) + 1e-3
    new_vp = postreduce(fn, ss, eps, jax.random.PRNGKey(1))
    # subset of the original V', nothing new invented
    assert bool(jnp.all(~new_vp | ss.vprime))
    assert int(jnp.sum(new_vp)) <= int(jnp.sum(ss.vprime))
    assert int(jnp.sum(new_vp)) > 0


def test_preprune_is_safe():
    """Wei-et-al rule must not hurt greedy's achievable value."""
    fn = make_fc(15, n=120)
    k = 6
    mask = preprune_mask(fn, k)
    assert int(jnp.sum(mask)) >= k
    g_full = greedy(fn, k)
    g_pruned = greedy(fn, k, alive=mask)
    assert float(g_pruned.value) >= 0.999 * float(g_full.value)


def test_ss_facility_location():
    X = jax.random.normal(jax.random.PRNGKey(0), (250, 12))
    fn = FacilityLocation.from_features(X, kernel="rbf")
    g = greedy(fn, 10)
    res, ss = summarize(fn, 10, jax.random.PRNGKey(1))
    assert float(res.value) >= 0.93 * float(g.value)


def test_ss_vprime_size_scales_polylog():
    """|V'| = O(log^2 n): growing n 4x should grow |V'| far less than 4x."""
    sizes, vps = [256, 1024], []
    for n in sizes:
        fn = make_fc(16, n=n, F=16)
        ss = ss_sparsify(fn, jax.random.PRNGKey(0))
        vps.append(int(jnp.sum(ss.vprime)))
    assert vps[1] < vps[0] * 2.5  # 4x data -> ~(log ratio)^2 ~= 1.5x


def test_conditional_ss_on_graph_given_s():
    """SS on the conditional graph G(V, E|S) (paper §3, 'SS can be easily
    extended to G(V, E|S)'): sparsify conditioned on a partial solution and
    check greedy-on-V' still matches greedy continuing from S."""
    import jax
    import jax.numpy as jnp
    from repro.core import FeatureCoverage, greedy
    from repro.core.sparsify import ss_sparsify

    key = jax.random.PRNGKey(11)
    W = jax.random.uniform(key, (200, 64))
    fn = FeatureCoverage(W=W, phi="sqrt")
    # condition on a 5-element prefix S
    prefix = greedy(fn, 5)
    state = prefix.state
    ss = ss_sparsify(fn, key, r=6, c=8.0, state=state)
    # keep the prefix out of the candidate pool either way
    avail = ss.vprime.at[prefix.selected].set(False)
    res_cond = greedy(fn, 5, alive=avail)
    full_avail = jnp.ones((200,), bool).at[prefix.selected].set(False)
    res_full = greedy(fn, 5, alive=full_avail)
    # compare the *continuations* from the shared state
    def continue_from(sel):
        st = state
        for i in range(5):
            st = fn.add(st, sel[i])
        return float(fn.value(st))
    v_cond = continue_from(res_cond.selected)
    v_full = continue_from(res_full.selected)
    assert v_cond >= 0.95 * v_full, (v_cond, v_full)


def test_facility_location_ss_end_to_end():
    """SS + greedy under the facility-location objective (the paper's other
    graph-based objective family)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import FacilityLocation, greedy
    from repro.core.sparsify import ss_sparsify

    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.random((300, 16), np.float32))
    fn = FacilityLocation.from_features(X, kernel="cosine")
    ref = greedy(fn, 8)
    ss = ss_sparsify(fn, jax.random.PRNGKey(0), r=8, c=8.0)
    red = greedy(fn, 8, alive=ss.vprime)
    assert int(jnp.sum(ss.vprime)) < 300
    assert float(red.value / ref.value) > 0.95
