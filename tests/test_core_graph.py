"""Tests for the submodularity graph: Lemmas 1-3 of the paper as executable
properties, plus divergence bookkeeping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # skips @given tests only

from repro.core import graph
from repro.core.functions import FacilityLocation, FeatureCoverage


def make_fc(seed: int, n: int = 16, F: int = 10) -> FeatureCoverage:
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    W = jax.random.uniform(k1, (n, F)) * (jax.random.uniform(k2, (n, F)) < 0.5)
    return FeatureCoverage(W=W)


def make_fl(seed: int, n: int = 14) -> FacilityLocation:
    X = jax.random.normal(jax.random.PRNGKey(seed), (n, 5))
    return FacilityLocation.from_features(X, kernel="rbf")


@pytest.mark.parametrize("mk", [make_fc, make_fl])
@given(seed=st.integers(0, 10))
@settings(max_examples=10, deadline=None)
def test_triangle_inequality_lemma3(mk, seed):
    """Lemma 3: w_vx <= w_vu + w_ux for all triples."""
    fn = mk(seed)
    W = graph.full_edge_matrix(fn)
    viol = float(graph.check_triangle_inequality(W))
    assert viol <= 1e-3, f"triangle inequality violated by {viol}"


@pytest.mark.parametrize("mk", [make_fc, make_fl])
def test_lemma2_marginal_gain_bound(mk):
    """Lemma 2: f(v|S) <= f(u|S) + w_uv|S for u != v not in S."""
    fn = mk(2)
    S = [0, 3]
    state = fn.empty_state()
    for x in S:
        state = fn.add(state, jnp.asarray(x))
    g = np.asarray(fn.gains(state))
    n = fn.n
    Wc = np.asarray(
        graph.edge_weights(fn, jnp.arange(n), state=state)
    )  # (n, n): rows u, cols v
    for u in range(n):
        for v in range(n):
            if u == v or u in S or v in S:
                continue
            assert g[v] <= g[u] + Wc[u, v] + 1e-3


def test_lemma1_conditional_monotone():
    """Lemma 1: w_uv|S <= w_uv|P for P ⊆ S."""
    fn = make_fc(3)
    sP = fn.add(fn.empty_state(), jnp.asarray(1))
    sS = fn.add(sP, jnp.asarray(2))
    probes = jnp.asarray([0, 5, 7])
    wP = np.asarray(graph.edge_weights(fn, probes, state=sP))
    wS = np.asarray(graph.edge_weights(fn, probes, state=sS))
    assert np.all(wS <= wP + 1e-4)


def test_divergence_is_min_over_probes():
    fn = make_fc(4)
    probes = jnp.asarray([0, 2, 9])
    W = np.asarray(graph.edge_weights(fn, probes))
    d = np.asarray(graph.divergence(fn, probes))
    np.testing.assert_allclose(d, W.min(axis=0), atol=1e-5)


def test_divergence_probe_mask_excludes():
    fn = make_fc(5)
    probes = jnp.asarray([0, 2, 9])
    mask = jnp.asarray([True, False, True])
    d_masked = np.asarray(graph.divergence(fn, probes, probe_mask=mask))
    d_sub = np.asarray(graph.divergence(fn, jnp.asarray([0, 9])))
    np.testing.assert_allclose(d_masked, d_sub, atol=1e-5)


def test_divergence_update_running_min():
    fn = make_fc(6)
    d1 = graph.divergence(fn, jnp.asarray([0, 1]))
    d2 = graph.divergence_update(fn, d1, jnp.asarray([2, 3]))
    d_all = graph.divergence(fn, jnp.asarray([0, 1, 2, 3]))
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d_all), atol=1e-5)


def test_edge_weight_definition():
    """w_uv = f(v|u) - f(u|V\\u) elementwise (Eq. 3)."""
    fn = make_fc(7)
    probes = jnp.asarray([3, 8])
    W = np.asarray(graph.edge_weights(fn, probes))
    pair = np.asarray(fn.pairwise_gains(probes))
    res = np.asarray(fn.residual_gains())
    np.testing.assert_allclose(W, pair - res[np.asarray([3, 8])][:, None], atol=1e-5)


def test_self_edge_nonpositive():
    """w_uu = f(u|u) - f(u|V\\u) = -f(u|V\\u) <= 0 (used in Prop. 1 proof)."""
    fn = make_fc(8)
    W = np.asarray(graph.full_edge_matrix(fn))
    assert np.all(np.diag(W) <= 1e-5)
